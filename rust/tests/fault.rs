//! Fault-plane acceptance pins: the deterministic failure-injection
//! contract end to end.  Faults off must be bit-identical (outputs AND
//! timestamps) to the fault-free engine; a fault seed must replay
//! byte-identically at any worker-thread count; a CSD death mid-decode
//! must recover to the exact fault-free outputs under both re-prefill
//! and replicated recovery; and the recovery work must stay inside the
//! exclusive attribution buckets' wall-time identity.

use instinfer::coordinator::{
    run_open_loop, EngineConfig, InferenceEngine, SchedConfig, ServeReport,
};
use instinfer::fault::{FaultConfig, RecoveryPolicy};
use instinfer::obs::{self, attr, TraceLevel};
use instinfer::runtime::Runtime;
use instinfer::workload::{ArrivalGen, LengthProfile, WorkloadGen};

/// The serve-bench recipe at 2 head-striped CSDs: 8 fixed-seed Poisson
/// arrivals, prompt 16, gen 8 — long enough that a midpoint loss lands
/// while decode is in flight.
fn serve(fault: Option<FaultConfig>, threads: usize) -> (InferenceEngine, ServeReport) {
    let rt = Runtime::open("artifacts").unwrap();
    let meta = rt.manifest.model.clone();
    let mut cfg = EngineConfig::micro_for(&meta, 2, false).threads(threads);
    if let Some(f) = fault {
        cfg = cfg.faults(f);
    }
    let mut engine = InferenceEngine::new(rt, cfg).unwrap();
    let wg = WorkloadGen::new(777, meta.vocab, meta.max_seq, LengthProfile::Fixed, 16, 8);
    let arrivals = ArrivalGen::new(wg, 778, 100.0).take(8);
    let report = run_open_loop(&mut engine, arrivals, SchedConfig::serving(4, 2, 16)).unwrap();
    (engine, report)
}

/// Everything observable about one traced run, folded into a comparable
/// bundle: `(id, tokens, arrival/TTFT/finish timestamps)` per request,
/// the unified metrics snapshot, and the full-level trace bytes.
fn traced_bundle(
    fault: Option<FaultConfig>,
    threads: usize,
) -> (Vec<(u64, Vec<i32>, String)>, String, String) {
    obs::install(TraceLevel::Full);
    let (engine, report) = serve(fault, threads);
    let sink = obs::uninstall().unwrap();
    let mut recs = report.records.clone();
    recs.sort_by_key(|r| r.id);
    let outputs = recs
        .iter()
        .map(|r| {
            (
                r.id,
                r.generated.clone(),
                format!("{:.9}/{:.9}/{:.9}", r.arrived_at, r.first_token_at, r.finished_at),
            )
        })
        .collect();
    let metrics = engine.metrics_registry(&report.overlap).to_json().to_string();
    (outputs, metrics, sink.export())
}

/// Sorted `(id, generated)` pairs — the output-only view used where
/// recovery legitimately shifts timestamps but must not touch tokens.
fn outputs_of(report: &ServeReport) -> Vec<(u64, Vec<i32>)> {
    let mut out: Vec<(u64, Vec<i32>)> =
        report.records.iter().map(|r| (r.id, r.generated.clone())).collect();
    out.sort_by_key(|(id, _)| *id);
    out
}

/// A scheduled loss of csd1 at the midpoint of the healthy run, plus
/// per-op injection at `rate`.
fn loss_config(rate: f64, recovery: RecoveryPolicy, replicas: u8) -> FaultConfig {
    let (_, probe) = serve(None, 1);
    FaultConfig {
        seed: 7,
        rate,
        csd_loss: Some((1, probe.sim_end * 0.5)),
        recovery,
        kv_replicas: replicas,
    }
}

/// Pin 1: `FaultConfig::none()` constructs no fault state at all — the
/// run is bit-identical (outputs, simulated timestamps, metrics
/// snapshot, trace bytes) to an engine built without the fault plane.
#[test]
fn faults_off_is_bit_identical_to_fault_free_engine() {
    let plain = traced_bundle(None, 1);
    let off = traced_bundle(Some(FaultConfig::none()), 1);
    assert_eq!(off.0, plain.0, "faults-off perturbed outputs or timestamps");
    assert_eq!(off.1, plain.1, "faults-off perturbed the metrics snapshot");
    assert_eq!(off.2, plain.2, "faults-off perturbed the trace bytes");
}

/// Pin 2: the fault sequence rides the per-device command order, which
/// the parallel executor keeps thread-count invariant — so one seed
/// replays byte-identically (outputs, timestamps, metrics, trace) at
/// any `--threads`, faults, loss, recovery and all.
#[test]
fn same_seed_fault_run_is_thread_count_invariant() {
    let fault = loss_config(2e-3, RecoveryPolicy::Replicated, 1);
    let base = traced_bundle(Some(fault), 1);
    for n in [2usize, 4] {
        let run = traced_bundle(Some(fault), n);
        assert_eq!(run.0, base.0, "fault outputs/timestamps diverged at {n} threads");
        assert_eq!(run.1, base.1, "fault metrics snapshot diverged at {n} threads");
        assert_eq!(run.2, base.2, "fault trace bytes diverged at {n} threads");
    }
}

/// Pin 3: a whole-CSD death mid-decode recovers to the exact fault-free
/// outputs — greedy decode is deterministic, so re-prefill and the peer
/// replica must both reconstruct the lost KV bit-exactly and every
/// request must finish with the same tokens it would have produced on a
/// healthy array.
#[test]
fn csd_loss_recovers_exact_outputs_under_reprefill_and_replicated() {
    let (_, reference) = serve(None, 1);
    let want = outputs_of(&reference);
    for (recovery, replicas) in
        [(RecoveryPolicy::RePrefill, 0u8), (RecoveryPolicy::Replicated, 1)]
    {
        let fault = loss_config(0.0, recovery, replicas);
        let (engine, report) = serve(Some(fault), 1);
        let label = recovery.label();
        let reg = engine.metrics_registry(&report.overlap);
        assert_eq!(reg.value("fault.csd_losses"), Some(1.0), "{label}: loss never fired");
        match recovery {
            // re-prefill recovers by restarting the in-flight requests
            // (the replacement device itself comes up instantly)
            RecoveryPolicy::RePrefill => assert!(
                engine.metrics.restarts > 0,
                "{label}: loss mid-decode restarted no requests"
            ),
            // the replica restore is a timed peer-to-peer copy
            RecoveryPolicy::Replicated => {
                assert_eq!(reg.value("fault.recoveries"), Some(1.0), "{label}: no restore");
                assert!(
                    engine.metrics.recovery_s > 0.0,
                    "{label}: restore took no simulated time"
                );
            }
            RecoveryPolicy::RetryOnly => unreachable!(),
        }
        assert_eq!(report.aborted_count(), 0, "{label}: recovery aborted requests");
        assert_eq!(outputs_of(&report), want, "{label}: outputs diverged from fault-free run");
    }
}

/// Pin 4: recovery work lands in its own exclusive attribution bucket
/// without breaking the per-request identity — buckets still sum to
/// measured wall time within 1e-6 relative, and the recovery bucket
/// actually carries the restore cost.
#[test]
fn recovery_attribution_preserves_wall_time_identity() {
    let fault = loss_config(2e-3, RecoveryPolicy::Replicated, 1);
    attr::install();
    let (_, report) = serve(Some(fault), 1);
    let sink = attr::uninstall().expect("attr sink should still be installed");
    let rep = attr::extract(&sink);
    assert_eq!(report.aborted_count(), 0, "replicated recovery aborted requests");
    assert!(!rep.requests.is_empty(), "no attributed requests");
    for r in &rep.requests {
        let tol = 1e-6 * r.wall.max(1e-9);
        let sum: f64 = r.buckets.iter().sum();
        assert!(
            (sum - r.wall).abs() <= tol,
            "req {} buckets sum {sum} != wall {} under faults",
            r.req,
            r.wall,
        );
    }
    let recovered: f64 =
        rep.requests.iter().map(|r| r.buckets[attr::Bucket::Recovery.index()]).sum();
    assert!(recovered > 0.0, "replicated recovery attributed no time to the recovery bucket");
}
