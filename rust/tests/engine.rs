//! End-to-end functional-plane integration: the coordinator drives the
//! PJRT artifacts and the in-storage CSD engines through real prefill +
//! decode, and the two attention backends agree.

use instinfer::coordinator::{EngineConfig, InferenceEngine, Sequence, SlotManager};
use instinfer::coordinator::engine::AttnBackend;
use instinfer::csd::AttnMode;
use instinfer::runtime::Runtime;
use instinfer::workload::{LengthProfile, WorkloadGen};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
}

fn engine(cfg: EngineConfig) -> InferenceEngine {
    let rt = Runtime::open(artifacts_dir()).expect("run `make artifacts` first");
    InferenceEngine::new(rt, cfg).unwrap()
}

fn mk_seqs(n: usize, prompt_len: usize, gen: usize, slots: &mut SlotManager) -> Vec<Sequence> {
    let mut wg = WorkloadGen::new(7, 512, 128, LengthProfile::Fixed, prompt_len, gen);
    wg.batch(n)
        .into_iter()
        .map(|r| Sequence::new(r, slots.alloc().unwrap()))
        .collect()
}

#[test]
fn generate_batch_in_storage_dense() {
    let mut eng = engine(EngineConfig::micro(2));
    let mut slots = SlotManager::new(8);
    let seqs = mk_seqs(3, 12, 6, &mut slots);
    let done = eng.generate(seqs, 4).unwrap();
    for s in &done {
        assert_eq!(s.generated.len(), 6);
        assert!(s.generated.iter().all(|&t| (0..512).contains(&t)));
    }
    assert!(eng.metrics.tokens_generated >= 18);
    assert!(eng.metrics.csd_sim_s > 0.0, "CSD device time must accrue");
    assert!(eng.sim_now > 0.0);
    // determinism: same run again gives identical tokens
    let mut eng2 = engine(EngineConfig::micro(2));
    let mut slots2 = SlotManager::new(8);
    let done2 = eng2.generate(mk_seqs(3, 12, 6, &mut slots2), 4).unwrap();
    for (a, b) in done.iter().zip(&done2) {
        assert_eq!(a.generated, b.generated);
    }
}

#[test]
fn csd_backend_matches_gpu_artifact_backend() {
    // The in-storage path (FP16 pages, rust-native engine) and the PJRT
    // artifact path must produce the same generations at this scale —
    // the FP16 quantisation noise is far below the micro model's logit
    // margins for the first several tokens.
    let mut a = engine(EngineConfig::micro(1));
    let mut b = engine(EngineConfig {
        backend: AttnBackend::GpuArtifact { sparse: false },
        ..EngineConfig::micro(1)
    });
    let mut s1 = SlotManager::new(8);
    let mut s2 = SlotManager::new(8);
    let da = a.generate(mk_seqs(2, 10, 5, &mut s1), 4).unwrap();
    let db = b.generate(mk_seqs(2, 10, 5, &mut s2), 4).unwrap();
    let ta: Vec<_> = da.iter().map(|s| s.generated.clone()).collect();
    let tb: Vec<_> = db.iter().map(|s| s.generated.clone()).collect();
    // require near-total agreement (allow one late-step divergence)
    let agree: usize = ta
        .iter()
        .flatten()
        .zip(tb.iter().flatten())
        .filter(|(x, y)| x == y)
        .count();
    assert!(agree >= 9, "only {agree}/10 tokens agree: {ta:?} vs {tb:?}");
}

#[test]
fn sparf_backend_generates_and_reads_fewer_pages() {
    let m = Runtime::open(artifacts_dir()).unwrap().manifest.model.clone();
    let mut dense = engine(EngineConfig::micro_for(&m, 1, false));
    let mut sparse = engine(EngineConfig::micro_for(&m, 1, true));
    let mut s1 = SlotManager::new(8);
    let mut s2 = SlotManager::new(8);
    let d1 = dense.generate(mk_seqs(2, 24, 6, &mut s1), 4).unwrap();
    let d2 = sparse.generate(mk_seqs(2, 24, 6, &mut s2), 4).unwrap();
    assert!(d1.iter().all(|s| s.generated.len() == 6));
    assert!(d2.iter().all(|s| s.generated.len() == 6));
    let reads_dense = dense.csds()[0].csd.ftl.array.counters.page_reads;
    let reads_sparse = sparse.csds()[0].csd.ftl.array.counters.page_reads;
    assert!(
        reads_sparse < reads_dense,
        "sparf {reads_sparse} !< dense {reads_dense} page reads"
    );
    // sparse and dense mostly agree on tokens (accuracy premise)
    let agree: usize = d1
        .iter()
        .flat_map(|s| &s.generated)
        .zip(d2.iter().flat_map(|s| &s.generated))
        .filter(|(x, y)| x == y)
        .count();
    assert!(agree >= 8, "sparse/dense agreement too low: {agree}/12");
}

#[test]
fn multi_csd_routing_is_transparent() {
    // 1-CSD and 3-CSD deployments must generate identical tokens
    let mut e1 = engine(EngineConfig::micro(1));
    let mut e3 = engine(EngineConfig::micro(3));
    let mut s1 = SlotManager::new(8);
    let mut s3 = SlotManager::new(8);
    let d1 = e1.generate(mk_seqs(2, 8, 5, &mut s1), 4).unwrap();
    let d3 = e3.generate(mk_seqs(2, 8, 5, &mut s3), 4).unwrap();
    for (a, b) in d1.iter().zip(&d3) {
        assert_eq!(a.generated, b.generated);
    }
    // and the 3-CSD run finishes its simulated step earlier (parallel heads)
    assert!(e3.sim_now < e1.sim_now, "3 CSDs {} !< 1 CSD {}", e3.sim_now, e1.sim_now);
}

#[test]
fn slot_reuse_after_free() {
    // run two batches back-to-back through the same engine: slots are
    // freed on completion so capacity never runs out
    let mut eng = engine(EngineConfig::micro(1));
    let mut slots = SlotManager::new(4);
    for _ in 0..3 {
        let seqs = mk_seqs(4, 8, 3, &mut slots);
        let done = eng.generate(seqs, 4).unwrap();
        for s in &done {
            slots.release(s.slot).unwrap();
        }
    }
    assert_eq!(slots.free_count(), 4);
    assert!(eng.csds()[0].csd.ftl.free_blocks() > 0);
}

#[test]
fn prompt_validation() {
    let mut eng = engine(EngineConfig::micro(1));
    let mut slots = SlotManager::new(2);
    // prompt longer than prefill_seq must be rejected cleanly
    let mut seqs = mk_seqs(1, 64, 2, &mut slots);
    seqs[0].req.prompt = (0..65).collect();
    let err = eng.prefill(&mut seqs, 1).unwrap_err().to_string();
    assert!(err.contains("prompt length"), "{err}");
}
