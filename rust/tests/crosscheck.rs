//! Cross-language, cross-implementation agreement:
//!
//!   jax ref (python)  ==  pallas kernel  ==  PJRT artifact (golden.bin)
//!                                         ==  rust-native sparse lib
//!
//! The first two equalities are enforced by pytest; golden.rs pins the
//! artifact to the jax outputs; this file closes the square by running
//! the rust-native attention (what the CSD engine computes) on the exact
//! golden inputs and comparing against the recorded jax outputs.

use instinfer::runtime::golden::read_golden_tensor;
use instinfer::runtime::Runtime;
use instinfer::sparse;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
}

struct AttnCase {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    lens: Vec<f32>,
    want: Vec<f32>,
    heads: usize,
    smax: usize,
    d: usize,
}

fn load_case(exe: &str) -> Option<AttnCase> {
    let rt = Runtime::open(artifacts_dir()).expect("opening runtime");
    let g = match rt.manifest.golden.get(exe) {
        Some(g) => g.clone(),
        None => {
            eprintln!(
                "skipping: no golden record for {exe} in {} \
                 (run `make artifacts` in a jax container to record it)",
                artifacts_dir().display()
            );
            return None;
        }
    };
    let mut f = std::fs::File::open(rt.manifest.dir.join("golden.bin")).unwrap();
    let by_name = |n: &str| g.inputs.iter().find(|r| r.name == n).unwrap();
    let q = read_golden_tensor(&mut f, by_name("q")).unwrap();
    let k = read_golden_tensor(&mut f, by_name("K")).unwrap();
    let v = read_golden_tensor(&mut f, by_name("V")).unwrap();
    let lens = read_golden_tensor(&mut f, by_name("lens")).unwrap();
    let want = read_golden_tensor(&mut f, &g.outputs[0]).unwrap();
    let m = &rt.manifest.model;
    Some(AttnCase {
        heads: m.n_heads,
        smax: m.max_seq,
        d: m.d_head,
        q: q.as_f32().unwrap().to_vec(),
        k: k.as_f32().unwrap().to_vec(),
        v: v.as_f32().unwrap().to_vec(),
        lens: lens.as_f32().unwrap().to_vec(),
        want: want.as_f32().unwrap().to_vec(),
    })
}

#[test]
fn rust_dense_attention_matches_jax_golden() {
    let Some(c) = load_case("attn_dense") else {
        return;
    };
    let (h, s, d) = (c.heads, c.smax, c.d);
    let len = c.lens[0] as usize;
    for hh in 0..h {
        let q = &c.q[hh * d..(hh + 1) * d];
        let k = &c.k[hh * s * d..(hh + 1) * s * d];
        let v = &c.v[hh * s * d..(hh + 1) * s * d];
        let out = sparse::dense_attention(q, k, v, len);
        for (a, b) in out.iter().zip(&c.want[hh * d..(hh + 1) * d]) {
            assert!((a - b).abs() < 1e-4, "head {hh}: {a} vs {b}");
        }
    }
}

#[test]
fn rust_sparf_attention_matches_jax_golden() {
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let m = rt.manifest.model.clone();
    let sp = m.sparsity();
    let Some(c) = load_case("attn_sparf") else {
        return;
    };
    let (h, s, d) = (c.heads, c.smax, c.d);
    let len = c.lens[0] as usize;
    for hh in 0..h {
        let q = &c.q[hh * d..(hh + 1) * d];
        let k = &c.k[hh * s * d..(hh + 1) * s * d];
        let v = &c.v[hh * s * d..(hh + 1) * s * d];
        let vbar = sparse::v_mean(v, d, len);
        let out = sparse::sparf_attention(q, k, v, &vbar, len, &sp);
        for (a, b) in out.out.iter().zip(&c.want[hh * d..(hh + 1) * d]) {
            assert!(
                (a - b).abs() < 5e-4,
                "head {hh}: rust {a} vs jax {b} (alpha={})",
                out.alpha
            );
        }
    }
}

#[test]
fn analytic_csd_model_tracks_functional_engine() {
    // DESIGN.md §5: the OPT-13B-scale analytic model and the functional
    // DES engine share constants; at micro scale their flash-byte counts
    // must agree within the group-overfetch tolerance.
    use instinfer::config::hw::CsdSpec;
    use instinfer::csd::{AttnMode, InstCsd};
    use instinfer::ftl::{FtlConfig, StreamKey};
    use instinfer::util::rng::Rng;

    let mut rng = Rng::new(21);
    let d = 32usize;
    let s_len = 96usize;
    assert_eq!(d, FtlConfig::micro_head().d_head, "micro model head dim");
    let mut csd = InstCsd::new(CsdSpec::micro(), FtlConfig::micro_head()).unwrap();
    for t in 0..s_len {
        let kr: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let vr: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        csd.write_token(0, 0, &kr, &vr, t as f64).unwrap();
    }
    let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    let key = StreamKey { slot: 0, layer: 0, head: 0 };
    let before = csd.ftl.array.counters.bytes_read;
    csd.attention_head(key, &q, s_len, AttnMode::Dense, 0.0).unwrap();
    let measured = (csd.ftl.array.counters.bytes_read - before) as f64;
    // analytic dense bytes for one head at this context
    let shape = instinfer::config::model::ModelShape {
        d_head: d,
        ..instinfer::config::model::ModelShape::opt_micro()
    };
    let analytic = instinfer::systems::insti::dense_head_flash_bytes(&shape, s_len);
    let ratio = measured / analytic;
    assert!(
        (0.9..1.5).contains(&ratio),
        "functional {measured} vs analytic {analytic} (ratio {ratio})"
    );
}

#[test]
fn ftl_write_amplification_matches_dual_k_model() {
    // K stored twice + V once over host K+V bytes => WA -> 1.5 as pages
    // fill completely (n=8 and t_emb=64 divide 96 evenly enough)
    use instinfer::ftl::{FtlConfig, KvFtl, StreamKey};
    use instinfer::util::rng::Rng;
    let mut rng = Rng::new(5);
    let mut ftl = KvFtl::new(
        instinfer::config::hw::FlashSpec::tiny(),
        FtlConfig::micro_head(),
    )
    .unwrap();
    let key = StreamKey { slot: 0, layer: 0, head: 0 };
    for _ in 0..128 {
        let kr: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        let vr: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        ftl.append_token(key, &kr, &vr, 0.0).unwrap();
    }
    let wa = ftl.write_amplification();
    assert!((1.45..1.55).contains(&wa), "WA {wa} (expect ~1.5: dual K)");
}
