//! Cross-request prefix caching crosschecks.
//!
//! The load-bearing guarantees:
//!
//! 1. Prefix caching never changes generated tokens: functional prefill
//!    always runs in full, so a 100%-hit request is bit-identical to its
//!    cold-path twin while its prompt KV is attached by reference from
//!    the donor's sealed flash pages (zero suffix shipping).
//! 2. With the cache off (the default), the engine takes the exact
//!    pre-PR path — `tests/pipeline.rs` pins outputs AND timestamps
//!    against the serialized reference replay; here we pin that the
//!    off-path never touches the prefix machinery.
//! 3. The cached-prefix admission split composes with the overlapped
//!    prefill/decode executor: same outputs, either stream layout.
//! 4. The `bench prefix` evidence run is monotone: more shared prompt
//!    (higher share ratio) means fewer prompt tokens shipped at prefill
//!    and more tokens attached by reference.

use instinfer::bench::prefix::run_config;
use instinfer::coordinator::{
    run_closed_loop, run_open_loop, EngineConfig, InferenceEngine, SchedConfig,
};
use instinfer::runtime::Runtime;
use instinfer::workload::{ArrivalGen, PrefixWorkloadGen, Request, RequestSource};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
}

fn engine(n_csds: usize, prefix_on: bool) -> InferenceEngine {
    let rt = Runtime::open(artifacts_dir()).expect("opening runtime");
    let meta = rt.manifest.model.clone();
    let cfg = EngineConfig::micro_for(&meta, n_csds, false).prefix_cached(prefix_on);
    InferenceEngine::new(rt, cfg).unwrap()
}

/// Two requests with the SAME group-aligned prompt: the first is the
/// donor (registers its sealed prefix at ship-done), the second is a
/// 100% hit.  Single seat + chunk 1 so the donor completes before the
/// twin is admitted.
fn twin_requests(engine: &InferenceEngine) -> Vec<Request> {
    let m = &engine.rt.manifest.model;
    // 3 full token groups at the micro model's n=8
    let plen = 3 * m.n;
    let prompt: Vec<i32> = (0..plen as i32).map(|i| (i * 7 + 3) % m.vocab as i32).collect();
    vec![
        Request { id: 0, prompt: prompt.clone(), max_new_tokens: 6 },
        Request { id: 1, prompt, max_new_tokens: 6 },
    ]
}

fn prefix_counters(engine: &InferenceEngine) -> (u64, u64, u64) {
    let (mut regs, mut attaches, mut toks) = (0u64, 0u64, 0u64);
    for q in engine.csds() {
        regs += q.csd.ftl.counters.prefix_registrations;
        attaches += q.csd.ftl.counters.prefix_attaches;
        toks += q.csd.ftl.counters.prefix_tokens_attached;
    }
    (regs, attaches, toks)
}

#[test]
fn full_hit_request_is_bit_identical_to_its_cold_twin() {
    // ISSUE acceptance: a second request whose prompt is 100% cached
    // produces bit-identical outputs to the same request served cold.
    let mut cold = engine(2, false);
    let mut warm = engine(2, true);
    let reqs = twin_requests(&cold);
    let sched = SchedConfig::serving(1, 1, 8);
    let rc = run_closed_loop(&mut cold, reqs.clone(), sched.clone()).unwrap();
    let rw = run_closed_loop(&mut warm, reqs, sched).unwrap();

    let key = |r: &instinfer::coordinator::ServeReport| {
        let mut t: Vec<(u64, Vec<i32>)> =
            r.records.iter().map(|x| (x.id, x.generated.clone())).collect();
        t.sort_by_key(|(id, _)| *id);
        t
    };
    assert_eq!(key(&rc), key(&rw), "prefix hit changed generated tokens");
    // identical prompts, deterministic engine: the twin's tokens equal
    // the donor's on BOTH paths
    let toks = key(&rw);
    assert_eq!(toks[0].1, toks[1].1);

    // the warm engine really took the cached path for the whole prompt
    let plen = 3 * warm.rt.manifest.model.n;
    assert_eq!(warm.metrics.prefix_hit_tokens, plen as u64);
    let (regs, attaches, attached) = prefix_counters(&warm);
    assert!(regs > 0, "donor never registered its prefix");
    assert!(attaches > 0, "twin never attached the cached prefix");
    assert_eq!(attached, plen as u64, "twin must attach every prompt group");
    // and shipped KV only for the donor's prompt, not the twin's
    assert_eq!(cold.metrics.prefill_tokens, 2 * plen as u64);
    assert_eq!(warm.metrics.prefill_tokens, plen as u64);
}

#[test]
fn prefix_off_never_touches_the_prefix_machinery() {
    // the default path (pinned bit-identical to the pre-PR executor by
    // tests/pipeline.rs) must leave zero prefix side effects even on a
    // workload full of repeated prompts
    let mut e = engine(2, false);
    let reqs = twin_requests(&e);
    let _ = run_closed_loop(&mut e, reqs, SchedConfig::serving(1, 1, 8)).unwrap();
    assert_eq!(prefix_counters(&e), (0, 0, 0));
    assert_eq!(e.metrics.prefix_hit_tokens, 0);
}

fn serve_prefix_tokens(overlap: bool) -> Vec<(u64, Vec<i32>)> {
    let mut e = engine(2, true);
    let m = e.rt.manifest.model.clone();
    let src = PrefixWorkloadGen::new(31, m.vocab, 24, 6, 0.5, m.n, 0.8, 2);
    let arrivals = ArrivalGen::new(src, 32, 100.0).take(8);
    let cfg = SchedConfig::serving(4, 2, 16).overlapped(overlap);
    let report = run_open_loop(&mut e, arrivals, cfg).unwrap();
    let mut toks: Vec<(u64, Vec<i32>)> =
        report.records.into_iter().map(|r| (r.id, r.generated)).collect();
    toks.sort_by_key(|(id, _)| *id);
    toks
}

#[test]
fn prefix_cache_composes_with_overlapped_streams() {
    // the admission split (attach prefix + ship suffix only) rides the
    // same prefill_stage both executors use, so stream layout must not
    // change outputs
    assert_eq!(serve_prefix_tokens(false), serve_prefix_tokens(true));
}

#[test]
fn warm_multi_turn_serving_ships_fewer_prompt_tokens() {
    let src = |seed: u64| {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let m = rt.manifest.model.clone();
        (rt, PrefixWorkloadGen::new(seed, m.vocab, 24, 6, 0.5, m.n, 1.0, 1))
    };
    let run = |prefix_on: bool| {
        let (rt, mut gen) = src(7);
        let meta = rt.manifest.model.clone();
        let cfg = EngineConfig::micro_for(&meta, 2, false).prefix_cached(prefix_on);
        let mut e = InferenceEngine::new(rt, cfg).unwrap();
        let reqs: Vec<Request> = (0..6).map(|_| gen.request()).collect();
        let _ = run_closed_loop(&mut e, reqs, SchedConfig::serving(1, 1, 8)).unwrap();
        (e.metrics.prefill_tokens, e.metrics.prefix_hit_tokens)
    };
    let (cold_ship, cold_hit) = run(false);
    let (warm_ship, warm_hit) = run(true);
    assert_eq!(cold_hit, 0);
    assert!(warm_hit > 0, "single-stem 100%-hit workload never hit the cache");
    assert!(
        warm_ship < cold_ship,
        "warm path shipped {warm_ship} prompt tokens, cold {cold_ship}"
    );
    // token conservation: every prompt token is either shipped or attached
    assert_eq!(warm_ship + warm_hit, cold_ship);
}

#[test]
fn bench_prefix_reduction_is_monotone_in_share_ratio() {
    // ISSUE acceptance: at fixed hit rate, the warm rows' shipped
    // prompt tokens fall (and attached tokens rise) monotonically as
    // the shared fraction of the prompt grows
    let runs: Vec<_> =
        [0.25f64, 0.5, 1.0].iter().map(|&s| run_config(s, 1.0, true).unwrap()).collect();
    for w in runs.windows(2) {
        assert!(
            w[1].prefill_tokens <= w[0].prefill_tokens,
            "shipped tokens rose with share ratio: {} -> {}",
            w[0].prefill_tokens,
            w[1].prefill_tokens
        );
        assert!(
            w[1].prefix_hit_tokens >= w[0].prefix_hit_tokens,
            "hit tokens fell with share ratio: {} -> {}",
            w[0].prefix_hit_tokens,
            w[1].prefix_hit_tokens
        );
    }
    assert!(
        runs[2].prefill_tokens < runs[0].prefill_tokens,
        "full-prompt sharing must beat quarter-prompt sharing"
    );
    // and every warm run beats its cold twin on data movement
    let cold = run_config(1.0, 1.0, false).unwrap();
    assert!(runs[2].prefill_tokens < cold.prefill_tokens);
    assert_eq!(cold.prefix_hit_tokens, 0);
}
