//! Trace-plane crosschecks.
//!
//! The load-bearing guarantees:
//!
//! 1. Tracing is purely observational: a run with the sink installed
//!    produces bit-identical outputs AND simulated timestamps to the
//!    same run with no sink — the emitters only record already-computed
//!    `(start, end)` values, they never schedule.
//! 2. Determinism: the same config + seed produces a byte-identical
//!    trace file (equal FNV digests ⟺ equal bytes).
//! 3. Well-formedness: spans have `end >= start`, every track is
//!    monotone in `ts`, and metadata events name every track before any
//!    data event appears.
//! 4. The export parses as chrome trace-event JSON with the keys
//!    Perfetto requires.

use instinfer::coordinator::{run_open_loop, EngineConfig, InferenceEngine, SchedConfig, ServeOpts};
use instinfer::obs::attr;
use instinfer::obs::{self, TraceLevel, TraceSink};
use instinfer::runtime::Runtime;
use instinfer::util::json::Json;
use instinfer::workload::{Arrival, ArrivalGen, LengthProfile, PrefixWorkloadGen, WorkloadGen};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
}

fn engine(n_csds: usize) -> InferenceEngine {
    let rt = Runtime::open(artifacts_dir()).expect("opening runtime");
    let meta = rt.manifest.model.clone();
    InferenceEngine::new(rt, EngineConfig::micro_for(&meta, n_csds, false)).unwrap()
}

/// Deterministic fixed-length Poisson trace (single priority class).
fn trace(engine: &InferenceEngine, n: usize, rate: f64) -> Vec<Arrival> {
    let m = &engine.rt.manifest.model;
    let wg = WorkloadGen::new(321, m.vocab, m.max_seq, LengthProfile::Fixed, 6, 4);
    ArrivalGen::new(wg, 654, rate).take(n)
}

fn sched(overlap: bool) -> SchedConfig {
    let mut s = SchedConfig::serving(4, 2, 16);
    s.overlap = overlap;
    s
}

/// Everything a run observably produces, per request: id, then the
/// bit-patterns of arrival / first-token / finish timestamps, then the
/// generated tokens (plus a final row for the simulated clock).
type Fingerprint = Vec<(u64, u64, u64, u64, Vec<i32>)>;

fn fingerprint(
    engine: &InferenceEngine,
    report: &instinfer::coordinator::ServeReport,
) -> Fingerprint {
    let mut rows: Vec<_> = report
        .records
        .iter()
        .map(|r| {
            (
                r.id,
                r.arrived_at.to_bits(),
                r.first_token_at.to_bits(),
                r.finished_at.to_bits(),
                r.generated.clone(),
            )
        })
        .collect();
    rows.sort_by_key(|r| r.0);
    rows.push((u64::MAX, report.sim_end.to_bits(), engine.sim_now.to_bits(), 0, Vec::new()));
    rows
}

/// One traced run at the given level; returns the drained sink plus the
/// run fingerprint.  Panics rather than leaking an installed sink.
fn traced_run(overlap: bool, level: TraceLevel) -> (TraceSink, Fingerprint) {
    let mut e = engine(2);
    let arrivals = trace(&e, 6, 200.0);
    obs::install(level);
    let report = run_open_loop(&mut e, arrivals, sched(overlap));
    let sink = obs::uninstall().expect("sink should still be installed");
    let report = report.unwrap();
    (sink, fingerprint(&e, &report))
}

#[test]
fn tracing_off_is_bit_identical_to_traced_run() {
    for overlap in [false, true] {
        let mut e = engine(2);
        let arrivals = trace(&e, 6, 200.0);
        assert!(!obs::enabled());
        let report = run_open_loop(&mut e, arrivals, sched(overlap)).unwrap();
        let untraced = fingerprint(&e, &report);

        let (sink, traced) = traced_run(overlap, TraceLevel::Full);
        assert!(!sink.is_empty(), "full-level trace captured no events");
        assert_eq!(
            untraced, traced,
            "tracing perturbed outputs or timestamps (overlap={overlap})"
        );
    }
}

#[test]
fn same_seed_produces_byte_identical_trace() {
    let (a, fp_a) = traced_run(true, TraceLevel::Full);
    let (b, fp_b) = traced_run(true, TraceLevel::Full);
    assert_eq!(fp_a, fp_b, "replay diverged before the trace comparison");
    assert_eq!(a.len(), b.len());
    assert_eq!(a.export(), b.export(), "trace files differ across identical runs");
    assert_eq!(a.digest_hex(), b.digest_hex());
    assert_eq!(a.digest_hex().len(), 16);

    // a lower trace level is a strict filter, not a different timeline
    let (c, fp_c) = traced_run(true, TraceLevel::Request);
    assert_eq!(fp_a, fp_c);
    assert!(c.len() < a.len(), "request level should drop device events");
}

#[test]
fn trace_spans_are_well_formed() {
    let (sink, _) = traced_run(true, TraceLevel::Full);
    for ev in sink.events() {
        assert!(ev.dur >= 0.0, "span {:?} ends before it starts", ev.name);
        assert!(ev.ts.is_finite() && ev.ts >= 0.0);
        assert!(
            matches!(ev.ph, 'X' | 'i' | 's' | 'f'),
            "sink holds only data and flow events"
        );
    }

    let doc = Json::parse(&sink.export()).expect("export is valid json");
    let events = doc.req("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());

    // every metadata event precedes every data event, and each track's
    // data timestamps are nondecreasing in file order
    let mut seen_data = false;
    let mut frontier: std::collections::BTreeMap<(u64, u64), f64> =
        std::collections::BTreeMap::new();
    for ev in events {
        let ph = ev.req("ph").unwrap().as_str().unwrap();
        if ph == "M" {
            assert!(!seen_data, "metadata event after a data event");
            continue;
        }
        seen_data = true;
        let pid = ev.req("pid").unwrap().as_f64().unwrap() as u64;
        let tid = ev.req("tid").unwrap().as_f64().unwrap() as u64;
        let ts = ev.req("ts").unwrap().as_f64().unwrap();
        let last = frontier.entry((pid, tid)).or_insert(f64::NEG_INFINITY);
        assert!(ts >= *last, "track ({pid},{tid}) went backwards: {ts} < {last}");
        *last = ts;
    }
    assert!(seen_data);
}

#[test]
fn export_is_valid_chrome_trace_event_json() {
    let (sink, _) = traced_run(false, TraceLevel::Full);
    let text = sink.export();
    assert!(text.ends_with('\n'));
    let doc = Json::parse(&text).expect("export is valid json");
    assert_eq!(doc.req("displayTimeUnit").unwrap().as_str(), Some("ms"));

    let events = doc.req("traceEvents").unwrap().as_arr().unwrap();
    let mut phases = std::collections::BTreeSet::new();
    for ev in events {
        for key in ["name", "ph", "pid", "tid"] {
            assert!(ev.get(key).is_some(), "event missing required key {key:?}");
        }
        let ph = ev.req("ph").unwrap().as_str().unwrap().to_string();
        match ph.as_str() {
            "M" => assert!(ev.req("args").unwrap().get("name").is_some()),
            "X" => {
                assert!(ev.req("ts").unwrap().as_f64().is_some());
                assert!(ev.req("dur").unwrap().as_f64().unwrap() >= 0.0);
            }
            "i" => {
                assert!(ev.req("ts").unwrap().as_f64().is_some());
                assert_eq!(ev.req("s").unwrap().as_str(), Some("t"));
            }
            "s" | "f" => {
                assert!(ev.req("ts").unwrap().as_f64().is_some());
                assert!(ev.get("id").is_some(), "flow event missing id");
                assert_eq!(ev.req("cat").unwrap().as_str(), Some("flow"));
                if ph == "f" {
                    assert_eq!(ev.req("bp").unwrap().as_str(), Some("e"));
                }
            }
            other => panic!("unexpected phase {other:?}"),
        }
        phases.insert(ph);
    }
    // a serve run must produce all four shapes: track names, request /
    // device spans, lifecycle instants, and dependency (flow) edges
    for want in ["M", "X", "i", "s", "f"] {
        assert!(phases.contains(want), "no {want:?} events in the export");
    }
}

// ---- latency attribution (obs::attr) --------------------------------------

/// One serving run of the attribution test matrix: `n_csds` devices,
/// serialized/overlapped scheduling, prefix cache on/off (the prefix
/// points serve a shared-stem multi-turn trace so the cache actually
/// engages).  Deterministic per config.
fn matrix_run(
    n_csds: usize,
    overlap: bool,
    prefix: bool,
) -> (InferenceEngine, instinfer::coordinator::ServeReport) {
    let rt = Runtime::open(artifacts_dir()).expect("opening runtime");
    let meta = rt.manifest.model.clone();
    let opts =
        ServeOpts { n_csds, prefix_cache: prefix, share_ratio: 0.5, ..ServeOpts::default() };
    let mut e = InferenceEngine::new(rt, opts.engine_config(&meta)).unwrap();
    let arrivals = if prefix {
        let src = PrefixWorkloadGen::new(9100, meta.vocab, 12, 4, 0.5, meta.n, 1.0, 2);
        ArrivalGen::new(src, 9101, 200.0).take(6)
    } else {
        let wg = WorkloadGen::new(321, meta.vocab, meta.max_seq, LengthProfile::Fixed, 6, 4);
        ArrivalGen::new(wg, 654, 200.0).take(6)
    };
    let report = run_open_loop(&mut e, arrivals, sched(overlap)).unwrap();
    (e, report)
}

/// The tentpole invariant: every request's exclusive buckets sum to its
/// measured wall time (and the TTFT/decode split partitions the same
/// total) within 1e-6 relative, across the whole config matrix.
#[test]
fn attr_buckets_sum_to_wall_across_matrix() {
    for overlap in [false, true] {
        for n_csds in [1usize, 2, 4] {
            for prefix in [false, true] {
                attr::install();
                let _ = matrix_run(n_csds, overlap, prefix);
                let sink = attr::uninstall().expect("attr sink should still be installed");
                let rep = attr::extract(&sink);
                let ctx = format!("csds={n_csds} overlap={overlap} prefix={prefix}");
                assert!(!rep.requests.is_empty(), "no attributed requests ({ctx})");
                for r in &rep.requests {
                    let tol = 1e-6 * r.wall.max(1e-9);
                    let sum: f64 = r.buckets.iter().sum();
                    assert!(
                        (sum - r.wall).abs() <= tol,
                        "req {} buckets sum {sum} != wall {} ({ctx})",
                        r.req,
                        r.wall,
                    );
                    let tsum: f64 = r.ttft_buckets.iter().sum();
                    assert!(
                        (tsum - r.ttft).abs() <= tol,
                        "req {} ttft buckets sum {tsum} != ttft {} ({ctx})",
                        r.req,
                        r.ttft,
                    );
                    let dsum: f64 = r.decode_buckets.iter().sum();
                    assert!(
                        (dsum - (r.wall - r.ttft)).abs() <= tol,
                        "req {} decode buckets sum {dsum} != wall-ttft {} ({ctx})",
                        r.req,
                        r.wall - r.ttft,
                    );
                }
            }
        }
    }
}

/// Attribution is strictly observational: installing the sink changes
/// neither the run's outputs/timestamps nor the trace byte stream.
#[test]
fn attribution_is_observational_bit_identical() {
    let (plain_sink, plain_fp) = traced_run(true, TraceLevel::Full);
    attr::install();
    let (sink, fp) = traced_run(true, TraceLevel::Full);
    let asink = attr::uninstall().expect("attr sink should still be installed");
    assert_eq!(plain_fp, fp, "attribution perturbed outputs or timestamps");
    assert_eq!(
        plain_sink.digest_hex(),
        sink.digest_hex(),
        "attribution perturbed the trace byte stream"
    );
    assert!(!attr::extract(&asink).requests.is_empty());
}

/// The paper's bottleneck claim on the DES plane: dense decode
/// attention attributes to flash-read wait (service + die/channel
/// conflict queueing), not to the on-device kernels.
#[test]
fn decode_attention_attributes_to_flash_wait_not_compute() {
    let rep = instinfer::bench::attr::run_attributed().expect("attributed bench run");
    let (flash, compute) = instinfer::bench::attr::measured_split(&rep);
    assert!(flash > 0.0, "no flash wait attributed to decode");
    assert!(
        flash > compute,
        "decode attention should be flash-bound: flash {flash}s vs compute {compute}s"
    );
}

/// The metrics snapshot's name set is config-invariant: the same keys
/// across CSD counts, scheduling modes, and prefix caching (with the
/// attribution names folded in at zero), so cross-run diffing and the
/// perf gate never chase schema drift.
#[test]
fn metrics_snapshot_name_set_is_config_invariant() {
    let mut baseline: Option<std::collections::BTreeSet<String>> = None;
    for n_csds in [1usize, 2, 4] {
        for overlap in [false, true] {
            for prefix in [false, true] {
                let (e, report) = matrix_run(n_csds, overlap, prefix);
                let mut reg = e.metrics_registry(&report.overlap);
                attr::AttrReport::default().fold_into(&mut reg);
                let keys: std::collections::BTreeSet<String> = match reg.to_json() {
                    Json::Obj(m) => m.keys().cloned().collect(),
                    other => panic!("metrics snapshot should be an object, got {other:?}"),
                };
                match &baseline {
                    None => baseline = Some(keys),
                    Some(b) => assert_eq!(
                        b, &keys,
                        "metric name set varies (csds={n_csds} overlap={overlap} prefix={prefix})"
                    ),
                }
            }
        }
    }
}
