//! Prefill/decode disaggregation crosschecks.
//!
//! The load-bearing guarantees:
//!
//! 1. `--overlap` OFF is the pre-pipeline serialized scheduler — outputs
//!    AND per-request timestamps are bit-identical to an independent
//!    replay of that executor (retire → admit → chunked prefill → retire
//!    → decode → retire on one clock), the same pin discipline the shard
//!    PR used for N=1.
//! 2. `--overlap` ON never changes the generated tokens: per-sequence
//!    generation depends only on the sequence's own prompt and KV, so
//!    disaggregation is a pure timing transform.
//! 3. Overlap never increases TTFT at any swept arrival rate, and under
//!    concurrent admissions the steady-state decode step time sits
//!    strictly below the serialized path (the ISSUE acceptance bar).

use instinfer::bench::overlap::run_pair;
use instinfer::coordinator::{
    run_closed_loop, run_open_loop, EngineConfig, InferenceEngine, SchedConfig, Sequence,
    SlotManager,
};
use instinfer::runtime::Runtime;
use instinfer::util::stats::percentile;
use instinfer::workload::{Arrival, ArrivalGen, LengthProfile, WorkloadGen};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
}

fn engine(n_csds: usize) -> InferenceEngine {
    let rt = Runtime::open(artifacts_dir()).expect("opening runtime");
    let meta = rt.manifest.model.clone();
    InferenceEngine::new(rt, EngineConfig::micro_for(&meta, n_csds, false)).unwrap()
}

/// Deterministic fixed-length Poisson trace (single priority class).
fn trace(engine: &InferenceEngine, n: usize, rate: f64, prompt: usize, gen: usize) -> Vec<Arrival> {
    let m = &engine.rt.manifest.model;
    let wg = WorkloadGen::new(321, m.vocab, m.max_seq, LengthProfile::Fixed, prompt, gen);
    ArrivalGen::new(wg, 654, rate).take(n)
}

#[derive(Debug, PartialEq)]
struct RefRecord {
    id: u64,
    admitted_at: f64,
    first_token_at: f64,
    finished_at: f64,
    generated: Vec<i32>,
}

fn ref_retire(
    engine: &mut InferenceEngine,
    running: &mut Vec<(Sequence, f64, f64)>,
    slots: &mut SlotManager,
    out: &mut Vec<RefRecord>,
    max_seq: usize,
) {
    let mut i = 0;
    while i < running.len() {
        let done = {
            let s = &running[i].0;
            s.is_done() || s.next_pos() >= max_seq
        };
        if !done {
            i += 1;
            continue;
        }
        let (mut s, admitted_at, first_token_at) = running.swap_remove(i);
        s.finish();
        engine.free_sequence(&s).unwrap();
        slots.release(s.slot).unwrap();
        out.push(RefRecord {
            id: s.req.id,
            admitted_at,
            first_token_at,
            finished_at: engine.sim_now,
            generated: s.generated,
        });
    }
}

/// Independent replay of the PRE-pipeline serialized executor for a
/// plain FIFO trace (one priority class, valid prompts, enough seats
/// that no preemption happens): fast-forward across idle gaps, then per
/// step retire → admit up to the chunk → chunked prefill (one clock) →
/// retire → decode → retire.  Slot allocation order mirrors the
/// scheduler's reserve/commit/release pattern so FTL stream keys match.
fn reference_serialized(
    engine: &mut InferenceEngine,
    arrivals: Vec<Arrival>,
    max_batch: usize,
    prefill_chunk: usize,
    slot_cap: usize,
) -> (Vec<RefRecord>, f64) {
    let max_seq = engine.rt.manifest.model.max_seq;
    let mut slots = SlotManager::new(slot_cap);
    let mut queue = arrivals;
    let mut running: Vec<(Sequence, f64, f64)> = Vec::new();
    let mut out: Vec<RefRecord> = Vec::new();

    while !(queue.is_empty() && running.is_empty()) {
        if running.is_empty() {
            let earliest = queue.iter().map(|a| a.at).fold(f64::INFINITY, f64::min);
            if earliest.is_finite() && earliest > engine.sim_now {
                engine.sim_now = earliest;
            }
        }
        ref_retire(engine, &mut running, &mut slots, &mut out, max_seq);
        let now = engine.sim_now;
        let seats = max_batch.min(engine.max_bucket());

        // admission: arrived requests in (arrival, id) order
        let mut cohort: Vec<Sequence> = Vec::new();
        loop {
            if running.len() + cohort.len() >= seats
                || cohort.len() >= prefill_chunk
                || slots.free_count() == 0
            {
                break;
            }
            let mut best: Option<usize> = None;
            for (i, a) in queue.iter().enumerate() {
                if a.at > now {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some(b) => (a.at, a.req.id) < (queue[b].at, queue[b].req.id),
                };
                if better {
                    best = Some(i);
                }
            }
            let Some(i) = best else { break };
            let a = queue.remove(i);
            let slot = slots.reserve().unwrap();
            cohort.push(Sequence::new(a.req, slot));
        }

        if !cohort.is_empty() {
            for s in &cohort {
                slots.commit(s.slot).unwrap();
            }
            let bucket = engine.bucket_for(cohort.len());
            engine.prefill(&mut cohort, bucket).unwrap();
            let first_token_at = engine.sim_now;
            for s in cohort.drain(..) {
                running.push((s, now, first_token_at));
            }
        }
        ref_retire(engine, &mut running, &mut slots, &mut out, max_seq);

        if !running.is_empty() {
            let bucket = engine.bucket_for(running.len());
            let mut batch: Vec<Sequence> = running.iter().map(|r| r.0.clone()).collect();
            engine.decode_step(&mut batch, bucket).unwrap();
            for (r, s) in running.iter_mut().zip(batch) {
                r.0 = s;
            }
        }
        ref_retire(engine, &mut running, &mut slots, &mut out, max_seq);
    }
    out.sort_by_key(|r| r.id);
    (out, engine.sim_now)
}

#[test]
fn overlap_off_is_bit_identical_to_the_serialized_executor() {
    // ISSUE acceptance: with --overlap off, outputs AND per-step timing
    // equal the pre-refactor serialized scheduler.  The reference replay
    // drives the same engine stages by hand on one clock.
    let mut e_ref = engine(2);
    let mut e_sched = engine(2);
    let arrivals = trace(&e_ref, 8, 400.0, 20, 5);
    let (want, want_end) = reference_serialized(&mut e_ref, arrivals.clone(), 4, 2, 8);

    // overlap off: the scheduler must replay the reference exactly
    let report = run_open_loop(&mut e_sched, arrivals, SchedConfig::serving(4, 2, 8)).unwrap();
    assert_eq!(want_end, report.sim_end, "sim_end must be bit-identical");
    let mut got: Vec<RefRecord> = report
        .records
        .into_iter()
        .filter(|r| !r.rejected)
        .map(|r| RefRecord {
            id: r.id,
            admitted_at: r.admitted_at,
            first_token_at: r.first_token_at,
            finished_at: r.finished_at,
            generated: r.generated,
        })
        .collect();
    got.sort_by_key(|r| r.id);
    assert_eq!(want, got, "serialized executor diverged from the reference replay");
    // and the serialized path never touches the pipeline machinery
    assert_eq!(report.overlap.cohorts, 0);
    assert_eq!(report.overlap.overlapped_s, 0.0);
    assert_eq!(e_sched.shards.stats.prefill_ship_bytes, 0.0);
    assert_eq!(e_sched.shards.stats.contended_merges, 0);
}

fn serve_tokens(overlap: bool, n_csds: usize, rate: f64) -> Vec<(u64, Vec<i32>)> {
    let mut e = engine(n_csds);
    let arrivals = trace(&e, 10, rate, 20, 6);
    let cfg = SchedConfig::serving(4, 2, 16).overlapped(overlap);
    let report = run_open_loop(&mut e, arrivals, cfg).unwrap();
    let mut toks: Vec<(u64, Vec<i32>)> =
        report.records.into_iter().map(|r| (r.id, r.generated)).collect();
    toks.sort_by_key(|(id, _)| *id);
    toks
}

#[test]
fn overlap_on_keeps_outputs_bit_identical() {
    // per-sequence generation depends only on the sequence's own KV, so
    // disaggregation must be a pure timing transform at every rate and
    // shard count
    for (n_csds, rate) in [(1usize, 200.0f64), (2, 200.0), (2, 2000.0), (4, 800.0)] {
        let serial = serve_tokens(false, n_csds, rate);
        let piped = serve_tokens(true, n_csds, rate);
        assert_eq!(
            serial, piped,
            "overlap changed generated tokens at {n_csds} CSDs, rate {rate}"
        );
    }
}

#[test]
fn overlap_on_closed_loop_matches_serialized_outputs() {
    let mut e1 = engine(2);
    let mut e2 = engine(2);
    let m = e1.rt.manifest.model.clone();
    let mut wg = WorkloadGen::new(99, m.vocab, m.max_seq, LengthProfile::Fixed, 20, 6);
    let reqs = wg.batch(6);
    let r1 = run_closed_loop(&mut e1, reqs.clone(), SchedConfig::serving(4, 2, 8)).unwrap();
    let cfg2 = SchedConfig::serving(4, 2, 8).overlapped(true);
    let r2 = run_closed_loop(&mut e2, reqs, cfg2).unwrap();
    let key = |r: &instinfer::coordinator::ServeReport| {
        let mut t: Vec<(u64, Vec<i32>)> =
            r.records.iter().map(|x| (x.id, x.generated.clone())).collect();
        t.sort_by_key(|(id, _)| *id);
        t
    };
    assert_eq!(key(&r1), key(&r2));
    // the overlapped run actually used the pipeline
    assert!(r2.overlap.cohorts > 0);
    assert_eq!(r1.overlap.cohorts, 0);
}

#[test]
fn overlap_never_increases_ttft_across_swept_rates() {
    // satellite: monotonicity — at every swept arrival rate, the
    // overlapped executor's TTFT must not exceed the serialized one's
    // (mean and p50).  Fixed-length prompts so cohort grouping cannot
    // reshuffle per-request ship times.
    for rate in [50.0f64, 200.0, 800.0, 3200.0] {
        let ttfts = |overlap: bool| -> Vec<f64> {
            let mut e = engine(2);
            let arrivals = trace(&e, 10, rate, 20, 6);
            let cfg = SchedConfig::serving(4, 2, 16).overlapped(overlap);
            let report = run_open_loop(&mut e, arrivals, cfg).unwrap();
            report
                .records
                .iter()
                .filter(|r| !r.rejected)
                .map(|r| (r.first_token_at - r.arrived_at).max(0.0))
                .collect()
        };
        let s = ttfts(false);
        let o = ttfts(true);
        assert_eq!(s.len(), o.len());
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(
            mean(&o) <= mean(&s) + 1e-9,
            "rate {rate}: overlap mean TTFT {} > serialized {}",
            mean(&o),
            mean(&s)
        );
        let p50 = |v: &[f64]| {
            let mut c = v.to_vec();
            percentile(&mut c, 50.0)
        };
        assert!(
            p50(&o) <= p50(&s) + 1e-9,
            "rate {rate}: overlap p50 TTFT {} > serialized {}",
            p50(&o),
            p50(&s)
        );
    }
}

#[test]
fn overlap_decode_step_time_strictly_below_serialized_under_admissions() {
    // ISSUE acceptance: at the default micro config, with concurrent
    // admissions in flight, the overlapped steady-state decode step
    // time (admission stalls included) sits strictly below the
    // serialized path's
    let (serial, piped) = run_pair(2, 4, 400.0).unwrap();
    assert!(
        piped.decode_step_s < serial.decode_step_s,
        "overlapped decode step {}s !< serialized {}s",
        piped.decode_step_s,
        serial.decode_step_s
    );
    // the win comes from real overlap: prefill time shadowed by decode
    assert!(piped.overlapped_s > 0.0, "no overlap was recorded");
    // and TTFT moved the right way too
    assert!(piped.ttft_p50_s <= serial.ttft_p50_s + 1e-9);
    // serialized rows never record overlap
    assert_eq!(serial.overlapped_s, 0.0);
    assert_eq!(serial.contended_merges, 0);
}

#[test]
fn overlap_survives_preemption_burst_that_empties_the_running_batch() {
    // regression: a high-priority burst can preempt EVERY runner while
    // its replacement cohort is still mid-prefill on the stream — the
    // decode frontier must fast-forward to the join (suspended seqs
    // cannot resume: parked cohorts hold all the seats) instead of
    // stalling the open loop
    let run = |overlap: bool| {
        let mut e = engine(2);
        let m = e.rt.manifest.model.clone();
        let mut wg = WorkloadGen::new(55, m.vocab, m.max_seq, LengthProfile::Fixed, 16, 6);
        let reqs = wg.batch(4);
        let mut arrivals: Vec<Arrival> = reqs
            .into_iter()
            .enumerate()
            .map(|(i, req)| Arrival {
                req,
                // two long low-priority requests at t=0 fill both seats;
                // two high-priority land mid-flight and preempt them both
                at: if i < 2 { 0.0 } else { 0.003 },
                priority: if i < 2 { 0 } else { 1 },
            })
            .collect();
        for (i, a) in arrivals.iter_mut().enumerate() {
            a.req.max_new_tokens = if i < 2 { 24 } else { 6 };
        }
        let cfg = SchedConfig::serving(2, 2, 8).overlapped(overlap);
        let report = run_open_loop(&mut e, arrivals, cfg).unwrap();
        let mut toks: Vec<(u64, usize)> = report
            .records
            .iter()
            .map(|r| (r.id, r.generated.len()))
            .collect();
        toks.sort_by_key(|(id, _)| *id);
        (toks, report.preemptions)
    };
    let (serial, sp) = run(false);
    let (piped, pp) = run(true);
    // both complete all 4 requests with the full token budget
    let want: Vec<usize> = vec![24, 24, 6, 6];
    for ((id, n), w) in serial.iter().chain(piped.iter()).zip(want.iter().cycle()) {
        assert_eq!(n, w, "req {id} generated {n} tokens, wanted {w}");
    }
    assert!(sp > 0, "serialized run never exercised preemption");
    assert!(pp > 0, "overlapped run never exercised preemption");
}

#[test]
fn overlap_one_token_requests_join_and_retire_cleanly() {
    // max_new_tokens == 1 finishes at the prefill stream: the cohort
    // must join and retire without ever decoding (stall regression)
    let mut e1 = engine(2);
    let mut e2 = engine(2);
    let m = e1.rt.manifest.model.clone();
    let wg = WorkloadGen::new(77, m.vocab, m.max_seq, LengthProfile::Fixed, 12, 1);
    let mut arrivals = ArrivalGen::new(wg, 78, 1000.0).take(6);
    for a in arrivals.iter_mut() {
        a.req.max_new_tokens = 1;
    }
    let r1 = run_open_loop(&mut e1, arrivals.clone(), SchedConfig::serving(4, 2, 8)).unwrap();
    let cfg2 = SchedConfig::serving(4, 2, 8).overlapped(true);
    let r2 = run_open_loop(&mut e2, arrivals, cfg2).unwrap();
    assert_eq!(r1.records.len(), r2.records.len());
    for r in r2.records.iter().chain(r1.records.iter()) {
        assert_eq!(r.generated.len(), 1, "req {} generated {:?}", r.id, r.generated);
    }
    let tok = |rep: &instinfer::coordinator::ServeReport| {
        let mut t: Vec<(u64, i32)> = rep.records.iter().map(|x| (x.id, x.generated[0])).collect();
        t.sort_by_key(|(id, _)| *id);
        t
    };
    assert_eq!(tok(&r1), tok(&r2));
}
