//! Multi-CSD shard subsystem crosschecks.
//!
//! The load-bearing guarantee: with ONE device, the shard coordinator
//! is the plain single-CSD engine — the same NVMe commands at the same
//! timestamps — so outputs *and* per-step timing are bit-identical to a
//! raw replay of the pre-shard command sequence.  On top of that, head
//! sharding must not change the numerics at any device count (heads are
//! computed independently over identical data), context sharding must
//! agree with the log-sum-exp reference, and the scaling sweep behind
//! `bench shard` must actually show the Fig. 17a shape.

use instinfer::bench::shard::run_config;
use instinfer::config::hw::{CsdSpec, GpuSpec, PcieSpec};
use instinfer::coordinator::{run_closed_loop, EngineConfig, InferenceEngine, SchedConfig};
use instinfer::csd::{AttnMode, CsdCommand, InstCsd, NvmeQueue};
use instinfer::ftl::FtlConfig;
use instinfer::kvtier::TierConfig;
use instinfer::runtime::native::sharded_reference_attention;
use instinfer::runtime::Runtime;
use instinfer::shard::{ShardCoordinator, ShardPolicy, ShardTopology};
use instinfer::sparse;
use instinfer::util::rng::Rng;
use instinfer::workload::{LengthProfile, WorkloadGen};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
}

fn coordinator(n: usize, policy: ShardPolicy) -> ShardCoordinator {
    ShardCoordinator::new(
        ShardTopology::new(n, policy, 4, 8),
        CsdSpec::tiny(),
        FtlConfig::micro_head(),
        TierConfig::flash_only(),
        PcieSpec::paper(),
        true,
        GpuSpec::a6000(),
    )
    .unwrap()
}

#[test]
fn n1_shard_path_bit_identical_to_raw_engine() {
    // ISSUE acceptance: N=1 outputs and per-step timing equal the
    // current single-CSD engine.  The raw queue below replays exactly
    // the pre-shard engine's command sequence (WriteToken at the step
    // clock, Attention at the write completion).
    let (h, d) = (4usize, 32usize);
    for policy in [ShardPolicy::HeadStripe, ShardPolicy::HeadBlock, ShardPolicy::Context] {
        let mut co = coordinator(1, policy);
        let mut raw = NvmeQueue::new(InstCsd::tiny_test(), &PcieSpec::paper(), true);
        let mut rng = Rng::new(31);
        let heads: Vec<u16> = (0..h as u16).collect();
        for t in 0..24 {
            let k: Vec<f32> = (0..h * d).map(|_| rng.normal_f32()).collect();
            let v: Vec<f32> = (0..h * d).map(|_| rng.normal_f32()).collect();
            let q: Vec<f32> = (0..h * d).map(|_| rng.normal_f32()).collect();
            let at = t as f64 * 1e-3;
            let (out, done, bd) = co
                .decode_token(0, 0, &q, &k, &v, t + 1, AttnMode::Dense, at)
                .unwrap();
            let wr = raw
                .submit(
                    CsdCommand::WriteToken { slot: 0, layer: 0, heads: heads.clone(), k, v },
                    at,
                )
                .unwrap();
            let comp = raw
                .submit(
                    CsdCommand::Attention {
                        slot: 0,
                        layer: 0,
                        heads: heads.clone(),
                        q,
                        len: t + 1,
                        mode: AttnMode::Dense,
                    },
                    wr.done,
                )
                .unwrap();
            assert_eq!(out, comp.data, "{policy:?} t={t}: outputs must be bit-identical");
            assert_eq!(done, comp.done, "{policy:?} t={t}: timing must be bit-identical");
            assert_eq!(bd.pcie_xfer, 0.0, "no transfer stage on a single device");
            assert_eq!(bd.gpu_merge, 0.0, "no merge stage on a single device");
        }
        assert_eq!(co.stats.merges, 0);
        assert_eq!(co.clock.barriers, 0);
    }
}

#[test]
fn n1_sparf_also_bit_identical() {
    let (h, d) = (4usize, 32usize);
    let sp = instinfer::config::model::SparsityParams { r: 8, k: 16, m: 4, n: 8 };
    let mut co = coordinator(1, ShardPolicy::HeadStripe);
    let mut raw = NvmeQueue::new(InstCsd::tiny_test(), &PcieSpec::paper(), true);
    let mut rng = Rng::new(32);
    let heads: Vec<u16> = (0..h as u16).collect();
    for t in 0..32 {
        let k: Vec<f32> = (0..h * d).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..h * d).map(|_| rng.normal_f32()).collect();
        let q: Vec<f32> = (0..h * d).map(|_| rng.normal_f32()).collect();
        let mode = AttnMode::SparF(sp);
        let (out, done, _) = co.decode_token(0, 0, &q, &k, &v, t + 1, mode, 0.0).unwrap();
        let wr = raw
            .submit(CsdCommand::WriteToken { slot: 0, layer: 0, heads: heads.clone(), k, v }, 0.0)
            .unwrap();
        let comp = raw
            .submit(
                CsdCommand::Attention {
                    slot: 0,
                    layer: 0,
                    heads: heads.clone(),
                    q,
                    len: t + 1,
                    mode,
                },
                wr.done,
            )
            .unwrap();
        assert_eq!(out, comp.data);
        assert_eq!(done, comp.done);
    }
}

fn engine(n: usize, policy: ShardPolicy) -> InferenceEngine {
    let rt = Runtime::open(artifacts_dir()).expect("opening runtime");
    let meta = rt.manifest.model.clone();
    InferenceEngine::new(rt, EngineConfig::micro_for(&meta, n, false).sharded(policy)).unwrap()
}

fn serve_tokens(engine: &mut InferenceEngine) -> Vec<(u64, Vec<i32>)> {
    let meta = engine.rt.manifest.model.clone();
    let mut wg = WorkloadGen::new(99, meta.vocab, meta.max_seq, LengthProfile::Fixed, 20, 6);
    let reqs = wg.batch(3);
    let report = run_closed_loop(
        engine,
        reqs,
        SchedConfig { max_batch: 4, prefill_chunk: 2, slots: 8, ..Default::default() },
    )
    .unwrap();
    let mut toks: Vec<(u64, Vec<i32>)> =
        report.records.into_iter().map(|r| (r.id, r.generated)).collect();
    toks.sort_by_key(|(id, _)| *id);
    toks
}

#[test]
fn head_sharding_never_changes_generated_tokens() {
    // heads are whole on one device under head policies, so the merged
    // attention — and therefore every generated token — is bit-identical
    // at any device count
    let mut e1 = engine(1, ShardPolicy::HeadStripe);
    let t1 = serve_tokens(&mut e1);
    for (n, policy) in [
        (2, ShardPolicy::HeadStripe),
        (4, ShardPolicy::HeadStripe),
        (3, ShardPolicy::HeadBlock),
    ] {
        let mut en = engine(n, policy);
        let tn = serve_tokens(&mut en);
        assert_eq!(t1, tn, "{n} CSDs ({policy:?}) changed the tokens");
        // but the sharded run did exercise the all-reduce machinery
        assert!(en.shards.stats.merges > 0);
        assert!(en.metrics.units.pcie_xfer > 0.0);
        assert!(en.metrics.units.gpu_merge > 0.0);
    }
    assert_eq!(e1.metrics.units.pcie_xfer, 0.0);
}

#[test]
fn context_sharding_tracks_single_device_generation() {
    // the log-sum-exp merge reorders float reductions, so context runs
    // are not bit-identical — but at micro scale the logit margins are
    // far wider than the merge noise, so generations must agree
    let mut e1 = engine(1, ShardPolicy::Context);
    let mut e2 = engine(2, ShardPolicy::Context);
    let t1 = serve_tokens(&mut e1);
    let t2 = serve_tokens(&mut e2);
    assert_eq!(t1, t2, "context striping diverged from the single device");
    assert!(e2.shards.stats.merges > 0);
    // context stripes spread the KV over both devices while running;
    // skew accounting saw the barriers
    assert!(e2.shards.clock.barriers > 0);
    assert!(e2.shards.clock.mean_skew_s() >= 0.0);
}

#[test]
fn sharded_reference_matches_dense_attention() {
    let (h, d, len, group) = (4usize, 16usize, 37usize, 8usize);
    let mut rng = Rng::new(41);
    let q: Vec<f32> = (0..h * d).map(|_| rng.normal_f32()).collect();
    let k: Vec<f32> = (0..h * len * d).map(|_| rng.normal_f32()).collect();
    let v: Vec<f32> = (0..h * len * d).map(|_| rng.normal_f32()).collect();
    let mut want = vec![0.0f32; h * d];
    for hh in 0..h {
        let o = sparse::dense_attention(
            &q[hh * d..(hh + 1) * d],
            &k[hh * len * d..(hh + 1) * len * d],
            &v[hh * len * d..(hh + 1) * len * d],
            len,
        );
        want[hh * d..(hh + 1) * d].copy_from_slice(&o);
    }
    for (n, policy) in [
        (1, ShardPolicy::HeadStripe),
        (2, ShardPolicy::HeadStripe),
        (1, ShardPolicy::Context),
        (2, ShardPolicy::Context),
        (3, ShardPolicy::Context),
    ] {
        let topo = ShardTopology::new(n, policy, h, group);
        let got = sharded_reference_attention(&q, &k, &v, len, d, &topo);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "n={n} {policy:?}: {a} vs {b}");
        }
    }
}

#[test]
fn bench_shard_sweep_meets_scaling_targets() {
    // ISSUE acceptance: >= 1.7x decode-attention speedup at 2 CSDs and
    // >= 3x at 4 on the micro model
    let r1 = run_config(1, ShardPolicy::HeadStripe).unwrap();
    let r2 = run_config(2, ShardPolicy::HeadStripe).unwrap();
    let r4 = run_config(4, ShardPolicy::HeadStripe).unwrap();
    let s2 = r1.attn_s_per_step / r2.attn_s_per_step;
    let s4 = r1.attn_s_per_step / r4.attn_s_per_step;
    assert!(s2 >= 1.7, "2-CSD attention speedup {s2:.2} < 1.7");
    assert!(s4 >= 3.0, "4-CSD attention speedup {s4:.2} < 3.0");
    // the merge term exists only when there is something to merge, and
    // grows (in share) as attention shrinks
    assert_eq!(r1.merge_s_per_step, 0.0);
    assert!(r2.merge_s_per_step > 0.0);
    let share2 = r2.merge_s_per_step / r2.decode_s_per_step;
    let share4 = r4.merge_s_per_step / r4.decode_s_per_step;
    assert!(share4 > share2, "merge share must grow with the shard count");
}

#[test]
fn fair_share_all_reduce_is_accounted() {
    let mut rng = Rng::new(51);
    let mut co = coordinator(4, ShardPolicy::HeadStripe);
    let (h, d) = (4usize, 32usize);
    for t in 0..16 {
        let k: Vec<f32> = (0..h * d).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..h * d).map(|_| rng.normal_f32()).collect();
        let q: Vec<f32> = (0..h * d).map(|_| rng.normal_f32()).collect();
        let (_, done, bd) = co
            .decode_token(0, 0, &q, &k, &v, t + 1, AttnMode::Dense, 0.0)
            .unwrap();
        // the step synchronizes on the slowest shard + all-reduce
        assert!(done > 0.0);
        assert!(bd.pcie_xfer >= 0.0 && bd.gpu_merge > 0.0);
    }
    assert_eq!(co.stats.merges, 16);
    assert!(co.stats.xfer_bytes > 0.0);
    assert_eq!(co.clock.barriers, 16);
    // every shard carried work (1 head each)
    for c in 0..4 {
        assert!(co.clock.now(c) > 0.0, "shard {c} never advanced");
    }
}
