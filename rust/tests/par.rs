//! Thread-count invariance: the scoped-thread executor must be
//! bit-identical to serial execution — same generated tokens, same
//! simulated timestamps, same metrics snapshots, same trace bytes —
//! for any worker-thread count.  These tests pin the determinism
//! contract `sim::par` promises: per-shard command streams are
//! self-contained between all-reduce barriers, sweep points are
//! independent fixed-seed simulations, and captured observability
//! sinks merge back in index order.

use instinfer::bench;
use instinfer::coordinator::{
    run_closed_loop, run_open_loop, EngineConfig, InferenceEngine, SchedConfig,
};
use instinfer::runtime::Runtime;
use instinfer::workload::{ArrivalGen, LengthProfile, WorkloadGen};

/// One traced open-loop serve at 2 CSDs: everything observable —
/// outputs, per-request timestamps, the unified metrics snapshot, and
/// the full-level trace bytes — folded into one comparable bundle.
fn traced_open_loop(threads: usize) -> (Vec<(u64, Vec<i32>, String)>, String, String) {
    let rt = Runtime::open("artifacts").unwrap();
    let meta = rt.manifest.model.clone();
    let cfg = EngineConfig::micro_for(&meta, 2, false).threads(threads);
    let mut engine = InferenceEngine::new(rt, cfg).unwrap();
    let wg = WorkloadGen::new(777, meta.vocab, meta.max_seq, LengthProfile::Fixed, 16, 8);
    let arrivals = ArrivalGen::new(wg, 778, 100.0).take(8);
    instinfer::obs::install(instinfer::obs::TraceLevel::Full);
    let report = run_open_loop(&mut engine, arrivals, SchedConfig::serving(4, 2, 16)).unwrap();
    let sink = instinfer::obs::uninstall().unwrap();
    let mut recs = report.records.clone();
    recs.sort_by_key(|r| r.id);
    let outputs: Vec<(u64, Vec<i32>, String)> = recs
        .iter()
        .map(|r| {
            (
                r.id,
                r.generated.clone(),
                format!("{:.9}/{:.9}/{:.9}", r.arrived_at, r.first_token_at, r.finished_at),
            )
        })
        .collect();
    let metrics = engine.metrics_registry(&report.overlap).to_json().to_string();
    (outputs, metrics, sink.export())
}

#[test]
fn traced_serve_is_thread_count_invariant() {
    let base = traced_open_loop(1);
    for n in [2usize, 4] {
        let run = traced_open_loop(n);
        assert_eq!(run.0, base.0, "outputs/timestamps diverged at {n} threads");
        assert_eq!(run.1, base.1, "metrics snapshot diverged at {n} threads");
        assert_eq!(run.2, base.2, "trace bytes diverged at {n} threads");
    }
}

/// Closed-loop decode across a 4-CSD array: the widest per-shard
/// fan-out the micro topology offers.
fn sharded_closed_loop(threads: usize) -> (Vec<Vec<i32>>, String) {
    let rt = Runtime::open("artifacts").unwrap();
    let meta = rt.manifest.model.clone();
    let cfg = EngineConfig::micro_for(&meta, 4, false).threads(threads);
    let mut engine = InferenceEngine::new(rt, cfg).unwrap();
    let mut wg = WorkloadGen::new(4242, meta.vocab, meta.max_seq, LengthProfile::Fixed, 20, 8);
    let reqs = wg.batch(4);
    let report = run_closed_loop(
        &mut engine,
        reqs,
        SchedConfig { max_batch: 4, prefill_chunk: 2, slots: 8, ..Default::default() },
    )
    .unwrap();
    let mut recs = report.records.clone();
    recs.sort_by_key(|r| r.id);
    let outputs = recs.iter().map(|r| r.generated.clone()).collect();
    let metrics = engine.metrics_registry(&report.overlap).to_json().to_string();
    (outputs, metrics)
}

#[test]
fn sharded_decode_is_thread_count_invariant() {
    let base = sharded_closed_loop(1);
    for n in [2usize, 8] {
        assert_eq!(sharded_closed_loop(n), base, "4-CSD run diverged at {n} threads");
    }
}

#[test]
fn canonical_trace_digest_is_thread_count_invariant() {
    let base = bench::canonical_trace_digest_with(1).unwrap();
    for n in [2usize, 8] {
        assert_eq!(
            bench::canonical_trace_digest_with(n).unwrap(),
            base,
            "canonical digest diverged at {n} threads"
        );
    }
}

#[test]
fn bench_serve_table_is_thread_count_invariant() {
    let base = bench::serve::serve_with_threads(1).render();
    for n in [2usize, 8] {
        assert_eq!(bench::serve::serve_with_threads(n).render(), base);
    }
}

#[test]
fn bench_tier_table_is_thread_count_invariant() {
    let base = bench::tier::tier_with_threads(1).render();
    assert_eq!(bench::tier::tier_with_threads(4).render(), base);
}

#[test]
fn bench_shard_table_is_thread_count_invariant() {
    let base = bench::shard::shard_with_threads(1).render();
    assert_eq!(bench::shard::shard_with_threads(4).render(), base);
}

#[test]
fn bench_flashpath_table_is_thread_count_invariant() {
    let base = bench::flashpath::flashpath_with_threads(1).render();
    for n in [3usize, 8] {
        assert_eq!(bench::flashpath::flashpath_with_threads(n).render(), base);
    }
}

#[test]
fn bench_fig16_table_is_thread_count_invariant() {
    let base = bench::figures::fig16_with_threads(1).render();
    assert_eq!(bench::figures::fig16_with_threads(2).render(), base);
}
