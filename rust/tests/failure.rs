//! Failure injection and robustness: the coordinator and substrates must
//! fail loudly and recover cleanly, never corrupt state.

use instinfer::config::hw::{FlashPathConfig, FlashSpec};
use instinfer::csd::{AttnMode, InstCsd};
use instinfer::ftl::{FtlConfig, KvFtl, StreamKey};
use instinfer::util::prop::check;
use instinfer::util::rng::Rng;

fn row(rng: &mut Rng, d: usize) -> Vec<f32> {
    (0..d).map(|_| rng.normal_f32()).collect()
}

#[test]
fn device_full_is_reported_not_corrupted() {
    // a deliberately minuscule flash: 1 channel x 4 blocks x 8 pages
    let spec = FlashSpec {
        channels: 1,
        dies_per_channel: 1,
        planes_per_die: 1,
        blocks_per_plane: 4,
        pages_per_block: 8,
        page_bytes: 512,
        channel_bw: 1e9,
        read_us: 10.0,
        program_us: 100.0,
        erase_ms: 1.0,
        path: FlashPathConfig::legacy(),
    };
    let mut ftl = KvFtl::new(spec, FtlConfig::micro_head()).unwrap();
    let mut rng = Rng::new(1);
    let key = StreamKey { slot: 0, layer: 0, head: 0 };
    let mut failed = false;
    for _ in 0..4096 {
        let (k, v) = (row(&mut rng, 32), row(&mut rng, 32));
        if ftl.append_token(key, &k, &v, 0.0).is_err() {
            failed = true;
            break;
        }
    }
    assert!(failed, "a 16 KiB device must eventually report 'full'");
    // device remains usable: free the stream, GC reclaims, writes resume
    ftl.free_slot(0, 0.0).unwrap();
    let key2 = StreamKey { slot: 1, layer: 0, head: 0 };
    for _ in 0..16 {
        let (k, v) = (row(&mut rng, 32), row(&mut rng, 32));
        ftl.append_token(key2, &k, &v, 0.0).expect("writes must resume after free");
    }
}

#[test]
fn attention_on_unknown_stream_errors() {
    let mut csd = InstCsd::tiny_test();
    let q = vec![0.5f32; 32];
    let key = StreamKey { slot: 9, layer: 0, head: 0 };
    assert!(csd.attention_head(key, &q, 8, AttnMode::Dense, 0.0).is_err());
}

#[test]
fn mismatched_row_lengths_rejected() {
    let mut csd = InstCsd::tiny_test();
    let bad = vec![0.0f32; 31];
    let good = vec![0.0f32; 32];
    assert!(csd.write_token_heads(0, 0, &[0], 0, &bad, &good, 0.0).is_err());
    let err = csd
        .write_token_heads(0, 0, &[0, 1], 0, &good, &good, 0.0)
        .unwrap_err()
        .to_string();
    assert!(err.contains("mismatch"), "{err}");
}

#[test]
fn prop_interleaved_streams_never_cross_contaminate() {
    // Interleave appends across random streams, then verify each stream
    // reads back exactly its own data (isolation invariant of the FTL
    // mapping under arbitrary interleaving + striping + GC pressure).
    check(
        "ftl_stream_isolation",
        10,
        |r| (r.next_u64(), r.range(2, 4), r.range(20, 60)),
        |&(seed, n_streams, toks)| {
            let mut ftl = KvFtl::new(
                FlashSpec::tiny(),
                FtlConfig::micro_head(),
            )
            .unwrap();
            let mut rng = Rng::new(seed);
            let mut truth: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n_streams];
            for t in 0..toks {
                for sidx in 0..n_streams {
                    let key = StreamKey { slot: sidx as u32, layer: 0, head: sidx as u16 };
                    let k = row(&mut rng, 32);
                    let v = row(&mut rng, 32);
                    ftl.append_token(key, &k, &v, t as f64).map_err(|e| e.to_string())?;
                    truth[sidx].push(
                        k.iter().map(|&x| instinfer::ftl::layout::q16(x)).collect(),
                    );
                }
            }
            for sidx in 0..n_streams {
                let key = StreamKey { slot: sidx as u32, layer: 0, head: sidx as u16 };
                let groups: Vec<usize> = (0..toks.div_ceil(8)).collect();
                let (rows, _) = ftl
                    .fetch_token_groups(key, instinfer::ftl::KvKind::K, &groups, 0.0)
                    .map_err(|e| e.to_string())?;
                for gf in rows {
                    for i in 0..8 {
                        let t = gf.base + i;
                        if t >= toks {
                            continue;
                        }
                        if gf.rows[i * 32..(i + 1) * 32] != truth[sidx][t][..] {
                            return Err(format!("stream {sidx} token {t} corrupted"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batcher_conserves_requests() {
    use instinfer::coordinator::OfflineBatcher;
    use instinfer::workload::Request;
    check(
        "batcher_conservation",
        50,
        |r| (r.range(0, 40), r.range(1, 10)),
        |&(n, maxb)| {
            let mut b = OfflineBatcher::new(vec![1, 4, 8], maxb);
            for i in 0..n {
                b.push(Request { id: i as u64, prompt: vec![1], max_new_tokens: 1 });
            }
            let mut seen = std::collections::BTreeSet::new();
            while let Some((reqs, bucket)) = b.next_batch() {
                if reqs.is_empty() || reqs.len() > bucket || bucket > 8 {
                    return Err(format!("bad batch: {} in bucket {bucket}", reqs.len()));
                }
                for r in reqs {
                    if !seen.insert(r.id) {
                        return Err(format!("request {} duplicated", r.id));
                    }
                }
            }
            if seen.len() != n {
                return Err(format!("{} of {n} requests delivered", seen.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_slot_manager_never_double_allocates() {
    use instinfer::coordinator::SlotManager;
    check(
        "slots_unique",
        50,
        |r| (r.next_u64(), r.range(1, 16)),
        |&(seed, cap)| {
            let mut rng = Rng::new(seed);
            let mut m = SlotManager::new(cap);
            let mut live = std::collections::BTreeSet::new();
            for _ in 0..200 {
                if rng.bool(0.6) {
                    match m.alloc() {
                        Ok(s) => {
                            if !live.insert(s) {
                                return Err(format!("slot {s} double-allocated"));
                            }
                        }
                        Err(_) => {
                            if live.len() != cap {
                                return Err("alloc failed below capacity".into());
                            }
                        }
                    }
                } else if let Some(&s) = live.iter().next() {
                    live.remove(&s);
                    m.release(s).map_err(|e| e.to_string())?;
                }
            }
            Ok(())
        },
    );
}
