//! Integration: rust execution of every AOT artifact reproduces the
//! jax outputs recorded in golden.bin (the python<->rust seam).
//!
//! The golden comparison needs `make artifacts` to have populated
//! ../artifacts and is skipped otherwise; the manifest/validation tests
//! run against the synthesized native manifest too.

use instinfer::runtime::{golden, Runtime};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
}

fn runtime() -> Runtime {
    Runtime::open(artifacts_dir()).expect("opening runtime")
}

#[test]
fn golden_all_executables() {
    let rt = runtime();
    if rt.manifest.golden.is_empty() {
        eprintln!(
            "skipping golden_all_executables: no golden records in {} \
             (run `make artifacts` in a jax container to record them)",
            artifacts_dir().display()
        );
        return;
    }
    let reports = golden::check_all(&rt, 2e-4).expect("golden mismatch");
    assert_eq!(reports.len(), rt.manifest.golden.len());
    assert!(reports.len() >= 8, "expected >= 8 golden records");
    for r in &reports {
        println!("golden {}: max_abs_err={:.2e} ({} outputs)", r.exe, r.max_abs_err, r.outputs);
    }
}

#[test]
fn manifest_shape_sanity() {
    let rt = runtime();
    let m = &rt.manifest.model;
    assert_eq!(m.d_model, m.n_heads * m.d_head);
    assert_eq!(rt.manifest.bucket_for(1), 1);
    assert_eq!(rt.manifest.bucket_for(3), 4);
    assert_eq!(rt.manifest.bucket_for(100), *rt.manifest.batch_buckets.last().unwrap());
    // every executable has every bucket
    for (name, exe) in &rt.manifest.executables {
        for b in &rt.manifest.batch_buckets {
            assert!(exe.buckets.contains_key(b), "{name} missing bucket {b}");
        }
    }
}

#[test]
fn call_shape_validation_errors() {
    let rt = runtime();
    // wrong input shape must be rejected with a useful message
    let bad = instinfer::runtime::HostTensor::zeros_f32(vec![1, 3]);
    let err = rt.call("qkv_proj", 1, 0, &[bad]).unwrap_err().to_string();
    assert!(err.contains("shape"), "{err}");
    // too few inputs
    let err = rt.call("attn_dense", 1, 0, &[]).unwrap_err().to_string();
    assert!(err.contains("missing input"), "{err}");
}

#[test]
fn weight_host_roundtrip() {
    let rt = runtime();
    let w = rt.weight_host("ln_f_g").unwrap();
    assert_eq!(w.dims, vec![rt.manifest.model.d_model]);
    // ln gains initialise to 1.0
    assert!(w.as_f32().unwrap().iter().all(|&x| x == 1.0));
}
