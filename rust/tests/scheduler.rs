//! Continuous-batching integration: batch churn (mid-decode admission,
//! mid-flight retirement, priority preemption to flash) must leave the
//! functional plane untouched — every sequence generates exactly the
//! tokens it would generate running alone — and must conserve KV slots.

use instinfer::coordinator::{
    run_closed_loop, EngineConfig, InferenceEngine, OfflineBatcher, SchedConfig, Scheduler,
    Sequence, SlotManager,
};
use instinfer::runtime::Runtime;
use instinfer::util::prop::check;
use instinfer::util::rng::Rng;
use instinfer::workload::{Arrival, LengthProfile, Request, WorkloadGen};
use std::collections::BTreeSet;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
}

fn engine() -> InferenceEngine {
    let rt = Runtime::open(artifacts_dir()).expect("runtime");
    InferenceEngine::new(rt, EngineConfig::micro(2)).unwrap()
}

fn req(id: u64, prompt_len: usize, gen: usize) -> Request {
    Request {
        id,
        prompt: (0..prompt_len as i64)
            .map(|t| ((t * 31 + id as i64 * 7) % 512) as i32)
            .collect(),
        max_new_tokens: gen,
    }
}

/// Ground truth: the request decoded alone on a fresh engine.
fn solo(r: &Request) -> Vec<i32> {
    let mut eng = engine();
    let mut slots = SlotManager::new(4);
    let seqs = vec![Sequence::new(r.clone(), slots.alloc().unwrap())];
    let done = eng.generate(seqs, 1).unwrap();
    done[0].generated.clone()
}

fn drain(sched: &mut Scheduler, eng: &mut InferenceEngine) {
    let mut guard = 0;
    while !sched.is_idle() {
        sched.step(eng).unwrap();
        guard += 1;
        assert!(guard < 500, "scheduler failed to drain");
    }
}

#[test]
fn mid_decode_admission_matches_solo_runs() {
    let r1 = req(1, 20, 10);
    let r2 = req(2, 16, 6);
    let solo1 = solo(&r1);
    let solo2 = solo(&r2);
    assert_eq!(solo1.len(), 10);
    assert_eq!(solo2.len(), 6);

    let mut eng = engine();
    let mut sched = Scheduler::new(SchedConfig {
        max_batch: 4,
        prefill_chunk: 2,
        slots: 8,
        ..Default::default()
    });
    sched.enqueue(Arrival { req: r1, at: 0.0, priority: 0 }).unwrap();
    // decode r1 for a few steps before r2 shows up
    let mut steps = 0;
    while eng.metrics.decode_steps < 3 {
        sched.step(&mut eng).unwrap();
        steps += 1;
        assert!(steps < 50);
    }
    assert_eq!(sched.running_count(), 1, "r1 must still be decoding");
    sched.enqueue(Arrival { req: r2, at: eng.sim_now, priority: 0 }).unwrap();
    drain(&mut sched, &mut eng);

    let g1 = sched.finished.iter().find(|r| r.id == 1).unwrap();
    let g2 = sched.finished.iter().find(|r| r.id == 2).unwrap();
    // (a) batch churn leaves the functional plane untouched
    assert_eq!(g1.generated, solo1, "r1 diverged from its solo run");
    assert_eq!(g2.generated, solo2, "r2 diverged from its solo run");
    // r2 joined while r1 was mid-flight
    assert!(g2.admitted_at > 0.0);
    assert!(g2.admitted_at < g1.finished_at, "admission was not mid-decode");
    assert_eq!(eng.metrics.admissions, 2);
    assert_eq!(eng.metrics.retirements, 2);
    // (b) all KV slots reclaimed
    assert_eq!(sched.slots.free_count(), 8);
    assert_eq!(sched.slots.live_count(), 0);
    assert_eq!(sched.slots.suspended_count(), 0);
}

#[test]
fn preempted_sequence_resumes_from_flash_and_matches_solo() {
    let low_a = req(10, 12, 14);
    let low_b = req(11, 12, 14);
    let high = req(12, 8, 4);
    let solo_a = solo(&low_a);
    let solo_b = solo(&low_b);
    let solo_h = solo(&high);

    let mut eng = engine();
    // two seats only: the high-priority arrival must preempt
    let mut sched = Scheduler::new(SchedConfig {
        max_batch: 2,
        prefill_chunk: 2,
        slots: 8,
        ..Default::default()
    });
    sched.enqueue(Arrival { req: low_a, at: 0.0, priority: 0 }).unwrap();
    sched.enqueue(Arrival { req: low_b, at: 0.0, priority: 0 }).unwrap();
    let mut steps = 0;
    while eng.metrics.decode_steps < 2 {
        sched.step(&mut eng).unwrap();
        steps += 1;
        assert!(steps < 50);
    }
    assert_eq!(sched.running_count(), 2);
    sched.enqueue(Arrival { req: high, at: eng.sim_now, priority: 1 }).unwrap();
    sched.step(&mut eng).unwrap();
    // the youngest low-priority runner (id 11) yielded its seat
    assert_eq!(sched.suspended_count(), 1);
    assert_eq!(eng.metrics.preemptions, 1);
    drain(&mut sched, &mut eng);

    let ga = sched.finished.iter().find(|r| r.id == 10).unwrap();
    let gb = sched.finished.iter().find(|r| r.id == 11).unwrap();
    let gh = sched.finished.iter().find(|r| r.id == 12).unwrap();
    assert_eq!(gb.preemptions, 1, "victim must record its preemption");
    assert!(eng.metrics.resumes >= 1);
    // resume continues from flash-resident KV: tokens still match solo
    assert_eq!(ga.generated, solo_a);
    assert_eq!(gb.generated, solo_b, "preempt/resume corrupted the victim");
    assert_eq!(gh.generated, solo_h);
    // high priority got served before the victim finished
    assert!(gh.finished_at <= gb.finished_at);
    assert_eq!(sched.slots.free_count(), 8);
}

#[test]
fn invalid_prompt_is_rejected_without_killing_the_run() {
    let mut eng = engine();
    let sp = eng.rt.manifest.model.prefill_seq;
    let mut sched = Scheduler::new(SchedConfig {
        max_batch: 4,
        prefill_chunk: 2,
        slots: 8,
        ..Default::default()
    });
    // over-long prompt arrives alongside a valid request
    sched.enqueue(Arrival { req: req(1, sp + 1, 4), at: 0.0, priority: 0 }).unwrap();
    sched.enqueue(Arrival { req: req(2, 8, 4), at: 0.0, priority: 0 }).unwrap();
    drain(&mut sched, &mut eng);
    let bad = sched.finished.iter().find(|r| r.id == 1).unwrap();
    let good = sched.finished.iter().find(|r| r.id == 2).unwrap();
    assert!(bad.rejected);
    assert!(bad.generated.is_empty());
    assert!(!good.rejected);
    assert_eq!(good.generated.len(), 4, "valid request must still be served");
    assert_eq!(sched.slots.free_count(), 8, "rejection must not leak a slot");
}

#[test]
fn closed_loop_continuous_no_slower_than_offline_drain() {
    // Same Chat workload through both paths; the continuous scheduler
    // retires stragglers mid-flight, so its simulated completion time
    // must not exceed the drain-the-queue baseline (small tolerance for
    // chunked-prefill scheduling differences).
    let mk_reqs = || {
        let mut wg = WorkloadGen::new(99, 512, 128, LengthProfile::Chat, 24, 16);
        wg.batch(12)
            .into_iter()
            .map(|mut r| {
                r.prompt.truncate(64);
                r.max_new_tokens = r.max_new_tokens.clamp(2, 16);
                r
            })
            .collect::<Vec<Request>>()
    };

    // offline drain baseline
    let mut off = engine();
    let mut batcher = OfflineBatcher::new(vec![1, 4, 8], 8);
    for r in mk_reqs() {
        batcher.push(r);
    }
    let mut slots = SlotManager::new(64);
    while let Some((reqs, bucket)) = batcher.next_batch() {
        let seqs: Vec<Sequence> = reqs
            .into_iter()
            .map(|r| Sequence::new(r, slots.alloc().unwrap()))
            .collect();
        for s in off.generate(seqs, bucket).unwrap() {
            slots.release(s.slot).unwrap();
        }
    }
    let off_sim = off.sim_now;

    // continuous path
    let mut cont = engine();
    let report = run_closed_loop(
        &mut cont,
        mk_reqs(),
        SchedConfig { max_batch: 8, prefill_chunk: 4, slots: 64, ..Default::default() },
    )
    .unwrap();
    let want: u64 = mk_reqs().iter().map(|r| r.max_new_tokens as u64).sum();
    assert_eq!(report.total_generated(), want, "continuous path lost tokens");
    assert!(
        cont.sim_now <= off_sim * 1.05,
        "continuous {:.6}s slower than offline drain {:.6}s",
        cont.sim_now,
        off_sim
    );
}

#[test]
fn prop_slot_churn_never_double_assigns() {
    // alloc/reserve/commit/cancel/suspend/resume/release churn: a slot is
    // never handed to two owners, and held+free always equals capacity.
    check(
        "slot_churn",
        60,
        |r| (r.next_u64(), r.range(1, 12)),
        |&(seed, cap)| {
            let mut rng = Rng::new(seed);
            let mut m = SlotManager::new(cap);
            let mut live: BTreeSet<u32> = BTreeSet::new();
            let mut reserved: BTreeSet<u32> = BTreeSet::new();
            let mut suspended: BTreeSet<u32> = BTreeSet::new();
            for step in 0..300 {
                match rng.below(7) {
                    0 => match m.alloc() {
                        Ok(s) => {
                            if live.contains(&s) || reserved.contains(&s) || suspended.contains(&s)
                            {
                                return Err(format!("step {step}: slot {s} double-assigned"));
                            }
                            live.insert(s);
                        }
                        Err(_) => {
                            if live.len() + reserved.len() + suspended.len() != cap {
                                return Err(format!("step {step}: alloc failed below capacity"));
                            }
                        }
                    },
                    1 => match m.reserve() {
                        Ok(s) => {
                            if live.contains(&s) || reserved.contains(&s) || suspended.contains(&s)
                            {
                                return Err(format!("step {step}: slot {s} double-reserved"));
                            }
                            reserved.insert(s);
                        }
                        Err(_) => {
                            if live.len() + reserved.len() + suspended.len() != cap {
                                return Err(format!("step {step}: reserve failed below capacity"));
                            }
                        }
                    },
                    2 => {
                        if let Some(&s) = reserved.iter().next() {
                            reserved.remove(&s);
                            m.commit(s).map_err(|e| e.to_string())?;
                            live.insert(s);
                        }
                    }
                    3 => {
                        if let Some(&s) = reserved.iter().next() {
                            reserved.remove(&s);
                            m.cancel(s).map_err(|e| e.to_string())?;
                        }
                    }
                    4 => {
                        if let Some(&s) = live.iter().next() {
                            live.remove(&s);
                            m.suspend(s).map_err(|e| e.to_string())?;
                            suspended.insert(s);
                        }
                    }
                    5 => {
                        if let Some(&s) = suspended.iter().next() {
                            suspended.remove(&s);
                            m.resume(s).map_err(|e| e.to_string())?;
                            live.insert(s);
                        }
                    }
                    _ => {
                        let pick = if rng.bool(0.5) {
                            live.iter().next().copied()
                        } else {
                            suspended.iter().next().copied()
                        };
                        if let Some(s) = pick {
                            live.remove(&s);
                            suspended.remove(&s);
                            m.release(s).map_err(|e| e.to_string())?;
                        }
                    }
                }
                let held = live.len() + reserved.len() + suspended.len();
                if held + m.free_count() != cap {
                    return Err(format!(
                        "step {step}: held {held} + free {} != capacity {cap}",
                        m.free_count()
                    ));
                }
                if m.live_count() != live.len()
                    || m.reserved_count() != reserved.len()
                    || m.suspended_count() != suspended.len()
                {
                    return Err(format!("step {step}: manager counts diverged from model"));
                }
            }
            Ok(())
        },
    );
}
