//! Flash-microarchitecture data-path crosschecks (ISSUE 5).
//!
//! The load-bearing guarantee: the legacy path (`FlashPathConfig::
//! legacy()` — one open block per channel, caller-order batch reads, a
//! full read->compute barrier) replays the PRE-refactor engine
//! bit-for-bit, outputs AND timestamps, in the GC-free regime (GC
//! relocation is deliberately concurrent on every path — the one
//! documented departure).  The replay below reconstructs that schedule
//! independently from the raw sim primitives — the same
//! `FifoResource`/`MultiServer` calls the pre-refactor engine made, in
//! the same order — so any silent change to the legacy schedule fails
//! the pin.  On top of that: the tuned path must compute bit-identical
//! outputs while being >= 2x faster at 4 dies/channel (the acceptance
//! gate), die placement must actually round-robin (including after GC
//! relocation), the interleaved read scheduler must be a pure function
//! of the PPAs, and concurrent GC relocation must beat the one-die
//! schedule on a multi-die device.

use instinfer::bench::flashpath::{run_attention, sparf_mode, spec};
use instinfer::config::hw::{FlashPathConfig, FlashPlacement, FlashReadSched, FlashSpec};
use instinfer::csd::{AttnMode, InstCsd};
use instinfer::flash::{BlockAddr, FlashArray};
use instinfer::ftl::{FtlConfig, KvFtl, KvKind, StreamKey};
use instinfer::sim::{FifoResource, MultiServer, Time};
use instinfer::util::rng::Rng;
use std::collections::BTreeSet;

const D: usize = 32;

fn key0() -> StreamKey {
    StreamKey { slot: 0, layer: 0, head: 0 }
}

/// Fill one head with `toks` tokens at t=0; returns the ship completion
/// and the RNG (so callers can draw the query from the same stream).
fn fill(csd: &mut InstCsd, toks: usize, seed: u64) -> (f64, Rng) {
    let mut rng = Rng::new(seed);
    let mut t_write = 0.0f64;
    for _ in 0..toks {
        let k: Vec<f32> = (0..D).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..D).map(|_| rng.normal_f32()).collect();
        t_write = t_write.max(csd.write_token(0, 0, &k, &v, 0.0).unwrap());
    }
    (t_write, rng)
}

/// Independent replay of the pre-refactor legacy schedule on the micro
/// geometry (4 channels x 2 dies, 512 B pages, one head): the
/// one-open-block-per-channel allocator placed every page of this
/// scenario in each channel's first block — die 0 — programs
/// channel-then-die, reads die-then-channel in caller order, and the
/// attention kernels sat behind a full read barrier.
struct LegacyMicroReplay {
    chans: Vec<FifoResource>,
    dies: Vec<FifoResource>,
    kernels: MultiServer,
    xfer: f64,
}

impl LegacyMicroReplay {
    fn new() -> Self {
        LegacyMicroReplay {
            chans: (0..4).map(|_| FifoResource::new()).collect(),
            dies: (0..8).map(|_| FifoResource::new()).collect(),
            kernels: MultiServer::new(2),
            xfer: 512.0 / 1.4e9,
        }
    }

    fn program(&mut self, ch: usize) -> Time {
        let (_, cd) = self.chans[ch].schedule(0.0, self.xfer);
        let (_, done) = self.dies[ch * 2].schedule(cd, 600.0 * 1e-6);
        done
    }

    fn read(&mut self, ch: usize, at: Time) -> Time {
        let (_, dd) = self.dies[ch * 2].schedule(at, 50.0 * 1e-6);
        let (_, done) = self.chans[ch].schedule(dd, self.xfer);
        done
    }

    fn kernel_time(flops: f64) -> f64 {
        // micro spec: 768 DSP MACs at 285 MHz, two kernels sharing
        flops / ((768.0 * 285e6 * 2.0) / 2.0)
    }

    /// One dense head over the 8 sealed groups of the 64-token fill,
    /// issued at `at`: K pages stripe (head + g) % 4, V (head + g + 1)
    /// % 4; both batches issue at `at` in group order; the two-kernel
    /// barrier follows the slowest read.
    fn dense(&mut self, at: Time) -> Time {
        let mut t_read = at;
        for g in 0..8usize {
            let t = self.read(g % 4, at);
            t_read = t_read.max(t);
        }
        for g in 0..8usize {
            let t = self.read((g + 1) % 4, at);
            t_read = t_read.max(t);
        }
        let logit_t = Self::kernel_time(2.0 * 64.0 * 32.0);
        let attend_t = Self::kernel_time(2.0 * 64.0 * 32.0);
        let (_, _, t1) = self.kernels.schedule(t_read, logit_t);
        let (_, _, t2) = self.kernels.schedule(t1, attend_t);
        t2
    }
}

#[test]
fn legacy_path_bit_identical_to_pre_refactor_replay() {
    let mut csd = InstCsd::micro_test();
    assert_eq!(csd.spec.flash.path, FlashPathConfig::legacy());
    let (t_write, mut rng) = fill(&mut csd, 64, 77);

    let mut rp = LegacyMicroReplay::new();
    let mut t_write_rp = 0.0f64;
    for g in 0..8usize {
        // each sealed group programs K then V on neighbouring channels
        let tk = rp.program(g % 4);
        let tv = rp.program((g + 1) % 4);
        t_write_rp = t_write_rp.max(tk).max(tv);
    }
    for eg in 0..8usize {
        // token 64 also seals the first embedding-page row block
        let te = rp.program(eg % 4);
        t_write_rp = t_write_rp.max(te);
    }
    assert_eq!(t_write.to_bits(), t_write_rp.to_bits(), "write-path timing diverged");

    let q: Vec<f32> = (0..D).map(|_| rng.normal_f32()).collect();
    let (out1, t_d1, bd) = csd.attention_head(key0(), &q, 64, AttnMode::Dense, t_write).unwrap();
    let t_rp1 = rp.dense(t_write_rp);
    assert_eq!(t_d1.to_bits(), t_rp1.to_bits(), "dense #1 timing diverged");
    assert!(bd.flash_read > 0.0 && bd.dram_hit == 0.0);

    // a second identical call pins the queue-state chaining too
    let (out2, t_d2, _) = csd.attention_head(key0(), &q, 64, AttnMode::Dense, t_d1).unwrap();
    let t_rp2 = rp.dense(t_rp1);
    assert_eq!(t_d2.to_bits(), t_rp2.to_bits(), "dense #2 timing diverged");
    assert_eq!(out1, out2, "sealed-group reads must be deterministic");
}

#[test]
fn tuned_path_2x_dense_at_4_dies_with_bit_identical_outputs() {
    let legacy = run_attention(4, FlashPathConfig::legacy(), AttnMode::Dense).unwrap();
    let tuned = run_attention(4, FlashPathConfig::tuned(), AttnMode::Dense).unwrap();
    assert_eq!(legacy.out, tuned.out, "outputs must be bit-identical across paths");
    let speedup = legacy.secs / tuned.secs.max(1e-30);
    assert!(speedup >= 2.0, "dense speedup {speedup:.2} < 2x at 4 dies/channel");

    let ls = run_attention(4, FlashPathConfig::legacy(), sparf_mode()).unwrap();
    let ts = run_attention(4, FlashPathConfig::tuned(), sparf_mode()).unwrap();
    assert_eq!(ls.out, ts.out, "sparf outputs must be bit-identical across paths");
    assert!(ts.secs < ls.secs, "sparf tuned {} !< legacy {}", ts.secs, ls.secs);

    // the ablation ladder is monotone: placement, then scheduling, then
    // pipelining each keep shaving the dense latency (non-strict — the
    // regular dense stripe already alternates dies under fifo issue)
    let die_fifo = FlashPathConfig {
        placement: FlashPlacement::Die,
        sched: FlashReadSched::Fifo,
        pipeline: false,
    };
    let die_ilv = FlashPathConfig {
        placement: FlashPlacement::Die,
        sched: FlashReadSched::Interleave,
        pipeline: false,
    };
    let df = run_attention(4, die_fifo, AttnMode::Dense).unwrap();
    let di = run_attention(4, die_ilv, AttnMode::Dense).unwrap();
    assert!(df.secs < legacy.secs, "die placement {} !< legacy {}", df.secs, legacy.secs);
    assert!(di.secs <= df.secs, "interleave {} !<= fifo {}", di.secs, df.secs);
    assert!(tuned.secs <= di.secs, "pipeline {} !<= barrier {}", tuned.secs, di.secs);
    assert_eq!(df.out, legacy.out);
    assert_eq!(di.out, legacy.out);

    // placement's effect is visible in the surfaced utilisation: the
    // legacy path convoys one die per channel (deep backlog), the
    // interleaved path spreads the same reads
    assert!(legacy.die_peak_q > tuned.die_peak_q, "{} !> {}", legacy.die_peak_q, tuned.die_peak_q);
}

#[test]
fn die_placement_round_robins_token_groups() {
    let mut csd = InstCsd::new(spec(2, FlashPathConfig::tuned()), FtlConfig::micro_head()).unwrap();
    fill(&mut csd, 64, 9);
    let key = key0();
    for ch in 0..4usize {
        let mut dies = BTreeSet::new();
        for g in 0..8usize {
            for kind in [KvKind::K, KvKind::V] {
                if csd.ftl.token_group_channel(key, kind, g) == Some(ch) {
                    dies.insert(csd.ftl.token_group_die(key, kind, g).unwrap());
                }
            }
        }
        assert!(dies.len() >= 2, "channel {ch} uses dies {dies:?}, expected the full rotation");
    }
}

#[test]
fn interleave_read_batch_is_pure_function_of_ppas() {
    let mut fs = FlashSpec::tiny();
    fs.channels = 1;
    fs.dies_per_channel = 4;
    fs.blocks_per_plane = 4;
    fs.path = FlashPathConfig::tuned();
    let build = || {
        let mut a = FlashArray::new(fs);
        let mut ppas = Vec::new();
        // three pages on each of the four dies (blocks 0..4 = dies 0..4)
        for b in 0..4usize {
            for p in 0..3usize {
                let (ppa, _) = a.program_next(BlockAddr(b), &[b as u8, p as u8], 0.0).unwrap();
                ppas.push(ppa);
            }
        }
        a.reset_timing();
        (a, ppas)
    };
    let (mut a1, ppas) = build();
    let t1 = a1.read_batch_times(&ppas, 0.0).unwrap();
    // a permuted caller order must give every page the same completion
    let (mut a2, _) = build();
    let perm: Vec<usize> = (0..ppas.len()).rev().collect();
    let shuffled: Vec<_> = perm.iter().map(|&i| ppas[i]).collect();
    let t2 = a2.read_batch_times(&shuffled, 0.0).unwrap();
    for (j, &i) in perm.iter().enumerate() {
        assert_eq!(
            t1[i].to_bits(),
            t2[j].to_bits(),
            "completion of ppa {:?} depends on caller order",
            ppas[i]
        );
    }
}

/// Two channels, constant 16 blocks x 8 pages (128 pages); only the
/// die count (and with it the relocation parallelism) varies.
fn gc_spec(dies: usize) -> FlashSpec {
    let mut fs = FlashSpec::tiny();
    fs.channels = 2;
    fs.dies_per_channel = dies;
    fs.blocks_per_plane = 8 / dies;
    fs.pages_per_block = 8;
    fs.path = FlashPathConfig::tuned();
    fs
}

/// Fill two streams back to back (their block boundaries straddle, so
/// freeing the second leaves mixed half-valid blocks), free it, then
/// append a third stream big enough that the allocator must GC.
/// Deterministic per die count; returns the FTL for inspection.
fn run_gc_scenario(dies: usize) -> KvFtl {
    let mut ftl = KvFtl::new(gc_spec(dies), FtlConfig::micro_head()).unwrap();
    let mut rng = Rng::new(5);
    for slot in 0..2u32 {
        let key = StreamKey { slot, layer: 0, head: 0 };
        for _ in 0..112 {
            let k: Vec<f32> = (0..D).map(|_| rng.normal_f32()).collect();
            let v: Vec<f32> = (0..D).map(|_| rng.normal_f32()).collect();
            ftl.append_token(key, &k, &v, 0.0).unwrap();
        }
    }
    ftl.free_slot(1, 0.0).unwrap();
    let s2 = StreamKey { slot: 2, layer: 0, head: 0 };
    for _ in 0..176 {
        let k: Vec<f32> = (0..D).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..D).map(|_| rng.normal_f32()).collect();
        ftl.append_token(s2, &k, &v, 0.0).unwrap();
    }
    ftl
}

#[test]
fn concurrent_gc_relocation_wins_on_multi_die() {
    let multi = run_gc_scenario(2);
    let single = run_gc_scenario(1);
    assert!(
        multi.counters.gc_relocations > 0 && single.counters.gc_relocations > 0,
        "GC must trigger in both scenarios ({} / {})",
        multi.counters.gc_relocations,
        single.counters.gc_relocations
    );
    let (tm, ts) = (multi.array.drained(), single.array.drained());
    assert!(tm < ts, "multi-die GC + writes {tm} !< single-die {ts}");
}

#[test]
fn die_round_robin_survives_gc_relocation() {
    let ftl = run_gc_scenario(2);
    assert!(ftl.counters.gc_relocations > 0, "scenario must exercise GC");
    // the surviving stream's sealed K groups still stripe the dies
    let s0 = key0();
    let mut dies = BTreeSet::new();
    for g in 0..14usize {
        if let Some(d) = ftl.token_group_die(s0, KvKind::K, g) {
            dies.insert(d);
        }
    }
    assert!(dies.len() >= 2, "post-GC K pages collapsed onto dies {dies:?}");
}
