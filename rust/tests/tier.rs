//! KV-tiering integration: the hot tier must never change the numerics
//! (only where bytes are served from), hit rate must be monotone in
//! capacity, drop-on-resume must be exact when nothing needs dropping,
//! and the FTL must conserve its mappings under promote/demote churn
//! interleaved with GC.

use instinfer::bench::tier::{run_config, working_set_bytes};
use instinfer::config::hw::FlashSpec;
use instinfer::coordinator::{
    run_closed_loop, EngineConfig, InferenceEngine, SchedConfig, Scheduler, Sequence,
    SlotManager,
};
use instinfer::ftl::{FtlConfig, KvFtl, KvKind, StreamKey};
use instinfer::kvtier::{TierConfig, TierPolicy};
use instinfer::runtime::Runtime;
use instinfer::util::rng::Rng;
use instinfer::workload::{Arrival, LengthProfile, Request, WorkloadGen};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
}

fn engine(cfg: EngineConfig) -> InferenceEngine {
    let rt = Runtime::open(artifacts_dir()).expect("runtime");
    InferenceEngine::new(rt, cfg).unwrap()
}

fn req(id: u64, prompt_len: usize, gen: usize) -> Request {
    Request {
        id,
        prompt: (0..prompt_len as i64)
            .map(|t| ((t * 31 + id as i64 * 7) % 512) as i32)
            .collect(),
        max_new_tokens: gen,
    }
}

/// Ground truth: the request decoded alone on a flash-only engine.
fn solo(r: &Request) -> Vec<i32> {
    let mut eng = engine(EngineConfig::micro(2));
    let mut slots = SlotManager::new(4);
    let seqs = vec![Sequence::new(r.clone(), slots.alloc().unwrap())];
    let done = eng.generate(seqs, 1).unwrap();
    done[0].generated.clone()
}

fn drain(sched: &mut Scheduler, eng: &mut InferenceEngine) {
    let mut guard = 0;
    while !sched.is_idle() {
        sched.step(eng).unwrap();
        guard += 1;
        assert!(guard < 500, "scheduler failed to drain");
    }
}

/// Run r1 through a forced preempt-resume cycle under the given tier
/// and drop settings; returns (r1 tokens, total dropped, records).
fn preempt_resume_run(tier: TierConfig, resume_keep: usize) -> (Vec<i32>, u64, usize) {
    let r1 = req(1, 24, 10);
    let r2 = req(2, 8, 3);
    let mut eng = engine(EngineConfig::micro(2).tiered(tier));
    let mut sched = Scheduler::new(SchedConfig {
        max_batch: 1,
        prefill_chunk: 1,
        slots: 4,
        drop_on_resume: true,
        resume_keep,
        ..Default::default()
    });
    sched.enqueue(Arrival { req: r1, at: 0.0, priority: 0 }).unwrap();
    let mut steps = 0;
    while eng.metrics.decode_steps < 3 {
        sched.step(&mut eng).unwrap();
        steps += 1;
        assert!(steps < 50);
    }
    // a high-priority arrival with one seat forces r1 to flash
    sched.enqueue(Arrival { req: r2, at: eng.sim_now, priority: 1 }).unwrap();
    drain(&mut sched, &mut eng);
    assert!(eng.metrics.preemptions >= 1, "r1 must have been preempted");
    assert!(eng.metrics.resumes >= 1, "r1 must have resumed");
    let g1 = sched.finished.iter().find(|r| r.id == 1).unwrap().generated.clone();
    (g1, eng.metrics.dropped_tokens, sched.finished.len())
}

#[test]
fn h2o_drop_on_resume_is_exact_when_capacity_covers_cache() {
    // Satellite (a): H2oScore eviction + drop-on-resume with a hot tier
    // larger than the whole cache and a keep budget larger than the
    // sequence must reproduce the dense flash-only tokens exactly.
    let solo1 = solo(&req(1, 24, 10));
    assert_eq!(solo1.len(), 10);
    let tier = TierConfig { hot_bytes: 1 << 20, policy: TierPolicy::H2oScore };
    let (g1, dropped, finished) = preempt_resume_run(tier, 128);
    assert_eq!(finished, 2);
    assert_eq!(dropped, 0, "keep budget covers the cache: nothing drops");
    assert_eq!(g1, solo1, "tier + resume must not perturb the tokens");
}

#[test]
fn h2o_drop_on_resume_small_budget_drops_and_completes() {
    let tier = TierConfig { hot_bytes: 1 << 20, policy: TierPolicy::H2oScore };
    let (g1, dropped, finished) = preempt_resume_run(tier, 8);
    assert_eq!(finished, 2);
    assert!(dropped > 0, "a small keep budget must drop tokens");
    assert_eq!(g1.len(), 10, "the sequence still decodes its full budget");
}

#[test]
fn hit_rate_is_monotone_in_hot_tier_capacity() {
    // Satellite (b): identical workload (the tier never changes the
    // numerics, so the page access stream is identical) under LRU at
    // growing capacities — the stack property makes hit rate monotone.
    let hit_rate = |hot_bytes: usize| -> f64 {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let meta = rt.manifest.model.clone();
        let mut eng = engine(
            EngineConfig::micro(1)
                .tiered(TierConfig { hot_bytes, policy: TierPolicy::Lru }),
        );
        let mut wg = WorkloadGen::new(77, meta.vocab, meta.max_seq, LengthProfile::Fixed, 24, 10);
        let reqs = wg.batch(4);
        run_closed_loop(
            &mut eng,
            reqs,
            SchedConfig { max_batch: 4, prefill_chunk: 2, slots: 8, ..Default::default() },
        )
        .unwrap();
        eng.tier_stats().hit_rate()
    };
    let small = hit_rate(64 << 10);
    let mid = hit_rate(256 << 10);
    let large = hit_rate(1 << 20);
    assert!(small <= mid, "hit rate dropped with capacity: {small} > {mid}");
    assert!(mid <= large, "hit rate dropped with capacity: {mid} > {large}");
    assert!(large > 0.3, "a full-working-set tier must mostly hit: {large}");
}

#[test]
fn h2o_tier_beats_flash_only_decode_time() {
    // The bench's acceptance shape: H2oScore at 50% of the working set
    // strictly beats the flash-only baseline's mean decode step time.
    let base = run_config(TierConfig::flash_only()).unwrap();
    let h2o = run_config(TierConfig {
        hot_bytes: working_set_bytes() / 2,
        policy: TierPolicy::H2oScore,
    })
    .unwrap();
    assert!(h2o.hit_rate > 0.0, "half-capacity H2O must hit");
    assert!(
        h2o.decode_s_per_step < base.decode_s_per_step,
        "H2O @50% ({}s/step) must beat flash-only ({}s/step)",
        h2o.decode_s_per_step,
        base.decode_s_per_step
    );
}

#[test]
fn gc_with_promote_demote_churn_conserves_pages() {
    // Satellite (c): promote/demote churn on a surviving stream while
    // scratch streams force GC — mappings, page counts and data must
    // all survive.
    let mut ftl = KvFtl::new(FlashSpec::tiny(), FtlConfig::micro_head()).unwrap();
    let mut rng = Rng::new(5);
    let row = |rng: &mut Rng| -> Vec<f32> { (0..32).map(|_| rng.normal_f32()).collect() };
    let keep = StreamKey { slot: 0, layer: 0, head: 0 };
    for _ in 0..64 {
        let (k, v) = (row(&mut rng), row(&mut rng));
        ftl.append_token(keep, &k, &v, 0.0).unwrap();
    }
    let groups: Vec<usize> = (0..8).collect();
    let (want, _) = ftl.fetch_token_groups(keep, KvKind::K, &groups, 0.0).unwrap();
    let mapped_before = ftl.mapped_token_pages(0);
    assert_eq!(mapped_before, 16); // 8 K + 8 V pages

    for round in 1..=8u32 {
        for head in 1..=2u16 {
            let scratch = StreamKey { slot: round, layer: 0, head };
            for _ in 0..64 {
                let (k, v) = (row(&mut rng), row(&mut rng));
                ftl.append_token(scratch, &k, &v, 0.0).expect("device must not fill");
            }
        }
        for g in 0..8usize {
            let (rows, t) = ftl.promote_group(keep, KvKind::K, g, 0.0).unwrap();
            assert_eq!(rows.len(), 8 * 32);
            assert!(t > 0.0);
            ftl.demote_group(keep, KvKind::K, g);
        }
        ftl.free_slot(round, 0.0).unwrap();
    }

    assert!(
        ftl.counters.gc_relocations > 0 || ftl.array.counters.block_erases > 0,
        "churn must have exercised reclamation"
    );
    assert_eq!(ftl.counters.promotions, 64);
    assert_eq!(ftl.counters.demotions, 64);
    // conservation: the surviving stream's mappings and bytes are intact
    assert_eq!(ftl.mapped_token_pages(0), mapped_before);
    let (got, _) = ftl.fetch_token_groups(keep, KvKind::K, &groups, 0.0).unwrap();
    for (g0, g1) in want.iter().zip(&got) {
        assert_eq!(g0.base, g1.base);
        assert_eq!(g0.rows, g1.rows, "group at token {} corrupted by churn", g0.base);
    }
    assert!(ftl.free_blocks() > 0);
}
