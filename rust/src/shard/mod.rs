//! Multi-CSD sharded execution (paper §IV-D "Scale To CSD Array",
//! Fig. 17a; cf. HeadInfer's head-wise offload partitioning).
//!
//! InstInfer's throughput scales with the *number* of CSDs: internal
//! flash bandwidth aggregates across drives while each drive's PCIe
//! link stays fixed.  This subsystem turns the engine's CSD array into
//! real per-device instances:
//!
//! * [`ShardTopology`] — how a sequence's KV is partitioned: heads
//!   striped/blocked across shards, or token groups striped with every
//!   head resident everywhere (`context`);
//! * [`clock`]   — per-CSD local clocks with barrier-skew accounting;
//! * [`merge`]   — the GPU-side combine: gather for head shards, the
//!   flash-decoding log-sum-exp reweighting for context shards;
//! * [`coordinator`] — [`ShardCoordinator`]: fans a decode step out to
//!   all shards, advances each shard's local time, ships the partial
//!   results back over a max-min fair-share PCIe model
//!   ([`crate::pcie::fair_share_finish`]), and synchronizes the step on
//!   the slowest shard at the merge barrier.
//!
//! With one CSD the coordinator degenerates to the plain single-engine
//! dataflow — same submissions at the same timestamps, no transfer or
//! merge stage — which the shard crosscheck test pins bit-exactly.

pub mod clock;
pub mod coordinator;
pub mod merge;

pub use clock::ShardClock;
pub use coordinator::{ShardCoordinator, ShardStats};
pub use merge::{lse_merge, Partial};

use anyhow::{bail, Result};

/// How a sequence's KV (and therefore its decode attention) is
/// partitioned across the CSD array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// heads striped round-robin across shards; merge is a gather
    HeadStripe,
    /// contiguous head blocks per shard (better NUMA/stream locality,
    /// same balance to within one head)
    HeadBlock,
    /// token groups striped across shards, every head on every shard;
    /// merge is the log-sum-exp combine (flash-decoding style)
    Context,
}

impl ShardPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "stripe" | "head" => ShardPolicy::HeadStripe,
            "block" => ShardPolicy::HeadBlock,
            "context" | "ctx" => ShardPolicy::Context,
            other => bail!("unknown shard policy {other:?} (stripe|block|context)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            ShardPolicy::HeadStripe => "stripe",
            ShardPolicy::HeadBlock => "block",
            ShardPolicy::Context => "context",
        }
    }
}

/// Shard topology: device count, partition policy, and the derived
/// head/token-group placement.
#[derive(Debug, Clone)]
pub struct ShardTopology {
    pub n_csds: usize,
    pub policy: ShardPolicy,
    pub n_heads: usize,
    /// tokens per FTL token group (`n`) — the context-striping grain
    pub group_tokens: usize,
    /// heads assigned to each shard (every head on every shard for
    /// `Context`)
    assignment: Vec<Vec<u16>>,
}

impl ShardTopology {
    pub fn new(n_csds: usize, policy: ShardPolicy, n_heads: usize, group_tokens: usize) -> Self {
        assert!(n_csds > 0 && n_heads > 0 && group_tokens > 0);
        let assignment: Vec<Vec<u16>> = match policy {
            ShardPolicy::HeadStripe => {
                let mut a = vec![Vec::new(); n_csds];
                for h in 0..n_heads {
                    a[h % n_csds].push(h as u16);
                }
                a
            }
            ShardPolicy::HeadBlock => {
                let mut a = vec![Vec::new(); n_csds];
                let base = n_heads / n_csds;
                let extra = n_heads % n_csds;
                let mut h = 0u16;
                for (c, out) in a.iter_mut().enumerate() {
                    let take = base + usize::from(c < extra);
                    for _ in 0..take {
                        out.push(h);
                        h += 1;
                    }
                }
                a
            }
            ShardPolicy::Context => vec![(0..n_heads as u16).collect(); n_csds],
        };
        ShardTopology { n_csds, policy, n_heads, group_tokens, assignment }
    }

    /// Heads resident on shard `c`.
    pub fn heads_of(&self, c: usize) -> &[u16] {
        &self.assignment[c]
    }

    /// Max heads on any shard (the head-imbalance bound of Fig. 17a).
    pub fn max_share(&self) -> usize {
        self.assignment.iter().map(|a| a.len()).max().unwrap()
    }

    /// True when the policy partitions the token axis (context striping
    /// with more than one device).
    pub fn splits_context(&self) -> bool {
        self.policy == ShardPolicy::Context && self.n_csds > 1
    }

    /// Which shard stores global token position `t` (context striping;
    /// identity on shard 0 for head policies — every shard holds every
    /// token for its own heads).
    pub fn token_shard(&self, t: usize) -> usize {
        if !self.splits_context() {
            return 0;
        }
        (t / self.group_tokens) % self.n_csds
    }

    /// Global token position -> (owning shard, local position).
    pub fn to_local(&self, t: usize) -> (usize, usize) {
        if !self.splits_context() {
            return (0, t);
        }
        let n = self.group_tokens;
        let g = t / n;
        (g % self.n_csds, (g / self.n_csds) * n + t % n)
    }

    /// Inverse of [`Self::to_local`].
    pub fn to_global(&self, c: usize, lt: usize) -> usize {
        if !self.splits_context() {
            return lt;
        }
        let n = self.group_tokens;
        ((lt / n) * self.n_csds + c) * n + lt % n
    }

    /// Number of token positions resident on shard `c` when the global
    /// stream holds `len` tokens.
    pub fn local_len(&self, c: usize, len: usize) -> usize {
        if !self.splits_context() {
            return if c == 0 { len } else { 0 };
        }
        let n = self.group_tokens;
        let full = len / n;
        let tail = len % n;
        // groups g < full with g % n_csds == c
        let mine = (full + self.n_csds - 1 - c) / self.n_csds;
        let mut l = mine * n;
        if tail > 0 && full % self.n_csds == c {
            l += tail;
        }
        l
    }

    /// Split a `(H, d)` row-major tensor into per-shard packed
    /// sub-tensors (rows in each shard's head order; context shards all
    /// receive the full copy).
    pub fn scatter(&self, rows: &[f32], d: usize) -> Vec<Vec<f32>> {
        debug_assert_eq!(rows.len(), self.n_heads * d);
        self.assignment
            .iter()
            .map(|heads| {
                let mut out = Vec::with_capacity(heads.len() * d);
                for &h in heads {
                    out.extend_from_slice(&rows[h as usize * d..(h as usize + 1) * d]);
                }
                out
            })
            .collect()
    }

    /// Inverse of [`Self::scatter`] for head policies: reassemble
    /// per-shard head outputs into `(H, d)`.
    pub fn gather(&self, parts: &[Vec<f32>], d: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n_heads * d];
        for (c, heads) in self.assignment.iter().enumerate() {
            for (i, &h) in heads.iter().enumerate() {
                out[h as usize * d..(h as usize + 1) * d]
                    .copy_from_slice(&parts[c][i * d..(i + 1) * d]);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_and_labels() {
        assert_eq!(ShardPolicy::parse("stripe").unwrap(), ShardPolicy::HeadStripe);
        assert_eq!(ShardPolicy::parse("block").unwrap(), ShardPolicy::HeadBlock);
        assert_eq!(ShardPolicy::parse("context").unwrap(), ShardPolicy::Context);
        assert!(ShardPolicy::parse("diagonal").is_err());
        assert_eq!(ShardPolicy::Context.label(), "context");
    }

    #[test]
    fn head_assignments_are_balanced_and_cover() {
        for policy in [ShardPolicy::HeadStripe, ShardPolicy::HeadBlock] {
            let t = ShardTopology::new(3, policy, 8, 8);
            let mut seen = vec![false; 8];
            let mut sizes = Vec::new();
            for c in 0..3 {
                sizes.push(t.heads_of(c).len());
                for &h in t.heads_of(c) {
                    assert!(!seen[h as usize], "head {h} assigned twice");
                    seen[h as usize] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "{policy:?} must cover all heads");
            assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
            assert_eq!(t.max_share(), 3);
        }
        // block policy keeps heads contiguous
        let t = ShardTopology::new(2, ShardPolicy::HeadBlock, 8, 8);
        assert_eq!(t.heads_of(0), &[0, 1, 2, 3]);
        assert_eq!(t.heads_of(1), &[4, 5, 6, 7]);
        // context: every head everywhere
        let t = ShardTopology::new(2, ShardPolicy::Context, 8, 8);
        assert_eq!(t.heads_of(0).len(), 8);
        assert_eq!(t.heads_of(1).len(), 8);
    }

    #[test]
    fn context_local_global_roundtrip() {
        let t = ShardTopology::new(3, ShardPolicy::Context, 4, 8);
        for tok in 0..200 {
            let (c, lt) = t.to_local(tok);
            assert_eq!(t.token_shard(tok), c);
            assert_eq!(t.to_global(c, lt), tok, "roundtrip for {tok}");
        }
        // local positions on each shard are dense prefixes
        for len in [0usize, 1, 7, 8, 9, 24, 25, 100] {
            let mut counts = vec![0usize; 3];
            for tok in 0..len {
                let (c, lt) = t.to_local(tok);
                assert!(lt < t.local_len(c, len), "tok {tok} len {len}");
                counts[c] += 1;
            }
            for c in 0..3 {
                assert_eq!(counts[c], t.local_len(c, len), "shard {c} len {len}");
            }
        }
    }

    #[test]
    fn head_policies_keep_context_whole() {
        let t = ShardTopology::new(4, ShardPolicy::HeadStripe, 8, 8);
        assert!(!t.splits_context());
        assert_eq!(t.to_local(37), (0, 37));
        assert_eq!(t.local_len(0, 37), 37);
        assert_eq!(t.local_len(2, 37), 0);
        // context with a single device is also whole
        let t1 = ShardTopology::new(1, ShardPolicy::Context, 8, 8);
        assert!(!t1.splits_context());
        assert_eq!(t1.local_len(0, 37), 37);
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let t = ShardTopology::new(3, ShardPolicy::HeadStripe, 7, 8);
        let d = 4;
        let rows: Vec<f32> = (0..7 * d).map(|x| x as f32).collect();
        let parts = t.scatter(&rows, d);
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), rows.len());
        assert_eq!(t.gather(&parts, d), rows);
    }
}
