//! GPU-side merge of per-shard partial attention.
//!
//! Head-sharded results concatenate (each head lives wholly on one CSD,
//! so the merge is a gather).  Context-sharded results combine with the
//! flash-decoding log-sum-exp reweighting: every shard returns its
//! locally-softmaxed output plus the (max logit, sum-of-exp) statistics,
//! and the GPU rescales each partial by its share of the global softmax
//! mass.  A single partial merges to itself bit-exactly (`l/l == 1.0`),
//! which is what keeps the N=1 shard path identical to the plain engine.

use crate::config::hw::GpuSpec;
use crate::config::model::FP16_BYTES;

/// One shard's partial attention for one head over its resident tokens.
#[derive(Debug, Clone)]
pub struct Partial {
    /// locally-softmaxed weighted V sum, length `d`
    pub out: Vec<f32>,
    /// max logit over the shard's valid tokens (`NEG_INF` when none)
    pub m: f32,
    /// sum of `exp(logit - m)` over the shard's valid tokens (0 if none)
    pub l: f32,
}

/// Per-partial merge weights `w_c = l_c e^{m_c - m*} / Σ_j l_j e^{m_j -
/// m*}` from the `(max_logit, sum_exp)` statistics (zero for partials
/// that saw no valid token).  `w_c · s_local` is exactly the global
/// softmax weight of the shard's tokens, which is why the same weights
/// also rescale the H2O importance write-back.
pub fn merge_weights(stats: &[(f32, f32)]) -> Vec<f32> {
    let mut w = vec![0.0f32; stats.len()];
    let mut mstar = f32::NEG_INFINITY;
    for &(m, l) in stats {
        if l > 0.0 && m > mstar {
            mstar = m;
        }
    }
    if mstar == f32::NEG_INFINITY {
        return w; // no shard saw a valid token
    }
    let mut denom = 0.0f32;
    for &(m, l) in stats {
        if l > 0.0 {
            denom += l * (m - mstar).exp();
        }
    }
    if denom <= 0.0 {
        return w;
    }
    for (wi, &(m, l)) in w.iter_mut().zip(stats) {
        if l > 0.0 {
            *wi = l * (m - mstar).exp() / denom;
        }
    }
    w
}

/// Exact log-sum-exp combine: `softmax(concat logits) · V` equals
/// `Σ_c w_c out_c` over [`merge_weights`].
pub fn lse_merge(parts: &[Partial], d: usize) -> Vec<f32> {
    let stats: Vec<(f32, f32)> = parts.iter().map(|p| (p.m, p.l)).collect();
    let w = merge_weights(&stats);
    let mut out = vec![0.0f32; d];
    for (p, &wc) in parts.iter().zip(&w) {
        if wc == 0.0 {
            continue;
        }
        for (o, &x) in out.iter_mut().zip(&p.out) {
            *o += wc * x;
        }
    }
    out
}

/// FLOPs of the log-sum-exp combine for `heads` heads over `parts`
/// partials (per head: a weight per partial, then a weighted d-vector
/// accumulation).
pub fn lse_merge_flops(heads: usize, d: usize, parts: usize) -> f64 {
    (heads * parts * (2 * d + 4)) as f64
}

/// GPU time of the context-shard merge (roofline over the partial
/// tensors: `heads x parts x (d + 2)` fp16 elements in, `heads x d` out).
pub fn lse_merge_time(gpu: &GpuSpec, heads: usize, d: usize, parts: usize) -> f64 {
    let bytes = ((heads * parts * (d + 2) + heads * d) * FP16_BYTES) as f64;
    gpu.op_time(lse_merge_flops(heads, d, parts), bytes)
}

/// GPU time of the head-shard gather (a pure memory move of the
/// concatenated head outputs).
pub fn gather_time(gpu: &GpuSpec, heads: usize, d: usize) -> f64 {
    gpu.op_time(0.0, (2 * heads * d * FP16_BYTES) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse;
    use crate::sparse::select::{dot, softmax_masked, NEG_INF};
    use crate::util::rng::Rng;

    /// Reference partial for tokens `lo..hi` of a (len, d) K/V pair.
    fn partial(q: &[f32], k: &[f32], v: &[f32], idx: &[usize], d: usize) -> Partial {
        let mut logits = vec![NEG_INF; idx.len()];
        let scale = 1.0 / (d as f32).sqrt();
        for (j, &t) in idx.iter().enumerate() {
            logits[j] = dot(q, &k[t * d..(t + 1) * d]) * scale;
        }
        let mask = vec![true; idx.len()];
        let s = softmax_masked(&logits, &mask);
        let mut m = NEG_INF;
        let mut l = 0.0f32;
        for &x in &logits {
            if x > m {
                m = x;
            }
        }
        for &x in &logits {
            l += (x - m).exp();
        }
        let mut out = vec![0.0f32; d];
        for (j, &t) in idx.iter().enumerate() {
            for c in 0..d {
                out[c] += s[j] * v[t * d + c];
            }
        }
        Partial { out, m, l }
    }

    #[test]
    fn single_partial_merges_to_itself_bit_exactly() {
        let mut rng = Rng::new(11);
        let d = 16;
        let p = Partial {
            out: (0..d).map(|_| rng.normal_f32()).collect(),
            m: 0.7,
            l: 3.3,
        };
        let merged = lse_merge(std::slice::from_ref(&p), d);
        assert_eq!(merged, p.out, "w = l/l must be exactly 1.0");
    }

    #[test]
    fn lse_merge_matches_dense_attention() {
        let mut rng = Rng::new(12);
        let (d, len) = (8, 24);
        let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let k: Vec<f32> = (0..len * d).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..len * d).map(|_| rng.normal_f32()).collect();
        let want = sparse::dense_attention(&q, &k, &v, len);
        // stripe tokens into 3 shards group-wise (group = 4 tokens)
        for n in [2usize, 3] {
            let mut parts = Vec::new();
            for c in 0..n {
                let idx: Vec<usize> = (0..len).filter(|t| (t / 4) % n == c).collect();
                if !idx.is_empty() {
                    parts.push(partial(&q, &k, &v, &idx, d));
                }
            }
            let got = lse_merge(&parts, d);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-5, "n={n}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn empty_partials_are_ignored() {
        let d = 4;
        let real = Partial { out: vec![1.0, 2.0, 3.0, 4.0], m: 0.5, l: 2.0 };
        let empty = Partial { out: vec![0.0; d], m: NEG_INF, l: 0.0 };
        let merged = lse_merge(&[empty.clone(), real.clone(), empty], d);
        assert_eq!(merged, real.out);
        assert_eq!(lse_merge(&[], d), vec![0.0; d]);
    }

    #[test]
    fn merge_weights_normalize_and_skip_empty() {
        let w = merge_weights(&[(0.0, 2.0), (NEG_INF, 0.0), (1.0, 1.0)]);
        assert_eq!(w[1], 0.0, "empty partial carries no mass");
        assert!((w.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(w[2] > w[0], "higher max-logit partial carries more mass");
        assert_eq!(merge_weights(&[]), Vec::<f32>::new());
    }

    #[test]
    fn merge_time_positive_and_grows_with_parts() {
        let gpu = GpuSpec::a6000();
        let t2 = lse_merge_time(&gpu, 8, 32, 2);
        let t8 = lse_merge_time(&gpu, 8, 32, 8);
        assert!(t2 > 0.0 && t8 > t2);
        assert!(gather_time(&gpu, 8, 32) > 0.0);
    }
}
