//! Per-CSD local clocks.
//!
//! Every shard advances its own frontier as its command stream completes;
//! a decode step only synchronizes at the GPU merge barrier, where the
//! step waits for the slowest shard.  The clock records how far apart the
//! shards drifted at each barrier — the straggler effect that head
//! imbalance, uneven flash layouts and fair-share PCIe induce (and that a
//! single global engine clock structurally cannot express).

use crate::sim::Time;

#[derive(Debug, Clone)]
pub struct ShardClock {
    local: Vec<Time>,
    /// merge barriers observed
    pub barriers: u64,
    /// accumulated (slowest - fastest) across barriers
    pub skew_s: Time,
    /// worst single-barrier skew
    pub max_skew_s: Time,
    /// how often each shard was the straggler at a barrier
    pub straggler: Vec<u64>,
}

impl ShardClock {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        ShardClock {
            local: vec![0.0; n],
            barriers: 0,
            skew_s: 0.0,
            max_skew_s: 0.0,
            straggler: vec![0; n],
        }
    }

    pub fn n(&self) -> usize {
        self.local.len()
    }

    /// Shard `c`'s local frontier.
    pub fn now(&self, c: usize) -> Time {
        self.local[c]
    }

    /// Advance shard `c`'s local frontier (monotone: time never rewinds).
    pub fn advance(&mut self, c: usize, t: Time) {
        if t > self.local[c] {
            self.local[c] = t;
        }
    }

    /// Latest local frontier across the array (what a global clock sees).
    pub fn max(&self) -> Time {
        self.local.iter().cloned().fold(0.0, f64::max)
    }

    /// Earliest local frontier (the most idle shard).
    pub fn min(&self) -> Time {
        self.local.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Record a merge barrier over the shards that participated in a
    /// fan-out (`(shard, completion)` pairs; idle shards are simply not
    /// listed so they never count as "fast").  Returns the barrier time
    /// (the slowest participant) and accounts skew + the straggler.
    pub fn note_barrier(&mut self, done: &[(usize, Time)]) -> Time {
        if done.is_empty() {
            return 0.0;
        }
        self.barriers += 1;
        let mut hi = f64::NEG_INFINITY;
        let mut lo = f64::INFINITY;
        let mut who = 0usize;
        for &(c, t) in done {
            if t > hi {
                hi = t;
                who = c;
            }
            if t < lo {
                lo = t;
            }
        }
        let skew = (hi - lo).max(0.0);
        self.skew_s += skew;
        if skew > self.max_skew_s {
            self.max_skew_s = skew;
        }
        self.straggler[who] += 1;
        hi
    }

    /// Mean per-barrier skew (0 when no barrier happened).
    pub fn mean_skew_s(&self) -> Time {
        if self.barriers == 0 {
            0.0
        } else {
            self.skew_s / self.barriers as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_is_monotone_per_shard() {
        let mut c = ShardClock::new(3);
        c.advance(1, 5.0);
        c.advance(1, 2.0); // rewind attempt is ignored
        assert_eq!(c.now(1), 5.0);
        assert_eq!(c.now(0), 0.0);
        assert_eq!(c.max(), 5.0);
        assert_eq!(c.min(), 0.0);
    }

    #[test]
    fn barrier_records_skew_and_straggler() {
        let mut c = ShardClock::new(3);
        let t = c.note_barrier(&[(0, 1.0), (1, 3.0), (2, 2.0)]);
        assert_eq!(t, 3.0);
        assert_eq!(c.barriers, 1);
        assert_eq!(c.skew_s, 2.0);
        assert_eq!(c.straggler, vec![0, 1, 0]);
        let t = c.note_barrier(&[(0, 4.0), (1, 4.0), (2, 4.0)]);
        assert_eq!(t, 4.0);
        assert_eq!(c.max_skew_s, 2.0);
        assert_eq!(c.mean_skew_s(), 1.0);
        // ties go to the first shard at the max
        assert_eq!(c.straggler, vec![1, 1, 0]);
        // an empty barrier (no participants) is a no-op
        assert_eq!(c.note_barrier(&[]), 0.0);
        assert_eq!(c.barriers, 2);
    }
}
