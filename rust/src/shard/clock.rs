//! Per-CSD local clocks.
//!
//! Every shard advances its own frontier as its command stream completes;
//! a decode step only synchronizes at the GPU merge barrier, where the
//! step waits for the slowest shard.  The clock records how far apart the
//! shards drifted at each barrier — the straggler effect that head
//! imbalance, uneven flash layouts and fair-share PCIe induce (and that a
//! single global engine clock structurally cannot express).
//!
//! Under the disaggregated executor each CSD's clock additionally
//! advances from two concurrent directions: prefill-KV **ingest** (the
//! GPU prefill stream shipping a cohort's cache down) and decode-result
//! **egress** (partial attention returns to the merge).  The clock keeps
//! the in-flight ingest windows per shard and accounts the time both
//! directions were simultaneously live (`dual_stream_s`) — the overlap
//! window the serialized executor never enters.

use crate::sim::Time;

#[derive(Debug, Clone)]
pub struct ShardClock {
    local: Vec<Time>,
    /// merge barriers observed
    pub barriers: u64,
    /// accumulated (slowest - fastest) across barriers
    pub skew_s: Time,
    /// worst single-barrier skew
    pub max_skew_s: Time,
    /// how often each shard was the straggler at a barrier
    pub straggler: Vec<u64>,
    /// per-shard prefill-KV ingest windows still in flight (overlap
    /// executor only; pruned as egress observations pass them)
    ingest: Vec<Vec<(Time, Time)>>,
    /// per-shard cumulative ingest busy seconds
    pub ingest_s: Vec<Time>,
    /// accumulated per-shard time where KV ingest and result egress
    /// were concurrently in flight
    pub dual_stream_s: Time,
}

impl ShardClock {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        ShardClock {
            local: vec![0.0; n],
            barriers: 0,
            skew_s: 0.0,
            max_skew_s: 0.0,
            straggler: vec![0; n],
            ingest: vec![Vec::new(); n],
            ingest_s: vec![0.0; n],
            dual_stream_s: 0.0,
        }
    }

    pub fn n(&self) -> usize {
        self.local.len()
    }

    /// Shard `c`'s local frontier.
    pub fn now(&self, c: usize) -> Time {
        self.local[c]
    }

    /// Advance shard `c`'s local frontier (monotone: time never rewinds).
    pub fn advance(&mut self, c: usize, t: Time) {
        if t > self.local[c] {
            self.local[c] = t;
        }
    }

    /// Latest local frontier across the array (what a global clock sees).
    pub fn max(&self) -> Time {
        self.local.iter().cloned().fold(0.0, f64::max)
    }

    /// Earliest local frontier (the most idle shard).
    pub fn min(&self) -> Time {
        self.local.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Record a merge barrier over the shards that participated in a
    /// fan-out (`(shard, completion)` pairs; idle shards are simply not
    /// listed so they never count as "fast").  Returns the barrier time
    /// (the slowest participant) and accounts skew + the straggler.
    pub fn note_barrier(&mut self, done: &[(usize, Time)]) -> Time {
        if done.is_empty() {
            return 0.0;
        }
        self.barriers += 1;
        let mut hi = f64::NEG_INFINITY;
        let mut lo = f64::INFINITY;
        let mut who = 0usize;
        for &(c, t) in done {
            if t > hi {
                hi = t;
                who = c;
            }
            if t < lo {
                lo = t;
            }
        }
        let skew = (hi - lo).max(0.0);
        self.skew_s += skew;
        if skew > self.max_skew_s {
            self.max_skew_s = skew;
        }
        self.straggler[who] += 1;
        hi
    }

    /// Record a prefill-KV ingest window on shard `c` (overlap executor:
    /// the GPU prefill stream occupies this device's link over
    /// `[start, end)` while decode egress may run concurrently).
    pub fn note_ingest(&mut self, c: usize, start: Time, end: Time) {
        if end <= start {
            return;
        }
        self.ingest_s[c] += end - start;
        self.ingest[c].push((start, end));
    }

    /// Drop ingest windows that ended at or before the decode frontier:
    /// future egress windows start at or after it, so those windows can
    /// never overlap again.  The coordinator calls this once per decode
    /// dispatch — the consumer-side prune that keeps a never-egressing
    /// shard (a single CSD has no all-reduce) bounded.
    pub fn prune_ingest(&mut self, frontier: Time) {
        for w in self.ingest.iter_mut() {
            w.retain(|&(_, e)| e > frontier);
        }
    }

    /// Record a decode-result egress window on shard `c` and account
    /// how much of it ran concurrently with in-flight ingest.  Egress
    /// windows arrive in non-decreasing start order per shard, so each
    /// observed ingest portion is consumed (no double counting when
    /// successive egress windows overlap the same ship) and windows
    /// fully behind `start` are pruned.  (This deliberately does NOT
    /// reuse [`crate::pipeline::StreamTimeline`]: that helper assumes
    /// non-overlapping observation windows — true for decode step spans
    /// — while per-CSD egress windows from different sequences of the
    /// same layer can overlap, which is why observed portions must be
    /// consumed here.)
    pub fn note_egress(&mut self, c: usize, start: Time, end: Time) {
        if end <= start {
            return;
        }
        let mut rest: Vec<(Time, Time)> = Vec::with_capacity(self.ingest[c].len());
        for &(s, e) in &self.ingest[c] {
            self.dual_stream_s += (e.min(end) - s.max(start)).max(0.0);
            if e > end {
                // tail not yet observed; the head (< start) can never be
                // observed again because egress starts are monotone
                rest.push((s.max(end), e));
            }
        }
        self.ingest[c] = rest;
    }

    /// Mean per-barrier skew (0 when no barrier happened).
    pub fn mean_skew_s(&self) -> Time {
        if self.barriers == 0 {
            0.0
        } else {
            self.skew_s / self.barriers as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_is_monotone_per_shard() {
        let mut c = ShardClock::new(3);
        c.advance(1, 5.0);
        c.advance(1, 2.0); // rewind attempt is ignored
        assert_eq!(c.now(1), 5.0);
        assert_eq!(c.now(0), 0.0);
        assert_eq!(c.max(), 5.0);
        assert_eq!(c.min(), 0.0);
    }

    #[test]
    fn barrier_records_skew_and_straggler() {
        let mut c = ShardClock::new(3);
        let t = c.note_barrier(&[(0, 1.0), (1, 3.0), (2, 2.0)]);
        assert_eq!(t, 3.0);
        assert_eq!(c.barriers, 1);
        assert_eq!(c.skew_s, 2.0);
        assert_eq!(c.straggler, vec![0, 1, 0]);
        let t = c.note_barrier(&[(0, 4.0), (1, 4.0), (2, 4.0)]);
        assert_eq!(t, 4.0);
        assert_eq!(c.max_skew_s, 2.0);
        assert_eq!(c.mean_skew_s(), 1.0);
        // ties go to the first shard at the max
        assert_eq!(c.straggler, vec![1, 1, 0]);
        // an empty barrier (no participants) is a no-op
        assert_eq!(c.note_barrier(&[]), 0.0);
        assert_eq!(c.barriers, 2);
    }

    #[test]
    fn dual_stream_overlap_consumes_ingest_windows() {
        let mut c = ShardClock::new(2);
        c.note_ingest(0, 0.0, 4.0);
        assert_eq!(c.ingest_s[0], 4.0);
        // egress [1, 2): one second concurrent
        c.note_egress(0, 1.0, 2.0);
        assert!((c.dual_stream_s - 1.0).abs() < 1e-12);
        // a second egress over the SAME ship window counts only the
        // not-yet-observed tail
        c.note_egress(0, 2.0, 10.0);
        assert!((c.dual_stream_s - 3.0).abs() < 1e-12);
        // fully observed: later egress adds nothing
        c.note_egress(0, 10.0, 12.0);
        assert!((c.dual_stream_s - 3.0).abs() < 1e-12);
        // other shards are independent
        c.note_ingest(1, 0.0, 1.0);
        c.note_egress(1, 5.0, 6.0);
        assert!((c.dual_stream_s - 3.0).abs() < 1e-12);
        // degenerate windows are ignored
        c.note_ingest(0, 3.0, 3.0);
        c.note_egress(0, 5.0, 5.0);
        assert_eq!(c.ingest_s[0], 4.0);
        // consumer-side prune at the decode frontier: a window wholly
        // behind it can never contribute overlap again
        c.note_ingest(0, 12.0, 13.0);
        c.prune_ingest(13.0);
        c.note_egress(0, 13.0, 15.0);
        assert!((c.dual_stream_s - 3.0).abs() < 1e-12, "pruned window added overlap");
    }
}
