//! The shard coordinator: N independent `InstCsd` engine instances —
//! each with its own flash array, FTL, hot tier, importance tracker and
//! local clock — driven as one logical attention device.
//!
//! A decode step fans out per the topology (head subsets or context
//! stripes), each shard executes against its own resources at its own
//! local time, and the partial results converge on the GPU through a
//! max-min fair-share PCIe model ([`crate::pcie::fair_share_finish`]):
//! all shards ship at once, so the concurrent streams share the GPU's
//! ingress link.  The step synchronizes on the slowest shard at the
//! merge barrier (gather for head shards, log-sum-exp for context
//! shards).
//!
//! With a single CSD there is nothing to transfer or merge and the
//! coordinator reduces exactly to the plain engine dataflow — the same
//! commands submitted at the same timestamps.  The shard crosscheck
//! test pins this bit-for-bit (outputs *and* completion times).

use super::clock::ShardClock;
use super::merge;
use super::ShardTopology;
use crate::config::hw::{CsdSpec, GpuSpec, PcieSpec};
use crate::config::model::FP16_BYTES;
use crate::csd::{AttnMode, CsdCommand, CsdCompletion, InstCsd, NvmeQueue, UnitBreakdown};
use crate::ftl::{prefix_hashes, FtlConfig};
use crate::kvtier::{TierConfig, TierStats};
use crate::obs::attr;
use crate::pcie::{self, XferReq};
use crate::sim::Time;
use anyhow::{Context, Result};

/// Aggregate shard-execution statistics (simulated seconds).
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    /// per-dispatch attention span (slowest shard's attention completion
    /// minus dispatch time), accumulated over sequence-layer dispatches
    pub attn_span_s: Time,
    /// all-reduce span (fair-share transfers + GPU merge), accumulated
    pub merge_span_s: Time,
    /// bytes shipped GPU-ward by partial-result transfers
    pub xfer_bytes: f64,
    /// merge barriers executed (0 on a single device)
    pub merges: u64,
    /// bytes shipped CSD-ward by the overlapped prefill stream
    /// (registered as background link load)
    pub prefill_ship_bytes: f64,
    /// all-reduces that were actually slowed by in-flight prefill KV
    /// shipping on the shared links
    pub contended_merges: u64,
    /// extra all-reduce latency attributable to that contention
    pub contention_delay_s: Time,
    /// KV bytes mirrored to peer CSDs by the replication knob
    pub replica_bytes: f64,
    /// whole-CSD losses detected on this array
    pub csd_losses: u64,
    /// device recoveries completed (replacement built and, under the
    /// replicated policy, streams restored)
    pub recoveries: u64,
    /// bytes moved peer-to-peer by replica restores
    pub restore_bytes: f64,
}

pub struct ShardCoordinator {
    pub topology: ShardTopology,
    pub queues: Vec<NvmeQueue>,
    pub clock: ShardClock,
    pub stats: ShardStats,
    pcie: PcieSpec,
    gpu: GpuSpec,
    d_head: usize,
    /// overlap executor: register prefill KV shipping as background
    /// link load so decode partial returns contend with it (off by
    /// default — the serialized path's timing is untouched)
    overlap_tracking: bool,
    /// in-flight background KV-ship transfers and their uncontended
    /// completion times (for pruning)
    bg_ship: Vec<(XferReq, Time)>,
    /// per-CSD frontier of the background ship chain: layer ships on
    /// one device link serialize (the NVMe queue runs them one after
    /// another), so their wire windows must chain, not stack
    bg_free: Vec<Time>,
    /// scoped worker threads for the per-shard fan-out sections between
    /// all-reduce barriers (1 = serial dispatch on the calling thread).
    /// Outputs, timestamps, stats and trace exports are bit-identical
    /// for any value — pinned by `tests/par.rs`.
    pub threads: usize,
    /// construction recipe kept for building replacement devices after a
    /// whole-CSD loss (spec carries the fault/replication knobs too)
    spec: CsdSpec,
    ftl_cfg: FtlConfig,
    tier: TierConfig,
    /// fault counters inherited from devices that were replaced
    retired: crate::fault::FaultTotals,
}

/// Disjoint mutable borrows of two queues (`a != b`).
fn two_queues(queues: &mut [NvmeQueue], a: usize, b: usize) -> (&mut NvmeQueue, &mut NvmeQueue) {
    debug_assert_ne!(a, b);
    if a < b {
        let (l, r) = queues.split_at_mut(b);
        (&mut l[a], &mut r[0])
    } else {
        let (l, r) = queues.split_at_mut(a);
        (&mut r[0], &mut l[b])
    }
}

impl ShardCoordinator {
    pub fn new(
        topology: ShardTopology,
        spec: CsdSpec,
        ftl_cfg: FtlConfig,
        tier: TierConfig,
        pcie: PcieSpec,
        p2p: bool,
        gpu: GpuSpec,
    ) -> Result<Self> {
        let n_csds = topology.n_csds;
        if spec.fault.kv_replicas > 0 {
            anyhow::ensure!(
                n_csds >= 2,
                "KV replication needs at least 2 CSDs to place a peer mirror"
            );
            anyhow::ensure!(
                !topology.splits_context(),
                "KV replication supports head-sharded topologies only \
                 (context stripes reuse the same stream keys on every device)"
            );
            anyhow::ensure!(
                spec.fault.kv_replicas == 1,
                "only 1 KV replica per token group is modeled"
            );
        }
        let mut queues = Vec::with_capacity(n_csds);
        for c in 0..n_csds {
            let csd = InstCsd::with_tier(spec, ftl_cfg, tier).context("constructing InstCSD")?;
            let mut q = NvmeQueue::new(csd, &pcie, p2p);
            q.dev = c;
            q.install_faults(&spec.fault);
            queues.push(q);
        }
        Ok(ShardCoordinator {
            clock: ShardClock::new(n_csds),
            topology,
            queues,
            stats: ShardStats::default(),
            pcie,
            gpu,
            d_head: ftl_cfg.d_head,
            overlap_tracking: false,
            bg_ship: Vec::new(),
            bg_free: vec![0.0; n_csds],
            threads: 1,
            spec,
            ftl_cfg,
            tier,
            retired: crate::fault::FaultTotals::default(),
        })
    }

    /// Whether the replication knob mirrors this array's KV writes.
    fn replicate(&self) -> bool {
        self.spec.fault.kv_replicas > 0 && self.topology.n_csds > 1
    }

    /// The peer CSD holding device `c`'s replica streams.
    fn replica_peer(&self, c: usize) -> usize {
        (c + 1) % self.topology.n_csds
    }

    pub fn n_csds(&self) -> usize {
        self.topology.n_csds
    }

    fn dev_bw(&self) -> f64 {
        self.pcie.ssd_link_bw * self.pcie.p2p_efficiency
    }

    fn io_lat(&self) -> Time {
        self.pcie.p2p_io_us * 1e-6
    }

    /// Enable/disable overlap link tracking (the pipelined executor
    /// turns this on; the serialized executor leaves it off so its
    /// arbiter calls — and therefore its timing — are unchanged).
    pub fn set_overlap_tracking(&mut self, on: bool) {
        self.overlap_tracking = on;
        if !on {
            self.bg_ship.clear();
            self.bg_free.iter_mut().for_each(|t| *t = 0.0);
        }
    }

    /// Register one prefill-stream KV ship to CSD `c`: background link
    /// load over the wire window (what decode partial returns contend
    /// with), and a device-side ingest window until the flash programs
    /// land (`ingest_done`) for the dual-stream clock accounting.
    fn note_prefill_ship(&mut self, c: usize, at: Time, bytes: f64, ingest_done: Time) {
        let dev_bw = self.dev_bw();
        if dev_bw <= 0.0 {
            return;
        }
        // chain on this device's link: the NVMe queue serializes the
        // layer ships, so their wire windows follow one another instead
        // of all stacking at the cohort's anchor (which would both
        // overstate simultaneous contention and end the background
        // window too early)
        let start = at.max(self.bg_free[c]);
        let wire_done = start + self.io_lat() + bytes / dev_bw;
        crate::obs::pcie_bg_span(c, "kv_ship", start, wire_done, bytes);
        self.bg_free[c] = wire_done;
        self.bg_ship.push((XferReq { start, bytes, dev_bw }, wire_done));
        self.stats.prefill_ship_bytes += bytes;
        self.clock.note_ingest(c, start, ingest_done.max(wire_done));
    }

    /// Background KV-ship transfers still in flight at `at` (prunes
    /// completed ones — dispatch times are non-decreasing).
    fn active_bg(&mut self, at: Time) -> Vec<XferReq> {
        self.bg_ship.retain(|(_, done)| *done > at);
        self.bg_ship.iter().map(|(r, _)| *r).collect()
    }

    /// The all-reduce's fair-share arbitration under background prefill
    /// KV contention: finish times for `reqs` (one per entry of
    /// `shards`), contention stats, and per-shard egress windows —
    /// shared by the head and context dispatch paths so the contention
    /// bookkeeping cannot drift between them.
    /// Returns the per-request finish times plus the total fair-share
    /// contention delay (0 when no background traffic was in the way).
    fn contended_all_reduce(
        &mut self,
        shards: &[usize],
        reqs: &[XferReq],
        at: Time,
    ) -> (Vec<Time>, Time) {
        let bg = if self.overlap_tracking { self.active_bg(at) } else { Vec::new() };
        let ingress = self.pcie.gpu_p2p_ingress_bw;
        let (fin, delay) = pcie::fair_share_contended(ingress, reqs, &bg);
        if delay > 0.0 {
            self.stats.contended_merges += 1;
            self.stats.contention_delay_s += delay;
        }
        if crate::obs::enabled() {
            for (k, &c) in shards.iter().enumerate() {
                if fin[k].is_finite() {
                    crate::obs::pcie_span(c, "all_reduce", reqs[k].start, fin[k], reqs[k].bytes);
                }
            }
        }
        if self.overlap_tracking {
            for (k, &c) in shards.iter().enumerate() {
                self.clock.note_egress(c, reqs[k].start, fin[k]);
            }
        }
        (fin, delay)
    }

    /// One sequence-layer decode on the array: ship this token's K/V,
    /// run attention on every shard, then the all-reduce back to the
    /// GPU.  `len` is the post-write context length (`kv_len + 1`).
    #[allow(clippy::too_many_arguments)]
    pub fn decode_token(
        &mut self,
        slot: u32,
        layer: u16,
        q_hd: &[f32],
        k_hd: &[f32],
        v_hd: &[f32],
        len: usize,
        mode: AttnMode,
        at: Time,
    ) -> Result<(Vec<f32>, Time, UnitBreakdown)> {
        if self.overlap_tracking {
            // consumer-side pruning at the DECODE frontier (which lags
            // the prefill stream's): ships and ingest windows wholly
            // behind `at` can never contend with this or any later
            // dispatch.  This is also what keeps the lists bounded on a
            // single CSD, where no all-reduce or egress ever runs.
            self.bg_ship.retain(|(_, done)| *done > at);
            self.clock.prune_ingest(at);
        }
        if self.topology.splits_context() {
            self.decode_token_context(slot, layer, q_hd, k_hd, v_hd, len, mode, at)
        } else {
            self.decode_token_heads(slot, layer, q_hd, k_hd, v_hd, len, mode, at)
        }
    }

    /// Head-sharded dispatch (also the single-CSD path): each shard
    /// stores and attends its own head subset over the full context.
    #[allow(clippy::too_many_arguments)]
    fn decode_token_heads(
        &mut self,
        slot: u32,
        layer: u16,
        q_hd: &[f32],
        k_hd: &[f32],
        v_hd: &[f32],
        len: usize,
        mode: AttnMode,
        at: Time,
    ) -> Result<(Vec<f32>, Time, UnitBreakdown)> {
        let n = self.topology.n_csds;
        let d = self.d_head;
        let mut bd = UnitBreakdown::default();
        let kparts = self.topology.scatter(k_hd, d);
        let vparts = self.topology.scatter(v_hd, d);
        let qparts = self.topology.scatter(q_hd, d);
        // fan out: until the all-reduce barrier below, each shard's
        // command stream is self-contained (own queue, own flash array,
        // own local clock), so the dispatches run on scoped threads in
        // contiguous shard chunks; clock advances and stat merges are
        // applied post-join in shard order, keeping every output,
        // timestamp and trace byte identical to the serial loop
        let topology = &self.topology;
        let comps = crate::sim::par::par_map_mut(
            self.threads,
            &mut self.queues,
            |c, que| -> Result<Option<CsdCompletion>> {
                let heads = topology.heads_of(c).to_vec();
                if heads.is_empty() {
                    // more devices than heads: nothing lives here, so no
                    // commands, no clock advance, no all-reduce share
                    return Ok(None);
                }
                let wr = que.submit(
                    CsdCommand::WriteToken {
                        slot,
                        layer,
                        heads: heads.clone(),
                        pos: len - 1,
                        k: kparts[c].clone(),
                        v: vparts[c].clone(),
                    },
                    at,
                )?;
                let comp = que.submit(
                    CsdCommand::Attention { slot, layer, heads, q: qparts[c].clone(), len, mode },
                    wr.done,
                )?;
                Ok(Some(comp))
            },
        );
        let mut parts: Vec<Vec<f32>> = vec![Vec::new(); n];
        let mut attn_done = vec![at; n];
        // advance every surviving shard's clock before propagating a
        // failure: a DeviceLost on one shard must not rewind (or leak
        // into) the others' frontiers across the recovery path
        let mut first_err: Option<anyhow::Error> = None;
        for (c, res) in comps.into_iter().enumerate() {
            match res {
                Ok(None) => {}
                Ok(Some(comp)) => {
                    attn_done[c] = comp.done;
                    self.clock.advance(c, comp.done);
                    if let Some(b) = &comp.breakdown {
                        bd.merge(b);
                    }
                    parts[c] = comp.data;
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        if self.replicate() {
            self.mirror_decode_writes(slot, layer, len, &kparts, &vparts, at)?;
        }
        let t_attn = attn_done.iter().cloned().fold(at, f64::max);
        self.stats.attn_span_s += t_attn - at;
        let mut done = t_attn;
        if n > 1 {
            // all-reduce: every head-bearing shard ships its partial
            // output at once; the streams fair-share the GPU ingress,
            // contending with any in-flight prefill KV shipping from
            // the overlapped prefill stream
            let active: Vec<usize> =
                (0..n).filter(|&c| !self.topology.heads_of(c).is_empty()).collect();
            let reqs: Vec<XferReq> = active
                .iter()
                .map(|&c| XferReq {
                    start: attn_done[c] + self.io_lat(),
                    bytes: (self.topology.heads_of(c).len() * d * FP16_BYTES) as f64,
                    dev_bw: self.dev_bw(),
                })
                .collect();
            let (fin, delay) = self.contended_all_reduce(&active, &reqs, at);
            let arrived = fin.iter().cloned().fold(t_attn, f64::max);
            let merge_t = merge::gather_time(&self.gpu, self.topology.n_heads, d);
            done = arrived + merge_t;
            bd.pcie_xfer += arrived - t_attn;
            bd.gpu_merge += merge_t;
            let xfer_wall = (arrived - t_attn).max(0.0);
            let contend = delay.min(xfer_wall).max(0.0);
            attr::seg(attr::Bucket::PcieContend, t_attn, done, contend);
            attr::seg(attr::Bucket::PcieXfer, t_attn, done, xfer_wall - contend);
            attr::seg(attr::Bucket::GpuMerge, t_attn, done, merge_t);
            self.stats.merge_span_s += done - t_attn;
            self.stats.xfer_bytes += reqs.iter().map(|r| r.bytes).sum::<f64>();
            self.stats.merges += 1;
            let pairs: Vec<(usize, Time)> = active.iter().map(|&c| (c, attn_done[c])).collect();
            self.clock.note_barrier(&pairs);
        }
        Ok((self.topology.gather(&parts, d), done, bd))
    }

    /// Context-sharded dispatch: the new token's K/V land on the owning
    /// stripe, every resident shard computes a locally-softmaxed partial
    /// over its tokens, and the GPU log-sum-exp-merges the partials.
    #[allow(clippy::too_many_arguments)]
    fn decode_token_context(
        &mut self,
        slot: u32,
        layer: u16,
        q_hd: &[f32],
        k_hd: &[f32],
        v_hd: &[f32],
        len: usize,
        mode: AttnMode,
        at: Time,
    ) -> Result<(Vec<f32>, Time, UnitBreakdown)> {
        anyhow::ensure!(
            mode == AttnMode::Dense,
            "context sharding supports dense attention only (SparF's token top-k is global)"
        );
        let n = self.topology.n_csds;
        let d = self.d_head;
        let h = self.topology.n_heads;
        let mut bd = UnitBreakdown::default();
        let all_heads: Vec<u16> = (0..h as u16).collect();
        let owner = self.topology.token_shard(len - 1);
        let wr = self.queues[owner].submit(
            CsdCommand::WriteToken {
                slot,
                layer,
                heads: all_heads.clone(),
                pos: self.topology.local_len(owner, len - 1),
                k: k_hd.to_vec(),
                v: v_hd.to_vec(),
            },
            at,
        )?;
        // fan out the partial-attention dispatches exactly like the
        // head path: shard streams are independent until the barrier,
        // clock/stat updates land post-join in shard order
        let topology = &self.topology;
        let wr_done = wr.done;
        let comps = crate::sim::par::par_map_mut(
            self.threads,
            &mut self.queues,
            |c, que| -> Result<Option<CsdCompletion>> {
                let llen = topology.local_len(c, len);
                if llen == 0 {
                    return Ok(None);
                }
                let start = if c == owner { wr_done } else { at };
                let comp = que.submit(
                    CsdCommand::PartialAttention {
                        slot,
                        layer,
                        heads: all_heads.clone(),
                        q: q_hd.to_vec(),
                        local_len: llen,
                    },
                    start,
                )?;
                Ok(Some(comp))
            },
        );
        let mut attn_done = vec![at; n];
        let mut pdata: Vec<Vec<f32>> = vec![Vec::new(); n];
        let mut pstats: Vec<Vec<(f32, f32)>> = vec![Vec::new(); n];
        let mut pweights: Vec<Vec<f32>> = vec![Vec::new(); n];
        // as in the head path: land every surviving shard's completion
        // before propagating the first failure
        let mut first_err: Option<anyhow::Error> = None;
        for (c, res) in comps.into_iter().enumerate() {
            match res {
                Ok(None) => {}
                Ok(Some(comp)) => {
                    attn_done[c] = comp.done;
                    self.clock.advance(c, comp.done);
                    if let Some(b) = &comp.breakdown {
                        bd.merge(b);
                    }
                    pdata[c] = comp.data;
                    pstats[c] = comp.stats;
                    pweights[c] = comp.weights;
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let t_attn = attn_done.iter().cloned().fold(at, f64::max);
        self.stats.attn_span_s += t_attn - at;
        let joined: Vec<usize> = (0..n).filter(|&c| !pstats[c].is_empty()).collect();
        // all-reduce: every participant ships outputs + LSE stats,
        // contending with in-flight prefill KV from the overlap stream
        let bytes = (h * (d + 2) * FP16_BYTES) as f64;
        let reqs: Vec<XferReq> = joined
            .iter()
            .map(|&c| XferReq {
                start: attn_done[c] + self.io_lat(),
                bytes,
                dev_bw: self.dev_bw(),
            })
            .collect();
        let (fin, delay) = self.contended_all_reduce(&joined, &reqs, at);
        let arrived = fin.iter().cloned().fold(t_attn, f64::max);
        let merge_t = merge::lse_merge_time(&self.gpu, h, d, joined.len());
        let done = arrived + merge_t;
        bd.pcie_xfer += arrived - t_attn;
        bd.gpu_merge += merge_t;
        let xfer_wall = (arrived - t_attn).max(0.0);
        let contend = delay.min(xfer_wall).max(0.0);
        attr::seg(attr::Bucket::PcieContend, t_attn, done, contend);
        attr::seg(attr::Bucket::PcieXfer, t_attn, done, xfer_wall - contend);
        attr::seg(attr::Bucket::GpuMerge, t_attn, done, merge_t);
        self.stats.merge_span_s += done - t_attn;
        self.stats.xfer_bytes += bytes * joined.len() as f64;
        self.stats.merges += 1;
        let pairs: Vec<(usize, Time)> = joined.iter().map(|&c| (c, attn_done[c])).collect();
        self.clock.note_barrier(&pairs);
        // functional merge, head by head, over the shared merge weights
        let head_w: Vec<Vec<f32>> = (0..h)
            .map(|head| {
                let stats_h: Vec<(f32, f32)> = joined.iter().map(|&c| pstats[c][head]).collect();
                merge::merge_weights(&stats_h)
            })
            .collect();
        let mut out = vec![0.0f32; h * d];
        for head in 0..h {
            let dst = &mut out[head * d..(head + 1) * d];
            for (idx, &c) in joined.iter().enumerate() {
                let wc = head_w[head][idx];
                if wc == 0.0 {
                    continue;
                }
                let src = &pdata[c][head * d..(head + 1) * d];
                for (o, &x) in dst.iter_mut().zip(src) {
                    *o += wc * x;
                }
            }
        }
        // H2O write-back: the partial path defers importance so the GPU
        // can rescale each shard's local softmax weights by its merge
        // weight — w_c * s_local is exactly the token's global softmax
        // mass, keeping cross-shard drop-on-resume comparisons honest
        for (idx, &c) in joined.iter().enumerate() {
            let llen = pweights[c].len() / h;
            let mut scaled = vec![0.0f32; llen];
            for head in 0..h {
                let wc = head_w[head][idx];
                if wc == 0.0 {
                    continue;
                }
                for (t, s) in scaled.iter_mut().enumerate() {
                    *s += wc * pweights[c][head * llen + t];
                }
            }
            let comp = self.queues[c]
                .submit(CsdCommand::AccumulateImportance { slot, weights: scaled }, done)?;
            self.clock.advance(c, comp.done);
        }
        Ok((out, done, bd))
    }

    /// Ship one sequence's prefill layer.  `k_seq`/`v_seq` are the
    /// `(H, sp, d)` blocks for this sequence; `len` is the prompt
    /// length.  Head policies send each shard its heads' rows over the
    /// whole prompt; context striping sends each shard its token groups
    /// for every head.  `skip` global tokens (the attached cached
    /// prefix, always a group multiple; 0 without prefix caching — the
    /// commands are then byte-identical to the pre-prefix engine) are
    /// already resident and are neither shipped nor re-programmed.
    #[allow(clippy::too_many_arguments)]
    pub fn prefill_layer(
        &mut self,
        slot: u32,
        layer: u16,
        sp: usize,
        len: usize,
        skip: usize,
        k_seq: &[f32],
        v_seq: &[f32],
        at: Time,
    ) -> Result<Time> {
        let d = self.d_head;
        let h = self.topology.n_heads;
        anyhow::ensure!(
            k_seq.len() == h * sp * d && v_seq.len() == h * sp * d,
            "prefill rows mismatch"
        );
        anyhow::ensure!(skip <= len, "prefix skip {skip} > prompt {len}");
        // fan out: each shard's K/V gather (the CPU-heavy slice
        // assembly) and its WritePrefillLayer submit are independent of
        // every other shard's; background-ship registration and clock
        // advances are applied post-join in shard order, so the wire
        // windows chain — and the trace exports byte-match — exactly as
        // in the serial loop
        let topology = &self.topology;
        let ships: Vec<Result<Option<(f64, Time)>>> = if topology.splits_context() {
            crate::sim::par::par_map_mut(self.threads, &mut self.queues, |c, que| {
                let llen = topology.local_len(c, len);
                // this shard's share of the attached prefix is already
                // resident at local positions [0, lskip)
                let lskip = topology.local_len(c, skip);
                if llen == lskip {
                    return Ok(None);
                }
                let mut kp = Vec::with_capacity(h * (llen - lskip) * d);
                let mut vp = Vec::with_capacity(h * (llen - lskip) * d);
                for hh in 0..h {
                    for lt in lskip..llen {
                        let t = topology.to_global(c, lt);
                        let base = (hh * sp + t) * d;
                        kp.extend_from_slice(&k_seq[base..base + d]);
                        vp.extend_from_slice(&v_seq[base..base + d]);
                    }
                }
                let ship_bytes = ((kp.len() + vp.len()) * FP16_BYTES) as f64;
                let comp = que.submit(
                    CsdCommand::WritePrefillLayer {
                        slot,
                        layer,
                        heads: (0..h as u16).collect(),
                        pos: lskip,
                        s_len: llen - lskip,
                        k: kp,
                        v: vp,
                    },
                    at,
                )?;
                Ok(Some((ship_bytes, comp.done)))
            })
        } else {
            crate::sim::par::par_map_mut(self.threads, &mut self.queues, |c, que| {
                let heads = topology.heads_of(c).to_vec();
                if heads.is_empty() {
                    return Ok(None); // more devices than heads: nothing lives here
                }
                if skip == len {
                    return Ok(None); // whole prompt attached: nothing to ship
                }
                let mut kp = Vec::with_capacity(heads.len() * (len - skip) * d);
                let mut vp = Vec::with_capacity(heads.len() * (len - skip) * d);
                for &hh in &heads {
                    let base = hh as usize * sp * d;
                    kp.extend_from_slice(&k_seq[base + skip * d..base + len * d]);
                    vp.extend_from_slice(&v_seq[base + skip * d..base + len * d]);
                }
                let ship_bytes = ((kp.len() + vp.len()) * FP16_BYTES) as f64;
                let comp = que.submit(
                    CsdCommand::WritePrefillLayer {
                        slot,
                        layer,
                        heads,
                        pos: skip,
                        s_len: len - skip,
                        k: kp,
                        v: vp,
                    },
                    at,
                )?;
                Ok(Some((ship_bytes, comp.done)))
            })
        };
        let mut done = at;
        let mut first_err: Option<anyhow::Error> = None;
        for (c, res) in ships.into_iter().enumerate() {
            match res {
                Ok(None) => {}
                Ok(Some((ship_bytes, comp_done))) => {
                    if self.overlap_tracking {
                        self.note_prefill_ship(c, at, ship_bytes, comp_done);
                    }
                    self.clock.advance(c, comp_done);
                    done = done.max(comp_done);
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        if self.replicate() && skip < len {
            done = done.max(self.mirror_prefill_writes(
                slot, layer, sp, len, skip, k_seq, v_seq, at,
            )?);
        }
        Ok(done)
    }

    /// Mirror one decode token's per-shard K/V to each shard's replica
    /// peer (head policies only; the replica stream reuses the primary's
    /// `StreamKey`, which is collision-free because head subsets are
    /// disjoint across devices).  Runs post-join at the dispatch anchor,
    /// so the mirror overlaps the attention fan-out on the wire model
    /// but serializes behind the peer's own write in its queue.
    fn mirror_decode_writes(
        &mut self,
        slot: u32,
        layer: u16,
        len: usize,
        kparts: &[Vec<f32>],
        vparts: &[Vec<f32>],
        at: Time,
    ) -> Result<()> {
        for c in 0..self.topology.n_csds {
            let heads = self.topology.heads_of(c).to_vec();
            if heads.is_empty() {
                continue;
            }
            let peer = self.replica_peer(c);
            if self.queues[peer].dead(at) {
                continue; // the peer is the lost device; its replicas die with it
            }
            let bytes = ((kparts[c].len() + vparts[c].len()) * FP16_BYTES) as f64;
            let comp = self.queues[peer].submit(
                CsdCommand::WriteToken {
                    slot,
                    layer,
                    heads,
                    pos: len - 1,
                    k: kparts[c].clone(),
                    v: vparts[c].clone(),
                },
                at,
            )?;
            self.clock.advance(peer, comp.done);
            self.stats.replica_bytes += bytes;
        }
        Ok(())
    }

    /// Mirror one prefill layer to each shard's replica peer (see
    /// [`Self::mirror_decode_writes`]).  Returns the latest mirror
    /// completion: the layer only counts as sealed once its replica is
    /// durable too.
    #[allow(clippy::too_many_arguments)]
    fn mirror_prefill_writes(
        &mut self,
        slot: u32,
        layer: u16,
        sp: usize,
        len: usize,
        skip: usize,
        k_seq: &[f32],
        v_seq: &[f32],
        at: Time,
    ) -> Result<Time> {
        let d = self.d_head;
        let mut done = at;
        for c in 0..self.topology.n_csds {
            let heads = self.topology.heads_of(c).to_vec();
            if heads.is_empty() {
                continue;
            }
            let peer = self.replica_peer(c);
            if self.queues[peer].dead(at) {
                continue;
            }
            let mut kp = Vec::with_capacity(heads.len() * (len - skip) * d);
            let mut vp = Vec::with_capacity(heads.len() * (len - skip) * d);
            for &hh in &heads {
                let base = hh as usize * sp * d;
                kp.extend_from_slice(&k_seq[base + skip * d..base + len * d]);
                vp.extend_from_slice(&v_seq[base + skip * d..base + len * d]);
            }
            let bytes = ((kp.len() + vp.len()) * FP16_BYTES) as f64;
            let comp = self.queues[peer].submit(
                CsdCommand::WritePrefillLayer {
                    slot,
                    layer,
                    heads,
                    pos: skip,
                    s_len: len - skip,
                    k: kp,
                    v: vp,
                },
                at,
            )?;
            self.clock.advance(peer, comp.done);
            self.stats.replica_bytes += bytes;
            done = done.max(comp.done);
        }
        Ok(done)
    }

    /// First device already dead at `at`, if any.
    pub fn dead_device(&self, at: Time) -> Option<usize> {
        self.queues.iter().position(|q| q.dead(at))
    }

    /// The recovery policy configured on this array's spec.
    pub fn recovery_policy(&self) -> crate::fault::RecoveryPolicy {
        self.spec.fault.recovery
    }

    /// Swap lost device `c` for a fresh replacement: same device index
    /// and command path, empty flash/FTL/hot tier, clean bill of health.
    /// The dead device's fault counters are folded into the array totals
    /// before it is dropped.
    pub fn replace_device(&mut self, c: usize) -> Result<()> {
        let csd = InstCsd::with_tier(self.spec, self.ftl_cfg, self.tier)
            .context("constructing replacement InstCSD")?;
        let old = &self.queues[c];
        self.retired.nvme_timeouts += old.timeouts;
        self.retired.nvme_retry_s += old.retry_s;
        self.retired.flash_ecc_corrected += old.csd.ftl.array.counters.ecc_corrected;
        self.retired.flash_read_retries += old.csd.ftl.array.counters.read_retries;
        self.retired.flash_bad_blocks += old.csd.ftl.counters.bad_blocks;
        let succ = self.queues[c].successor(csd);
        self.queues[c] = succ;
        self.stats.csd_losses += 1;
        Ok(())
    }

    /// Restore device `lost`'s KV onto its (already-replaced, empty)
    /// successor from the peer mirrors: the lost primaries come off the
    /// replica peer, and the replicas the lost device was holding for
    /// its predecessor are rebuilt from that predecessor's primaries —
    /// so the array tolerates a subsequent single loss too.  Returns the
    /// restore completion time.
    pub fn restore_from_replica(&mut self, lost: usize, at: Time) -> Result<Time> {
        anyhow::ensure!(
            self.replicate(),
            "replica restore needs --kv-replicas 1 on a multi-CSD head topology"
        );
        let n = self.topology.n_csds;
        let peer = self.replica_peer(lost);
        let prev = (lost + n - 1) % n;
        let mut t = at;
        let mut bytes = 0f64;
        // (source device, heads whose streams to copy)
        let plans: [(usize, Vec<u16>); 2] = [
            (peer, self.topology.heads_of(lost).to_vec()),
            (prev, self.topology.heads_of(prev).to_vec()),
        ];
        for (src, heads) in plans {
            if src == lost || heads.is_empty() {
                continue;
            }
            let keys: Vec<crate::ftl::StreamKey> = self.queues[src]
                .csd
                .ftl
                .stream_keys()
                .into_iter()
                .filter(|k| {
                    k.slot < crate::ftl::PREFIX_SLOT_BASE && heads.contains(&k.head)
                })
                .collect();
            for key in keys {
                let (a, b) = two_queues(&mut self.queues, src, lost);
                let (exp, rd) = a.csd.ftl.export_stream(key, at)?;
                let wr = b.csd.ftl.import_stream(key, &exp, rd)?;
                bytes += exp.bytes() as f64;
                t = t.max(wr);
                self.clock.advance(src, rd);
                self.clock.advance(lost, wr);
            }
        }
        self.stats.restore_bytes += bytes;
        self.stats.recoveries += 1;
        crate::obs::device_instant(lost, "replica_restore", t);
        Ok(t)
    }

    /// Aggregate fault counters across the array (live devices plus the
    /// retired counters of replaced ones).
    pub fn fault_totals(&self) -> crate::fault::FaultTotals {
        let mut tot = self.retired;
        for q in &self.queues {
            tot.nvme_timeouts += q.timeouts;
            tot.nvme_retry_s += q.retry_s;
            tot.flash_ecc_corrected += q.csd.ftl.array.counters.ecc_corrected;
            tot.flash_read_retries += q.csd.ftl.array.counters.read_retries;
            tot.flash_bad_blocks += q.csd.ftl.counters.bad_blocks;
        }
        tot
    }

    /// Local tokens of a `global`-token prefix resident on shard `c`:
    /// all of them for a head-bearing shard under head policies, the
    /// stripe's round-robin share under context striping, 0 where
    /// nothing lives.
    fn shard_prefix_tokens(&self, c: usize, global: usize) -> usize {
        if self.topology.splits_context() {
            self.topology.local_len(c, global)
        } else if self.topology.heads_of(c).is_empty() {
            0
        } else {
            global
        }
    }

    /// Longest registered prefix of `prompt` on the array, in global
    /// tokens (0 when nothing matches).  Shard 0's index is the
    /// representative: register/attach commands mirror to every
    /// populated shard, so the per-device indexes stay in lockstep, and
    /// shard 0 always owns the first token group.
    pub fn prefix_match(&self, prompt: &[i32]) -> usize {
        let n = self.queues[0].csd.ftl.cfg.n;
        let hashes = prefix_hashes(prompt, n);
        match self.queues[0].csd.ftl.lookup_prefix(&hashes) {
            Some(i) => (i + 1) * n,
            None => 0,
        }
    }

    /// Attach the cached prefix covering `hit` global tokens of the
    /// prompt to `slot` on every shard that holds part of it — a
    /// metadata-only NVMe command per shard (the aliased flash pages
    /// never move, so only the command latency is charged).
    pub fn attach_prefix(&mut self, slot: u32, prompt: &[i32], hit: usize, at: Time) -> Result<Time> {
        let n = self.queues[0].csd.ftl.cfg.n;
        let hashes = prefix_hashes(&prompt[..hit], n);
        let hash = *hashes.last().expect("attach below one token group");
        let mut done = at;
        for c in 0..self.topology.n_csds {
            if self.shard_prefix_tokens(c, hit) == 0 {
                continue;
            }
            let comp = self.queues[c].submit(CsdCommand::AttachPrefix { slot, hash }, at)?;
            self.clock.advance(c, comp.done);
            done = done.max(comp.done);
        }
        Ok(done)
    }

    /// Register `slot`'s just-shipped prompt in the content-addressed
    /// prefix index of every shard, each with its local token count per
    /// group boundary.
    pub fn register_prefix(&mut self, slot: u32, prompt: &[i32], at: Time) -> Result<Time> {
        let n = self.queues[0].csd.ftl.cfg.n;
        let hashes = prefix_hashes(prompt, n);
        if hashes.is_empty() {
            return Ok(at);
        }
        let mut done = at;
        for c in 0..self.topology.n_csds {
            let bounds: Vec<(u64, usize)> = hashes
                .iter()
                .enumerate()
                .filter_map(|(i, &h)| {
                    let local = self.shard_prefix_tokens(c, (i + 1) * n);
                    (local > 0).then_some((h, local))
                })
                .collect();
            if bounds.is_empty() {
                continue;
            }
            let comp = self.queues[c].submit(CsdCommand::RegisterPrefix { slot, bounds }, at)?;
            self.clock.advance(c, comp.done);
            done = done.max(comp.done);
        }
        Ok(done)
    }

    /// Release a finished sequence on every shard (chained completions,
    /// exactly like the engine's original loop — identical at N=1).
    pub fn free_slot(&mut self, slot: u32, at: Time) -> Result<Time> {
        let mut t = at;
        for c in 0..self.topology.n_csds {
            let comp = self.queues[c].submit(CsdCommand::FreeSlot { slot }, t)?;
            self.clock.advance(c, comp.done);
            t = t.max(comp.done);
        }
        Ok(t)
    }

    /// Mask token positions (GLOBAL coordinates) out of future
    /// attention.  Head policies broadcast to every shard; context
    /// striping routes each position to its owner in local coordinates.
    pub fn drop_tokens(&mut self, slot: u32, tokens: &[u32], at: Time) -> Result<Time> {
        let mut t = at;
        if self.topology.splits_context() {
            let mut per: Vec<Vec<u32>> = vec![Vec::new(); self.topology.n_csds];
            for &tok in tokens {
                let (c, lt) = self.topology.to_local(tok as usize);
                per[c].push(lt as u32);
            }
            for (c, local) in per.into_iter().enumerate() {
                if local.is_empty() {
                    continue;
                }
                let comp =
                    self.queues[c].submit(CsdCommand::DropTokens { slot, tokens: local }, t)?;
                self.clock.advance(c, comp.done);
                t = t.max(comp.done);
            }
        } else {
            for c in 0..self.topology.n_csds {
                let comp = self.queues[c]
                    .submit(CsdCommand::DropTokens { slot, tokens: tokens.to_vec() }, t)?;
                self.clock.advance(c, comp.done);
                t = t.max(comp.done);
            }
        }
        Ok(t)
    }

    /// Cumulative per-token attention mass for `slot` in GLOBAL
    /// positions, summed across the array (context shards report local
    /// indices, which are mapped back through the stripe).
    pub fn token_importance(&self, slot: u32) -> Vec<f32> {
        let mut out: Vec<f32> = Vec::new();
        for (c, q) in self.queues.iter().enumerate() {
            let Some(s) = q.csd.tier.importance.scores(slot) else { continue };
            if self.topology.splits_context() {
                for (lt, &v) in s.iter().enumerate() {
                    let g = self.topology.to_global(c, lt);
                    if g >= out.len() {
                        out.resize(g + 1, 0.0);
                    }
                    out[g] += v;
                }
            } else {
                if s.len() > out.len() {
                    out.resize(s.len(), 0.0);
                }
                for (o, &v) in out.iter_mut().zip(s) {
                    *o += v;
                }
            }
        }
        out
    }

    /// Hot-tier statistics aggregated across the array.
    pub fn tier_stats(&self) -> TierStats {
        TierStats::merged(self.queues.iter().map(|q| &q.csd.tier.stats))
    }

    /// Aggregate flash-array utilisation across the shards (busy times
    /// sum; the peak die queue depth takes the worst device).
    pub fn flash_util(&self) -> crate::csd::FlashUtil {
        let mut u = crate::csd::FlashUtil::default();
        for q in &self.queues {
            u.merge(&q.csd.flash_util());
        }
        u
    }

    /// Per-shard hot-tier statistics (the tier dashboard's per-device
    /// rows).
    pub fn per_shard_tier_stats(&self) -> Vec<TierStats> {
        self.queues.iter().map(|q| q.csd.tier.stats).collect()
    }

    /// Bytes currently resident in the hot tiers of all shards.
    pub fn tier_hot_bytes(&self) -> usize {
        self.queues.iter().map(|q| q.csd.tier.hot.bytes()).sum()
    }

    /// Configured hot-tier capacity across all shards.
    pub fn tier_capacity_bytes(&self) -> usize {
        self.queues.iter().map(|q| q.csd.tier.cfg.hot_bytes).sum()
    }

    /// Flash-mapped KV bytes per shard, token + dual-K embedding pages
    /// (the cold-tier footprint each device actually carries — balanced
    /// by construction for head stripes, group-balanced for context
    /// stripes).
    pub fn mapped_kv_bytes(&self) -> Vec<u64> {
        self.queues
            .iter()
            .map(|q| (q.csd.ftl.mapped_pages_total() * q.csd.spec.flash.page_bytes) as u64)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::ShardPolicy;
    use crate::util::rng::Rng;

    fn coord(n: usize, policy: ShardPolicy) -> ShardCoordinator {
        let topology = ShardTopology::new(n, policy, 4, 8);
        ShardCoordinator::new(
            topology,
            CsdSpec::tiny(),
            FtlConfig::micro_head(),
            TierConfig::flash_only(),
            PcieSpec::paper(),
            true,
            GpuSpec::a6000(),
        )
        .unwrap()
    }

    fn decode_some(
        co: &mut ShardCoordinator,
        toks: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, Time) {
        let d = 32;
        let h = 4;
        let mut out = Vec::new();
        let mut done = 0.0;
        for t in 0..toks {
            let k: Vec<f32> = (0..h * d).map(|_| rng.normal_f32()).collect();
            let v: Vec<f32> = (0..h * d).map(|_| rng.normal_f32()).collect();
            let q: Vec<f32> = (0..h * d).map(|_| rng.normal_f32()).collect();
            let (o, dn, _) = co
                .decode_token(0, 0, &q, &k, &v, t + 1, AttnMode::Dense, 0.0)
                .unwrap();
            out = o;
            done = dn;
        }
        (out, done)
    }

    #[test]
    fn head_outputs_identical_across_shard_counts() {
        // heads are computed independently over identical data, so the
        // merged outputs are bit-identical no matter the shard count
        let mut rng1 = Rng::new(21);
        let mut rng2 = Rng::new(21);
        let mut rng4 = Rng::new(21);
        let mut c1 = coord(1, ShardPolicy::HeadStripe);
        let mut c2 = coord(2, ShardPolicy::HeadStripe);
        let mut c4 = coord(4, ShardPolicy::HeadBlock);
        let (o1, _) = decode_some(&mut c1, 12, &mut rng1);
        let (o2, _) = decode_some(&mut c2, 12, &mut rng2);
        let (o4, _) = decode_some(&mut c4, 12, &mut rng4);
        assert_eq!(o1, o2);
        assert_eq!(o1, o4);
        assert_eq!(c1.stats.merges, 0, "single device never merges");
        assert!(c2.stats.merges > 0 && c2.stats.xfer_bytes > 0.0);
        assert!(c2.clock.barriers > 0);
    }

    #[test]
    fn context_merge_matches_single_device() {
        let mut rng1 = Rng::new(22);
        let mut rng2 = Rng::new(22);
        let mut c1 = coord(1, ShardPolicy::Context);
        let mut c2 = coord(2, ShardPolicy::Context);
        // 20 tokens: groups 0,1 on shard 0, group 2 (incl. tail) on 1
        let (o1, _) = decode_some(&mut c1, 20, &mut rng1);
        let (o2, _) = decode_some(&mut c2, 20, &mut rng2);
        assert_eq!(o1.len(), o2.len());
        for (a, b) in o1.iter().zip(&o2) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        // both shards actually hold KV
        let mapped = c2.mapped_kv_bytes();
        assert!(mapped[0] > 0 && mapped[1] > 0, "{mapped:?}");
        // the importance write-back reproduces the single device's H2O
        // signal: w_c-rescaled local weights == global softmax mass
        let i1 = c1.token_importance(0);
        let i2 = c2.token_importance(0);
        assert_eq!(i1.len(), i2.len());
        for (a, b) in i1.iter().zip(&i2) {
            assert!((a - b).abs() < 1e-2 * (1.0 + a.abs()), "importance {a} vs {b}");
        }
    }

    #[test]
    fn context_rejects_sparf() {
        let mut co = coord(2, ShardPolicy::Context);
        let sp = crate::config::model::SparsityParams { r: 8, k: 16, m: 4, n: 8 };
        let q = vec![0.0f32; 4 * 32];
        let err = co
            .decode_token(0, 0, &q, &q, &q, 1, AttnMode::SparF(sp), 0.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("dense attention only"), "{err}");
    }

    #[test]
    fn sharding_speeds_up_attention_span() {
        let mut rng1 = Rng::new(23);
        let mut rng2 = Rng::new(23);
        let mut c1 = coord(1, ShardPolicy::HeadStripe);
        let mut c2 = coord(2, ShardPolicy::HeadStripe);
        decode_some(&mut c1, 24, &mut rng1);
        decode_some(&mut c2, 24, &mut rng2);
        assert!(
            c2.stats.attn_span_s < c1.stats.attn_span_s,
            "2 shards {} !< 1 shard {}",
            c2.stats.attn_span_s,
            c1.stats.attn_span_s
        );
    }

    #[test]
    fn free_slot_clears_every_shard() {
        let mut rng = Rng::new(24);
        let mut co = coord(2, ShardPolicy::Context);
        decode_some(&mut co, 20, &mut rng);
        let t = co.free_slot(0, 0.0).unwrap();
        assert!(t > 0.0);
        for b in co.mapped_kv_bytes() {
            assert_eq!(b, 0);
        }
    }

    #[test]
    fn drop_tokens_routes_to_owning_stripe() {
        let mut rng = Rng::new(25);
        let mut co = coord(2, ShardPolicy::Context);
        decode_some(&mut co, 32, &mut rng);
        // drop global group 1 (tokens 8..16) — it lives on shard 1
        let before = co.mapped_kv_bytes();
        let drop: Vec<u32> = (8..16).collect();
        co.drop_tokens(0, &drop, 0.0).unwrap();
        let after = co.mapped_kv_bytes();
        assert_eq!(before[0], after[0], "shard 0 untouched");
        assert!(after[1] < before[1], "shard 1 freed the group");
        // importance comes back in global coordinates
        let imp = co.token_importance(0);
        assert_eq!(imp.len(), 32);
    }
}
