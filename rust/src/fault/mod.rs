//! Deterministic fault plane: seed-reproducible failure injection for
//! every layer of the KV data path.
//!
//! Three fault classes, one knob surface ([`FaultConfig`] on the CSD
//! spec):
//!
//! - **flash page reads** fail transiently (ECC-correctable, or
//!   uncorrectable with escalating read-retry `tR` steps) or permanently
//!   (bad block — the FTL relocates the still-valid pages with full
//!   refcount/prefix-sharing discipline and retires the block);
//! - **NVMe commands** time out and are retried with exponential
//!   backoff; past the retry budget the error completion propagates as
//!   a typed `Result` instead of being assumed successful;
//! - **a whole CSD dies** mid-decode ([`FaultConfig::csd_loss`]); the
//!   shard coordinator + scheduler then recover the lost heads' KV by
//!   re-prefill or from a peer replica ([`RecoveryPolicy`]).
//!
//! Determinism contract: every injection site draws from a private
//! per-device, per-domain xoshiro stream seeded from
//! `(FaultConfig::seed, device, domain)`.  Per-device command order is
//! thread-count invariant (the `sim/par.rs` dispatch preserves it), so
//! the fault sequence is too — same seed, same faults, any `--threads`.
//! With `rate == 0` and no scheduled loss, no stream is even
//! constructed and the engine is bit-identical (outputs AND timestamps)
//! to the fault-free build.

use crate::util::rng::Rng;

/// Simulated latency to *detect* an NVMe command timeout (the host-side
/// completion poll deadline).
pub const TIMEOUT_DETECT_S: f64 = 500e-6;
/// Base step of the exponential retry backoff (doubles per attempt,
/// exponent capped so the wait stays bounded).
pub const BACKOFF_BASE_S: f64 = 100e-6;
/// NVMe retry budget; exceeding it surfaces [`FaultError::CommandTimeout`].
pub const MAX_RETRY: u32 = 8;

/// Extra `tR` fraction added by a correctable-ECC read (one soft retry
/// inside the die, no host involvement).
pub const ECC_EXTRA_TR: f64 = 0.2;
/// Per-step escalation of the read-retry voltage sweep: retry `i` costs
/// an extra `0.5 * i * tR`.
pub const RETRY_STEP_TR: f64 = 0.5;

/// Domain tags separating the per-device fault streams so flash reads
/// and NVMe submissions never share draws.
pub const DOMAIN_NVME: u64 = 1;
pub const DOMAIN_FLASH: u64 = 2;

/// How the serving plane recovers the lost heads' KV after a CSD dies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryPolicy {
    /// No KV recovery: in-flight requests on the lost device abort; the
    /// replacement device serves new traffic only.
    RetryOnly,
    /// Re-run prefill for affected requests on the replacement device
    /// (reuses the restart machinery; no extra capacity cost).
    RePrefill,
    /// Restore the lost streams from a peer CSD's mirror
    /// (`--kv-replicas 1`): capacity-for-availability tradeoff.
    Replicated,
}

impl RecoveryPolicy {
    pub fn parse(s: &str) -> anyhow::Result<RecoveryPolicy> {
        match s {
            "retry" => Ok(RecoveryPolicy::RetryOnly),
            "reprefill" => Ok(RecoveryPolicy::RePrefill),
            "replicated" => Ok(RecoveryPolicy::Replicated),
            other => anyhow::bail!("unknown recovery policy {other:?} (retry|reprefill|replicated)"),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            RecoveryPolicy::RetryOnly => "retry",
            RecoveryPolicy::RePrefill => "reprefill",
            RecoveryPolicy::Replicated => "replicated",
        }
    }
}

/// Fault-injection knobs, carried on [`crate::config::hw::CsdSpec`] so
/// every engine layer sees the same configuration.  `none()` (the
/// default everywhere) constructs no RNG state and injects nothing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Base seed for every per-device fault stream.
    pub seed: u64,
    /// Per-operation fault probability (flash page reads and NVMe
    /// command submissions draw independently).
    pub rate: f64,
    /// Scheduled whole-device loss: `(device index, sim time)`.  The
    /// device rejects every submission at or after the given time until
    /// the coordinator replaces it.
    pub csd_loss: Option<(usize, f64)>,
    /// What the scheduler does about a lost device's KV.
    pub recovery: RecoveryPolicy,
    /// Mirror sealed KV writes to this many peer CSDs (0 or 1).
    pub kv_replicas: u8,
}

impl FaultConfig {
    pub fn none() -> FaultConfig {
        FaultConfig {
            seed: 0,
            rate: 0.0,
            csd_loss: None,
            recovery: RecoveryPolicy::RePrefill,
            kv_replicas: 0,
        }
    }

    /// True when per-operation injection is on (flash/NVMe draws).
    pub fn injecting(&self) -> bool {
        self.rate > 0.0
    }

    /// True when *any* part of the fault plane is active (injection,
    /// scheduled loss, or replication).
    pub fn any_active(&self) -> bool {
        self.rate > 0.0 || self.csd_loss.is_some() || self.kv_replicas > 0
    }
}

impl Default for FaultConfig {
    fn default() -> FaultConfig {
        FaultConfig::none()
    }
}

/// Aggregate fault/recovery counters across a CSD array — the metrics
/// surface of the fault plane (all zeros with faults off).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultTotals {
    /// NVMe command timeouts detected (each cost one detect + backoff)
    pub nvme_timeouts: u64,
    /// wall seconds spent in NVMe timeout detection + backoff
    pub nvme_retry_s: f64,
    /// flash reads that needed a correctable-ECC soft retry
    pub flash_ecc_corrected: u64,
    /// escalating read-retry steps taken on uncorrectable flash reads
    pub flash_read_retries: u64,
    /// blocks retired permanently (valid pages relocated by the FTL)
    pub flash_bad_blocks: u64,
}

impl FaultTotals {
    pub fn add(&mut self, other: &FaultTotals) {
        self.nvme_timeouts += other.nvme_timeouts;
        self.nvme_retry_s += other.nvme_retry_s;
        self.flash_ecc_corrected += other.flash_ecc_corrected;
        self.flash_read_retries += other.flash_read_retries;
        self.flash_bad_blocks += other.flash_bad_blocks;
    }
}

/// Typed fault completions.  Carried through `anyhow::Result` chains;
/// callers that need to branch on the class downcast with
/// `e.downcast_ref::<FaultError>()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultError {
    /// The device is dead (scheduled loss fired); the submission never
    /// entered the queue.
    DeviceLost { dev: usize },
    /// The command timed out `attempts` times and exhausted the retry
    /// budget.
    CommandTimeout { dev: usize, cmd: &'static str, attempts: u32 },
    /// The command failed validation before dispatch — a host-side bug,
    /// surfaced as an error completion instead of a panic.
    MalformedCommand { dev: usize, cmd: &'static str, why: String },
}

impl std::fmt::Display for FaultError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultError::DeviceLost { dev } => write!(f, "csd{dev} is lost"),
            FaultError::CommandTimeout { dev, cmd, attempts } => {
                write!(f, "csd{dev} {cmd} timed out after {attempts} attempts")
            }
            FaultError::MalformedCommand { dev, cmd, why } => {
                write!(f, "csd{dev} malformed {cmd}: {why}")
            }
        }
    }
}

impl std::error::Error for FaultError {}

/// Stable per-device, per-domain stream seed (splitmix-style avalanche
/// so adjacent devices get uncorrelated streams).
fn mix(seed: u64, dev: u64, domain: u64) -> u64 {
    let mut x = seed
        .wrapping_mul(0x9e3779b97f4a7c15)
        .wrapping_add(dev.wrapping_mul(0xbf58476d1ce4e5b9))
        .wrapping_add(domain.wrapping_mul(0x94d049bb133111eb));
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Per-device injection state: a private RNG stream plus the rate.
/// Only constructed when `rate > 0` — the `Option<FaultState>` gate is
/// what makes faults-off bit-identical.
#[derive(Debug, Clone)]
pub struct FaultState {
    pub rate: f64,
    rng: Rng,
}

impl FaultState {
    pub fn new(cfg: &FaultConfig, dev: usize, domain: u64) -> FaultState {
        FaultState { rate: cfg.rate, rng: Rng::new(mix(cfg.seed, dev as u64, domain)) }
    }

    /// One Bernoulli trial at the configured rate (always consumes
    /// exactly one draw, so the stream position is operation-count
    /// deterministic).
    pub fn trips(&mut self) -> bool {
        self.rng.f64() < self.rate
    }

    /// Uniform severity draw in [0, 1) for sites that need to pick a
    /// fault class after `trips()` fired.
    pub fn severity(&mut self) -> f64 {
        self.rng.f64()
    }
}

/// Detect-plus-backoff delay for NVMe retry attempt `attempt` (1-based):
/// timeout detection plus an exponentially growing wait, exponent capped
/// at 6 so a deep retry chain stays bounded.
pub fn retry_delay(attempt: u32) -> f64 {
    TIMEOUT_DETECT_S + BACKOFF_BASE_S * (1u64 << (attempt - 1).min(6)) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_inactive() {
        let f = FaultConfig::none();
        assert!(!f.injecting());
        assert!(!f.any_active());
        assert_eq!(f, FaultConfig::default());
    }

    #[test]
    fn any_active_tracks_each_knob() {
        let mut f = FaultConfig::none();
        f.kv_replicas = 1;
        assert!(f.any_active() && !f.injecting());
        let mut f = FaultConfig::none();
        f.csd_loss = Some((1, 0.5));
        assert!(f.any_active());
        let mut f = FaultConfig::none();
        f.rate = 0.1;
        assert!(f.any_active() && f.injecting());
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [RecoveryPolicy::RetryOnly, RecoveryPolicy::RePrefill, RecoveryPolicy::Replicated]
        {
            assert_eq!(RecoveryPolicy::parse(p.label()).unwrap(), p);
        }
        assert!(RecoveryPolicy::parse("bogus").is_err());
    }

    #[test]
    fn per_device_streams_are_deterministic_and_distinct() {
        let cfg = FaultConfig { seed: 7, rate: 0.5, ..FaultConfig::none() };
        let mut a0 = FaultState::new(&cfg, 0, DOMAIN_NVME);
        let mut a1 = FaultState::new(&cfg, 0, DOMAIN_NVME);
        let mut b = FaultState::new(&cfg, 1, DOMAIN_NVME);
        let mut c = FaultState::new(&cfg, 0, DOMAIN_FLASH);
        let sa: Vec<bool> = (0..64).map(|_| a0.trips()).collect();
        let sa2: Vec<bool> = (0..64).map(|_| a1.trips()).collect();
        let sb: Vec<bool> = (0..64).map(|_| b.trips()).collect();
        let sc: Vec<bool> = (0..64).map(|_| c.trips()).collect();
        assert_eq!(sa, sa2, "same (seed, dev, domain) must replay");
        assert_ne!(sa, sb, "devices must not share a stream");
        assert_ne!(sa, sc, "domains must not share a stream");
    }

    #[test]
    fn retry_delay_grows_then_caps() {
        assert!(retry_delay(1) < retry_delay(2));
        assert!(retry_delay(2) < retry_delay(5));
        // exponent cap: attempts past 7 cost the same
        assert_eq!(retry_delay(7), retry_delay(8));
        assert_eq!(retry_delay(7), retry_delay(20));
    }
}
