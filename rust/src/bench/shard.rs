//! `bench shard` — the multi-CSD scaling evidence run: sweep the shard
//! count (and partition policy) on the functional engine and report the
//! per-step decode-attention time against the all-reduce (fair-share
//! PCIe + GPU merge) overhead.
//!
//! Runs on the native backend with no artifacts present (the runtime
//! synthesizes the opt-micro model), a fixed closed-loop workload, and
//! the flash-only tier — so every row decodes identical tokens and the
//! only difference between rows is how the heads/context stripe across
//! engine instances.  Expected shape (paper Fig. 17a): decode attention
//! shrinks near-linearly in the shard count — each device serves 1/N of
//! the flash traffic from its own channels — while the merge column
//! grows with N until the PCIe all-reduce dominates.

use crate::coordinator::{run_closed_loop, EngineConfig, InferenceEngine, SchedConfig};
use crate::runtime::Runtime;
use crate::shard::ShardPolicy;
use crate::util::table::{eng, Table};
use crate::workload::{LengthProfile, WorkloadGen};

const PROMPT: usize = 24;
const GEN: usize = 10;
const REQUESTS: usize = 4;
const SEATS: usize = 4;

pub struct ShardRun {
    /// mean per-step attention span (slowest shard), seconds
    pub attn_s_per_step: f64,
    /// mean per-step all-reduce span (transfers + merge), seconds
    pub merge_s_per_step: f64,
    /// mean per-step decode time (write + attention + all-reduce)
    pub decode_s_per_step: f64,
    /// mean per-barrier clock skew across shards, seconds
    pub skew_s: f64,
    /// aggregate die busy seconds across the array (utilisation)
    pub die_busy_s: f64,
    /// worst per-die backlog observed on any shard
    pub die_peak_q: usize,
}

/// One full serving run under a shard topology; deterministic per config.
pub fn run_config(n_csds: usize, policy: ShardPolicy) -> anyhow::Result<ShardRun> {
    let rt = Runtime::open("artifacts")?;
    let meta = rt.manifest.model.clone();
    let cfg = EngineConfig::micro_for(&meta, n_csds, false).sharded(policy);
    let mut engine = InferenceEngine::new(rt, cfg)?;
    let mut wg =
        WorkloadGen::new(4242, meta.vocab, meta.max_seq, LengthProfile::Fixed, PROMPT, GEN);
    let reqs = wg.batch(REQUESTS);
    run_closed_loop(
        &mut engine,
        reqs,
        SchedConfig { max_batch: SEATS, prefill_chunk: 2, slots: 8, ..Default::default() },
    )?;
    let steps = engine.metrics.decode_steps.max(1) as f64;
    let st = &engine.shards.stats;
    let fu = engine.flash_util();
    Ok(ShardRun {
        attn_s_per_step: st.attn_span_s / steps,
        merge_s_per_step: st.merge_span_s / steps,
        decode_s_per_step: engine.metrics.decode_sim_s / steps,
        skew_s: engine.shards.clock.mean_skew_s(),
        die_busy_s: fu.die_busy_s,
        die_peak_q: fu.die_peak_depth,
    })
}

fn err_row(t: &mut Table, policy: &str, n: usize, e: &anyhow::Error) {
    t.row(vec![
        policy.into(),
        n.to_string(),
        "ERR".into(),
        format!("{e:#}"),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
}

pub fn shard() -> Table {
    shard_with_threads(super::threads())
}

/// `bench shard` at an explicit worker-thread count: the single-CSD
/// baseline plus the six sweep topologies are independent fixed-seed
/// runs fanned out on `sim::par::par_map` (baseline at index 0 — its
/// attention time feeds every speedup column) and reassembled in index
/// order, so the table is byte-identical for any thread count.
pub fn shard_with_threads(threads: usize) -> Table {
    let mut t = Table::new(
        "Head sharding — decode attention vs CSD count (opt-micro, sim)",
        &[
            "policy",
            "csds",
            "attn_ms_per_step",
            "attn_speedup",
            "merge_us_per_step",
            "decode_ms_per_step",
            "skew_us",
            "die_busy_ms",
            "peak_die_q",
        ],
    );
    let row = |r: &ShardRun, policy: ShardPolicy, n: usize, base: &ShardRun| {
        vec![
            policy.label().into(),
            n.to_string(),
            eng(r.attn_s_per_step * 1e3),
            eng(base.attn_s_per_step / r.attn_s_per_step.max(1e-30)),
            eng(r.merge_s_per_step * 1e6),
            eng(r.decode_s_per_step * 1e3),
            eng(r.skew_s * 1e6),
            eng(r.die_busy_s * 1e3),
            r.die_peak_q.to_string(),
        ]
    };
    let mut sweep: Vec<(ShardPolicy, usize)> = vec![(ShardPolicy::HeadStripe, 1)];
    for n in [2usize, 4, 8] {
        sweep.push((ShardPolicy::HeadStripe, n));
    }
    sweep.push((ShardPolicy::HeadBlock, 4));
    for n in [2usize, 4] {
        sweep.push((ShardPolicy::Context, n));
    }
    let configs = sweep.clone();
    let mut runs =
        crate::sim::par::par_map(threads, configs, |_, (policy, n)| run_config(n, policy))
            .into_iter();
    let base = match runs.next().expect("baseline slot") {
        Ok(r) => r,
        Err(e) => {
            err_row(&mut t, "stripe", 1, &e);
            return t;
        }
    };
    t.row(row(&base, ShardPolicy::HeadStripe, 1, &base));
    for (policy, n) in sweep.into_iter().skip(1) {
        match runs.next().expect("sweep slot") {
            Ok(r) => t.row(row(&r, policy, n, &base)),
            Err(e) => err_row(&mut t, policy.label(), n, &e),
        }
    }
    t
}
