//! `bench prefix` — the cross-request prefix-caching evidence run:
//! sweep shared-stem ratio x stem hit rate over a multi-turn workload
//! ([`crate::workload::PrefixWorkloadGen`]) and serve the same open-loop
//! Poisson trace twice, cold (prefix cache off) and warm (on).
//!
//! The headline columns are the prompt tokens actually shipped at
//! prefill (`prefill_tok`) and TTFT p50.  Warm, every admission whose
//! stem is already sealed in the flash tier attaches the donor's token
//! groups by reference and ships KV only for the unique suffix, so
//! `prefill_tok` must fall monotonically as the shared fraction of the
//! prompt grows (pinned by `tests/prefix.rs`).  `mapped_pages` counts
//! physical flash pages holding KV across the array — aliasing shows up
//! as warm < cold at equal logical footprint.  Functional prefill always
//! runs in full, so warm outputs stay bit-identical to cold ones; the
//! cache is a data-movement and flash-capacity optimisation.

use crate::coordinator::{run_open_loop, InferenceEngine, ServeOpts};
use crate::runtime::Runtime;
use crate::util::table::{eng, Table};
use crate::workload::{ArrivalGen, PrefixWorkloadGen};

const PROMPT: usize = 24;
const GEN: usize = 8;
const REQUESTS: usize = 12;
const SEATS: usize = 4;
const SLOTS: usize = 16;
const RATE: f64 = 50.0;
const STEMS: usize = 2;

/// One serving run's prefix-cache-relevant numbers.
pub struct PrefixRun {
    pub ttft_p50_s: f64,
    pub latency_p50_s: f64,
    pub sim_end_s: f64,
    /// prompt tokens shipped over PCIe at prefill (suffix-only when warm)
    pub prefill_tokens: u64,
    /// prompt tokens covered by attached cached prefixes
    pub prefix_hit_tokens: u64,
    /// sealed prefixes registered in the FTL index, summed over CSDs
    pub registrations: u64,
    /// cache hits that attached shared groups, summed over CSDs
    pub attaches: u64,
    /// tokens attached by reference, summed over CSDs
    pub tokens_attached: u64,
    /// physical flash pages mapped across the array (aliasing evidence)
    pub mapped_pages: usize,
}

/// Serve one deterministic multi-turn trace.  Same seeds per config, so
/// the cold and warm rows face the identical workload.
pub fn run_config(share_ratio: f64, hit_rate: f64, prefix_on: bool) -> anyhow::Result<PrefixRun> {
    let rt = Runtime::open("artifacts")?;
    let meta = rt.manifest.model.clone();
    let opts = ServeOpts {
        batch: SEATS,
        slots: SLOTS,
        prefix_cache: prefix_on,
        share_ratio,
        ..ServeOpts::default()
    };
    let mut engine = InferenceEngine::new(rt, opts.engine_config(&meta))?;
    let src = PrefixWorkloadGen::new(
        9100, meta.vocab, PROMPT, GEN, share_ratio, meta.n, hit_rate, STEMS,
    );
    let arrivals = ArrivalGen::new(src, 9101, RATE).take(REQUESTS);
    let report = run_open_loop(&mut engine, arrivals, opts.sched_config())?;
    let [t50, _, _] = report.ttft_percentiles().unwrap_or([0.0; 3]);
    let [l50, _, _] = report.latency_percentiles().unwrap_or([0.0; 3]);
    let mut registrations = 0u64;
    let mut attaches = 0u64;
    let mut tokens_attached = 0u64;
    let mut mapped_pages = 0usize;
    for q in engine.csds() {
        registrations += q.csd.ftl.counters.prefix_registrations;
        attaches += q.csd.ftl.counters.prefix_attaches;
        tokens_attached += q.csd.ftl.counters.prefix_tokens_attached;
        mapped_pages += q.csd.ftl.mapped_pages_total();
    }
    Ok(PrefixRun {
        ttft_p50_s: t50,
        latency_p50_s: l50,
        sim_end_s: report.sim_end,
        prefill_tokens: engine.metrics.prefill_tokens,
        prefix_hit_tokens: engine.metrics.prefix_hit_tokens,
        registrations,
        attaches,
        tokens_attached,
        mapped_pages,
    })
}

/// `bench prefix --trace`: run the designated sweep point (share 0.5,
/// hit rate 1.0, cache on) with the trace plane installed and return the
/// drained sink.
pub fn traced(level: crate::obs::TraceLevel) -> anyhow::Result<crate::obs::TraceSink> {
    crate::obs::install(level);
    let run = run_config(0.5, 1.0, true);
    let sink = crate::obs::uninstall();
    run?;
    sink.ok_or_else(|| anyhow::anyhow!("trace sink was not installed"))
}

/// The cold/warm pair for one config (test hook).
pub fn run_pair(share_ratio: f64, hit_rate: f64) -> anyhow::Result<(PrefixRun, PrefixRun)> {
    Ok((
        run_config(share_ratio, hit_rate, false)?,
        run_config(share_ratio, hit_rate, true)?,
    ))
}

fn err_row(t: &mut Table, share: f64, hit: f64, e: &anyhow::Error) {
    t.row(vec![
        format!("{share}"),
        format!("{hit}"),
        "ERR".into(),
        format!("{e:#}"),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
}

pub fn prefix() -> Table {
    prefix_with_threads(super::threads())
}

/// `bench prefix` at an explicit worker-thread count: the six
/// (share x hit) configs each produce an independent fixed-seed
/// cold/warm pair, fanned out on `sim::par::par_map` and reassembled in
/// index order, so the table is byte-identical for any thread count.
pub fn prefix_with_threads(threads: usize) -> Table {
    let mut t = Table::new(
        "Cross-request prefix caching — cold vs warm flash KV reuse (opt-micro, sim)",
        &[
            "share_ratio",
            "hit_rate",
            "mode",
            "prefill_tok",
            "hit_tok",
            "ttft_p50_s",
            "ttft_save",
            "attaches",
            "attached_tok",
            "mapped_pages",
        ],
    );
    let mut configs: Vec<(f64, f64)> = vec![];
    for share in [0.25f64, 0.5, 1.0] {
        for hit in [0.5f64, 1.0] {
            configs.push((share, hit));
        }
    }
    let runs = crate::sim::par::par_map(threads, configs, |_, (share, hit)| {
        (share, hit, run_pair(share, hit))
    });
    for (share, hit, pair) in runs {
        let (cold, warm) = match pair {
            Ok(p) => p,
            Err(e) => {
                err_row(&mut t, share, hit, &e);
                continue;
            }
        };
        let save = 1.0 - warm.ttft_p50_s / cold.ttft_p50_s.max(1e-30);
        t.row(vec![
            format!("{share}"),
            format!("{hit}"),
            "cold".into(),
            cold.prefill_tokens.to_string(),
            cold.prefix_hit_tokens.to_string(),
            eng(cold.ttft_p50_s),
            "0".into(),
            cold.attaches.to_string(),
            cold.tokens_attached.to_string(),
            cold.mapped_pages.to_string(),
        ]);
        t.row(vec![
            format!("{share}"),
            format!("{hit}"),
            "warm".into(),
            warm.prefill_tokens.to_string(),
            warm.prefix_hit_tokens.to_string(),
            eng(warm.ttft_p50_s),
            eng(save),
            warm.attaches.to_string(),
            warm.tokens_attached.to_string(),
            warm.mapped_pages.to_string(),
        ]);
    }
    t
}
