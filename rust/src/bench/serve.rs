//! `bench serve` — the open-loop arrival-rate sweep: continuous
//! batching (admit-on-arrival, per-step membership) against the offline
//! drain (wait for the whole cohort, then batch it) at the same offered
//! load.  Closes the ROADMAP "continuous vs offline throughput across
//! arrival rates" dashboard item.
//!
//! Both modes serve the identical Poisson arrival trace on the simulated
//! device clock, so every row is deterministic.  Expected shape: at low
//! rates the offline drain wastes most of its makespan waiting for the
//! cohort to assemble (continuous wins on latency *and* throughput); as
//! the rate grows the two converge, with continuous keeping the TTFT
//! advantage.

use crate::coordinator::{
    run_closed_loop, run_open_loop, EngineConfig, InferenceEngine, SchedConfig,
};
use crate::runtime::Runtime;
use crate::util::table::{eng, Table};
use crate::workload::{ArrivalGen, LengthProfile, WorkloadGen};

const PROMPT: usize = 16;
const GEN: usize = 8;
const REQUESTS: usize = 8;
const SEATS: usize = 4;

struct ServeRun {
    tput_tok_s: f64,
    p50_latency_s: f64,
    p95_latency_s: f64,
    p50_ttft_s: f64,
    p95_ttft_s: f64,
    mean_occupancy: f64,
    die_busy_s: f64,
    die_peak_q: usize,
}

fn engine() -> anyhow::Result<InferenceEngine> {
    let rt = Runtime::open("artifacts")?;
    let meta = rt.manifest.model.clone();
    InferenceEngine::new(rt, EngineConfig::micro_for(&meta, 2, false))
}

fn arrivals(engine: &InferenceEngine, rate: f64) -> Vec<crate::workload::Arrival> {
    let m = &engine.rt.manifest.model;
    let wg = WorkloadGen::new(777, m.vocab, m.max_seq, LengthProfile::Fixed, PROMPT, GEN);
    ArrivalGen::new(wg, 778, rate).take(REQUESTS)
}

fn sched() -> SchedConfig {
    SchedConfig::serving(SEATS, 2, 16)
}

/// Continuous: requests admitted the step they arrive.
fn run_continuous(rate: f64) -> anyhow::Result<ServeRun> {
    let mut engine = engine()?;
    let arr = arrivals(&engine, rate);
    let report = run_open_loop(&mut engine, arr, sched())?;
    let [p50, p95, _] = report.latency_percentiles().unwrap_or([0.0; 3]);
    let [t50, t95, _] = report.ttft_percentiles().unwrap_or([0.0; 3]);
    // occupancy and flash utilisation read through the unified registry
    // so the bench rows embed the same snapshot `--metrics-json` dumps
    let reg = engine.metrics_registry(&report.overlap);
    Ok(ServeRun {
        tput_tok_s: report.total_generated() as f64 / report.sim_end.max(1e-12),
        p50_latency_s: p50,
        p95_latency_s: p95,
        p50_ttft_s: t50,
        p95_ttft_s: t95,
        mean_occupancy: reg.value("engine.step_occupancy").unwrap_or(0.0),
        die_busy_s: reg.value("flash.die_busy_s").unwrap_or(0.0),
        die_peak_q: reg.value("flash.die_peak_depth").unwrap_or(0.0) as usize,
    })
}

/// Offline drain: the batch only forms once the whole cohort has
/// arrived (the paper's throughput-oriented policy under online load).
fn run_offline(rate: f64) -> anyhow::Result<ServeRun> {
    let mut engine = engine()?;
    let arr = arrivals(&engine, rate);
    let last_at = arr.iter().map(|a| a.at).fold(0.0f64, f64::max);
    // each request's wait for the cohort to assemble, keyed by id (the
    // closed loop stamps everyone's arrival at the drain start)
    let waited: std::collections::HashMap<u64, f64> =
        arr.iter().map(|a| (a.req.id, last_at - a.at)).collect();
    engine.sim_now = last_at;
    let reqs = arr.into_iter().map(|a| a.req).collect();
    let report = run_closed_loop(&mut engine, reqs, sched())?;
    // latency measured from each request's TRUE arrival, not the drain
    // start
    let mut lats: Vec<f64> = report
        .records
        .iter()
        .filter(|r| !r.rejected)
        .map(|r| {
            (r.finished_at - r.arrived_at).max(0.0) + waited.get(&r.id).copied().unwrap_or(0.0)
        })
        .collect();
    let mut ttfts: Vec<f64> = report
        .records
        .iter()
        .filter(|r| !r.rejected)
        .map(|r| {
            (r.first_token_at - r.arrived_at).max(0.0) + waited.get(&r.id).copied().unwrap_or(0.0)
        })
        .collect();
    use crate::util::stats::percentile;
    let reg = engine.metrics_registry(&report.overlap);
    Ok(ServeRun {
        tput_tok_s: report.total_generated() as f64 / report.sim_end.max(1e-12),
        p50_latency_s: percentile(&mut lats, 50.0),
        p95_latency_s: percentile(&mut lats, 95.0),
        p50_ttft_s: percentile(&mut ttfts, 50.0),
        p95_ttft_s: percentile(&mut ttfts, 95.0),
        mean_occupancy: reg.value("engine.step_occupancy").unwrap_or(0.0),
        die_busy_s: reg.value("flash.die_busy_s").unwrap_or(0.0),
        die_peak_q: reg.value("flash.die_peak_depth").unwrap_or(0.0) as usize,
    })
}

fn err_row(t: &mut Table, rate: f64, mode: &str, e: &anyhow::Error) {
    t.row(vec![
        format!("{rate}"),
        mode.into(),
        "ERR".into(),
        format!("{e:#}"),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
}

pub fn serve() -> Table {
    serve_with_threads(super::threads())
}

/// `bench serve` at an explicit worker-thread count: the six sweep
/// points (3 rates x continuous/offline) are independent fixed-seed
/// simulations fanned out on `sim::par::par_map` and reassembled in
/// index order, so the table is byte-identical for any thread count.
pub fn serve_with_threads(threads: usize) -> Table {
    let mut t = Table::new(
        "Serving — continuous batching vs offline drain across arrival rates (sim)",
        &[
            "rate_req_s",
            "mode",
            "tput_tok_s",
            "p50_latency_s",
            "p95_latency_s",
            "p50_ttft_s",
            "p95_ttft_s",
            "mean_occupancy",
            "die_busy_ms",
            "peak_die_q",
        ],
    );
    let row = |rate: f64, mode: &str, r: &ServeRun| {
        vec![
            format!("{rate}"),
            mode.into(),
            eng(r.tput_tok_s),
            eng(r.p50_latency_s),
            eng(r.p95_latency_s),
            eng(r.p50_ttft_s),
            eng(r.p95_ttft_s),
            eng(r.mean_occupancy),
            eng(r.die_busy_s * 1e3),
            r.die_peak_q.to_string(),
        ]
    };
    let points: Vec<(f64, bool)> = [25.0f64, 100.0, 400.0]
        .iter()
        .flat_map(|&rate| [(rate, true), (rate, false)])
        .collect();
    let runs = crate::sim::par::par_map(threads, points, |_, (rate, continuous)| {
        let res = if continuous { run_continuous(rate) } else { run_offline(rate) };
        (rate, continuous, res)
    });
    for (rate, continuous, res) in runs {
        let mode = if continuous { "continuous" } else { "offline" };
        match res {
            Ok(r) => t.row(row(rate, mode, &r)),
            Err(e) => err_row(&mut t, rate, mode, &e),
        }
    }
    t
}
