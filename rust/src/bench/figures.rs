//! Throughput / latency / roofline / scalability figures (timing plane)
//! and the design ablations.  Expected shapes are recorded next to each
//! figure in EXPERIMENTS.md.

use crate::baselines::{self, flexgen_tier};
use crate::config::hw::{CsdSpec, GpuSpec};
use crate::config::model::{ModelShape, SparsityParams};
use crate::config::system::{OffloadPolicy, SystemConfig};
use crate::csd::resources;
use crate::ftl::{FtlConfig, KvFtl, KvKind, StreamKey};
use crate::gpu;
use crate::systems::{self, insti};
use crate::util::rng::Rng;
use crate::util::table::{eng, Table};

fn base(p: OffloadPolicy) -> SystemConfig {
    SystemConfig::paper_base(p)
}

fn tput(cfg: &SystemConfig, b: usize) -> String {
    match systems::run(cfg, b) {
        Ok(r) => eng(r.throughput),
        Err(_) => "OOM".into(),
    }
}

/// Fig. 4: DeepSpeed / FlexGen (tiered) throughput vs batch (motivation).
pub fn fig4() -> Table {
    let mut t = Table::new(
        "Fig. 4 — DeepSpeed/FlexGen throughput vs batch (tok/s, OPT-13B 1024/1024)",
        &["bs", "DeepSpeed", "FlexGen(tiered)"],
    );
    let ds = base(OffloadPolicy::HostDram);
    let fg = base(OffloadPolicy::SsdViaHost).tiered();
    for b in [4usize, 8, 16, 32, 64, 128] {
        t.row(vec![b.to_string(), tput(&ds, b), tput(&fg, b)]);
    }
    t
}

/// Fig. 5: FlexGen decode latency breakdown vs batch.
pub fn fig5() -> Table {
    let mut t = Table::new(
        "Fig. 5 — FlexGen decode latency breakdown (% of step)",
        &["bs", "tier", "Weight%", "KV%", "Compute%"],
    );
    let fg = base(OffloadPolicy::SsdViaHost).tiered();
    for b in [4usize, 8, 16, 32, 64] {
        match baselines::flexgen(&fg, b) {
            Ok(r) => {
                let bd = r.decode_breakdown;
                let tot = bd.total().max(1e-30);
                let tier = format!("{:?}", flexgen_tier(&fg, b, fg.kv_bytes_total(b)));
                t.row(vec![
                    b.to_string(),
                    tier,
                    eng(100.0 * bd.weight / tot),
                    eng(100.0 * bd.kv / tot),
                    eng(100.0 * bd.compute / tot),
                ]);
            }
            Err(_) => t.row(vec![b.to_string(), "OOM".into(), "-".into(), "-".into(), "-".into()]),
        }
    }
    t
}

/// Fig. 6: roofline placement — per-operator intensity and time on
/// A6000 vs Zynq7045 CSD (prefill and decode).
pub fn fig6() -> Table {
    let mut t = Table::new(
        "Fig. 6 — operator roofline: A6000 vs InstCSD (OPT-13B, bs=64, s=1536)",
        &["phase", "op", "FLOP/B", "gpu_ms", "csd_ms", "placement"],
    );
    let m = ModelShape::opt_13b();
    let g = GpuSpec::a6000();
    let c = CsdSpec::zynq7045();
    let rows = gpu::prefill_ops(&m, 64, 1024)
        .into_iter()
        .map(|o| ("prefill", o))
        .chain(gpu::decode_ops(&m, 64, 1536).into_iter().map(|o| ("decode", o)));
    for (phase, op) in rows {
        let gt = op.gpu_time(&g) * 1e3;
        let ct = op.csd_time(&c) * 1e3;
        let attn = op.name == "Logit" || op.name == "Attend";
        let place = if phase == "decode" && attn { "CSD" } else { "GPU" };
        t.row(vec![
            phase.into(),
            op.name.into(),
            eng(op.intensity()),
            eng(gt),
            eng(ct),
            place.into(),
        ]);
    }
    t
}

fn sweep(table: &mut Table, cfgs: &[(&str, SystemConfig)], batches: &[usize]) {
    for &b in batches {
        let mut row = vec![b.to_string()];
        for (_, cfg) in cfgs {
            row.push(tput(cfg, b));
        }
        table.row(row);
    }
}

/// Fig. 12: throughput of the five systems, 1 SSD/CSD.
pub fn fig12() -> Table {
    let mut t = Table::new(
        "Fig. 12 — throughput, 1 SSD/CSD (tok/s)",
        &["bs", "DeepSpeed", "FlexGen", "FlexGen-SparQ", "InstI-Dense", "InstI-SparF"],
    );
    let cfgs = [
        ("ds", base(OffloadPolicy::HostDram)),
        ("fg", base(OffloadPolicy::SsdViaHost)),
        ("fgs", base(OffloadPolicy::SsdViaHost).with_default_sparsity()),
        ("iid", base(OffloadPolicy::InStorage)),
        ("iis", base(OffloadPolicy::InStorage).with_default_sparsity()),
    ];
    sweep(&mut t, &cfgs, &[4, 8, 16, 32, 64, 128, 256]);
    t
}

/// Fig. 13: throughput with 2 SSDs/CSDs.
pub fn fig13() -> Table {
    let mut t = Table::new(
        "Fig. 13 — throughput, 2 SSDs/CSDs (tok/s)",
        &["bs", "DeepSpeed", "FlexGen", "FlexGen-SparQ", "InstI-Dense", "InstI-SparF"],
    );
    let cfgs = [
        ("ds", base(OffloadPolicy::HostDram).with_devices(2)),
        ("fg", base(OffloadPolicy::SsdViaHost).with_devices(2)),
        ("fgs", base(OffloadPolicy::SsdViaHost).with_default_sparsity().with_devices(2)),
        ("iid", base(OffloadPolicy::InStorage).with_devices(2)),
        ("iis", base(OffloadPolicy::InStorage).with_default_sparsity().with_devices(2)),
    ];
    sweep(&mut t, &cfgs, &[4, 8, 16, 32, 64, 128, 256]);
    t
}

fn breakdown_rows(t: &mut Table, label: &str, cfg: &SystemConfig, batches: &[usize]) {
    for &b in batches {
        match systems::run(cfg, b) {
            Ok(r) => {
                let bd = r.decode_breakdown;
                let tot = bd.total().max(1e-30);
                t.row(vec![
                    label.into(),
                    b.to_string(),
                    eng(100.0 * bd.kv / tot),
                    eng(100.0 * bd.weight / tot),
                    eng(100.0 * bd.compute / tot),
                    eng(100.0 * bd.comm / tot),
                ]);
            }
            Err(_) => t.row(vec![
                label.into(), b.to_string(), "OOM".into(), "-".into(), "-".into(), "-".into(),
            ]),
        }
    }
}

/// Fig. 14: decode latency breakdown, dense systems.
pub fn fig14() -> Table {
    let mut t = Table::new(
        "Fig. 14 — dense decode latency breakdown (% of step)",
        &["system", "bs", "KV%", "Weight%", "Compute%", "Comm%"],
    );
    let batches = [4usize, 64, 256];
    breakdown_rows(&mut t, "FlexGen", &base(OffloadPolicy::SsdViaHost), &batches);
    breakdown_rows(&mut t, "InstI", &base(OffloadPolicy::InStorage), &batches);
    breakdown_rows(&mut t, "InstI-2", &base(OffloadPolicy::InStorage).with_devices(2), &batches);
    t
}

/// Fig. 15: decode latency breakdown, sparse (1/8) systems.
pub fn fig15() -> Table {
    let mut t = Table::new(
        "Fig. 15 — sparse (1/8) decode latency breakdown (% of step)",
        &["system", "bs", "KV%", "Weight%", "Compute%", "Comm%"],
    );
    let batches = [4usize, 64, 256];
    breakdown_rows(
        &mut t,
        "FlexGen-SparQ",
        &base(OffloadPolicy::SsdViaHost).with_default_sparsity(),
        &batches,
    );
    breakdown_rows(
        &mut t,
        "InstI-SparF",
        &base(OffloadPolicy::InStorage).with_default_sparsity(),
        &batches,
    );
    breakdown_rows(
        &mut t,
        "InstI-SparF-2",
        &base(OffloadPolicy::InStorage).with_default_sparsity().with_devices(2),
        &batches,
    );
    t
}

/// Fig. 16: SparF attention-engine unit breakdown (dense vs 1/8).
pub fn fig16() -> Table {
    fig16_with_threads(super::threads())
}

/// [`fig16`] at an explicit worker-thread count: both analytic points
/// fan out on `sim::par::par_map` and land in index order, so the
/// table is byte-identical for any thread count (the runs are cheap —
/// this exists so the whole trajectory set shares one execution model).
pub fn fig16_with_threads(threads: usize) -> Table {
    let mut t = Table::new(
        "Fig. 16 — SparF engine unit breakdown (% of engine time, bs=64 s=1536)",
        &["mode", "argtopk", "flash", "filter", "Logit-0", "Logit", "Attend"],
    );
    let points = vec![
        ("dense", base(OffloadPolicy::InStorage)),
        ("sparf-1/8", base(OffloadPolicy::InStorage).with_default_sparsity()),
    ];
    let rows = crate::sim::par::par_map(threads, points, |_, (label, cfg)| {
        let st = insti::csd_layer_step(&cfg, 64, 1536, cfg.model.n_heads);
        let u = &st.units;
        let tot = u.total().max(1e-30);
        vec![
            label.into(),
            eng(100.0 * u.argtopk / tot),
            eng(100.0 * u.flash_read / tot),
            eng(100.0 * u.nfc_filter / tot),
            eng(100.0 * u.logit0 / tot),
            eng(100.0 * u.logit / tot),
            eng(100.0 * u.attend / tot),
        ]
    });
    for r in rows {
        t.row(r);
    }
    t
}

/// Fig. 17a: scalability with 1..20 CSDs at bs=256.
pub fn fig17a() -> Table {
    let mut t = Table::new(
        "Fig. 17a — throughput vs number of CSDs (bs=256, tok/s)",
        &["CSDs", "InstI-Dense", "InstI-SparF", "dense speedup", "sparf speedup"],
    );
    let d1 = systems::run(&base(OffloadPolicy::InStorage), 256).unwrap().throughput;
    let s1 = systems::run(&base(OffloadPolicy::InStorage).with_default_sparsity(), 256)
        .unwrap()
        .throughput;
    for n in [1usize, 2, 4, 8, 12, 16, 20] {
        let d = systems::run(&base(OffloadPolicy::InStorage).with_devices(n), 256)
            .unwrap()
            .throughput;
        let s = systems::run(
            &base(OffloadPolicy::InStorage).with_default_sparsity().with_devices(n),
            256,
        )
        .unwrap()
        .throughput;
        t.row(vec![n.to_string(), eng(d), eng(s), eng(d / d1), eng(s / s1)]);
    }
    t
}

/// Fig. 17b: sensitivity to compression ratio (1 and 2 CSDs, bs=256).
pub fn fig17b() -> Table {
    let mut t = Table::new(
        "Fig. 17b — throughput vs compression ratio (bs=256, tok/s)",
        &["ratio", "InstI x1", "InstI x2"],
    );
    let m = ModelShape::opt_13b();
    for c in [2usize, 4, 8, 16, 32] {
        let sp = SparsityParams::with_compression(&m, 2048, c);
        let one = systems::run(&base(OffloadPolicy::InStorage).with_sparsity(sp), 256)
            .unwrap()
            .throughput;
        let two = systems::run(
            &base(OffloadPolicy::InStorage).with_sparsity(sp).with_devices(2),
            256,
        )
        .unwrap()
        .throughput;
        t.row(vec![format!("1/{c}"), eng(one), eng(two)]);
    }
    t
}

/// Table I: Zynq7045 resource utilisation.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table I — InstCSD resource utilisation on Zynq7045",
        &["unit", "LUT(K)", "FF(K)", "BRAM", "DSP"],
    );
    for u in resources::UNITS {
        t.row(vec![
            u.name.into(),
            eng(u.lut_k),
            eng(u.ff_k),
            eng(u.bram_tiles),
            u.dsp.to_string(),
        ]);
    }
    let a = resources::AVAILABLE;
    t.row(vec![
        "Available".into(),
        eng(a.lut_k),
        eng(a.ff_k),
        eng(a.bram_tiles),
        a.dsp.to_string(),
    ]);
    let (lut, ff, bram, dsp) = resources::utilisation();
    t.row(vec![
        "Percent(%)".into(),
        eng(lut),
        eng(ff),
        eng(bram),
        eng(dsp),
    ]);
    t
}

// ---------------------------------------------------------------------------
// Ablations (DESIGN.md §6)
// ---------------------------------------------------------------------------

/// Group-aligned dual-step loading vs token-granular random reads: page
/// fetches per SparF step on the functional FTL.
pub fn ablate_group() -> Table {
    let mut t = Table::new(
        "Ablation — dual-step group loading vs token-granular reads (pages/step)",
        &["tokens", "group pages", "naive pages (1/token)", "saving"],
    );
    let mut rng = Rng::new(11);
    for s in [32usize, 64, 96] {
        let mut ftl =
            KvFtl::new(crate::config::hw::FlashSpec::tiny(), FtlConfig::micro_head()).unwrap();
        let key = StreamKey { slot: 0, layer: 0, head: 0 };
        for _ in 0..s {
            let kr: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
            let vr: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
            ftl.append_token(key, &kr, &vr, 0.0).unwrap();
        }
        // SparF top-k selection of k = s/8 clustered tokens
        let k = (s / 8).max(1);
        let toks: Vec<usize> = (0..k).map(|i| (i * 3) % s).collect();
        let groups: std::collections::BTreeSet<usize> = toks.iter().map(|t| t / 8).collect();
        let before = ftl.array.counters.page_reads;
        let gl: Vec<usize> = groups.iter().cloned().collect();
        ftl.fetch_token_groups(key, KvKind::K, &gl, 0.0).unwrap();
        let group_pages = ftl.array.counters.page_reads - before;
        // naive: one page-granule read per token (no grouping: each token
        // row straddles its own page-sized access)
        let naive = k as u64;
        t.row(vec![
            s.to_string(),
            group_pages.to_string(),
            naive.to_string(),
            eng(naive as f64 / group_pages.max(1) as f64),
        ]);
    }
    t
}

/// Storing K twice (dual-indexed) vs transposing token pages on the fly:
/// step-2 bytes + capacity cost.
pub fn ablate_dualk() -> Table {
    let mut t = Table::new(
        "Ablation — dual-indexed K copy vs on-the-fly transpose (per head step)",
        &["s", "dual KB read", "transpose KB read", "capacity x"],
    );
    let m = ModelShape::opt_13b();
    for s in [1024usize, 2048] {
        let sp = SparsityParams::paper_default(&m, s);
        // dual: embedding-indexed pages only fetch selected channel groups
        let eg = m.d_head as f64 / sp.m as f64;
        let f1 = insti::expected_groups(eg, sp.r as f64) / eg;
        let dual = f1 * s as f64 * m.d_head as f64 * 2.0;
        // without the K^T copy, step 2 must read ALL token pages of K
        let transpose = s as f64 * m.d_head as f64 * 2.0;
        t.row(vec![
            s.to_string(),
            eng(dual / 1024.0),
            eng(transpose / 1024.0),
            "1.5".into(),
        ]);
    }
    t
}

/// Layer-wise pipelined prefill shipping vs bulk ship after compute.
pub fn ablate_pipeline() -> Table {
    let mut t = Table::new(
        "Ablation — layer-wise pipelined prefill vs bulk ship (prefill s)",
        &["bs", "pipelined", "bulk", "speedup"],
    );
    for b in [16usize, 64, 256] {
        let pipe = systems::run(&base(OffloadPolicy::InStorage), b).map(|r| r.prefill_s);
        let mut cfg = base(OffloadPolicy::InStorage);
        cfg.layerwise_pipeline = false;
        let bulk = systems::run(&cfg, b).map(|r| r.prefill_s);
        match (pipe, bulk) {
            (Ok(p), Ok(k)) => t.row(vec![b.to_string(), eng(p), eng(k), eng(k / p)]),
            _ => t.row(vec![b.to_string(), "OOM".into(), "OOM".into(), "-".into()]),
        }
    }
    t
}

/// P2P DMA vs host-mediated path for the decode-step vector exchange.
pub fn ablate_p2p() -> Table {
    let mut t = Table::new(
        "Ablation — P2P DMA vs host-mediated CSD path (tok/s, bs=64)",
        &["variant", "throughput", "prefill s"],
    );
    let p2p = systems::run(&base(OffloadPolicy::InStorage), 64).unwrap();
    let mut cfg = base(OffloadPolicy::InStorage);
    cfg.p2p_dma = false;
    let host = systems::run(&cfg, 64).unwrap();
    t.row(vec!["P2P".into(), eng(p2p.throughput), eng(p2p.prefill_s)]);
    t.row(vec!["via host FS".into(), eng(host.throughput), eng(host.prefill_s)]);
    t
}

/// Head-striped block placement vs sequential placement: channel balance
/// of one head's group reads on the functional FTL.
pub fn ablate_placement() -> Table {
    let mut t = Table::new(
        "Ablation — head-striped placement: channels touched by one head's groups",
        &["head", "groups", "channels used", "of channels"],
    );
    let mut rng = Rng::new(13);
    let mut ftl =
        KvFtl::new(crate::config::hw::FlashSpec::tiny(), FtlConfig::micro_head()).unwrap();
    for head in 0..2u16 {
        let key = StreamKey { slot: 0, layer: 0, head };
        for _ in 0..64 {
            let kr: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
            let vr: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
            ftl.append_token(key, &kr, &vr, 0.0).unwrap();
        }
        let mut chans = std::collections::BTreeSet::new();
        for g in 0..8usize {
            // 64 tokens / 8 per group — where did each group's page land?
            if let Some(c) = ftl.token_group_channel(key, KvKind::K, g) {
                chans.insert(c);
            }
        }
        let total = ftl.array.spec.channels;
        t.row(vec![
            head.to_string(),
            "8".into(),
            chans.len().to_string(),
            format!("{}/{}", chans.len(), total),
        ]);
    }
    t
}
