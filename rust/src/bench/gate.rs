//! `bench gate` — the CI perf regression gate: diff the key metrics of
//! a `bench all --json` document against the committed baseline
//! (`baselines/bench-baseline.json`, schema
//! `instinfer-bench-gate-baseline/v1`) with per-metric one-sided
//! tolerances, and fail loudly — printing the run's top decode
//! attribution buckets so the failure names its suspect — when any
//! metric regresses past tolerance.
//!
//! The baseline ships unseeded (`"seeded": false`): the gate then
//! reports the current values and passes with a notice, so a fresh
//! checkout stays green until someone runs
//! `instinfer bench all --json BENCH_all.json && instinfer bench gate --update`
//! on the reference machine and commits the result.  Re-baselining
//! after an intentional perf change is the same two commands.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

pub const SCHEMA: &str = "instinfer-bench-gate-baseline/v1";
pub const DEFAULT_BENCH: &str = "BENCH_all.json";
/// cargo runs from `rust/`; the baseline is committed at the repo root.
pub const DEFAULT_BASELINE: &str = "../baselines/bench-baseline.json";

/// One gated metric: a (target, row, column) address into the bench
/// document plus the regression direction and tolerance.
pub struct MetricSpec {
    /// baseline key (stable across runs; the row address spelled out)
    pub key: &'static str,
    /// bench target whose table holds the metric
    pub target: &'static str,
    /// (column, cell) pairs that select the row
    pub matchers: &'static [(&'static str, &'static str)],
    /// column holding the metric value
    pub column: &'static str,
    /// regression direction: `true` gates on falling below baseline
    pub higher_is_better: bool,
    /// one-sided relative tolerance before a drift counts as regression
    pub tol_rel: f64,
}

/// The gated metrics: the serving dashboard's headline numbers, one per
/// evidence run.
pub const METRICS: &[MetricSpec] = &[
    MetricSpec {
        key: "serve.continuous.rate100.p95_ttft_s",
        target: "serve",
        matchers: &[("rate_req_s", "100"), ("mode", "continuous")],
        column: "p95_ttft_s",
        higher_is_better: false,
        tol_rel: 0.05,
    },
    MetricSpec {
        key: "serve.continuous.rate100.tput_tok_s",
        target: "serve",
        matchers: &[("rate_req_s", "100"), ("mode", "continuous")],
        column: "tput_tok_s",
        higher_is_better: true,
        tol_rel: 0.05,
    },
    MetricSpec {
        key: "overlap.csds2.chunk4.rate400.decode_step_ms",
        target: "overlap",
        matchers: &[
            ("csds", "2"),
            ("prefill_chunk", "4"),
            ("rate_req_s", "400"),
            ("mode", "overlapped"),
        ],
        column: "decode_step_ms",
        higher_is_better: false,
        tol_rel: 0.05,
    },
    MetricSpec {
        key: "overlap.csds2.chunk4.rate400.step_speedup",
        target: "overlap",
        matchers: &[
            ("csds", "2"),
            ("prefill_chunk", "4"),
            ("rate_req_s", "400"),
            ("mode", "overlapped"),
        ],
        column: "step_speedup",
        higher_is_better: true,
        tol_rel: 0.05,
    },
    MetricSpec {
        key: "shard.stripe.csds4.attn_speedup",
        target: "shard",
        matchers: &[("policy", "stripe"), ("csds", "4")],
        column: "attn_speedup",
        higher_is_better: true,
        tol_rel: 0.05,
    },
    MetricSpec {
        key: "prefix.share0.5.hit1.warm.ttft_save",
        target: "prefix",
        matchers: &[("share_ratio", "0.5"), ("hit_rate", "1"), ("mode", "warm")],
        column: "ttft_save",
        higher_is_better: true,
        tol_rel: 0.05,
    },
    MetricSpec {
        key: "flashpath.dies4.tuned.dense_speedup",
        target: "flashpath",
        matchers: &[("dies", "4"), ("path", "die/interleave/pipe")],
        column: "dense_speedup",
        higher_is_better: true,
        tol_rel: 0.05,
    },
];

/// One gated metric's verdict.
pub struct GateResult {
    pub key: &'static str,
    /// `None` when the metric could not be read from the bench document
    pub current: Option<f64>,
    pub baseline: Option<f64>,
    /// populated iff this metric fails the gate
    pub failure: Option<String>,
}

/// The tables of a bench document: both the stitched trajectory shape
/// (`{"targets": [...]}`) and the plain per-target array are accepted.
fn tables(doc: &Json) -> Vec<(&str, &Json)> {
    let arr = doc
        .get("targets")
        .and_then(|t| t.as_arr())
        .or_else(|| doc.as_arr())
        .unwrap_or(&[]);
    arr.iter()
        .filter_map(|t| Some((t.get("target")?.as_str()?, t)))
        .collect()
}

fn header_index(table: &Json, column: &str) -> Option<usize> {
    table
        .get("header")?
        .as_arr()?
        .iter()
        .position(|h| h.as_str() == Some(column))
}

/// The rows of `target`'s table whose cells satisfy every matcher.
fn matching_rows<'a>(
    doc: &'a Json,
    target: &str,
    matchers: &[(&str, &str)],
) -> Result<Vec<&'a [Json]>> {
    let (_, table) = tables(doc)
        .into_iter()
        .find(|(name, _)| *name == target)
        .with_context(|| format!("bench document has no {target:?} table"))?;
    let rows = table.req("rows")?.as_arr().context("rows is not an array")?;
    let mut cols = Vec::new();
    for (c, want) in matchers {
        let idx = header_index(table, c)
            .with_context(|| format!("{target:?} table has no column {c:?}"))?;
        cols.push((idx, *want));
    }
    Ok(rows
        .iter()
        .filter_map(|r| r.as_arr())
        .filter(|r| {
            cols.iter()
                .all(|(i, want)| r.get(*i).and_then(|c| c.as_str()) == Some(*want))
        })
        .collect())
}

/// Read one metric out of a bench document; `ERR`/`-` cells and missing
/// rows fail loudly (a gate that silently skips is no gate).
fn metric_value(doc: &Json, spec: &MetricSpec) -> Result<f64> {
    let rows = matching_rows(doc, spec.target, spec.matchers)?;
    let row = match rows.as_slice() {
        [] => bail!("{}: no row matches {:?}", spec.key, spec.matchers),
        [r] => *r,
        more => bail!("{}: {} rows match {:?}", spec.key, more.len(), spec.matchers),
    };
    let (_, table) = tables(doc)
        .into_iter()
        .find(|(name, _)| *name == spec.target)
        .unwrap();
    let idx = header_index(table, spec.column)
        .with_context(|| format!("{}: no column {:?}", spec.key, spec.column))?;
    let cell = row
        .get(idx)
        .and_then(|c| c.as_str())
        .with_context(|| format!("{}: row too short for column {:?}", spec.key, spec.column))?;
    cell.parse::<f64>()
        .with_context(|| format!("{}: cell {cell:?} is not a number", spec.key))
}

/// Gate every metric in `METRICS` against the baseline document.  An
/// unseeded baseline (`"seeded": false` or an empty/missing metrics
/// map) yields no failures; a seeded baseline gates one-sided with each
/// spec's relative tolerance.
pub fn evaluate(bench: &Json, baseline: &Json) -> Vec<GateResult> {
    let seeded = baseline.get("seeded").and_then(|s| s.as_bool()).unwrap_or(false);
    let base_metrics = baseline.get("metrics").and_then(|m| m.as_obj());
    METRICS
        .iter()
        .map(|spec| {
            let current = metric_value(bench, spec);
            let base = base_metrics.and_then(|m| m.get(spec.key)).and_then(|v| v.as_f64());
            let failure = match (&current, base, seeded) {
                (Err(e), _, _) => Some(format!("unreadable: {e:#}")),
                (_, None, true) => Some("missing from seeded baseline".to_string()),
                (_, _, false) => None,
                (Ok(cur), Some(b), true) => {
                    let (bound, breached) = if spec.higher_is_better {
                        let bound = b * (1.0 - spec.tol_rel);
                        (bound, *cur < bound)
                    } else {
                        let bound = b * (1.0 + spec.tol_rel);
                        (bound, *cur > bound)
                    };
                    breached.then(|| {
                        format!(
                            "REGRESSION: current {cur:.6} vs baseline {b:.6} \
                             (bound {bound:.6}, {} is better)",
                            if spec.higher_is_better { "higher" } else { "lower" },
                        )
                    })
                }
            };
            GateResult { key: spec.key, current: current.ok(), baseline: base, failure }
        })
        .collect()
}

/// Render a seeded baseline document from the bench document's current
/// metric values (the `--update` path).  Unreadable metrics abort: a
/// baseline must cover every gated metric.
pub fn baseline_from(bench: &Json) -> Result<Json> {
    let mut metrics = std::collections::BTreeMap::new();
    for spec in METRICS {
        let v = metric_value(bench, spec)?;
        metrics.insert(spec.key.to_string(), Json::Num(v));
    }
    let mut doc = std::collections::BTreeMap::new();
    doc.insert("schema".to_string(), Json::Str(SCHEMA.to_string()));
    doc.insert("seeded".to_string(), Json::Bool(true));
    doc.insert("metrics".to_string(), Json::Obj(metrics));
    Ok(Json::Obj(doc))
}

/// The bench document's top decode attribution buckets (from the
/// `attr` target), for naming the suspect when the gate fails.
fn top_decode_attr(bench: &Json, n: usize) -> Vec<(String, String, String)> {
    let rows = match matching_rows(bench, "attr", &[("scope", "decode")]) {
        Ok(r) => r,
        Err(_) => return Vec::new(),
    };
    let mut parsed: Vec<(String, f64, String, String)> = rows
        .iter()
        .filter_map(|r| {
            let bucket = r.get(1)?.as_str()?.to_string();
            let s_cell = r.get(2)?.as_str()?.to_string();
            let frac = r.get(3)?.as_str()?.to_string();
            let s = s_cell.parse::<f64>().ok()?;
            Some((bucket, s, s_cell, frac))
        })
        .filter(|(_, s, _, _)| *s > 0.0)
        .collect();
    parsed.sort_by(|a, b| b.1.total_cmp(&a.1));
    parsed.truncate(n);
    parsed.into_iter().map(|(b, _, s, f)| (b, s, f)).collect()
}

fn load(path: &str) -> Result<Json> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))
}

/// `instinfer bench gate [--bench FILE] [--baseline FILE] [--update]`.
pub fn gate_cmd(args: &[String]) -> Result<()> {
    let mut bench_path = DEFAULT_BENCH.to_string();
    let mut baseline_path = DEFAULT_BASELINE.to_string();
    let mut update = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--bench" => {
                bench_path = args.get(i + 1).context("--bench needs a file path")?.clone();
                i += 2;
            }
            "--baseline" => {
                baseline_path =
                    args.get(i + 1).context("--baseline needs a file path")?.clone();
                i += 2;
            }
            "--update" => {
                update = true;
                i += 1;
            }
            other => bail!("unexpected bench gate argument {other:?}"),
        }
    }
    let bench = load(&bench_path)?;
    if update {
        let doc = baseline_from(&bench)?;
        std::fs::write(&baseline_path, format!("{doc}\n"))
            .with_context(|| format!("writing {baseline_path}"))?;
        println!("gate: seeded {baseline_path} from {bench_path} ({} metrics)", METRICS.len());
        return Ok(());
    }
    let baseline = load(&baseline_path).with_context(|| {
        format!("no baseline at {baseline_path}; run `bench gate --update` to seed one")
    })?;
    if let Some(s) = baseline.get("schema").and_then(|s| s.as_str()) {
        if s != SCHEMA {
            bail!("baseline {baseline_path} has schema {s:?}, expected {SCHEMA:?}");
        }
    }
    let seeded = baseline.get("seeded").and_then(|s| s.as_bool()).unwrap_or(false);
    let results = evaluate(&bench, &baseline);
    let mut failures = 0usize;
    for r in &results {
        let cur = r.current.map(|v| format!("{v:.6}")).unwrap_or_else(|| "?".into());
        let base = r.baseline.map(|v| format!("{v:.6}")).unwrap_or_else(|| "-".into());
        match &r.failure {
            Some(msg) => {
                failures += 1;
                println!("gate: FAIL {} current={cur} baseline={base}: {msg}", r.key);
            }
            None => println!("gate: ok   {} current={cur} baseline={base}", r.key),
        }
    }
    if !seeded {
        println!(
            "gate: baseline {baseline_path} is unseeded; reporting only.  Seed it with \
             `instinfer bench all --json {bench_path} && instinfer bench gate --update` \
             and commit the result."
        );
    }
    if failures > 0 {
        let top = top_decode_attr(&bench, 5);
        if !top.is_empty() {
            println!("gate: top decode attribution buckets (suspects):");
            for (bucket, s, frac) in &top {
                println!("gate:   {bucket:<16} {s}s  ({frac} of decode)");
            }
        }
        bail!("{failures}/{} gated metrics regressed past tolerance", results.len());
    }
    println!("gate: {} metrics within tolerance", results.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal synthetic trajectory document covering every gated
    /// metric plus an attr table (for the failure report).
    fn bench_doc() -> Json {
        Json::parse(
            r#"{
              "schema": "instinfer-bench-trajectory/v1",
              "targets": [
                {"target": "serve",
                 "header": ["rate_req_s","mode","tput_tok_s","p95_ttft_s"],
                 "rows": [["100","continuous","5000","0.020"],
                          ["100","offline","4000","0.050"]]},
                {"target": "overlap",
                 "header": ["csds","prefill_chunk","rate_req_s","mode","decode_step_ms","step_speedup"],
                 "rows": [["2","4","400","serialized","2.0","1.0"],
                          ["2","4","400","overlapped","1.0","2.0"]]},
                {"target": "shard",
                 "header": ["policy","csds","attn_speedup"],
                 "rows": [["stripe","4","3.5"]]},
                {"target": "prefix",
                 "header": ["share_ratio","hit_rate","mode","ttft_save"],
                 "rows": [["0.5","1","warm","0.400"]]},
                {"target": "flashpath",
                 "header": ["dies","path","dense_speedup"],
                 "rows": [["4","die/interleave/pipe","4.2"]]},
                {"target": "attr",
                 "header": ["scope","bucket","s","frac","pred_frac","rel_err"],
                 "rows": [["decode","flash_read","0.030","0.700","-","-"],
                          ["decode","csd_compute","0.010","0.230","-","-"]]}
              ]
            }"#,
        )
        .unwrap()
    }

    fn baseline_with(doctor: &[(&str, f64)]) -> Json {
        let bench = bench_doc();
        let mut doc = baseline_from(&bench).unwrap();
        if let Json::Obj(m) = &mut doc {
            if let Some(Json::Obj(metrics)) = m.get_mut("metrics") {
                for (k, v) in doctor {
                    metrics.insert(k.to_string(), Json::Num(*v));
                }
            }
        }
        doc
    }

    #[test]
    fn matching_baseline_passes() {
        let bench = bench_doc();
        let baseline = baseline_with(&[]);
        let results = evaluate(&bench, &baseline);
        assert_eq!(results.len(), METRICS.len());
        assert!(results.iter().all(|r| r.failure.is_none()));
    }

    #[test]
    fn doctored_baseline_fails_both_directions() {
        let bench = bench_doc();
        // doctor the baseline past tolerance: claim twice the current
        // throughput (higher-better) and half the current p95 TTFT
        // (lower-better) — both must read as regressions of the run
        let baseline = baseline_with(&[
            ("serve.continuous.rate100.tput_tok_s", 10000.0),
            ("serve.continuous.rate100.p95_ttft_s", 0.010),
        ]);
        let results = evaluate(&bench, &baseline);
        let failed: Vec<&str> =
            results.iter().filter(|r| r.failure.is_some()).map(|r| r.key).collect();
        assert_eq!(
            failed,
            vec![
                "serve.continuous.rate100.p95_ttft_s",
                "serve.continuous.rate100.tput_tok_s",
            ],
        );
    }

    #[test]
    fn within_tolerance_drift_passes() {
        let bench = bench_doc();
        // 3% better baseline: inside the 5% one-sided tolerance
        let baseline = baseline_with(&[("serve.continuous.rate100.tput_tok_s", 5150.0)]);
        let results = evaluate(&bench, &baseline);
        assert!(results.iter().all(|r| r.failure.is_none()));
    }

    #[test]
    fn unseeded_baseline_reports_without_failing() {
        let bench = bench_doc();
        let baseline = Json::parse(
            r#"{"schema":"instinfer-bench-gate-baseline/v1","seeded":false,"metrics":{}}"#,
        )
        .unwrap();
        let results = evaluate(&bench, &baseline);
        assert!(results.iter().all(|r| r.failure.is_none()));
        assert!(results.iter().all(|r| r.current.is_some()));
    }

    #[test]
    fn missing_metric_fails_loudly() {
        // drop the flashpath table entirely: the gate must flag the
        // metric as unreadable, not skip it
        let mut bench = bench_doc();
        if let Json::Obj(m) = &mut bench {
            if let Some(Json::Arr(targets)) = m.get_mut("targets") {
                targets.retain(|t| t.get("target").and_then(|n| n.as_str()) != Some("flashpath"));
            }
        }
        let baseline = baseline_with(&[]);
        // baseline_from over the doctored doc would fail; reuse the full
        // one so only the bench side is missing the table
        let results = evaluate(&bench, &baseline);
        let bad: Vec<&str> =
            results.iter().filter(|r| r.failure.is_some()).map(|r| r.key).collect();
        assert_eq!(bad, vec!["flashpath.dies4.tuned.dense_speedup"]);
    }

    #[test]
    fn attr_suspects_ranked() {
        let top = top_decode_attr(&bench_doc(), 5);
        assert_eq!(top[0].0, "flash_read");
        assert_eq!(top.len(), 2);
    }
}
