//! `bench tier` — the KV-tiering evidence run: sweep hot-tier capacity
//! x eviction policy on the functional engine and report the DRAM hit
//! rate against the mean per-step decode time (simulated device clock).
//!
//! Runs on the native backend with no artifacts present (the runtime
//! synthesizes the opt-micro model), one CSD, a fixed closed-loop
//! workload — so every row decodes identical tokens and the only
//! difference between rows is where the KV pages are served from.
//! Expected shape: `h2o` holds its hit rate as capacity shrinks
//! (heavy hitters stay resident) while `lru` thrashes under the dense
//! decode loop's cyclic scan; any hit rate > 0 strictly lowers the
//! decode time versus the flash-only baseline because hits skip the
//! flash die/channel FIFOs entirely.

use crate::config::hw::CsdSpec;
use crate::coordinator::{run_closed_loop, EngineConfig, InferenceEngine, SchedConfig};
use crate::kvtier::{TierConfig, TierPolicy};
use crate::runtime::native::micro_meta;
use crate::runtime::Runtime;
use crate::util::table::{eng, Table};
use crate::workload::{LengthProfile, WorkloadGen};

const PROMPT: usize = 24;
const GEN: usize = 12;
const REQUESTS: usize = 6;
const SEATS: usize = 4;

pub struct TierRun {
    pub hit_rate: f64,
    pub decode_s_per_step: f64,
    /// flash-array utilisation: hits skipping flash show up as lower
    /// die busy time at the same decode workload
    pub die_busy_s: f64,
    pub die_peak_q: usize,
}

/// One full serving run under a tier config; deterministic per config.
pub fn run_config(tier: TierConfig) -> anyhow::Result<TierRun> {
    let rt = Runtime::open("artifacts")?;
    let meta = rt.manifest.model.clone();
    let mut engine = InferenceEngine::new(rt, EngineConfig::micro(1).tiered(tier))?;
    let mut wg =
        WorkloadGen::new(4242, meta.vocab, meta.max_seq, LengthProfile::Fixed, PROMPT, GEN);
    let reqs = wg.batch(REQUESTS);
    run_closed_loop(
        &mut engine,
        reqs,
        SchedConfig { max_batch: SEATS, prefill_chunk: 2, slots: 8, ..Default::default() },
    )?;
    let st = engine.tier_stats();
    let steps = engine.metrics.decode_steps.max(1) as f64;
    let fu = engine.flash_util();
    Ok(TierRun {
        hit_rate: st.hit_rate(),
        decode_s_per_step: engine.metrics.decode_sim_s / steps,
        die_busy_s: fu.die_busy_s,
        die_peak_q: fu.die_peak_depth,
    })
}

/// Sealed token-page working set of this sweep's workload (per CSD):
/// what "100% capacity" means in the table.  Sized from the same model
/// `run_config` will open (falling back to the synthesized opt-micro
/// shape), so the capacity fractions stay honest if artifacts exist.
pub fn working_set_bytes() -> usize {
    let m = match Runtime::open("artifacts") {
        Ok(rt) => rt.manifest.model.clone(),
        Err(_) => micro_meta(),
    };
    let groups = (PROMPT + GEN).div_ceil(m.n);
    SEATS * m.n_layers * m.n_heads * groups * 2 * CsdSpec::micro().flash.page_bytes
}

fn err_row(t: &mut Table, policy: &str, hot_kib: usize, cap: &str, e: &anyhow::Error) {
    t.row(vec![
        policy.into(),
        hot_kib.to_string(),
        cap.into(),
        "ERR".into(),
        format!("{e:#}"),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
}

pub fn tier() -> Table {
    tier_with_threads(super::threads())
}

/// `bench tier` at an explicit worker-thread count: the flash-only
/// baseline plus the nine policy x capacity configs are independent
/// fixed-seed runs fanned out on `sim::par::par_map` (baseline at index
/// 0 — its decode time feeds every speedup column) and reassembled in
/// index order, so the table is byte-identical for any thread count.
pub fn tier_with_threads(threads: usize) -> Table {
    let mut t = Table::new(
        "KV tiering — hot-tier capacity x policy (DRAM hit rate vs decode time)",
        &[
            "policy",
            "hot_KiB",
            "capacity",
            "hit_rate_%",
            "decode_ms_per_step",
            "speedup",
            "die_busy_ms",
            "peak_die_q",
        ],
    );
    let full = working_set_bytes();
    let policies = [
        TierPolicy::Lru,
        TierPolicy::H2oScore,
        TierPolicy::PinRecentWindow { window: 16 },
    ];
    let mut configs = vec![TierConfig::flash_only()];
    for policy in policies {
        for frac in [0.125f64, 0.5, 1.0] {
            configs.push(TierConfig { hot_bytes: (full as f64 * frac) as usize, policy });
        }
    }
    let fracs = [0.125f64, 0.5, 1.0];
    let mut runs =
        crate::sim::par::par_map(threads, configs, |_, cfg| run_config(cfg)).into_iter();
    let base = match runs.next().expect("baseline slot") {
        Ok(r) => r,
        Err(e) => {
            err_row(&mut t, "flash-only", 0, "0%", &e);
            return t;
        }
    };
    t.row(vec![
        "flash-only".into(),
        "0".into(),
        "0%".into(),
        eng(0.0),
        eng(base.decode_s_per_step * 1e3),
        eng(1.0),
        eng(base.die_busy_s * 1e3),
        base.die_peak_q.to_string(),
    ]);
    for policy in policies {
        for frac in fracs {
            let hot_bytes = (full as f64 * frac) as usize;
            let cap = format!("{:.0}%", frac * 100.0);
            match runs.next().expect("sweep slot") {
                Ok(r) => t.row(vec![
                    policy.label(),
                    (hot_bytes / 1024).to_string(),
                    cap,
                    eng(100.0 * r.hit_rate),
                    eng(r.decode_s_per_step * 1e3),
                    eng(base.decode_s_per_step / r.decode_s_per_step.max(1e-30)),
                    eng(r.die_busy_s * 1e3),
                    r.die_peak_q.to_string(),
                ]),
                Err(e) => err_row(&mut t, &policy.label(), hot_bytes / 1024, &cap, &e),
            }
        }
    }
    t
}
