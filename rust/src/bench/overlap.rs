//! `bench overlap` — the prefill/decode disaggregation evidence run:
//! sweep prefill chunk x arrival rate x CSD count and serve the same
//! open-loop Poisson trace twice, serialized and overlapped.
//!
//! The headline column is the steady-state decode step time
//! ([`crate::coordinator::EngineMetrics::decode_step_time_s`]): the mean
//! simulated span of decode-carrying scheduler steps, admission stalls
//! included.  Serialized, every admission's chunked prefill + layer-wise
//! KV shipping lands inside a decode step and stalls the whole batch;
//! overlapped, the cohort rides the GPU prefill stream while decode
//! ticks keep advancing, so under concurrent admissions the overlapped
//! decode step time must sit strictly below the serialized one (pinned
//! by `tests/pipeline.rs`).  TTFT drops with it — the cohort's first
//! token is stamped at the prefill stream's completion, which no longer
//! queues behind decode.  The overlap/contention columns surface where
//! the win comes from and what it costs on the shared PCIe links.

use crate::coordinator::{run_open_loop, EngineConfig, InferenceEngine, SchedConfig};
use crate::runtime::Runtime;
use crate::util::table::{eng, Table};
use crate::workload::{ArrivalGen, LengthProfile, WorkloadGen};

const PROMPT: usize = 24;
const GEN: usize = 12;
const REQUESTS: usize = 10;
const SEATS: usize = 4;
const SLOTS: usize = 16;

/// One serving run's overlap-relevant numbers.
pub struct OverlapRun {
    /// mean sim span of decode-carrying steps (admission stalls incl.)
    pub decode_step_s: f64,
    pub ttft_p50_s: f64,
    pub latency_p50_s: f64,
    pub sim_end_s: f64,
    /// prefill-stream time shadowed by concurrent decode
    pub overlapped_s: f64,
    /// decode-stream time with the prefill stream idle
    pub gpu_idle_s: f64,
    /// prefill-stream time with the decode plane idle
    pub csd_idle_s: f64,
    /// all-reduces slowed by in-flight prefill KV on the shared links
    pub contended_merges: u64,
    pub contention_delay_s: f64,
    /// aggregate die busy seconds across the CSD array (utilisation)
    pub die_busy_s: f64,
    /// worst per-die backlog observed on any shard
    pub die_peak_q: usize,
}

/// Serve a deterministic Poisson trace once.  Same seed per config, so
/// the serialized and overlapped rows face the identical workload.
pub fn run_config(
    n_csds: usize,
    prefill_chunk: usize,
    rate: f64,
    overlap: bool,
) -> anyhow::Result<OverlapRun> {
    let rt = Runtime::open("artifacts")?;
    let meta = rt.manifest.model.clone();
    let mut engine = InferenceEngine::new(rt, EngineConfig::micro_for(&meta, n_csds, false))?;
    let wg = WorkloadGen::new(4711, meta.vocab, meta.max_seq, LengthProfile::Fixed, PROMPT, GEN);
    let arrivals = ArrivalGen::new(wg, 4712, rate).take(REQUESTS);
    let cfg = SchedConfig::serving(SEATS, prefill_chunk, SLOTS).overlapped(overlap);
    let report = run_open_loop(&mut engine, arrivals, cfg)?;
    let [t50, _, _] = report.ttft_percentiles().unwrap_or([0.0; 3]);
    let [l50, _, _] = report.latency_percentiles().unwrap_or([0.0; 3]);
    let st = &engine.shards.stats;
    let fu = engine.flash_util();
    Ok(OverlapRun {
        decode_step_s: engine.metrics.decode_step_time_s(),
        ttft_p50_s: t50,
        latency_p50_s: l50,
        sim_end_s: report.sim_end,
        overlapped_s: report.overlap.overlapped_s,
        gpu_idle_s: report.overlap.gpu_idle_during_decode_s,
        csd_idle_s: report.overlap.csd_idle_during_prefill_s(),
        contended_merges: st.contended_merges,
        contention_delay_s: st.contention_delay_s,
        die_busy_s: fu.die_busy_s,
        die_peak_q: fu.die_peak_depth,
    })
}

/// `bench overlap --trace`: run the designated sweep point (2 CSDs,
/// chunk 4, 400 req/s, overlapped) with the trace plane installed and
/// return the drained sink.
pub fn traced(level: crate::obs::TraceLevel) -> anyhow::Result<crate::obs::TraceSink> {
    crate::obs::install(level);
    let run = run_config(2, 4, 400.0, true);
    let sink = crate::obs::uninstall();
    run?;
    sink.ok_or_else(|| anyhow::anyhow!("trace sink was not installed"))
}

/// The serialized/overlapped pair for one config (test hook).
pub fn run_pair(
    n_csds: usize,
    prefill_chunk: usize,
    rate: f64,
) -> anyhow::Result<(OverlapRun, OverlapRun)> {
    Ok((
        run_config(n_csds, prefill_chunk, rate, false)?,
        run_config(n_csds, prefill_chunk, rate, true)?,
    ))
}

fn err_row(t: &mut Table, csds: usize, chunk: usize, rate: f64, e: &anyhow::Error) {
    t.row(vec![
        csds.to_string(),
        chunk.to_string(),
        format!("{rate}"),
        "ERR".into(),
        format!("{e:#}"),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
}

pub fn overlap() -> Table {
    overlap_with_threads(super::threads())
}

/// `bench overlap` at an explicit worker-thread count: the twelve
/// (csds x chunk x rate) configs each produce an independent fixed-seed
/// serialized/overlapped pair, fanned out on `sim::par::par_map` and
/// reassembled in index order, so the table is byte-identical for any
/// thread count.
pub fn overlap_with_threads(threads: usize) -> Table {
    let mut t = Table::new(
        "Prefill/decode disaggregation — serialized vs overlapped streams (opt-micro, sim)",
        &[
            "csds",
            "prefill_chunk",
            "rate_req_s",
            "mode",
            "decode_step_ms",
            "step_speedup",
            "ttft_p50_s",
            "overlap_ms",
            "gpu_idle_ms",
            "contention_us",
            "die_busy_ms",
            "peak_die_q",
        ],
    );
    let mut configs: Vec<(usize, usize, f64)> = vec![];
    for n_csds in [1usize, 2, 4] {
        for chunk in [1usize, 4] {
            for rate in [100.0f64, 400.0] {
                configs.push((n_csds, chunk, rate));
            }
        }
    }
    let runs = crate::sim::par::par_map(threads, configs, |_, (n_csds, chunk, rate)| {
        (n_csds, chunk, rate, run_pair(n_csds, chunk, rate))
    });
    for (n_csds, chunk, rate, pair) in runs {
        let (serial, piped) = match pair {
            Ok(p) => p,
            Err(e) => {
                err_row(&mut t, n_csds, chunk, rate, &e);
                continue;
            }
        };
        let speedup = serial.decode_step_s / piped.decode_step_s.max(1e-30);
        t.row(vec![
            n_csds.to_string(),
            chunk.to_string(),
            format!("{rate}"),
            "serialized".into(),
            eng(serial.decode_step_s * 1e3),
            "1.0".into(),
            eng(serial.ttft_p50_s),
            "0".into(),
            "-".into(),
            "0".into(),
            eng(serial.die_busy_s * 1e3),
            serial.die_peak_q.to_string(),
        ]);
        t.row(vec![
            n_csds.to_string(),
            chunk.to_string(),
            format!("{rate}"),
            "overlapped".into(),
            eng(piped.decode_step_s * 1e3),
            eng(speedup),
            eng(piped.ttft_p50_s),
            eng(piped.overlapped_s * 1e3),
            eng(piped.gpu_idle_s * 1e3),
            eng(piped.contention_delay_s * 1e6),
            eng(piped.die_busy_s * 1e3),
            piped.die_peak_q.to_string(),
        ]);
    }
    t
}
