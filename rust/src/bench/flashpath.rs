//! `bench flashpath` — the flash-microarchitecture data-path evidence
//! run: sweep block placement x read scheduling x read-compute
//! pipelining over the dies-per-channel axis on the functional engine,
//! dense and SparF.
//!
//! Each row fills a fresh micro-geometry device with the same 256-token
//! stream, clears the array timing, and measures one full-context
//! decode-attention call at t=0 — so every row computes bit-identical
//! outputs and the only difference is how the same pages lay out and
//! stream through the die/plane/channel FIFOs.  Expected shape (paper
//! §IV, Fig. 8): the legacy channel placement is flat in the die count
//! (one open block per channel pins every read to one die), while the
//! die-interleaved + conflict-aware + pipelined path scales with the
//! dies until the channel bus or the kernels bind.

use crate::config::hw::{CsdSpec, FlashPathConfig, FlashPlacement, FlashReadSched};
use crate::config::model::SparsityParams;
use crate::csd::{AttnMode, InstCsd};
use crate::ftl::{FtlConfig, StreamKey};
use crate::util::rng::Rng;
use crate::util::table::{eng, Table};

/// Context length of the measured decode-attention call.
pub const TOKENS: usize = 256;

/// Micro-geometry CSD with `dies` dies per channel and the given path.
pub fn spec(dies: usize, path: FlashPathConfig) -> CsdSpec {
    let mut s = CsdSpec::micro();
    s.flash.dies_per_channel = dies;
    s.flash.path = path;
    s.kv_capacity_bytes = s.flash.usable_capacity_bytes() as u64;
    s
}

/// The sweep's SparF point: the paper's 1/8 token budget at d_head 32.
pub fn sparf_mode() -> AttnMode {
    AttnMode::SparF(SparsityParams { r: 8, k: 32, m: 4, n: 8 })
}

pub struct AttnRun {
    pub out: Vec<f32>,
    /// completion of the attention call issued at t=0 on a quiet array
    pub secs: f64,
    /// the breakdown's flash wall-wait
    pub flash_wait_s: f64,
    pub die_busy_s: f64,
    pub channel_busy_s: f64,
    pub die_peak_q: usize,
}

/// One decode-attention measurement on a freshly-filled device:
/// deterministic per (dies, path, mode).
pub fn run_attention(
    dies: usize,
    path: FlashPathConfig,
    mode: AttnMode,
) -> anyhow::Result<AttnRun> {
    let mut csd = InstCsd::new(spec(dies, path), FtlConfig::micro_head())?;
    let key = StreamKey { slot: 0, layer: 0, head: 0 };
    let mut rng = Rng::new(4242);
    for _ in 0..TOKENS {
        let k: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        csd.write_token(0, 0, &k, &v, 0.0)?;
    }
    let q: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
    csd.ftl.array.reset_timing();
    let (out, done, bd) = csd.attention_head(key, &q, TOKENS, mode, 0.0)?;
    let fu = csd.flash_util();
    Ok(AttnRun {
        out,
        secs: done,
        flash_wait_s: bd.flash_read,
        die_busy_s: fu.die_busy_s,
        channel_busy_s: fu.channel_busy_s,
        die_peak_q: fu.die_peak_depth,
    })
}

/// `bench flashpath --trace`: run the designated sweep point (4 dies,
/// tuned path, dense) with the trace plane installed and return the
/// drained sink.
pub fn traced(level: crate::obs::TraceLevel) -> anyhow::Result<crate::obs::TraceSink> {
    crate::obs::install(level);
    let run = run_attention(4, FlashPathConfig::tuned(), AttnMode::Dense);
    let sink = crate::obs::uninstall();
    run?;
    sink.ok_or_else(|| anyhow::anyhow!("trace sink was not installed"))
}

/// The ablation ladder from the legacy path to the tuned path.
pub fn ladder() -> Vec<FlashPathConfig> {
    vec![
        FlashPathConfig::legacy(),
        FlashPathConfig {
            placement: FlashPlacement::Die,
            sched: FlashReadSched::Fifo,
            pipeline: false,
        },
        FlashPathConfig {
            placement: FlashPlacement::Die,
            sched: FlashReadSched::Interleave,
            pipeline: false,
        },
        FlashPathConfig::tuned(),
    ]
}

fn err_row(t: &mut Table, dies: usize, label: String, e: &anyhow::Error) {
    t.row(vec![
        dies.to_string(),
        label,
        "ERR".into(),
        format!("{e:#}"),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
}

pub fn flashpath() -> Table {
    flashpath_with_threads(super::threads())
}

/// `bench flashpath` at an explicit worker-thread count: each
/// (dies, path) rung is an independent fixed-seed dense+SparF
/// measurement pair, fanned out on `sim::par::par_map` (each dies
/// group's first rung is the legacy baseline feeding its speedup
/// columns) and reassembled in index order, so the table is
/// byte-identical for any thread count.
pub fn flashpath_with_threads(threads: usize) -> Table {
    let mut t = Table::new(
        "Flash data path — placement x sched x pipeline vs dies/channel (opt-micro, sim)",
        &[
            "dies",
            "path",
            "dense_us",
            "dense_speedup",
            "sparf_us",
            "sparf_speedup",
            "die_busy_us",
            "chan_busy_us",
            "peak_die_q",
        ],
    );
    let rungs = ladder();
    let mut configs: Vec<(usize, FlashPathConfig)> = vec![];
    for dies in [1usize, 2, 4] {
        for path in &rungs {
            configs.push((dies, *path));
        }
    }
    let mut runs = crate::sim::par::par_map(threads, configs, |_, (dies, path)| {
        (
            dies,
            path,
            run_attention(dies, path, AttnMode::Dense),
            run_attention(dies, path, sparf_mode()),
        )
    })
    .into_iter();
    for _ in 0..3 {
        // the ladder's first rung IS the baseline — run once per dies
        // group and reused for every speedup column in the group
        let (dies, _, bd, bs) = runs.next().expect("baseline slot");
        let (base_dense, base_sparf) = match (bd, bs) {
            (Ok(d), Ok(s)) => (d, s),
            (Err(e), _) | (_, Err(e)) => {
                err_row(&mut t, dies, "legacy".into(), &e);
                // drop the rest of this dies group, as the serial
                // sweep's `continue` did
                for _ in 1..rungs.len() {
                    let _ = runs.next();
                }
                continue;
            }
        };
        let mk = |path: FlashPathConfig, d: &AttnRun, s: &AttnRun| -> Vec<String> {
            vec![
                dies.to_string(),
                path.label(),
                eng(d.secs * 1e6),
                eng(base_dense.secs / d.secs.max(1e-30)),
                eng(s.secs * 1e6),
                eng(base_sparf.secs / s.secs.max(1e-30)),
                eng(d.die_busy_s * 1e6),
                eng(d.channel_busy_s * 1e6),
                d.die_peak_q.to_string(),
            ]
        };
        t.row(mk(FlashPathConfig::legacy(), &base_dense, &base_sparf));
        for _ in 1..rungs.len() {
            let (dies, path, dense, sparf) = runs.next().expect("sweep slot");
            match (dense, sparf) {
                (Ok(d), Ok(s)) => t.row(mk(path, &d, &s)),
                (Err(e), _) | (_, Err(e)) => err_row(&mut t, dies, path.label(), &e),
            }
        }
    }
    t
}
