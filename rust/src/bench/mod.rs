//! Bench harness: regenerates every table and figure of the paper's
//! evaluation (§VI) plus the design-choice ablations of DESIGN.md §6.
//! Each function prints the same rows/series the paper plots and returns
//! the rendered table for logging.

pub mod accuracy;
pub mod attr;
pub mod fault;
pub mod figures;
pub mod flashpath;
pub mod gate;
pub mod overlap;
pub mod prefix;
pub mod serve;
pub mod shard;
pub mod tier;

use crate::util::table::Table;
use std::sync::atomic::{AtomicUsize, Ordering};

/// The serving-dashboard trajectory targets: the subset of `bench all`
/// that CI stitches across runs (run-numbered artifacts) to track the
/// system's performance trajectory.
pub const TRAJECTORY: &[&str] =
    &["fig16", "tier", "shard", "serve", "overlap", "flashpath", "prefix", "attr", "fault"];

/// Worker threads for sweep execution (`bench ... --threads`).  The
/// registry entries are plain `fn()` pointers, so the knob is a
/// process-global rather than an argument; every sweep point is an
/// independent fixed-seed simulation reassembled in index order, so the
/// tables — and the trajectory document minus its wall-clock timing
/// block — are byte-identical for any value (pinned by `tests/par.rs`
/// through the `*_with_threads` entry points).
static BENCH_THREADS: AtomicUsize = AtomicUsize::new(1);

/// Set the sweep worker-thread count (clamped to >= 1).
pub fn set_threads(n: usize) {
    BENCH_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// The sweep worker-thread count (1 = serial).
pub fn threads() -> usize {
    BENCH_THREADS.load(Ordering::Relaxed)
}

/// All paper targets in order; returns rendered tables.
pub fn run_all() -> Vec<String> {
    run_all_tables()
        .into_iter()
        .map(|(name, t)| {
            println!();
            t.print();
            format!("[{name}]\n{}", t.render())
        })
        .collect()
}

/// All paper targets in order as structured tables (JSON dumps, CI).
pub fn run_all_tables() -> Vec<(&'static str, Table)> {
    run_all_tables_timed().into_iter().map(|(n, t, _)| (n, t)).collect()
}

/// All paper targets with per-target wall-clock seconds — real time,
/// not simulated: the only intentionally machine-dependent numbers in
/// the bench plane, carried by the trajectory document under its
/// strippable `"timing"` key.
pub fn run_all_tables_timed() -> Vec<(&'static str, Table, f64)> {
    registry()
        .into_iter()
        .map(|(n, f)| {
            let t0 = std::time::Instant::now();
            let t = f();
            (n, t, t0.elapsed().as_secs_f64())
        })
        .collect()
}

type BenchFn = fn() -> Table;

/// (target name, generator) — the CLI dispatches on the name.
pub fn registry() -> Vec<(&'static str, BenchFn)> {
    vec![
        ("fig4", figures::fig4 as BenchFn),
        ("fig5", figures::fig5),
        ("fig6", figures::fig6),
        ("fig11", accuracy::fig11),
        ("fig12", figures::fig12),
        ("fig13", figures::fig13),
        ("fig14", figures::fig14),
        ("fig15", figures::fig15),
        ("fig16", figures::fig16),
        ("fig17a", figures::fig17a),
        ("fig17b", figures::fig17b),
        ("table1", figures::table1),
        ("tier", tier::tier),
        ("shard", shard::shard),
        ("serve", serve::serve),
        ("overlap", overlap::overlap),
        ("flashpath", flashpath::flashpath),
        ("prefix", prefix::prefix),
        ("attr", attr::attr),
        ("fault", fault::fault),
        ("ablate-group", figures::ablate_group),
        ("ablate-dualk", figures::ablate_dualk),
        ("ablate-pipeline", figures::ablate_pipeline),
        ("ablate-p2p", figures::ablate_p2p),
        ("ablate-placement", figures::ablate_placement),
    ]
}

pub fn run_one(name: &str) -> Option<Table> {
    registry().into_iter().find(|(n, _)| *n == name).map(|(_, f)| f())
}

/// Digest of the canonical traced serve run: a fixed-seed open-loop
/// sweep point traced at `full` level, hashed byte-for-byte.  The
/// trajectory document carries this fingerprint so cross-run stitching
/// catches any timing/ordering perturbation even when every table cell
/// still agrees.
pub fn canonical_trace_digest() -> anyhow::Result<String> {
    // runs at the configured `--threads` count: the digest is pinned
    // thread-count-invariant, so a threaded CI bench-all reproduces the
    // serial run's fingerprint exactly — that equality IS the
    // determinism proof the trajectory document carries
    canonical_trace_digest_with(threads())
}

/// [`canonical_trace_digest`] at an explicit engine worker-thread count
/// (the thread-invariance tests compare 1/2/8 directly).
pub fn canonical_trace_digest_with(threads: usize) -> anyhow::Result<String> {
    use crate::coordinator::{run_open_loop, EngineConfig, InferenceEngine, SchedConfig};
    use crate::runtime::Runtime;
    use crate::workload::{ArrivalGen, LengthProfile, WorkloadGen};

    let rt = Runtime::open("artifacts")?;
    let meta = rt.manifest.model.clone();
    let mut engine =
        InferenceEngine::new(rt, EngineConfig::micro_for(&meta, 2, false).threads(threads))?;
    let wg = WorkloadGen::new(777, meta.vocab, meta.max_seq, LengthProfile::Fixed, 16, 8);
    let arrivals = ArrivalGen::new(wg, 778, 100.0).take(8);
    crate::obs::install(crate::obs::TraceLevel::Full);
    let run = run_open_loop(&mut engine, arrivals, SchedConfig::serving(4, 2, 16));
    let sink = crate::obs::uninstall();
    run?;
    match sink {
        Some(s) => Ok(s.digest_hex()),
        None => anyhow::bail!("trace sink was not installed"),
    }
}
