//! Fig. 11 — accuracy of the sparsity methods vs compression ratio.
//!
//! The paper evaluates task accuracy on ShareGPT/WikiText-2/SQuAD/TriviaQA;
//! offline we measure attention-output fidelity (relative L2 error vs
//! dense attention) on two synthetic regimes standing in for the two
//! sub-figures (DESIGN.md §1): (a) heavy-hitter-structured attention
//! (knowledge-lookup-like) and (b) diffuse attention (summarisation-like).
//! The claim that must reproduce: SparF == SparQ >> H2O > local,
//! with SparF degrading gracefully up to 1/8 compression.

use crate::config::model::SparsityParams;
use crate::sparse;
use crate::util::rng::Rng;
use crate::util::stats::Welford;
use crate::util::table::{eng, Table};
use crate::workload::AttnStatsGen;

pub struct AccuracyPoint {
    pub compression: usize,
    pub sparf: f64,
    pub sparq: f64,
    pub h2o: f64,
    pub local: f64,
}

/// Mean relative L2 error of each method vs dense over `trials` heads.
pub fn sweep(
    gen: &AttnStatsGen,
    compressions: &[usize],
    trials: usize,
    seed: u64,
) -> Vec<AccuracyPoint> {
    let (s, d) = (gen.s, gen.d);
    let mut out = Vec::new();
    for &c in compressions {
        let mut rng = Rng::new(seed);
        let (mut wf, mut wq, mut wh, mut wl) =
            (Welford::new(), Welford::new(), Welford::new(), Welford::new());
        for _ in 0..trials {
            let (q, k, v) = gen.sample(&mut rng);
            let truth = sparse::dense_attention(&q, &k, &v, s);
            let norm = truth.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt().max(1e-9);
            let rel = |o: &[f32]| {
                o.iter()
                    .zip(&truth)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt()
                    / norm
            };
            let r = (d * 2 / c).max(1).min(d);
            let kk = (s / c).max(1);
            let vbar = sparse::v_mean(&v, d, s);
            let sp = SparsityParams { r, k: kk, m: 4, n: 8 };
            let of = sparse::sparf_attention(&q, &k, &v, &vbar, s, &sp);
            let oq = sparse::sparq_attention(&q, &k, &v, &vbar, s, r, kk);
            // H2O's accumulated scores: the true attention distribution
            // (its idealised oracle — favourable to H2O)
            let scale = 1.0 / (d as f32).sqrt();
            let logits: Vec<f32> = (0..s)
                .map(|t| sparse::select::dot(&q, &k[t * d..(t + 1) * d]) * scale)
                .collect();
            let acc = sparse::select::softmax_masked(&logits, &vec![true; s]);
            let oh = sparse::h2o_attention(&q, &k, &v, &acc, s, kk, (kk / 2).max(1));
            let ol = sparse::local_attention(&q, &k, &v, s, kk);
            wf.push(rel(&of.out));
            wq.push(rel(&oq.out));
            wh.push(rel(&oh));
            wl.push(rel(&ol));
        }
        out.push(AccuracyPoint {
            compression: c,
            sparf: wf.mean(),
            sparq: wq.mean(),
            h2o: wh.mean(),
            local: wl.mean(),
        });
    }
    out
}

/// Fig. 11a+b combined table.
pub fn fig11() -> Table {
    let mut t = Table::new(
        "Fig. 11 — attention-output rel. L2 error vs compression (lower=better)",
        &["regime", "ratio", "SparF", "SparQ", "H2O", "local"],
    );
    let compressions = [2usize, 4, 8, 16, 32];
    let hitter = AttnStatsGen::paper_like(256, 64);
    let diffuse = AttnStatsGen { s: 256, d: 64, hitters: 1, hitter_gain: 0.5 };
    for (name, gen) in [("lookup (11a)", &hitter), ("diffuse (11b)", &diffuse)] {
        for p in sweep(gen, &compressions, 40, 0xACC) {
            t.row(vec![
                name.into(),
                format!("1/{}", p.compression),
                eng(p.sparf),
                eng(p.sparq),
                eng(p.h2o),
                eng(p.local),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11_ordering_and_graceful_degradation() {
        let gen = AttnStatsGen::paper_like(128, 32);
        let pts = sweep(&gen, &[2, 8, 32], 30, 1);
        for p in &pts {
            // SparF == SparQ numerically (identical arithmetic)
            assert!((p.sparf - p.sparq).abs() < 1e-9);
            // SparF beats local everywhere, and H2O at moderate+ ratios
            assert!(p.sparf < p.local, "1/{}: sparf {} local {}", p.compression, p.sparf, p.local);
        }
        // errors grow with compression but stay modest at 1/8
        assert!(pts[0].sparf <= pts[1].sparf + 1e-9);
        assert!(pts[1].sparf <= pts[2].sparf + 1e-9);
        assert!(pts[1].sparf < 0.15, "1/8 error {} too large", pts[1].sparf);
        // ...and the paper's headline: SparF tracks dense closely vs H2O
        assert!(pts[1].sparf < pts[1].h2o, "sparf {} h2o {}", pts[1].sparf, pts[1].h2o);
    }
}
