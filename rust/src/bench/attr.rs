//! `bench attr` — the critical-path latency-attribution evidence run:
//! serve a dense closed-loop micro workload on one CSD with the
//! attribution sink installed, aggregate the per-request exclusive
//! buckets ([`crate::obs::attr`]), and cross-check the measured decode
//! shares against the analytic plane's per-unit terms
//! ([`crate::systems::insti::csd_layer_step`]).
//!
//! The e2e/decode rows are the bottleneck report: every bucket's
//! attributed seconds and its share of the scope's wall time (the
//! buckets are exclusive and sum to wall, pinned by `tests/obs.rs`).
//! The `xcheck` rows map the DES-side decode buckets onto the analytic
//! model's terms — flash wait (`flash_read` + conflict queueing) vs
//! on-device compute — normalised over the pair, with the relative
//! error between the measured and predicted shares.  Expected shape
//! (paper Fig. 14): decode attention is flash-read bound, not compute
//! bound, on both planes.

use crate::config::model::ModelShape;
use crate::config::system::{OffloadPolicy, SystemConfig};
use crate::coordinator::{run_closed_loop, EngineConfig, InferenceEngine, SchedConfig};
use crate::obs::attr::{self, AttrReport, Bucket, BUCKETS};
use crate::runtime::Runtime;
use crate::systems::insti;
use crate::util::table::{eng, Table};
use crate::workload::{LengthProfile, WorkloadGen};

const PROMPT: usize = 24;
const GEN: usize = 8;
const REQUESTS: usize = 8;
const SEATS: usize = 4;
const SLOTS: usize = 16;

/// Mid-generation context length the analytic cross-check is evaluated
/// at: the fixed prompt plus half the generation budget.
const XCHECK_CTX: usize = PROMPT + GEN / 2;

/// Serve the designated dense micro workload (1 CSD, closed loop) with
/// the attribution sink installed and return the extracted report.
pub fn run_attributed() -> anyhow::Result<AttrReport> {
    let rt = Runtime::open("artifacts")?;
    let meta = rt.manifest.model.clone();
    let mut engine = InferenceEngine::new(rt, EngineConfig::micro_for(&meta, 1, false))?;
    let mut wg =
        WorkloadGen::new(6001, meta.vocab, meta.max_seq, LengthProfile::Fixed, PROMPT, GEN);
    let reqs = wg.batch(REQUESTS);
    attr::install();
    let run = run_closed_loop(&mut engine, reqs, SchedConfig::serving(SEATS, 2, SLOTS));
    let sink = attr::uninstall().unwrap_or_default();
    run?;
    Ok(attr::extract(&sink))
}

/// The analytic plane's (flash, compute) decode-step seconds for the
/// same rig: opt-micro shapes on the micro CSD geometry, dense.
///
/// `flash` is the model's streamed flash-read term; `compute` lumps the
/// on-device kernels (argtopk + NFC filter + logits + attend) because
/// the DES engine charges the filter pass to its compute accumulator on
/// the dense path too.
pub fn predicted_split() -> (f64, f64) {
    let mut cfg = SystemConfig::paper_base(OffloadPolicy::InStorage);
    cfg.model = ModelShape::opt_micro();
    cfg.csd = crate::config::hw::CsdSpec::micro();
    let step = insti::csd_layer_step(&cfg, SEATS, XCHECK_CTX, cfg.model.n_heads);
    let u = &step.units;
    let flash = u.flash_read;
    let compute = u.argtopk + u.nfc_filter + u.logit0 + u.logit + u.attend;
    (flash, compute)
}

/// The measured (flash, compute) decode seconds from an attribution
/// report: flash wait = raw read service + die/channel conflict
/// queueing; compute = the CSD kernel bucket.
pub fn measured_split(rep: &AttrReport) -> (f64, f64) {
    let flash = rep.decode_total[Bucket::FlashRead.index()]
        + rep.decode_total[Bucket::FlashConflict.index()];
    let compute = rep.decode_total[Bucket::CsdCompute.index()];
    (flash, compute)
}

fn share(x: f64, total: f64) -> f64 {
    x / total.max(1e-30)
}

pub fn attr() -> Table {
    let mut t = Table::new(
        "Critical-path latency attribution — exclusive buckets + analytic cross-check (opt-micro, sim)",
        &["scope", "bucket", "s", "frac", "pred_frac", "rel_err"],
    );
    let rep = match run_attributed() {
        Ok(r) => r,
        Err(e) => {
            t.row(vec![
                "-".into(),
                "-".into(),
                "ERR".into(),
                format!("{e:#}"),
                "-".into(),
                "-".into(),
            ]);
            return t;
        }
    };
    let scope_rows = |t: &mut Table, scope: &str, totals: &[f64; attr::NBUCKETS], wall: f64| {
        for b in BUCKETS {
            let s = totals[b.index()];
            t.row(vec![
                scope.into(),
                b.label().into(),
                eng(s),
                eng(share(s, wall)),
                "-".into(),
                "-".into(),
            ]);
        }
    };
    let decode_wall: f64 = rep.decode_total.iter().sum();
    scope_rows(&mut t, "e2e", &rep.total, rep.wall_total);
    scope_rows(&mut t, "decode", &rep.decode_total, decode_wall);
    // predicted-vs-measured: shares normalised over the flash/compute
    // pair so both planes answer the same question ("which binds?")
    let (pf, pc) = predicted_split();
    let (mf, mc) = measured_split(&rep);
    let pairs = [("flash", mf, share(pf, pf + pc)), ("compute", mc, share(pc, pf + pc))];
    for (name, meas_s, pred_share) in pairs {
        let meas_share = share(meas_s, mf + mc);
        let rel_err = (meas_share - pred_share).abs() / pred_share.max(1e-30);
        t.row(vec![
            "xcheck".into(),
            name.into(),
            eng(meas_s),
            eng(meas_share),
            eng(pred_share),
            eng(rel_err),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predicted_split_is_flash_bound() {
        // the paper's claim on the analytic plane: dense decode
        // attention waits on flash reads, not on the kernels
        let (flash, compute) = predicted_split();
        assert!(flash > 0.0 && compute > 0.0);
        assert!(flash > compute, "flash {flash} vs compute {compute}");
    }
}
