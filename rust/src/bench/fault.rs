//! `bench fault` — the fault-plane sweep: recovery policy x per-op
//! fault rate under a scheduled mid-run CSD loss.  Closes the ROADMAP
//! "degraded-mode serving" dashboard item: what does each recovery
//! policy cost in goodput, tail latency and availability when a device
//! dies while requests are in flight?
//!
//! Every point serves the identical fixed-seed Poisson trace the serve
//! bench uses; a fault-free probe run first measures the healthy
//! `sim_end`, and the loss is anchored at 50% of it so the death lands
//! mid-decode for every policy.  The `faultfree` row is the reference:
//! by the fault plane's bit-identity contract its cells match `bench
//! serve`'s continuous row at the same rate.
//!
//! Expected shape: `retry` keeps the replacement device for new traffic
//! only (in-flight work aborts — availability drops, goodput with it);
//! `reprefill` re-runs lost prefills (everything completes, tail
//! latency pays the re-prefill); `replicated` restores from the peer
//! mirror (everything completes, recovery_ms pays the restore and the
//! mirror writes tax the healthy path).

use crate::coordinator::{run_open_loop, EngineConfig, InferenceEngine, SchedConfig};
use crate::fault::{FaultConfig, RecoveryPolicy};
use crate::runtime::Runtime;
use crate::util::table::{eng, Table};
use crate::workload::{ArrivalGen, LengthProfile, WorkloadGen};

const PROMPT: usize = 16;
const GEN: usize = 8;
const REQUESTS: usize = 8;
const SEATS: usize = 4;
const ARRIVAL_RATE: f64 = 100.0;
/// Base seed of every per-device fault stream in the sweep.
const FAULT_SEED: u64 = 7;
/// The device the scheduled loss kills (head-striped pair: csd1).
const LOST_DEV: usize = 1;

struct FaultRun {
    goodput_tok_s: f64,
    p50_latency_s: f64,
    p95_latency_s: f64,
    served: usize,
    aborted: usize,
    restarts: u64,
    recovery_ms: f64,
    nvme_timeouts: u64,
    flash_retries: u64,
    availability: f64,
}

fn engine(fault: FaultConfig) -> anyhow::Result<InferenceEngine> {
    let rt = Runtime::open("artifacts")?;
    let meta = rt.manifest.model.clone();
    InferenceEngine::new(rt, EngineConfig::micro_for(&meta, 2, false).faults(fault))
}

fn arrivals(engine: &InferenceEngine) -> Vec<crate::workload::Arrival> {
    let m = &engine.rt.manifest.model;
    let wg = WorkloadGen::new(777, m.vocab, m.max_seq, LengthProfile::Fixed, PROMPT, GEN);
    ArrivalGen::new(wg, 778, ARRIVAL_RATE).take(REQUESTS)
}

fn sched() -> SchedConfig {
    SchedConfig::serving(SEATS, 2, 16)
}

/// Fault-free probe: the healthy run's `sim_end`, which anchors the
/// scheduled loss at its midpoint for every sweep point.
fn probe_end() -> anyhow::Result<f64> {
    let mut engine = engine(FaultConfig::none())?;
    let arr = arrivals(&engine);
    let report = run_open_loop(&mut engine, arr, sched())?;
    Ok(report.sim_end)
}

fn run_point(fault: FaultConfig) -> anyhow::Result<FaultRun> {
    let mut engine = engine(fault)?;
    let arr = arrivals(&engine);
    let report = run_open_loop(&mut engine, arr, sched())?;
    let [p50, p95, _] = report.latency_percentiles().unwrap_or([0.0; 3]);
    let served = report.served().count();
    // goodput counts completed requests' tokens only: an aborted
    // request's pre-loss output is wasted work, not serving
    let good_toks: u64 = report.served().map(|r| r.generated.len() as u64).sum();
    let reg = engine.metrics_registry(&report.overlap);
    Ok(FaultRun {
        goodput_tok_s: good_toks as f64 / report.sim_end.max(1e-12),
        p50_latency_s: p50,
        p95_latency_s: p95,
        served,
        aborted: report.aborted_count(),
        restarts: engine.metrics.restarts,
        recovery_ms: engine.metrics.recovery_s * 1e3,
        nvme_timeouts: reg.value("fault.nvme_timeouts").unwrap_or(0.0) as u64,
        flash_retries: reg.value("fault.flash_read_retries").unwrap_or(0.0) as u64,
        availability: served as f64 / REQUESTS as f64,
    })
}

fn err_row(t: &mut Table, policy: &str, rate: f64, e: &anyhow::Error) {
    t.row(vec![
        policy.into(),
        format!("{rate}"),
        "ERR".into(),
        format!("{e:#}"),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
}

pub fn fault() -> Table {
    fault_with_threads(super::threads())
}

/// `bench fault` at an explicit worker-thread count: the probe runs
/// first (it anchors every point's loss time), then the sweep points
/// fan out on `sim::par::par_map` and reassemble in index order, so the
/// table is byte-identical for any thread count.
pub fn fault_with_threads(threads: usize) -> Table {
    let mut t = Table::new(
        "Fault plane — recovery policy x fault rate under a mid-run CSD loss (sim)",
        &[
            "policy",
            "fault_rate",
            "goodput_tok_s",
            "p50_latency_s",
            "p95_latency_s",
            "served",
            "aborted",
            "restarts",
            "recovery_ms",
            "nvme_timeouts",
            "flash_retries",
            "availability",
        ],
    );
    let row = |policy: &str, rate: f64, r: &FaultRun| {
        vec![
            policy.into(),
            format!("{rate}"),
            eng(r.goodput_tok_s),
            eng(r.p50_latency_s),
            eng(r.p95_latency_s),
            r.served.to_string(),
            r.aborted.to_string(),
            r.restarts.to_string(),
            eng(r.recovery_ms),
            r.nvme_timeouts.to_string(),
            r.flash_retries.to_string(),
            format!("{:.3}", r.availability),
        ]
    };
    let loss_at = match probe_end() {
        Ok(end) => end * 0.5,
        Err(e) => {
            err_row(&mut t, "probe", 0.0, &e);
            return t;
        }
    };
    // (policy, per-op rate, scheduled loss?) — the first point is the
    // fault-free reference row
    let mut points: Vec<(RecoveryPolicy, f64, bool)> = vec![(RecoveryPolicy::RePrefill, 0.0, false)];
    for policy in [RecoveryPolicy::RetryOnly, RecoveryPolicy::RePrefill, RecoveryPolicy::Replicated]
    {
        for rate in [0.0, 2e-3] {
            points.push((policy, rate, true));
        }
    }
    let runs = crate::sim::par::par_map(threads, points, |_, (policy, rate, loss)| {
        let fault = FaultConfig {
            seed: FAULT_SEED,
            rate,
            csd_loss: loss.then_some((LOST_DEV, loss_at)),
            recovery: policy,
            kv_replicas: u8::from(loss && policy == RecoveryPolicy::Replicated),
        };
        (policy, rate, loss, run_point(fault))
    });
    for (policy, rate, loss, res) in runs {
        let label = if loss { policy.label() } else { "faultfree" };
        match res {
            Ok(r) => t.row(row(label, rate, &r)),
            Err(e) => err_row(&mut t, label, rate, &e),
        }
    }
    t
}
