//! Stream timelines: busy-interval bookkeeping for overlap accounting.
//!
//! The two-stream executor needs to know how much of the decode stream's
//! progress was shadowed by concurrent prefill work (the disaggregation
//! win) and how much of each stream ran alone (the idle cost the
//! serialized executor pays structurally).  A [`StreamTimeline`] records
//! one stream's busy intervals and answers overlap queries from the
//! other stream's observation windows, pruning intervals once the
//! observing frontier has passed them so a long run stays O(in-flight).

use crate::sim::Time;

/// Busy intervals of one engine stream.  Observation windows must be
/// presented in non-decreasing order (the decode stream's step spans
/// are), so every interval contributes to the overlap total exactly
/// once before it is pruned.
#[derive(Debug, Clone, Default)]
pub struct StreamTimeline {
    intervals: Vec<(Time, Time)>,
    busy_s: Time,
}

impl StreamTimeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a busy interval `[start, end)`; empty/inverted intervals
    /// are ignored.
    pub fn push(&mut self, start: Time, end: Time) {
        if end > start {
            self.busy_s += end - start;
            self.intervals.push((start, end));
        }
    }

    /// Total busy seconds ever recorded (never pruned away).
    pub fn busy_s(&self) -> Time {
        self.busy_s
    }

    /// Intervals still in flight (not yet passed by an observation).
    pub fn in_flight(&self) -> usize {
        self.intervals.len()
    }

    /// Overlap of the observation window `[d0, d1)` with the recorded
    /// intervals.  Intervals that end at or before `d1` are pruned:
    /// successive windows are non-overlapping and non-decreasing, so a
    /// pruned interval can never contribute again, and a surviving one
    /// only contributes its not-yet-observed tail.
    pub fn overlap_and_prune(&mut self, d0: Time, d1: Time) -> Time {
        let mut ov = 0.0;
        for &(s, e) in &self.intervals {
            ov += (e.min(d1) - s.max(d0)).max(0.0);
        }
        self.intervals.retain(|&(_, e)| e > d1);
        ov
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_ignores_empty_intervals() {
        let mut t = StreamTimeline::new();
        t.push(2.0, 2.0);
        t.push(3.0, 1.0);
        assert_eq!(t.in_flight(), 0);
        assert_eq!(t.busy_s(), 0.0);
        t.push(1.0, 4.0);
        assert_eq!(t.in_flight(), 1);
        assert_eq!(t.busy_s(), 3.0);
    }

    #[test]
    fn overlap_counts_each_interval_once() {
        let mut t = StreamTimeline::new();
        t.push(0.0, 10.0);
        // two successive decode windows split the interval's coverage
        assert!((t.overlap_and_prune(1.0, 4.0) - 3.0).abs() < 1e-12);
        assert_eq!(t.in_flight(), 1, "interval outlives the first window");
        assert!((t.overlap_and_prune(4.0, 12.0) - 6.0).abs() < 1e-12);
        assert_eq!(t.in_flight(), 0, "fully observed intervals are pruned");
        assert_eq!(t.overlap_and_prune(12.0, 20.0), 0.0);
    }

    #[test]
    fn disjoint_interval_reports_zero_overlap() {
        let mut t = StreamTimeline::new();
        t.push(5.0, 6.0);
        assert_eq!(t.overlap_and_prune(0.0, 5.0), 0.0);
        assert_eq!(t.in_flight(), 1, "future intervals survive");
        assert!((t.overlap_and_prune(5.5, 8.0) - 0.5).abs() < 1e-12);
        assert_eq!(t.in_flight(), 0);
    }
}
