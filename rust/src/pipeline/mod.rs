//! Prefill/decode disaggregation: the two-stream pipelined executor's
//! state (paper §IV-B dataflow; ROADMAP "prefill/decode disaggregation").
//!
//! The serialized scheduler runs chunked prefill *inside* the step, so
//! every admission stalls all in-flight decodes: the step clock first
//! advances through the cohort's layer-wise KV shipping and only then
//! starts the decode tick.  The pipelined executor instead advances two
//! streams on a shared event timeline:
//!
//! * the GPU **prefill stream** — chunked prefill plus layer-wise KV
//!   shipping to the CSD array.  In the simulated plane its cost is the
//!   shipping (GPU block compute is functional wall time, exactly as the
//!   serialized path accounts it); the stream has its own frontier
//!   (`prefill_free`) and cohorts queue on it FIFO.
//! * the CSD **decode stream** — the per-step decode ticks over the live
//!   batch, advancing `engine.sim_now` without ever waiting on the
//!   prefill stream.
//!
//! A cohort whose prefill completes at `ready` is *parked* until the
//! decode frontier reaches `ready`, then joins the running batch.  While
//! both streams are in flight, prefill KV shipping and decode partial
//! returns contend for the same PCIe links — the shard coordinator
//! registers the shipping as background load for its fair-share
//! all-reduce arbiter ([`crate::pcie::fair_share_contended`]).
//!
//! [`OverlapStats`] is the overlap-efficiency ledger: how much decode
//! time was shadowed by prefill (the win), how long the GPU prefill
//! stream sat idle during decode, and how long the CSDs sat idle during
//! prefill (both costs the serialized executor pays on every admission).

pub mod stream;

pub use stream::StreamTimeline;

use crate::coordinator::request::Sequence;
use crate::sim::Time;

/// A prefilled cohort parked on the prefill stream, waiting for the
/// decode stream's frontier to reach its ready time.
#[derive(Debug)]
pub struct PendingCohort {
    pub seqs: Vec<Sequence>,
    /// prefill-stream completion (GPU blocks + layer-wise KV ship done)
    pub ready: Time,
}

/// Overlap-efficiency accounting across a run (simulated seconds).
#[derive(Debug, Clone, Default)]
pub struct OverlapStats {
    /// prefill-stream busy time (layer-wise KV shipping spans)
    pub prefill_busy_s: Time,
    /// decode-stream busy time (step spans over the live batch)
    pub decode_busy_s: Time,
    /// time both streams were simultaneously busy — the disaggregation
    /// win the serialized executor structurally cannot have
    pub overlapped_s: Time,
    /// decode-stream time with the GPU prefill stream idle
    pub gpu_idle_during_decode_s: Time,
    /// cohorts that rode the prefill stream
    pub cohorts: u64,
    /// decode steps taken while at least one prefill was in flight
    pub steps_with_prefill_inflight: u64,
}

impl OverlapStats {
    /// Prefill-stream time during which the CSD decode plane sat idle
    /// (shipping that was NOT shadowed by a concurrent decode tick).
    pub fn csd_idle_during_prefill_s(&self) -> Time {
        (self.prefill_busy_s - self.overlapped_s).max(0.0)
    }

    /// Fraction of prefill-stream busy time shadowed by decode work.
    pub fn overlap_frac(&self) -> f64 {
        if self.prefill_busy_s <= 0.0 {
            0.0
        } else {
            (self.overlapped_s / self.prefill_busy_s).clamp(0.0, 1.0)
        }
    }
}

/// State of the two engine streams: the prefill-stream frontier, the
/// parked cohorts awaiting their decode-stream join, and the overlap
/// ledger.  Owned by the scheduler; inert (and empty) when the
/// serialized executor runs.
#[derive(Debug, Default)]
pub struct PipelineState {
    /// when the GPU prefill stream next frees up (cohorts queue FIFO)
    pub prefill_free: Time,
    pending: Vec<PendingCohort>,
    prefill_intervals: StreamTimeline,
    pub stats: OverlapStats,
}

impl PipelineState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parked cohorts still mid-prefill (or awaiting their join).
    pub fn pending_cohorts(&self) -> usize {
        self.pending.len()
    }

    /// Sequences across all parked cohorts — they hold KV slots and
    /// claim decode seats, so admission planning must count them.
    pub fn pending_seqs(&self) -> usize {
        self.pending.iter().map(|c| c.seqs.len()).sum()
    }

    /// Iterate the parked sequences (KV-byte accounting).
    pub fn pending_iter(&self) -> impl Iterator<Item = &Sequence> + '_ {
        self.pending.iter().flat_map(|c| c.seqs.iter())
    }

    /// Earliest prefill-stream completion among parked cohorts.
    pub fn earliest_ready(&self) -> Option<Time> {
        self.pending.iter().map(|c| c.ready).fold(None, |acc, t| match acc {
            Some(b) if b <= t => Some(b),
            _ => Some(t),
        })
    }

    /// Park a cohort that occupied the prefill stream over
    /// `[start, ready)`; it joins the decode stream once the decode
    /// frontier reaches `ready`.
    pub fn park(&mut self, seqs: Vec<Sequence>, start: Time, ready: Time) {
        crate::obs::stream_span(0, "prefill_cohort", start, ready);
        self.stats.cohorts += 1;
        self.prefill_intervals.push(start, ready);
        // single source of truth: the timeline's cumulative busy time
        self.stats.prefill_busy_s = self.prefill_intervals.busy_s();
        if ready > self.prefill_free {
            self.prefill_free = ready;
        }
        self.pending.push(PendingCohort { seqs, ready });
    }

    /// Pop every parked sequence whose cohort's prefill finished by
    /// `now` (the decode frontier), in stream order.
    pub fn take_ready(&mut self, now: Time) -> Vec<Sequence> {
        if self.pending.is_empty() {
            return Vec::new();
        }
        let mut ready: Vec<PendingCohort> = Vec::new();
        let mut keep: Vec<PendingCohort> = Vec::new();
        for c in self.pending.drain(..) {
            if c.ready <= now {
                ready.push(c);
            } else {
                keep.push(c);
            }
        }
        self.pending = keep;
        // the stream is FIFO so push order == ready order, but keep the
        // join order explicit for safety
        ready.sort_by(|a, b| a.ready.total_cmp(&b.ready));
        for c in &ready {
            // dependency arrow: prefill-stream completion feeds the
            // decode-stream tick that absorbs the cohort
            crate::obs::flow(
                "cohort_join",
                crate::obs::TraceLevel::Device,
                (crate::obs::PID_STREAMS, 0, c.ready),
                (crate::obs::PID_STREAMS, 1, now),
            );
        }
        ready.into_iter().flat_map(|c| c.seqs).collect()
    }

    /// Drain every parked cohort regardless of readiness.  Post-fault
    /// recovery: the scheduler restarts or aborts the parked sequences,
    /// so they must leave the stream without a join.  The prefill-stream
    /// frontier and busy ledger are untouched — the shipping happened.
    pub fn drain_all(&mut self) -> Vec<Sequence> {
        self.pending.drain(..).flat_map(|c| c.seqs).collect()
    }

    /// Account one decode-stream step span `[d0, d1)` against the
    /// prefill stream's busy intervals.
    pub fn note_decode(&mut self, d0: Time, d1: Time) {
        crate::obs::stream_span(1, "decode_step", d0, d1);
        let span = (d1 - d0).max(0.0);
        self.stats.decode_busy_s += span;
        if self.prefill_free > d0 {
            self.stats.steps_with_prefill_inflight += 1;
        }
        let ov = self.prefill_intervals.overlap_and_prune(d0, d1);
        self.stats.overlapped_s += ov;
        self.stats.gpu_idle_during_decode_s += (span - ov).max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Request;

    fn seq(id: u64) -> Sequence {
        Sequence::new(Request { id, prompt: vec![1, 2], max_new_tokens: 2 }, id as u32)
    }

    #[test]
    fn park_and_join_in_stream_order() {
        let mut p = PipelineState::new();
        assert_eq!(p.pending_cohorts(), 0);
        assert!(p.earliest_ready().is_none());
        p.park(vec![seq(1), seq(2)], 0.0, 2.0);
        p.park(vec![seq(3)], 2.0, 5.0);
        assert_eq!(p.pending_seqs(), 3);
        assert_eq!(p.earliest_ready(), Some(2.0));
        assert_eq!(p.prefill_free, 5.0);
        // frontier at 1.0: nothing ready yet
        assert!(p.take_ready(1.0).is_empty());
        // frontier at 2.0: first cohort joins, second stays parked
        let j = p.take_ready(2.0);
        assert_eq!(j.iter().map(|s| s.req.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(p.pending_cohorts(), 1);
        let j = p.take_ready(10.0);
        assert_eq!(j.iter().map(|s| s.req.id).collect::<Vec<_>>(), vec![3]);
        assert_eq!(p.pending_cohorts(), 0);
        assert_eq!(p.stats.cohorts, 2);
        assert!((p.stats.prefill_busy_s - 5.0).abs() < 1e-12);
    }

    #[test]
    fn decode_overlap_accounting() {
        let mut p = PipelineState::new();
        p.park(vec![seq(1)], 0.0, 4.0);
        // decode tick [1, 3): fully shadowed by the prefill interval
        p.note_decode(1.0, 3.0);
        assert!((p.stats.overlapped_s - 2.0).abs() < 1e-12);
        assert_eq!(p.stats.steps_with_prefill_inflight, 1);
        // decode tick [3, 6): one more second of overlap, two alone
        p.note_decode(3.0, 6.0);
        assert!((p.stats.overlapped_s - 3.0).abs() < 1e-12);
        assert!((p.stats.gpu_idle_during_decode_s - 2.0).abs() < 1e-12);
        assert!((p.stats.csd_idle_during_prefill_s() - 1.0).abs() < 1e-12);
        assert!((p.stats.overlap_frac() - 0.75).abs() < 1e-12);
        // after the stream drains, later ticks are all GPU-idle
        p.note_decode(6.0, 7.0);
        assert_eq!(p.stats.steps_with_prefill_inflight, 2);
        assert!((p.stats.gpu_idle_during_decode_s - 3.0).abs() < 1e-12);
    }
}
