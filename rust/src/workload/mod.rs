//! Workload generation: request batches with realistic length
//! distributions (the paper samples ShareGPT/WikiText-2/SQuAD/TriviaQA;
//! offline, we synthesise matched distributions — DESIGN.md §1) and the
//! attention-statistics model behind the Fig. 11 accuracy study.

use crate::util::rng::Rng;

/// One offline inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// Length distribution families matched to the paper's datasets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LengthProfile {
    /// fixed input/output (the paper's throughput runs: 1024/1024)
    Fixed,
    /// ShareGPT-like: lognormal-ish chat turns, long tail
    Chat,
    /// SQuAD-like: mid-length context, short answers
    Qa,
}

#[derive(Debug, Clone)]
pub struct WorkloadGen {
    rng: Rng,
    vocab: usize,
    max_seq: usize,
    profile: LengthProfile,
    input_len: usize,
    output_len: usize,
    next_id: u64,
}

impl WorkloadGen {
    pub fn new(
        seed: u64,
        vocab: usize,
        max_seq: usize,
        profile: LengthProfile,
        input_len: usize,
        output_len: usize,
    ) -> Self {
        WorkloadGen {
            rng: Rng::new(seed),
            vocab,
            max_seq,
            profile,
            input_len,
            output_len,
            next_id: 0,
        }
    }

    fn sample_lens(&mut self) -> (usize, usize) {
        match self.profile {
            LengthProfile::Fixed => (self.input_len, self.output_len),
            LengthProfile::Chat => {
                // lognormal around the configured mean, clipped to context
                let ln = |rng: &mut Rng, mean: f64| -> usize {
                    let mu = mean.ln() - 0.32; // sigma^2/2 with sigma=0.8
                    let x = (mu + 0.8 * rng.normal()).exp();
                    (x as usize).clamp(4, mean as usize * 4)
                };
                let i = ln(&mut self.rng, self.input_len as f64);
                let o = ln(&mut self.rng, self.output_len as f64);
                let i = i.min(self.max_seq / 2);
                let o = o.min(self.max_seq - i);
                (i.max(1), o.max(1))
            }
            LengthProfile::Qa => {
                let i = self.rng.range(self.input_len / 2, self.input_len.max(2));
                let o = self.rng.range(1, (self.output_len / 4).max(2));
                let i = i.min(self.max_seq - 1);
                (i.max(1), o.min(self.max_seq - i).max(1))
            }
        }
    }

    pub fn request(&mut self) -> Request {
        let (i, o) = self.sample_lens();
        let prompt = (0..i).map(|_| self.rng.below(self.vocab) as i32).collect();
        let id = self.next_id;
        self.next_id += 1;
        Request { id, prompt, max_new_tokens: o }
    }

    pub fn batch(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.request()).collect()
    }
}

/// Anything that can mint the next [`Request`] of a stream — the seam
/// that lets [`ArrivalGen`] drive either independent prompts
/// ([`WorkloadGen`]) or prefix-sharing ones ([`PrefixWorkloadGen`])
/// through the same Poisson arrival process.
pub trait RequestSource {
    fn request(&mut self) -> Request;
}

impl RequestSource for WorkloadGen {
    fn request(&mut self) -> Request {
        WorkloadGen::request(self)
    }
}

impl<T: RequestSource + ?Sized> RequestSource for Box<T> {
    fn request(&mut self) -> Request {
        (**self).request()
    }
}

/// Multi-turn / shared-system-prompt workload: a fixed pool of prompt
/// *stems* (the shared system prompt or conversation history) is
/// generated up front; each request then either reuses a stem followed
/// by a unique suffix (probability `hit_rate`) or is fully unique.
/// `stem_len` is rounded to whole KV token groups so a reused stem is
/// exactly the portion the FTL's content-addressed index can seal and
/// share.  Deterministic per seed.
#[derive(Debug, Clone)]
pub struct PrefixWorkloadGen {
    rng: Rng,
    vocab: usize,
    prompt_len: usize,
    output_len: usize,
    stem_len: usize,
    hit_rate: f64,
    stems: Vec<Vec<i32>>,
    next_id: u64,
}

impl PrefixWorkloadGen {
    /// `share_ratio` is the target shared fraction of each prompt;
    /// the stem length is `share_ratio * prompt_len` rounded to whole
    /// token groups of `group` tokens (the FTL's sealing granule).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        seed: u64,
        vocab: usize,
        prompt_len: usize,
        output_len: usize,
        share_ratio: f64,
        group: usize,
        hit_rate: f64,
        n_stems: usize,
    ) -> Self {
        assert!(prompt_len >= 1 && group >= 1);
        let share = share_ratio.clamp(0.0, 1.0);
        let groups = (prompt_len as f64 * share / group as f64).round() as usize;
        let stem_len = (groups * group).min(prompt_len);
        let mut rng = Rng::new(seed);
        let stems = (0..n_stems.max(1))
            .map(|_| (0..stem_len).map(|_| rng.below(vocab) as i32).collect())
            .collect();
        PrefixWorkloadGen {
            rng,
            vocab,
            prompt_len,
            output_len,
            stem_len,
            hit_rate: hit_rate.clamp(0.0, 1.0),
            stems,
            next_id: 0,
        }
    }

    /// The stem length actually in use (whole token groups, tokens).
    pub fn stem_len(&self) -> usize {
        self.stem_len
    }
}

impl RequestSource for PrefixWorkloadGen {
    fn request(&mut self) -> Request {
        let shared = self.stem_len > 0 && self.rng.bool(self.hit_rate);
        let mut prompt: Vec<i32> = if shared {
            let s = self.rng.below(self.stems.len());
            self.stems[s].clone()
        } else {
            (0..self.stem_len).map(|_| self.rng.below(self.vocab) as i32).collect()
        };
        prompt.extend((prompt.len()..self.prompt_len).map(|_| self.rng.below(self.vocab) as i32));
        let id = self.next_id;
        self.next_id += 1;
        Request { id, prompt, max_new_tokens: self.output_len }
    }
}

/// One open-loop request: a [`Request`] stamped with its (simulated)
/// arrival time and a scheduling priority (higher = more urgent).
#[derive(Debug, Clone)]
pub struct Arrival {
    pub req: Request,
    /// arrival timestamp on the simulated device clock, seconds
    pub at: f64,
    pub priority: u8,
}

/// Open-loop arrival process: Poisson arrivals at `rate` requests per
/// simulated second over any [`RequestSource`] (length-profile prompts
/// by default; prefix-sharing prompts via [`PrefixWorkloadGen`]), with
/// an optional fraction of high-priority requests (priority 1 vs 0) to
/// exercise preemption.  Deterministic per seed.
#[derive(Debug, Clone)]
pub struct ArrivalGen<S = WorkloadGen> {
    lengths: S,
    rng: Rng,
    rate: f64,
    hi_frac: f64,
    clock: f64,
}

impl<S: RequestSource> ArrivalGen<S> {
    /// `rate` must be > 0 (requests per simulated second).
    pub fn new(lengths: S, seed: u64, rate: f64) -> Self {
        assert!(rate > 0.0, "arrival rate must be positive");
        ArrivalGen { lengths, rng: Rng::new(seed), rate, hi_frac: 0.0, clock: 0.0 }
    }

    /// Mark roughly `frac` of requests as high priority.
    pub fn with_high_priority_fraction(mut self, frac: f64) -> Self {
        self.hi_frac = frac.clamp(0.0, 1.0);
        self
    }

    /// Next arrival; the exponential gap advances the internal clock.
    pub fn next_arrival(&mut self) -> Arrival {
        self.clock += self.rng.exp(1.0 / self.rate);
        let priority = if self.rng.bool(self.hi_frac) { 1 } else { 0 };
        Arrival { req: self.lengths.request(), at: self.clock, priority }
    }

    /// The next `n` arrivals in time order.
    pub fn take(&mut self, n: usize) -> Vec<Arrival> {
        (0..n).map(|_| self.next_arrival()).collect()
    }
}

/// Synthetic attention statistics for the Fig. 11 accuracy study: K rows
/// with planted heavy hitters (a few history tokens strongly aligned with
/// q) over a diffuse background — the structure sparse attention exploits,
/// with `hitters` controlling how concentrated the mass is.
pub struct AttnStatsGen {
    pub s: usize,
    pub d: usize,
    pub hitters: usize,
    pub hitter_gain: f32,
}

impl AttnStatsGen {
    pub fn paper_like(s: usize, d: usize) -> Self {
        AttnStatsGen { s, d, hitters: (s / 32).max(2), hitter_gain: 2.0 }
    }

    /// One head's (q, K, V) sample.
    pub fn sample(&self, rng: &mut Rng) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (s, d) = (self.s, self.d);
        let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let mut k: Vec<f32> = (0..s * d).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..s * d).map(|_| rng.normal_f32()).collect();
        for _ in 0..self.hitters {
            // zipf-distributed positions: recent tokens slightly favoured
            let t = s - 1 - rng.zipf(s, 1.1);
            for c in 0..d {
                k[t * d + c] += q[c] * self.hitter_gain;
            }
        }
        (q, k, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_profile_is_fixed() {
        let mut g = WorkloadGen::new(1, 512, 2048, LengthProfile::Fixed, 1024, 1024);
        for _ in 0..10 {
            let r = g.request();
            assert_eq!(r.prompt.len(), 1024);
            assert_eq!(r.max_new_tokens, 1024);
        }
    }

    #[test]
    fn chat_profile_varies_within_context() {
        let mut g = WorkloadGen::new(2, 512, 256, LengthProfile::Chat, 64, 64);
        let mut lens = std::collections::HashSet::new();
        for _ in 0..50 {
            let r = g.request();
            assert!(r.prompt.len() + r.max_new_tokens <= 256);
            assert!(!r.prompt.is_empty() && r.max_new_tokens >= 1);
            lens.insert(r.prompt.len());
        }
        assert!(lens.len() > 5, "chat lengths should vary: {lens:?}");
    }

    #[test]
    fn request_ids_unique_and_tokens_in_vocab() {
        let mut g = WorkloadGen::new(3, 100, 256, LengthProfile::Qa, 64, 32);
        let rs = g.batch(20);
        let ids: std::collections::HashSet<u64> = rs.iter().map(|r| r.id).collect();
        assert_eq!(ids.len(), 20);
        for r in &rs {
            assert!(r.prompt.iter().all(|&t| (0..100).contains(&t)));
        }
    }

    #[test]
    fn arrivals_are_ordered_and_poisson_ish() {
        let wg = WorkloadGen::new(1, 128, 256, LengthProfile::Qa, 32, 16);
        let mut ag = ArrivalGen::new(wg, 9, 100.0).with_high_priority_fraction(0.25);
        let arrivals = ag.take(200);
        let mut prev = 0.0;
        let mut hi = 0usize;
        for a in &arrivals {
            assert!(a.at > prev, "arrival times must strictly increase");
            prev = a.at;
            hi += a.priority as usize;
        }
        // mean gap ~ 1/rate = 10ms: the 200th arrival lands around 2s
        assert!((0.5..8.0).contains(&prev), "total span {prev}");
        assert!(hi > 10 && hi < 100, "high-priority count {hi}");
        // determinism
        let wg2 = WorkloadGen::new(1, 128, 256, LengthProfile::Qa, 32, 16);
        let mut ag2 = ArrivalGen::new(wg2, 9, 100.0).with_high_priority_fraction(0.25);
        let b = ag2.take(200);
        assert_eq!(arrivals[50].req.prompt, b[50].req.prompt);
        assert_eq!(arrivals[50].at, b[50].at);
    }

    #[test]
    fn prefix_workload_shares_group_aligned_stems() {
        // share_ratio 0.5 over 24-token prompts with 8-token groups:
        // stems are 16 tokens (rounded to whole groups)
        let mut g = PrefixWorkloadGen::new(11, 128, 24, 6, 0.5, 8, 0.7, 2);
        assert_eq!(g.stem_len(), 16);
        let reqs: Vec<Request> = (0..60).map(|_| g.request()).collect();
        let mut stem_counts = std::collections::HashMap::new();
        for r in &reqs {
            assert_eq!(r.prompt.len(), 24);
            assert_eq!(r.max_new_tokens, 6);
            *stem_counts.entry(r.prompt[..16].to_vec()).or_insert(0usize) += 1;
        }
        // with hit_rate 0.7 and 2 stems, the two pool stems must repeat
        // many times while misses stay unique
        let repeated: usize = stem_counts.values().filter(|&&c| c > 1).copied().sum();
        assert!(repeated > 20, "only {repeated}/60 requests shared a stem");
        assert!(stem_counts.values().filter(|&&c| c == 1).count() > 3);
        // determinism per seed
        let mut g2 = PrefixWorkloadGen::new(11, 128, 24, 6, 0.5, 8, 0.7, 2);
        let again: Vec<Request> = (0..60).map(|_| g2.request()).collect();
        for (a, b) in reqs.iter().zip(&again) {
            assert_eq!(a.prompt, b.prompt);
        }
        // share_ratio 0 degenerates to fully unique prompts
        let mut g0 = PrefixWorkloadGen::new(5, 128, 24, 6, 0.0, 8, 1.0, 2);
        assert_eq!(g0.stem_len(), 0);
        let a = g0.request();
        let b = g0.request();
        assert_ne!(a.prompt, b.prompt);
    }

    #[test]
    fn attn_stats_concentrate_mass() {
        // planted hitters must make top-k coverage far better than uniform
        let gen = AttnStatsGen::paper_like(128, 32);
        let mut rng = Rng::new(4);
        let mut cover = 0.0f64;
        for _ in 0..20 {
            let (q, k, _) = gen.sample(&mut rng);
            let scale = 1.0 / (32.0f32).sqrt();
            let logits: Vec<f32> = (0..128)
                .map(|t| crate::sparse::select::dot(&q, &k[t * 32..(t + 1) * 32]) * scale)
                .collect();
            let mask = vec![true; 128];
            let sm = crate::sparse::select::softmax_masked(&logits, &mask);
            let top = crate::sparse::select::topk_mask_heap(&sm, 16);
            cover += sm
                .iter()
                .zip(&top)
                .filter(|(_, &m)| m)
                .map(|(s, _)| *s as f64)
                .sum::<f64>();
        }
        cover /= 20.0;
        assert!(cover > 0.5, "top-16/128 coverage {cover} too low");
    }
}
