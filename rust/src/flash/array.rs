//! The flash array proper: page data + state machine + timing.
//!
//! Rules enforced (violations are errors — the FTL must respect them):
//! * reads/programs are whole-page operations;
//! * a page must be erased before it can be programmed (no overwrite);
//! * pages within a block must be programmed sequentially (NAND constraint);
//! * erase operates on whole blocks.
//!
//! Timing: a read occupies the page's read unit for tR, then its channel
//! for the transfer; a program occupies the channel first, then the unit
//! for tProg; an erase occupies the unit for tBERS.  Units and channels
//! are FIFO resources, so contention (the thing the FTL's striping
//! fights) emerges naturally.
//!
//! The read unit's granularity follows the configured data path
//! (`FlashSpec::path`): the legacy channel-placement path keeps the
//! pre-refactor die-granular pipelines (planes serialize on their die);
//! the die-aware path splits them per plane, modelling multi-plane read
//! pipelining — the parallelism the die-interleaved placement exists to
//! exploit.

use super::addr::{BlockAddr, Geometry, Ppa};
use crate::config::hw::{FlashPlacement, FlashReadSched, FlashSpec};
use crate::sim::{FifoResource, Time};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PageState {
    Erased,
    Programmed,
    /// programmed but superseded (awaiting GC)
    Invalid,
}

#[derive(Debug, Clone, Default)]
pub struct FlashCounters {
    pub page_reads: u64,
    pub page_programs: u64,
    pub block_erases: u64,
    pub bytes_read: u64,
    pub bytes_programmed: u64,
    /// reads that needed the in-die ECC soft retry (correctable)
    pub ecc_corrected: u64,
    /// escalating read-retry steps taken on uncorrectable reads
    pub read_retries: u64,
}

pub struct FlashArray {
    pub spec: FlashSpec,
    pub geo: Geometry,
    state: Vec<PageState>,
    data: Vec<Option<Box<[u8]>>>,
    /// next sequential programmable page per block
    write_ptr: Vec<u16>,
    /// tR/tProg/tBERS pipelines: one per die (legacy channel placement)
    /// or one per plane (die-aware placement)
    units: Vec<FifoResource>,
    channels: Vec<FifoResource>,
    pub counters: FlashCounters,
    /// per-device read-fault stream; `None` (faults off) takes exactly
    /// the pre-fault code path — no draws, no state, bit-identical
    fault: Option<crate::fault::FaultState>,
    /// blocks that hit a permanent read failure; the FTL drains these
    /// and retires them (relocate valid pages, never reuse the block)
    pending_retire: Vec<BlockAddr>,
}

impl FlashArray {
    pub fn new(spec: FlashSpec) -> Self {
        let geo = Geometry::of(&spec);
        let pages = geo.total_pages();
        let n_units = spec.channels
            * spec.dies_per_channel
            * match spec.path.placement {
                FlashPlacement::Channel => 1,
                FlashPlacement::Die => spec.planes_per_die,
            };
        FlashArray {
            spec,
            geo,
            state: vec![PageState::Erased; pages],
            data: (0..pages).map(|_| None).collect(),
            write_ptr: vec![0; geo.total_blocks()],
            units: (0..n_units).map(|_| FifoResource::new()).collect(),
            channels: (0..spec.channels).map(|_| FifoResource::new()).collect(),
            counters: FlashCounters::default(),
            fault: None,
            pending_retire: Vec::new(),
        }
    }

    /// Arm read-fault injection with this device's private stream.
    /// Never called when `cfg.rate == 0`, preserving bit-identity.
    pub fn install_fault(&mut self, cfg: &crate::fault::FaultConfig, dev: usize) {
        self.fault = Some(crate::fault::FaultState::new(cfg, dev, crate::fault::DOMAIN_FLASH));
    }

    /// Blocks flagged bad by permanent read failures since the last
    /// drain; the FTL retires them between command boundaries.
    pub fn take_pending_retire(&mut self) -> Vec<BlockAddr> {
        std::mem::take(&mut self.pending_retire)
    }

    fn xfer_time(&self, bytes: usize) -> Time {
        bytes as f64 / self.spec.channel_bw
    }

    /// The FIFO pipeline a page's array operation occupies.
    fn unit_of(&self, b: BlockAddr) -> usize {
        match self.spec.path.placement {
            FlashPlacement::Channel => self.geo.block_die_global(b),
            FlashPlacement::Die => self.geo.block_plane_global(b),
        }
    }

    /// Program the next sequential page of `block` with `data`
    /// (<= page size; short pages are padded).  Returns (ppa, completion).
    pub fn program_next(&mut self, block: BlockAddr, data: &[u8], at: Time) -> Result<(Ppa, Time)> {
        if block.0 >= self.geo.total_blocks() {
            bail!("program: block {} out of range", block.0);
        }
        if data.len() > self.spec.page_bytes {
            bail!("program: {} bytes > page size {}", data.len(), self.spec.page_bytes);
        }
        let wp = self.write_ptr[block.0] as usize;
        if wp >= self.geo.pages_per_block {
            bail!("program: block {} is full", block.0);
        }
        let ppa = self.geo.page_of(block, wp);
        debug_assert_eq!(self.state[ppa.0], PageState::Erased);
        self.write_ptr[block.0] += 1;

        let mut page = vec![0u8; self.spec.page_bytes];
        page[..data.len()].copy_from_slice(data);
        self.data[ppa.0] = Some(page.into_boxed_slice());
        self.state[ppa.0] = PageState::Programmed;
        self.counters.page_programs += 1;
        self.counters.bytes_programmed += self.spec.page_bytes as u64;

        // channel transfer, then die program
        let ch = self.geo.page_channel(ppa);
        let unit = self.unit_of(block);
        let xfer = self.xfer_time(self.spec.page_bytes);
        let (c0, ch_done) = self.channels[ch].schedule(at, xfer);
        let (u0, done) = self.units[unit].schedule(ch_done, self.spec.program_us * 1e-6);
        crate::obs::flash_channel_span(ch, "program_xfer", c0, ch_done);
        crate::obs::flash_unit_span(unit, "program", u0, done);
        Ok((ppa, done))
    }

    /// Read one page.  Returns (data, completion).
    pub fn read(&mut self, ppa: Ppa, at: Time) -> Result<(&[u8], Time)> {
        if ppa.0 >= self.geo.total_pages() {
            bail!("read: ppa {} out of range", ppa.0);
        }
        match self.state[ppa.0] {
            PageState::Programmed | PageState::Invalid => {}
            PageState::Erased => bail!("read of erased page {}", ppa.0),
        }
        let unit = self.unit_of(self.geo.block_of(ppa));
        let ch = self.geo.page_channel(ppa);
        let xfer = self.xfer_time(self.spec.page_bytes);
        // fault draw happens BEFORE scheduling so the stream position is
        // a pure function of per-device read order (thread-invariant);
        // retries inflate the unit occupancy (extra tR steps on the die)
        let mut read_s = self.spec.read_us * 1e-6;
        if let Some(f) = self.fault.as_mut() {
            if f.trips() {
                let t_r = self.spec.read_us * 1e-6;
                let sev = f.severity();
                if sev < 0.70 {
                    // correctable: one in-die ECC soft retry
                    read_s += crate::fault::ECC_EXTRA_TR * t_r;
                    self.counters.ecc_corrected += 1;
                } else {
                    // uncorrectable: escalating read-retry voltage sweep;
                    // severity >= 0.95 is a permanent failure — the sweep
                    // runs to its deepest step and the block is retired
                    let k: u64 = if sev < 0.95 {
                        1 + (((sev - 0.70) / 0.25) * 3.0).min(2.0) as u64
                    } else {
                        4
                    };
                    read_s += crate::fault::RETRY_STEP_TR * t_r * (k * (k + 1) / 2) as f64;
                    self.counters.read_retries += k;
                    crate::obs::dev_instant("flash_retry", at);
                    if sev >= 0.95 {
                        let b = self.geo.block_of(ppa);
                        if !self.pending_retire.contains(&b) {
                            self.pending_retire.push(b);
                            crate::obs::dev_instant("bad_block", at);
                        }
                    }
                }
            }
        }
        let (u0, unit_done) = self.units[unit].schedule(at, read_s);
        let (c0, done) = self.channels[ch].schedule(unit_done, xfer);
        crate::obs::flash_unit_span(unit, "read", u0, unit_done);
        crate::obs::flash_channel_span(ch, "read_xfer", c0, done);
        crate::obs::flash_read_flow(unit, unit_done, ch, c0);
        // FIFO wait vs service split for the attribution plane (values
        // already computed by the schedulers — purely observational)
        crate::obs::attr::flash_read_busy(
            (u0 - at) + (c0 - unit_done),
            (unit_done - u0) + (done - c0),
        );
        self.counters.page_reads += 1;
        self.counters.bytes_read += self.spec.page_bytes as u64;
        Ok((self.data[ppa.0].as_deref().unwrap(), done))
    }

    /// Read a batch of pages concurrently; returns the completion time of
    /// the slowest page (per-die/per-channel FIFO contention applies).
    /// This is the primitive whose latency the dual-step loading optimises.
    pub fn read_batch(&mut self, ppas: &[Ppa], at: Time) -> Result<Time> {
        let times = self.read_batch_times(ppas, at)?;
        Ok(times.iter().fold(at, |a, &t| a.max(t)))
    }

    /// Read a batch of pages under the configured issue scheduler,
    /// returning per-page completion times aligned with `ppas` (the
    /// read-compute pipelining consumes these incrementally).
    ///
    /// `Fifo` issues in caller order — exactly the legacy `read_batch`.
    /// `Interleave` buckets the batch by read unit (sorted by PPA within
    /// a bucket) and issues round-robin, one page per unit per round, so
    /// one hot die no longer convoys the whole fetch.  The order is a
    /// pure function of the PPAs — never of hash-map iteration order —
    /// so replays are deterministic.
    pub fn read_batch_times(&mut self, ppas: &[Ppa], at: Time) -> Result<Vec<Time>> {
        let mut times = vec![at; ppas.len()];
        match self.spec.path.sched {
            FlashReadSched::Fifo => {
                for (i, &p) in ppas.iter().enumerate() {
                    let (_, t) = self.read(p, at)?;
                    times[i] = t;
                }
            }
            FlashReadSched::Interleave => {
                let mut buckets: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
                for (i, &p) in ppas.iter().enumerate() {
                    if p.0 >= self.geo.total_pages() {
                        bail!("read: ppa {} out of range", p.0);
                    }
                    let u = self.unit_of(self.geo.block_of(p));
                    buckets.entry(u).or_default().push(i);
                }
                for idxs in buckets.values_mut() {
                    idxs.sort_by_key(|&i| (ppas[i].0, i));
                }
                let rounds = buckets.values().map(|v| v.len()).max().unwrap_or(0);
                for round in 0..rounds {
                    for idxs in buckets.values() {
                        if let Some(&i) = idxs.get(round) {
                            let (_, t) = self.read(ppas[i], at)?;
                            times[i] = t;
                        }
                    }
                }
            }
        }
        Ok(times)
    }

    /// Copy of page data without timing (for assembling after read_batch;
    /// the timing was charged by `read_batch`).
    pub fn page_data(&self, ppa: Ppa) -> Result<&[u8]> {
        match self.state[ppa.0] {
            PageState::Programmed | PageState::Invalid => {
                Ok(self.data[ppa.0].as_deref().unwrap())
            }
            PageState::Erased => bail!("page_data of erased page {}", ppa.0),
        }
    }

    /// Mark a page superseded (old mapping dropped by the FTL).
    pub fn invalidate(&mut self, ppa: Ppa) {
        if self.state[ppa.0] == PageState::Programmed {
            self.state[ppa.0] = PageState::Invalid;
        }
    }

    /// Erase a whole block; all pages return to Erased.
    pub fn erase(&mut self, block: BlockAddr, at: Time) -> Result<Time> {
        if block.0 >= self.geo.total_blocks() {
            bail!("erase: block {} out of range", block.0);
        }
        for i in 0..self.geo.pages_per_block {
            let ppa = self.geo.page_of(block, i);
            self.state[ppa.0] = PageState::Erased;
            self.data[ppa.0] = None;
        }
        self.write_ptr[block.0] = 0;
        self.counters.block_erases += 1;
        let unit = self.unit_of(block);
        let (u0, done) = self.units[unit].schedule(at, self.spec.erase_ms * 1e-3);
        crate::obs::flash_unit_span(unit, "erase", u0, done);
        Ok(done)
    }

    /// Valid (programmed, not invalidated) page indices within a block.
    pub fn valid_pages(&self, block: BlockAddr) -> Vec<usize> {
        (0..self.geo.pages_per_block)
            .filter(|&i| self.state[self.geo.page_of(block, i).0] == PageState::Programmed)
            .collect()
    }

    /// Number of pages programmed so far in the block (the write pointer).
    pub fn programmed_pages(&self, block: BlockAddr) -> usize {
        self.write_ptr[block.0] as usize
    }

    /// All work drained at...
    pub fn drained(&self) -> Time {
        self.units
            .iter()
            .map(|d| d.free_at())
            .chain(self.channels.iter().map(|c| c.free_at()))
            .fold(0.0, f64::max)
    }

    /// Total seconds the channel buses were busy (bandwidth accounting).
    pub fn channel_busy(&self) -> Time {
        self.channels.iter().map(|c| c.busy()).sum()
    }

    /// Total seconds the die pipelines were busy (summed over the read
    /// units, so a die's planes contribute their combined busy time).
    pub fn die_busy(&self) -> Time {
        self.units.iter().map(|d| d.busy()).sum()
    }

    /// Deepest backlog any die/plane pipeline ever saw — the convoy the
    /// interleaved read scheduler flattens.
    pub fn die_peak_depth(&self) -> usize {
        self.units.iter().map(|d| d.peak_depth()).max().unwrap_or(0)
    }

    pub fn reset_timing(&mut self) {
        self.units.iter_mut().for_each(|d| d.reset());
        self.channels.iter_mut().for_each(|c| c.reset());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> FlashArray {
        FlashArray::new(FlashSpec::tiny())
    }

    #[test]
    fn program_read_roundtrip() {
        let mut a = tiny();
        let payload = vec![7u8; 100];
        let (ppa, t1) = a.program_next(BlockAddr(0), &payload, 0.0).unwrap();
        assert!(t1 > 0.0);
        let (data, t2) = a.read(ppa, t1).unwrap();
        assert_eq!(&data[..100], &payload[..]);
        assert_eq!(data.len(), 512); // padded to page
        assert!(t2 > t1);
    }

    #[test]
    fn sequential_program_constraint() {
        let mut a = tiny();
        let (p0, _) = a.program_next(BlockAddr(2), &[1], 0.0).unwrap();
        let (p1, _) = a.program_next(BlockAddr(2), &[2], 0.0).unwrap();
        assert_eq!(a.geo.page_in_block(p0), 0);
        assert_eq!(a.geo.page_in_block(p1), 1);
        // fill the block, next program errors
        for _ in 2..16 {
            a.program_next(BlockAddr(2), &[0], 0.0).unwrap();
        }
        assert!(a.program_next(BlockAddr(2), &[0], 0.0).is_err());
    }

    #[test]
    fn erase_before_reprogram() {
        let mut a = tiny();
        for _ in 0..16 {
            a.program_next(BlockAddr(1), &[9], 0.0).unwrap();
        }
        assert!(a.program_next(BlockAddr(1), &[0], 0.0).is_err());
        let t = a.erase(BlockAddr(1), 1.0).unwrap();
        assert!(t >= 1.0 + 1e-3);
        let (ppa, _) = a.program_next(BlockAddr(1), &[5], t).unwrap();
        assert_eq!(a.geo.page_in_block(ppa), 0);
        assert_eq!(a.counters.block_erases, 1);
    }

    #[test]
    fn read_of_erased_page_errors() {
        let mut a = tiny();
        assert!(a.read(Ppa(0), 0.0).is_err());
    }

    #[test]
    fn batch_reads_parallelise_across_channels() {
        let mut a = tiny();
        // one page in a block on channel 0, one on channel 1
        let (p0, _) = a.program_next(BlockAddr(0), &[1], 0.0).unwrap();
        let (p1, _) = a.program_next(BlockAddr(1), &[2], 0.0).unwrap();
        a.reset_timing();
        let t_par = a.read_batch(&[p0, p1], 0.0).unwrap();

        let mut b = tiny();
        // both pages in the same block => same die+channel => serialised
        let (q0, _) = b.program_next(BlockAddr(0), &[1], 0.0).unwrap();
        let (q1, _) = b.program_next(BlockAddr(0), &[2], 0.0).unwrap();
        b.reset_timing();
        let t_ser = b.read_batch(&[q0, q1], 0.0).unwrap();
        assert!(t_par < t_ser, "parallel {t_par} vs serial {t_ser}");
    }

    #[test]
    fn invalidate_then_valid_pages() {
        let mut a = tiny();
        let (p0, _) = a.program_next(BlockAddr(0), &[1], 0.0).unwrap();
        let (_p1, _) = a.program_next(BlockAddr(0), &[2], 0.0).unwrap();
        a.invalidate(p0);
        assert_eq!(a.valid_pages(BlockAddr(0)), vec![1]);
        // invalid pages remain readable until erased (GC relocation needs this)
        assert!(a.read(p0, 0.0).is_ok());
    }

    #[test]
    fn counters_track_io() {
        let mut a = tiny();
        let (p, _) = a.program_next(BlockAddr(0), &[1], 0.0).unwrap();
        a.read(p, 0.0).unwrap();
        a.read(p, 0.0).unwrap();
        assert_eq!(a.counters.page_programs, 1);
        assert_eq!(a.counters.page_reads, 2);
        assert_eq!(a.counters.bytes_read, 2 * 512);
    }
}
