//! NAND flash array simulator: geometry, page/block state machine, and
//! contention-aware timing (per-die tR/tProg/tBERS, per-channel bus).
//!
//! This is the substrate the paper's evaluation rests on (§V-B builds the
//! same thing on NVMeVirt): it enforces the three flash facts the SparF /
//! FTL co-design exists to handle — page-granular access, erase-before-
//! program at block granularity, and parallelism across channels/dies.

pub mod addr;
pub mod array;

pub use addr::{BlockAddr, Geometry, Ppa};
pub use array::{FlashArray, FlashCounters};
