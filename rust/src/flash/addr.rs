//! Physical addressing: (channel, die, plane, block, page) <-> linear ids.

use crate::config::hw::FlashSpec;

/// Geometry helper bound to a `FlashSpec`.
#[derive(Debug, Clone, Copy)]
pub struct Geometry {
    pub channels: usize,
    pub dies_per_channel: usize,
    pub planes_per_die: usize,
    pub blocks_per_plane: usize,
    pub pages_per_block: usize,
}

/// Physical page address (linear id over the whole device).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Ppa(pub usize);

/// Physical block address (linear id).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockAddr(pub usize);

impl Geometry {
    pub fn of(spec: &FlashSpec) -> Self {
        Geometry {
            channels: spec.channels,
            dies_per_channel: spec.dies_per_channel,
            planes_per_die: spec.planes_per_die,
            blocks_per_plane: spec.blocks_per_plane,
            pages_per_block: spec.pages_per_block,
        }
    }

    pub fn total_blocks(&self) -> usize {
        self.channels * self.dies_per_channel * self.planes_per_die * self.blocks_per_plane
    }

    pub fn total_pages(&self) -> usize {
        self.total_blocks() * self.pages_per_block
    }

    /// Block id layout: channel-major so `block % channels` recovers the
    /// channel — blocks with consecutive ids round-robin across channels,
    /// which is what the FTL's striped allocation exploits.
    pub fn block_channel(&self, b: BlockAddr) -> usize {
        b.0 % self.channels
    }

    pub fn block_die(&self, b: BlockAddr) -> usize {
        (b.0 / self.channels) % self.dies_per_channel
    }

    /// Global die index (channel, die) for queueing.
    pub fn block_die_global(&self, b: BlockAddr) -> usize {
        self.block_channel(b) * self.dies_per_channel + self.block_die(b)
    }

    /// Plane within the die (the next id dimension after channel, die).
    pub fn block_plane(&self, b: BlockAddr) -> usize {
        (b.0 / (self.channels * self.dies_per_channel)) % self.planes_per_die
    }

    /// Global plane index (channel, die, plane) for the plane-split
    /// read pipelines of the die-aware data path.
    pub fn block_plane_global(&self, b: BlockAddr) -> usize {
        self.block_die_global(b) * self.planes_per_die + self.block_plane(b)
    }

    pub fn page_of(&self, b: BlockAddr, page_in_block: usize) -> Ppa {
        debug_assert!(page_in_block < self.pages_per_block);
        Ppa(b.0 * self.pages_per_block + page_in_block)
    }

    pub fn block_of(&self, p: Ppa) -> BlockAddr {
        BlockAddr(p.0 / self.pages_per_block)
    }

    pub fn page_in_block(&self, p: Ppa) -> usize {
        p.0 % self.pages_per_block
    }

    pub fn page_channel(&self, p: Ppa) -> usize {
        self.block_channel(self.block_of(p))
    }

    pub fn page_die_global(&self, p: Ppa) -> usize {
        self.block_die_global(self.block_of(p))
    }

    pub fn page_plane_global(&self, p: Ppa) -> usize {
        self.block_plane_global(self.block_of(p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_striping() {
        let g = Geometry::of(&FlashSpec::tiny());
        assert_eq!(g.total_blocks(), 2 * 8);
        assert_eq!(g.total_pages(), 16 * 16);
        // consecutive blocks alternate channels (striping)
        assert_eq!(g.block_channel(BlockAddr(0)), 0);
        assert_eq!(g.block_channel(BlockAddr(1)), 1);
        assert_eq!(g.block_channel(BlockAddr(2)), 0);
        let p = g.page_of(BlockAddr(3), 5);
        assert_eq!(g.block_of(p), BlockAddr(3));
        assert_eq!(g.page_in_block(p), 5);
        assert_eq!(g.page_channel(p), 1);
    }

    #[test]
    fn die_indexing_within_bounds() {
        let g = Geometry::of(&FlashSpec::instcsd());
        for b in [0, 7, 8, 63, 1000] {
            let d = g.block_die_global(BlockAddr(b));
            assert!(d < g.channels * g.dies_per_channel);
        }
    }
}
