//! Shared timing-plane pieces: step breakdowns, run summaries, memory
//! feasibility, and the effective-bandwidth calibrations.
//!
//! Calibration constants (documented per DESIGN.md §1; each reproduces a
//! measured inefficiency of the corresponding real system, and the values
//! are pinned by the paper's own reported ratios):
//! * `HOST_STAGE_EFF` — DeepSpeed-style layer-staged KV transfers reach
//!   ~1/3 of raw PCIe Gen4 x16 (pinned-buffer copies + per-layer sync; the
//!   paper's observation that InstI-dense at 11.2 GB/s internal ~matches
//!   DeepSpeed's host path implies ~10.7 GB/s effective).
//! * `SSD_FS_EFF` — FlexGen's SSD path through the filesystem reaches
//!   ~70% of the already-charged two-hop + per-IO cost (the 6.85x
//!   InstI/FlexGen ratio at bs=64 pins ~1.6 GB/s effective end-to-end).
//! * `SWAP_BW` — DeepSpeed's kernel-swap cliff: once the KV working set
//!   exceeds DRAM, the sequential full-scan access pattern defeats LRU
//!   (classic scan-thrash: every page faults), so ALL KV traffic moves at
//!   swap readahead speed (~350 MB/s; reproduces the 32.6x collapse).

use crate::config::system::SystemConfig;

pub const HOST_STAGE_EFF: f64 = 0.335;
pub const SSD_FS_EFF: f64 = 0.70;
pub const SWAP_BW: f64 = 350e6;

/// Per-decode-step component times (seconds, whole model, one step).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StepBreakdown {
    /// streaming model weights through the GPU compute units
    pub weight: f64,
    /// KV-cache access (the paper's "KV Cache Access")
    pub kv: f64,
    /// arithmetic not hidden behind the above (GPU + CSD kernels)
    pub compute: f64,
    /// qkv/output vector movement, command overheads
    pub comm: f64,
}

impl StepBreakdown {
    pub fn total(&self) -> f64 {
        self.weight + self.kv + self.compute + self.comm
    }

    pub fn scaled(&self, f: f64) -> StepBreakdown {
        StepBreakdown {
            weight: self.weight * f,
            kv: self.kv * f,
            compute: self.compute * f,
            comm: self.comm * f,
        }
    }

    pub fn add(&mut self, o: &StepBreakdown) {
        self.weight += o.weight;
        self.kv += o.kv;
        self.compute += o.compute;
        self.comm += o.comm;
    }
}

/// Outcome of one simulated offline batch run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub label: String,
    pub batch: usize,
    /// end-to-end generated tokens per second
    pub throughput: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
    /// aggregate over all decode steps
    pub decode_breakdown: StepBreakdown,
    /// total KV bytes at end of run
    pub kv_bytes: usize,
}

/// Aggregate decode time over the whole generation by sampling the
/// per-step model at the midpoint context (components are affine in s,
/// so midpoint x steps is exact for the total).
pub fn integrate_decode(
    cfg: &SystemConfig,
    step: impl Fn(usize) -> StepBreakdown,
) -> (f64, StepBreakdown) {
    let s_mid = cfg.input_len + cfg.output_len / 2;
    let per = step(s_mid);
    let total = per.scaled(cfg.output_len as f64);
    (total.total(), total)
}

/// GPU VRAM demand during prefill (bytes).  `kv_layers_buffered` models
/// how many layers of full-batch KV the system keeps resident before
/// offloading — the FlexGen OOM mechanism at bs=128 (§VI-C).
pub fn vram_demand(cfg: &SystemConfig, b: usize, kv_layers_buffered: usize) -> usize {
    let m = &cfg.model;
    let weights = m.weight_bytes();
    // activations: x + residual + ffn scratch for the prompt
    let act = 3 * b * cfg.input_len * m.d_model * crate::config::model::FP16_BYTES;
    let kv_buf = kv_layers_buffered * b * cfg.input_len * m.kv_bytes_per_token_layer();
    weights + act + kv_buf
}

pub fn check_vram(cfg: &SystemConfig, b: usize, kv_layers_buffered: usize) -> Result<(), String> {
    let need = vram_demand(cfg, b, kv_layers_buffered);
    if need > cfg.gpu.vram_bytes {
        return Err(format!(
            "OOM: prefill needs {:.1} GB VRAM ({} layers of KV buffered) > {:.0} GB",
            need as f64 / 1e9,
            kv_layers_buffered,
            cfg.gpu.vram_bytes as f64 / 1e9
        ));
    }
    Ok(())
}

/// Non-attention GPU work per decode step (QKV + O proj + FFN, all layers)
/// split into weight-streaming vs arithmetic for the breakdown figures.
pub fn gpu_nonattn_step(cfg: &SystemConfig, b: usize) -> (f64, f64) {
    let m = &cfg.model;
    let weight_t = m.weight_bytes() as f64 / cfg.gpu.mem_bw;
    let total: f64 =
        m.n_layers as f64 * crate::gpu::gpu_decode_nonattn_time(m, &cfg.gpu, b);
    let compute_t = (total - weight_t).max(total * 0.05);
    (weight_t, compute_t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::system::OffloadPolicy;

    #[test]
    fn vram_demand_reproduces_flexgen_oom_boundary() {
        let cfg = SystemConfig::paper_base(OffloadPolicy::SsdViaHost);
        // FlexGen's block schedule buffers ~10 layers of full-batch KV:
        // fits at bs=64, OOMs at bs=128 (Fig. 12)
        assert!(check_vram(&cfg, 64, 10).is_ok());
        assert!(check_vram(&cfg, 128, 10).is_err());
        // InstInfer's layer-wise pipeline buffers ~2: fine at bs=256
        assert!(check_vram(&cfg, 256, 2).is_ok());
    }

    #[test]
    fn breakdown_arithmetic() {
        let mut a = StepBreakdown { weight: 1.0, kv: 2.0, compute: 3.0, comm: 4.0 };
        assert_eq!(a.total(), 10.0);
        let b = a.scaled(2.0);
        assert_eq!(b.total(), 20.0);
        a.add(&b);
        assert_eq!(a.total(), 30.0);
    }

    #[test]
    fn integrate_uses_midpoint() {
        let cfg = SystemConfig::paper_base(OffloadPolicy::GpuOnly);
        let (t, bd) = integrate_decode(&cfg, |s| StepBreakdown {
            kv: s as f64 * 1e-6,
            ..Default::default()
        });
        let s_mid = (cfg.input_len + cfg.output_len / 2) as f64;
        assert!((t - cfg.output_len as f64 * s_mid * 1e-6).abs() < 1e-9);
        assert!(bd.kv > 0.0);
    }
}
