//! Paper-scale system models: the five inference systems of §VI-A on the
//! shared timing substrate.  One `SystemModel` per curve in Figs. 4/5/12-17.
//!
//! The models are analytic compositions of the same constants the
//! functional simulators use (flash geometry, link bandwidths, engine
//! FLOP/s); integration tests validate the analytic CSD step time against
//! the event-driven engine at micro scale.

pub mod insti;
pub mod stepmodel;

use crate::baselines;
use crate::config::system::{OffloadPolicy, SystemConfig};
pub use stepmodel::{RunSummary, StepBreakdown};

/// Dispatch a SystemConfig to its model and simulate a full offline batch
/// (prefill + `output_len` decode steps at batch `b`).
/// Returns Err with an OOM-style message when the configuration does not
/// fit (the paper plots these points as missing bars).
pub fn run(cfg: &SystemConfig, b: usize) -> Result<RunSummary, String> {
    match cfg.policy {
        OffloadPolicy::GpuOnly => baselines::gpu_only(cfg, b),
        OffloadPolicy::HostDram => baselines::deepspeed(cfg, b),
        OffloadPolicy::SsdViaHost => baselines::flexgen(cfg, b),
        OffloadPolicy::InStorage => insti::run(cfg, b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::SparsityParams;

    fn base(p: OffloadPolicy) -> SystemConfig {
        SystemConfig::paper_base(p)
    }

    #[test]
    fn headline_fig12_shape() {
        // Fig. 12 qualitative claims, 1 SSD/CSD:
        let ds16 = run(&base(OffloadPolicy::HostDram), 16).unwrap();
        let ds32 = run(&base(OffloadPolicy::HostDram), 32).unwrap();
        // DeepSpeed collapses at bs=32 (host DRAM exhausted -> swap)
        assert!(
            ds16.throughput > 5.0 * ds32.throughput,
            "ds16 {} vs ds32 {}", ds16.throughput, ds32.throughput
        );

        let fg64 = run(&base(OffloadPolicy::SsdViaHost), 64).unwrap();
        // FlexGen OOMs at bs=128 (prefill KV buffering exceeds VRAM)
        assert!(run(&base(OffloadPolicy::SsdViaHost), 128).is_err());

        let ii64 = run(&base(OffloadPolicy::InStorage), 64).unwrap();
        let ii256 = run(&base(OffloadPolicy::InStorage), 256).unwrap();
        // InstI-Dense ~6.85x FlexGen at bs=64 (paper: 6.85x)
        let r = ii64.throughput / fg64.throughput;
        assert!((4.0..10.0).contains(&r), "InstI/FlexGen at 64 = {r}");
        // InstI bs=256 roughly matches DeepSpeed's best (paper: +4.6%)
        let r2 = ii256.throughput / ds16.throughput;
        assert!((0.7..1.6).contains(&r2), "InstI256/DS16 = {r2}");

        // SparF ~2x over dense at bs=256 (paper: 2.08x)
        let sp = SparsityParams::paper_default(&base(OffloadPolicy::InStorage).model, 2048);
        let iisp = run(&base(OffloadPolicy::InStorage).with_sparsity(sp), 256).unwrap();
        let r3 = iisp.throughput / ii256.throughput;
        assert!((1.5..3.0).contains(&r3), "SparF/Dense = {r3}");
        // headline: InstI-SparF vs FlexGen best ~ 11.1x
        let fgbest = (4..=64)
            .filter_map(|b| run(&base(OffloadPolicy::SsdViaHost), b).ok())
            .map(|r| r.throughput)
            .fold(0.0, f64::max);
        let headline = iisp.throughput / fgbest;
        assert!((7.0..16.0).contains(&headline), "headline {headline}");
    }

    #[test]
    fn instinfer_scales_with_csds_baselines_do_not() {
        // Fig. 13/17a
        let i1 = run(&base(OffloadPolicy::InStorage), 256).unwrap();
        let i2 = run(&base(OffloadPolicy::InStorage).with_devices(2), 256).unwrap();
        let i8 = run(&base(OffloadPolicy::InStorage).with_devices(8), 256).unwrap();
        assert!(i2.throughput > 1.5 * i1.throughput);
        assert!(i8.throughput > 3.0 * i1.throughput);
        let f1 = run(&base(OffloadPolicy::SsdViaHost), 32).unwrap();
        let mut cfg2 = base(OffloadPolicy::SsdViaHost);
        cfg2.n_devices = 2;
        let f2 = run(&cfg2, 32).unwrap();
        assert!(f2.throughput < 1.15 * f1.throughput, "host path must not scale");
    }

    #[test]
    fn kv_access_dominates_breakdowns() {
        // Fig. 5 / 14: KV access is the top component for offloading systems
        let fg = run(&base(OffloadPolicy::SsdViaHost), 64).unwrap();
        assert!(fg.decode_breakdown.kv / fg.decode_breakdown.total() > 0.9);
        let ii = run(&base(OffloadPolicy::InStorage), 64).unwrap();
        let frac = ii.decode_breakdown.kv / ii.decode_breakdown.total();
        assert!(
            (0.5..0.95).contains(&frac),
            "InstI kv fraction {frac} (paper: 80.7%)"
        );
        assert!(frac < 0.97, "InstI must reduce the 98.9% FlexGen fraction");
    }
}
