//! InstInfer system model (InstI-Dense / InstI-SparF, 1..n CSDs).
//!
//! Decode step dataflow (paper §IV-D): GPU runs QKV/O-proj/FFN; q,k,v
//! vectors cross to the CSDs over P2P DMA; each CSD computes attention
//! for its share of heads against flash-resident KV; outputs return over
//! P2P.  GPU and CSD work overlap in mini-batches, so the step time is
//! max(gpu, csd) plus the un-overlappable transfer tails.
//!
//! The SparF data-movement model below reproduces Algorithm 1's dual-step
//! loading at page granularity, including the paper's measured overfetch
//! ("about half of the sparsity" retained during the first-step loading):
//! expected distinct pages follow the occupancy formula
//! `E[G] = G(1-(1-1/G)^x)`, with token selections clustered
//! (`TOKEN_CLUSTER` effective independent draws per selected token —
//! heavy hitters are contiguous passages, observed in the functional
//! engine's page counts as well).

use crate::config::model::{ModelShape, SparsityParams, FP16_BYTES};
use crate::config::system::SystemConfig;
use crate::csd::UnitBreakdown;
use crate::pcie::{self, Path};
use crate::systems::stepmodel::{
    check_vram, gpu_nonattn_step, integrate_decode, RunSummary, StepBreakdown,
};

/// Effective independent page draws per selected token (selection
/// clustering).  Calibrated so the dual-step loading retains the paper's
/// reported sparsity (§IV-C "about half of the sparsity … during the
/// first-step loading"; 2.08x SparF speedup at bs=256 pins the total).
pub const TOKEN_CLUSTER: f64 = 0.16;

/// E[distinct groups] when drawing `x` of `g` groups uniformly.
pub fn expected_groups(g: f64, x: f64) -> f64 {
    if g <= 0.0 {
        return 0.0;
    }
    g * (1.0 - (1.0 - 1.0 / g).powf(x))
}

/// Flash bytes one head must stream for one SparF step at context `s`
/// (dense = the full 2*s*d KV bytes).
pub fn sparf_head_flash_bytes(m: &ModelShape, sp: &SparsityParams, s: usize) -> f64 {
    let d = m.d_head as f64;
    let dense_k = s as f64 * d * FP16_BYTES as f64; // K bytes (V same)
    // step 2: embedding-indexed pages — r channels over d/m groups
    let eg = d / sp.m as f64;
    let f1 = expected_groups(eg, sp.r as f64) / eg;
    let step1 = f1 * dense_k;
    // step 8: token-indexed pages — k clustered tokens over s/n groups, K+V
    let tg = s as f64 / sp.n as f64;
    let f2 = expected_groups(tg, sp.k as f64 * TOKEN_CLUSTER) / tg;
    let step2 = f2 * 2.0 * dense_k;
    // the engine falls back to dense streaming whenever the sparse plan
    // would move more bytes (possible at very low compression, where the
    // dual-indexed K copy is pure overhead)
    (step1 + step2).min(2.0 * dense_k)
}

pub fn dense_head_flash_bytes(m: &ModelShape, s: usize) -> f64 {
    2.0 * s as f64 * m.d_head as f64 * FP16_BYTES as f64
}

/// Engine FLOPs one head costs per step.
fn head_flops(m: &ModelShape, sp: Option<&SparsityParams>, s: usize) -> f64 {
    let d = m.d_head as f64;
    match sp {
        None => 2.0 * 2.0 * s as f64 * d, // Logit + Attend over full context
        Some(sp) => {
            2.0 * s as f64 * sp.r as f64        // Logit-0 approx scores
                + 2.0 * 2.0 * sp.k as f64 * d   // exact Logit + Attend on k
        }
    }
}

/// Per-CSD attention time for its share of one layer's heads, plus the
/// unit breakdown (all heads, all layers, per step) for Fig. 16.
pub struct CsdStep {
    pub time: f64,
    pub units: UnitBreakdown,
    pub flash_bytes: f64,
}

pub fn csd_layer_step(cfg: &SystemConfig, b: usize, s: usize, heads: usize) -> CsdStep {
    let m = &cfg.model;
    let sp = cfg.sparsity.as_ref();
    let units_per_layer = (b * heads) as f64;

    let bytes_per_head = match sp {
        Some(sp) => sparf_head_flash_bytes(m, sp, s),
        None => dense_head_flash_bytes(m, s),
    };
    let flash_bytes = bytes_per_head * units_per_layer;
    let flops = head_flops(m, sp, s) * units_per_layer;

    let csd = &cfg.csd;
    // sustained internal rate is the aggregated channel bandwidth (the
    // paper's 11.2 GB/s) plus one array-read latency to first byte —
    // PROVIDED the data path keeps every die's tR pipeline busy.  The
    // derate below models the flash microarchitecture (cf. the DES
    // engine's die/plane FIFOs): channel placement leaves one die per
    // channel on the critical path, so the sustained rate collapses by
    // the die x plane parallelism; die placement with FIFO issue still
    // convoys about half the batch behind the hottest die.
    let path = csd.flash.path;
    let die_par = (csd.flash.dies_per_channel * csd.flash.planes_per_die).max(1) as f64;
    let place_f = match path.placement {
        crate::config::hw::FlashPlacement::Die => match path.sched {
            crate::config::hw::FlashReadSched::Interleave => 1.0,
            crate::config::hw::FlashReadSched::Fifo => {
                if die_par > 1.0 {
                    0.5
                } else {
                    1.0
                }
            }
        },
        crate::config::hw::FlashPlacement::Channel => 1.0 / die_par,
    };
    let t_flash = flash_bytes / (csd.flash.internal_bw() * place_f) + csd.flash.read_us * 1e-6;
    let t_kernel = flops / csd.engine_flops;
    let t_filter = flash_bytes / (csd.filter_bw_per_channel * csd.flash.channels as f64);
    let t_argtopk = match sp {
        Some(_) => units_per_layer * (m.d_head + s) as f64 / csd.argtopk_elems_per_s,
        None => 0.0,
    };

    // pipeline: the kernels and NFC filters consume pages as they stream,
    // but page-batch synchronisation exposes ~25% of their time as stalls
    // (calibrated against Fig. 14's 80.7% KV-access share; the functional
    // engine shows the same page-boundary bubbles).  Without read-compute
    // pipelining the kernels and filters sit fully behind the reads.
    const PIPE_STALL: f64 = 0.25;
    let stall = if path.pipeline { PIPE_STALL } else { 1.0 };
    let time = t_argtopk + t_flash + stall * (t_kernel + t_filter);

    let (logit0, logit, attend) = match sp {
        Some(sp) => {
            let f0 = 2.0 * s as f64 * sp.r as f64 * units_per_layer / csd.engine_flops;
            let fk = 2.0 * sp.k as f64 * m.d_head as f64 * units_per_layer / csd.engine_flops;
            (f0, fk, fk)
        }
        None => {
            let fk = 2.0 * s as f64 * m.d_head as f64 * units_per_layer / csd.engine_flops;
            (0.0, fk, fk)
        }
    };
    CsdStep {
        time,
        units: UnitBreakdown {
            argtopk: t_argtopk,
            flash_read: t_flash,
            // the analytic OPT-13B plane models the flash-only dataflow
            dram_hit: 0.0,
            nfc_filter: t_filter,
            logit0,
            logit,
            attend,
            writeback: 0.0,
            // the all-reduce tail is accounted in the step's comm term
            pcie_xfer: 0.0,
            gpu_merge: 0.0,
        },
        flash_bytes,
    }
}

/// Full InstInfer run at batch `b`.
pub fn run(cfg: &SystemConfig, b: usize) -> Result<RunSummary, String> {
    let m = &cfg.model;
    // layer-wise pipelined prefill shipping: only ~2 layers of KV buffered
    check_vram(cfg, b, 2)?;
    let n = cfg.n_devices.max(1);
    // context striping keeps every head on every CSD over 1/n of the
    // tokens; head policies give each CSD its head subset over all tokens
    let context_stripe = cfg.shard_policy == crate::shard::ShardPolicy::Context && n > 1;
    let heads_per_csd = if context_stripe { m.n_heads } else { m.n_heads.div_ceil(n) };

    // capacity: each CSD stores its stripe's K (twice) + V
    let stripe_frac = if context_stripe {
        1.0 / n as f64
    } else {
        heads_per_csd as f64 / m.n_heads as f64
    };
    let kv_per_csd = cfg.kv_bytes_total(b) as f64 * 1.5 * stripe_frac;
    if kv_per_csd > cfg.csd.kv_capacity_bytes as f64 {
        return Err(format!(
            "CSD capacity: {:.0} GB KV per device > {:.0} GB flash",
            kv_per_csd / 1e9,
            cfg.csd.kv_capacity_bytes as f64 / 1e9
        ));
    }

    // ---- prefill: GPU compute, KV shipped layer-wise over P2P, overlapped
    let prefill_compute = m.n_layers as f64
        * crate::gpu::gpu_prefill_layer_time(m, &cfg.gpu, b, cfg.input_len);
    let kv_bytes = m.kv_bytes(b, cfg.input_len) as f64 * 1.5; // K stored twice
    let ship_path = if cfg.p2p_dma { Path::P2p } else { Path::SsdGpuViaHost };
    let ios = (kv_bytes / (128.0 * 1024.0)).ceil() as u64;
    let ship = pcie::transfer_time(&cfg.pcie, ship_path, kv_bytes / n as f64, ios / n as u64)
        .max(kv_bytes / n as f64 / cfg.csd.flash.internal_bw());
    let prefill = if cfg.layerwise_pipeline {
        prefill_compute.max(ship) + ship / m.n_layers as f64
    } else {
        prefill_compute + ship
    };

    // ---- decode: GPU part overlaps CSD part (mini-batch pipelining)
    let step = move |s: usize| {
        let (w, c) = gpu_nonattn_step(cfg, b);
        let gpu_t = w + c;
        let s_eff = if context_stripe { s.div_ceil(n) } else { s };
        let per_csd = csd_layer_step(cfg, b, s_eff, heads_per_csd);
        let csd_t = per_csd.time * m.n_layers as f64;
        let csd_flash_t = (per_csd.units.flash_read) * m.n_layers as f64;
        let csd_other_t = (csd_t - csd_flash_t).max(0.0);
        // qkv + attention-output vectors over P2P, per layer.  Head
        // policies move q,k,v out + attn in once; context striping
        // broadcasts q to every stripe and returns a partial (output +
        // LSE stats) from each — the all-reduce's extra traffic.
        let (vec_elems, ret_elems) = if context_stripe {
            // q broadcast to every stripe + k,v to the owner; every
            // stripe returns a partial (output + LSE stats)
            let ret = n * (m.d_model + 2 * m.n_heads);
            ((n + 2) * m.d_model + ret, ret)
        } else {
            // q,k,v out once + the attention output back
            (4 * m.d_model, m.d_model)
        };
        let vec_bytes = (b * m.n_layers * vec_elems * FP16_BYTES) as f64;
        let mut comm = pcie::transfer_time(
            &cfg.pcie,
            if cfg.p2p_dma { Path::P2p } else { Path::SsdGpuViaHost },
            vec_bytes / n as f64,
            (2 * m.n_layers) as u64,
        );
        if cfg.p2p_dma {
            // only the device->GPU return leg converges on the GPU's
            // ingress; the concurrent streams fair-share it (cf.
            // pcie::fair_share_finish in the DES plane)
            let ret_bytes = (b * m.n_layers * ret_elems * FP16_BYTES) as f64;
            comm = comm.max(ret_bytes / cfg.pcie.gpu_p2p_ingress_bw);
        }
        // wall time: GPU and CSD overlap; comm + pipeline bubble don't.
        // Attribute components proportionally so the breakdown keeps the
        // paper's percentage semantics while summing to wall time.
        let bubble = 0.02 * gpu_t.min(csd_t); // pipeline fill/drain
        let wall = gpu_t.max(csd_t) + comm + bubble;
        let raw = (gpu_t + csd_t + comm).max(1e-30);
        let f = wall / raw;
        StepBreakdown {
            weight: w * f,
            kv: csd_flash_t * f,
            compute: (c + csd_other_t) * f,
            comm: comm * f,
        }
    };
    let (decode_s, bd) = integrate_decode(cfg, step);
    let total = prefill + decode_s;
    Ok(RunSummary {
        label: cfg.label(),
        batch: b,
        throughput: (b * cfg.output_len) as f64 / total,
        prefill_s: prefill,
        decode_s,
        decode_breakdown: bd,
        kv_bytes: cfg.kv_bytes_total(b),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::system::OffloadPolicy;

    #[test]
    fn context_stripe_scales_but_pays_the_allreduce() {
        // the context policy keeps every head on every CSD over 1/n of
        // the tokens: same per-device flash traffic as head striping,
        // but the all-reduce ships a partial from every stripe (plus a
        // q broadcast), so it lands at or just below head striping
        let base = SystemConfig::paper_base(OffloadPolicy::InStorage);
        let head = run(&base.clone().with_devices(4), 256).unwrap();
        let ctx = run(
            &base.with_devices(4).with_shard_policy(crate::shard::ShardPolicy::Context),
            256,
        )
        .unwrap();
        assert!(ctx.throughput > 0.0);
        assert!(
            ctx.throughput <= head.throughput,
            "context {} must not beat head striping {} (extra comm)",
            ctx.throughput,
            head.throughput
        );
        assert!(
            ctx.throughput > 0.5 * head.throughput,
            "context {} collapsed vs head {}",
            ctx.throughput,
            head.throughput
        );
    }

    #[test]
    fn expected_groups_limits() {
        assert!((expected_groups(64.0, 1.0) - 1.0).abs() < 1e-9);
        assert!(expected_groups(64.0, 10000.0) > 63.9);
        assert!(expected_groups(64.0, 32.0) < 32.0); // collisions only reduce
    }

    #[test]
    fn sparf_bytes_below_dense_and_monotone_in_budget() {
        let m = ModelShape::opt_13b();
        let s = 2048;
        let dense = dense_head_flash_bytes(&m, s);
        let mut last = dense * 1.001; // c=2 may cap at the dense fallback
        for c in [2usize, 4, 8, 16, 32] {
            let sp = SparsityParams::with_compression(&m, s, c);
            let b = sparf_head_flash_bytes(&m, &sp, s);
            assert!(b < last, "c={c}: {b} !< {last}");
            last = b;
        }
        // at the paper's 1/8 point, roughly half the dense traffic
        let sp = SparsityParams::paper_default(&m, s);
        let frac = sparf_head_flash_bytes(&m, &sp, s) / dense;
        assert!((0.25..0.7).contains(&frac), "frac {frac}");
    }

    #[test]
    fn fig16_shape_logit0_only_in_sparf() {
        let cfg = SystemConfig::paper_base(OffloadPolicy::InStorage);
        let dense = csd_layer_step(&cfg, 64, 1536, cfg.model.n_heads);
        let scfg = cfg.clone().with_default_sparsity();
        let sparse = csd_layer_step(&scfg, 64, 1536, scfg.model.n_heads);
        assert_eq!(dense.units.logit0, 0.0);
        assert!(sparse.units.logit0 > 0.0);
        assert!(sparse.flash_bytes < dense.flash_bytes);
        assert!(sparse.units.argtopk > 0.0 && dense.units.argtopk == 0.0);
    }

    #[test]
    fn capacity_gate_on_huge_batches() {
        // a single 68 GB CSD cannot hold bs=2048 x 2K-ctx KV (1.6 TB x1.5)
        let cfg = SystemConfig::paper_base(OffloadPolicy::InStorage);
        assert!(run(&cfg, 2048).is_err());
        assert!(run(&cfg, 32).is_ok());
    }

    #[test]
    fn csd_bound_decode_dominated_by_flash() {
        let cfg = SystemConfig::paper_base(OffloadPolicy::InStorage);
        let st = csd_layer_step(&cfg, 256, 1536, cfg.model.n_heads);
        assert!(st.units.flash_read > st.units.logit + st.units.attend);
    }

    #[test]
    fn flash_path_derates_order_legacy_below_tuned() {
        use crate::config::hw::{FlashPathConfig, FlashPlacement, FlashReadSched};
        // zynq7045's default IS the tuned path (the paper's engine), so
        // the calibrated numbers above are the tuned numbers
        let tuned = SystemConfig::paper_base(OffloadPolicy::InStorage);
        assert_eq!(tuned.csd.flash.path, FlashPathConfig::tuned());
        let mut legacy = tuned.clone();
        legacy.csd.flash.path = FlashPathConfig::legacy();
        let mut mid = tuned.clone();
        mid.csd.flash.path = FlashPathConfig {
            placement: FlashPlacement::Die,
            sched: FlashReadSched::Fifo,
            pipeline: true,
        };
        let tt = csd_layer_step(&tuned, 64, 1536, tuned.model.n_heads).time;
        let mt = csd_layer_step(&mid, 64, 1536, mid.model.n_heads).time;
        let lt = csd_layer_step(&legacy, 64, 1536, legacy.model.n_heads).time;
        assert!(tt < mt && mt < lt, "tuned {tt} !< die/fifo {mt} !< legacy {lt}");
        // the channel placement's collapse scales with die x plane
        // parallelism (4 dies x 2 planes on the paper spec)
        assert!(lt > 4.0 * tt, "legacy {lt} should be >4x tuned {tt}");
    }
}
