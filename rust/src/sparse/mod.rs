//! Rust-native sparse attention family — the arithmetic the InstCSD engine
//! executes, mirroring `python/compile/kernels/ref.py` function-for-function
//! (same masks, same alpha blend, same stable-argsort top-k tie-breaking).
//!
//! Used by:
//! * [`crate::csd::engine`] — the functional in-storage attention engine
//!   (operates on f16-decoded page data fetched through the FTL);
//! * the Fig. 11 accuracy study (dense vs SparQ/SparF/H2O/local);
//! * integration tests cross-checking rust vs the PJRT artifacts.

pub mod attention;
pub mod select;

pub use attention::{
    dense_attention, h2o_attention, local_attention, sparf_attention, sparq_attention,
    v_mean, SparfOutput,
};
pub use select::{softmax_masked, topk_mask};
