//! Selection primitives shared by the sparse attention family: stable top-k
//! masks (argtopk unit) and masked softmax — semantics identical to
//! `ref.topk_mask` / `ref.masked_softmax` on the python side.

pub const NEG_INF: f32 = -1e30;

/// Boolean mask of the `k` largest entries (ties -> lower index first),
/// matching a stable descending argsort — the same tie-break the jax
/// kernels use, so rust and pallas select identical elements.
pub fn topk_mask(xs: &[f32], k: usize) -> Vec<bool> {
    let k = k.min(xs.len());
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    // sort_by is stable: equal keys keep ascending index order
    idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal));
    let mut mask = vec![false; xs.len()];
    for &i in &idx[..k] {
        mask[i] = true;
    }
    mask
}

/// Partial top-k mask without the full sort: O(n log k) via a bounded
/// binary heap — the hot-path variant used by the CSD engine (profiled
/// faster than full sort for k << n).  Identical selection to `topk_mask`.
pub fn topk_mask_heap(xs: &[f32], k: usize) -> Vec<bool> {
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;

    #[derive(PartialEq)]
    struct Entry(f32, usize); // min-heap by (value, reversed index)

    impl Eq for Entry {}
    impl PartialOrd for Entry {
        fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for Entry {
        fn cmp(&self, other: &Self) -> Ordering {
            // smaller value = "greater" for a min-heap via Reverse below;
            // tie: HIGHER index is weaker (stable sort keeps lower index)
            self.0
                .partial_cmp(&other.0)
                .unwrap_or(Ordering::Equal)
                .then(other.1.cmp(&self.1))
        }
    }

    let k = k.min(xs.len());
    let mut mask = vec![false; xs.len()];
    if k == 0 {
        return mask;
    }
    let mut heap: BinaryHeap<std::cmp::Reverse<Entry>> = BinaryHeap::with_capacity(k + 1);
    for (i, &x) in xs.iter().enumerate() {
        heap.push(std::cmp::Reverse(Entry(x, i)));
        if heap.len() > k {
            heap.pop();
        }
    }
    for std::cmp::Reverse(Entry(_, i)) in heap {
        mask[i] = true;
    }
    mask
}

/// Numerically-stable masked softmax; masked-out entries get exactly 0.
pub fn softmax_masked(logits: &[f32], mask: &[bool]) -> Vec<f32> {
    debug_assert_eq!(logits.len(), mask.len());
    let mut mx = NEG_INF;
    for (l, &m) in logits.iter().zip(mask) {
        if m && *l > mx {
            mx = *l;
        }
    }
    let mut out = vec![0.0f32; logits.len()];
    let mut z = 0.0f32;
    for i in 0..logits.len() {
        if mask[i] {
            let e = (logits[i] - mx).exp();
            out[i] = e;
            z += e;
        }
    }
    let inv = 1.0 / z.max(1e-30);
    for o in &mut out {
        *o *= inv;
    }
    out
}

/// O(n) top-k mask via quickselect partition (`select_nth_unstable_by`)
/// on a total order (value desc, index asc) — the same selection as the
/// stable sort, ~4x faster at k ~ n/8 (§Perf iteration 1).  This is the
/// hot-path selector; `topk_mask`/`topk_mask_heap` remain as oracles.
pub fn topk_mask_select(xs: &[f32], k: usize) -> Vec<bool> {
    let k = k.min(xs.len());
    let mut mask = vec![false; xs.len()];
    if k == 0 {
        return mask;
    }
    if k == xs.len() {
        mask.iter_mut().for_each(|m| *m = true);
        return mask;
    }
    let mut idx: Vec<u32> = (0..xs.len() as u32).collect();
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        xs[b as usize]
            .partial_cmp(&xs[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    for &i in &idx[..k] {
        mask[i as usize] = true;
    }
    mask
}

/// dot(a, b) — 4-way unrolled for autovectorization (§Perf iteration 2);
/// kept as a named helper so the engine's FLOP accounting references one
/// place.  Summation order differs from the naive loop by design; all
/// comparisons against jax use tolerances.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n4 = a.len() / 4 * 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let mut i = 0;
    while i < n4 {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while i < a.len() {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn topk_basic() {
        let m = topk_mask(&[1.0, 5.0, 3.0, 5.0], 2);
        // ties broken by lower index: both 5.0s selected
        assert_eq!(m, vec![false, true, false, true]);
        let m = topk_mask(&[2.0, 2.0, 2.0], 2);
        assert_eq!(m, vec![true, true, false]);
    }

    #[test]
    fn topk_k_clamped() {
        assert_eq!(topk_mask(&[1.0], 5), vec![true]);
        assert_eq!(topk_mask(&[], 3), Vec::<bool>::new());
    }

    #[test]
    fn select_matches_sort_property() {
        check(
            "topk_select==topk_sort",
            200,
            |r| {
                let n = r.range(1, 200);
                let k = r.range(0, n);
                let xs: Vec<f32> = (0..n)
                    .map(|_| if r.bool(0.2) { 1.0 } else { r.normal_f32() })
                    .collect();
                (xs, k)
            },
            |(xs, k)| {
                let a = topk_mask(xs, *k);
                let b = topk_mask_select(xs, *k);
                if a == b {
                    Ok(())
                } else {
                    Err(format!("sort={a:?} select={b:?}"))
                }
            },
        );
    }

    #[test]
    fn heap_matches_sort_property() {
        check(
            "topk_heap==topk_sort",
            200,
            |r| {
                let n = r.range(1, 200);
                let k = r.range(0, n);
                let xs: Vec<f32> = (0..n)
                    .map(|_| if r.bool(0.2) { 1.0 } else { r.normal_f32() })
                    .collect();
                (xs, k)
            },
            |(xs, k)| {
                let a = topk_mask(xs, *k);
                let b = topk_mask_heap(xs, *k);
                if a == b {
                    Ok(())
                } else {
                    Err(format!("sort={a:?} heap={b:?}"))
                }
            },
        );
    }

    #[test]
    fn softmax_masked_properties() {
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let n = rng.range(2, 64);
            let logits: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 5.0).collect();
            let mask: Vec<bool> = (0..n).map(|_| rng.bool(0.6)).collect();
            if !mask.iter().any(|&m| m) {
                continue;
            }
            let s = softmax_masked(&logits, &mask);
            let sum: f32 = s.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "sum={sum}");
            for i in 0..n {
                if !mask[i] {
                    assert_eq!(s[i], 0.0);
                }
                assert!(s[i] >= 0.0);
            }
        }
    }

    #[test]
    fn softmax_extreme_logits_stable() {
        let s = softmax_masked(&[1e4, -1e4], &[true, true]);
        assert!((s[0] - 1.0).abs() < 1e-6 && s[1] >= 0.0);
    }
}
