//! Single-head decode attention variants over (S, d) caches — arithmetic
//! mirrors python/compile/kernels/ref.py exactly (see module docs there).
//!
//! All functions take the padded cache plus a `length` of valid rows.
//! Row-major layout: `K[t * d + c]` is token t, channel c.

use super::select::{dot, softmax_masked, topk_mask_heap, topk_mask_select, NEG_INF};
use crate::config::model::SparsityParams;

/// Mean of the valid V rows (the compensation vector v̄).
pub fn v_mean(v: &[f32], d: usize, length: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; d];
    for t in 0..length {
        for c in 0..d {
            out[c] += v[t * d + c];
        }
    }
    let inv = 1.0 / (length.max(1)) as f32;
    for o in &mut out {
        *o *= inv;
    }
    out
}

/// Dense decode attention: softmax(K q / sqrt(d)) V over the first
/// `length` rows.
pub fn dense_attention(q: &[f32], k: &[f32], v: &[f32], length: usize) -> Vec<f32> {
    let d = q.len();
    let s_rows = k.len() / d;
    let scale = 1.0 / (d as f32).sqrt();
    let mut logits = vec![NEG_INF; s_rows];
    for t in 0..length {
        logits[t] = dot(q, &k[t * d..(t + 1) * d]) * scale;
    }
    let mask: Vec<bool> = (0..s_rows).map(|t| t < length).collect();
    let s = softmax_masked(&logits, &mask);
    weighted_sum(&s, v, d, length)
}

fn weighted_sum(w: &[f32], v: &[f32], d: usize, length: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; d];
    for t in 0..length {
        let wt = w[t];
        if wt == 0.0 {
            continue;
        }
        let row = &v[t * d..(t + 1) * d];
        for c in 0..d {
            out[c] += wt * row[c];
        }
    }
    out
}

/// Everything a SparF/SparQ step produces: the output vector plus the
/// data-movement facts the FTL/bandwidth model charges for.
#[derive(Debug, Clone)]
pub struct SparfOutput {
    pub out: Vec<f32>,
    /// exact channels kept by the filter (== r)
    pub emb_mask: Vec<bool>,
    /// exact tokens kept by the filter
    pub tok_mask: Vec<bool>,
    /// embedding-indexed pages fetched in step 2 (group-OR of emb_mask)
    pub emb_groups: Vec<bool>,
    /// token-indexed pages fetched in step 8 (group-OR of tok_mask)
    pub tok_groups: Vec<bool>,
    /// covered approximate-score mass (step 7)
    pub alpha: f32,
}

/// SparQ attention [Ribar et al.]: the functional core of Algorithm 1
/// (SparF adds the group/page structure on top).
pub fn sparq_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    vbar: &[f32],
    length: usize,
    r: usize,
    kk: usize,
) -> SparfOutput {
    sparf_attention(
        q,
        k,
        v,
        vbar,
        length,
        &SparsityParams { r, k: kk, m: 1, n: 1 },
    )
}

/// SparF attention — Algorithm 1.  Group sizes (m, n) shape `emb_groups` /
/// `tok_groups` (what moves over the flash channels); the arithmetic uses
/// the exact post-filter masks, identical to SparQ.
pub fn sparf_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    vbar: &[f32],
    length: usize,
    sp: &SparsityParams,
) -> SparfOutput {
    let d = q.len();
    let s_rows = k.len() / d;
    debug_assert_eq!(k.len(), v.len());
    debug_assert_eq!(vbar.len(), d);
    debug_assert_eq!(d % sp.m, 0, "d must be a multiple of the embedding group");
    debug_assert_eq!(s_rows % sp.n, 0, "S must be a multiple of the token group");

    // ---- step 1: top-r channels of |q| (argtopk unit)
    let absq: Vec<f32> = q.iter().map(|x| x.abs()).collect();
    let emb_mask = topk_mask_select(&absq, sp.r);
    let emb_groups = group_or(&emb_mask, sp.m);

    // ---- step 4: approximate scores with temperature correction
    let l1_all: f32 = absq.iter().sum();
    let l1_kept: f32 = absq
        .iter()
        .zip(&emb_mask)
        .filter(|(_, &m)| m)
        .map(|(a, _)| a)
        .sum();
    let scale_hat = (d as f32 * l1_kept / l1_all.max(1e-30)).sqrt().max(1e-30);
    let valid: Vec<bool> = (0..s_rows).map(|t| t < length).collect();
    // gather the r selected channels once (§Perf iteration 3: ~r/d fewer
    // multiplies than the masked full-width loop — the same win the NFC
    // filter gives the hardware kernel)
    let sel: Vec<(usize, f32)> =
        (0..d).filter(|&c| emb_mask[c]).map(|c| (c, q[c])).collect();
    let inv_scale_hat = 1.0 / scale_hat;
    let mut logits_hat = vec![NEG_INF; s_rows];
    for t in 0..length {
        let row = &k[t * d..(t + 1) * d];
        let mut acc = 0.0f32;
        for &(c, qc) in &sel {
            acc += qc * row[c];
        }
        logits_hat[t] = acc * inv_scale_hat;
    }
    let s_hat = softmax_masked(&logits_hat, &valid);

    // ---- steps 5-6: top-k tokens of the approximate scores
    let pool: Vec<f32> = s_hat
        .iter()
        .zip(&valid)
        .map(|(&s, &m)| if m { s } else { -1.0 })
        .collect();
    let mut tok_mask = topk_mask_select(&pool, sp.k);
    for t in 0..s_rows {
        tok_mask[t] &= valid[t];
    }
    let tok_groups = group_or(&tok_mask, sp.n);

    // ---- step 7: covered mass
    let alpha: f32 = s_hat
        .iter()
        .zip(&tok_mask)
        .filter(|(_, &m)| m)
        .map(|(s, _)| s)
        .sum::<f32>()
        .clamp(0.0, 1.0);

    // ---- step 10: exact scores over kept tokens
    let scale = 1.0 / (d as f32).sqrt();
    let mut logits = vec![NEG_INF; s_rows];
    for t in 0..s_rows {
        if tok_mask[t] {
            logits[t] = dot(q, &k[t * d..(t + 1) * d]) * scale;
        }
    }
    let s = softmax_masked(&logits, &tok_mask);

    // ---- step 11: blend with v̄
    let mut out = weighted_sum(&s, v, d, s_rows.min(length));
    for c in 0..d {
        out[c] = alpha * out[c] + (1.0 - alpha) * vbar[c];
    }

    SparfOutput { out, emb_mask, tok_mask, emb_groups, tok_groups, alpha }
}

fn group_or(mask: &[bool], g: usize) -> Vec<bool> {
    mask.chunks(g).map(|c| c.iter().any(|&b| b)).collect()
}

/// H2O-style heavy hitters: `window` recent tokens + heaviest accumulated
/// historical scores, `k` total.
pub fn h2o_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    acc_scores: &[f32],
    length: usize,
    kk: usize,
    window: usize,
) -> Vec<f32> {
    let d = q.len();
    let s_rows = k.len() / d;
    let recent_from = length.saturating_sub(window);
    let mut keep: Vec<bool> = (0..s_rows).map(|t| t >= recent_from && t < length).collect();
    let n_heavy = kk.saturating_sub(window);
    if n_heavy > 0 {
        let pool: Vec<f32> = (0..s_rows)
            .map(|t| if t < recent_from { acc_scores[t] } else { -1.0 })
            .collect();
        let heavy = topk_mask_heap(&pool, n_heavy);
        for t in 0..recent_from {
            keep[t] |= heavy[t];
        }
    }
    let scale = 1.0 / (d as f32).sqrt();
    let mut logits = vec![NEG_INF; s_rows];
    for t in 0..s_rows {
        if keep[t] {
            logits[t] = dot(q, &k[t * d..(t + 1) * d]) * scale;
        }
    }
    let s = softmax_masked(&logits, &keep);
    weighted_sum(&s, v, d, length)
}

/// Sliding-window attention over the `k` most recent tokens.
pub fn local_attention(q: &[f32], k: &[f32], v: &[f32], length: usize, kk: usize) -> Vec<f32> {
    let d = q.len();
    let s_rows = k.len() / d;
    let from = length.saturating_sub(kk);
    let keep: Vec<bool> = (0..s_rows).map(|t| t >= from && t < length).collect();
    let scale = 1.0 / (d as f32).sqrt();
    let mut logits = vec![NEG_INF; s_rows];
    for t in from..length {
        logits[t] = dot(q, &k[t * d..(t + 1) * d]) * scale;
    }
    let s = softmax_masked(&logits, &keep);
    weighted_sum(&s, v, d, length)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mk(rng: &mut Rng, s: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let k: Vec<f32> = (0..s * d).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..s * d).map(|_| rng.normal_f32()).collect();
        (q, k, v)
    }

    #[test]
    fn dense_weights_sum_to_one_effectively() {
        let mut rng = Rng::new(1);
        let (q, k, v) = mk(&mut rng, 32, 16);
        // with V = all-ones, output must be all-ones
        let ones = vec![1.0f32; 32 * 16];
        let out = dense_attention(&q, &k, &ones, 20);
        for o in out {
            assert!((o - 1.0).abs() < 1e-5);
        }
        let _ = v;
    }

    #[test]
    fn sparf_full_budget_equals_dense() {
        let mut rng = Rng::new(2);
        let (q, k, v) = mk(&mut rng, 32, 16);
        let vbar = v_mean(&v, 16, 32);
        let sp = SparsityParams { r: 16, k: 32, m: 4, n: 8 };
        let o = sparf_attention(&q, &k, &v, &vbar, 32, &sp);
        let d = dense_attention(&q, &k, &v, 32);
        assert!((o.alpha - 1.0).abs() < 1e-5, "alpha={}", o.alpha);
        for (a, b) in o.out.iter().zip(&d) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn sparf_group_masks_cover_token_masks() {
        let mut rng = Rng::new(3);
        let (q, k, v) = mk(&mut rng, 64, 32);
        let vbar = v_mean(&v, 32, 50);
        let sp = SparsityParams { r: 8, k: 8, m: 4, n: 8 };
        let o = sparf_attention(&q, &k, &v, &vbar, 50, &sp);
        assert_eq!(o.emb_mask.iter().filter(|&&b| b).count(), 8);
        assert_eq!(o.tok_mask.iter().filter(|&&b| b).count(), 8);
        for (t, &m) in o.tok_mask.iter().enumerate() {
            if m {
                assert!(o.tok_groups[t / sp.n], "token {t} kept but group not fetched");
            }
        }
        for (c, &m) in o.emb_mask.iter().enumerate() {
            if m {
                assert!(o.emb_groups[c / sp.m]);
            }
        }
        // page counts bounded by ceil-division and budget
        let tg = o.tok_groups.iter().filter(|&&b| b).count();
        assert!((1..=8).contains(&tg));
    }

    #[test]
    fn sparq_equals_sparf_arithmetic() {
        let mut rng = Rng::new(4);
        let (q, k, v) = mk(&mut rng, 64, 32);
        let vbar = v_mean(&v, 32, 48);
        let sp = SparsityParams { r: 8, k: 12, m: 4, n: 8 };
        let a = sparf_attention(&q, &k, &v, &vbar, 48, &sp);
        let b = sparq_attention(&q, &k, &v, &vbar, 48, 8, 12);
        assert_eq!(a.out, b.out);
    }

    #[test]
    fn local_covers_short_sequences() {
        let mut rng = Rng::new(5);
        let (q, k, v) = mk(&mut rng, 32, 8);
        let a = local_attention(&q, &k, &v, 10, 16);
        let b = dense_attention(&q, &k, &v, 10);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn h2o_window_tracks_dominant_recent_token() {
        let mut rng = Rng::new(6);
        let (q, mut k, v) = mk(&mut rng, 64, 16);
        // token 49 strongly dominates attention and is inside the window,
        // so H2O (window always kept) must track dense closely
        for c in 0..16 {
            k[49 * 16 + c] = q[c] * 30.0;
        }
        let acc: Vec<f32> = (0..64).map(|_| rng.f32()).collect();
        let a = h2o_attention(&q, &k, &v, &acc, 50, 16, 8);
        let b = dense_attention(&q, &k, &v, 50);
        let err = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(err < 0.05, "err={err}");
    }

    #[test]
    fn sparsity_error_ordering_matches_fig11_premise() {
        // averaged over heads: SparF(=SparQ) error < H2O error < local error
        // on heavy-hitter-structured attention at 1/8 compression
        let mut rng = Rng::new(7);
        let (s, d, kk) = (128usize, 32usize, 16usize);
        let (mut e_sparf, mut e_h2o, mut e_local) = (0.0f64, 0.0f64, 0.0f64);
        let trials = 50;
        for _ in 0..trials {
            let (q, mut k, v) = mk(&mut rng, s, d);
            // plant a few heavy hitters aligned with q spread across history
            for _ in 0..4 {
                let t = rng.below(s);
                for c in 0..d {
                    k[t * d + c] += q[c] * 2.0;
                }
            }
            let truth = dense_attention(&q, &k, &v, s);
            let vbar = v_mean(&v, d, s);
            let sp = SparsityParams { r: d / 4, k: kk, m: 4, n: 8 };
            let o = sparf_attention(&q, &k, &v, &vbar, s, &sp).out;
            // H2O "history" = true accumulated scores (its idealised oracle)
            let scale = 1.0 / (d as f32).sqrt();
            let logits: Vec<f32> =
                (0..s).map(|t| dot(&q, &k[t * d..(t + 1) * d]) * scale).collect();
            let mask = vec![true; s];
            let acc = softmax_masked(&logits, &mask);
            let h = h2o_attention(&q, &k, &v, &acc, s, kk, 4);
            let l = local_attention(&q, &k, &v, s, kk);
            let err = |a: &[f32]| -> f64 {
                a.iter()
                    .zip(&truth)
                    .map(|(x, y)| ((x - y) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt()
            };
            e_sparf += err(&o);
            e_h2o += err(&h);
            e_local += err(&l);
        }
        assert!(e_sparf < e_h2o, "sparf={e_sparf} h2o={e_h2o}");
        assert!(e_h2o < e_local, "h2o={e_h2o} local={e_local}");
    }
}
