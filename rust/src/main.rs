//! InstInfer CLI — the leader entrypoint.
//!
//! Subcommands (hand-rolled arg parsing; no clap in the offline crate set):
//!   serve    run the functional engine through the continuous-batching
//!            scheduler — closed-loop by default, open-loop Poisson
//!            arrivals with --arrival-rate
//!   bench    regenerate paper figures/tables (fig4..fig17b, table1,
//!            ablate-*, or `all`); --json FILE dumps machine-readable rows
//!   golden   validate every AOT artifact against the jax golden record
//!   inspect  dump the artifact manifest summary

use anyhow::{bail, Context, Result};
use instinfer::bench;
use instinfer::config::hw::{FlashPathConfig, FlashPlacement, FlashReadSched};
use instinfer::coordinator::{
    run_closed_loop, run_open_loop, EngineConfig, InferenceEngine, SchedConfig,
};
use instinfer::kvtier::{TierConfig, TierPolicy};
use instinfer::runtime::{golden, Runtime};
use instinfer::shard::ShardPolicy;
use instinfer::util::json::Json;
use instinfer::util::table::Table;
use instinfer::workload::{ArrivalGen, LengthProfile, Request, WorkloadGen};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: instinfer <command> [options]\n\
         \n\
         commands:\n\
         \x20 serve [--requests N] [--batch B] [--gen T] [--n-csds K] [--sparse]\n\
         \x20       [--shard-policy stripe|block|context] [--overlap]\n\
         \x20       [--profile fixed|chat|qa] [--artifacts DIR]\n\
         \x20       [--arrival-rate R] [--prefill-chunk C] [--slots S]\n\
         \x20       [--hi-frac F]\n\
         \x20       [--hot-kib N] [--tier-policy lru|h2o|pin[:W]]\n\
         \x20       [--drop-on-resume] [--resume-keep K]\n\
         \x20       [--flash-path legacy|tuned] [--flash-placement channel|die]\n\
         \x20       [--flash-sched fifo|interleave]\n\
         \x20       [--flash-pipeline | --flash-no-pipeline]\n\
         \x20       continuous batching; --arrival-rate R runs open-loop\n\
         \x20       Poisson arrivals (R req/s on the simulated clock),\n\
         \x20       otherwise all requests are present at t=0.\n\
         \x20       --overlap disaggregates prefill and decode onto two\n\
         \x20       pipelined engine streams (admissions prefill on the GPU\n\
         \x20       stream while decode ticks keep advancing; same outputs,\n\
         \x20       decoupled TTFT/decode latency).\n\
         \x20       --n-csds shards each sequence across K engine instances\n\
         \x20       (--csds is an alias); --shard-policy picks head striping,\n\
         \x20       head blocks, or context (token-group) striping with a\n\
         \x20       log-sum-exp merge — context implies dense attention.\n\
         \x20       --hot-kib enables the per-CSD DRAM hot tier;\n\
         \x20       --drop-on-resume keeps only the --resume-keep most\n\
         \x20       important tokens when a preempted sequence returns.\n\
         \x20       --flash-path picks the flash KV data path (default\n\
         \x20       legacy = channel placement + fifo reads + read barrier;\n\
         \x20       tuned = die-interleaved placement + conflict-aware reads\n\
         \x20       + read-compute pipelining); the individual --flash-*\n\
         \x20       flags then override its components, e.g. --flash-path\n\
         \x20       tuned --flash-no-pipeline ablates only the pipelining\n\
         \x20 bench <target|all> [--json FILE]   regenerate paper figures\n\
         \x20       (fig4 fig5 fig6 fig11 fig12 fig13 fig14 fig15 fig16\n\
         \x20       fig17a fig17b table1 tier shard serve overlap flashpath\n\
         \x20       ablate-group ablate-dualk ablate-pipeline ablate-p2p\n\
         \x20       ablate-placement);\n\
         \x20       `bench all --json` emits one stitched trajectory document\n\
         \x20       (schema instinfer-bench-trajectory/v1, run-numbered in CI)\n\
         \x20 golden [--artifacts DIR] [--tol T]\n\
         \x20 inspect [--artifacts DIR]"
    );
    std::process::exit(2);
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn artifacts_dir(args: &[String]) -> String {
    flag_value(args, "--artifacts").unwrap_or("artifacts").to_string()
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        Some("serve") => serve(&args[1..]),
        Some("bench") => bench_cmd(&args[1..]),
        Some("golden") => golden_cmd(&args[1..]),
        Some("inspect") => inspect(&args[1..]),
        _ => usage(),
    }
}

fn serve(args: &[String]) -> Result<()> {
    let n_req: usize = flag_value(args, "--requests").unwrap_or("8").parse()?;
    let batch: usize = flag_value(args, "--batch").unwrap_or("4").parse()?;
    let gen_toks: usize = flag_value(args, "--gen").unwrap_or("8").parse()?;
    let n_csds: usize = flag_value(args, "--n-csds")
        .or_else(|| flag_value(args, "--csds"))
        .unwrap_or("2")
        .parse()?;
    let shard_policy = ShardPolicy::parse(flag_value(args, "--shard-policy").unwrap_or("stripe"))?;
    if n_csds == 0 {
        bail!("--n-csds must be >= 1");
    }
    let prefill_chunk: usize = flag_value(args, "--prefill-chunk").unwrap_or("4").parse()?;
    let slot_cap: usize = flag_value(args, "--slots").unwrap_or("64").parse()?;
    let hi_frac: f64 = flag_value(args, "--hi-frac").unwrap_or("0").parse()?;
    let hot_kib: usize = flag_value(args, "--hot-kib").unwrap_or("0").parse()?;
    let tier_policy = TierPolicy::parse(flag_value(args, "--tier-policy").unwrap_or("lru"))?;
    let drop_on_resume = has_flag(args, "--drop-on-resume");
    let resume_keep: usize = flag_value(args, "--resume-keep").unwrap_or("0").parse()?;
    let overlap = has_flag(args, "--overlap");
    let mut flash_path = match flag_value(args, "--flash-path") {
        Some(v) => FlashPathConfig::parse(v)?,
        None => FlashPathConfig::legacy(),
    };
    if let Some(v) = flag_value(args, "--flash-placement") {
        flash_path.placement = FlashPlacement::parse(v)?;
    }
    if let Some(v) = flag_value(args, "--flash-sched") {
        flash_path.sched = FlashReadSched::parse(v)?;
    }
    if has_flag(args, "--flash-pipeline") {
        flash_path.pipeline = true;
    }
    if has_flag(args, "--flash-no-pipeline") {
        flash_path.pipeline = false;
    }
    let arrival_rate: Option<f64> = match flag_value(args, "--arrival-rate") {
        Some(v) => Some(v.parse().context("--arrival-rate")?),
        None => None,
    };
    let profile = match flag_value(args, "--profile").unwrap_or("fixed") {
        "fixed" => LengthProfile::Fixed,
        "chat" => LengthProfile::Chat,
        "qa" => LengthProfile::Qa,
        other => bail!("unknown profile {other:?}"),
    };

    let rt = Runtime::open(artifacts_dir(args)).context("opening artifacts")?;
    println!("platform: {}", rt.platform());
    let compiled = rt.warmup()?;
    println!("prepared {compiled} executables");
    let meta = rt.manifest.model.clone();
    let sparse = has_flag(args, "--sparse");
    if sparse && shard_policy == ShardPolicy::Context {
        bail!("--shard-policy context supports dense attention only (drop --sparse)");
    }
    let cfg = EngineConfig::micro_for(&meta, n_csds, sparse)
        .tiered(TierConfig { hot_bytes: hot_kib * 1024, policy: tier_policy })
        .sharded(shard_policy)
        .flash_path(flash_path);
    let mut engine = InferenceEngine::new(rt, cfg)?;

    let mut wg = WorkloadGen::new(42, meta.vocab, meta.max_seq, profile,
                                  meta.prefill_seq / 2, gen_toks);
    let sanitize = |mut r: Request| -> Request {
        r.prompt.truncate(meta.prefill_seq);
        r.max_new_tokens = r.max_new_tokens.min(gen_toks).max(1);
        r
    };
    let scfg = SchedConfig {
        drop_on_resume,
        resume_keep,
        ..SchedConfig::serving(batch, prefill_chunk, slot_cap).overlapped(overlap)
    };
    let t0 = std::time::Instant::now();
    let report = match arrival_rate {
        Some(rate) => {
            if rate <= 0.0 {
                bail!("--arrival-rate must be > 0");
            }
            let mut ag = ArrivalGen::new(wg, 43, rate).with_high_priority_fraction(hi_frac);
            let mut arrivals = ag.take(n_req);
            for a in arrivals.iter_mut() {
                a.req = sanitize(a.req.clone());
            }
            println!("open loop: {n_req} requests at {rate} req/s (sim clock)\n");
            run_open_loop(&mut engine, arrivals, scfg)?
        }
        None => {
            let reqs: Vec<Request> = wg.batch(n_req).into_iter().map(sanitize).collect();
            println!("closed loop: {n_req} requests at t=0\n");
            run_closed_loop(&mut engine, reqs, scfg)?
        }
    };
    let wall = t0.elapsed().as_secs_f64();

    let mut records = report.records.clone();
    records.sort_by_key(|r| r.id);
    for r in &records {
        println!(
            "req {:>3} prio {} prompt {:>3} gen {:>3} preempt {} \
             arrive {:.4}s first-tok {:.4}s done {:.4}s{}",
            r.id,
            r.priority,
            r.prompt_len,
            r.generated.len(),
            r.preemptions,
            r.arrived_at,
            r.first_token_at,
            r.finished_at,
            if r.rejected { "  REJECTED (invalid prompt)" } else { "" },
        );
    }
    println!("\n{}", report.summary(&engine.metrics));
    println!("{}", engine.metrics.report());
    println!(
        "wall {:.2}s | simulated CSD device time {:.4}s | e2e {:.1} tok/s",
        wall,
        engine.sim_now,
        engine.metrics.tokens_generated as f64 / wall.max(1e-9)
    );
    let u = &engine.metrics.units;
    if u.total() > 0.0 {
        println!(
            "CSD units: argtopk {:.1}% flash {:.1}% dram {:.1}% filter {:.1}% \
             logit0 {:.1}% logit {:.1}% attend {:.1}% xfer {:.1}% merge {:.1}%",
            100.0 * u.argtopk / u.total(),
            100.0 * u.flash_read / u.total(),
            100.0 * u.dram_hit / u.total(),
            100.0 * u.nfc_filter / u.total(),
            100.0 * u.logit0 / u.total(),
            100.0 * u.logit / u.total(),
            100.0 * u.attend / u.total(),
            100.0 * u.pcie_xfer / u.total(),
            100.0 * u.gpu_merge / u.total(),
        );
    }
    let fu = engine.flash_util();
    println!(
        "flash path {}: die busy {:.6}s, channel busy {:.6}s, peak die queue {}",
        flash_path.label(),
        fu.die_busy_s,
        fu.channel_busy_s,
        fu.die_peak_depth,
    );
    if engine.shards.n_csds() > 1 {
        let st = &engine.shards.stats;
        let ck = &engine.shards.clock;
        println!(
            "shards ({} x {}): attn {:.6}s, all-reduce {:.6}s ({:.1} KiB shipped), \
             mean barrier skew {:.2}us over {} barriers, stragglers {:?}",
            engine.shards.n_csds(),
            shard_policy.label(),
            st.attn_span_s,
            st.merge_span_s,
            st.xfer_bytes / 1024.0,
            ck.mean_skew_s() * 1e6,
            ck.barriers,
            ck.straggler,
        );
    }
    if overlap {
        let st = &engine.shards.stats;
        let ck = &engine.shards.clock;
        println!(
            "pipeline: decode step {:.6}s (admission stalls incl.), {:.1} KiB prefill \
             KV shipped as background link load ({:.6}s ingest busy), {} contended \
             all-reduces (+{:.2}us total), dual-stream link time {:.6}s",
            engine.metrics.decode_step_time_s(),
            st.prefill_ship_bytes / 1024.0,
            ck.ingest_s.iter().sum::<f64>(),
            st.contended_merges,
            st.contention_delay_s * 1e6,
            ck.dual_stream_s,
        );
    }
    let st = engine.tier_stats();
    if st.hits + st.misses > 0 {
        println!(
            "KV tier ({}, {} KiB/CSD): {} hits / {} misses ({:.1}% hit rate), \
             {} admissions, {} evictions, {} tokens dropped on resume",
            tier_policy.label(),
            hot_kib,
            st.hits,
            st.misses,
            100.0 * st.hit_rate(),
            st.admissions,
            st.evictions,
            engine.metrics.dropped_tokens,
        );
        if engine.shards.n_csds() > 1 {
            for (c, s) in engine.shards.per_shard_tier_stats().iter().enumerate() {
                if s.hits + s.misses > 0 {
                    println!(
                        "  csd{c}: {} hits / {} misses ({:.1}%), {} evictions",
                        s.hits,
                        s.misses,
                        100.0 * s.hit_rate(),
                        s.evictions,
                    );
                }
            }
        }
    }
    Ok(())
}

fn bench_tables_json(tables: &[(&str, Table)]) -> Vec<Json> {
    let mut items = Vec::new();
    for (name, t) in tables {
        if let Json::Obj(mut m) = t.to_json() {
            m.insert("target".to_string(), Json::Str(name.to_string()));
            items.push(Json::Obj(m));
        }
    }
    items
}

fn write_bench_json(path: &str, tables: &[(&str, Table)]) -> Result<()> {
    let doc = Json::Arr(bench_tables_json(tables));
    std::fs::write(path, format!("{doc}\n")).with_context(|| format!("writing {path}"))?;
    println!("wrote {path}");
    Ok(())
}

/// The `bench all --json` umbrella: one stitched trajectory document —
/// every table, plus the dashboard subset (`bench::TRAJECTORY`) called
/// out so cross-run stitching knows which targets to chart.  CI names
/// the uploaded artifact with the run number; `run` carries it inside
/// the document too (from `GITHUB_RUN_NUMBER` when present).
fn write_trajectory_json(path: &str, tables: &[(&str, Table)]) -> Result<()> {
    let mut doc = std::collections::BTreeMap::new();
    doc.insert("schema".to_string(), Json::Str("instinfer-bench-trajectory/v1".to_string()));
    let run = std::env::var("GITHUB_RUN_NUMBER").map(Json::Str).unwrap_or(Json::Null);
    doc.insert("run".to_string(), run);
    doc.insert(
        "trajectory_targets".to_string(),
        Json::Arr(bench::TRAJECTORY.iter().map(|s| Json::Str(s.to_string())).collect()),
    );
    doc.insert("targets".to_string(), Json::Arr(bench_tables_json(tables)));
    let doc = Json::Obj(doc);
    std::fs::write(path, format!("{doc}\n")).with_context(|| format!("writing {path}"))?;
    println!("wrote {path} (stitched trajectory)");
    Ok(())
}

fn bench_cmd(args: &[String]) -> Result<()> {
    let mut target: Option<&str> = None;
    let mut json_path: Option<&str> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--json" => {
                json_path = args.get(i + 1).map(|s| s.as_str());
                if json_path.is_none() {
                    bail!("--json needs a file path");
                }
                i += 2;
            }
            t if target.is_none() => {
                target = Some(t);
                i += 1;
            }
            other => bail!("unexpected bench argument {other:?}"),
        }
    }
    match target {
        None | Some("all") => {
            let tables = bench::run_all_tables();
            for (_, t) in &tables {
                println!();
                t.print();
            }
            if let Some(p) = json_path {
                write_trajectory_json(p, &tables)?;
            }
        }
        Some(name) => match bench::run_one(name) {
            Some(t) => {
                t.print();
                if let Some(p) = json_path {
                    write_bench_json(p, &[(name, t)])?;
                }
            }
            None => bail!(
                "unknown bench target {name:?}; known: {:?}",
                bench::registry().iter().map(|(n, _)| *n).collect::<Vec<_>>()
            ),
        },
    }
    Ok(())
}

fn golden_cmd(args: &[String]) -> Result<()> {
    let tol: f32 = flag_value(args, "--tol").unwrap_or("2e-4").parse()?;
    let rt = Runtime::open(artifacts_dir(args))?;
    if rt.manifest.golden.is_empty() {
        println!(
            "no golden records in this manifest (native synthesized model) — \
             run `make artifacts` to record jax outputs"
        );
        return Ok(());
    }
    for r in golden::check_all(&rt, tol)? {
        println!("golden {:<16} max_abs_err {:.3e} ({} outputs)", r.exe, r.max_abs_err, r.outputs);
    }
    println!("all golden checks passed (tol {tol})");
    Ok(())
}

fn inspect(args: &[String]) -> Result<()> {
    let rt = Runtime::open(artifacts_dir(args))?;
    println!("backend: {}", rt.platform());
    let m = &rt.manifest.model;
    println!(
        "model {} — vocab {} d_model {} heads {}x{} ffn {} layers {} ctx {} \
         (prefill chunk {})",
        m.name, m.vocab, m.d_model, m.n_heads, m.d_head, m.d_ffn, m.n_layers,
        m.max_seq, m.prefill_seq
    );
    println!("sparsity defaults: r={} k={} m={} n={}", m.r, m.k, m.m, m.n);
    println!("batch buckets: {:?}", rt.manifest.batch_buckets);
    println!("{} weights, {} golden records", rt.manifest.weights.len(), rt.manifest.golden.len());
    for (name, exe) in &rt.manifest.executables {
        let inputs: Vec<String> = exe
            .inputs()
            .map(|a| format!("{}{:?}", a.name, a.concrete_shape(1)))
            .collect();
        println!("  {name:<14} ({} buckets) inputs: {}", exe.buckets.len(), inputs.join(", "));
    }
    Ok(())
}
