//! InstInfer CLI — the leader entrypoint.
//!
//! Subcommands (hand-rolled arg parsing; no clap in the offline crate set):
//!   serve    run the functional engine on a synthetic offline workload
//!   bench    regenerate paper figures/tables (fig4..fig17b, table1,
//!            ablate-*, or `all`)
//!   golden   validate every AOT artifact against the jax golden record
//!   inspect  dump the artifact manifest summary

use anyhow::{bail, Context, Result};
use instinfer::bench;
use instinfer::config::model::SparsityParams;
use instinfer::coordinator::{
    EngineConfig, InferenceEngine, OfflineBatcher, Sequence, SlotManager,
};
use instinfer::runtime::{golden, Runtime};
use instinfer::workload::{LengthProfile, WorkloadGen};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: instinfer <command> [options]\n\
         \n\
         commands:\n\
         \x20 serve [--requests N] [--batch B] [--gen T] [--csds K] [--sparse]\n\
         \x20       [--profile fixed|chat|qa] [--artifacts DIR]\n\
         \x20 bench <target|all>      regenerate paper figures (fig4 fig5 fig6\n\
         \x20       fig11 fig12 fig13 fig14 fig15 fig16 fig17a fig17b table1\n\
         \x20       ablate-group ablate-dualk ablate-pipeline ablate-p2p\n\
         \x20       ablate-placement)\n\
         \x20 golden [--artifacts DIR] [--tol T]\n\
         \x20 inspect [--artifacts DIR]"
    );
    std::process::exit(2);
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn artifacts_dir(args: &[String]) -> String {
    flag_value(args, "--artifacts").unwrap_or("artifacts").to_string()
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        Some("serve") => serve(&args[1..]),
        Some("bench") => bench_cmd(&args[1..]),
        Some("golden") => golden_cmd(&args[1..]),
        Some("inspect") => inspect(&args[1..]),
        _ => usage(),
    }
}

fn serve(args: &[String]) -> Result<()> {
    let n_req: usize = flag_value(args, "--requests").unwrap_or("8").parse()?;
    let batch: usize = flag_value(args, "--batch").unwrap_or("4").parse()?;
    let gen_toks: usize = flag_value(args, "--gen").unwrap_or("8").parse()?;
    let n_csds: usize = flag_value(args, "--csds").unwrap_or("2").parse()?;
    let profile = match flag_value(args, "--profile").unwrap_or("fixed") {
        "fixed" => LengthProfile::Fixed,
        "chat" => LengthProfile::Chat,
        "qa" => LengthProfile::Qa,
        other => bail!("unknown profile {other:?}"),
    };

    let rt = Runtime::open(artifacts_dir(args)).context("opening artifacts")?;
    println!("platform: {}", rt.platform());
    let compiled = rt.warmup()?;
    println!("compiled {compiled} executables");
    let meta = rt.manifest.model.clone();
    let mut cfg = EngineConfig::micro(n_csds);
    if has_flag(args, "--sparse") {
        cfg = cfg.sparse(SparsityParams { r: meta.r, k: meta.k, m: meta.m, n: meta.n });
    }
    let buckets = rt.manifest.batch_buckets.clone();
    let mut engine = InferenceEngine::new(rt, cfg)?;

    let mut wg = WorkloadGen::new(42, meta.vocab, meta.max_seq, profile,
                                  meta.prefill_seq / 2, gen_toks);
    let mut batcher = OfflineBatcher::new(buckets, batch);
    for r in wg.batch(n_req) {
        let mut r = r;
        r.prompt.truncate(meta.prefill_seq);
        r.max_new_tokens = r.max_new_tokens.min(gen_toks);
        batcher.push(r);
    }
    let mut slots = SlotManager::new(64);
    let t0 = std::time::Instant::now();
    while let Some((reqs, bucket)) = batcher.next_batch() {
        let seqs: Vec<Sequence> = reqs
            .into_iter()
            .map(|r| Ok(Sequence::new(r, slots.alloc()?)))
            .collect::<Result<_>>()?;
        let done = engine.generate(seqs, bucket)?;
        for s in &done {
            println!(
                "req {:>3} slot {:>2} prompt {:>3} -> {:?}",
                s.req.id, s.slot, s.req.prompt.len(), s.generated
            );
            slots.release(s.slot)?;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("\n{}", engine.metrics.report());
    println!(
        "wall {:.2}s | simulated CSD device time {:.4}s | e2e {:.1} tok/s",
        wall,
        engine.sim_now,
        engine.metrics.tokens_generated as f64 / wall
    );
    let u = &engine.metrics.units;
    if u.total() > 0.0 {
        println!(
            "CSD units: argtopk {:.1}% flash {:.1}% filter {:.1}% logit0 {:.1}% \
             logit {:.1}% attend {:.1}%",
            100.0 * u.argtopk / u.total(),
            100.0 * u.flash_read / u.total(),
            100.0 * u.nfc_filter / u.total(),
            100.0 * u.logit0 / u.total(),
            100.0 * u.logit / u.total(),
            100.0 * u.attend / u.total(),
        );
    }
    Ok(())
}

fn bench_cmd(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        None | Some("all") => {
            bench::run_all();
        }
        Some(name) => match bench::run_one(name) {
            Some(t) => t.print(),
            None => bail!(
                "unknown bench target {name:?}; known: {:?}",
                bench::registry().iter().map(|(n, _)| *n).collect::<Vec<_>>()
            ),
        },
    }
    Ok(())
}

fn golden_cmd(args: &[String]) -> Result<()> {
    let tol: f32 = flag_value(args, "--tol").unwrap_or("2e-4").parse()?;
    let rt = Runtime::open(artifacts_dir(args))?;
    for r in golden::check_all(&rt, tol)? {
        println!("golden {:<16} max_abs_err {:.3e} ({} outputs)", r.exe, r.max_abs_err, r.outputs);
    }
    println!("all golden checks passed (tol {tol})");
    Ok(())
}

fn inspect(args: &[String]) -> Result<()> {
    let rt = Runtime::open(artifacts_dir(args))?;
    let m = &rt.manifest.model;
    println!(
        "model {} — vocab {} d_model {} heads {}x{} ffn {} layers {} ctx {} \
         (prefill chunk {})",
        m.name, m.vocab, m.d_model, m.n_heads, m.d_head, m.d_ffn, m.n_layers,
        m.max_seq, m.prefill_seq
    );
    println!("sparsity defaults: r={} k={} m={} n={}", m.r, m.k, m.m, m.n);
    println!("batch buckets: {:?}", rt.manifest.batch_buckets);
    println!("{} weights, {} golden records", rt.manifest.weights.len(), rt.manifest.golden.len());
    for (name, exe) in &rt.manifest.executables {
        let inputs: Vec<String> = exe
            .inputs()
            .map(|a| format!("{}{:?}", a.name, a.concrete_shape(1)))
            .collect();
        println!("  {name:<14} ({} buckets) inputs: {}", exe.buckets.len(), inputs.join(", "));
    }
    Ok(())
}
