//! InstInfer CLI — the leader entrypoint.
//!
//! Subcommands (hand-rolled arg parsing; no clap in the offline crate set):
//!   serve    run the functional engine through the continuous-batching
//!            scheduler — closed-loop by default, open-loop Poisson
//!            arrivals with --arrival-rate
//!   bench    regenerate paper figures/tables (fig4..fig17b, table1,
//!            ablate-*, or `all`); --json FILE dumps machine-readable rows
//!   golden   validate every AOT artifact against the jax golden record
//!   inspect  dump the artifact manifest summary

use anyhow::{bail, Context, Result};
use instinfer::bench;
use instinfer::coordinator::{run_closed_loop, run_open_loop, InferenceEngine, ServeOpts};
use instinfer::runtime::{golden, Runtime};
use instinfer::util::json::Json;
use instinfer::util::table::Table;
use instinfer::workload::{
    ArrivalGen, PrefixWorkloadGen, Request, RequestSource, WorkloadGen,
};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: instinfer <command> [options]\n\
         \n\
         commands:\n\
         \x20 serve — continuous batching on the functional engine.\n\
         \x20       Closed-loop by default; --arrival-rate R runs open-loop\n\
         \x20       Poisson arrivals (R req/s on the simulated clock).\n\
         \x20       --overlap disaggregates prefill and decode onto two\n\
         \x20       pipelined engine streams (same outputs, decoupled TTFT);\n\
         \x20       --prefix-cache shares sealed prompt prefixes across\n\
         \x20       requests through the FTL's content-addressed index.\n\
         \x20       Flags (generated from the ServeOpts table):\n\
         {}\
         \x20 bench <target|all> [--json FILE] [--threads N]   regenerate\n\
         \x20       paper figures (fig4 fig5 fig6 fig11 fig12 fig13 fig14\n\
         \x20       fig15 fig16 fig17a fig17b table1 tier shard serve overlap\n\
         \x20       flashpath prefix attr fault ablate-group ablate-dualk\n\
         \x20       ablate-pipeline ablate-p2p ablate-placement);\n\
         \x20       `bench all` exits non-zero if any table has error rows;\n\
         \x20       --threads N fans sweep points out on N worker threads\n\
         \x20       (0 = all cores; tables are byte-identical for any N);\n\
         \x20       `bench all --json` emits one stitched trajectory document\n\
         \x20       (schema instinfer-bench-trajectory/v1, run-numbered in CI)\n\
         \x20       with per-target wall-clock timing under its strippable\n\
         \x20       \"timing\" key; --timing-baseline FILE folds a previous\n\
         \x20       trajectory document's total into a measured speedup;\n\
         \x20       overlap|prefix|flashpath accept --trace FILE\n\
         \x20       [--trace-level L] to dump one sweep point's timeline\n\
         \x20 bench gate [--bench FILE] [--baseline FILE] [--update]\n\
         \x20       diff BENCH_all.json key metrics against the committed\n\
         \x20       baseline (fails loudly on out-of-tolerance regressions)\n\
         \x20 golden [--artifacts DIR] [--tol T]\n\
         \x20 inspect [--artifacts DIR]",
        ServeOpts::usage_block()
    );
    std::process::exit(2);
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn artifacts_dir(args: &[String]) -> String {
    flag_value(args, "--artifacts").unwrap_or("artifacts").to_string()
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        Some("serve") => serve(&args[1..]),
        Some("bench") => bench_cmd(&args[1..]),
        Some("golden") => golden_cmd(&args[1..]),
        Some("inspect") => inspect(&args[1..]),
        _ => usage(),
    }
}

/// Stem-reuse probability of the multi-turn workload behind
/// `serve --prefix-cache` (the share *length* is `--share-ratio`).
const PREFIX_HIT_RATE: f64 = 0.8;
/// Stem pool size of that workload (distinct shared system prompts).
const PREFIX_STEMS: usize = 4;

fn serve(args: &[String]) -> Result<()> {
    let opts = ServeOpts::parse(args)?;
    let rt = Runtime::open(&opts.artifacts).context("opening artifacts")?;
    println!("platform: {}", rt.platform());
    let compiled = rt.warmup()?;
    println!("prepared {compiled} executables");
    let meta = rt.manifest.model.clone();
    println!("{opts}");
    let mut engine = InferenceEngine::new(rt, opts.engine_config(&meta))?;

    // multi-turn / shared-system-prompt workload when prefix caching is
    // on (stems rounded to whole token groups); independent prompts
    // from the length profile otherwise
    let prompt_len = (meta.prefill_seq / 2).max(1);
    let mut src: Box<dyn RequestSource> = if opts.prefix_cache {
        Box::new(PrefixWorkloadGen::new(
            42,
            meta.vocab,
            prompt_len,
            opts.gen,
            opts.share_ratio,
            meta.n,
            PREFIX_HIT_RATE,
            PREFIX_STEMS,
        ))
    } else {
        Box::new(WorkloadGen::new(
            42,
            meta.vocab,
            meta.max_seq,
            opts.profile,
            prompt_len,
            opts.gen,
        ))
    };
    let sanitize = |mut r: Request| -> Request {
        r.prompt.truncate(meta.prefill_seq);
        r.max_new_tokens = r.max_new_tokens.min(opts.gen).max(1);
        r
    };
    let scfg = opts.sched_config();
    let n_req = opts.requests;
    if opts.trace.is_some() {
        instinfer::obs::install(opts.trace_level);
    }
    if opts.attr_json.is_some() {
        instinfer::obs::attr::install();
    }
    let t0 = std::time::Instant::now();
    let report = match opts.arrival_rate {
        Some(rate) => {
            let mut ag =
                ArrivalGen::new(src, 43, rate).with_high_priority_fraction(opts.hi_frac);
            let mut arrivals = ag.take(n_req);
            for a in arrivals.iter_mut() {
                a.req = sanitize(a.req.clone());
            }
            println!("open loop: {n_req} requests at {rate} req/s (sim clock)\n");
            run_open_loop(&mut engine, arrivals, scfg)?
        }
        None => {
            let reqs: Vec<Request> = (0..n_req).map(|_| sanitize(src.request())).collect();
            println!("closed loop: {n_req} requests at t=0\n");
            run_closed_loop(&mut engine, reqs, scfg)?
        }
    };
    let wall = t0.elapsed().as_secs_f64();

    // drain the trace sink first so nothing below can perturb the event
    // stream; the digest doubles as the determinism fingerprint
    let mut trace_digest: Option<String> = None;
    if let Some(path) = &opts.trace {
        if let Some(sink) = instinfer::obs::uninstall() {
            std::fs::write(path, sink.export()).with_context(|| format!("writing {path}"))?;
            let digest = sink.digest_hex();
            println!(
                "trace: {} events -> {path} (level {}, digest {digest})",
                sink.len(),
                sink.level.label(),
            );
            trace_digest = Some(digest);
        }
    }

    // drain the attribution sink next (also observational-only); the
    // report is folded into the metrics snapshot further down
    let mut attr_report: Option<instinfer::obs::attr::AttrReport> = None;
    if let Some(path) = &opts.attr_json {
        let sink = instinfer::obs::attr::uninstall().unwrap_or_default();
        let rep = instinfer::obs::attr::extract(&sink);
        std::fs::write(path, format!("{}\n", rep.to_json()))
            .with_context(|| format!("writing {path}"))?;
        println!(
            "attr: {} requests, {:.4}s attributed wall time -> {path}",
            rep.requests.len(),
            rep.wall_total,
        );
        if rep.wall_total > 0.0 {
            let ranked = instinfer::obs::attr::AttrReport::ranked(&rep.total);
            let top: Vec<String> = ranked
                .iter()
                .filter(|(_, v)| *v > 0.0)
                .take(5)
                .map(|(l, v)| format!("{l} {:.1}%", 100.0 * v / rep.wall_total))
                .collect();
            println!("attr top buckets: {}", top.join(", "));
        }
        attr_report = Some(rep);
    }

    let mut records = report.records.clone();
    records.sort_by_key(|r| r.id);
    for r in &records {
        println!(
            "req {:>3} prio {} prompt {:>3} gen {:>3} preempt {} \
             arrive {:.4}s first-tok {:.4}s done {:.4}s{}",
            r.id,
            r.priority,
            r.prompt_len,
            r.generated.len(),
            r.preemptions,
            r.arrived_at,
            r.first_token_at,
            r.finished_at,
            if r.rejected {
                "  REJECTED (invalid prompt)"
            } else if r.aborted {
                "  ABORTED (device loss, retry-only recovery)"
            } else {
                ""
            },
        );
    }
    println!("\n{}", report.summary(&engine.metrics));
    println!("{}", engine.metrics.report());
    println!(
        "wall {:.2}s | simulated CSD device time {:.4}s | e2e {:.1} tok/s",
        wall,
        engine.sim_now,
        engine.metrics.tokens_generated as f64 / wall.max(1e-9)
    );
    let u = &engine.metrics.units;
    if u.total() > 0.0 {
        println!(
            "CSD units: argtopk {:.1}% flash {:.1}% dram {:.1}% filter {:.1}% \
             logit0 {:.1}% logit {:.1}% attend {:.1}% xfer {:.1}% merge {:.1}%",
            100.0 * u.argtopk / u.total(),
            100.0 * u.flash_read / u.total(),
            100.0 * u.dram_hit / u.total(),
            100.0 * u.nfc_filter / u.total(),
            100.0 * u.logit0 / u.total(),
            100.0 * u.logit / u.total(),
            100.0 * u.attend / u.total(),
            100.0 * u.pcie_xfer / u.total(),
            100.0 * u.gpu_merge / u.total(),
        );
    }
    let fu = engine.flash_util();
    println!(
        "flash path {}: die busy {:.6}s, channel busy {:.6}s, peak die queue {}",
        opts.flash_path.label(),
        fu.die_busy_s,
        fu.channel_busy_s,
        fu.die_peak_depth,
    );
    if engine.shards.n_csds() > 1 {
        let st = &engine.shards.stats;
        let ck = &engine.shards.clock;
        println!(
            "shards ({} x {}): attn {:.6}s, all-reduce {:.6}s ({:.1} KiB shipped), \
             mean barrier skew {:.2}us over {} barriers, stragglers {:?}",
            engine.shards.n_csds(),
            opts.shard_policy.label(),
            st.attn_span_s,
            st.merge_span_s,
            st.xfer_bytes / 1024.0,
            ck.mean_skew_s() * 1e6,
            ck.barriers,
            ck.straggler,
        );
    }
    if opts.overlap {
        let st = &engine.shards.stats;
        let ck = &engine.shards.clock;
        println!(
            "pipeline: decode step {:.6}s (admission stalls incl.), {:.1} KiB prefill \
             KV shipped as background link load ({:.6}s ingest busy), {} contended \
             all-reduces (+{:.2}us total), dual-stream link time {:.6}s",
            engine.metrics.decode_step_time_s(),
            st.prefill_ship_bytes / 1024.0,
            ck.ingest_s.iter().sum::<f64>(),
            st.contended_merges,
            st.contention_delay_s * 1e6,
            ck.dual_stream_s,
        );
    }
    let st = engine.tier_stats();
    if st.hits + st.misses > 0 {
        println!(
            "KV tier ({}, {} KiB/CSD): {} hits / {} misses ({:.1}% hit rate), \
             {} admissions, {} evictions, {} tokens dropped on resume",
            opts.tier_policy.label(),
            opts.hot_kib,
            st.hits,
            st.misses,
            100.0 * st.hit_rate(),
            st.admissions,
            st.evictions,
            engine.metrics.dropped_tokens,
        );
        if engine.shards.n_csds() > 1 {
            for (c, s) in engine.shards.per_shard_tier_stats().iter().enumerate() {
                if s.hits + s.misses > 0 {
                    println!(
                        "  csd{c}: {} hits / {} misses ({:.1}%), {} evictions",
                        s.hits,
                        s.misses,
                        100.0 * s.hit_rate(),
                        s.evictions,
                    );
                }
            }
        }
    }
    if opts.prefix_cache {
        let (mut regs, mut attaches, mut toks) = (0u64, 0u64, 0u64);
        for q in engine.csds() {
            let c = &q.csd.ftl.counters;
            regs += c.prefix_registrations;
            attaches += c.prefix_attaches;
            toks += c.prefix_tokens_attached;
        }
        println!(
            "prefix cache: {regs} registrations, {attaches} attaches, {toks} shared \
             tokens attached across shards, {} prompt tokens skipped at prefill",
            engine.metrics.prefix_hit_tokens,
        );
    }
    if let Some(path) = &opts.metrics_json {
        let mut reg = engine.metrics_registry(&report.overlap);
        // fold an empty report when attribution is off so the snapshot
        // name set does not depend on --attr-json
        match &attr_report {
            Some(rep) => rep.fold_into(&mut reg),
            None => instinfer::obs::attr::AttrReport::default().fold_into(&mut reg),
        }
        let mut doc = std::collections::BTreeMap::new();
        doc.insert("schema".to_string(), Json::Str("instinfer-metrics/v1".to_string()));
        doc.insert("metrics".to_string(), reg.to_json());
        doc.insert(
            "trace_digest".to_string(),
            match &trace_digest {
                Some(d) => Json::Str(d.clone()),
                None => Json::Null,
            },
        );
        std::fs::write(path, format!("{}\n", Json::Obj(doc)))
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path} (unified metrics snapshot, {} series)", reg.len());
    }
    Ok(())
}

fn bench_tables_json<'a>(tables: impl IntoIterator<Item = (&'a str, &'a Table)>) -> Vec<Json> {
    let mut items = Vec::new();
    for (name, t) in tables {
        if let Json::Obj(mut m) = t.to_json() {
            m.insert("target".to_string(), Json::Str(name.to_string()));
            items.push(Json::Obj(m));
        }
    }
    items
}

fn write_bench_json(path: &str, tables: &[(&str, Table)]) -> Result<()> {
    let doc = Json::Arr(bench_tables_json(tables.iter().map(|(n, t)| (*n, t))));
    std::fs::write(path, format!("{doc}\n")).with_context(|| format!("writing {path}"))?;
    println!("wrote {path}");
    Ok(())
}

/// The `bench all --json` umbrella: one stitched trajectory document —
/// every table, plus the dashboard subset (`bench::TRAJECTORY`) called
/// out so cross-run stitching knows which targets to chart.  CI names
/// the uploaded artifact with the run number; `run` carries it inside
/// the document too (from `GITHUB_RUN_NUMBER` when present).
///
/// The `timing` key is the document's only intentionally
/// machine-dependent block (per-target and total wall-clock seconds at
/// the configured thread count, plus the measured speedup against an
/// optional previous document's total): strip it and two documents from
/// runs at any `--threads` value must be byte-identical.
fn write_trajectory_json(
    path: &str,
    tables: &[(&str, Table, f64)],
    baseline_total_wall_s: Option<f64>,
) -> Result<()> {
    let mut doc = std::collections::BTreeMap::new();
    doc.insert("schema".to_string(), Json::Str("instinfer-bench-trajectory/v1".to_string()));
    let run = std::env::var("GITHUB_RUN_NUMBER").map(Json::Str).unwrap_or(Json::Null);
    doc.insert("run".to_string(), run);
    doc.insert(
        "trajectory_targets".to_string(),
        Json::Arr(bench::TRAJECTORY.iter().map(|s| Json::Str(s.to_string())).collect()),
    );
    // determinism fingerprint: the digest of the canonical traced serve
    // run, stitched into every trajectory document so cross-run diffs
    // catch timing perturbations even when the tables agree
    doc.insert(
        "trace_digest".to_string(),
        match bench::canonical_trace_digest() {
            Ok(d) => Json::Str(d),
            Err(_) => Json::Null,
        },
    );
    doc.insert(
        "targets".to_string(),
        Json::Arr(bench_tables_json(tables.iter().map(|(n, t, _)| (*n, t)))),
    );
    let total: f64 = tables.iter().map(|(_, _, s)| s).sum();
    let mut timing = std::collections::BTreeMap::new();
    timing.insert("threads".to_string(), Json::Num(bench::threads() as f64));
    timing.insert("total_wall_s".to_string(), Json::Num(total));
    timing.insert(
        "targets".to_string(),
        Json::Obj(
            tables.iter().map(|(n, _, s)| (n.to_string(), Json::Num(*s))).collect(),
        ),
    );
    timing.insert(
        "baseline_total_wall_s".to_string(),
        baseline_total_wall_s.map(Json::Num).unwrap_or(Json::Null),
    );
    timing.insert(
        "speedup".to_string(),
        baseline_total_wall_s.map(|b| Json::Num(b / total.max(1e-9))).unwrap_or(Json::Null),
    );
    doc.insert("timing".to_string(), Json::Obj(timing));
    let doc = Json::Obj(doc);
    std::fs::write(path, format!("{doc}\n")).with_context(|| format!("writing {path}"))?;
    println!("wrote {path} (stitched trajectory)");
    Ok(())
}

/// Total wall seconds recorded in a previous trajectory document (the
/// `--timing-baseline` input for the measured-speedup column).
fn baseline_total_wall_s(path: &str) -> Result<f64> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
    doc.get("timing")
        .and_then(|t| t.get("total_wall_s"))
        .and_then(|v| v.as_f64())
        .with_context(|| format!("{path} has no timing.total_wall_s (not a trajectory doc?)"))
}

fn bench_cmd(args: &[String]) -> Result<()> {
    if args.first().map(|s| s.as_str()) == Some("gate") {
        return bench::gate::gate_cmd(&args[1..]);
    }
    let mut target: Option<&str> = None;
    let mut json_path: Option<&str> = None;
    let mut trace_path: Option<&str> = None;
    let mut trace_level = instinfer::obs::TraceLevel::Device;
    let mut timing_baseline: Option<&str> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--json" => {
                json_path = args.get(i + 1).map(|s| s.as_str());
                if json_path.is_none() {
                    bail!("--json needs a file path");
                }
                i += 2;
            }
            "--threads" => {
                let Some(v) = args.get(i + 1) else {
                    bail!("--threads needs a value (0 = all cores)");
                };
                let n: usize = v.parse().with_context(|| format!("--threads {v:?}"))?;
                bench::set_threads(if n == 0 {
                    instinfer::sim::par::available_threads()
                } else {
                    n
                });
                i += 2;
            }
            "--timing-baseline" => {
                timing_baseline = args.get(i + 1).map(|s| s.as_str());
                if timing_baseline.is_none() {
                    bail!("--timing-baseline needs a file path");
                }
                i += 2;
            }
            "--trace" => {
                trace_path = args.get(i + 1).map(|s| s.as_str());
                if trace_path.is_none() {
                    bail!("--trace needs a file path");
                }
                i += 2;
            }
            "--trace-level" => {
                let Some(v) = args.get(i + 1) else {
                    bail!("--trace-level needs a value");
                };
                trace_level = instinfer::obs::TraceLevel::parse(v)?;
                i += 2;
            }
            t if target.is_none() => {
                target = Some(t);
                i += 1;
            }
            other => bail!("unexpected bench argument {other:?}"),
        }
    }
    if let Some(path) = trace_path {
        if json_path.is_some() {
            bail!("--trace and --json are mutually exclusive for bench targets");
        }
        let sink = match target {
            Some("overlap") => bench::overlap::traced(trace_level)?,
            Some("prefix") => bench::prefix::traced(trace_level)?,
            Some("flashpath") => bench::flashpath::traced(trace_level)?,
            other => bail!(
                "--trace supports bench overlap|prefix|flashpath (got {other:?})"
            ),
        };
        std::fs::write(path, sink.export()).with_context(|| format!("writing {path}"))?;
        println!(
            "trace: {} events -> {path} (level {}, digest {})",
            sink.len(),
            sink.level.label(),
            sink.digest_hex(),
        );
        return Ok(());
    }
    match target {
        None | Some("all") => {
            let baseline_total = match timing_baseline {
                Some(p) => Some(baseline_total_wall_s(p)?),
                None => None,
            };
            let tables = bench::run_all_tables_timed();
            for (_, t, _) in &tables {
                println!();
                t.print();
            }
            let total: f64 = tables.iter().map(|(_, _, s)| s).sum();
            println!("\nbench all wall clock ({} threads):", bench::threads());
            for (name, _, secs) in &tables {
                println!("  {name:<16} {secs:>8.3}s");
            }
            match baseline_total {
                Some(b) => println!(
                    "  {:<16} {total:>8.3}s (baseline {b:.3}s, speedup {:.2}x)",
                    "total",
                    b / total.max(1e-9),
                ),
                None => println!("  {:<16} {total:>8.3}s", "total"),
            }
            if let Some(p) = json_path {
                write_trajectory_json(p, &tables, baseline_total)?;
            }
            // a sweep that degraded to error rows must fail the run,
            // not just print "ERR" cells CI never reads — the artifact
            // above is still written for post-mortem
            let broken: Vec<&str> = tables
                .iter()
                .filter(|(_, t, _)| t.has_error_rows())
                .map(|(n, _, _)| *n)
                .collect();
            if !broken.is_empty() {
                bail!("bench targets with error rows: {broken:?}");
            }
        }
        Some(name) => match bench::run_one(name) {
            Some(t) => {
                t.print();
                if let Some(p) = json_path {
                    write_bench_json(p, &[(name, t)])?;
                }
            }
            None => bail!(
                "unknown bench target {name:?}; known: {:?}",
                bench::registry().iter().map(|(n, _)| *n).collect::<Vec<_>>()
            ),
        },
    }
    Ok(())
}

fn golden_cmd(args: &[String]) -> Result<()> {
    let tol: f32 = flag_value(args, "--tol").unwrap_or("2e-4").parse()?;
    let rt = Runtime::open(artifacts_dir(args))?;
    if rt.manifest.golden.is_empty() {
        println!(
            "no golden records in this manifest (native synthesized model) — \
             run `make artifacts` to record jax outputs"
        );
        return Ok(());
    }
    for r in golden::check_all(&rt, tol)? {
        println!("golden {:<16} max_abs_err {:.3e} ({} outputs)", r.exe, r.max_abs_err, r.outputs);
    }
    println!("all golden checks passed (tol {tol})");
    Ok(())
}

fn inspect(args: &[String]) -> Result<()> {
    let rt = Runtime::open(artifacts_dir(args))?;
    println!("backend: {}", rt.platform());
    let m = &rt.manifest.model;
    println!(
        "model {} — vocab {} d_model {} heads {}x{} ffn {} layers {} ctx {} \
         (prefill chunk {})",
        m.name, m.vocab, m.d_model, m.n_heads, m.d_head, m.d_ffn, m.n_layers,
        m.max_seq, m.prefill_seq
    );
    println!("sparsity defaults: r={} k={} m={} n={}", m.r, m.k, m.m, m.n);
    println!("batch buckets: {:?}", rt.manifest.batch_buckets);
    println!("{} weights, {} golden records", rt.manifest.weights.len(), rt.manifest.golden.len());
    for (name, exe) in &rt.manifest.executables {
        let inputs: Vec<String> = exe
            .inputs()
            .map(|a| format!("{}{:?}", a.name, a.concrete_shape(1)))
            .collect();
        println!("  {name:<14} ({} buckets) inputs: {}", exe.buckets.len(), inputs.join(", "));
    }
    Ok(())
}
