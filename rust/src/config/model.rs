//! Transformer shape parameters and derived size/FLOP accounting.
//!
//! Byte accounting uses FP16 (2 bytes/element) to match the paper even
//! though the functional plane computes in f32 on CPU (DESIGN.md §1).

pub const FP16_BYTES: usize = 2;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelShape {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ffn: usize,
    pub n_layers: usize,
    pub max_seq: usize,
}

impl ModelShape {
    /// OPT-13B — the paper's evaluation model (§VI-A).
    pub fn opt_13b() -> Self {
        ModelShape {
            name: "opt-13b",
            vocab: 50272,
            d_model: 5120,
            n_heads: 40,
            d_head: 128,
            d_ffn: 20480,
            n_layers: 40,
            max_seq: 2048,
        }
    }

    /// OPT-30B — used for capacity headroom discussions.
    pub fn opt_30b() -> Self {
        ModelShape {
            name: "opt-30b",
            vocab: 50272,
            d_model: 7168,
            n_heads: 56,
            d_head: 128,
            d_ffn: 28672,
            n_layers: 48,
            max_seq: 2048,
        }
    }

    /// The functional-plane model — must match `python/compile/model.SMALL`.
    pub fn opt_micro() -> Self {
        ModelShape {
            name: "opt-micro-14m",
            vocab: 512,
            d_model: 256,
            n_heads: 8,
            d_head: 32,
            d_ffn: 1024,
            n_layers: 4,
            max_seq: 128,
        }
    }

    /// Total parameter count (embeddings + blocks, tied unembedding).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let per_layer = 4 * d * d          // wq wk wv wo
            + 4 * d                         // their biases
            + 2 * d * self.d_ffn            // w1 w2
            + self.d_ffn + d                // b1 b2
            + 4 * d; // ln1/ln2 gain+bias
        self.vocab * d + self.max_seq * d + self.n_layers * per_layer + 2 * d
    }

    /// Model weight bytes in FP16 (paper: "model weight size is 2p").
    pub fn weight_bytes(&self) -> usize {
        self.param_count() * FP16_BYTES
    }

    /// KV-cache bytes per token per layer (K and V, all heads, FP16).
    pub fn kv_bytes_per_token_layer(&self) -> usize {
        2 * self.n_heads * self.d_head * FP16_BYTES
    }

    /// KV-cache bytes per token across all layers
    /// (paper: "KV cache size stored in FP16 is 4bsp/…" — i.e. 4·d·L bytes).
    pub fn kv_bytes_per_token(&self) -> usize {
        self.n_layers * self.kv_bytes_per_token_layer()
    }

    /// Full KV-cache bytes for `batch` sequences of `seq` tokens.
    pub fn kv_bytes(&self, batch: usize, seq: usize) -> usize {
        batch * seq * self.kv_bytes_per_token()
    }

    // ---- per-operator FLOP/byte accounting for one decode step -----------
    // (drives the roofline placement analysis of Fig. 6)

    /// FLOPs of the QKV projection for one token (per layer).
    pub fn flops_qkv(&self) -> usize {
        2 * 3 * self.d_model * self.d_model
    }

    /// FLOPs of the O projection (per layer, per token).
    pub fn flops_oproj(&self) -> usize {
        2 * self.d_model * self.d_model
    }

    /// FLOPs of the FFN (per layer, per token).
    pub fn flops_ffn(&self) -> usize {
        2 * 2 * self.d_model * self.d_ffn
    }

    /// FLOPs of decode attention (Logit + Attend) per layer per token at
    /// context length `s`.
    pub fn flops_attn_decode(&self, s: usize) -> usize {
        2 * 2 * self.n_heads * s * self.d_head
    }

    /// Bytes the decode attention must read from the KV cache per layer
    /// per token (dense).
    pub fn attn_kv_read_bytes(&self, s: usize) -> usize {
        2 * self.n_heads * s * self.d_head * FP16_BYTES
    }
}

/// SparF/SparQ hyper-parameters (Algorithm 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SparsityParams {
    /// top-r |q| channels used for approximate scores
    pub r: usize,
    /// top-k tokens attended exactly
    pub k: usize,
    /// embedding-indexed group: channels per flash page
    pub m: usize,
    /// token-indexed group: tokens per flash page
    pub n: usize,
}

impl SparsityParams {
    /// The paper's default 1/8 compression for OPT-13B-shaped heads:
    /// r = d_head/4, k = s/8; token group 16 = 4 KiB page / (128·FP16);
    /// embedding group m=2 (the paper adapts m within 2-8 to the context
    /// length — m=2 keeps first-step overfetch at the reported "about
    /// half of the sparsity" for 1-2K contexts, §IV-C).
    pub fn paper_default(shape: &ModelShape, seq: usize) -> Self {
        SparsityParams {
            r: shape.d_head / 4,
            k: (seq / 8).max(1),
            m: 2,
            n: 16,
        }
    }

    /// Scale r and k for a target compression ratio `1/c` (Fig. 17b sweep).
    pub fn with_compression(shape: &ModelShape, seq: usize, c: usize) -> Self {
        SparsityParams {
            r: (shape.d_head * 2 / c).max(1),
            k: (seq / c).max(1),
            m: 2,
            n: 16,
        }
    }

    /// Approximate fraction of dense KV bytes a SparQ/SparF step transfers:
    /// r/d for the K-row pass + 2k/s for the exact K,V pass (SparQ paper).
    pub fn transfer_fraction(&self, shape: &ModelShape, seq: usize) -> f64 {
        let a = self.r as f64 / shape.d_head as f64 / 2.0; // only K, halved over K+V
        let b = self.k as f64 / seq as f64;
        (a + b).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opt13b_sizes_match_paper() {
        let m = ModelShape::opt_13b();
        // ~13B params => ~26 GB FP16 ("about 24GB" with paper rounding)
        let gb = m.weight_bytes() as f64 / 1e9;
        assert!((24.0..28.5).contains(&gb), "weights {gb} GB");
        // paper §I: 13B model, batch 32, 4K tokens => ~100 GB KV cache.
        // OPT-13B caps at 2K context; the paper's example uses 4K tokens.
        let kv = m.kv_bytes(32, 4096) as f64 / 1e9;
        assert!((100.0..115.0).contains(&kv), "kv {kv} GB");
        // paper §III-A: 2K-length batch 128 => ~200 GB
        let kv2 = m.kv_bytes(128, 2048) as f64 / 1e9;
        assert!((195.0..225.0).contains(&kv2), "kv2 {kv2} GB");
    }

    #[test]
    fn kv_per_token_is_4dl_bytes() {
        let m = ModelShape::opt_13b();
        assert_eq!(m.kv_bytes_per_token(), 4 * m.d_model * m.n_layers);
    }

    #[test]
    fn micro_matches_python_small() {
        let m = ModelShape::opt_micro();
        assert_eq!(m.d_model, m.n_heads * m.d_head);
        assert_eq!((m.vocab, m.d_model, m.n_layers, m.max_seq), (512, 256, 4, 128));
    }

    #[test]
    fn paper_default_sparsity_is_one_eighth() {
        let m = ModelShape::opt_13b();
        let sp = SparsityParams::paper_default(&m, 2048);
        assert_eq!(sp.r, 32);
        assert_eq!(sp.k, 256);
        // 16 tokens x 128 channels x 2 B = 4 KiB page (paper §IV-C)
        assert_eq!(sp.n * m.d_head * FP16_BYTES, 4096);
        let f = sp.transfer_fraction(&m, 2048);
        assert!((0.2..0.3).contains(&f), "fraction {f}");
    }

    #[test]
    fn compression_sweep_monotone() {
        let m = ModelShape::opt_13b();
        let mut last = f64::MAX;
        for c in [2, 4, 8, 16, 32] {
            let f = SparsityParams::with_compression(&m, 2048, c)
                .transfer_fraction(&m, 2048);
            assert!(f < last, "c={c} f={f} last={last}");
            last = f;
        }
    }
}
