//! Hardware specifications — every constant cites the paper section or the
//! public datasheet it comes from.  These drive the roofline models (Fig. 6)
//! and the DES timing plane.

/// GPU compute/memory model (roofline, §III-B Fig. 6).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// peak FP16 tensor throughput, FLOP/s
    pub flops_fp16: f64,
    /// HBM/GDDR bandwidth, bytes/s
    pub mem_bw: f64,
    /// VRAM capacity, bytes
    pub vram_bytes: usize,
}

impl GpuSpec {
    /// NVIDIA RTX A6000 (paper §VI-A): 48 GB GDDR6, 768 GB/s,
    /// ~155 TFLOP/s FP16 tensor (datasheet: 309.7 TFLOP/s with sparsity,
    /// 154.8 dense).
    pub fn a6000() -> Self {
        GpuSpec {
            name: "A6000",
            flops_fp16: 154.8e12,
            mem_bw: 768e9,
            vram_bytes: 48 * (1 << 30),
        }
    }

    /// Time to execute `flops` touching `bytes`, roofline style.
    pub fn op_time(&self, flops: f64, bytes: f64) -> f64 {
        (flops / self.flops_fp16).max(bytes / self.mem_bw)
    }

    /// Arithmetic-intensity knee (FLOP/byte) where compute == memory time.
    pub fn knee(&self) -> f64 {
        self.flops_fp16 / self.mem_bw
    }
}

/// Where the FTL opens blocks — what a stream's consecutive KV pages
/// stripe across (paper §IV, Fig. 8: the in-storage engine's bandwidth
/// comes from channel-, die- and plane-level parallelism).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlashPlacement {
    /// one open block per channel: consecutive pages on a channel land
    /// on the same die and serialize on one tR pipeline (the legacy
    /// pre-refactor allocator)
    Channel,
    /// one open block per (channel, die): token groups and dual-K
    /// embedding pages round-robin across dies, and reads split per
    /// plane, so a stream stripes over the full array
    Die,
}

impl FlashPlacement {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "channel" => Ok(FlashPlacement::Channel),
            "die" => Ok(FlashPlacement::Die),
            other => anyhow::bail!("unknown flash placement {other:?} (channel|die)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            FlashPlacement::Channel => "channel",
            FlashPlacement::Die => "die",
        }
    }
}

/// How a batch of page reads is issued to the array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlashReadSched {
    /// caller order (the legacy `read_batch`): one hot die convoys the
    /// whole fetch behind its tR pipeline
    Fifo,
    /// conflict-aware issue: the batch is re-ordered round-robin across
    /// (channel, die) queues — a pure function of the PPAs, so replays
    /// are deterministic — and completions return per page
    Interleave,
}

impl FlashReadSched {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "fifo" => Ok(FlashReadSched::Fifo),
            "interleave" => Ok(FlashReadSched::Interleave),
            other => anyhow::bail!("unknown flash read sched {other:?} (fifo|interleave)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            FlashReadSched::Fifo => "fifo",
            FlashReadSched::Interleave => "interleave",
        }
    }
}

/// The flash-microarchitecture-aware KV data path (ISSUE 5 tentpole):
/// block placement x read scheduling x read-compute pipelining.
/// `legacy()` replays the pre-refactor data path bit-identically —
/// outputs AND timing — for placement, batch reads, and kernel
/// scheduling (pinned by `tests/flashpath.rs`).  The one deliberate
/// exception: GC relocation reads now issue concurrently on every
/// path (the serialized read->program->read chain was a bug, not a
/// behaviour), so timings diverge from the pre-refactor engine only
/// once a device is full enough to garbage-collect.  `tuned()` is the
/// paper's engine — die-interleaved placement, conflict-aware reads,
/// and per-group pipelining of the attention kernels behind the page
/// reads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlashPathConfig {
    pub placement: FlashPlacement,
    pub sched: FlashReadSched,
    /// schedule per-group Logit/Attend kernel time incrementally as each
    /// group's read completes instead of a full read->compute barrier
    /// (timing only — outputs are bit-identical either way)
    pub pipeline: bool,
}

impl FlashPathConfig {
    pub fn legacy() -> Self {
        FlashPathConfig {
            placement: FlashPlacement::Channel,
            sched: FlashReadSched::Fifo,
            pipeline: false,
        }
    }

    pub fn tuned() -> Self {
        FlashPathConfig {
            placement: FlashPlacement::Die,
            sched: FlashReadSched::Interleave,
            pipeline: true,
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "legacy" => Ok(Self::legacy()),
            "tuned" => Ok(Self::tuned()),
            other => anyhow::bail!("unknown flash path {other:?} (legacy|tuned)"),
        }
    }

    pub fn label(&self) -> String {
        format!(
            "{}/{}{}",
            self.placement.label(),
            self.sched.label(),
            if self.pipeline { "/pipe" } else { "" }
        )
    }
}

/// NAND flash array geometry + timing (§II-C, §V-B).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlashSpec {
    pub channels: usize,
    pub dies_per_channel: usize,
    pub planes_per_die: usize,
    pub blocks_per_plane: usize,
    pub pages_per_block: usize,
    pub page_bytes: usize,
    /// per-channel bus bandwidth, bytes/s
    pub channel_bw: f64,
    /// tR: page read (array -> die register), seconds
    pub read_us: f64,
    /// tProg: page program, seconds
    pub program_us: f64,
    /// tBERS: block erase, seconds
    pub erase_ms: f64,
    /// KV data-path policy: placement, read scheduling, pipelining
    pub path: FlashPathConfig,
}

impl FlashSpec {
    /// The paper's software-defined InstCSD backend (§V-B): 8 channels at
    /// 1.4 GB/s (11.2 GB/s aggregate, quoted in §VI-C), 4 KiB pages;
    /// read/program/erase latencies typical of recent TLC
    /// (tR~50us, tProg~600us, tBERS~3ms).  The paper's engine is the
    /// tuned data path — the quoted 11.2 GB/s internal rate presumes
    /// die-interleaved, pipelined reads keep every die's tR off the
    /// critical path.
    pub fn instcsd() -> Self {
        FlashSpec {
            channels: 8,
            dies_per_channel: 4,
            planes_per_die: 2,
            blocks_per_plane: 1024,
            pages_per_block: 256,
            page_bytes: 4096,
            channel_bw: 1.4e9,
            read_us: 50.0,
            program_us: 600.0,
            erase_ms: 3.0,
            path: FlashPathConfig::tuned(),
        }
    }

    /// Samsung 980pro-like consumer NVMe (for the FlexGen baseline): the
    /// external PCIe x4 link is the binding constraint, internal dies
    /// similar to instcsd.
    pub fn ssd_980pro() -> Self {
        FlashSpec { channels: 8, ..Self::instcsd() }
    }

    /// A tiny geometry for unit tests (fast to fill and GC).  The unit
    /// tests pin the legacy data path; benches/tests opt into the tuned
    /// path explicitly.
    pub fn tiny() -> Self {
        FlashSpec {
            channels: 2,
            dies_per_channel: 1,
            planes_per_die: 1,
            blocks_per_plane: 8,
            pages_per_block: 16,
            page_bytes: 512,
            channel_bw: 1.0e9,
            read_us: 10.0,
            program_us: 100.0,
            erase_ms: 1.0,
            path: FlashPathConfig::legacy(),
        }
    }

    pub fn internal_bw(&self) -> f64 {
        self.channels as f64 * self.channel_bw
    }

    pub fn total_blocks(&self) -> usize {
        self.channels * self.dies_per_channel * self.planes_per_die * self.blocks_per_plane
    }

    pub fn total_pages(&self) -> usize {
        self.total_blocks() * self.pages_per_block
    }

    pub fn capacity_bytes(&self) -> usize {
        self.total_pages() * self.page_bytes
    }

    /// Capacity available to KV mappings: raw capacity minus one block
    /// per channel held back as the FTL's GC relocation reserve (see
    /// `KvFtl::alloc_block`) — what capacity gates should advertise so
    /// admitted work can never hit a device-full error the reserve
    /// created.
    pub fn usable_capacity_bytes(&self) -> usize {
        self.capacity_bytes().saturating_sub(self.channels * self.block_bytes())
    }

    pub fn block_bytes(&self) -> usize {
        self.pages_per_block * self.page_bytes
    }
}

/// In-storage compute engine (§V-B, Table I).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CsdSpec {
    pub name: &'static str,
    pub flash: FlashSpec,
    /// attention-engine peak, FLOP/s
    pub engine_flops: f64,
    /// engine clock, Hz
    pub clock_hz: f64,
    /// on-device DRAM, bytes
    pub dram_bytes: usize,
    /// number of parallel attention kernels in the engine (Fig. 8: two)
    pub attn_kernels: usize,
    /// argtopk unit throughput, elements/s (sorting-network style)
    pub argtopk_elems_per_s: f64,
    /// NFC filter throughput per channel, bytes/s (filters at line rate)
    pub filter_bw_per_channel: f64,
    /// on-device DRAM bandwidth, bytes/s — what a hot-tier page hit
    /// costs instead of a flash die read + channel transfer
    pub dram_bw: f64,
    /// bytes of `dram_bytes` reserved as the KV hot tier (group buffers
    /// in front of the flash array; 0 = flash-only dataflow).  The
    /// functional-plane test specs default to 0 so the paper's baseline
    /// timing is preserved unless tiering is opted in via
    /// `EngineConfig`/CLI/bench.
    pub hot_tier_bytes: usize,
    /// KV capacity of the backing store, bytes.  The functional flash
    /// array models the OpenSSD-like 68 GB geometry; the paper's
    /// software-defined InstCSD is backed by a 2 TB 980pro (§V-B, §VI-A),
    /// which is what the capacity gate in the timing plane uses.
    pub kv_capacity_bytes: u64,
    /// Fault-injection knobs (`FaultConfig::none()` = fault plane off;
    /// the default everywhere keeps the engine bit-identical).
    pub fault: crate::fault::FaultConfig,
}

impl CsdSpec {
    /// Zynq7045-based InstCSD (§V-B): 285 MHz engine; Table I shows 768 of
    /// 900 DSP slices in the attention kernels => 768 MAC/cycle =
    /// 768 · 285e6 · 2 ≈ 0.44 TFLOP/s — "2~3 orders of magnitude weaker
    /// than GPUs" (§I) vs the A6000's 155 TFLOP/s.
    pub fn zynq7045() -> Self {
        let flash = FlashSpec::instcsd();
        CsdSpec {
            name: "InstCSD-Zynq7045",
            flash,
            engine_flops: 768.0 * 285e6 * 2.0,
            clock_hz: 285e6,
            dram_bytes: 2 << 30,
            attn_kernels: 2,
            argtopk_elems_per_s: 285e6, // 1 element/cycle streaming topk
            filter_bw_per_channel: flash.channel_bw, // line-rate filtering
            dram_bw: 4.2e9, // Zynq PS-side DDR3 (~4.2 GB/s effective)
            hot_tier_bytes: 1 << 30, // half the 2 GB DRAM as KV hot tier
            kv_capacity_bytes: 2_000_000_000_000, // 2 TB 980pro backing
            fault: crate::fault::FaultConfig::none(),
        }
    }

    /// A tiny engine matched to FlashSpec::tiny for unit tests.
    pub fn tiny() -> Self {
        CsdSpec {
            name: "tiny-csd",
            flash: FlashSpec::tiny(),
            engine_flops: 1e9,
            clock_hz: 100e6,
            dram_bytes: 1 << 20,
            attn_kernels: 2,
            argtopk_elems_per_s: 100e6,
            filter_bw_per_channel: 1.0e9,
            dram_bw: 1.0e9,
            hot_tier_bytes: 0, // unit tests opt in explicitly
            kv_capacity_bytes: FlashSpec::tiny().usable_capacity_bytes() as u64,
            fault: crate::fault::FaultConfig::none(),
        }
    }

    pub fn op_time(&self, flops: f64, kv_bytes: f64) -> f64 {
        (flops / self.engine_flops).max(kv_bytes / self.flash.internal_bw())
    }

    pub fn knee(&self) -> f64 {
        self.engine_flops / self.flash.internal_bw()
    }
}

/// PCIe link + host-path overheads (§III-A, §IV-D).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PcieSpec {
    /// GPU <-> host bandwidth (Gen4 x16), bytes/s
    pub gpu_host_bw: f64,
    /// SSD/CSD <-> host or peer bandwidth (Gen3/4 x4), bytes/s
    pub ssd_link_bw: f64,
    /// P2P DMA efficiency factor (switch traversal) in (0, 1]
    pub p2p_efficiency: f64,
    /// per-IO software latency through the host block/filesystem stack, s
    pub host_fs_io_us: f64,
    /// per-IO latency of the P2P/NVMe-command path (no FS), s
    pub p2p_io_us: f64,
    /// GPU-side ingress ceiling shared by concurrent P2P streams (the
    /// GPU sits on one Gen4 x16 slot, so N CSDs shipping results at
    /// once fair-share this link even though each has its own x4 lane)
    pub gpu_p2p_ingress_bw: f64,
}

impl PcieSpec {
    /// Paper testbed: GPU on Gen4 x16 (32 GB/s, §VI-C quotes 32GB/s);
    /// CSD/SSD on Gen3x4/Gen4x4 ~3.5 GB/s effective (§I: 3~6 GB/s).
    /// Host FS stack cost ~15us/IO (VFS+block+NVMe submission, cf. §VI-C
    /// "heavy burden on data transmission"); P2P command path ~3us.
    pub fn paper() -> Self {
        PcieSpec {
            gpu_host_bw: 32e9,
            ssd_link_bw: 3.5e9,
            p2p_efficiency: 0.9,
            host_fs_io_us: 15.0,
            p2p_io_us: 3.0,
            gpu_p2p_ingress_bw: 32e9,
        }
    }
}

/// Host CPU + DRAM (§VI-A: Xeon 5320, 96 GB DDR4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostSpec {
    pub dram_bytes: usize,
    /// DRAM bandwidth, bytes/s
    pub dram_bw: f64,
    /// fraction of DRAM usable for KV staging (rest: OS, activations)
    pub usable_frac: f64,
}

impl HostSpec {
    pub fn xeon_5320_96g() -> Self {
        HostSpec {
            dram_bytes: 96 * (1 << 30),
            dram_bw: 38e9, // 6-ch DDR4-2933 derated
            usable_frac: 0.75,
        }
    }

    pub fn usable_dram(&self) -> usize {
        (self.dram_bytes as f64 * self.usable_frac) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csd_is_2_to_3_orders_below_gpu() {
        let ratio = GpuSpec::a6000().flops_fp16 / CsdSpec::zynq7045().engine_flops;
        assert!((100.0..1000.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn internal_bw_exceeds_external_pcie() {
        // the paper's core premise (§I): tens of GB/s inside vs 3-6 outside
        let f = FlashSpec::instcsd();
        let p = PcieSpec::paper();
        assert!((11.0e9..12.0e9).contains(&f.internal_bw()));
        assert!(f.internal_bw() > 2.0 * p.ssd_link_bw);
        // ...but below GPU-host PCIe (paper §VI-C: InstI-dense only ~matches
        // DeepSpeed's host-memory peak)
        assert!(f.internal_bw() < p.gpu_host_bw);
    }

    #[test]
    fn page_and_block_arithmetic() {
        let f = FlashSpec::instcsd();
        assert_eq!(f.block_bytes(), 256 * 4096);
        assert_eq!(f.total_blocks(), 8 * 4 * 2 * 1024);
        assert!(f.capacity_bytes() as u64 > 60 * (1u64 << 30)); // >= OpenSSD's 64 GB
    }

    #[test]
    fn rooflines_order_operators_like_fig6() {
        // Fig. 6 ordering: decode attention (GeMV, ~1 FLOP/byte) sits far
        // below both knees (memory-bound everywhere); decode QKV/FFN at
        // batch b has intensity ~b FLOP/byte — beyond the CSD knee for the
        // paper's batches (so they'd saturate the CSD's compute: keep on
        // GPU), below the GPU knee (memory-bound there: fine on GPU).
        let gpu = GpuSpec::a6000();
        let csd = CsdSpec::zynq7045();
        let attn_intensity = 1.0; // 2 FLOPs per fp16 element read
        assert!(attn_intensity < csd.knee() && attn_intensity < gpu.knee());
        let ffn_intensity_bs64 = 64.0;
        assert!(ffn_intensity_bs64 > csd.knee(), "FFN would be compute-bound on CSD");
        assert!(ffn_intensity_bs64 < gpu.knee(), "FFN stays memory-bound on GPU");
    }
}
