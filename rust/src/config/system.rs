//! System-level configuration: which inference system, on what hardware,
//! with what offload/sparsity policy.  This is the unit the bench harness
//! sweeps (one `SystemConfig` per curve point in Figs. 4-17).

use super::hw::{CsdSpec, GpuSpec, HostSpec, PcieSpec};
use super::model::{ModelShape, SparsityParams};
use crate::shard::ShardPolicy;

/// Where the KV cache lives and who computes decode attention.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffloadPolicy {
    /// everything in VRAM (upper-bound reference)
    GpuOnly,
    /// KV in host DRAM, attention on GPU (DeepSpeed-MII-like; spills to
    /// SSD by kernel swapping once DRAM is exhausted)
    HostDram,
    /// KV on SSD through the host filesystem, attention on GPU
    /// (FlexGen-like)
    SsdViaHost,
    /// KV on CSD flash, decode attention in storage (InstInfer)
    InStorage,
}

impl OffloadPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            OffloadPolicy::GpuOnly => "GPU-only",
            OffloadPolicy::HostDram => "DeepSpeed",
            OffloadPolicy::SsdViaHost => "FlexGen",
            OffloadPolicy::InStorage => "InstInfer",
        }
    }
}

#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub model: ModelShape,
    pub gpu: GpuSpec,
    pub host: HostSpec,
    pub pcie: PcieSpec,
    pub csd: CsdSpec,
    pub policy: OffloadPolicy,
    /// number of SSDs/CSDs attached (Figs. 12/13/17a)
    pub n_devices: usize,
    /// how a sequence's KV is partitioned across the CSD array (head
    /// subsets vs context stripes; shapes the all-reduce comm term)
    pub shard_policy: ShardPolicy,
    /// None = dense attention; Some = SparQ/SparF parameters
    pub sparsity: Option<SparsityParams>,
    /// prompt and generation lengths (paper: 1024/1024)
    pub input_len: usize,
    pub output_len: usize,
    /// peer-to-peer DMA between GPU and CSD (InstInfer) vs host-mediated
    pub p2p_dma: bool,
    /// layer-wise pipelined prefill KV shipping (InstInfer §IV-D)
    pub layerwise_pipeline: bool,
    /// FlexGen tier policy: true = pick GPU/host/SSD by capacity (the
    /// Fig. 4 motivation runs); false = offload target fixed to SSD
    /// (the Fig. 12/13 configuration, §VI-A)
    pub tiered: bool,
}

impl SystemConfig {
    /// The paper's common testbed: OPT-13B, A6000, 1024/1024 (§VI-A).
    pub fn paper_base(policy: OffloadPolicy) -> Self {
        let model = ModelShape::opt_13b();
        let in_storage = policy == OffloadPolicy::InStorage;
        SystemConfig {
            model,
            gpu: GpuSpec::a6000(),
            host: HostSpec::xeon_5320_96g(),
            pcie: PcieSpec::paper(),
            csd: CsdSpec::zynq7045(),
            policy,
            n_devices: 1,
            shard_policy: ShardPolicy::HeadStripe,
            sparsity: None,
            input_len: 1024,
            output_len: 1024,
            p2p_dma: in_storage,
            layerwise_pipeline: in_storage,
            tiered: false,
        }
    }

    pub fn with_sparsity(mut self, sp: SparsityParams) -> Self {
        self.sparsity = Some(sp);
        self
    }

    pub fn with_devices(mut self, n: usize) -> Self {
        self.n_devices = n;
        self
    }

    /// Pick how heads/context stripe across the CSD array.
    pub fn with_shard_policy(mut self, p: ShardPolicy) -> Self {
        self.shard_policy = p;
        self
    }

    /// Capacity-tiered KV placement (Fig. 4 motivation configuration).
    pub fn tiered(mut self) -> Self {
        self.tiered = true;
        self
    }

    /// Paper's default 1/8 compression on the decode context.
    pub fn with_default_sparsity(self) -> Self {
        let sp = SparsityParams::paper_default(&self.model, self.input_len + self.output_len);
        self.with_sparsity(sp)
    }

    /// Display label matching the paper's legend names.
    pub fn label(&self) -> String {
        match (self.policy, self.sparsity.is_some()) {
            (OffloadPolicy::HostDram, _) => "DeepSpeed".into(),
            (OffloadPolicy::SsdViaHost, false) => "FlexGen".into(),
            (OffloadPolicy::SsdViaHost, true) => "FlexGen-SparQ".into(),
            (OffloadPolicy::InStorage, false) => format!("InstI-Dense x{}", self.n_devices),
            (OffloadPolicy::InStorage, true) => format!("InstI-SparF x{}", self.n_devices),
            (OffloadPolicy::GpuOnly, _) => "GPU-only".into(),
        }
    }

    /// Total KV bytes at end of generation for batch `b`.
    pub fn kv_bytes_total(&self, b: usize) -> usize {
        self.model.kv_bytes(b, self.input_len + self.output_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_base_sane() {
        let c = SystemConfig::paper_base(OffloadPolicy::InStorage);
        assert!(c.p2p_dma && c.layerwise_pipeline);
        assert_eq!(c.input_len, 1024);
        let f = SystemConfig::paper_base(OffloadPolicy::SsdViaHost);
        assert!(!f.p2p_dma && !f.layerwise_pipeline);
    }

    #[test]
    fn labels_match_paper_legends() {
        let c = SystemConfig::paper_base(OffloadPolicy::SsdViaHost).with_default_sparsity();
        assert_eq!(c.label(), "FlexGen-SparQ");
        let i = SystemConfig::paper_base(OffloadPolicy::InStorage)
            .with_default_sparsity()
            .with_devices(2);
        assert_eq!(i.label(), "InstI-SparF x2");
    }

    #[test]
    fn kv_exceeds_vram_at_moderate_batch() {
        // the motivation: at bs=64 with 2048 ctx, KV ~ 100+ GB >> 48 GB VRAM
        let c = SystemConfig::paper_base(OffloadPolicy::SsdViaHost);
        assert!(c.kv_bytes_total(64) > c.gpu.vram_bytes);
    }
}
