//! Configuration system: model shapes, hardware specs, system presets.
//!
//! Presets mirror the paper's testbed (§V, §VI-A); every number is cited at
//! its definition.  `SystemConfig` composes a model + hardware + offload
//! policy and is what the bench harness sweeps.

pub mod hw;
pub mod model;
pub mod system;

pub use hw::{CsdSpec, FlashSpec, GpuSpec, HostSpec, PcieSpec};
pub use model::{ModelShape, SparsityParams};
pub use system::{OffloadPolicy, SystemConfig};
