//! Golden cross-checks: execute each artifact with the inputs recorded by
//! `aot.py` and compare against the jax outputs bit-for-bit-ish.
//!
//! This is the python<->rust seam test: if it passes, the rust runtime is
//! running the exact computation the jax/pallas layer defined.

use super::manifest::{DType, GoldenRec, TensorRec};
use super::tensor::{read_f32_at, read_i32_at, HostTensor};
use super::Runtime;
use anyhow::{bail, Context, Result};
use std::fs::File;

/// Read one tensor recorded in golden.bin.
pub fn read_golden_tensor(f: &mut File, rec: &TensorRec) -> Result<HostTensor> {
    Ok(match rec.dtype {
        DType::F32 => HostTensor::f32(rec.shape.clone(), read_f32_at(f, rec.offset, rec.len())?),
        DType::I32 => HostTensor::i32(rec.shape.clone(), read_i32_at(f, rec.offset, rec.len())?),
    })
}

/// Result of checking one executable against its golden record.
#[derive(Debug)]
pub struct GoldenReport {
    pub exe: String,
    pub max_abs_err: f32,
    pub outputs: usize,
}

/// Execute `exe` with its golden inputs and compare outputs.
/// `tol` is the max absolute error allowed on f32 outputs; i32 outputs
/// (greedy token ids) must match exactly unless the float margin is tiny.
pub fn check_exe(rt: &Runtime, exe: &str, tol: f32) -> Result<GoldenReport> {
    let g: &GoldenRec = rt
        .manifest
        .golden
        .get(exe)
        .with_context(|| format!("no golden record for {exe}"))?;
    let mut f = File::open(rt.manifest.dir.join("golden.bin"))?;
    let inputs: Vec<HostTensor> = g
        .inputs
        .iter()
        .map(|r| read_golden_tensor(&mut f, r))
        .collect::<Result<Vec<_>>>()?;
    let outs = rt.call(exe, g.batch, g.layer, &inputs)?;
    if outs.len() != g.outputs.len() {
        bail!("{exe}: {} outputs vs {} golden", outs.len(), g.outputs.len());
    }
    let mut max_err = 0f32;
    for (i, (got, rec)) in outs.iter().zip(&g.outputs).enumerate() {
        let want = read_golden_tensor(&mut f, rec)?;
        match rec.dtype {
            DType::F32 => {
                let e = got.max_abs_diff(&want)?;
                if e > tol {
                    bail!("{exe} out{i}: max abs err {e} > tol {tol}");
                }
                max_err = max_err.max(e);
            }
            DType::I32 => {
                let a = got.as_i32()?;
                let b = want.as_i32()?;
                let diff = a.iter().zip(b).filter(|(x, y)| x != y).count();
                // greedy argmax can flip on ~ulp logit ties; allow none here,
                // the micro model's logit margins are wide
                if diff != 0 {
                    bail!("{exe} out{i}: {diff} of {} token ids differ", a.len());
                }
            }
        }
    }
    Ok(GoldenReport { exe: exe.to_string(), max_abs_err: max_err, outputs: outs.len() })
}

/// Check every executable with a golden record; returns per-exe reports.
pub fn check_all(rt: &Runtime, tol: f32) -> Result<Vec<GoldenReport>> {
    let names: Vec<String> = rt.manifest.golden.keys().cloned().collect();
    names.iter().map(|n| check_exe(rt, n, tol)).collect()
}
