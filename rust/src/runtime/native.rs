//! Rust-native reference backend: executes the same operator set the AOT
//! artifacts implement (`python/compile/model.py`), mirrored op-for-op in
//! plain rust over `HostTensor`s.
//!
//! Two ways to get a model:
//! * [`NativeModel::from_manifest`] — load the real weights from an
//!   artifact directory's `weights.bin` (numerically interchangeable with
//!   the PJRT backend up to summation order);
//! * [`NativeModel::synthesize`] — deterministic OPT-style random init of
//!   the opt-micro architecture, used when no artifacts are present so the
//!   functional plane (engine, scheduler, tests, examples) runs
//!   everywhere without the python/jax toolchain.
//!
//! The decode attention ops reuse [`crate::sparse`] — the same arithmetic
//! the in-storage CSD engine executes — so the `GpuArtifact` ablation
//! backend and the CSD backend agree through this path exactly as they do
//! through the PJRT artifacts.

use super::manifest::{
    ArgKind, ArgSpec, BucketSpec, DType, Dim, ExeSpec, Manifest, ModelMeta, OutSpec, TensorRec,
    WeightScope,
};
use super::tensor::HostTensor;
use crate::sparse;
use crate::sparse::select::dot;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Seed for the synthesized functional-plane model (no artifacts case).
pub const DEFAULT_SEED: u64 = 0x1a57_15f3;

/// Batch buckets baked by `python/compile/aot.py`; the synthetic manifest
/// mirrors them so bucket-selection logic behaves identically.
pub const BATCH_BUCKETS: [usize; 3] = [1, 4, 8];

/// Per-layer weight slots in positional order (mirrors `model.LAYER_SLOTS`).
const LAYER_SLOTS: [&str; 16] = [
    "ln1_g", "ln1_b", "wq", "bq", "wk", "bk", "wv", "bv",
    "wo", "bo", "ln2_g", "ln2_b", "w1", "b1", "w2", "b2",
];

/// The opt-micro functional-plane architecture (`model.SMALL`).
pub fn micro_meta() -> ModelMeta {
    ModelMeta {
        name: "opt-micro-14m".to_string(),
        vocab: 512,
        d_model: 256,
        n_heads: 8,
        d_head: 32,
        d_ffn: 1024,
        n_layers: 4,
        max_seq: 128,
        prefill_seq: 64,
        r: 8,
        k: 16,
        m: 4,
        n: 8,
    }
}

pub struct NativeModel {
    pub meta: ModelMeta,
    weights: BTreeMap<String, HostTensor>,
}

fn slot_shape(meta: &ModelMeta, slot: &str) -> Vec<usize> {
    let (d, f) = (meta.d_model, meta.d_ffn);
    match slot {
        "ln1_g" | "ln1_b" | "bq" | "bk" | "bv" | "bo" | "ln2_g" | "ln2_b" | "b2" => vec![d],
        "wq" | "wk" | "wv" | "wo" => vec![d, d],
        "w1" => vec![d, f],
        "b1" => vec![f],
        "w2" => vec![f, d],
        other => unreachable!("unknown layer slot {other}"),
    }
}

impl NativeModel {
    /// Deterministic OPT-style init of the opt-micro model (same shapes
    /// and scales as `model.init_params`; different PRNG, so tokens are
    /// not bit-identical to the jax-seeded weights — everything else is).
    pub fn synthesize(seed: u64) -> NativeModel {
        let meta = micro_meta();
        let mut rng = Rng::new(seed);
        let mut weights: BTreeMap<String, HostTensor> = BTreeMap::new();

        let dense = |rng: &mut Rng, shape: Vec<usize>, fan_in: usize| -> HostTensor {
            let n: usize = shape.iter().product();
            let s = (fan_in as f32).powf(-0.5);
            HostTensor::f32(shape, (0..n).map(|_| rng.normal_f32() * s).collect())
        };
        let ones = |shape: Vec<usize>| -> HostTensor {
            let n: usize = shape.iter().product();
            HostTensor::f32(shape, vec![1.0; n])
        };
        let zeros = HostTensor::zeros_f32;

        let d = meta.d_model;
        weights.insert("tok_emb".into(), dense(&mut rng, vec![meta.vocab, d], d));
        weights.insert("pos_emb".into(), dense(&mut rng, vec![meta.max_seq, d], d));
        for layer in 0..meta.n_layers {
            for slot in LAYER_SLOTS {
                let shape = slot_shape(&meta, slot);
                let name = format!("layers.{layer}.{slot}");
                let t = if slot.starts_with("ln") && slot.ends_with("_g") {
                    ones(shape)
                } else if shape.len() == 1 {
                    zeros(shape)
                } else {
                    let fan_in = shape[0];
                    dense(&mut rng, shape, fan_in)
                };
                weights.insert(name, t);
            }
        }
        weights.insert("ln_f_g".into(), ones(vec![d]));
        weights.insert("ln_f_b".into(), zeros(vec![d]));
        NativeModel { meta, weights }
    }

    /// Load the real artifact weights for native execution.
    pub fn from_manifest(manifest: &Manifest) -> Result<NativeModel> {
        let wpath = manifest.dir.join("weights.bin");
        let mut f = std::fs::File::open(&wpath)
            .map_err(|e| anyhow!("opening {wpath:?}: {e}"))?;
        let mut weights = BTreeMap::new();
        for (name, rec) in &manifest.weights {
            let data = super::tensor::read_f32_at(&mut f, rec.offset, rec.len())?;
            weights.insert(name.clone(), HostTensor::f32(rec.shape.clone(), data));
        }
        Ok(NativeModel { meta: manifest.model.clone(), weights })
    }

    pub fn weight_host(&self, pname: &str) -> Result<HostTensor> {
        self.weights
            .get(pname)
            .cloned()
            .ok_or_else(|| anyhow!("weight {pname:?} not in model"))
    }

    fn w(&self, name: &str) -> Result<&[f32]> {
        self.weights
            .get(name)
            .ok_or_else(|| anyhow!("weight {name:?} not in model"))?
            .as_f32()
    }

    fn lw(&self, layer: usize, slot: &str) -> Result<&[f32]> {
        self.w(&format!("layers.{layer}.{slot}"))
    }

    /// Execute one operator group (same names/signatures as the AOT
    /// artifacts).  Inputs are already shape-validated by the facade.
    pub fn call(
        &self,
        name: &str,
        b: usize,
        layer: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        match name {
            "embed_decode" => self.embed_decode(b, inputs),
            "embed_prefill" => self.embed_prefill(b, inputs),
            "qkv_proj" => self.qkv_proj(b, layer, inputs),
            "attn_dense" => self.attn(b, inputs, false),
            "attn_sparf" => self.attn(b, inputs, true),
            "post_attn" => self.post_attn(b, layer, inputs),
            "logits" => self.logits(b, inputs),
            "prefill_block" => self.prefill_block(b, layer, inputs),
            other => bail!("native backend: unknown executable {other:?}"),
        }
    }

    fn embed_decode(&self, b: usize, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let ids = inputs[0].as_i32()?;
        let pos = inputs[1].as_i32()?;
        let te = self.w("tok_emb")?;
        let pe = self.w("pos_emb")?;
        let d = self.meta.d_model;
        let mut x = vec![0.0f32; b * d];
        for r in 0..b {
            // XLA gather clamps out-of-range indices; mirror that.
            let ti = (ids[r].max(0) as usize).min(self.meta.vocab - 1);
            let pi = (pos[r].max(0) as usize).min(self.meta.max_seq - 1);
            let row = &mut x[r * d..(r + 1) * d];
            let trow = &te[ti * d..(ti + 1) * d];
            let prow = &pe[pi * d..(pi + 1) * d];
            for ((o, &t), &p) in row.iter_mut().zip(trow).zip(prow) {
                *o = t + p;
            }
        }
        Ok(vec![HostTensor::f32(vec![b, d], x)])
    }

    fn embed_prefill(&self, b: usize, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let ids = inputs[0].as_i32()?;
        let te = self.w("tok_emb")?;
        let pe = self.w("pos_emb")?;
        let (d, sp) = (self.meta.d_model, self.meta.prefill_seq);
        let mut x = vec![0.0f32; b * sp * d];
        for r in 0..b {
            for t in 0..sp {
                let ti = (ids[r * sp + t].max(0) as usize).min(self.meta.vocab - 1);
                let row = &mut x[(r * sp + t) * d..(r * sp + t + 1) * d];
                let trow = &te[ti * d..(ti + 1) * d];
                let prow = &pe[t * d..(t + 1) * d];
                for ((o, &tv), &pv) in row.iter_mut().zip(trow).zip(prow) {
                    *o = tv + pv;
                }
            }
        }
        Ok(vec![HostTensor::f32(vec![b, sp, d], x)])
    }

    fn qkv_proj(&self, b: usize, layer: usize, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let x = inputs[0].as_f32()?;
        let (d, h, dh) = (self.meta.d_model, self.meta.n_heads, self.meta.d_head);
        let hx = layer_norm_rows(x, self.lw(layer, "ln1_g")?, self.lw(layer, "ln1_b")?, d);
        let q = matmul_bias(&hx, self.lw(layer, "wq")?, self.lw(layer, "bq")?, b, d, d);
        let k = matmul_bias(&hx, self.lw(layer, "wk")?, self.lw(layer, "bk")?, b, d, d);
        let v = matmul_bias(&hx, self.lw(layer, "wv")?, self.lw(layer, "bv")?, b, d, d);
        // (B, D) rows are already (B, H, dh) in row-major memory
        Ok(vec![
            HostTensor::f32(vec![b, h, dh], q),
            HostTensor::f32(vec![b, h, dh], k),
            HostTensor::f32(vec![b, h, dh], v),
        ])
    }

    fn attn(&self, b: usize, inputs: &[HostTensor], sparf: bool) -> Result<Vec<HostTensor>> {
        let q = inputs[0].as_f32()?;
        let kc = inputs[1].as_f32()?;
        let vc = inputs[2].as_f32()?;
        let lens = inputs[3].as_f32()?;
        let (h, dh, smax) = (self.meta.n_heads, self.meta.d_head, self.meta.max_seq);
        let sp = self.meta.sparsity();
        let mut out = vec![0.0f32; b * h * dh];
        for r in 0..b {
            let len = (lens[r] as usize).clamp(1, smax);
            for hh in 0..h {
                let qrow = &q[(r * h + hh) * dh..(r * h + hh + 1) * dh];
                let base = (r * h + hh) * smax * dh;
                let krows = &kc[base..base + smax * dh];
                let vrows = &vc[base..base + smax * dh];
                let o = if sparf {
                    let vbar = sparse::v_mean(vrows, dh, len);
                    sparse::sparf_attention(qrow, krows, vrows, &vbar, len, &sp).out
                } else {
                    sparse::dense_attention(qrow, krows, vrows, len)
                };
                out[(r * h + hh) * dh..(r * h + hh + 1) * dh].copy_from_slice(&o);
            }
        }
        Ok(vec![HostTensor::f32(vec![b, h, dh], out)])
    }

    fn post_attn(&self, b: usize, layer: usize, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let x = inputs[0].as_f32()?;
        let attn = inputs[1].as_f32()?;
        let (d, dff) = (self.meta.d_model, self.meta.d_ffn);
        let o = matmul_bias(attn, self.lw(layer, "wo")?, self.lw(layer, "bo")?, b, d, d);
        let x1: Vec<f32> = x.iter().zip(&o).map(|(a, c)| a + c).collect();
        let h2 = layer_norm_rows(&x1, self.lw(layer, "ln2_g")?, self.lw(layer, "ln2_b")?, d);
        let mut f1 = matmul_bias(&h2, self.lw(layer, "w1")?, self.lw(layer, "b1")?, b, d, dff);
        for v in f1.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        let f2 = matmul_bias(&f1, self.lw(layer, "w2")?, self.lw(layer, "b2")?, b, dff, d);
        let x2: Vec<f32> = x1.iter().zip(&f2).map(|(a, c)| a + c).collect();
        Ok(vec![HostTensor::f32(vec![b, d], x2)])
    }

    fn logits(&self, b: usize, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let x = inputs[0].as_f32()?;
        let (d, vocab) = (self.meta.d_model, self.meta.vocab);
        let h = layer_norm_rows(x, self.w("ln_f_g")?, self.w("ln_f_b")?, d);
        let te = self.w("tok_emb")?;
        let mut lg = vec![0.0f32; b * vocab];
        let mut ids = vec![0i32; b];
        for r in 0..b {
            let hr = &h[r * d..(r + 1) * d];
            let row = &mut lg[r * vocab..(r + 1) * vocab];
            for (v, o) in row.iter_mut().enumerate() {
                *o = dot(hr, &te[v * d..(v + 1) * d]);
            }
            // first-occurrence argmax, like jnp.argmax
            let mut best = f32::NEG_INFINITY;
            for (v, &o) in row.iter().enumerate() {
                if o > best {
                    best = o;
                    ids[r] = v as i32;
                }
            }
        }
        Ok(vec![
            HostTensor::f32(vec![b, vocab], lg),
            HostTensor::i32(vec![b], ids),
        ])
    }

    fn prefill_block(
        &self,
        b: usize,
        layer: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let x = inputs[0].as_f32()?;
        let (d, dff, h, dh, sp) = (
            self.meta.d_model,
            self.meta.d_ffn,
            self.meta.n_heads,
            self.meta.d_head,
            self.meta.prefill_seq,
        );
        let rows = b * sp;
        let h1 = layer_norm_rows(x, self.lw(layer, "ln1_g")?, self.lw(layer, "ln1_b")?, d);
        let q = matmul_bias(&h1, self.lw(layer, "wq")?, self.lw(layer, "bq")?, rows, d, d);
        let k = matmul_bias(&h1, self.lw(layer, "wk")?, self.lw(layer, "bk")?, rows, d, d);
        let v = matmul_bias(&h1, self.lw(layer, "wv")?, self.lw(layer, "bv")?, rows, d, d);

        // causal self-attention per (batch row, head)
        let scale = 1.0 / (dh as f32).sqrt();
        let mut ar = vec![0.0f32; rows * d];
        let mut lg = vec![0.0f32; sp];
        for bb in 0..b {
            for hh in 0..h {
                for t in 0..sp {
                    let qoff = (bb * sp + t) * d + hh * dh;
                    let qrow = &q[qoff..qoff + dh];
                    let mut mx = f32::NEG_INFINITY;
                    for (u, l) in lg.iter_mut().enumerate().take(t + 1) {
                        let koff = (bb * sp + u) * d + hh * dh;
                        *l = dot(qrow, &k[koff..koff + dh]) * scale;
                        mx = mx.max(*l);
                    }
                    let mut den = 0.0f32;
                    for l in lg.iter_mut().take(t + 1) {
                        *l = (*l - mx).exp();
                        den += *l;
                    }
                    let inv = 1.0 / den;
                    let aoff = (bb * sp + t) * d + hh * dh;
                    for (u, &l) in lg.iter().enumerate().take(t + 1) {
                        let s = l * inv;
                        let voff = (bb * sp + u) * d + hh * dh;
                        for (acc, &vv) in
                            ar[aoff..aoff + dh].iter_mut().zip(&v[voff..voff + dh])
                        {
                            *acc += s * vv;
                        }
                    }
                }
            }
        }

        let o = matmul_bias(&ar, self.lw(layer, "wo")?, self.lw(layer, "bo")?, rows, d, d);
        let x1: Vec<f32> = x.iter().zip(&o).map(|(a, c)| a + c).collect();
        let h2 = layer_norm_rows(&x1, self.lw(layer, "ln2_g")?, self.lw(layer, "ln2_b")?, d);
        let mut f1 = matmul_bias(&h2, self.lw(layer, "w1")?, self.lw(layer, "b1")?, rows, d, dff);
        for fv in f1.iter_mut() {
            if *fv < 0.0 {
                *fv = 0.0;
            }
        }
        let f2 = matmul_bias(&f1, self.lw(layer, "w2")?, self.lw(layer, "b2")?, rows, dff, d);
        let x2: Vec<f32> = x1.iter().zip(&f2).map(|(a, c)| a + c).collect();

        // (B, SP, H, dh) -> (B, H, SP, dh) for the KV-cache consumers
        let mut kk = vec![0.0f32; b * h * sp * dh];
        let mut vv = vec![0.0f32; b * h * sp * dh];
        for bb in 0..b {
            for t in 0..sp {
                for hh in 0..h {
                    let src = (bb * sp + t) * d + hh * dh;
                    let dst = ((bb * h + hh) * sp + t) * dh;
                    kk[dst..dst + dh].copy_from_slice(&k[src..src + dh]);
                    vv[dst..dst + dh].copy_from_slice(&v[src..src + dh]);
                }
            }
        }
        Ok(vec![
            HostTensor::f32(vec![b, sp, d], x2),
            HostTensor::f32(vec![b, h, sp, dh], kk),
            HostTensor::f32(vec![b, h, sp, dh], vv),
        ])
    }
}

/// Pre-LN layer norm over rows of width `d` (population variance + 1e-5,
/// matching `model.layer_norm`).
fn layer_norm_rows(x: &[f32], g: &[f32], b: &[f32], d: usize) -> Vec<f32> {
    let rows = x.len() / d;
    let mut out = vec![0.0f32; x.len()];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mu = xr.iter().sum::<f32>() / d as f32;
        let var = xr.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        let or = &mut out[r * d..(r + 1) * d];
        for (((o, &xv), &gv), &bv) in or.iter_mut().zip(xr).zip(g).zip(b) {
            *o = (xv - mu) * inv * gv + bv;
        }
    }
    out
}

/// `out = x @ w + bias` with `x` (rows, din), `w` (din, dout) row-major.
fn matmul_bias(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    rows: usize,
    din: usize,
    dout: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * dout];
    for r in 0..rows {
        let xr = &x[r * din..(r + 1) * din];
        let or = &mut out[r * dout..(r + 1) * dout];
        or.copy_from_slice(bias);
        for (i, &xv) in xr.iter().enumerate() {
            let wr = &w[i * dout..(i + 1) * dout];
            for (o, &wv) in or.iter_mut().zip(wr) {
                *o += xv * wv;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Synthetic manifest (mirrors aot.py's registry so bucket/shape validation
// and `inspect` behave identically without artifacts on disk)
// ---------------------------------------------------------------------------

fn arg_in(name: &str, shape: Vec<Dim>, dtype: DType) -> ArgSpec {
    ArgSpec {
        name: name.to_string(),
        kind: ArgKind::Input,
        scope: WeightScope::Global,
        shape,
        dtype,
    }
}

fn arg_w(name: &str, shape: Vec<Dim>, scope: WeightScope) -> ArgSpec {
    ArgSpec {
        name: name.to_string(),
        kind: ArgKind::Weight,
        scope,
        shape,
        dtype: DType::F32,
    }
}

fn layer_args(meta: &ModelMeta, slots: &[&str]) -> Vec<ArgSpec> {
    slots
        .iter()
        .map(|s| {
            let shape = slot_shape(meta, s).into_iter().map(Dim::Fixed).collect();
            arg_w(s, shape, WeightScope::Layer)
        })
        .collect()
}

fn buckets_for(outputs: impl Fn(usize) -> Vec<OutSpec>, exe: &str) -> BTreeMap<usize, BucketSpec> {
    BATCH_BUCKETS
        .iter()
        .map(|&b| {
            (
                b,
                BucketSpec {
                    file: format!("native://{exe}__b{b}"),
                    outputs: outputs(b),
                },
            )
        })
        .collect()
}

/// Reference sharded decode attention over host-resident `(H, len, d)`
/// K/V: partitions the work exactly like the shard coordinator (head
/// subsets stay whole; context stripes split the token axis per the
/// topology's group map), computes every partial with the same
/// `sparse`/`select` arithmetic the CSD engine executes, and merges on
/// the "GPU" (a single partial per head for head policies — the
/// log-sum-exp of one partial is itself, bit-exactly — and the
/// flash-decoding combine for context stripes).  The shard crosscheck
/// tests pin the functional engine against this.
pub fn sharded_reference_attention(
    q_hd: &[f32],
    k_hsd: &[f32],
    v_hsd: &[f32],
    len: usize,
    d: usize,
    topology: &crate::shard::ShardTopology,
) -> Vec<f32> {
    use crate::shard::merge::{lse_merge, Partial};
    use crate::sparse::select::{softmax_masked, NEG_INF};
    let h = topology.n_heads;
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0.0f32; h * d];
    for hh in 0..h {
        let q = &q_hd[hh * d..(hh + 1) * d];
        let base = hh * len * d;
        let mut parts: Vec<Partial> = Vec::new();
        for c in 0..topology.n_csds {
            let llen = topology.local_len(c, len);
            if llen == 0 {
                continue;
            }
            let mut logits = vec![NEG_INF; llen];
            for (lt, lg) in logits.iter_mut().enumerate() {
                let t = topology.to_global(c, lt);
                *lg = dot(q, &k_hsd[base + t * d..base + (t + 1) * d]) * scale;
            }
            let mask = vec![true; llen];
            let s = softmax_masked(&logits, &mask);
            let mut m = NEG_INF;
            for &x in &logits {
                if x > m {
                    m = x;
                }
            }
            let mut l = 0.0f32;
            for &x in &logits {
                l += (x - m).exp();
            }
            let mut po = vec![0.0f32; d];
            for (lt, &w) in s.iter().enumerate() {
                if w == 0.0 {
                    continue;
                }
                let t = topology.to_global(c, lt);
                for cc in 0..d {
                    po[cc] += w * v_hsd[base + t * d + cc];
                }
            }
            parts.push(Partial { out: po, m, l });
        }
        out[hh * d..(hh + 1) * d].copy_from_slice(&lse_merge(&parts, d));
    }
    out
}

/// Build an in-memory manifest describing the native executables — the
/// same signatures `aot.py` records, with no files behind them.
pub fn synthetic_manifest(dir: PathBuf, meta: &ModelMeta) -> Manifest {
    use Dim::{Batch as B, Fixed as F};
    let (d, h, dh, dff, s, sp, v) = (
        meta.d_model,
        meta.n_heads,
        meta.d_head,
        meta.d_ffn,
        meta.max_seq,
        meta.prefill_seq,
        meta.vocab,
    );
    let f32o = |shape: Vec<usize>| OutSpec { shape, dtype: DType::F32 };
    let i32o = |shape: Vec<usize>| OutSpec { shape, dtype: DType::I32 };

    let mut executables = BTreeMap::new();
    executables.insert(
        "embed_decode".to_string(),
        ExeSpec {
            args: vec![
                arg_in("ids", vec![B], DType::I32),
                arg_in("pos", vec![B], DType::I32),
                arg_w("tok_emb", vec![F(v), F(d)], WeightScope::Global),
                arg_w("pos_emb", vec![F(s), F(d)], WeightScope::Global),
            ],
            buckets: buckets_for(|b| vec![f32o(vec![b, d])], "embed_decode"),
        },
    );
    executables.insert(
        "qkv_proj".to_string(),
        ExeSpec {
            args: {
                let mut a = vec![arg_in("x", vec![B, F(d)], DType::F32)];
                a.extend(layer_args(meta, &["ln1_g", "ln1_b", "wq", "bq", "wk", "bk", "wv", "bv"]));
                a
            },
            buckets: buckets_for(|b| vec![f32o(vec![b, h, dh]); 3], "qkv_proj"),
        },
    );
    for exe in ["attn_dense", "attn_sparf"] {
        executables.insert(
            exe.to_string(),
            ExeSpec {
                args: vec![
                    arg_in("q", vec![B, F(h), F(dh)], DType::F32),
                    arg_in("K", vec![B, F(h), F(s), F(dh)], DType::F32),
                    arg_in("V", vec![B, F(h), F(s), F(dh)], DType::F32),
                    arg_in("lens", vec![B], DType::F32),
                ],
                buckets: buckets_for(|b| vec![f32o(vec![b, h, dh])], exe),
            },
        );
    }
    executables.insert(
        "post_attn".to_string(),
        ExeSpec {
            args: {
                let mut a = vec![
                    arg_in("x", vec![B, F(d)], DType::F32),
                    arg_in("attn", vec![B, F(h), F(dh)], DType::F32),
                ];
                a.extend(layer_args(meta, &["wo", "bo", "ln2_g", "ln2_b", "w1", "b1", "w2", "b2"]));
                a
            },
            buckets: buckets_for(|b| vec![f32o(vec![b, d])], "post_attn"),
        },
    );
    executables.insert(
        "logits".to_string(),
        ExeSpec {
            args: vec![
                arg_in("x", vec![B, F(d)], DType::F32),
                arg_w("ln_f_g", vec![F(d)], WeightScope::Global),
                arg_w("ln_f_b", vec![F(d)], WeightScope::Global),
                arg_w("tok_emb", vec![F(v), F(d)], WeightScope::Global),
            ],
            buckets: buckets_for(|b| vec![f32o(vec![b, v]), i32o(vec![b])], "logits"),
        },
    );
    executables.insert(
        "embed_prefill".to_string(),
        ExeSpec {
            args: vec![
                arg_in("ids", vec![B, F(sp)], DType::I32),
                arg_w("tok_emb", vec![F(v), F(d)], WeightScope::Global),
                arg_w("pos_emb", vec![F(s), F(d)], WeightScope::Global),
            ],
            buckets: buckets_for(|b| vec![f32o(vec![b, sp, d])], "embed_prefill"),
        },
    );
    executables.insert(
        "prefill_block".to_string(),
        ExeSpec {
            args: {
                let mut a = vec![arg_in("x", vec![B, F(sp), F(d)], DType::F32)];
                a.extend(layer_args(meta, &LAYER_SLOTS));
                a
            },
            buckets: buckets_for(
                |b| {
                    vec![
                        f32o(vec![b, sp, d]),
                        f32o(vec![b, h, sp, dh]),
                        f32o(vec![b, h, sp, dh]),
                    ]
                },
                "prefill_block",
            ),
        },
    );

    // weight records with as-if-packed offsets (native keeps them in
    // memory; offsets exist so `inspect` and tooling see a real layout)
    let mut weights = BTreeMap::new();
    let mut offset = 0u64;
    let mut push = |weights: &mut BTreeMap<String, TensorRec>, name: String, shape: Vec<usize>| {
        let len: usize = shape.iter().product();
        weights.insert(
            name.clone(),
            TensorRec { name, offset, shape, dtype: DType::F32 },
        );
        offset += (len * 4) as u64;
    };
    push(&mut weights, "tok_emb".into(), vec![v, d]);
    push(&mut weights, "pos_emb".into(), vec![s, d]);
    for layer in 0..meta.n_layers {
        for slot in LAYER_SLOTS {
            push(&mut weights, format!("layers.{layer}.{slot}"), slot_shape(meta, slot));
        }
    }
    push(&mut weights, "ln_f_g".into(), vec![d]);
    push(&mut weights, "ln_f_b".into(), vec![d]);

    Manifest {
        dir,
        model: meta.clone(),
        batch_buckets: BATCH_BUCKETS.to_vec(),
        executables,
        weights,
        golden: BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthesize_is_deterministic() {
        let a = NativeModel::synthesize(7);
        let b = NativeModel::synthesize(7);
        let wa = a.weight_host("layers.0.wq").unwrap();
        let wb = b.weight_host("layers.0.wq").unwrap();
        assert_eq!(wa, wb);
        let c = NativeModel::synthesize(8);
        let wc = c.weight_host("layers.0.wq").unwrap();
        assert_ne!(wa, wc);
    }

    #[test]
    fn op_shapes_match_manifest() {
        let model = NativeModel::synthesize(1);
        let meta = model.meta.clone();
        let man = synthetic_manifest(PathBuf::from("."), &meta);
        let b = 4usize;
        let ids = HostTensor::i32(vec![b], vec![1, 2, 3, 4]);
        let pos = HostTensor::i32(vec![b], vec![0, 1, 2, 3]);
        let x = model.call("embed_decode", b, 0, &[ids, pos]).unwrap().remove(0);
        assert_eq!(x.dims, vec![b, meta.d_model]);
        let qkv = model.call("qkv_proj", b, 0, &[x.clone()]).unwrap();
        assert_eq!(qkv.len(), 3);
        assert_eq!(qkv[0].dims, vec![b, meta.n_heads, meta.d_head]);
        let kc = HostTensor::zeros_f32(vec![b, meta.n_heads, meta.max_seq, meta.d_head]);
        let lens = HostTensor::f32(vec![b], vec![4.0; b]);
        let a = model
            .call("attn_dense", b, 0, &[qkv[0].clone(), kc.clone(), kc.clone(), lens])
            .unwrap()
            .remove(0);
        assert_eq!(a.dims, vec![b, meta.n_heads, meta.d_head]);
        let x2 = model.call("post_attn", b, 0, &[x, a]).unwrap().remove(0);
        let lg = model.call("logits", b, 0, &[x2]).unwrap();
        assert_eq!(lg[0].dims, vec![b, meta.vocab]);
        assert_eq!(lg[1].dims, vec![b]);
        // every executable in the synthetic manifest has every bucket
        for (name, exe) in &man.executables {
            for bb in &man.batch_buckets {
                assert!(exe.buckets.contains_key(bb), "{name} missing bucket {bb}");
            }
        }
    }

    #[test]
    fn layer_norm_normalizes() {
        let d = 8;
        let x: Vec<f32> = (0..d).map(|i| i as f32).collect();
        let g = vec![1.0f32; d];
        let b = vec![0.0f32; d];
        let y = layer_norm_rows(&x, &g, &b, d);
        let mu: f32 = y.iter().sum::<f32>() / d as f32;
        let var: f32 = y.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        assert!(mu.abs() < 1e-5, "mean {mu}");
        assert!((var - 1.0).abs() < 1e-2, "var {var}");
    }

    #[test]
    fn prefill_block_causal_first_row_ignores_future() {
        // Row 0 of the prefill attention must not depend on later tokens:
        // changing token t>0 must leave x'[0]'s attention contribution
        // unchanged up to the (token-independent) LN/FFN path.
        let model = NativeModel::synthesize(2);
        let meta = model.meta.clone();
        let sp = meta.prefill_seq;
        let mk = |second: i32| {
            let mut ids = vec![0i32; sp];
            ids[0] = 5;
            ids[1] = second;
            let t = HostTensor::i32(vec![1, sp], ids);
            let x = model.call("embed_prefill", 1, 0, &[t]).unwrap().remove(0);
            model.call("prefill_block", 1, 0, &[x]).unwrap().remove(0)
        };
        let a = mk(7);
        let b = mk(400);
        let d = meta.d_model;
        let ra = &a.as_f32().unwrap()[0..d];
        let rb = &b.as_f32().unwrap()[0..d];
        for (x, y) in ra.iter().zip(rb) {
            assert!((x - y).abs() < 1e-5, "row 0 changed: {x} vs {y}");
        }
    }
}
