//! Host tensors: the typed boundary between the coordinator and PJRT.

use anyhow::{bail, Context, Result};
use std::io::{Read, Seek, SeekFrom};

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor { dims, data: TensorData::F32(data) }
    }

    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor { dims, data: TensorData::I32(data) }
    }

    pub fn zeros_f32(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        HostTensor { dims, data: TensorData::F32(vec![0.0; n]) }
    }

    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => bail!("tensor is f32, expected i32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    /// Pad the leading (batch) dimension with zeros up to `b`.
    pub fn pad_batch(&self, b: usize) -> Result<HostTensor> {
        let cur = *self.dims.first().context("pad_batch on rank-0 tensor")?;
        if cur > b {
            bail!("cannot pad batch {cur} down to {b}");
        }
        let row = self.len() / cur.max(1);
        let mut dims = self.dims.clone();
        dims[0] = b;
        Ok(match &self.data {
            TensorData::F32(v) => {
                let mut out = vec![0.0f32; row * b];
                out[..v.len()].copy_from_slice(v);
                HostTensor { dims, data: TensorData::F32(out) }
            }
            TensorData::I32(v) => {
                let mut out = vec![0i32; row * b];
                out[..v.len()].copy_from_slice(v);
                HostTensor { dims, data: TensorData::I32(out) }
            }
        })
    }

    /// Truncate the leading (batch) dimension to `b`.
    pub fn trim_batch(&self, b: usize) -> HostTensor {
        let cur = self.dims[0];
        assert!(b <= cur);
        let row = self.len() / cur.max(1);
        let mut dims = self.dims.clone();
        dims[0] = b;
        match &self.data {
            TensorData::F32(v) => HostTensor { dims, data: TensorData::F32(v[..row * b].to_vec()) },
            TensorData::I32(v) => HostTensor { dims, data: TensorData::I32(v[..row * b].to_vec()) },
        }
    }

    /// Max absolute difference against another f32 tensor.
    pub fn max_abs_diff(&self, other: &HostTensor) -> Result<f32> {
        let a = self.as_f32()?;
        let b = other.as_f32()?;
        if a.len() != b.len() {
            bail!("length mismatch {} vs {}", a.len(), b.len());
        }
        Ok(a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max))
    }
}

/// Read `count` f32 values at byte `offset` from an open file.
pub fn read_f32_at(f: &mut std::fs::File, offset: u64, count: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; count * 4];
    f.seek(SeekFrom::Start(offset))?;
    f.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read `count` i32 values at byte `offset` from an open file.
pub fn read_i32_at(f: &mut std::fs::File, offset: u64, count: usize) -> Result<Vec<i32>> {
    let mut bytes = vec![0u8; count * 4];
    f.seek(SeekFrom::Start(offset))?;
    f.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_and_trim_roundtrip() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let p = t.pad_batch(4).unwrap();
        assert_eq!(p.dims, vec![4, 3]);
        assert_eq!(p.as_f32().unwrap()[6..], [0.0; 6]);
        let back = p.trim_batch(2);
        assert_eq!(back, t);
    }

    #[test]
    fn type_mismatch_errors() {
        let t = HostTensor::i32(vec![2], vec![1, 2]);
        assert!(t.as_f32().is_err());
        assert!(t.as_i32().is_ok());
    }

    #[test]
    fn max_abs_diff_works() {
        let a = HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0]);
        let b = HostTensor::f32(vec![3], vec![1.0, 2.5, 3.0]);
        assert_eq!(a.max_abs_diff(&b).unwrap(), 0.5);
    }
}
