//! Runtime: execute the model operator set from the rust request path.
//!
//! * [`tensor`]   — host-side tensors + raw .bin readers
//! * [`manifest`] — typed view of `artifacts/manifest.json` (or the
//!                  synthetic manifest when no artifacts exist)
//! * [`native`]   — rust reference backend (always built; synthesizes a
//!                  deterministic opt-micro model without artifacts)
//! * [`client`]   — the `Runtime` facade: validation, stats, backend
//!                  dispatch
//! * [`pjrt`]     — PJRT CPU backend over the AOT HLO artifacts
//!                  (`--features pjrt`; needs the `xla` bindings)
//! * [`golden`]   — cross-language checks against `golden.bin`

pub mod client;
pub mod golden;
pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod tensor;

pub use client::{Runtime, RuntimeStats};
pub use manifest::{ArgKind, DType, Dim, Manifest};
pub use tensor::HostTensor;
