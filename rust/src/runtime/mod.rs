//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and execute them from the rust request path (python is build-time only).
//!
//! * [`tensor`]   — host-side tensors + raw .bin readers
//! * [`manifest`] — typed view of `artifacts/manifest.json`
//! * [`client`]   — PJRT CPU client, executable cache, device-resident
//!                  weights, typed call interface
//! * [`golden`]   — cross-language checks against `golden.bin`

pub mod client;
pub mod golden;
pub mod manifest;
pub mod tensor;

pub use client::Runtime;
pub use manifest::{ArgKind, DType, Dim, Manifest};
pub use tensor::HostTensor;
