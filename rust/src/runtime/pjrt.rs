//! PJRT CPU backend: compile HLO-text artifacts once, keep weights
//! device-resident, execute from the decode hot loop with buffer reuse.
//!
//! Pattern from /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute_b`.
//! Artifacts are lowered with `return_tuple=True`, so every executable
//! returns a single tuple literal that we decompose.
//!
//! Only compiled with `--features pjrt`: the `xla` bindings are not part
//! of the offline crate set.  The default build executes the same
//! operator set through [`super::native`].

use super::manifest::{ArgKind, BucketSpec, DType, Manifest};
use super::tensor::{HostTensor, TensorData};
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::fs::File;

struct CompiledExe {
    exe: xla::PjRtLoadedExecutable,
    out_dtypes: Vec<DType>,
    out_shapes: Vec<Vec<usize>>,
}

/// The PJRT execution backend: one per process; not Sync (PJRT handles
/// are raw pointers) — the coordinator pins it to the executor thread.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: RefCell<HashMap<(String, usize), std::rc::Rc<CompiledExe>>>,
    weight_bufs: RefCell<HashMap<String, std::rc::Rc<xla::PjRtBuffer>>>,
    weights_file: RefCell<File>,
}

impl PjrtBackend {
    /// Open the artifact directory's PJRT side (after `make artifacts`).
    pub fn open(manifest: &Manifest) -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let wpath = manifest.dir.join("weights.bin");
        let weights_file = File::open(&wpath)
            .with_context(|| format!("opening {wpath:?}"))?;
        Ok(PjrtBackend {
            client,
            manifest: manifest.clone(),
            exes: RefCell::new(HashMap::new()),
            weight_bufs: RefCell::new(HashMap::new()),
            weights_file: RefCell::new(weights_file),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) executable `name` at batch bucket `b`.
    fn compiled(&self, name: &str, b: usize) -> Result<std::rc::Rc<CompiledExe>> {
        let key = (name.to_string(), b);
        if let Some(e) = self.exes.borrow().get(&key) {
            return Ok(e.clone());
        }
        let spec = self.manifest.exe(name)?;
        let bucket: &BucketSpec = spec
            .buckets
            .get(&b)
            .ok_or_else(|| anyhow!("{name}: no bucket for batch {b}"))?;
        let path = self.manifest.dir.join(&bucket.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name} b{b}: {e:?}"))?;
        let ce = std::rc::Rc::new(CompiledExe {
            exe,
            out_dtypes: bucket.outputs.iter().map(|o| o.dtype).collect(),
            out_shapes: bucket.outputs.iter().map(|o| o.shape.clone()).collect(),
        });
        self.exes.borrow_mut().insert(key, ce.clone());
        Ok(ce)
    }

    /// Eagerly compile every executable at every bucket (startup warmup so
    /// the request path never pays compile latency).
    pub fn warmup(&self) -> Result<usize> {
        let names: Vec<(String, usize)> = self
            .manifest
            .executables
            .iter()
            .flat_map(|(n, e)| e.buckets.keys().map(move |b| (n.clone(), *b)))
            .collect();
        for (n, b) in &names {
            self.compiled(n, *b)?;
        }
        Ok(names.len())
    }

    /// Device-resident weight buffer (uploaded once, then reused).
    fn weight_buffer(&self, pname: &str) -> Result<std::rc::Rc<xla::PjRtBuffer>> {
        if let Some(b) = self.weight_bufs.borrow().get(pname) {
            return Ok(b.clone());
        }
        let rec = self
            .manifest
            .weights
            .get(pname)
            .ok_or_else(|| anyhow!("weight {pname:?} not in manifest"))?;
        let data = super::tensor::read_f32_at(
            &mut self.weights_file.borrow_mut(),
            rec.offset,
            rec.len(),
        )?;
        let buf = self
            .client
            .buffer_from_host_buffer(&data, &rec.shape, None)
            .map_err(|e| anyhow!("uploading {pname}: {e:?}"))?;
        let rc = std::rc::Rc::new(buf);
        self.weight_bufs.borrow_mut().insert(pname.to_string(), rc.clone());
        Ok(rc)
    }

    fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        match &t.data {
            TensorData::F32(v) => self
                .client
                .buffer_from_host_buffer(v, &t.dims, None)
                .map_err(|e| anyhow!("upload f32: {e:?}")),
            TensorData::I32(v) => self
                .client
                .buffer_from_host_buffer(v, &t.dims, None)
                .map_err(|e| anyhow!("upload i32: {e:?}")),
        }
    }

    /// Execute `name` at bucket `b`, binding layer-scoped weights for
    /// `layer`.  `inputs` must match the manifest's input args in order;
    /// batch dims must already equal `b` (use `HostTensor::pad_batch`).
    pub fn call(
        &self,
        name: &str,
        b: usize,
        layer: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let ce = self.compiled(name, b)?;
        let spec = self.manifest.exe(name)?;

        let mut args: Vec<std::rc::Rc<xla::PjRtBuffer>> = Vec::with_capacity(spec.args.len());
        let mut in_iter = inputs.iter();
        for a in &spec.args {
            match a.kind {
                ArgKind::Input => {
                    let t = in_iter
                        .next()
                        .ok_or_else(|| anyhow!("{name}: missing input {:?}", a.name))?;
                    let want = a.concrete_shape(b);
                    if t.dims != want {
                        bail!(
                            "{name}: input {:?} shape {:?} != expected {:?}",
                            a.name, t.dims, want
                        );
                    }
                    args.push(std::rc::Rc::new(self.upload(t)?));
                }
                ArgKind::Weight => {
                    let pname = self.manifest.weight_name(a, layer);
                    args.push(self.weight_buffer(&pname)?);
                }
            }
        }
        if in_iter.next().is_some() {
            bail!("{name}: too many inputs supplied");
        }

        let borrowed: Vec<&xla::PjRtBuffer> = args.iter().map(|r| r.as_ref()).collect();
        let result = ce
            .exe
            .execute_b(&borrowed)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;

        // return_tuple=True => single tuple output buffer
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("download {name}: {e:?}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        if parts.len() != ce.out_dtypes.len() {
            bail!(
                "{name}: got {} outputs, manifest says {}",
                parts.len(),
                ce.out_dtypes.len()
            );
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (i, part) in parts.into_iter().enumerate() {
            let dims = ce.out_shapes[i].clone();
            let t = match ce.out_dtypes[i] {
                DType::F32 => HostTensor::f32(
                    dims,
                    part.to_vec::<f32>()
                        .map_err(|e| anyhow!("{name} out{i} as f32: {e:?}"))?,
                ),
                DType::I32 => HostTensor::i32(
                    dims,
                    part.to_vec::<i32>()
                        .map_err(|e| anyhow!("{name} out{i} as i32: {e:?}"))?,
                ),
            };
            outs.push(t);
        }
        Ok(outs)
    }

    /// Read a weight tensor back to the host.
    pub fn weight_host(&self, pname: &str) -> Result<HostTensor> {
        let rec = self
            .manifest
            .weights
            .get(pname)
            .ok_or_else(|| anyhow!("weight {pname:?} not in manifest"))?;
        let data = super::tensor::read_f32_at(
            &mut self.weights_file.borrow_mut(),
            rec.offset,
            rec.len(),
        )?;
        Ok(HostTensor::f32(rec.shape.clone(), data))
    }
}
