//! The runtime facade: a manifest-typed call interface over whichever
//! execution backend is available.
//!
//! * **native** (always built) — [`super::native::NativeModel`], the
//!   rust reference implementation of the operator set.  Used with real
//!   artifact weights when `manifest.json`/`weights.bin` exist, or with
//!   a deterministically synthesized opt-micro model when they don't —
//!   so the full stack runs without the python/jax toolchain.
//! * **pjrt** (`--features pjrt`) — [`super::pjrt::PjrtBackend`], the
//!   AOT-compiled HLO artifacts through the PJRT C API.
//!
//! Input validation (arity + shapes against the manifest) happens here,
//! so both backends reject malformed calls identically.

use super::manifest::{ArgKind, Manifest};
use super::native::{self, NativeModel};
use super::tensor::HostTensor;
use anyhow::{anyhow, bail, Result};
use std::cell::RefCell;
use std::path::Path;

/// Timing counters for the §Perf pass (nanoseconds, monotone totals);
/// `execute_ns` covers the whole backend call including host<->device
/// transfers.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub calls: u64,
    pub execute_ns: u64,
}

enum Backend {
    Native(NativeModel),
    #[cfg(feature = "pjrt")]
    Pjrt(super::pjrt::PjrtBackend),
}

/// The functional-plane runtime: one per process; not Sync — the
/// coordinator pins it to the executor thread.
pub struct Runtime {
    pub manifest: Manifest,
    backend: Backend,
    pub stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Open an artifact directory.  If `manifest.json` is present the
    /// recorded model is used (PJRT execution with `--features pjrt`,
    /// native execution of the recorded weights otherwise); if absent, a
    /// deterministic synthesized opt-micro model stands in so the stack
    /// runs without `make artifacts`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref();
        if dir.join("manifest.json").exists() {
            let manifest = Manifest::load(dir)?;
            #[cfg(feature = "pjrt")]
            {
                let backend = Backend::Pjrt(super::pjrt::PjrtBackend::open(&manifest)?);
                return Ok(Runtime {
                    manifest,
                    backend,
                    stats: RefCell::new(RuntimeStats::default()),
                });
            }
            #[cfg(not(feature = "pjrt"))]
            {
                let model = NativeModel::from_manifest(&manifest)?;
                return Ok(Runtime {
                    manifest,
                    backend: Backend::Native(model),
                    stats: RefCell::new(RuntimeStats::default()),
                });
            }
        }
        // Make the substitution loud: a mistyped --artifacts path should
        // not silently produce synthetic-model numbers.
        eprintln!(
            "note: no manifest.json under {dir:?} — running the synthesized \
             native opt-micro model (run `make artifacts` for the recorded one)"
        );
        let model = NativeModel::synthesize(native::DEFAULT_SEED);
        let manifest = native::synthetic_manifest(dir.to_path_buf(), &model.meta);
        Ok(Runtime {
            manifest,
            backend: Backend::Native(model),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Native(_) => "native-rust".to_string(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.platform(),
        }
    }

    /// True when running the synthesized/loaded rust reference backend.
    pub fn is_native(&self) -> bool {
        matches!(self.backend, Backend::Native(_))
    }

    /// Eagerly prepare every executable at every bucket (startup warmup
    /// so the request path never pays compile latency).  Returns the
    /// number of (executable, bucket) pairs.
    pub fn warmup(&self) -> Result<usize> {
        match &self.backend {
            Backend::Native(_) => Ok(self
                .manifest
                .executables
                .values()
                .map(|e| e.buckets.len())
                .sum()),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.warmup(),
        }
    }

    /// Execute `name` at bucket `b`, binding layer-scoped weights for
    /// `layer`.  `inputs` must match the manifest's input args in order;
    /// batch dims must already equal `b` (use `HostTensor::pad_batch`).
    pub fn call(
        &self,
        name: &str,
        b: usize,
        layer: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        self.validate(name, b, inputs)?;
        let t0 = std::time::Instant::now();
        let outs = match &self.backend {
            Backend::Native(m) => m.call(name, b, layer, inputs)?,
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.call(name, b, layer, inputs)?,
        };
        let mut st = self.stats.borrow_mut();
        st.calls += 1;
        st.execute_ns += t0.elapsed().as_nanos() as u64;
        Ok(outs)
    }

    /// Check arity and shapes against the manifest's signature.
    fn validate(&self, name: &str, b: usize, inputs: &[HostTensor]) -> Result<()> {
        let spec = self.manifest.exe(name)?;
        if !spec.buckets.contains_key(&b) {
            bail!("{name}: no bucket for batch {b}");
        }
        let mut in_iter = inputs.iter();
        for a in &spec.args {
            if a.kind != ArgKind::Input {
                continue;
            }
            let t = in_iter
                .next()
                .ok_or_else(|| anyhow!("{name}: missing input {:?}", a.name))?;
            let want = a.concrete_shape(b);
            if t.dims != want {
                bail!(
                    "{name}: input {:?} shape {:?} != expected {:?}",
                    a.name, t.dims, want
                );
            }
        }
        if in_iter.next().is_some() {
            bail!("{name}: too many inputs supplied");
        }
        Ok(())
    }

    /// Read a weight tensor back to the host (for the rust-native CSD
    /// engine, which needs raw K/V projection weights — and for tests).
    pub fn weight_host(&self, pname: &str) -> Result<HostTensor> {
        match &self.backend {
            Backend::Native(m) => m.weight_host(pname),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.weight_host(pname),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nonexistent_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("no-such-artifacts-dir")
    }

    #[test]
    fn open_without_artifacts_synthesizes_native_model() {
        let rt = Runtime::open(nonexistent_dir()).unwrap();
        assert!(rt.is_native());
        assert_eq!(rt.platform(), "native-rust");
        assert_eq!(rt.manifest.model.d_model, 256);
        assert!(rt.manifest.golden.is_empty());
        assert!(rt.warmup().unwrap() >= 8 * 3);
    }

    #[test]
    fn call_validates_like_the_manifest_says() {
        let rt = Runtime::open(nonexistent_dir()).unwrap();
        let bad = HostTensor::zeros_f32(vec![1, 3]);
        let err = rt.call("qkv_proj", 1, 0, &[bad]).unwrap_err().to_string();
        assert!(err.contains("shape"), "{err}");
        let err = rt.call("attn_dense", 1, 0, &[]).unwrap_err().to_string();
        assert!(err.contains("missing input"), "{err}");
        let err = rt
            .call("qkv_proj", 3, 0, &[HostTensor::zeros_f32(vec![3, 256])])
            .unwrap_err()
            .to_string();
        assert!(err.contains("bucket"), "{err}");
    }

    #[test]
    fn native_decode_roundtrip_generates_in_vocab() {
        let rt = Runtime::open(nonexistent_dir()).unwrap();
        let m = rt.manifest.model.clone();
        let b = 1usize;
        let ids = HostTensor::i32(vec![b], vec![42]);
        let pos = HostTensor::i32(vec![b], vec![0]);
        let mut x = rt.call("embed_decode", b, 0, &[ids, pos]).unwrap().remove(0);
        for layer in 0..m.n_layers {
            let qkv = rt.call("qkv_proj", b, layer, &[x.clone()]).unwrap();
            let kc = HostTensor::zeros_f32(vec![b, m.n_heads, m.max_seq, m.d_head]);
            let lens = HostTensor::f32(vec![b], vec![1.0]);
            let a = rt
                .call("attn_dense", b, 0, &[qkv[0].clone(), kc.clone(), kc, lens])
                .unwrap()
                .remove(0);
            x = rt.call("post_attn", b, layer, &[x, a]).unwrap().remove(0);
        }
        let out = rt.call("logits", b, 0, &[x]).unwrap();
        let id = out[1].as_i32().unwrap()[0];
        assert!((0..m.vocab as i32).contains(&id));
        assert!(rt.stats.borrow().calls >= 10);
    }
}
