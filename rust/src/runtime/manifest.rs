//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype {other:?}"),
        }
    }
}

/// A dimension in a manifest shape: the batch symbol or a fixed size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dim {
    Batch,
    Fixed(usize),
}

impl Dim {
    pub fn concrete(&self, b: usize) -> usize {
        match self {
            Dim::Batch => b,
            Dim::Fixed(n) => *n,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArgKind {
    Input,
    Weight,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightScope {
    Global,
    Layer,
}

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub kind: ArgKind,
    pub scope: WeightScope,
    pub shape: Vec<Dim>,
    pub dtype: DType,
}

impl ArgSpec {
    pub fn concrete_shape(&self, b: usize) -> Vec<usize> {
        self.shape.iter().map(|d| d.concrete(b)).collect()
    }
}

#[derive(Debug, Clone)]
pub struct OutSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

#[derive(Debug, Clone)]
pub struct BucketSpec {
    pub file: String,
    pub outputs: Vec<OutSpec>,
}

#[derive(Debug, Clone)]
pub struct ExeSpec {
    pub args: Vec<ArgSpec>,
    pub buckets: BTreeMap<usize, BucketSpec>,
}

impl ExeSpec {
    pub fn inputs(&self) -> impl Iterator<Item = &ArgSpec> {
        self.args.iter().filter(|a| a.kind == ArgKind::Input)
    }
}

/// Location of one tensor inside weights.bin / golden.bin.
#[derive(Debug, Clone)]
pub struct TensorRec {
    pub name: String,
    pub offset: u64,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorRec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[derive(Debug, Clone)]
pub struct GoldenRec {
    pub batch: usize,
    pub layer: usize,
    pub inputs: Vec<TensorRec>,
    pub outputs: Vec<TensorRec>,
}

/// Metadata of the functional-plane model (matches python SMALL config).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ffn: usize,
    pub n_layers: usize,
    pub max_seq: usize,
    pub prefill_seq: usize,
    pub r: usize,
    pub k: usize,
    pub m: usize,
    pub n: usize,
}

impl ModelMeta {
    /// The model's default SparF parameters — the single source for
    /// every call site that used to hand-roll
    /// `SparsityParams { r, k, m, n }` from these fields.
    pub fn sparsity(&self) -> crate::config::model::SparsityParams {
        crate::config::model::SparsityParams { r: self.r, k: self.k, m: self.m, n: self.n }
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelMeta,
    pub batch_buckets: Vec<usize>,
    pub executables: BTreeMap<String, ExeSpec>,
    pub weights: BTreeMap<String, TensorRec>,
    pub golden: BTreeMap<String, GoldenRec>,
}

fn usize_of(j: &Json, key: &str) -> Result<usize> {
    j.req(key)?
        .as_usize()
        .ok_or_else(|| anyhow!("{key} is not a number"))
}

fn dims_of(arr: &Json) -> Result<Vec<Dim>> {
    arr.as_arr()
        .context("shape not an array")?
        .iter()
        .map(|d| match d {
            Json::Str(s) if s == "B" => Ok(Dim::Batch),
            Json::Num(n) => Ok(Dim::Fixed(*n as usize)),
            other => bail!("bad dim {other:?}"),
        })
        .collect()
}

fn fixed_shape_of(arr: &Json) -> Result<Vec<usize>> {
    arr.as_arr()
        .context("shape not an array")?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("non-numeric dim")))
        .collect()
}

fn tensor_rec(name: String, j: &Json) -> Result<TensorRec> {
    Ok(TensorRec {
        name,
        offset: usize_of(j, "offset")? as u64,
        shape: fixed_shape_of(j.req("shape")?)?,
        dtype: DType::parse(j.req("dtype")?.as_str().context("dtype")?)?,
    })
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;

        let m = j.req("model")?;
        let model = ModelMeta {
            name: m.req("name")?.as_str().context("name")?.to_string(),
            vocab: usize_of(m, "vocab")?,
            d_model: usize_of(m, "d_model")?,
            n_heads: usize_of(m, "n_heads")?,
            d_head: usize_of(m, "d_head")?,
            d_ffn: usize_of(m, "d_ffn")?,
            n_layers: usize_of(m, "n_layers")?,
            max_seq: usize_of(m, "max_seq")?,
            prefill_seq: usize_of(m, "prefill_seq")?,
            r: usize_of(m, "r")?,
            k: usize_of(m, "k")?,
            m: usize_of(m, "m")?,
            n: usize_of(m, "n")?,
        };

        let batch_buckets = j
            .req("batch_buckets")?
            .as_arr()
            .context("batch_buckets")?
            .iter()
            .map(|b| b.as_usize().context("bucket"))
            .collect::<Result<Vec<_>>>()?;

        let mut executables = BTreeMap::new();
        for (name, spec) in j.req("executables")?.as_obj().context("executables")? {
            let mut args = Vec::new();
            for a in spec.req("args")?.as_arr().context("args")? {
                args.push(ArgSpec {
                    name: a.req("name")?.as_str().context("arg name")?.to_string(),
                    kind: match a.req("kind")?.as_str().context("kind")? {
                        "input" => ArgKind::Input,
                        "weight" => ArgKind::Weight,
                        other => bail!("bad arg kind {other:?}"),
                    },
                    scope: match a.req("scope")?.as_str().context("scope")? {
                        "global" => WeightScope::Global,
                        "layer" => WeightScope::Layer,
                        other => bail!("bad scope {other:?}"),
                    },
                    shape: dims_of(a.req("shape")?)?,
                    dtype: DType::parse(a.req("dtype")?.as_str().context("dtype")?)?,
                });
            }
            let mut buckets = BTreeMap::new();
            for (b, bj) in spec.req("buckets")?.as_obj().context("buckets")? {
                let outputs = bj
                    .req("outputs")?
                    .as_arr()
                    .context("outputs")?
                    .iter()
                    .map(|o| {
                        Ok(OutSpec {
                            shape: fixed_shape_of(o.req("shape")?)?,
                            dtype: DType::parse(o.req("dtype")?.as_str().context("dtype")?)?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                buckets.insert(
                    b.parse::<usize>().context("bucket key")?,
                    BucketSpec {
                        file: bj.req("file")?.as_str().context("file")?.to_string(),
                        outputs,
                    },
                );
            }
            executables.insert(name.clone(), ExeSpec { args, buckets });
        }

        let mut weights = BTreeMap::new();
        for (name, w) in j.req("weights")?.as_obj().context("weights")? {
            weights.insert(name.clone(), tensor_rec(name.clone(), w)?);
        }

        let mut golden = BTreeMap::new();
        for (name, g) in j.req("golden")?.as_obj().context("golden")? {
            let inputs = g
                .req("inputs")?
                .as_arr()
                .context("golden inputs")?
                .iter()
                .map(|i| {
                    tensor_rec(
                        i.req("name")?.as_str().context("name")?.to_string(),
                        i,
                    )
                })
                .collect::<Result<Vec<_>>>()?;
            let outputs = g
                .req("outputs")?
                .as_arr()
                .context("golden outputs")?
                .iter()
                .enumerate()
                .map(|(idx, o)| tensor_rec(format!("out{idx}"), o))
                .collect::<Result<Vec<_>>>()?;
            golden.insert(
                name.clone(),
                GoldenRec {
                    batch: usize_of(g, "batch")?,
                    layer: usize_of(g, "layer")?,
                    inputs,
                    outputs,
                },
            );
        }

        Ok(Manifest { dir, model, batch_buckets, executables, weights, golden })
    }

    /// Resolve the weight-bin tensor name for an argument of `exe` bound at
    /// `layer` (layer-scoped slots become `layers.{i}.<slot>`).
    pub fn weight_name(&self, arg: &ArgSpec, layer: usize) -> String {
        match arg.scope {
            WeightScope::Global => arg.name.clone(),
            WeightScope::Layer => format!("layers.{layer}.{}", arg.name),
        }
    }

    /// Smallest bucket that fits `batch`, or the largest bucket if none do.
    pub fn bucket_for(&self, batch: usize) -> usize {
        self.batch_buckets
            .iter()
            .copied()
            .find(|&b| b >= batch)
            .unwrap_or_else(|| *self.batch_buckets.last().unwrap())
    }

    pub fn exe(&self, name: &str) -> Result<&ExeSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow!("no executable {name:?} in manifest"))
    }
}
