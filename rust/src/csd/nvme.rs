//! NVMe command surface of the InstCSD (paper §V-A: "specific modifications
//! to NVMe commands to accommodate the unique computational capabilities").
//!
//! The host coordinator talks to a CSD exclusively through this queue: it
//! models submission/completion latency (the P2P command path vs the host
//! filesystem path) and dispatches to the engine.  This is the seam where
//! the real system would marshal qkv vectors over PCIe BARs.

use super::engine::{AttnMode, InstCsd, UnitBreakdown};
use crate::config::hw::PcieSpec;
use crate::obs::attr;
use crate::sim::{FifoResource, Time};
use anyhow::Result;

/// Extended NVMe commands (vendor-specific opcodes in the real device).
#[derive(Debug, Clone)]
pub enum CsdCommand {
    /// store one decode token's K/V rows for this CSD's heads; `pos` is
    /// the token's stream position so a command replayed after a fault
    /// (or mirrored to a replica) is idempotent
    WriteToken { slot: u32, layer: u16, heads: Vec<u16>, pos: usize, k: Vec<f32>, v: Vec<f32> },
    /// store a prefill layer for this CSD's heads (layer-wise shipping);
    /// `pos` is the stream position the `s_len` tokens start at
    WritePrefillLayer {
        slot: u32,
        layer: u16,
        heads: Vec<u16>,
        pos: usize,
        s_len: usize,
        k: Vec<f32>,
        v: Vec<f32>,
    },
    /// compute decode attention for this CSD's heads of a layer
    Attention { slot: u32, layer: u16, heads: Vec<u16>, q: Vec<f32>, len: usize, mode: AttnMode },
    /// context-shard partial attention over this device's resident token
    /// prefix (dense only); the completion carries per-head
    /// (max-logit, sum-exp) statistics for the GPU's log-sum-exp merge
    PartialAttention { slot: u32, layer: u16, heads: Vec<u16>, q: Vec<f32>, local_len: usize },
    /// fold globally-rescaled attention mass into the H2O importance
    /// tracker after a context-shard all-reduce.  On the wire this is
    /// the GPU returning the per-head merge weights (h fp16 values,
    /// covered by the command's P2P latency); the scaled per-token
    /// vector carried here is the result of the multiply the shard
    /// performs against its DRAM-resident local weights
    AccumulateImportance { slot: u32, weights: Vec<f32> },
    /// mask token positions of a live sequence out of future attention
    /// (H2O-style drop-on-resume; fully-dropped groups free flash pages)
    DropTokens { slot: u32, tokens: Vec<u32> },
    /// register a just-prefilled slot's sealed prefix in the FTL's
    /// content-addressed index: `bounds[i] = (boundary hash, local
    /// tokens)` per complete token group of the prompt (metadata only —
    /// the sealed pages are refcount-aliased, never copied)
    RegisterPrefix { slot: u32, bounds: Vec<(u64, usize)> },
    /// attach a cached prefix to a new slot's stream mappings before its
    /// (suffix-only) prefill ships
    AttachPrefix { slot: u32, hash: u64 },
    /// drop a finished sequence
    FreeSlot { slot: u32 },
}

impl CsdCommand {
    /// Trace label for the command's span on the device track.
    pub fn name(&self) -> &'static str {
        match self {
            CsdCommand::WriteToken { .. } => "write_token",
            CsdCommand::WritePrefillLayer { .. } => "write_prefill_layer",
            CsdCommand::Attention { .. } => "attention",
            CsdCommand::PartialAttention { .. } => "partial_attention",
            CsdCommand::AccumulateImportance { .. } => "accumulate_importance",
            CsdCommand::DropTokens { .. } => "drop_tokens",
            CsdCommand::RegisterPrefix { .. } => "register_prefix",
            CsdCommand::AttachPrefix { .. } => "attach_prefix",
            CsdCommand::FreeSlot { .. } => "free_slot",
        }
    }

    /// Structural validation at the submission boundary.  A malformed
    /// command surfaces as a typed [`FaultError::MalformedCommand`]
    /// error completion — even with fault injection off — instead of
    /// panicking or corrupting device state deeper in the stack.
    /// `d` is the device's per-head embedding dimension.
    pub fn validate(&self, dev: usize, d: usize) -> Result<()> {
        let malformed = |why: String| -> anyhow::Error {
            crate::fault::FaultError::MalformedCommand { dev, cmd: self.name(), why }.into()
        };
        let slot = match self {
            CsdCommand::WriteToken { slot, .. }
            | CsdCommand::WritePrefillLayer { slot, .. }
            | CsdCommand::Attention { slot, .. }
            | CsdCommand::PartialAttention { slot, .. }
            | CsdCommand::AccumulateImportance { slot, .. }
            | CsdCommand::DropTokens { slot, .. }
            | CsdCommand::RegisterPrefix { slot, .. }
            | CsdCommand::AttachPrefix { slot, .. }
            | CsdCommand::FreeSlot { slot } => *slot,
        };
        if slot >= crate::ftl::PREFIX_SLOT_BASE {
            return Err(malformed(format!(
                "slot {slot} collides with the prefix pseudo-slot range"
            )));
        }
        match self {
            CsdCommand::WriteToken { heads, k, v, .. } => {
                if k.len() != v.len() {
                    return Err(malformed(format!(
                        "k rows ({}) != v rows ({})",
                        k.len(),
                        v.len()
                    )));
                }
                if k.len() != heads.len() * d {
                    return Err(malformed(format!(
                        "{} k values for {} heads of dim {d}",
                        k.len(),
                        heads.len()
                    )));
                }
            }
            CsdCommand::WritePrefillLayer { heads, s_len, k, v, .. } => {
                if k.len() != v.len() {
                    return Err(malformed(format!(
                        "k rows ({}) != v rows ({})",
                        k.len(),
                        v.len()
                    )));
                }
                if k.len() != heads.len() * s_len * d {
                    return Err(malformed(format!(
                        "{} k values for {} heads x {s_len} tokens of dim {d}",
                        k.len(),
                        heads.len()
                    )));
                }
            }
            CsdCommand::Attention { heads, q, .. }
            | CsdCommand::PartialAttention { heads, q, .. } => {
                if q.len() != heads.len() * d {
                    return Err(malformed(format!(
                        "{} query values for {} heads of dim {d}",
                        q.len(),
                        heads.len()
                    )));
                }
            }
            CsdCommand::AccumulateImportance { weights, .. } => {
                if weights.iter().any(|w| !w.is_finite()) {
                    return Err(malformed("non-finite attention mass".into()));
                }
            }
            _ => {}
        }
        Ok(())
    }
}

#[derive(Debug, Clone)]
pub struct CsdCompletion {
    /// attention output (empty for writes/frees)
    pub data: Vec<f32>,
    /// completion timestamp
    pub done: Time,
    /// per-unit breakdown (attention commands only)
    pub breakdown: Option<UnitBreakdown>,
    /// per-head (max-logit, sum-exp) merge statistics
    /// (`PartialAttention` only)
    pub stats: Vec<(f32, f32)>,
    /// per-head local softmax weights packed `(heads, local_len)`
    /// (`PartialAttention` only).  Functional plane only: architecturally
    /// these stay in CSD DRAM — the GPU ships back the per-head merge
    /// weights (h tiny floats, folded into the write-back command's P2P
    /// latency) and the shard rescales locally; the coordinator performs
    /// that multiply host-side for it, so the all-reduce timing model
    /// correctly charges only `h*(d+2)` elements per shard
    pub weights: Vec<f32>,
}

/// Single-submission-queue model: commands incur the command-path latency
/// (P2P doorbell vs host-FS stack) then execute on the device.
pub struct NvmeQueue {
    pub csd: InstCsd,
    sq: FifoResource,
    cmd_latency: Time,
    pub submitted: u64,
    /// device index in the CSD array — tags this queue's trace track
    /// (and, via the ambient device scope, everything the command
    /// touches down-stack: FTL GC, flash FIFOs).  Purely observational.
    pub dev: usize,
    /// NVMe-domain fault injector (`None` = fault plane off: the submit
    /// path is bit-identical to the fault-free engine)
    fault: Option<crate::fault::FaultState>,
    /// sim time at which this whole device dies; every submission at or
    /// after it completes with `FaultError::DeviceLost`
    dead_at: Option<Time>,
    /// command timeouts detected (each cost one detection window + one
    /// backoff step before the retry succeeded)
    pub timeouts: u64,
    /// total wall time spent in timeout detection + backoff on this queue
    pub retry_s: f64,
}

impl NvmeQueue {
    /// `p2p`: commands arrive over the peer-to-peer path (no host FS).
    pub fn new(csd: InstCsd, pcie: &PcieSpec, p2p: bool) -> Self {
        let cmd_latency = if p2p { pcie.p2p_io_us } else { pcie.host_fs_io_us } * 1e-6;
        NvmeQueue {
            csd,
            sq: FifoResource::new(),
            cmd_latency,
            submitted: 0,
            dev: 0,
            fault: None,
            dead_at: None,
            timeouts: 0,
            retry_s: 0.0,
        }
    }

    /// Arm fault injection on this queue and its engine.  Must be called
    /// after `dev` is set: the per-device RNG streams are seeded from it.
    pub fn install_faults(&mut self, cfg: &crate::fault::FaultConfig) {
        if cfg.injecting() {
            self.fault =
                Some(crate::fault::FaultState::new(cfg, self.dev, crate::fault::DOMAIN_NVME));
        }
        if let Some((dev, t)) = cfg.csd_loss {
            if dev == self.dev {
                self.dead_at = Some(t);
            }
        }
        self.csd.install_fault(cfg, self.dev);
    }

    /// Whether the device has (already) died by sim time `at`.
    pub fn dead(&self, at: Time) -> bool {
        self.dead_at.is_some_and(|t| at >= t)
    }

    /// Build the replacement queue for a lost device: same command-path
    /// latency and device index, a fresh submission queue, and a clean
    /// bill of health (no injector, no scheduled death — the dead drive
    /// was swapped for a good one).
    pub fn successor(&self, csd: InstCsd) -> NvmeQueue {
        NvmeQueue {
            csd,
            sq: FifoResource::new(),
            cmd_latency: self.cmd_latency,
            submitted: 0,
            dev: self.dev,
            fault: None,
            dead_at: None,
            timeouts: 0,
            retry_s: 0.0,
        }
    }

    pub fn submit(&mut self, cmd: CsdCommand, at: Time) -> Result<CsdCompletion> {
        self.submitted += 1;
        let _scope = crate::obs::DeviceScope::enter(self.dev);
        if let Some(t) = self.dead_at {
            if at >= t {
                return Err(crate::fault::FaultError::DeviceLost { dev: self.dev }.into());
            }
        }
        cmd.validate(self.dev, self.csd.head_dim())?;
        let cmd_name = cmd.name();
        let is_write = matches!(
            cmd,
            CsdCommand::WriteToken { .. } | CsdCommand::WritePrefillLayer { .. }
        );
        // timeout detection + bounded retry with exponential backoff:
        // each trip of the injector models a command the device never
        // completed — the host notices after TIMEOUT_DETECT_S, backs off,
        // and resubmits.  MAX_RETRY consecutive losses surface as a typed
        // CommandTimeout error completion.
        let mut at_eff = at;
        let mut tries: u32 = 0;
        let mut gave_up = false;
        if let Some(f) = self.fault.as_mut() {
            while f.trips() {
                tries += 1;
                if tries >= crate::fault::MAX_RETRY {
                    gave_up = true;
                    break;
                }
                at_eff += crate::fault::retry_delay(tries);
            }
        }
        if tries > 0 {
            self.timeouts += tries as u64;
            self.retry_s += at_eff - at;
            crate::obs::dev_instant("nvme_timeout", at);
            attr::seg(attr::Bucket::FaultRetry, at, at_eff, at_eff - at);
        }
        if gave_up {
            return Err(crate::fault::FaultError::CommandTimeout {
                dev: self.dev,
                cmd: cmd_name,
                attempts: tries,
            }
            .into());
        }
        let (d0, dispatched) = self.sq.schedule(at_eff, self.cmd_latency);
        let comp: Result<CsdCompletion> = match cmd {
            CsdCommand::WriteToken { slot, layer, heads, pos, k, v } => {
                let done =
                    self.csd.write_token_heads(slot, layer, &heads, pos, &k, &v, dispatched)?;
                Ok(CsdCompletion {
                    data: vec![],
                    done,
                    breakdown: None,
                    stats: vec![],
                    weights: vec![],
                })
            }
            CsdCommand::WritePrefillLayer { slot, layer, heads, pos, s_len, k, v } => {
                let done = self
                    .csd
                    .write_prefill_heads(slot, layer, &heads, pos, s_len, &k, &v, dispatched)?;
                Ok(CsdCompletion {
                    data: vec![],
                    done,
                    breakdown: None,
                    stats: vec![],
                    weights: vec![],
                })
            }
            CsdCommand::Attention { slot, layer, heads, q, len, mode } => {
                let (out, done, bd) =
                    self.csd.attention_heads(slot, layer, &heads, &q, len, mode, dispatched)?;
                Ok(CsdCompletion {
                    data: out,
                    done,
                    breakdown: Some(bd),
                    stats: vec![],
                    weights: vec![],
                })
            }
            CsdCommand::PartialAttention { slot, layer, heads, q, local_len } => {
                let (out, stats, weights, done, bd) = self
                    .csd
                    .partial_attention_heads(slot, layer, &heads, &q, local_len, dispatched)?;
                Ok(CsdCompletion { data: out, done, breakdown: Some(bd), stats, weights })
            }
            CsdCommand::AccumulateImportance { slot, weights } => {
                self.csd.accumulate_importance(slot, &weights)?;
                Ok(CsdCompletion {
                    data: vec![],
                    done: dispatched,
                    breakdown: None,
                    stats: vec![],
                    weights: vec![],
                })
            }
            CsdCommand::DropTokens { slot, tokens } => {
                self.csd.drop_tokens(slot, &tokens)?;
                Ok(CsdCompletion {
                    data: vec![],
                    done: dispatched,
                    breakdown: None,
                    stats: vec![],
                    weights: vec![],
                })
            }
            CsdCommand::RegisterPrefix { slot, bounds } => {
                self.csd.register_prefix(slot, &bounds)?;
                Ok(CsdCompletion {
                    data: vec![],
                    done: dispatched,
                    breakdown: None,
                    stats: vec![],
                    weights: vec![],
                })
            }
            CsdCommand::AttachPrefix { slot, hash } => {
                self.csd.attach_prefix(slot, hash)?;
                Ok(CsdCompletion {
                    data: vec![],
                    done: dispatched,
                    breakdown: None,
                    stats: vec![],
                    weights: vec![],
                })
            }
            CsdCommand::FreeSlot { slot } => {
                let done = self.csd.free_slot(slot, dispatched)?;
                Ok(CsdCompletion {
                    data: vec![],
                    done,
                    breakdown: None,
                    stats: vec![],
                    weights: vec![],
                })
            }
        };
        let comp = comp?;
        crate::obs::device_span(self.dev, cmd_name, d0, comp.done);
        // attribution: charge this command's wall window to the ambient
        // request.  The flash/GC accumulators are drained per command
        // regardless, so no busy time ever leaks into a later command.
        let (fifo_wait, fifo_svc) = attr::drain_flash();
        let gc = attr::drain_gc();
        if let Some(req) = crate::obs::cur_req() {
            crate::obs::cmd_flow(req, at_eff, self.dev, d0);
        }
        attr::seg(attr::Bucket::NvmeCmd, at_eff, dispatched, dispatched - at_eff);
        if let Some(bd) = &comp.breakdown {
            // attention: split the device window into data-fetch wall
            // (flash tR/transfer + DRAM-tier hits), the share of it spent
            // queued behind other reads (FIFO conflicts), in-storage
            // compute, and GC interference
            let fetch_wall = bd.flash_read + bd.dram_hit;
            let denom = fifo_wait + fifo_svc;
            let conflict = if denom > 0.0 { fetch_wall * fifo_wait / denom } else { 0.0 };
            attr::seg(attr::Bucket::FlashConflict, dispatched, comp.done, conflict);
            attr::seg(attr::Bucket::FlashRead, dispatched, comp.done, fetch_wall - conflict);
            let compute =
                bd.argtopk + bd.nfc_filter + bd.logit0 + bd.logit + bd.attend + bd.writeback;
            attr::seg(attr::Bucket::CsdCompute, dispatched, comp.done, compute);
            attr::seg(attr::Bucket::Gc, dispatched, comp.done, gc);
        } else if is_write {
            let svc = comp.done - dispatched;
            attr::seg(attr::Bucket::Gc, dispatched, comp.done, gc);
            attr::seg(attr::Bucket::KvShip, dispatched, comp.done, (svc - gc).max(0.0));
        }
        Ok(comp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn queue(p2p: bool) -> NvmeQueue {
        NvmeQueue::new(InstCsd::tiny_test(), &PcieSpec::paper(), p2p)
    }

    #[test]
    fn write_then_attend_roundtrip() {
        let mut q = queue(true);
        let mut rng = Rng::new(1);
        for pos in 0..16 {
            let k: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
            let v: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
            q.submit(
                CsdCommand::WriteToken { slot: 0, layer: 0, heads: vec![0, 1], pos, k, v },
                0.0,
            )
            .unwrap();
        }
        let qv: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
        let c = q
            .submit(
                CsdCommand::Attention {
                    slot: 0,
                    layer: 0,
                    heads: vec![0, 1],
                    q: qv,
                    len: 16,
                    mode: AttnMode::Dense,
                },
                0.0,
            )
            .unwrap();
        assert_eq!(c.data.len(), 64);
        assert!(c.breakdown.is_some());
        q.submit(CsdCommand::FreeSlot { slot: 0 }, c.done).unwrap();
        assert_eq!(q.submitted, 18);
    }

    #[test]
    fn p2p_commands_cheaper_than_host_fs() {
        let mut a = queue(true);
        let mut b = queue(false);
        let mk = |rng: &mut Rng, pos: usize| CsdCommand::WriteToken {
            slot: 0,
            layer: 0,
            heads: vec![0, 1],
            pos,
            k: (0..64).map(|_| rng.normal_f32()).collect(),
            v: (0..64).map(|_| rng.normal_f32()).collect(),
        };
        let mut rng = Rng::new(2);
        let mut ta: Time = 0.0;
        let mut tb: Time = 0.0;
        // enough commands that queueing on the submission path dominates
        for pos in 0..100 {
            ta = ta.max(a.submit(mk(&mut rng, pos), 0.0).unwrap().done);
            tb = tb.max(b.submit(mk(&mut rng, pos), 0.0).unwrap().done);
        }
        assert!(ta < tb, "p2p {ta} !< host-fs {tb}");
    }

    #[test]
    fn malformed_commands_are_error_completions_not_panics() {
        let mut q = queue(true);
        // k/v length mismatch
        let err = q
            .submit(
                CsdCommand::WriteToken {
                    slot: 0,
                    layer: 0,
                    heads: vec![0, 1],
                    pos: 0,
                    k: vec![0.0; 64],
                    v: vec![0.0; 32],
                },
                0.0,
            )
            .unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<crate::fault::FaultError>(),
                Some(crate::fault::FaultError::MalformedCommand { .. })
            ),
            "{err}"
        );
        // query length not heads * d
        assert!(q
            .submit(
                CsdCommand::Attention {
                    slot: 0,
                    layer: 0,
                    heads: vec![0],
                    q: vec![0.0; 7],
                    len: 1,
                    mode: AttnMode::Dense,
                },
                0.0,
            )
            .is_err());
        // slot in the prefix pseudo-slot range
        assert!(q
            .submit(CsdCommand::FreeSlot { slot: crate::ftl::PREFIX_SLOT_BASE }, 0.0)
            .is_err());
        // non-finite importance mass
        assert!(q
            .submit(
                CsdCommand::AccumulateImportance { slot: 0, weights: vec![f32::NAN] },
                0.0,
            )
            .is_err());
        // the queue stays usable after error completions
        q.submit(
            CsdCommand::WriteToken {
                slot: 0,
                layer: 0,
                heads: vec![0, 1],
                pos: 0,
                k: vec![0.0; 64],
                v: vec![0.0; 64],
            },
            0.0,
        )
        .unwrap();
    }

    #[test]
    fn timeout_retry_is_deterministic_and_dead_device_errors() {
        let run = |seed: u64| {
            let mut q = queue(true);
            let cfg = crate::fault::FaultConfig {
                seed,
                rate: 0.4,
                csd_loss: Some((0, 0.5)),
                ..crate::fault::FaultConfig::none()
            };
            q.install_faults(&cfg);
            let mut rng = Rng::new(3);
            let mut done: Vec<Time> = Vec::new();
            for pos in 0..32 {
                let k: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
                let v: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
                // a command may exhaust MAX_RETRY at this trip rate —
                // record the typed error completion instead of unwrapping
                match q.submit(
                    CsdCommand::WriteToken { slot: 0, layer: 0, heads: vec![0, 1], pos, k, v },
                    pos as f64 * 1e-4,
                ) {
                    Ok(c) => done.push(c.done),
                    Err(_) => done.push(-1.0),
                }
            }
            (done, q.timeouts, q.retry_s)
        };
        let a = run(7);
        let b = run(7);
        assert_eq!(a, b, "same fault seed must replay bit-identically");
        assert!(a.1 > 0, "a 40% trip rate over 32 commands must time out at least once");
        // past the scheduled death every submission is a DeviceLost error
        let mut q = queue(true);
        q.install_faults(&crate::fault::FaultConfig {
            csd_loss: Some((0, 0.5)),
            ..crate::fault::FaultConfig::none()
        });
        assert!(!q.dead(0.49));
        assert!(q.dead(0.5));
        let err = q.submit(CsdCommand::FreeSlot { slot: 0 }, 0.6).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<crate::fault::FaultError>(),
                Some(crate::fault::FaultError::DeviceLost { dev: 0 })
            ),
            "{err}"
        );
    }
}
