//! Zynq7045 resource-utilisation model — reproduces Table I.
//!
//! These are the paper's reported synthesis results (§V-B Table I); we keep
//! them as a structured model so the Table-I bench target can print the
//! table and so the engine constants (DSP count -> FLOP/s) are derived,
//! not free parameters.

#[derive(Debug, Clone, Copy)]
pub struct UnitResources {
    pub name: &'static str,
    pub lut_k: f64,
    pub ff_k: f64,
    pub bram_tiles: f64,
    pub dsp: u32,
}

/// Table I rows (paper §V-B).
pub const UNITS: &[UnitResources] = &[
    UnitResources {
        name: "Attention Kernel",
        lut_k: 99.2,
        ff_k: 207.3,
        bram_tiles: 96.0,
        dsp: 768,
    },
    UnitResources { name: "Argtopk", lut_k: 5.83, ff_k: 3.87, bram_tiles: 24.0, dsp: 0 },
    UnitResources { name: "NFC", lut_k: 58.332, ff_k: 27.8, bram_tiles: 96.0, dsp: 0 },
    UnitResources { name: "NVMe Controller", lut_k: 7.99, ff_k: 12.45, bram_tiles: 27.5, dsp: 0 },
    UnitResources { name: "Interconnect", lut_k: 4.12, ff_k: 6.17, bram_tiles: 7.5, dsp: 0 },
];

/// Device totals (Zynq7045 datasheet, as quoted in Table I).
pub const AVAILABLE: UnitResources =
    UnitResources { name: "Available", lut_k: 218.6, ff_k: 437.2, bram_tiles: 545.0, dsp: 900 };

pub fn used() -> UnitResources {
    let mut u = UnitResources { name: "Used", lut_k: 0.0, ff_k: 0.0, bram_tiles: 0.0, dsp: 0 };
    for r in UNITS {
        u.lut_k += r.lut_k;
        u.ff_k += r.ff_k;
        u.bram_tiles += r.bram_tiles;
        u.dsp += r.dsp;
    }
    u
}

/// Utilisation percentages (the Table I "Percent" row).
pub fn utilisation() -> (f64, f64, f64, f64) {
    let u = used();
    (
        100.0 * u.lut_k / AVAILABLE.lut_k,
        100.0 * u.ff_k / AVAILABLE.ff_k,
        100.0 * u.bram_tiles / AVAILABLE.bram_tiles,
        100.0 * u.dsp as f64 / AVAILABLE.dsp as f64,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_percentages() {
        let (lut, ff, bram, dsp) = utilisation();
        // paper: 80.27%, 58.92%, 46.06%, 85.33%
        assert!((lut - 80.27).abs() < 0.2, "lut {lut}");
        assert!((ff - 58.92).abs() < 0.2, "ff {ff}");
        assert!((bram - 46.06).abs() < 0.2, "bram {bram}");
        assert!((dsp - 85.33).abs() < 0.2, "dsp {dsp}");
    }

    #[test]
    fn engine_flops_derived_from_dsp_count() {
        // CsdSpec::zynq7045 must use Table I's attention-kernel DSP count
        let spec = crate::config::hw::CsdSpec::zynq7045();
        let dsp = UNITS[0].dsp as f64;
        assert_eq!(spec.engine_flops, dsp * spec.clock_hz * 2.0);
    }
}
