//! InstCSD: the computational storage drive (paper §IV-B, Fig. 8).
//!
//! * [`engine`]    — the hardware SparF/dense attention engine: argtopk
//!   unit, per-NFC filters, two attention kernels, dual-step page loading
//!   through the KV-oriented FTL.  Functional (real numerics over the
//!   simulated flash bytes) *and* timed (per-unit busy ledger -> Fig. 16).
//! * [`nvme`]      — the NVMe command surface the host coordinator drives
//!   (extended commands for KV writes and attention offload, §V-A).
//! * [`resources`] — the Zynq7045 resource-utilisation model (Table I).

pub mod engine;
pub mod nvme;
pub mod resources;

pub use engine::{AttnMode, FlashUtil, InstCsd, UnitBreakdown};
pub use nvme::{CsdCommand, CsdCompletion, NvmeQueue};
