//! The in-storage attention engine (paper Fig. 8), functional + timed.
//!
//! Dataflow per (slot, layer, head) decode step in SparF mode:
//!
//! ```text
//! q --> [argtopk r] --> emb page idxs --> [FTL/flash: K^T pages]
//!                                         --> [NFC filter] --> K^T_[:,i]
//! q_[i], K^T_[:,i] --> [Attention Kernel (Logit-0)] --> s_hat
//! s_hat --> [argtopk k] --> token groups --> [FTL/flash: K,V pages]
//!                                         --> [NFC filter] --> K_[j], V_[j]
//! q, K_[j] --> [Attention Kernel (Logit)] --> s --> [x V_[j] (Attend)]
//! out = alpha * s V + (1-alpha) v̄
//! ```
//!
//! Numerics come from [`crate::sparse`] over the FP16 bytes actually
//! resident in the simulated flash; timing comes from the unit models
//! (argtopk throughput, filter line rate, the two-kernel `MultiServer`,
//! and the flash array's die/channel FIFOs).  Per-unit busy time feeds
//! Fig. 16; the same constants drive the analytic model used at
//! OPT-13B scale (`systems::insti`), which is validated against this
//! engine in the integration tests.

use crate::config::hw::CsdSpec;
use crate::config::model::SparsityParams;
use crate::ftl::{FtlConfig, KvFtl, KvKind, StreamKey};
use crate::sim::{BusyLedger, MultiServer, Time};
use crate::sparse;
use anyhow::{anyhow, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnMode {
    Dense,
    SparF(SparsityParams),
}

/// Per-unit time breakdown of one engine invocation (Fig. 16 rows).
#[derive(Debug, Clone, Default)]
pub struct UnitBreakdown {
    pub argtopk: Time,
    pub flash_read: Time,
    pub nfc_filter: Time,
    pub logit0: Time,
    pub logit: Time,
    pub attend: Time,
    pub writeback: Time,
}

impl UnitBreakdown {
    pub fn total(&self) -> Time {
        self.argtopk + self.flash_read + self.nfc_filter + self.logit0 + self.logit
            + self.attend + self.writeback
    }

    pub fn merge(&mut self, o: &UnitBreakdown) {
        self.argtopk += o.argtopk;
        self.flash_read += o.flash_read;
        self.nfc_filter += o.nfc_filter;
        self.logit0 += o.logit0;
        self.logit += o.logit;
        self.attend += o.attend;
        self.writeback += o.writeback;
    }
}

pub struct InstCsd {
    pub spec: CsdSpec,
    pub ftl: KvFtl,
    kernels: MultiServer,
    pub ledger: BusyLedger,
    d_head: usize,
}

impl InstCsd {
    pub fn new(spec: CsdSpec, ftl_cfg: FtlConfig) -> Result<Self> {
        let ftl = KvFtl::new(spec.flash, ftl_cfg)?;
        Ok(InstCsd {
            kernels: MultiServer::new(spec.attn_kernels),
            spec,
            ftl,
            ledger: BusyLedger::default(),
            d_head: ftl_cfg.d_head,
        })
    }

    fn argtopk_time(&self, elems: usize) -> Time {
        elems as f64 / self.spec.argtopk_elems_per_s
    }

    fn kernel_time(&self, flops: f64) -> Time {
        // one kernel owns half the engine's DSPs (Fig. 8: two identical
        // kernels share the array)
        flops / (self.spec.engine_flops / self.spec.attn_kernels as f64)
    }

    fn filter_time(&self, bytes: usize) -> Time {
        // NFC filters run at line rate per channel; aggregate across
        // channels since pages arrive distributed
        bytes as f64 / (self.spec.filter_bw_per_channel * self.spec.flash.channels as f64)
    }

    /// Store one token's K/V rows for every head of a layer (decode write).
    pub fn write_token(
        &mut self,
        slot: u32,
        layer: u16,
        k_rows: &[f32],
        v_rows: &[f32],
        at: Time,
    ) -> Result<Time> {
        let heads: Vec<u16> = (0..(k_rows.len() / self.d_head) as u16).collect();
        self.write_token_heads(slot, layer, &heads, k_rows, v_rows, at)
    }

    /// Store one token's K/V rows for an explicit head subset (the rows are
    /// packed in the order of `heads` — what the head->CSD router ships).
    pub fn write_token_heads(
        &mut self,
        slot: u32,
        layer: u16,
        heads: &[u16],
        k_rows: &[f32],
        v_rows: &[f32],
        at: Time,
    ) -> Result<Time> {
        let d = self.d_head;
        anyhow::ensure!(k_rows.len() == heads.len() * d, "k rows/heads mismatch");
        let mut t = at;
        for (i, &h) in heads.iter().enumerate() {
            let key = StreamKey { slot, layer, head: h };
            t = t.max(self.ftl.append_token(
                key,
                &k_rows[i * d..(i + 1) * d],
                &v_rows[i * d..(i + 1) * d],
                at,
            )?);
        }
        Ok(t)
    }

    /// Store a prefill layer's KV for every head (layer-wise shipping).
    pub fn write_prefill_layer(
        &mut self,
        slot: u32,
        layer: u16,
        heads: usize,
        s_len: usize,
        k_hsd: &[f32],
        v_hsd: &[f32],
        at: Time,
    ) -> Result<Time> {
        let hs: Vec<u16> = (0..heads as u16).collect();
        self.write_prefill_heads(slot, layer, &hs, s_len, k_hsd, v_hsd, at)
    }

    /// Store a prefill layer's KV for an explicit head subset (rows packed
    /// (heads, s_len, d) in the order of `heads`).
    pub fn write_prefill_heads(
        &mut self,
        slot: u32,
        layer: u16,
        heads: &[u16],
        s_len: usize,
        k_hsd: &[f32],
        v_hsd: &[f32],
        at: Time,
    ) -> Result<Time> {
        let d = self.d_head;
        anyhow::ensure!(k_hsd.len() == heads.len() * s_len * d, "prefill rows/heads mismatch");
        let mut t = at;
        for (i, &h) in heads.iter().enumerate() {
            let key = StreamKey { slot, layer, head: h };
            let base = i * s_len * d;
            t = t.max(self.ftl.append_prefill(
                key,
                &k_hsd[base..base + s_len * d],
                &v_hsd[base..base + s_len * d],
                at,
            )?);
        }
        Ok(t)
    }

    /// Decode-phase attention for one head.  Returns (output, completion,
    /// per-unit breakdown).
    pub fn attention_head(
        &mut self,
        key: StreamKey,
        q: &[f32],
        len: usize,
        mode: AttnMode,
        at: Time,
    ) -> Result<(Vec<f32>, Time, UnitBreakdown)> {
        match mode {
            AttnMode::Dense => self.dense_head(key, q, len, at),
            AttnMode::SparF(sp) => self.sparf_head(key, q, len, &sp, at),
        }
    }

    fn dense_head(
        &mut self,
        key: StreamKey,
        q: &[f32],
        len: usize,
        at: Time,
    ) -> Result<(Vec<f32>, Time, UnitBreakdown)> {
        let d = self.d_head;
        let n = self.ftl.cfg.n;
        let mut bd = UnitBreakdown::default();
        let n_groups = len.div_ceil(n);
        let groups: Vec<usize> = (0..n_groups).collect();

        let t0 = at;
        let (k_rows, tk) = self.ftl.fetch_token_groups(key, KvKind::K, &groups, t0)?;
        let (v_rows, tv) = self.ftl.fetch_token_groups(key, KvKind::V, &groups, t0)?;
        let t_read = tk.max(tv);
        bd.flash_read = t_read - t0;

        let kmat = assemble_rows(&k_rows, n_groups * n, d);
        let vmat = assemble_rows(&v_rows, n_groups * n, d);
        let out = sparse::dense_attention(q, &kmat, &vmat, len);

        // Logit GeMV (2*len*d) + softmax + Attend GeMV (2*len*d)
        let logit_t = self.kernel_time(2.0 * len as f64 * d as f64);
        let attend_t = self.kernel_time(2.0 * len as f64 * d as f64);
        let (_, _, t1) = self.kernels.schedule(t_read, logit_t);
        let (_, _, t2) = self.kernels.schedule(t1, attend_t);
        bd.logit = logit_t;
        bd.attend = attend_t;
        self.ledger.add("flash_read", bd.flash_read);
        self.ledger.add("kernel", logit_t + attend_t);
        Ok((out, t2, bd))
    }

    fn sparf_head(
        &mut self,
        key: StreamKey,
        q: &[f32],
        len: usize,
        sp: &SparsityParams,
        at: Time,
    ) -> Result<(Vec<f32>, Time, UnitBreakdown)> {
        let d = self.d_head;
        let n = self.ftl.cfg.n;
        let mut bd = UnitBreakdown::default();
        let page_bytes = self.spec.flash.page_bytes;

        // ---- step 1: argtopk over |q| (d elements)
        let t_top1 = self.argtopk_time(d);
        let t1 = at + t_top1;
        bd.argtopk += t_top1;
        let absq: Vec<f32> = q.iter().map(|x| x.abs()).collect();
        let emb_mask = sparse::select::topk_mask_select(&absq, sp.r);
        let channels: Vec<usize> =
            (0..d).filter(|&c| emb_mask[c]).collect();

        // ---- step 2: embedding-indexed page fetch (group-shared)
        let (lanes, t_fetch1) = self.ftl.fetch_emb_channels(key, &channels, len, t1)?;
        bd.flash_read += t_fetch1 - t1;
        // NFC filter pass over the fetched pages
        let egroups: std::collections::BTreeSet<usize> =
            channels.iter().map(|c| c / self.ftl.cfg.m).collect();
        let t_emb = self.ftl.tokens_per_emb_page();
        let fetched_bytes = egroups.len() * len.div_ceil(t_emb) * page_bytes;
        let t_filt1 = self.filter_time(fetched_bytes);
        bd.nfc_filter += t_filt1;

        // ---- step 4: Kernel #1 — approximate scores over r channels
        let l1_all: f32 = absq.iter().sum();
        let l1_kept: f32 = channels.iter().map(|&c| absq[c]).sum();
        let scale_hat = ((d as f32) * l1_kept / l1_all.max(1e-30)).sqrt().max(1e-30);
        let mut logits_hat = vec![sparse::select::NEG_INF; pad_to(len, n)];
        for t in 0..len {
            let mut acc = 0.0f32;
            for (ci, &c) in channels.iter().enumerate() {
                acc += q[c] * lanes[ci][t];
            }
            logits_hat[t] = acc / scale_hat;
        }
        let valid: Vec<bool> = (0..logits_hat.len()).map(|t| t < len).collect();
        let s_hat = sparse::select::softmax_masked(&logits_hat, &valid);
        let k1_flops = 2.0 * len as f64 * sp.r as f64;
        let k1_t = self.kernel_time(k1_flops);
        let (_, _, t_k1) = self.kernels.schedule(t_fetch1 + t_filt1, k1_t);
        bd.logit0 = k1_t;

        // ---- steps 5-6: argtopk over tokens
        let t_top2 = self.argtopk_time(len);
        bd.argtopk += t_top2;
        let pool: Vec<f32> = s_hat
            .iter()
            .zip(&valid)
            .map(|(&s, &m)| if m { s } else { -1.0 })
            .collect();
        let mut tok_mask = sparse::select::topk_mask_select(&pool, sp.k.min(len));
        for (t, tm) in tok_mask.iter_mut().enumerate() {
            *tm &= t < len;
        }
        let alpha: f32 = s_hat
            .iter()
            .zip(&tok_mask)
            .filter(|(_, &m)| m)
            .map(|(s, _)| s)
            .sum::<f32>()
            .clamp(0.0, 1.0);

        // ---- step 8: token-indexed page fetch for K and V
        let groups: Vec<usize> = (0..tok_mask.len().div_ceil(n))
            .filter(|&g| tok_mask[g * n..((g + 1) * n).min(tok_mask.len())].iter().any(|&b| b))
            .collect();
        let t2 = t_k1 + t_top2;
        let (k_rows, tk) = self.ftl.fetch_token_groups(key, KvKind::K, &groups, t2)?;
        let (v_rows, tv) = self.ftl.fetch_token_groups(key, KvKind::V, &groups, t2)?;
        let t_fetch2 = tk.max(tv);
        bd.flash_read += t_fetch2 - t2;
        let t_filt2 = self.filter_time(2 * groups.len() * page_bytes);
        bd.nfc_filter += t_filt2;

        // ---- steps 9-11: Kernel #2 — exact attention over kept tokens
        let rows = pad_to(len, n);
        let kmat = assemble_rows(&k_rows, rows, d);
        let vmat = assemble_rows(&v_rows, rows, d);
        let scale = 1.0 / (d as f32).sqrt();
        let mut logits = vec![sparse::select::NEG_INF; rows];
        for t in 0..rows {
            if tok_mask[t] {
                logits[t] = sparse::select::dot(q, &kmat[t * d..(t + 1) * d]) * scale;
            }
        }
        let s = sparse::select::softmax_masked(&logits, &tok_mask);
        let vbar = self
            .ftl
            .vbar(key)
            .ok_or_else(|| anyhow!("no v̄ for stream {key:?}"))?;
        let mut out = vec![0.0f32; d];
        for t in 0..rows {
            if s[t] != 0.0 {
                for c in 0..d {
                    out[c] += s[t] * vmat[t * d + c];
                }
            }
        }
        for c in 0..d {
            out[c] = alpha * out[c] + (1.0 - alpha) * vbar[c];
        }
        let kept = tok_mask.iter().filter(|&&b| b).count();
        let k2_flops = 2.0 * 2.0 * kept as f64 * d as f64;
        let k2_t = self.kernel_time(k2_flops);
        let (_, _, t_k2) = self.kernels.schedule(t_fetch2 + t_filt2, k2_t);
        bd.logit = k2_t / 2.0;
        bd.attend = k2_t / 2.0;

        self.ledger.add("argtopk", bd.argtopk);
        self.ledger.add("flash_read", bd.flash_read);
        self.ledger.add("nfc_filter", bd.nfc_filter);
        self.ledger.add("kernel", bd.logit0 + bd.logit + bd.attend);
        Ok((out, t_k2, bd))
    }

    /// Decode attention for all heads of one layer (q laid out (H, d)).
    /// Heads share the two attention kernels and the flash channels —
    /// the contention is what multi-CSD scaling (Fig. 17a) relieves.
    pub fn attention_layer(
        &mut self,
        slot: u32,
        layer: u16,
        q_hd: &[f32],
        len: usize,
        mode: AttnMode,
        at: Time,
    ) -> Result<(Vec<f32>, Time, UnitBreakdown)> {
        let heads: Vec<u16> = (0..(q_hd.len() / self.d_head) as u16).collect();
        self.attention_heads(slot, layer, &heads, q_hd, len, mode, at)
    }

    /// Decode attention for an explicit head subset (rows packed in the
    /// order of `heads`).
    pub fn attention_heads(
        &mut self,
        slot: u32,
        layer: u16,
        heads: &[u16],
        q: &[f32],
        len: usize,
        mode: AttnMode,
        at: Time,
    ) -> Result<(Vec<f32>, Time, UnitBreakdown)> {
        let d = self.d_head;
        anyhow::ensure!(q.len() == heads.len() * d, "q rows/heads mismatch");
        let mut out = vec![0.0f32; q.len()];
        let mut done = at;
        let mut bd = UnitBreakdown::default();
        for (i, &h) in heads.iter().enumerate() {
            let key = StreamKey { slot, layer, head: h };
            let (o, t, b) = self.attention_head(key, &q[i * d..(i + 1) * d], len, mode, at)?;
            out[i * d..(i + 1) * d].copy_from_slice(&o);
            done = done.max(t);
            bd.merge(&b);
        }
        Ok((out, done, bd))
    }
}

fn pad_to(x: usize, multiple: usize) -> usize {
    x.div_ceil(multiple) * multiple
}

/// Assemble sparse group rows into a dense (rows x d) matrix (absent
/// groups stay zero; they are never touched thanks to the masks).
fn assemble_rows(groups: &[(usize, Vec<f32>)], rows: usize, d: usize) -> Vec<f32> {
    let mut mat = vec![0.0f32; rows * d];
    for (base, data) in groups {
        let n_rows = data.len() / d;
        for i in 0..n_rows {
            let t = base + i;
            if t < rows {
                mat[t * d..(t + 1) * d].copy_from_slice(&data[i * d..(i + 1) * d]);
            }
        }
    }
    mat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hw::CsdSpec;
    use crate::util::rng::Rng;

    fn mk() -> InstCsd {
        InstCsd::new(CsdSpec::tiny(), FtlConfig { d_head: 32, m: 4, n: 8 }).unwrap()
    }

    fn fill(csd: &mut InstCsd, slot: u32, layer: u16, heads: usize, toks: usize, rng: &mut Rng)
        -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        // returns per-head (K rows, V rows) as written (pre-quantisation)
        let d = 32;
        let mut ks = vec![Vec::new(); heads];
        let mut vs = vec![Vec::new(); heads];
        for _ in 0..toks {
            let mut krow = Vec::new();
            let mut vrow = Vec::new();
            for h in 0..heads {
                let kr: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                let vr: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                ks[h].extend_from_slice(&kr);
                vs[h].extend_from_slice(&vr);
                krow.extend(kr);
                vrow.extend(vr);
            }
            csd.write_token(slot, layer, &krow, &vrow, 0.0).unwrap();
        }
        (ks, vs)
    }

    #[test]
    fn dense_engine_matches_sparse_lib() {
        let mut csd = mk();
        let mut rng = Rng::new(1);
        let (ks, vs) = fill(&mut csd, 0, 0, 2, 40, &mut rng);
        let q: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        let key = StreamKey { slot: 0, layer: 0, head: 1 };
        let (out, t, bd) = csd.attention_head(key, &q, 40, AttnMode::Dense, 0.0).unwrap();
        // reference over the SAME fp16-quantised data
        let kq: Vec<f32> = ks[1].iter().map(|&x| crate::ftl::layout::q16(x)).collect();
        let vq: Vec<f32> = vs[1].iter().map(|&x| crate::ftl::layout::q16(x)).collect();
        let want = sparse::dense_attention(&q, &kq, &vq, 40);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        assert!(t > 0.0 && bd.flash_read > 0.0);
    }

    #[test]
    fn sparf_engine_matches_sparse_lib() {
        let mut csd = mk();
        let mut rng = Rng::new(2);
        let (ks, vs) = fill(&mut csd, 0, 0, 1, 64, &mut rng);
        let q: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        let key = StreamKey { slot: 0, layer: 0, head: 0 };
        let sp = SparsityParams { r: 8, k: 16, m: 4, n: 8 };
        let (out, _, bd) = csd
            .attention_head(key, &q, 64, AttnMode::SparF(sp), 0.0)
            .unwrap();
        let kq: Vec<f32> = ks[0].iter().map(|&x| crate::ftl::layout::q16(x)).collect();
        let vq: Vec<f32> = vs[0].iter().map(|&x| crate::ftl::layout::q16(x)).collect();
        let vbar = sparse::v_mean(&vq, 32, 64);
        let want = sparse::sparf_attention(&q, &kq, &vq, &vbar, 64, &sp);
        for (a, b) in out.iter().zip(&want.out) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        assert!(bd.argtopk > 0.0 && bd.logit0 > 0.0 && bd.nfc_filter > 0.0);
    }

    #[test]
    fn sparf_reads_fewer_pages_than_dense() {
        // paper regime: context much longer than k*n, budget 1/8
        let mut rng = Rng::new(3);
        let mut csd = mk();
        fill(&mut csd, 0, 0, 1, 128, &mut rng);
        let q: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        let key = StreamKey { slot: 0, layer: 0, head: 0 };
        let before = csd.ftl.array.counters.page_reads;
        csd.attention_head(key, &q, 128, AttnMode::Dense, 0.0).unwrap();
        let dense_reads = csd.ftl.array.counters.page_reads - before;
        let before = csd.ftl.array.counters.page_reads;
        let sp = SparsityParams { r: 4, k: 8, m: 4, n: 8 };
        csd.attention_head(key, &q, 128, AttnMode::SparF(sp), 0.0).unwrap();
        let sparf_reads = csd.ftl.array.counters.page_reads - before;
        assert!(
            sparf_reads < dense_reads,
            "sparf {sparf_reads} !< dense {dense_reads}"
        );
    }

    #[test]
    fn layer_attention_covers_all_heads() {
        let mut csd = mk();
        let mut rng = Rng::new(4);
        fill(&mut csd, 0, 1, 4, 24, &mut rng);
        let q: Vec<f32> = (0..4 * 32).map(|_| rng.normal_f32()).collect();
        let (out, t, _) = csd
            .attention_layer(0, 1, &q, 24, AttnMode::Dense, 0.0)
            .unwrap();
        assert_eq!(out.len(), 4 * 32);
        assert!(out.iter().any(|&x| x != 0.0));
        assert!(t > 0.0);
    }

    #[test]
    fn unit_breakdown_totals_positive_and_fig16_shape() {
        // Fig. 16's qualitative claim: SparF adds a Logit-0 stage but the
        // flash read time drops (fewer pages); kernel time stays small.
        let key = StreamKey { slot: 0, layer: 0, head: 0 };
        let mut csd = mk();
        let mut rng = Rng::new(5);
        fill(&mut csd, 0, 0, 1, 128, &mut rng);
        let q: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        csd.ftl.array.reset_timing();
        let (_, _, bdd) = csd.attention_head(key, &q, 128, AttnMode::Dense, 0.0).unwrap();
        // fresh device with identical contents: timing starts cold again
        let mut csd2 = mk();
        let mut rng2 = Rng::new(5);
        fill(&mut csd2, 0, 0, 1, 128, &mut rng2);
        let q2: Vec<f32> = (0..32).map(|_| rng2.normal_f32()).collect();
        csd2.ftl.array.reset_timing();
        let sp = SparsityParams { r: 4, k: 8, m: 4, n: 8 };
        let (_, _, bds) = csd2.attention_head(key, &q2, 128, AttnMode::SparF(sp), 0.0).unwrap();
        assert_eq!(bdd.logit0, 0.0);
        assert!(bds.logit0 > 0.0);
        assert!(bds.flash_read < bdd.flash_read);
    }
}
