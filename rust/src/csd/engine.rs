//! The in-storage attention engine (paper Fig. 8), functional + timed.
//!
//! Dataflow per (slot, layer, head) decode step in SparF mode:
//!
//! ```text
//! q --> [argtopk r] --> emb page idxs --> [FTL/flash: K^T pages]
//!                                         --> [NFC filter] --> K^T_[:,i]
//! q_[i], K^T_[:,i] --> [Attention Kernel (Logit-0)] --> s_hat
//! s_hat --> [argtopk k] --> token groups --> [FTL/flash: K,V pages]
//!                                         --> [NFC filter] --> K_[j], V_[j]
//! q, K_[j] --> [Attention Kernel (Logit)] --> s --> [x V_[j] (Attend)]
//! out = alpha * s V + (1-alpha) v̄
//! ```
//!
//! Numerics come from [`crate::sparse`] over the FP16 bytes actually
//! resident in the simulated flash; timing comes from the unit models
//! (argtopk throughput, filter line rate, the two-kernel `MultiServer`,
//! and the flash array's die/channel FIFOs).  Per-unit busy time feeds
//! Fig. 16; the same constants drive the analytic model used at
//! OPT-13B scale (`systems::insti`), which is validated against this
//! engine in the integration tests.

use crate::config::hw::CsdSpec;
use crate::config::model::SparsityParams;
use crate::ftl::{FtlConfig, KvFtl, KvKind, StreamKey};
use crate::kvtier::{PageId, TierConfig, TieredKv};
use crate::sim::{BusyLedger, FifoResource, MultiServer, Time};
use crate::sparse;
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, BTreeSet};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnMode {
    Dense,
    SparF(SparsityParams),
}

/// Per-unit time breakdown of one engine invocation (Fig. 16 rows).
///
/// Semantics: every field is the *wall-clock wait* one invocation spent
/// on that unit — the time the step's critical path could see.
/// Concurrent activity within an invocation counts once: the K and V
/// fetches overlap, so `flash_read` and `dram_hit` each take the max of
/// the two waits, never their sum.  `merge` then sums invocations
/// (heads run back to back through the shared units), which is what the
/// Fig. 16 percentage rows divide.
#[derive(Debug, Clone, Default)]
pub struct UnitBreakdown {
    pub argtopk: Time,
    pub flash_read: Time,
    /// KV pages served by the CSD-DRAM hot tier instead of flash
    pub dram_hit: Time,
    pub nfc_filter: Time,
    pub logit0: Time,
    pub logit: Time,
    pub attend: Time,
    pub writeback: Time,
    /// partial-result transfer to the GPU over fair-share P2P (the
    /// multi-CSD all-reduce tail; zero on a single device)
    pub pcie_xfer: Time,
    /// GPU-side merge of per-shard partials (gather or log-sum-exp)
    pub gpu_merge: Time,
}

impl UnitBreakdown {
    pub fn total(&self) -> Time {
        self.argtopk + self.flash_read + self.dram_hit + self.nfc_filter + self.logit0
            + self.logit + self.attend + self.writeback + self.pcie_xfer + self.gpu_merge
    }

    pub fn merge(&mut self, o: &UnitBreakdown) {
        self.argtopk += o.argtopk;
        self.flash_read += o.flash_read;
        self.dram_hit += o.dram_hit;
        self.nfc_filter += o.nfc_filter;
        self.logit0 += o.logit0;
        self.logit += o.logit;
        self.attend += o.attend;
        self.writeback += o.writeback;
        self.pcie_xfer += o.pcie_xfer;
        self.gpu_merge += o.gpu_merge;
    }
}

/// (outputs, per-head `(max_logit, sum_exp)`, per-head local softmax
/// weights packed `(heads, local_len)`, completion, breakdown) of a
/// context-shard partial attention.  The weights come back so the GPU
/// can rescale them by the merge weight before the importance
/// write-back — locally they sum to 1 per head, which would bias any
/// cross-shard comparison.
pub type PartialAttnResult = (Vec<f32>, Vec<(f32, f32)>, Vec<f32>, Time, UnitBreakdown);

/// dense attention + LSE stats:
/// (out, max_logit, sum_exp, softmax weights over `len`, done, breakdown)
type DenseStats = (Vec<f32>, f32, f32, Vec<f32>, Time, UnitBreakdown);

/// Result of a tier-aware token-group fetch.
struct TieredFetch {
    rows: Vec<(usize, Vec<f32>)>,
    /// per-group completion times aligned with `rows` (base-sorted) —
    /// what the read-compute pipelining consumes
    group_done: Vec<Time>,
    done: Time,
    /// wall wait attributable to hot-tier hits (latest hit completion
    /// minus issue time; zero when everything missed)
    dram_wait: Time,
    /// wall wait attributable to flash (misses), relative to issue time
    flash_wait: Time,
}

/// Flash-array utilisation snapshot: the die/channel busy seconds and
/// the deepest die backlog, surfaced in the serve summary and the
/// engine-backed bench rows so the placement's effect on the internal
/// parallelism is visible in the trajectory document.
#[derive(Debug, Clone, Copy, Default)]
pub struct FlashUtil {
    pub die_busy_s: Time,
    pub channel_busy_s: Time,
    pub die_peak_depth: usize,
}

impl FlashUtil {
    pub fn merge(&mut self, o: &FlashUtil) {
        self.die_busy_s += o.die_busy_s;
        self.channel_busy_s += o.channel_busy_s;
        self.die_peak_depth = self.die_peak_depth.max(o.die_peak_depth);
    }
}

/// One group's slice of the step-8 kernel work for the pipelined path.
struct KernelChunk {
    /// K-page landing time (Logit readiness)
    k_ready: Time,
    /// V-page landing time (Attend readiness, once its logits are in)
    v_ready: Time,
    logit_flops: f64,
    attend_flops: f64,
}

pub struct InstCsd {
    pub spec: CsdSpec,
    pub ftl: KvFtl,
    /// CSD-DRAM hot tier + importance tracker fronting the FTL
    pub tier: TieredKv,
    kernels: MultiServer,
    /// DRAM group-buffer port serving hot-tier hits
    dram: FifoResource,
    pub ledger: BusyLedger,
    d_head: usize,
    /// per-slot token positions masked out by drop-on-resume
    dropped: BTreeMap<u32, BTreeSet<u32>>,
    /// slot -> (prefix pseudo-slot, shared tokens) for streams that
    /// attached a cached prefix; the hot tier keys shared groups under
    /// the pseudo-slot so every sharer hits one DRAM copy
    attached: BTreeMap<u32, (u32, usize)>,
}

impl InstCsd {
    /// Construct with the spec's default tier shape (`hot_tier_bytes`
    /// under LRU; the unit-test specs default to flash-only).
    pub fn new(spec: CsdSpec, ftl_cfg: FtlConfig) -> Result<Self> {
        let tier = TierConfig::for_spec(&spec);
        Self::with_tier(spec, ftl_cfg, tier)
    }

    /// Construct with an explicit hot-tier capacity and policy.
    pub fn with_tier(spec: CsdSpec, ftl_cfg: FtlConfig, tier: TierConfig) -> Result<Self> {
        let ftl = KvFtl::new(spec.flash, ftl_cfg)?;
        Ok(InstCsd {
            kernels: MultiServer::new(spec.attn_kernels),
            tier: TieredKv::new(tier, spec.flash.page_bytes, ftl_cfg.n),
            spec,
            ftl,
            dram: FifoResource::new(),
            ledger: BusyLedger::default(),
            d_head: ftl_cfg.d_head,
            dropped: BTreeMap::new(),
            attached: BTreeMap::new(),
        })
    }

    /// Per-head embedding dimension this engine was configured with.
    pub fn head_dim(&self) -> usize {
        self.d_head
    }

    /// Arm the flash-layer fault injector for this engine (device index
    /// `dev` seeds an independent per-device RNG stream).  A config with
    /// `rate == 0` leaves the read path untouched.
    pub fn install_fault(&mut self, cfg: &crate::fault::FaultConfig, dev: usize) {
        if cfg.injecting() {
            self.ftl.array.install_fault(cfg, dev);
        }
    }

    fn argtopk_time(&self, elems: usize) -> Time {
        elems as f64 / self.spec.argtopk_elems_per_s
    }

    fn kernel_time(&self, flops: f64) -> Time {
        // one kernel owns half the engine's DSPs (Fig. 8: two identical
        // kernels share the array)
        flops / (self.spec.engine_flops / self.spec.attn_kernels as f64)
    }

    fn filter_time(&self, bytes: usize) -> Time {
        // NFC filters run at line rate per channel; aggregate across
        // channels since pages arrive distributed
        bytes as f64 / (self.spec.filter_bw_per_channel * self.spec.flash.channels as f64)
    }

    /// Incrementally schedule per-group Logit/Attend kernel chunks as
    /// the group reads complete (paper Fig. 8's pipelined engine,
    /// `FlashPathConfig::pipeline`).  Logit chunks chain in K-arrival
    /// order on one logical kernel; a group's Attend chunk needs its V
    /// page and its own logits (the online-softmax rescale is folded
    /// into the final chunk).  Timing only — the functional softmax and
    /// attend arithmetic are computed exactly as in the barrier path,
    /// so outputs are bit-identical.  Returns (completion, logit busy,
    /// attend busy); `floor` is the completion when there is no chunk.
    fn pipeline_kernels(&mut self, chunks: &[KernelChunk], floor: Time) -> (Time, Time, Time) {
        let mut order: Vec<usize> = (0..chunks.len()).collect();
        order.sort_by(|&a, &b| {
            chunks[a].k_ready.partial_cmp(&chunks[b].k_ready).unwrap().then(a.cmp(&b))
        });
        let mut logit_end = vec![0.0; chunks.len()];
        let mut prev = f64::NEG_INFINITY;
        let mut logit_busy = 0.0;
        for &i in &order {
            let svc = self.kernel_time(chunks[i].logit_flops);
            let (_, _, e) = self.kernels.schedule(chunks[i].k_ready.max(prev), svc);
            logit_end[i] = e;
            prev = e;
            logit_busy += svc;
        }
        let ready: Vec<Time> =
            (0..chunks.len()).map(|i| chunks[i].v_ready.max(logit_end[i])).collect();
        let mut order2: Vec<usize> = (0..chunks.len()).collect();
        order2.sort_by(|&a, &b| ready[a].partial_cmp(&ready[b]).unwrap().then(a.cmp(&b)));
        let mut done = floor;
        let mut prev2 = f64::NEG_INFINITY;
        let mut attend_busy = 0.0;
        for &i in &order2 {
            let svc = self.kernel_time(chunks[i].attend_flops);
            let (_, _, e) = self.kernels.schedule(ready[i].max(prev2), svc);
            prev2 = e;
            attend_busy += svc;
            done = done.max(e);
        }
        (done, logit_busy, attend_busy)
    }

    /// Fold the die/channel busy accumulated since the given marks into
    /// the per-engine ledger (the utilisation rows next to the unit
    /// breakdowns).
    fn ledger_flash_busy(&mut self, die_mark: Time, chan_mark: Time) {
        let die_d = self.ftl.array.die_busy() - die_mark;
        if die_d > 0.0 {
            self.ledger.add("flash_die_busy", die_d);
        }
        let chan_d = self.ftl.array.channel_busy() - chan_mark;
        if chan_d > 0.0 {
            self.ledger.add("flash_chan_busy", chan_d);
        }
    }

    /// Flash-array utilisation counters for this engine.
    pub fn flash_util(&self) -> FlashUtil {
        FlashUtil {
            die_busy_s: self.ftl.array.die_busy(),
            channel_busy_s: self.ftl.array.channel_busy(),
            die_peak_depth: self.ftl.array.die_peak_depth(),
        }
    }

    /// Tier-aware token-group fetch: hot-tier hits are served by the
    /// DRAM group-buffer port and never touch the flash die/channel
    /// FIFOs; misses stream from flash and are read-allocated into the
    /// tier (evicting per the configured policy).  Tail groups pass
    /// through to the FTL, which serves them from its stream buffer.
    fn fetch_token_groups_tiered(
        &mut self,
        key: StreamKey,
        kind: KvKind,
        groups: &[usize],
        at: Time,
    ) -> Result<TieredFetch> {
        let n = self.ftl.cfg.n;
        let page_bytes = self.spec.flash.page_bytes;
        let sealed = self.ftl.sealed_groups(key);
        // groups inside an attached shared prefix are keyed in the hot
        // tier under the prefix pseudo-slot, so every sharer (and every
        // future sharer) hits the same DRAM copy instead of pinning
        // per-slot duplicates of one physical flash page
        let attached = self.attached.get(&key.slot).copied();
        let canon = |g: usize| match attached {
            Some((pslot, toks)) if (g + 1) * n <= toks => {
                StreamKey { slot: pslot, layer: key.layer, head: key.head }
            }
            _ => key,
        };
        let mut items: Vec<(usize, Vec<f32>, Time)> = Vec::with_capacity(groups.len());
        let mut misses: Vec<usize> = Vec::new();
        let mut done = at;
        let mut dram_done = at;
        let mut flash_wait = 0.0;
        for &g in groups {
            if g >= sealed {
                misses.push(g); // tail group: FTL DRAM stream buffer
                continue;
            }
            let id = PageId { key: canon(g), kind, group: g as u32 };
            match self.tier.lookup(id) {
                Some(data) => {
                    let svc = page_bytes as f64 / self.spec.dram_bw;
                    let (_, t) = self.dram.schedule(at, svc);
                    dram_done = dram_done.max(t);
                    done = done.max(t);
                    items.push((g * n, data, t));
                }
                None => misses.push(g),
            }
        }
        if !misses.is_empty() {
            let (fetched, t) = self.ftl.fetch_token_groups(key, kind, &misses, at)?;
            flash_wait = t - at;
            done = done.max(t);
            let stream_len = self.ftl.tokens_appended(key);
            for gf in fetched {
                let g = gf.base / n;
                if g < sealed {
                    let id = PageId { key: canon(g), kind, group: g as u32 };
                    let (resident, evicted) = self.tier.admit(id, gf.rows.clone(), stream_len);
                    if resident {
                        self.ftl.counters.promotions += 1;
                    }
                    for ev in evicted {
                        self.ftl.demote_group(ev.key, ev.kind, ev.group as usize);
                    }
                }
                items.push((gf.base, gf.rows, gf.done));
            }
        }
        items.sort_by_key(|it| it.0);
        let dram_wait = (dram_done - at).max(0.0);
        let mut rows = Vec::with_capacity(items.len());
        let mut group_done = Vec::with_capacity(items.len());
        for (base, data, t) in items {
            rows.push((base, data));
            group_done.push(t);
        }
        Ok(TieredFetch { rows, group_done, done, dram_wait, flash_wait })
    }

    /// Mask token positions of `slot` out of all future attention
    /// (H2O-style drop-on-resume).  Sealed groups whose tokens are all
    /// dropped are demoted from the hot tier and their flash pages
    /// freed; partially-dropped groups keep their pages and are masked
    /// per token.  Positions are preserved, so nothing is re-indexed.
    pub fn drop_tokens(&mut self, slot: u32, tokens: &[u32]) -> Result<()> {
        if tokens.is_empty() {
            return Ok(());
        }
        let set = self.dropped.entry(slot).or_default();
        for &t in tokens {
            set.insert(t);
        }
        let set = set.clone();
        let n = self.ftl.cfg.n;
        let attached = self.attached.get(&slot).copied();
        for key in self.ftl.stream_keys(slot) {
            let sealed = self.ftl.sealed_groups(key);
            for g in 0..sealed {
                let all_dropped = (g * n..(g + 1) * n).all(|t| set.contains(&(t as u32)));
                if !all_dropped {
                    continue;
                }
                // a group inside an attached shared prefix keeps its
                // canonical hot-tier page (other sharers still read it);
                // detaching only drops this stream's reference
                let shared_prefix = attached.is_some_and(|(_, toks)| (g + 1) * n <= toks);
                if !shared_prefix {
                    for kind in [KvKind::K, KvKind::V] {
                        let id = PageId { key, kind, group: g as u32 };
                        if self.tier.drop_page(id) {
                            self.ftl.demote_group(key, kind, g);
                        }
                    }
                }
                self.ftl.free_token_group(key, g);
            }
        }
        Ok(())
    }

    /// Release a finished sequence everywhere: hot-tier pages,
    /// importance statistics, drop masks, then the FTL mappings.
    pub fn free_slot(&mut self, slot: u32, at: Time) -> Result<Time> {
        self.tier.free_slot(slot);
        self.dropped.remove(&slot);
        self.attached.remove(&slot);
        self.ftl.free_slot(slot, at)
    }

    /// Attach a registered prefix (looked up by its boundary hash) to
    /// `slot`: the FTL aliases the sealed pages into the slot's stream
    /// mappings and this engine records the canonical pseudo-slot so the
    /// hot tier serves one shared DRAM copy for all sharers.  Returns the
    /// attached token count.
    pub fn attach_prefix(&mut self, slot: u32, hash: u64) -> Result<usize> {
        let (pslot, tokens) = self.ftl.attach_prefix(hash, slot)?;
        if tokens > 0 {
            self.attached.insert(slot, (pslot, tokens));
        }
        Ok(tokens)
    }

    /// Register a just-prefilled slot's sealed prefix groups in the
    /// content-addressed index.  Hot-tier pages keyed under any
    /// LRU-evicted registration's pseudo-slot are purged with it.
    /// Malformed bounds (non-ascending, or not group-aligned) are
    /// rejected as error completions instead of corrupting the index.
    pub fn register_prefix(&mut self, slot: u32, bounds: &[(u64, usize)]) -> Result<()> {
        anyhow::ensure!(
            slot < crate::ftl::PREFIX_SLOT_BASE,
            "register_prefix: slot {slot} collides with the pseudo-slot range"
        );
        let n = self.ftl.cfg.n;
        anyhow::ensure!(
            bounds.windows(2).all(|w| w[0].1 < w[1].1),
            "register_prefix: bounds not strictly ascending"
        );
        anyhow::ensure!(
            bounds.iter().all(|&(_, t)| t > 0 && t % n == 0),
            "register_prefix: bounds not aligned to the {n}-token group size"
        );
        for pslot in self.ftl.register_prefix(slot, bounds) {
            self.tier.free_slot(pslot);
        }
        Ok(())
    }

    /// Store one token's K/V rows for every head of a layer (decode write).
    pub fn write_token(
        &mut self,
        slot: u32,
        layer: u16,
        k_rows: &[f32],
        v_rows: &[f32],
        at: Time,
    ) -> Result<Time> {
        let heads: Vec<u16> = (0..(k_rows.len() / self.d_head) as u16).collect();
        let pos = self.ftl.tokens_appended(StreamKey { slot, layer, head: 0 });
        self.write_token_heads(slot, layer, &heads, pos, k_rows, v_rows, at)
    }

    /// Store one token's K/V rows for an explicit head subset (the rows are
    /// packed in the order of `heads` — what the head->CSD router ships).
    /// `pos` is the token's stream position (tokens already appended
    /// before it): a stream that is already past `pos` skips the append,
    /// so re-running a partially-applied command after a fault is exact
    /// instead of double-writing.
    pub fn write_token_heads(
        &mut self,
        slot: u32,
        layer: u16,
        heads: &[u16],
        pos: usize,
        k_rows: &[f32],
        v_rows: &[f32],
        at: Time,
    ) -> Result<Time> {
        let d = self.d_head;
        anyhow::ensure!(k_rows.len() == heads.len() * d, "k rows/heads mismatch");
        let mut t = at;
        for (i, &h) in heads.iter().enumerate() {
            let key = StreamKey { slot, layer, head: h };
            let have = self.ftl.tokens_appended(key);
            if have > pos {
                continue; // already applied (command retried after a fault)
            }
            anyhow::ensure!(have == pos, "write_token at pos {pos} but stream holds {have}");
            t = t.max(self.ftl.append_token(
                key,
                &k_rows[i * d..(i + 1) * d],
                &v_rows[i * d..(i + 1) * d],
                at,
            )?);
        }
        Ok(t)
    }

    /// Store a prefill layer's KV for every head (layer-wise shipping).
    pub fn write_prefill_layer(
        &mut self,
        slot: u32,
        layer: u16,
        heads: usize,
        s_len: usize,
        k_hsd: &[f32],
        v_hsd: &[f32],
        at: Time,
    ) -> Result<Time> {
        let hs: Vec<u16> = (0..heads as u16).collect();
        let pos = self.ftl.tokens_appended(StreamKey { slot, layer, head: 0 });
        self.write_prefill_heads(slot, layer, &hs, pos, s_len, k_hsd, v_hsd, at)
    }

    /// Store a prefill layer's KV for an explicit head subset (rows packed
    /// (heads, s_len, d) in the order of `heads`).  `pos` is the stream
    /// position the `s_len` tokens start at (the prefix-attach/context
    /// skip); a stream already holding `pos + s_len` tokens skips the
    /// append, making post-fault re-runs exact.
    pub fn write_prefill_heads(
        &mut self,
        slot: u32,
        layer: u16,
        heads: &[u16],
        pos: usize,
        s_len: usize,
        k_hsd: &[f32],
        v_hsd: &[f32],
        at: Time,
    ) -> Result<Time> {
        let d = self.d_head;
        anyhow::ensure!(k_hsd.len() == heads.len() * s_len * d, "prefill rows/heads mismatch");
        let mut t = at;
        for (i, &h) in heads.iter().enumerate() {
            let key = StreamKey { slot, layer, head: h };
            let have = self.ftl.tokens_appended(key);
            if have >= pos + s_len {
                continue; // already applied (command retried after a fault)
            }
            anyhow::ensure!(have == pos, "prefill at pos {pos} but stream holds {have}");
            let base = i * s_len * d;
            t = t.max(self.ftl.append_prefill(
                key,
                &k_hsd[base..base + s_len * d],
                &v_hsd[base..base + s_len * d],
                at,
            )?);
        }
        Ok(t)
    }

    /// Decode-phase attention for one head.  Returns (output, completion,
    /// per-unit breakdown).
    pub fn attention_head(
        &mut self,
        key: StreamKey,
        q: &[f32],
        len: usize,
        mode: AttnMode,
        at: Time,
    ) -> Result<(Vec<f32>, Time, UnitBreakdown)> {
        match mode {
            AttnMode::Dense => self.dense_head(key, q, len, at),
            AttnMode::SparF(sp) => self.sparf_head(key, q, len, &sp, at),
        }
    }

    fn dense_head(
        &mut self,
        key: StreamKey,
        q: &[f32],
        len: usize,
        at: Time,
    ) -> Result<(Vec<f32>, Time, UnitBreakdown)> {
        let (out, _, _, _, t, bd) = self.dense_head_stats(key, q, len, at, true)?;
        Ok((out, t, bd))
    }

    /// Dense decode attention plus the log-sum-exp statistics (max
    /// logit, sum of exp over valid tokens) a context-shard merge needs.
    /// The output is the plain dense path's — the stats are observed,
    /// never applied — so single-device callers are bit-for-bit
    /// unchanged.  `feed_importance: true` is the plain path: H2O mass
    /// accumulates here and the stats/weights come back empty (no extra
    /// passes on the hot path).  `feed_importance: false` (the partial
    /// path) skips accumulation — the coordinator writes back
    /// merge-weight-rescaled mass instead — and returns real stats plus
    /// the local softmax weights.
    fn dense_head_stats(
        &mut self,
        key: StreamKey,
        q: &[f32],
        len: usize,
        at: Time,
        feed_importance: bool,
    ) -> Result<DenseStats> {
        let d = self.d_head;
        let n = self.ftl.cfg.n;
        let mut bd = UnitBreakdown::default();
        let n_groups = len.div_ceil(n);
        let dropped = self.dropped.get(&key.slot).cloned().unwrap_or_default();
        // fully-dropped groups were freed on flash: skip them at the source
        let groups: Vec<usize> = (0..n_groups)
            .filter(|&g| {
                let hi = ((g + 1) * n).min(len);
                (g * n..hi).any(|t| !dropped.contains(&(t as u32)))
            })
            .collect();

        let t0 = at;
        let die_mark = self.ftl.array.die_busy();
        let chan_mark = self.ftl.array.channel_busy();
        let fk = self.fetch_token_groups_tiered(key, KvKind::K, &groups, t0)?;
        let fv = self.fetch_token_groups_tiered(key, KvKind::V, &groups, t0)?;
        let t_read = fk.done.max(fv.done);
        bd.flash_read = fk.flash_wait.max(fv.flash_wait);
        bd.dram_hit = fk.dram_wait.max(fv.dram_wait);

        let rows = n_groups * n;
        let kmat = assemble_rows(&fk.rows, rows, d);
        let vmat = assemble_rows(&fv.rows, rows, d);

        // exact attention over the non-dropped prefix; arithmetic is
        // identical to sparse::dense_attention when nothing is dropped,
        // and the softmax weights feed the H2O importance tracker
        let scale = 1.0 / (d as f32).sqrt();
        let mask: Vec<bool> =
            (0..rows).map(|t| t < len && !dropped.contains(&(t as u32))).collect();
        let mut logits = vec![sparse::select::NEG_INF; rows];
        for t in 0..rows {
            if mask[t] {
                logits[t] = sparse::select::dot(q, &kmat[t * d..(t + 1) * d]) * scale;
            }
        }
        let s = sparse::select::softmax_masked(&logits, &mask);
        // LSE stats for cross-shard merging (partial path only — the
        // plain path would drop them), with softmax_masked's exact
        // reduction order so a lone shard reproduces `s` bit-for-bit
        let mut mx = sparse::select::NEG_INF;
        let mut sum_exp = 0.0f32;
        if !feed_importance {
            for (l, &mk) in logits.iter().zip(&mask) {
                if mk && *l > mx {
                    mx = *l;
                }
            }
            for (l, &mk) in logits.iter().zip(&mask) {
                if mk {
                    sum_exp += (*l - mx).exp();
                }
            }
        }
        let mut out = vec![0.0f32; d];
        for t in 0..rows {
            let wt = s[t];
            if wt == 0.0 {
                continue;
            }
            let row = &vmat[t * d..(t + 1) * d];
            for c in 0..d {
                out[c] += wt * row[c];
            }
        }
        let weights = if feed_importance {
            self.tier.importance.accumulate(key.slot, &s[..len]);
            Vec::new()
        } else {
            s[..len].to_vec()
        };

        // Logit GeMV (2*len*d) + softmax + Attend GeMV (2*len*d): one
        // barrier'd pass behind the full fetch (legacy), or per-group
        // chunks pipelined behind the page reads as they land
        let (t2, logit_busy, attend_busy) = if self.spec.flash.path.pipeline {
            let chunks: Vec<KernelChunk> = groups
                .iter()
                .enumerate()
                .map(|(i, &g)| {
                    let toks = n.min(len - g * n) as f64;
                    KernelChunk {
                        k_ready: fk.group_done[i],
                        v_ready: fv.group_done[i],
                        logit_flops: 2.0 * toks * d as f64,
                        attend_flops: 2.0 * toks * d as f64,
                    }
                })
                .collect();
            self.pipeline_kernels(&chunks, t_read)
        } else {
            let logit_t = self.kernel_time(2.0 * len as f64 * d as f64);
            let attend_t = self.kernel_time(2.0 * len as f64 * d as f64);
            let (_, _, t1) = self.kernels.schedule(t_read, logit_t);
            let (_, _, t2) = self.kernels.schedule(t1, attend_t);
            (t2, logit_t, attend_t)
        };
        bd.logit = logit_busy;
        bd.attend = attend_busy;
        self.ledger.add("flash_read", bd.flash_read);
        if bd.dram_hit > 0.0 {
            self.ledger.add("dram_hit", bd.dram_hit);
        }
        self.ledger.add("kernel", logit_busy + attend_busy);
        self.ledger_flash_busy(die_mark, chan_mark);
        Ok((out, mx, sum_exp, weights, t2, bd))
    }

    fn sparf_head(
        &mut self,
        key: StreamKey,
        q: &[f32],
        len: usize,
        sp: &SparsityParams,
        at: Time,
    ) -> Result<(Vec<f32>, Time, UnitBreakdown)> {
        let d = self.d_head;
        let n = self.ftl.cfg.n;
        let mut bd = UnitBreakdown::default();
        let page_bytes = self.spec.flash.page_bytes;
        let dropped = self.dropped.get(&key.slot).cloned().unwrap_or_default();
        let die_mark = self.ftl.array.die_busy();
        let chan_mark = self.ftl.array.channel_busy();

        // ---- step 1: argtopk over |q| (d elements)
        let t_top1 = self.argtopk_time(d);
        let t1 = at + t_top1;
        bd.argtopk += t_top1;
        let absq: Vec<f32> = q.iter().map(|x| x.abs()).collect();
        let emb_mask = sparse::select::topk_mask_select(&absq, sp.r);
        let channels: Vec<usize> =
            (0..d).filter(|&c| emb_mask[c]).collect();

        // ---- step 2: embedding-indexed page fetch (group-shared)
        let (lanes, t_fetch1) = self.ftl.fetch_emb_channels(key, &channels, len, t1)?;
        bd.flash_read += t_fetch1 - t1;
        // NFC filter pass over the fetched pages
        let egroups: std::collections::BTreeSet<usize> =
            channels.iter().map(|c| c / self.ftl.cfg.m).collect();
        let t_emb = self.ftl.tokens_per_emb_page();
        let fetched_bytes = egroups.len() * len.div_ceil(t_emb) * page_bytes;
        let t_filt1 = self.filter_time(fetched_bytes);
        bd.nfc_filter += t_filt1;

        // ---- step 4: Kernel #1 — approximate scores over r channels
        let l1_all: f32 = absq.iter().sum();
        let l1_kept: f32 = channels.iter().map(|&c| absq[c]).sum();
        let scale_hat = ((d as f32) * l1_kept / l1_all.max(1e-30)).sqrt().max(1e-30);
        let mut logits_hat = vec![sparse::select::NEG_INF; pad_to(len, n)];
        for t in 0..len {
            let mut acc = 0.0f32;
            for (ci, &c) in channels.iter().enumerate() {
                acc += q[c] * lanes[ci][t];
            }
            logits_hat[t] = acc / scale_hat;
        }
        let valid: Vec<bool> = (0..logits_hat.len())
            .map(|t| t < len && !dropped.contains(&(t as u32)))
            .collect();
        let s_hat = sparse::select::softmax_masked(&logits_hat, &valid);
        let k1_flops = 2.0 * len as f64 * sp.r as f64;
        let k1_t = self.kernel_time(k1_flops);
        let (_, _, t_k1) = self.kernels.schedule(t_fetch1 + t_filt1, k1_t);
        bd.logit0 = k1_t;

        // ---- steps 5-6: argtopk over tokens
        let t_top2 = self.argtopk_time(len);
        bd.argtopk += t_top2;
        let pool: Vec<f32> = s_hat
            .iter()
            .zip(&valid)
            .map(|(&s, &m)| if m { s } else { -1.0 })
            .collect();
        let mut tok_mask = sparse::select::topk_mask_select(&pool, sp.k.min(len));
        for (t, tm) in tok_mask.iter_mut().enumerate() {
            *tm &= valid[t];
        }
        let alpha: f32 = s_hat
            .iter()
            .zip(&tok_mask)
            .filter(|(_, &m)| m)
            .map(|(s, _)| s)
            .sum::<f32>()
            .clamp(0.0, 1.0);

        // ---- step 8: token-indexed page fetch for K and V
        let groups: Vec<usize> = (0..tok_mask.len().div_ceil(n))
            .filter(|&g| tok_mask[g * n..((g + 1) * n).min(tok_mask.len())].iter().any(|&b| b))
            .collect();
        let t2 = t_k1 + t_top2;
        let fk = self.fetch_token_groups_tiered(key, KvKind::K, &groups, t2)?;
        let fv = self.fetch_token_groups_tiered(key, KvKind::V, &groups, t2)?;
        let t_fetch2 = fk.done.max(fv.done);
        bd.flash_read += fk.flash_wait.max(fv.flash_wait);
        bd.dram_hit += fk.dram_wait.max(fv.dram_wait);

        // ---- steps 9-11: Kernel #2 — exact attention over kept tokens
        let rows = pad_to(len, n);
        let kmat = assemble_rows(&fk.rows, rows, d);
        let vmat = assemble_rows(&fv.rows, rows, d);
        let scale = 1.0 / (d as f32).sqrt();
        let mut logits = vec![sparse::select::NEG_INF; rows];
        for t in 0..rows {
            if tok_mask[t] {
                logits[t] = sparse::select::dot(q, &kmat[t * d..(t + 1) * d]) * scale;
            }
        }
        let s = sparse::select::softmax_masked(&logits, &tok_mask);
        let vbar = self
            .ftl
            .vbar(key)
            .ok_or_else(|| anyhow!("no v̄ for stream {key:?}"))?;
        let mut out = vec![0.0f32; d];
        for t in 0..rows {
            if s[t] != 0.0 {
                for c in 0..d {
                    out[c] += s[t] * vmat[t * d + c];
                }
            }
        }
        for c in 0..d {
            out[c] = alpha * out[c] + (1.0 - alpha) * vbar[c];
        }
        // Kernel #2 timing: one barrier'd pass after the whole fetch +
        // filter (legacy), or per-group chunks pipelined behind the page
        // reads — each group becomes ready one per-page filter pass
        // after its K/V pages land.  The filter wall-wait follows suit:
        // barrier'd, the whole 2*G-page pass sits on the critical path;
        // pipelined, the passes overlap the reads and only one page's
        // filter depth delays the last chunk.
        let t_k2 = if self.spec.flash.path.pipeline {
            // one page streams through its OWN channel's filter at the
            // per-channel line rate (filter_time's aggregate rate only
            // applies to batches striped across every channel)
            let pf = page_bytes as f64 / self.spec.filter_bw_per_channel;
            bd.nfc_filter += pf;
            let chunks: Vec<KernelChunk> = groups
                .iter()
                .enumerate()
                .map(|(i, &g)| {
                    let hi = ((g + 1) * n).min(tok_mask.len());
                    let kept_g = tok_mask[g * n..hi].iter().filter(|&&b| b).count() as f64;
                    KernelChunk {
                        k_ready: fk.group_done[i] + pf,
                        v_ready: fv.group_done[i] + pf,
                        logit_flops: 2.0 * kept_g * d as f64,
                        attend_flops: 2.0 * kept_g * d as f64,
                    }
                })
                .collect();
            let (t_k2, logit_busy, attend_busy) = self.pipeline_kernels(&chunks, t_fetch2);
            bd.logit = logit_busy;
            bd.attend = attend_busy;
            t_k2
        } else {
            let t_filt2 = self.filter_time(2 * groups.len() * page_bytes);
            bd.nfc_filter += t_filt2;
            let kept = tok_mask.iter().filter(|&&b| b).count();
            let k2_flops = 2.0 * 2.0 * kept as f64 * d as f64;
            let k2_t = self.kernel_time(k2_flops);
            let (_, _, t_k2) = self.kernels.schedule(t_fetch2 + t_filt2, k2_t);
            bd.logit = k2_t / 2.0;
            bd.attend = k2_t / 2.0;
            t_k2
        };
        self.tier.importance.accumulate(key.slot, &s[..len]);

        self.ledger.add("argtopk", bd.argtopk);
        self.ledger.add("flash_read", bd.flash_read);
        if bd.dram_hit > 0.0 {
            self.ledger.add("dram_hit", bd.dram_hit);
        }
        self.ledger.add("nfc_filter", bd.nfc_filter);
        self.ledger.add("kernel", bd.logit0 + bd.logit + bd.attend);
        self.ledger_flash_busy(die_mark, chan_mark);
        Ok((out, t_k2, bd))
    }

    /// Decode attention for all heads of one layer (q laid out (H, d)).
    /// Heads share the two attention kernels and the flash channels —
    /// the contention is what multi-CSD scaling (Fig. 17a) relieves.
    pub fn attention_layer(
        &mut self,
        slot: u32,
        layer: u16,
        q_hd: &[f32],
        len: usize,
        mode: AttnMode,
        at: Time,
    ) -> Result<(Vec<f32>, Time, UnitBreakdown)> {
        let heads: Vec<u16> = (0..(q_hd.len() / self.d_head) as u16).collect();
        self.attention_heads(slot, layer, &heads, q_hd, len, mode, at)
    }

    /// Decode attention for an explicit head subset (rows packed in the
    /// order of `heads`).
    pub fn attention_heads(
        &mut self,
        slot: u32,
        layer: u16,
        heads: &[u16],
        q: &[f32],
        len: usize,
        mode: AttnMode,
        at: Time,
    ) -> Result<(Vec<f32>, Time, UnitBreakdown)> {
        let d = self.d_head;
        anyhow::ensure!(q.len() == heads.len() * d, "q rows/heads mismatch");
        let mut out = vec![0.0f32; q.len()];
        let mut done = at;
        let mut bd = UnitBreakdown::default();
        for (i, &h) in heads.iter().enumerate() {
            let key = StreamKey { slot, layer, head: h };
            let (o, t, b) = self.attention_head(key, &q[i * d..(i + 1) * d], len, mode, at)?;
            out[i * d..(i + 1) * d].copy_from_slice(&o);
            done = done.max(t);
            bd.merge(&b);
        }
        Ok((out, done, bd))
    }

    /// Context-shard decode attention: locally-softmaxed dense attention
    /// over the `local_len` tokens resident on this device, returning
    /// each head's `(max_logit, sum_exp)` merge statistics alongside the
    /// outputs.  Arithmetic and timing are exactly the dense path's — a
    /// lone shard merged with itself reproduces [`Self::attention_heads`]
    /// bit-for-bit.
    pub fn partial_attention_heads(
        &mut self,
        slot: u32,
        layer: u16,
        heads: &[u16],
        q: &[f32],
        local_len: usize,
        at: Time,
    ) -> Result<PartialAttnResult> {
        let d = self.d_head;
        anyhow::ensure!(q.len() == heads.len() * d, "q rows/heads mismatch");
        let mut out = vec![0.0f32; q.len()];
        let mut stats = Vec::with_capacity(heads.len());
        let mut weights = Vec::with_capacity(heads.len() * local_len);
        let mut done = at;
        let mut bd = UnitBreakdown::default();
        for (i, &h) in heads.iter().enumerate() {
            let key = StreamKey { slot, layer, head: h };
            let (o, m, l, w, t, b) =
                self.dense_head_stats(key, &q[i * d..(i + 1) * d], local_len, at, false)?;
            out[i * d..(i + 1) * d].copy_from_slice(&o);
            stats.push((m, l));
            weights.extend_from_slice(&w);
            done = done.max(t);
            bd.merge(&b);
        }
        Ok((out, stats, weights, done, bd))
    }

    /// Fold externally-computed (globally-rescaled) attention mass into
    /// the H2O importance tracker — the context-shard write-back the
    /// GPU issues after the log-sum-exp merge.  Non-finite mass is a
    /// malformed command: surfaced as an error completion, not folded.
    pub fn accumulate_importance(&mut self, slot: u32, weights: &[f32]) -> Result<()> {
        anyhow::ensure!(
            weights.iter().all(|w| w.is_finite()),
            "accumulate_importance: non-finite attention mass for slot {slot}"
        );
        self.tier.importance.accumulate(slot, weights);
        Ok(())
    }

    /// Shared tiny-geometry engine for unit tests and benches (tiny
    /// flash array, opt-micro head shape).  Call sites used to
    /// copy-paste the spec + FtlConfig literals.
    pub fn tiny_test() -> Self {
        InstCsd::new(CsdSpec::tiny(), FtlConfig::micro_head()).expect("tiny test spec")
    }

    /// Shared micro-geometry engine (micro flash sized for opt-micro).
    pub fn micro_test() -> Self {
        InstCsd::new(CsdSpec::micro(), FtlConfig::micro_head()).expect("micro test spec")
    }
}

fn pad_to(x: usize, multiple: usize) -> usize {
    x.div_ceil(multiple) * multiple
}

/// Assemble sparse group rows into a dense (rows x d) matrix (absent
/// groups stay zero; they are never touched thanks to the masks).
fn assemble_rows(groups: &[(usize, Vec<f32>)], rows: usize, d: usize) -> Vec<f32> {
    let mut mat = vec![0.0f32; rows * d];
    for (base, data) in groups {
        let n_rows = data.len() / d;
        for i in 0..n_rows {
            let t = base + i;
            if t < rows {
                mat[t * d..(t + 1) * d].copy_from_slice(&data[i * d..(i + 1) * d]);
            }
        }
    }
    mat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hw::CsdSpec;
    use crate::util::rng::Rng;

    fn mk() -> InstCsd {
        InstCsd::tiny_test()
    }

    fn fill(csd: &mut InstCsd, slot: u32, layer: u16, heads: usize, toks: usize, rng: &mut Rng)
        -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        // returns per-head (K rows, V rows) as written (pre-quantisation)
        let d = 32;
        let mut ks = vec![Vec::new(); heads];
        let mut vs = vec![Vec::new(); heads];
        for _ in 0..toks {
            let mut krow = Vec::new();
            let mut vrow = Vec::new();
            for h in 0..heads {
                let kr: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                let vr: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                ks[h].extend_from_slice(&kr);
                vs[h].extend_from_slice(&vr);
                krow.extend(kr);
                vrow.extend(vr);
            }
            csd.write_token(slot, layer, &krow, &vrow, 0.0).unwrap();
        }
        (ks, vs)
    }

    #[test]
    fn dense_engine_matches_sparse_lib() {
        let mut csd = mk();
        let mut rng = Rng::new(1);
        let (ks, vs) = fill(&mut csd, 0, 0, 2, 40, &mut rng);
        let q: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        let key = StreamKey { slot: 0, layer: 0, head: 1 };
        let (out, t, bd) = csd.attention_head(key, &q, 40, AttnMode::Dense, 0.0).unwrap();
        // reference over the SAME fp16-quantised data
        let kq: Vec<f32> = ks[1].iter().map(|&x| crate::ftl::layout::q16(x)).collect();
        let vq: Vec<f32> = vs[1].iter().map(|&x| crate::ftl::layout::q16(x)).collect();
        let want = sparse::dense_attention(&q, &kq, &vq, 40);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        assert!(t > 0.0 && bd.flash_read > 0.0);
    }

    #[test]
    fn sparf_engine_matches_sparse_lib() {
        let mut csd = mk();
        let mut rng = Rng::new(2);
        let (ks, vs) = fill(&mut csd, 0, 0, 1, 64, &mut rng);
        let q: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        let key = StreamKey { slot: 0, layer: 0, head: 0 };
        let sp = SparsityParams { r: 8, k: 16, m: 4, n: 8 };
        let (out, _, bd) = csd
            .attention_head(key, &q, 64, AttnMode::SparF(sp), 0.0)
            .unwrap();
        let kq: Vec<f32> = ks[0].iter().map(|&x| crate::ftl::layout::q16(x)).collect();
        let vq: Vec<f32> = vs[0].iter().map(|&x| crate::ftl::layout::q16(x)).collect();
        let vbar = sparse::v_mean(&vq, 32, 64);
        let want = sparse::sparf_attention(&q, &kq, &vq, &vbar, 64, &sp);
        for (a, b) in out.iter().zip(&want.out) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        assert!(bd.argtopk > 0.0 && bd.logit0 > 0.0 && bd.nfc_filter > 0.0);
    }

    #[test]
    fn sparf_reads_fewer_pages_than_dense() {
        // paper regime: context much longer than k*n, budget 1/8
        let mut rng = Rng::new(3);
        let mut csd = mk();
        fill(&mut csd, 0, 0, 1, 128, &mut rng);
        let q: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        let key = StreamKey { slot: 0, layer: 0, head: 0 };
        let before = csd.ftl.array.counters.page_reads;
        csd.attention_head(key, &q, 128, AttnMode::Dense, 0.0).unwrap();
        let dense_reads = csd.ftl.array.counters.page_reads - before;
        let before = csd.ftl.array.counters.page_reads;
        let sp = SparsityParams { r: 4, k: 8, m: 4, n: 8 };
        csd.attention_head(key, &q, 128, AttnMode::SparF(sp), 0.0).unwrap();
        let sparf_reads = csd.ftl.array.counters.page_reads - before;
        assert!(
            sparf_reads < dense_reads,
            "sparf {sparf_reads} !< dense {dense_reads}"
        );
    }

    #[test]
    fn layer_attention_covers_all_heads() {
        let mut csd = mk();
        let mut rng = Rng::new(4);
        fill(&mut csd, 0, 1, 4, 24, &mut rng);
        let q: Vec<f32> = (0..4 * 32).map(|_| rng.normal_f32()).collect();
        let (out, t, _) = csd
            .attention_layer(0, 1, &q, 24, AttnMode::Dense, 0.0)
            .unwrap();
        assert_eq!(out.len(), 4 * 32);
        assert!(out.iter().any(|&x| x != 0.0));
        assert!(t > 0.0);
    }

    #[test]
    fn unit_breakdown_totals_positive_and_fig16_shape() {
        // Fig. 16's qualitative claim: SparF adds a Logit-0 stage but the
        // flash read time drops (fewer pages); kernel time stays small.
        let key = StreamKey { slot: 0, layer: 0, head: 0 };
        let mut csd = mk();
        let mut rng = Rng::new(5);
        fill(&mut csd, 0, 0, 1, 128, &mut rng);
        let q: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        csd.ftl.array.reset_timing();
        let (_, _, bdd) = csd.attention_head(key, &q, 128, AttnMode::Dense, 0.0).unwrap();
        // fresh device with identical contents: timing starts cold again
        let mut csd2 = mk();
        let mut rng2 = Rng::new(5);
        fill(&mut csd2, 0, 0, 1, 128, &mut rng2);
        let q2: Vec<f32> = (0..32).map(|_| rng2.normal_f32()).collect();
        csd2.ftl.array.reset_timing();
        let sp = SparsityParams { r: 4, k: 8, m: 4, n: 8 };
        let (_, _, bds) = csd2.attention_head(key, &q2, 128, AttnMode::SparF(sp), 0.0).unwrap();
        assert_eq!(bdd.logit0, 0.0);
        assert!(bds.logit0 > 0.0);
        assert!(bds.flash_read < bdd.flash_read);
    }

    #[test]
    fn hot_tier_hits_skip_flash_and_match_flash_bytes() {
        use crate::kvtier::{TierConfig, TierPolicy};
        let tier = TierConfig { hot_bytes: 1 << 20, policy: TierPolicy::Lru };
        let mut csd = InstCsd::with_tier(CsdSpec::tiny(), FtlConfig::micro_head(), tier).unwrap();
        let mut rng = Rng::new(7);
        fill(&mut csd, 0, 0, 1, 40, &mut rng);
        let q: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        let key = StreamKey { slot: 0, layer: 0, head: 0 };
        let (cold, _, _) = csd.attention_head(key, &q, 40, AttnMode::Dense, 0.0).unwrap();
        let reads_after_cold = csd.ftl.array.counters.page_reads;
        let (warm, _, bd) = csd.attention_head(key, &q, 40, AttnMode::Dense, 0.0).unwrap();
        // second pass: every sealed page is served by the DRAM tier
        assert_eq!(csd.ftl.array.counters.page_reads, reads_after_cold);
        assert_eq!(cold, warm, "tier hits must return the flash bytes");
        assert!(bd.dram_hit > 0.0 && bd.flash_read == 0.0);
        assert!(csd.ledger.get("dram_hit") > 0.0);
        assert!(csd.tier.stats.hits > 0 && csd.tier.stats.misses > 0);
        assert!(csd.ftl.counters.promotions > 0);
    }

    #[test]
    fn importance_accumulates_softmax_mass() {
        let mut csd = mk();
        let mut rng = Rng::new(8);
        fill(&mut csd, 0, 0, 2, 24, &mut rng);
        let q: Vec<f32> = (0..2 * 32).map(|_| rng.normal_f32()).collect();
        csd.attention_layer(0, 0, &q, 24, AttnMode::Dense, 0.0).unwrap();
        let s = csd.tier.importance.scores(0).unwrap();
        assert_eq!(s.len(), 24);
        let total: f32 = s.iter().sum();
        // two heads, one softmax each: total mass == 2
        assert!((total - 2.0).abs() < 1e-3, "mass {total}");
    }

    #[test]
    fn drop_tokens_masks_attention_and_frees_groups() {
        let mut csd = mk();
        let mut rng = Rng::new(9);
        let (ks, vs) = fill(&mut csd, 0, 0, 1, 32, &mut rng);
        let q: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        let key = StreamKey { slot: 0, layer: 0, head: 0 };
        let before = csd.ftl.mapped_token_pages(0);
        let drop: Vec<u32> = (0..8).collect();
        csd.drop_tokens(0, &drop).unwrap();
        // group 0 fully dropped: its K and V pages are freed
        assert_eq!(csd.ftl.mapped_token_pages(0), before - 2);
        assert_eq!(csd.ftl.counters.dropped_groups, 1);
        let (out, _, _) = csd.attention_head(key, &q, 32, AttnMode::Dense, 0.0).unwrap();
        // reference: masked dense attention over tokens 8..32 of the
        // same fp16-quantised data
        let kq: Vec<f32> = ks[0].iter().map(|&x| crate::ftl::layout::q16(x)).collect();
        let vq: Vec<f32> = vs[0].iter().map(|&x| crate::ftl::layout::q16(x)).collect();
        let scale = 1.0 / (32.0f32).sqrt();
        let mask: Vec<bool> = (0..32).map(|t| t >= 8).collect();
        let mut logits = vec![sparse::select::NEG_INF; 32];
        for t in 8..32 {
            logits[t] = sparse::select::dot(&q, &kq[t * 32..(t + 1) * 32]) * scale;
        }
        let s = sparse::select::softmax_masked(&logits, &mask);
        let mut want = vec![0.0f32; 32];
        for t in 8..32 {
            for c in 0..32 {
                want[c] += s[t] * vq[t * 32 + c];
            }
        }
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        // idempotent: dropping the same tokens again changes nothing
        csd.drop_tokens(0, &[0, 1]).unwrap();
        assert_eq!(csd.ftl.counters.dropped_groups, 1);
    }
}
