//! InstInfer: in-storage attention offloading for cost-effective long-context
//! LLM inference — a full-system reproduction of the cs.AR 2024 paper.
//!
//! Architecture (see DESIGN.md):
//! * [`runtime`] loads and executes the AOT-compiled HLO artifacts produced
//!   by `python/compile/aot.py` via the PJRT C API (functional plane).
//! * [`flash`], [`ftl`], [`csd`], [`gpu`], [`pcie`] model the hardware
//!   substrate the paper runs on (timing plane + page-accurate KV storage).
//! * [`sparse`] is the rust-native attention family (dense/SparQ/SparF/H2O/
//!   local) that the in-storage engine executes and Fig. 11 evaluates.
//! * [`kvtier`] fronts the FTL with a CSD-DRAM hot tier + flash cold tier:
//!   H2O-style importance tracking and pluggable admission/eviction.
//! * [`systems`] and [`baselines`] are the InstInfer dataflows and the
//!   FlexGen/DeepSpeed-style comparators, all on the same DES substrate.
//! * [`shard`] turns the CSD array into real per-device engine instances:
//!   head/context partitioning, per-CSD local clocks, fair-share PCIe
//!   all-reduce, and the GPU-side partial-attention merge.
//! * [`pipeline`] disaggregates prefill and decode onto two overlapped
//!   engine streams: the GPU prefill stream (chunked prefill + KV
//!   shipping) runs concurrently with the CSD decode stream, contending
//!   for the same PCIe links.
//! * [`coordinator`] is the L3 host control plane: request batching,
//!   prefill/decode scheduling, head->CSD routing, KV management.
//! * [`fault`] is the deterministic fault plane: seeded flash/NVMe/CSD
//!   failure injection with typed error completions and end-to-end
//!   recovery (re-prefill or peer-replica restore).
//! * [`obs`] is the deterministic trace plane: zero-perturbation span
//!   recording on simulated time, Perfetto-loadable export, and the
//!   unified metrics registry.
//! * [`bench`] regenerates every table and figure of the paper's evaluation.

pub mod bench;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod csd;
pub mod fault;
pub mod flash;
pub mod ftl;
pub mod gpu;
pub mod kvtier;
pub mod obs;
pub mod pcie;
pub mod pipeline;
pub mod runtime;
pub mod shard;
pub mod sim;
pub mod sparse;
pub mod systems;
pub mod util;
pub mod workload;
