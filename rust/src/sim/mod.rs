//! Discrete-event scheduling primitives for the timing plane.
//!
//! The simulators model hardware units as FIFO servers: a job arrives at
//! time `a`, waits until the unit is free, occupies it for its service
//! time, and completes.  Composing these through the dataflow graph gives
//! event-ordered, contention-aware completion times without a global event
//! queue — every path in this codebase that "takes time" routes through
//! these primitives, and per-unit busy counters feed the latency-breakdown
//! figures (Figs. 5/14/15/16).

pub mod par;

/// Simulated time in seconds.
pub type Time = f64;

/// Completions kept per resource for backlog-depth accounting.  A burst
/// deeper than this saturates the depth tracking (the true peak is
/// recorded before capping), but a burst with no later arrivals can no
/// longer hold its completion list forever.
const IN_SYSTEM_CAP: usize = 4096;

/// A serial FIFO resource (one job at a time): a flash die, a PCIe link,
/// a DMA engine, the argtopk unit...
#[derive(Debug, Clone, Default)]
pub struct FifoResource {
    free_at: Time,
    busy: Time,
    jobs: u64,
    /// completion times of jobs still in the system (waiting or in
    /// service) relative to the last arrival — sorted ascending (ends
    /// are monotone), prefix-pruned on each schedule, capped at
    /// `IN_SYSTEM_CAP` newest entries
    in_system: Vec<Time>,
    peak_depth: usize,
}

impl FifoResource {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule a job arriving at `arrival` needing `service` seconds.
    /// Returns (start, completion).
    pub fn schedule(&mut self, arrival: Time, service: Time) -> (Time, Time) {
        debug_assert!(service >= 0.0);
        let start = self.free_at.max(arrival);
        let end = start + service;
        self.free_at = end;
        self.busy += service;
        self.jobs += 1;
        // ends are monotone non-decreasing (end_k+1 = max(end_k, a) + s),
        // so completed jobs form a prefix: drain it instead of scanning
        let done = self.in_system.partition_point(|&e| e <= arrival);
        if done > 0 {
            self.in_system.drain(..done);
            // a burst's worth of capacity should not outlive the burst
            if self.in_system.capacity() > IN_SYSTEM_CAP
                && self.in_system.len() <= IN_SYSTEM_CAP / 2
            {
                self.in_system.shrink_to_fit();
            }
        }
        self.in_system.push(end);
        self.peak_depth = self.peak_depth.max(self.in_system.len());
        // cap AFTER recording the peak: drop the oldest completions (they
        // finish first anyway), so a burst with no later arrivals cannot
        // hold the whole vector until reset
        if self.in_system.len() > IN_SYSTEM_CAP {
            let excess = self.in_system.len() - IN_SYSTEM_CAP;
            self.in_system.drain(..excess);
        }
        (start, end)
    }

    pub fn free_at(&self) -> Time {
        self.free_at
    }

    /// Total busy seconds (for utilisation/breakdown accounting).
    pub fn busy(&self) -> Time {
        self.busy
    }

    pub fn jobs(&self) -> u64 {
        self.jobs
    }

    /// Deepest backlog ever observed at an arrival instant (jobs waiting
    /// plus the one in service) — the convoy signature the conflict-aware
    /// read scheduler is meant to flatten.
    pub fn peak_depth(&self) -> usize {
        self.peak_depth
    }

    pub fn reset(&mut self) {
        *self = Self::default();
    }
}

/// `k` identical servers with earliest-free dispatch: the two attention
/// kernels in the SparF engine, a pool of NFC filters, multi-queue NVMe.
#[derive(Debug, Clone)]
pub struct MultiServer {
    free_at: Vec<Time>,
    busy: Time,
    jobs: u64,
}

impl MultiServer {
    pub fn new(k: usize) -> Self {
        assert!(k > 0);
        MultiServer { free_at: vec![0.0; k], busy: 0.0, jobs: 0 }
    }

    /// Dispatch to the earliest-free server; returns (server, start, end).
    pub fn schedule(&mut self, arrival: Time, service: Time) -> (usize, Time, Time) {
        let (idx, &t) = self
            .free_at
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let start = t.max(arrival);
        let end = start + service;
        self.free_at[idx] = end;
        self.busy += service;
        self.jobs += 1;
        (idx, start, end)
    }

    /// When all outstanding work completes.
    pub fn drained(&self) -> Time {
        self.free_at.iter().cloned().fold(0.0, f64::max)
    }

    pub fn busy(&self) -> Time {
        self.busy
    }

    pub fn servers(&self) -> usize {
        self.free_at.len()
    }

    pub fn reset(&mut self) {
        self.free_at.iter_mut().for_each(|t| *t = 0.0);
        self.busy = 0.0;
        self.jobs = 0;
    }
}

/// Per-component busy-time ledger -> latency breakdown rows.
#[derive(Debug, Clone, Default)]
pub struct BusyLedger {
    entries: std::collections::BTreeMap<&'static str, Time>,
}

impl BusyLedger {
    pub fn add(&mut self, component: &'static str, t: Time) {
        *self.entries.entry(component).or_insert(0.0) += t;
    }

    pub fn get(&self, component: &str) -> Time {
        self.entries.get(component).copied().unwrap_or(0.0)
    }

    pub fn total(&self) -> Time {
        self.entries.values().sum()
    }

    /// (component, seconds, fraction) rows sorted by component name.
    pub fn rows(&self) -> Vec<(&'static str, Time, f64)> {
        let total = self.total().max(1e-30);
        self.entries.iter().map(|(k, v)| (*k, *v, v / total)).collect()
    }

    pub fn merge(&mut self, other: &BusyLedger) {
        for (k, v) in &other.entries {
            self.add(k, *v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_serialises() {
        let mut r = FifoResource::new();
        let (s1, e1) = r.schedule(0.0, 2.0);
        let (s2, e2) = r.schedule(1.0, 3.0); // arrives while busy
        assert_eq!((s1, e1), (0.0, 2.0));
        assert_eq!((s2, e2), (2.0, 5.0));
        let (s3, e3) = r.schedule(10.0, 1.0); // idle gap
        assert_eq!((s3, e3), (10.0, 11.0));
        assert_eq!(r.busy(), 6.0);
        assert_eq!(r.jobs(), 3);
    }

    #[test]
    fn fifo_in_system_is_capped_and_prefix_pruned() {
        let mut r = FifoResource::new();
        // a burst with no later arrivals: every job lands at t=0 and the
        // backlog only grows — the cap must bound the vector while the
        // peak keeps counting the true depth
        for _ in 0..(IN_SYSTEM_CAP + 100) {
            r.schedule(0.0, 1.0);
        }
        assert!(r.in_system.len() <= IN_SYSTEM_CAP);
        assert_eq!(r.peak_depth(), IN_SYSTEM_CAP + 100);
        // the survivors are the newest completions, still sorted
        assert!(r.in_system.windows(2).all(|w| w[0] <= w[1]));
        // a late arrival past the backlog drains everything completed
        let drain_at = r.free_at() + 1.0;
        r.schedule(drain_at, 1.0);
        assert_eq!(r.in_system.len(), 1);
        assert_eq!(r.peak_depth(), IN_SYSTEM_CAP + 100);
    }

    #[test]
    fn fifo_prefix_prune_matches_retain_semantics() {
        // interleaved idle gaps and overlap: depth accounting must match
        // the old retain(|e| e > arrival) scan exactly
        let mut r = FifoResource::new();
        r.schedule(0.0, 2.0); // in system: [2]
        r.schedule(1.0, 2.0); // arrival 1.0 < 2 -> [2, 4], depth 2
        assert_eq!(r.peak_depth(), 2);
        r.schedule(3.0, 1.0); // 2 completed -> [4, 5], depth stays 2
        assert_eq!(r.peak_depth(), 2);
        r.schedule(10.0, 1.0); // idle gap clears all -> [11]
        assert_eq!(r.in_system.len(), 1);
        assert_eq!(r.peak_depth(), 2);
    }

    #[test]
    fn multiserver_parallelises() {
        let mut m = MultiServer::new(2);
        let (_, s1, e1) = m.schedule(0.0, 4.0);
        let (_, s2, e2) = m.schedule(0.0, 4.0);
        let (_, s3, e3) = m.schedule(0.0, 4.0);
        assert_eq!((s1, e1), (0.0, 4.0));
        assert_eq!((s2, e2), (0.0, 4.0));
        assert_eq!((s3, e3), (4.0, 8.0)); // third waits for a server
        assert_eq!(m.drained(), 8.0);
    }

    #[test]
    fn ledger_fractions_sum_to_one() {
        let mut l = BusyLedger::default();
        l.add("flash", 3.0);
        l.add("engine", 1.0);
        l.add("flash", 1.0);
        let rows = l.rows();
        assert_eq!(rows.len(), 2);
        let fsum: f64 = rows.iter().map(|r| r.2).sum();
        assert!((fsum - 1.0).abs() < 1e-12);
        assert_eq!(l.get("flash"), 4.0);
    }
}
