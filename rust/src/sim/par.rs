//! Deterministic parallel execution over `std::thread::scope` — zero
//! new dependencies.
//!
//! Two shapes cover every fan-out in the codebase:
//!
//! * [`par_map_mut`] — in-place fan-out over a mutable slice in
//!   contiguous ascending chunks, one scoped thread per chunk, joined in
//!   chunk order.  Used for the per-CSD shard loops: each shard's
//!   command stream is self-contained between all-reduce barriers (the
//!   per-CSD `ShardClock`s and `NvmeQueue`s share no state), so the
//!   chunked join reproduces the serial emission order exactly.
//! * [`par_map`] — consuming fan-out over independent work items on a
//!   bounded worker pool with work stealing (atomic next-index).  Used
//!   for the bench sweeps: every sweep point is an independent
//!   fixed-seed simulation, and results are reassembled in item-index
//!   order regardless of which worker ran which item.
//!
//! Determinism contract: the observability sinks (`TraceSink`,
//! `AttrSink`) are thread-local, so each worker runs with its own sinks
//! (replicated from the spawning thread via `obs::CaptureSpec`) and the
//! spawning thread merges them back in item/chunk index order
//! (`obs::merge_captured`).  Together with the export's
//! `(pid, tid, ts, emission)` stable sort this makes trace exports,
//! digests, metrics snapshots and all simulation outputs byte-identical
//! for any thread count — pinned by `tests/par.rs`.
//!
//! `threads <= 1` (or a single item) short-circuits to a plain serial
//! loop on the calling thread with no capture round-trip, so the default
//! configuration has zero overhead.

use crate::obs;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker threads the host offers (`--threads 0` resolves to this).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(index, item)` for every item on up to `threads` scoped worker
/// threads and return the results in item order.  Workers pull items via
/// an atomic cursor (work stealing), so wall-clock tracks the slowest
/// items rather than the unluckiest static partition; observability is
/// captured per item and merged in index order, so outputs are
/// byte-identical to the serial loop for any thread count.
///
/// A panic inside `f` propagates to the caller (the scope re-raises it
/// on join), matching the serial loop's behavior.
pub fn par_map<I, T, F>(threads: usize, items: Vec<I>, f: F) -> Vec<T>
where
    I: Send,
    T: Send,
    F: Fn(usize, I) -> T + Sync,
{
    let n = items.len();
    if threads <= 1 || n <= 1 {
        return items.into_iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let spec = obs::CaptureSpec::of_current();
    let work: Vec<Mutex<Option<I>>> = items.into_iter().map(|x| Mutex::new(Some(x))).collect();
    let slots: Vec<Mutex<Option<(T, obs::Captured)>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads.min(n) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = work[i].lock().unwrap().take().expect("work item taken twice");
                spec.install();
                let out = f(i, item);
                *slots[i].lock().unwrap() = Some((out, obs::capture_take()));
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            let (out, cap) = slot
                .into_inner()
                .unwrap_or_else(|e| e.into_inner())
                .expect("worker left a result slot empty");
            obs::merge_captured(cap);
            out
        })
        .collect()
}

/// Run `f(index, &mut item)` over a mutable slice, split into contiguous
/// ascending chunks of one scoped thread each, joined (and observability
/// merged) in chunk order.  Because the chunks are contiguous and merged
/// in order, the concatenated emission sequence equals the serial
/// loop's, making this the right shape for the per-shard NVMe dispatch
/// loops.  Results come back in item order.
pub fn par_map_mut<I, T, F>(threads: usize, items: &mut [I], f: F) -> Vec<T>
where
    I: Send,
    T: Send,
    F: Fn(usize, &mut I) -> T + Sync,
{
    let n = items.len();
    if threads <= 1 || n <= 1 {
        return items.iter_mut().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let spec = obs::CaptureSpec::of_current();
    let chunk = n.div_ceil(threads.min(n));
    let mut out: Vec<T> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (w, slice) in items.chunks_mut(chunk).enumerate() {
            let spec = &spec;
            let f = &f;
            handles.push(s.spawn(move || {
                spec.install();
                let base = w * chunk;
                let res: Vec<T> =
                    slice.iter_mut().enumerate().map(|(j, x)| f(base + j, x)).collect();
                (res, obs::capture_take())
            }));
        }
        for h in handles {
            match h.join() {
                Ok((res, cap)) => {
                    obs::merge_captured(cap);
                    out.extend(res);
                }
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_item_order() {
        for threads in [1usize, 2, 8] {
            let items: Vec<usize> = (0..17).collect();
            let out = par_map(threads, items, |i, x| {
                assert_eq!(i, x);
                x * 3
            });
            assert_eq!(out, (0..17).map(|x| x * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn par_map_mut_mutates_in_place_in_order() {
        for threads in [1usize, 3, 8] {
            let mut items: Vec<usize> = vec![0; 10];
            let out = par_map_mut(threads, &mut items, |i, x| {
                *x = i + 1;
                i * 2
            });
            assert_eq!(items, (1..=10).collect::<Vec<_>>());
            assert_eq!(out, (0..10).map(|i| i * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn empty_and_single_inputs_stay_serial() {
        let out: Vec<usize> = par_map(8, Vec::<usize>::new(), |_, x| x);
        assert!(out.is_empty());
        let out = par_map(8, vec![41usize], |_, x| x + 1);
        assert_eq!(out, vec![42]);
    }
}
