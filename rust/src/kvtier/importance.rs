//! H2O-style token-importance statistics.
//!
//! Every Logit pass of the in-storage engine produces a softmax over the
//! context; accumulating those per-position weights across heads, layers
//! and steps yields the "heavy hitter" signal of H2O [Zhang et al.]: a
//! small set of tokens carries most of the attention mass.  The tracker
//! stores that cumulative mass per (slot, position) and serves two
//! consumers:
//!
//! * the `H2oScore` eviction policy (which token groups deserve the DRAM
//!   hot tier), and
//! * the scheduler's drop-on-resume path (which positions can be dropped
//!   outright when a preempted sequence returns).
//!
//! Scores are aggregated over heads and layers (the per-CSD view); the
//! coordinator sums the trackers of all CSDs for sequence-level
//! decisions.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct ImportanceTracker {
    /// slot -> cumulative attention mass per token position
    scores: BTreeMap<u32, Vec<f32>>,
}

impl ImportanceTracker {
    /// Fold one softmax row (position-indexed weights) into the slot's
    /// running totals.  Shorter/longer rows than seen before are fine —
    /// the vector grows as the context does.
    pub fn accumulate(&mut self, slot: u32, weights: &[f32]) {
        let v = self.scores.entry(slot).or_default();
        if v.len() < weights.len() {
            v.resize(weights.len(), 0.0);
        }
        for (a, &w) in v.iter_mut().zip(weights) {
            *a += w;
        }
    }

    pub fn scores(&self, slot: u32) -> Option<&[f32]> {
        self.scores.get(&slot).map(|v| v.as_slice())
    }

    /// Cumulative mass of one token group (`n` tokens starting at
    /// `group * n`); unseen slots/positions score zero.
    pub fn group_score(&self, slot: u32, group: u32, n: usize) -> f32 {
        match self.scores.get(&slot) {
            None => 0.0,
            Some(v) => {
                let lo = (group as usize) * n;
                if lo >= v.len() {
                    return 0.0;
                }
                let hi = (lo + n).min(v.len());
                v[lo..hi].iter().sum()
            }
        }
    }

    /// Token positions of `slot` sorted least-important first
    /// (deterministic: ties break on position).
    pub fn ranked_ascending(&self, slot: u32) -> Vec<usize> {
        let mut idx: Vec<usize> = match self.scores.get(&slot) {
            None => return Vec::new(),
            Some(v) => (0..v.len()).collect(),
        };
        let v = &self.scores[&slot];
        idx.sort_by(|&a, &b| v[a].total_cmp(&v[b]).then(a.cmp(&b)));
        idx
    }

    pub fn forget(&mut self, slot: u32) {
        self.scores.remove(&slot);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_ranks() {
        let mut t = ImportanceTracker::default();
        t.accumulate(3, &[0.1, 0.7, 0.2]);
        t.accumulate(3, &[0.1, 0.6, 0.3, 0.9]);
        let s = t.scores(3).unwrap();
        assert_eq!(s.len(), 4);
        assert!((s[1] - 1.3).abs() < 1e-6);
        assert_eq!(t.ranked_ascending(3), vec![0, 2, 3, 1]);
        t.forget(3);
        assert!(t.scores(3).is_none());
    }

    #[test]
    fn group_score_sums_token_range() {
        let mut t = ImportanceTracker::default();
        t.accumulate(0, &[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((t.group_score(0, 0, 2) - 3.0).abs() < 1e-6);
        assert!((t.group_score(0, 2, 2) - 5.0).abs() < 1e-6); // clipped tail
        assert_eq!(t.group_score(0, 9, 2), 0.0);
        assert_eq!(t.group_score(7, 0, 2), 0.0);
    }
}
