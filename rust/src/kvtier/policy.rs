//! Pluggable admission/eviction policies for the hot tier.
//!
//! Admission is uniform (read-allocate: a page admitted on its first
//! flash fetch — the `KvFtl::promote_group` API exists for explicit
//! warm-up); policies differ in *who leaves* when the tier is full:
//!
//! * `Lru` — classic recency.  Has the inclusion property, so hit rate
//!   is monotone in capacity, but the dense decode loop's cyclic scan
//!   over all groups thrashes it when the working set exceeds capacity.
//! * `H2oScore` — evict the group with the least cumulative attention
//!   mass (H2O heavy hitters stay resident).  Scan-resistant: the same
//!   high-mass pages stay hot across steps.
//! * `PinRecentWindow` — LRU, but groups covering the most recent
//!   `window` tokens of their stream are pinned (streaming/locality
//!   prior); pinned pages are evicted only when nothing else is left.

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierPolicy {
    Lru,
    H2oScore,
    PinRecentWindow { window: usize },
}

impl TierPolicy {
    /// Parse a CLI spelling: `lru`, `h2o`, `pin` or `pin:<window>`.
    pub fn parse(s: &str) -> Result<TierPolicy> {
        match s {
            "lru" => Ok(TierPolicy::Lru),
            "h2o" => Ok(TierPolicy::H2oScore),
            "pin" => Ok(TierPolicy::PinRecentWindow { window: 16 }),
            other => {
                if let Some(w) = other.strip_prefix("pin:") {
                    let window: usize = w
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad pin window {w:?}"))?;
                    return Ok(TierPolicy::PinRecentWindow { window });
                }
                bail!("unknown tier policy {other:?} (want lru | h2o | pin[:WINDOW])")
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            TierPolicy::Lru => "lru".to_string(),
            TierPolicy::H2oScore => "h2o".to_string(),
            TierPolicy::PinRecentWindow { window } => format!("pin{window}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips() {
        assert_eq!(TierPolicy::parse("lru").unwrap(), TierPolicy::Lru);
        assert_eq!(TierPolicy::parse("h2o").unwrap(), TierPolicy::H2oScore);
        assert_eq!(TierPolicy::parse("pin").unwrap(), TierPolicy::PinRecentWindow { window: 16 });
        assert_eq!(
            TierPolicy::parse("pin:32").unwrap(),
            TierPolicy::PinRecentWindow { window: 32 }
        );
        assert!(TierPolicy::parse("mru").is_err());
        assert!(TierPolicy::parse("pin:x").is_err());
        assert_eq!(TierPolicy::parse("pin:4").unwrap().label(), "pin4");
    }
}
