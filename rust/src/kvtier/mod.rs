//! Multi-tier KV cache: a CSD-DRAM hot tier in front of the flash cold
//! tier, with importance-driven admission/eviction (ISSUE 2 tentpole;
//! cf. KVDrive's multi-tier KV management and HillInfer's hierarchical
//! eviction on SmartSSDs).
//!
//! InstInfer's engine reads every KV page at the flash internal-channel
//! rate; the CSD's DRAM group buffers are an untapped hot tier sitting
//! directly in front of the array.  This subsystem fronts the FTL with:
//!
//! * [`hot`]        — the capacity-bounded page cache (per-CSD group
//!   buffers; deterministic victim selection);
//! * [`importance`] — H2O-style cumulative attention-mass statistics
//!   collected from the engine's Logit passes;
//! * [`policy`]     — the pluggable eviction policies (`Lru`,
//!   `H2oScore`, `PinRecentWindow`).
//!
//! The engine consults [`TieredKv`] on every token-group fetch: hits are
//! served at DRAM bandwidth and skip the flash die/channel FIFOs in the
//! DES timing (the `dram_hit` breakdown row); misses stream from flash
//! and are read-allocated into the tier, evicting per policy.  The same
//! importance signal drives the scheduler's drop-on-resume path (keep
//! heavy hitters, drop the long tail when a preempted sequence returns).

pub mod hot;
pub mod importance;
pub mod policy;

pub use hot::{HotTier, PageId};
pub use importance::ImportanceTracker;
pub use policy::TierPolicy;

use crate::config::hw::CsdSpec;

/// Hot-tier shape: capacity carved out of the CSD DRAM plus the policy.
#[derive(Debug, Clone, Copy)]
pub struct TierConfig {
    /// bytes of CSD DRAM used as the hot tier (0 = flash-only)
    pub hot_bytes: usize,
    pub policy: TierPolicy,
}

impl TierConfig {
    /// Default for a hardware spec: the spec's reserved group-buffer
    /// bytes under LRU.
    pub fn for_spec(spec: &CsdSpec) -> Self {
        TierConfig { hot_bytes: spec.hot_tier_bytes, policy: TierPolicy::Lru }
    }

    /// No hot tier: every read streams from flash (the paper's baseline
    /// dataflow, and the default for the unit-test specs).
    pub fn flash_only() -> Self {
        TierConfig { hot_bytes: 0, policy: TierPolicy::Lru }
    }
}

/// Monotone tier counters (sealed-group fetches only; the FTL's DRAM
/// tail buffer is accounted separately as `tail_hits`).
#[derive(Debug, Clone, Copy, Default)]
pub struct TierStats {
    pub hits: u64,
    pub misses: u64,
    pub admissions: u64,
    pub evictions: u64,
    /// admissions skipped because the tier cannot hold even one page
    pub rejected: u64,
}

impl TierStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn merge(&mut self, o: &TierStats) {
        self.hits += o.hits;
        self.misses += o.misses;
        self.admissions += o.admissions;
        self.evictions += o.evictions;
        self.rejected += o.rejected;
    }

    /// Aggregate an iterator of per-shard stats (the CSD-array rollup
    /// the engine and dashboards report).
    pub fn merged<'a, I: IntoIterator<Item = &'a TierStats>>(stats: I) -> TierStats {
        let mut out = TierStats::default();
        for s in stats {
            out.merge(s);
        }
        out
    }
}

/// Per-CSD tier state: the hot page cache, the importance tracker that
/// feeds `H2oScore` decisions, and the configured policy.
#[derive(Debug)]
pub struct TieredKv {
    pub cfg: TierConfig,
    pub hot: HotTier,
    pub importance: ImportanceTracker,
    pub stats: TierStats,
    /// tokens per token-group page (the FTL's `n`)
    tokens_per_group: usize,
}

impl TieredKv {
    pub fn new(cfg: TierConfig, page_bytes: usize, tokens_per_group: usize) -> Self {
        TieredKv {
            cfg,
            hot: HotTier::new(page_bytes),
            importance: ImportanceTracker::default(),
            stats: TierStats::default(),
            tokens_per_group,
        }
    }

    /// Look up a page; a hit refreshes recency and clones the rows (the
    /// DRAM copy the engine computes over).  A disabled tier
    /// (`hot_bytes == 0`) counts nothing — flash-only engines must not
    /// accumulate phantom tier traffic.
    pub fn lookup(&mut self, id: PageId) -> Option<Vec<f32>> {
        if self.cfg.hot_bytes == 0 {
            return None;
        }
        match self.hot.get(&id) {
            Some(rows) => {
                let rows = rows.clone();
                self.stats.hits += 1;
                Some(rows)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Admit a page read from flash, evicting per policy until the tier
    /// fits its capacity again.  Returns `(resident, evicted)`: whether
    /// the page survived its own admission (under `H2oScore` a zero-mass
    /// newcomer can be its own victim) and which pages left (so the FTL
    /// can log demotions).
    pub fn admit(&mut self, id: PageId, rows: Vec<f32>, stream_len: usize) -> (bool, Vec<PageId>) {
        if self.cfg.hot_bytes < self.hot.page_bytes() {
            self.stats.rejected += 1;
            return (false, Vec::new());
        }
        self.hot.note_stream_len(id.key, stream_len);
        self.hot.insert(id, rows);
        self.stats.admissions += 1;
        let mut evicted = Vec::new();
        let mut resident = true;
        while self.hot.bytes() > self.cfg.hot_bytes {
            let Some(v) = self.victim() else { break };
            self.hot.remove(&v);
            self.stats.evictions += 1;
            if v == id {
                resident = false;
            } else {
                evicted.push(v);
            }
        }
        (resident, evicted)
    }

    /// Forcibly drop one page (drop-on-resume freed its flash home).
    pub fn drop_page(&mut self, id: PageId) -> bool {
        if self.hot.remove(&id) {
            self.stats.evictions += 1;
            true
        } else {
            false
        }
    }

    /// Retire a sequence: its pages, stream lengths and importance go.
    pub fn free_slot(&mut self, slot: u32) {
        self.hot.remove_slot(slot);
        self.importance.forget(slot);
    }

    /// Policy victim: minimum `(rank, last_use, id)` — rank is 0 for
    /// LRU, cumulative attention mass for `H2oScore`, and a pin bit for
    /// `PinRecentWindow` (pinned pages only lose to other pinned pages).
    /// Fully deterministic: ties break on recency then page identity.
    /// O(resident pages) per eviction — fine at the functional plane's
    /// scale (thousands of pages); a production-sized tier (the zynq
    /// spec's 1 GiB) would want an ordered victim index instead.
    fn victim(&self) -> Option<PageId> {
        let n = self.tokens_per_group;
        let mut best: Option<(f32, u64, PageId)> = None;
        for (id, e) in self.hot.iter() {
            let rank = match self.cfg.policy {
                TierPolicy::Lru => 0.0,
                TierPolicy::H2oScore => self.importance.group_score(id.key.slot, id.group, n),
                TierPolicy::PinRecentWindow { window } => {
                    let len = self.hot.stream_len(&id.key);
                    let pinned = (id.group as usize + 1) * n > len.saturating_sub(window);
                    if pinned {
                        1.0
                    } else {
                        0.0
                    }
                }
            };
            let cand = (rank, e.last_use, *id);
            let better = match &best {
                None => true,
                Some(b) => {
                    cand.0
                        .total_cmp(&b.0)
                        .then(cand.1.cmp(&b.1))
                        .then(cand.2.cmp(&b.2))
                        == std::cmp::Ordering::Less
                }
            };
            if better {
                best = Some(cand);
            }
        }
        best.map(|(_, _, id)| id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftl::{KvKind, StreamKey};

    fn id(slot: u32, group: u32) -> PageId {
        PageId { key: StreamKey { slot, layer: 0, head: 0 }, kind: KvKind::K, group }
    }

    fn tier(policy: TierPolicy, pages: usize) -> TieredKv {
        TieredKv::new(TierConfig { hot_bytes: pages * 512, policy }, 512, 8)
    }

    #[test]
    fn zero_capacity_rejects_and_counts_no_traffic() {
        let mut t = tier(TierPolicy::Lru, 0);
        assert!(t.lookup(id(0, 0)).is_none());
        let (resident, ev) = t.admit(id(0, 0), vec![1.0], 8);
        assert!(!resident && ev.is_empty());
        // a disabled tier records rejections but no phantom misses
        assert_eq!((t.stats.misses, t.stats.rejected), (0, 1));
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut t = tier(TierPolicy::Lru, 2);
        t.admit(id(0, 0), vec![0.0], 8);
        t.admit(id(0, 1), vec![1.0], 16);
        assert!(t.lookup(id(0, 0)).is_some()); // refresh group 0
        let (resident, ev) = t.admit(id(0, 2), vec![2.0], 24);
        assert!(resident);
        assert_eq!(ev, vec![id(0, 1)]); // group 1 was least recent
        assert!(t.hot.contains(&id(0, 0)) && t.hot.contains(&id(0, 2)));
    }

    #[test]
    fn h2o_keeps_heavy_hitters() {
        let mut t = tier(TierPolicy::H2oScore, 2);
        // group 0 (tokens 0..8) is heavy, group 1 (8..16) is light but
        // non-zero (ties fall back to recency, which would let a fresh
        // zero-mass page displace an equally-zero old one)
        let mut w = vec![0.0f32; 16];
        w[0] = 5.0;
        w[1] = 5.0;
        w[8] = 0.1;
        t.importance.accumulate(0, &w);
        t.admit(id(0, 0), vec![0.0], 8);
        t.admit(id(0, 1), vec![1.0], 16);
        // newcomer group 2 has zero mass: it is its own victim
        let (resident, ev) = t.admit(id(0, 2), vec![2.0], 24);
        assert!(!resident, "zero-mass newcomer must not displace hitters");
        assert!(ev.is_empty());
        assert!(t.hot.contains(&id(0, 0)) && t.hot.contains(&id(0, 1)));
        // once group 2 outweighs group 1, it displaces it
        let mut w = vec![0.0f32; 24];
        w[16] = 1.0;
        t.importance.accumulate(0, &w);
        let (resident, ev) = t.admit(id(0, 2), vec![2.0], 24);
        assert!(resident);
        assert_eq!(ev, vec![id(0, 1)]);
    }

    #[test]
    fn pin_recent_window_protects_tail() {
        let mut t = tier(TierPolicy::PinRecentWindow { window: 8 }, 2);
        // stream at 24 tokens: group 2 (tokens 16..24) is in the window
        t.admit(id(0, 2), vec![2.0], 24);
        t.admit(id(0, 0), vec![0.0], 24);
        assert!(t.lookup(id(0, 0)).is_some()); // group 0 most recent now
        let (resident, ev) = t.admit(id(0, 1), vec![1.0], 24);
        assert!(resident);
        // LRU alone would evict group 2; the pin deflects it to group 0
        assert_eq!(ev, vec![id(0, 0)]);
        assert!(t.hot.contains(&id(0, 2)));
    }

    #[test]
    fn free_slot_clears_state_and_stats_merge() {
        let mut t = tier(TierPolicy::Lru, 4);
        t.admit(id(3, 0), vec![0.0], 8);
        t.importance.accumulate(3, &[1.0]);
        t.free_slot(3);
        assert!(t.hot.is_empty());
        assert!(t.importance.scores(3).is_none());
        let mut a = TierStats { hits: 1, misses: 2, ..Default::default() };
        a.merge(&TierStats { hits: 3, evictions: 4, ..Default::default() });
        assert_eq!((a.hits, a.misses, a.evictions), (4, 2, 4));
        assert!((a.hit_rate() - 4.0 / 6.0).abs() < 1e-12);
        assert_eq!(TierStats::default().hit_rate(), 0.0);
    }
}
