//! The CSD-DRAM hot tier proper: a capacity-bounded cache of sealed KV
//! pages (token groups) sitting in the device's group buffers, directly
//! in front of the flash array.
//!
//! The tier is a *cache*, never the home: every page it holds is also
//! mapped on flash by the FTL, so eviction is metadata-only (a demote
//! notification) and crash-consistency is trivial.  Entries are whole
//! pages — the same granularity the FTL maps and the flash array
//! transfers — so hit accounting translates 1:1 into saved page reads.
//!
//! Determinism: the map is a `BTreeMap` and every policy breaks ties on
//! `(last_use, PageId)`, so victim selection never depends on hash-seed
//! iteration order (the serving plane is deterministic per trace).

use crate::ftl::{KvKind, StreamKey};
use std::collections::BTreeMap;

/// Identity of one cached page: one token group of one KV stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PageId {
    pub key: StreamKey,
    pub kind: KvKind,
    pub group: u32,
}

/// One resident page: the decoded (FP16-quantised) rows plus recency.
#[derive(Debug)]
pub struct Entry {
    pub rows: Vec<f32>,
    pub last_use: u64,
}

#[derive(Debug, Default)]
pub struct HotTier {
    page_bytes: usize,
    clock: u64,
    map: BTreeMap<PageId, Entry>,
    /// tokens appended per stream at the last admission touching it —
    /// what `PinRecentWindow` measures recency against
    stream_len: BTreeMap<StreamKey, usize>,
}

impl HotTier {
    pub fn new(page_bytes: usize) -> Self {
        HotTier { page_bytes, ..Default::default() }
    }

    pub fn page_bytes(&self) -> usize {
        self.page_bytes
    }

    /// Bytes currently resident (pages are cached whole).
    pub fn bytes(&self) -> usize {
        self.map.len() * self.page_bytes
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, id: &PageId) -> bool {
        self.map.contains_key(id)
    }

    /// Look a page up, refreshing its recency on hit.
    pub fn get(&mut self, id: &PageId) -> Option<&Vec<f32>> {
        self.clock += 1;
        let clock = self.clock;
        match self.map.get_mut(id) {
            Some(e) => {
                e.last_use = clock;
                Some(&e.rows)
            }
            None => None,
        }
    }

    pub fn insert(&mut self, id: PageId, rows: Vec<f32>) {
        self.clock += 1;
        self.map.insert(id, Entry { rows, last_use: self.clock });
    }

    pub fn remove(&mut self, id: &PageId) -> bool {
        self.map.remove(id).is_some()
    }

    /// Drop every page of a retired sequence; returns how many.
    pub fn remove_slot(&mut self, slot: u32) -> usize {
        let before = self.map.len();
        self.map.retain(|id, _| id.key.slot != slot);
        self.stream_len.retain(|k, _| k.slot != slot);
        before - self.map.len()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&PageId, &Entry)> {
        self.map.iter()
    }

    pub fn note_stream_len(&mut self, key: StreamKey, len: usize) {
        let e = self.stream_len.entry(key).or_insert(0);
        *e = (*e).max(len);
    }

    pub fn stream_len(&self, key: &StreamKey) -> usize {
        self.stream_len.get(key).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn id(slot: u32, group: u32) -> PageId {
        PageId { key: StreamKey { slot, layer: 0, head: 0 }, kind: KvKind::K, group }
    }

    #[test]
    fn get_refreshes_recency() {
        let mut h = HotTier::new(512);
        h.insert(id(0, 0), vec![1.0]);
        h.insert(id(0, 1), vec![2.0]);
        let t0 = h.iter().find(|(i, _)| **i == id(0, 0)).unwrap().1.last_use;
        assert!(h.get(&id(0, 0)).is_some());
        let t1 = h.iter().find(|(i, _)| **i == id(0, 0)).unwrap().1.last_use;
        assert!(t1 > t0, "hit must refresh last_use");
        assert_eq!(h.bytes(), 2 * 512);
    }

    #[test]
    fn remove_slot_drops_only_that_slot() {
        let mut h = HotTier::new(512);
        h.insert(id(0, 0), vec![]);
        h.insert(id(1, 0), vec![]);
        h.note_stream_len(id(0, 0).key, 8);
        assert_eq!(h.remove_slot(0), 1);
        assert_eq!(h.len(), 1);
        assert!(h.contains(&id(1, 0)));
        assert_eq!(h.stream_len(&id(0, 0).key), 0);
    }
}
