//! Continuous-batching scheduler: batch membership is a per-step decision.
//!
//! The offline flow (`OfflineBatcher` + `InferenceEngine::generate`) forms
//! a batch once and drains it to completion — stragglers hold the bucket
//! hostage and arrivals wait for the whole batch.  This scheduler instead
//! runs an *engine step loop* where every step:
//!
//! 1. **retires** finished sequences mid-flight (KV slot + FTL streams
//!    reclaimed immediately via `FreeSlot`),
//! 2. **admits** queued requests into free KV slots — new arrivals get a
//!    chunked prefill (at most `prefill_chunk` per step) interleaved with
//!    the decode of running sequences,
//! 3. **preempts** the lowest-priority running sequence when seats are
//!    exhausted and a strictly higher-priority request waits.  The victim
//!    parks on flash: its slot and KV pages stay resident, so a later
//!    `resume` continues decoding with no re-prefill — the payoff of
//!    flash-resident KV (paper §IV-C),
//! 4. **decodes** one token for every running sequence.
//!
//! Time is the simulated CSD device clock (`engine.sim_now`): arrivals are
//! stamped on it, admission is gated on it, and the open-loop driver
//! fast-forwards it across idle gaps — so serving runs are deterministic.
//!
//! Two executors share the planning logic.  The **serialized** step (the
//! default) runs the cohort's chunked prefill inside the step, so every
//! admission stalls the in-flight decodes.  With [`SchedConfig::overlap`]
//! the **pipelined** executor ([`crate::pipeline`]) disaggregates the
//! phases: admissions prefill on the GPU stream (own frontier, FIFO
//! cohorts) while decode ticks keep advancing `sim_now`, and a cohort
//! joins the batch at the first tick after its prefill + KV ship
//! completes.  Outputs are identical either way; only timing moves.

use crate::coordinator::engine::{AttnBackend, InferenceEngine};
use crate::coordinator::kvmgr::SlotManager;
use crate::coordinator::metrics::EngineMetrics;
use crate::coordinator::request::{RequestPhase, Sequence};
use crate::fault::{FaultError, RecoveryPolicy};
use crate::obs::attr;
use crate::pipeline::{OverlapStats, PipelineState};
use crate::sim::Time;
use crate::util::stats::percentile;
use crate::workload::{Arrival, Request};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// The device behind an error completion, when the error is a whole-CSD
/// loss (the one fault class the scheduler recovers from in-line).
fn lost_device(e: &anyhow::Error) -> Option<usize> {
    match e.downcast_ref::<FaultError>() {
        Some(FaultError::DeviceLost { dev }) => Some(*dev),
        _ => None,
    }
}

#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// decode seats: max sequences per engine step (clamped to the
    /// largest AOT batch bucket at runtime)
    pub max_batch: usize,
    /// chunked prefill: max new admissions prefilled per step, so a
    /// burst of arrivals cannot starve running decodes
    pub prefill_chunk: usize,
    /// KV slot capacity handed to the [`SlotManager`]
    pub slots: usize,
    /// on resume from preemption, drop low-importance token positions
    /// (H2O-style, via the tier's importance tracker) instead of
    /// bringing the full cache back into the working set
    pub drop_on_resume: bool,
    /// token budget kept per sequence on resume (0 = keep everything);
    /// only effective with `drop_on_resume`
    pub resume_keep: usize,
    /// disaggregate prefill and decode onto overlapped engine streams:
    /// admissions prefill on the GPU stream while decode ticks keep
    /// advancing, and the cohort joins the batch when its prefill
    /// completes.  Off = the serialized step (bit-identical outputs AND
    /// timing to the pre-pipeline scheduler).
    pub overlap: bool,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            max_batch: 8,
            prefill_chunk: 4,
            slots: 64,
            drop_on_resume: false,
            resume_keep: 0,
            overlap: false,
        }
    }
}

impl SchedConfig {
    /// The one shared serving-config constructor for the CLI, the
    /// examples and the benches (mirrors [`super::EngineConfig::micro_for`]
    /// for engine configs): `max_batch` decode seats, chunked prefill of
    /// `prefill_chunk` per step, `slots` KV slots, everything else at
    /// the defaults.  Call sites used to hand-roll this literal; one
    /// helper keeps the knobs from drifting between examples and benches.
    pub fn serving(max_batch: usize, prefill_chunk: usize, slots: usize) -> Self {
        SchedConfig { max_batch, prefill_chunk, slots, ..Default::default() }
    }

    /// Enable (or disable) the two-stream pipelined executor.
    pub fn overlapped(mut self, on: bool) -> Self {
        self.overlap = on;
        self
    }
}

/// Drop-on-resume always preserves this many of the most recent token
/// positions (the DRAM tail groups and the decode neighbourhood), on top
/// of the importance-ranked keep set.
const RESUME_RECENT_WINDOW: usize = 16;

/// Per-request bookkeeping kept while a request is in flight.
#[derive(Debug, Clone)]
struct ReqMeta {
    priority: u8,
    arrived_at: Time,
    admitted_at: Time,
    first_token_at: Time,
    preemptions: u32,
}

/// Lifecycle record of one completed request.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: u64,
    pub priority: u8,
    pub arrived_at: Time,
    pub admitted_at: Time,
    pub first_token_at: Time,
    pub finished_at: Time,
    pub prompt_len: usize,
    pub generated: Vec<i32>,
    pub preemptions: u32,
    /// admission rejected the request (empty or over-long prompt); no
    /// tokens were generated and no slot was ever held
    pub rejected: bool,
    /// the retry-only recovery policy aborted the request at a device
    /// loss (its KV died with the device; `generated` holds whatever was
    /// produced before the loss)
    pub aborted: bool,
}

/// What one engine step did (for logs and tests).
#[derive(Debug, Clone, Copy, Default)]
pub struct StepReport {
    /// sequences admitted: serialized = prefilled and decoding this
    /// step; overlapped = launched on the prefill stream this step
    pub admitted: usize,
    pub resumed: usize,
    pub preempted: usize,
    pub retired: usize,
    /// requests bounced at admission (invalid prompt)
    pub rejected: usize,
    /// running sequences decoded this step
    pub occupancy: usize,
    /// overlap executor: sequences whose finished prefill joined the
    /// decode stream this step
    pub joined: usize,
    /// overlap executor: sequences still mid-prefill on the GPU stream
    /// at the end of this step
    pub prefill_inflight: usize,
    /// in-flight sequences a device-loss recovery touched this step
    /// (kept decoding on restored replicas, reset to re-prefill, or
    /// aborted — per the configured [`RecoveryPolicy`])
    pub recovered: usize,
}

#[derive(Debug, Clone, Copy)]
enum Cand {
    /// index into `suspended`
    Resume(usize),
    /// index into `queue`
    Admit(usize),
}

pub struct Scheduler {
    cfg: SchedConfig,
    pub slots: SlotManager,
    queue: Vec<Arrival>,
    running: Vec<Sequence>,
    suspended: Vec<Sequence>,
    meta: HashMap<u64, ReqMeta>,
    /// every id ever enqueued (duplicates are rejected even after the
    /// original retires — records must stay unambiguous)
    seen_ids: std::collections::BTreeSet<u64>,
    pub finished: Vec<RequestRecord>,
    pub steps: u64,
    /// two-stream executor state (prefill-stream frontier, parked
    /// cohorts, overlap ledger); inert when `cfg.overlap` is off
    pub pipeline: PipelineState,
}

/// Admission order: priority desc, then arrival asc, then id asc.
fn beats(a: (u8, Time, u64), b: (u8, Time, u64)) -> bool {
    if a.0 != b.0 {
        return a.0 > b.0;
    }
    if a.1 != b.1 {
        return a.1 < b.1;
    }
    a.2 < b.2
}

impl Scheduler {
    pub fn new(cfg: SchedConfig) -> Self {
        let cfg = SchedConfig {
            max_batch: cfg.max_batch.max(1),
            prefill_chunk: cfg.prefill_chunk.max(1),
            slots: cfg.slots.max(1),
            ..cfg
        };
        let slots = SlotManager::new(cfg.slots);
        Scheduler {
            cfg,
            slots,
            queue: Vec::new(),
            running: Vec::new(),
            suspended: Vec::new(),
            meta: HashMap::new(),
            seen_ids: std::collections::BTreeSet::new(),
            finished: Vec::new(),
            steps: 0,
            pipeline: PipelineState::new(),
        }
    }

    /// Hand a request to the scheduler; it becomes admissible once the
    /// device clock reaches `a.at`.  Duplicate ids are rejected (records
    /// are keyed by id).
    pub fn enqueue(&mut self, a: Arrival) -> Result<()> {
        if !self.seen_ids.insert(a.req.id) {
            bail!("duplicate request id {}", a.req.id);
        }
        self.meta.insert(
            a.req.id,
            ReqMeta {
                priority: a.priority,
                arrived_at: a.at,
                admitted_at: 0.0,
                first_token_at: 0.0,
                preemptions: 0,
            },
        );
        crate::obs::req_instant(a.req.id, "arrive", a.at);
        attr::mark(a.req.id, attr::MarkKind::Arrive, a.at);
        self.queue.push(a);
        Ok(())
    }

    pub fn queued_count(&self) -> usize {
        self.queue.len()
    }

    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    pub fn suspended_count(&self) -> usize {
        self.suspended.len()
    }

    /// Nothing queued, running, parked, or mid-prefill on the stream.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty()
            && self.running.is_empty()
            && self.suspended.is_empty()
            && self.pipeline.pending_cohorts() == 0
    }

    /// Earliest arrival time still waiting in the queue.
    pub fn earliest_pending(&self) -> Option<Time> {
        self.queue.iter().map(|a| a.at).fold(None, |acc, t| match acc {
            Some(b) if b <= t => Some(b),
            _ => Some(t),
        })
    }

    /// Best eligible waiting candidate: parked (resume) and — when a new
    /// admission is currently possible — arrived queue entries.
    fn best_eligible(&self, now: Time, can_admit_new: bool) -> Option<(u8, Cand)> {
        let mut best: Option<((u8, Time, u64), Cand)> = None;
        for (i, s) in self.suspended.iter().enumerate() {
            let m = &self.meta[&s.req.id];
            let key = (m.priority, m.arrived_at, s.req.id);
            if best.is_none() || beats(key, best.as_ref().unwrap().0) {
                best = Some((key, Cand::Resume(i)));
            }
        }
        if can_admit_new {
            for (i, a) in self.queue.iter().enumerate() {
                if a.at > now {
                    continue;
                }
                let key = (a.priority, a.at, a.req.id);
                if best.is_none() || beats(key, best.as_ref().unwrap().0) {
                    best = Some((key, Cand::Admit(i)));
                }
            }
        }
        best.map(|(key, c)| (key.0, c))
    }

    /// Weakest running sequence — the preemption victim — but only if it
    /// is strictly weaker than `than_priority`.  Lowest priority loses;
    /// among equals the youngest (latest arrival) yields first.  "a is
    /// weaker than b" is exactly `beats(b, a)`, so the admission order
    /// and the victim order can never diverge.
    fn weakest_running(&self, than_priority: u8) -> Option<usize> {
        let mut worst: Option<((u8, Time, u64), usize)> = None;
        for (i, s) in self.running.iter().enumerate() {
            let m = &self.meta[&s.req.id];
            let key = (m.priority, m.arrived_at, s.req.id);
            if worst.is_none() || beats(worst.as_ref().unwrap().0, key) {
                worst = Some((key, i));
            }
        }
        match worst {
            Some(((p, _, _), i)) if p < than_priority => Some(i),
            _ => None,
        }
    }

    /// One engine step.  Serialized (the default): retire, (resume |
    /// admit | preempt), chunked prefill for the admitted cohort, then
    /// one decode step — prefill and decode share one clock, so every
    /// admission stalls the in-flight decodes.  With `cfg.overlap` the
    /// pipelined two-stream executor runs instead: admissions prefill
    /// on the GPU stream while the decode tick advances independently.
    pub fn step(&mut self, engine: &mut InferenceEngine) -> Result<StepReport> {
        // The GpuArtifact ablation keeps its host KV cache indexed by
        // batch position, which cannot survive per-step membership
        // changes (retire/admit reorder the batch); the CSD backend keys
        // KV streams by slot and is membership-agnostic.
        if matches!(engine.cfg.backend, AttnBackend::GpuArtifact { .. }) {
            bail!("continuous batching requires the in-storage (Csd) attention backend");
        }
        if self.cfg.overlap {
            self.step_overlapped(engine)
        } else {
            self.step_serialized(engine)
        }
    }

    /// The serialized executor — kept verbatim from the pre-pipeline
    /// scheduler; `tests/pipeline.rs` pins its outputs AND per-step
    /// timing against an independent replay.
    fn step_serialized(&mut self, engine: &mut InferenceEngine) -> Result<StepReport> {
        engine.shards.set_overlap_tracking(false);
        let mut rep = StepReport::default();
        self.steps += 1;
        // a scheduled CSD loss may have fired between steps (or the idle
        // fast-forward jumped the clock past it): recover before
        // dispatching anything at the dead device
        if engine.fault_active() {
            if let Some(dev) = engine.dead_device() {
                self.recover_loss(engine, dev, &mut rep)?;
            }
        }
        self.retire(engine, &mut rep)?;
        let t_in = engine.sim_now;

        let now = engine.sim_now;
        let seats = self.cfg.max_batch.min(engine.max_bucket());
        let mut cohort = self.plan_cohort(engine, now, seats, 0, &mut rep)?;

        // ---- chunked prefill for the admitted cohort ------------------
        if !cohort.is_empty() {
            for s in &cohort {
                self.slots.commit(s.slot)?;
            }
            let bucket = engine.bucket_for(cohort.len());
            let mut prefilled = true;
            if let Err(e) = engine.prefill(&mut cohort, bucket) {
                let Some(dev) = lost_device(&e) else { return Err(e) };
                let keep = engine.shards.recovery_policy() == RecoveryPolicy::Replicated;
                if !keep {
                    // the half-prefilled cohort joins the in-flight set
                    // so the policy handler restarts or aborts it along
                    // with everything else
                    self.running.append(&mut cohort);
                }
                self.recover_loss(engine, dev, &mut rep)?;
                if keep {
                    // KV intact: replay — idempotent pos-aware writes
                    // skip the layers that already shipped
                    engine.prefill(&mut cohort, bucket)?;
                } else {
                    prefilled = false;
                }
            }
            if prefilled {
                let first_token_at = engine.sim_now;
                for s in &cohort {
                    crate::obs::req_instant(s.req.id, "admit", now);
                    crate::obs::req_span(s.req.id, "prefill", now, first_token_at);
                    attr::mark(s.req.id, attr::MarkKind::Admit, now);
                    attr::frame(s.req.id, attr::FrameKind::Prefill, now, first_token_at);
                    if let Some(m) = self.meta.get_mut(&s.req.id) {
                        crate::obs::flow(
                            "admit",
                            crate::obs::TraceLevel::Request,
                            (crate::obs::PID_REQUESTS, s.req.id, m.arrived_at),
                            (crate::obs::PID_REQUESTS, s.req.id, now),
                        );
                        m.admitted_at = now;
                        m.first_token_at = first_token_at;
                    }
                }
                engine.metrics.admissions += cohort.len() as u64;
                rep.admitted = cohort.len();
                self.running.append(&mut cohort);
            }
        }

        // prefill alone can finish a request (max_new_tokens == 1):
        // retire before decoding so it never gets an extra token
        self.retire(engine, &mut rep)?;

        // ---- one decode step over the live batch ----------------------
        if !self.running.is_empty() {
            let bucket = engine.bucket_for(self.running.len());
            let d0 = engine.sim_now;
            if let Err(e) = engine.decode_step(&mut self.running, bucket) {
                let Some(dev) = lost_device(&e) else { return Err(e) };
                if self.recover_loss(engine, dev, &mut rep)? && !self.running.is_empty() {
                    // KV intact (replica restore): replay the whole step
                    // — surviving shards skip the writes they already
                    // applied, so outputs match the fault-free run
                    let bucket = engine.bucket_for(self.running.len());
                    engine.decode_step(&mut self.running, bucket)?;
                }
            }
            if crate::obs::enabled() {
                for s in &self.running {
                    crate::obs::req_span(s.req.id, "decode_step", d0, engine.sim_now);
                }
            }
            if attr::enabled() {
                for s in &self.running {
                    attr::frame(s.req.id, attr::FrameKind::Decode, d0, engine.sim_now);
                }
            }
        }
        rep.occupancy = self.running.len();
        self.retire(engine, &mut rep)?;
        if rep.occupancy > 0 {
            engine.metrics.busy_steps += 1;
            engine.metrics.busy_step_sim_s += engine.sim_now - t_in;
        }
        self.check_capacity(engine)?;
        Ok(rep)
    }

    /// The pipelined executor: the decode stream ticks at the engine
    /// clock while admissions ride the GPU prefill stream, joining the
    /// batch at the first tick after their prefill (and layer-wise KV
    /// ship) completes.  Outputs are bit-identical to the serialized
    /// path — per-sequence generation depends only on the sequence's
    /// own KV — but TTFT and steady-state decode latency decouple.
    fn step_overlapped(&mut self, engine: &mut InferenceEngine) -> Result<StepReport> {
        engine.shards.set_overlap_tracking(true);
        let mut rep = StepReport::default();
        self.steps += 1;
        if engine.fault_active() {
            if let Some(dev) = engine.dead_device() {
                self.recover_loss(engine, dev, &mut rep)?;
            }
        }
        self.retire(engine, &mut rep)?;

        let seats = self.cfg.max_batch.min(engine.max_bucket());
        // the decode plane is empty and nothing can resume — either no
        // suspended sequences, or parked cohorts hold every seat (a
        // preemption burst can suspend the whole running batch while its
        // replacement is still mid-prefill): the frontier has nothing to
        // do before the earliest parked cohort joins
        let resumable = !self.suspended.is_empty() && self.pipeline.pending_seqs() < seats;
        if self.running.is_empty() && !resumable {
            if let Some(t) = self.pipeline.earliest_ready() {
                if t > engine.sim_now {
                    engine.sim_now = t;
                }
            }
        }
        let t_in = engine.sim_now;

        // ---- join: cohorts whose prefill stream completed -------------
        let joined = self.pipeline.take_ready(engine.sim_now);
        rep.joined = joined.len();
        self.running.extend(joined);
        // prefill alone can finish a request (max_new_tokens == 1):
        // retire at the join so it never gets an extra token
        self.retire(engine, &mut rep)?;

        let now = engine.sim_now;
        // parked cohorts hold seats: admission planning must count them
        // or a join could overflow the batch bucket
        let held = self.pipeline.pending_seqs();
        let mut cohort = self.plan_cohort(engine, now, seats, held, &mut rep)?;

        // ---- decode tick at the decode frontier -----------------------
        // (never waits on the prefill stream).  The tick runs before the
        // cohort's prefill is submitted: the prefill stream starts at or
        // after this frontier, so submitting it first would let its
        // flash programs queue ahead of this tick's reads on shared dies
        // — a priority inversion the real pipeline doesn't have.
        let decode_span = if self.running.is_empty() {
            None
        } else {
            let d0 = engine.sim_now;
            let bucket = engine.bucket_for(self.running.len());
            if let Err(e) = engine.decode_step(&mut self.running, bucket) {
                let Some(dev) = lost_device(&e) else { return Err(e) };
                if self.recover_loss(engine, dev, &mut rep)? && !self.running.is_empty() {
                    let bucket = engine.bucket_for(self.running.len());
                    engine.decode_step(&mut self.running, bucket)?;
                }
            }
            if crate::obs::enabled() {
                for s in &self.running {
                    crate::obs::req_span(s.req.id, "decode_step", d0, engine.sim_now);
                }
            }
            if attr::enabled() {
                for s in &self.running {
                    attr::frame(s.req.id, attr::FrameKind::Decode, d0, engine.sim_now);
                }
            }
            Some((d0, engine.sim_now))
        };
        rep.occupancy = self.running.len();

        // ---- launch the cohort on the prefill stream ------------------
        if !cohort.is_empty() {
            for s in &cohort {
                self.slots.commit(s.slot)?;
            }
            let bucket = engine.bucket_for(cohort.len());
            let start = now.max(self.pipeline.prefill_free);
            let ready = match engine.prefill_stage(&mut cohort, bucket, start) {
                Ok(t) => Some(t),
                Err(e) => {
                    let Some(dev) = lost_device(&e) else { return Err(e) };
                    let keep = engine.shards.recovery_policy() == RecoveryPolicy::Replicated;
                    if !keep {
                        self.running.append(&mut cohort);
                    }
                    self.recover_loss(engine, dev, &mut rep)?;
                    if keep {
                        let s2 = start.max(engine.sim_now);
                        Some(engine.prefill_stage(&mut cohort, bucket, s2)?)
                    } else {
                        None
                    }
                }
            };
            if let Some(ready) = ready {
                for s in &cohort {
                    crate::obs::req_instant(s.req.id, "admit", now);
                    crate::obs::req_span(s.req.id, "prefill", start, ready);
                    attr::mark(s.req.id, attr::MarkKind::Admit, now);
                    attr::frame(s.req.id, attr::FrameKind::Prefill, start, ready);
                    if let Some(m) = self.meta.get_mut(&s.req.id) {
                        crate::obs::flow(
                            "admit",
                            crate::obs::TraceLevel::Request,
                            (crate::obs::PID_REQUESTS, s.req.id, m.arrived_at),
                            (crate::obs::PID_REQUESTS, s.req.id, now),
                        );
                        // TTFT is pinned to the prefill STREAM's completion,
                        // not to the end of the decode step that later
                        // absorbs the cohort
                        m.admitted_at = ready;
                        m.first_token_at = ready;
                    }
                }
                engine.metrics.admissions += cohort.len() as u64;
                rep.admitted = cohort.len();
                self.pipeline.park(cohort, start, ready);
            }
        }
        if let Some((d0, d1)) = decode_span {
            // accounted after the park so this tick's overlap with the
            // cohort it launched is counted too
            self.pipeline.note_decode(d0, d1);
        }
        self.retire(engine, &mut rep)?;
        if rep.occupancy > 0 {
            engine.metrics.busy_steps += 1;
            engine.metrics.busy_step_sim_s += engine.sim_now - t_in;
        }
        rep.prefill_inflight = self.pipeline.pending_seqs();
        self.check_capacity(engine)?;
        Ok(rep)
    }

    /// Planning half of a step: place the best eligible candidates
    /// (resume | admit | preempt) best-first until seats, the prefill
    /// chunk, or the slot pool run out.  `held` counts seats claimed
    /// outside `running` (the overlap executor's parked cohorts).
    /// Returns the newly admitted cohort with slots reserved but not
    /// yet committed.
    ///
    /// Terminates: every iteration either consumes a waiting candidate
    /// or replaces a strictly lower-priority runner (bounded).
    fn plan_cohort(
        &mut self,
        engine: &mut InferenceEngine,
        now: Time,
        seats: usize,
        held: usize,
        rep: &mut StepReport,
    ) -> Result<Vec<Sequence>> {
        let mut cohort: Vec<Sequence> = Vec::new();
        loop {
            let can_admit_new =
                cohort.len() < self.cfg.prefill_chunk && self.slots.free_count() > 0;
            let Some((prio, cand)) = self.best_eligible(now, can_admit_new) else {
                break;
            };
            // reject invalid requests before they can cost a victim its
            // seat (and instead of letting engine.prefill abort the run);
            // max_new_tokens == 0 is invalid because prefill always emits
            // one token
            if let Cand::Admit(i) = cand {
                let sp = engine.rt.manifest.model.prefill_seq;
                let bad = {
                    let a = &self.queue[i];
                    a.req.prompt.is_empty()
                        || a.req.prompt.len() > sp
                        || a.req.max_new_tokens == 0
                };
                if bad {
                    let a = self.queue.remove(i);
                    self.meta.remove(&a.req.id);
                    crate::obs::req_instant(a.req.id, "reject", now);
                    self.finished.push(RequestRecord {
                        id: a.req.id,
                        priority: a.priority,
                        arrived_at: a.at,
                        admitted_at: 0.0,
                        first_token_at: 0.0,
                        finished_at: now,
                        prompt_len: a.req.prompt.len(),
                        generated: Vec::new(),
                        preemptions: 0,
                        rejected: true,
                        aborted: false,
                    });
                    rep.rejected += 1;
                    continue;
                }
            }
            if self.running.len() + held + cohort.len() >= seats {
                let Some(vi) = self.weakest_running(prio) else {
                    break;
                };
                let mut victim = self.running.swap_remove(vi);
                victim.phase = RequestPhase::Preempted;
                self.slots.suspend(victim.slot)?;
                if let Some(m) = self.meta.get_mut(&victim.req.id) {
                    m.preemptions += 1;
                }
                engine.metrics.preemptions += 1;
                rep.preempted += 1;
                crate::obs::req_instant(victim.req.id, "preempt", now);
                attr::mark(victim.req.id, attr::MarkKind::Preempt, now);
                self.suspended.push(victim);
            }
            match cand {
                Cand::Resume(i) => {
                    let mut s = self.suspended.remove(i);
                    self.slots.resume(s.slot)?;
                    if self.cfg.drop_on_resume {
                        self.drop_low_importance(engine, &mut s)?;
                    }
                    s.phase = RequestPhase::Decoding;
                    engine.metrics.resumes += 1;
                    rep.resumed += 1;
                    crate::obs::req_instant(s.req.id, "resume", now);
                    attr::mark(s.req.id, attr::MarkKind::Resume, now);
                    self.running.push(s);
                }
                Cand::Admit(i) => {
                    let a = self.queue.remove(i);
                    let slot = self.slots.reserve()?;
                    let mut s = Sequence::new(a.req, slot);
                    // split the prompt at admission: cached prefix
                    // (attached from the index) + unique suffix (the
                    // only part prefill ships).  A pure lookup — returns
                    // 0 with prefix caching off.
                    s.prefix_hit = engine.prefix_match(&s.req.prompt);
                    s.phase = RequestPhase::Prefilling;
                    cohort.push(s);
                }
            }
        }
        Ok(cohort)
    }

    /// KV byte accounting + capacity invariants.
    ///
    /// Flash-resident bytes are tracked once per held slot (live,
    /// parked mid-pipeline, or suspended — no double counting of
    /// preempted sequences), and the DRAM hot tier is bounded
    /// separately: slot bytes + tier bytes can never exceed flash
    /// capacity + tier capacity.
    fn check_capacity(&mut self, engine: &mut InferenceEngine) -> Result<()> {
        let m = &engine.rt.manifest.model;
        let per_tok =
            (2 * m.n_heads * m.d_head * crate::config::model::FP16_BYTES * m.n_layers) as u64;
        for s in self.running.iter().chain(self.pipeline.pending_iter()) {
            let resident_toks = s.kv_len.saturating_sub(s.dropped.len());
            self.slots.set_kv_bytes(s.slot, resident_toks as u64 * per_tok);
        }
        let resident = self.slots.resident_kv_bytes();
        anyhow::ensure!(
            resident <= engine.kv_capacity_bytes(),
            "resident KV ({resident} B) exceeds flash capacity ({} B)",
            engine.kv_capacity_bytes()
        );
        anyhow::ensure!(
            engine.tier_hot_bytes() <= engine.tier_capacity_bytes(),
            "hot tier ({} B) exceeds its configured capacity ({} B)",
            engine.tier_hot_bytes(),
            engine.tier_capacity_bytes()
        );
        // shard-aware accounting: every individual device must fit its
        // stripe — the aggregate bound can hide one overflowing shard.
        // Unlike the analytic K+V bound above, this one counts PHYSICAL
        // mapped pages (dual-K embedding copies and page rounding
        // included), because stripe imbalance manifests on flash; with
        // the current specs mapped bytes can never exceed the physical
        // array, so this is a tripwire for accounting bugs (slot leaks,
        // broken striping), not an admission-control path.
        self.slots.set_shard_kv_bytes(engine.shards.mapped_kv_bytes());
        let per_csd_cap = engine.kv_capacity_bytes_per_csd();
        for (c, &b) in self.slots.shard_kv_bytes().iter().enumerate() {
            anyhow::ensure!(
                b <= per_csd_cap,
                "shard {c} stripe ({b} B) exceeds its flash capacity ({per_csd_cap} B)"
            );
        }
        Ok(())
    }

    /// H2O-style drop-on-resume: keep the `resume_keep` most important
    /// token positions (by cumulative attention mass from the engine's
    /// Logit passes) plus a recent window, and drop the rest.  Dropped
    /// positions are masked out of future attention and fully-dropped
    /// token groups free their flash pages — the resumed sequence comes
    /// back with a smaller cache instead of re-materializing all of it.
    fn drop_low_importance(
        &mut self,
        engine: &mut InferenceEngine,
        s: &mut Sequence,
    ) -> Result<()> {
        let keep = self.cfg.resume_keep;
        if keep == 0 {
            return Ok(());
        }
        let resident = s.kv_len.saturating_sub(s.dropped.len());
        if resident <= keep {
            return Ok(());
        }
        let n_drop = resident - keep;
        let recent = RESUME_RECENT_WINDOW.min(keep);
        let protect_from = s.kv_len.saturating_sub(recent);
        let imp = engine.token_importance(s.slot);
        let mut cand: Vec<(f32, usize)> = (0..protect_from)
            .filter(|t| !s.dropped.contains(&(*t as u32)))
            .map(|t| (imp.get(t).copied().unwrap_or(0.0), t))
            .collect();
        cand.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        cand.truncate(n_drop);
        let mut drop: Vec<u32> = cand.into_iter().map(|(_, t)| t as u32).collect();
        drop.sort_unstable();
        if drop.is_empty() {
            return Ok(());
        }
        engine.drop_tokens(s.slot, &drop)?;
        for &t in &drop {
            s.dropped.insert(t);
        }
        Ok(())
    }

    /// Recover from the loss of CSD `dev`: replace the device (and under
    /// the replicated policy restore its KV from the peer mirrors), then
    /// apply the policy's sequence-level consequences — keep decoding
    /// (Replicated), reset every in-flight sequence to re-prefill
    /// (RePrefill), or abort them (RetryOnly).  Returns whether in-flight
    /// KV survived (i.e. the caller may replay the failed operation).
    fn recover_loss(
        &mut self,
        engine: &mut InferenceEngine,
        dev: usize,
        rep: &mut StepReport,
    ) -> Result<bool> {
        let policy = engine.shards.recovery_policy();
        let (rt0, rt1) = engine.recover_lost_device(dev)?;
        // the outage window on every in-flight request's track: a frame
        // fully covered by a Recovery segment keeps the per-request
        // wall-time identity intact by construction (when the window
        // falls inside a later decode frame, the segment still lands in
        // that frame's weighted split)
        if attr::enabled() && rt1 > rt0 {
            for s in self.running.iter().chain(self.pipeline.pending_iter()) {
                let _req = crate::obs::ReqScope::enter(s.req.id);
                attr::frame(s.req.id, attr::FrameKind::Decode, rt0, rt1);
                attr::seg(attr::Bucket::Recovery, rt0, rt1, rt1 - rt0);
            }
        }
        match policy {
            RecoveryPolicy::Replicated => {
                rep.recovered += self.running.len() + self.pipeline.pending_seqs();
                Ok(true)
            }
            RecoveryPolicy::RePrefill => {
                self.restart_in_flight(engine, rep)?;
                Ok(false)
            }
            RecoveryPolicy::RetryOnly => {
                self.abort_in_flight(engine, rep)?;
                Ok(false)
            }
        }
    }

    /// RePrefill recovery: every in-flight sequence (running, suspended,
    /// or parked mid-pipeline) lost part of its KV with the device, so
    /// free the surviving stripes, return the slots, and put the
    /// requests back in the arrival queue.  They re-admit through the
    /// normal planner and regenerate from scratch — the model is
    /// deterministic, so the final outputs match the fault-free run.
    fn restart_in_flight(
        &mut self,
        engine: &mut InferenceEngine,
        rep: &mut StepReport,
    ) -> Result<()> {
        let mut seqs: Vec<Sequence> = Vec::new();
        seqs.append(&mut self.running);
        seqs.append(&mut self.suspended);
        seqs.extend(self.pipeline.drain_all());
        for s in seqs {
            engine.free_sequence(&s)?;
            self.slots.release(s.slot)?;
            engine.metrics.restarts += 1;
            rep.recovered += 1;
            crate::obs::req_instant(s.req.id, "restart", engine.sim_now);
            // the id is already in seen_ids and keeps its meta (arrival
            // stamp, priority, preemption count) — requeue directly
            // instead of enqueue()
            let (at, priority) = {
                let m = &self.meta[&s.req.id];
                (m.arrived_at, m.priority)
            };
            self.queue.push(Arrival { req: s.req, at, priority });
        }
        Ok(())
    }

    /// RetryOnly recovery: in-flight sequences abort (their KV died with
    /// the device); the replacement serves queued traffic only.
    fn abort_in_flight(
        &mut self,
        engine: &mut InferenceEngine,
        rep: &mut StepReport,
    ) -> Result<()> {
        let mut seqs: Vec<Sequence> = Vec::new();
        seqs.append(&mut self.running);
        seqs.append(&mut self.suspended);
        seqs.extend(self.pipeline.drain_all());
        for mut s in seqs {
            s.finish();
            engine.free_sequence(&s)?;
            self.slots.release(s.slot)?;
            engine.metrics.aborted_requests += 1;
            rep.recovered += 1;
            crate::obs::req_instant(s.req.id, "abort", engine.sim_now);
            attr::mark(s.req.id, attr::MarkKind::Retire, engine.sim_now);
            let m = self.meta.remove(&s.req.id).unwrap_or_else(|| ReqMeta {
                priority: 0,
                arrived_at: 0.0,
                admitted_at: 0.0,
                first_token_at: 0.0,
                preemptions: 0,
            });
            self.finished.push(RequestRecord {
                id: s.req.id,
                priority: m.priority,
                arrived_at: m.arrived_at,
                admitted_at: m.admitted_at,
                first_token_at: m.first_token_at,
                finished_at: engine.sim_now,
                prompt_len: s.req.prompt.len(),
                generated: s.generated,
                preemptions: m.preemptions,
                rejected: false,
                aborted: true,
            });
        }
        Ok(())
    }

    /// Drop finished (or context-exhausted) sequences from the batch,
    /// freeing their KV slot and FTL streams immediately.  A `FreeSlot`
    /// that lands on a just-lost device triggers recovery and retries
    /// against the replacement (a clean device frees as a no-op).
    fn retire(&mut self, engine: &mut InferenceEngine, rep: &mut StepReport) -> Result<()> {
        let max_seq = engine.rt.manifest.model.max_seq;
        let mut i = 0;
        while i < self.running.len() {
            let done = {
                let s = &self.running[i];
                s.is_done() || s.next_pos() >= max_seq
            };
            if !done {
                i += 1;
                continue;
            }
            let mut s = self.running.swap_remove(i);
            s.finish();
            if let Err(e) = engine.free_sequence(&s) {
                let Some(dev) = lost_device(&e) else { return Err(e) };
                self.recover_loss(engine, dev, rep)?;
                engine.free_sequence(&s)?;
            }
            self.slots.release(s.slot)?;
            engine.metrics.requests_done += 1;
            engine.metrics.retirements += 1;
            crate::obs::req_instant(s.req.id, "retire", engine.sim_now);
            attr::mark(s.req.id, attr::MarkKind::Retire, engine.sim_now);
            let m = self.meta.remove(&s.req.id).unwrap_or_else(|| ReqMeta {
                priority: 0,
                arrived_at: 0.0,
                admitted_at: 0.0,
                first_token_at: 0.0,
                preemptions: 0,
            });
            self.finished.push(RequestRecord {
                id: s.req.id,
                priority: m.priority,
                arrived_at: m.arrived_at,
                admitted_at: m.admitted_at,
                first_token_at: m.first_token_at,
                finished_at: engine.sim_now,
                prompt_len: s.req.prompt.len(),
                generated: s.generated,
                preemptions: m.preemptions,
                rejected: false,
                aborted: false,
            });
            rep.retired += 1;
        }
        Ok(())
    }
}

/// Summary of a full serving run.
#[derive(Debug)]
pub struct ServeReport {
    pub records: Vec<RequestRecord>,
    pub steps: u64,
    pub preemptions: u64,
    /// simulated device time at the end of the run
    pub sim_end: Time,
    /// two-stream overlap ledger (all zero on serialized runs)
    pub overlap: OverlapStats,
}

impl ServeReport {
    fn percentiles(samples: Vec<f64>) -> Option<[f64; 3]> {
        if samples.is_empty() {
            return None;
        }
        let mut s = samples;
        Some([
            percentile(&mut s, 50.0),
            percentile(&mut s, 95.0),
            percentile(&mut s, 99.0),
        ])
    }

    /// Records of requests that were served to completion — neither
    /// rejected at admission nor aborted at a device loss by the
    /// retry-only recovery policy (the goodput set).
    pub fn served(&self) -> impl Iterator<Item = &RequestRecord> {
        self.records.iter().filter(|r| !r.rejected && !r.aborted)
    }

    /// p50/p95/p99 of request latency (arrival -> retirement, sim time).
    /// Rejected and aborted requests are excluded — the percentiles
    /// describe the traffic the degraded array still completed.
    pub fn latency_percentiles(&self) -> Option<[f64; 3]> {
        Self::percentiles(
            self.served()
                .map(|r| (r.finished_at - r.arrived_at).max(0.0))
                .collect(),
        )
    }

    /// p50/p95/p99 of time-to-first-token (arrival -> prefill done),
    /// over served requests only.
    pub fn ttft_percentiles(&self) -> Option<[f64; 3]> {
        Self::percentiles(
            self.served()
                .map(|r| (r.first_token_at - r.arrived_at).max(0.0))
                .collect(),
        )
    }

    pub fn total_generated(&self) -> u64 {
        self.records.iter().map(|r| r.generated.len() as u64).sum()
    }

    pub fn rejected_count(&self) -> usize {
        self.records.iter().filter(|r| r.rejected).count()
    }

    /// Requests the retry-only recovery policy aborted at a device loss.
    pub fn aborted_count(&self) -> usize {
        self.records.iter().filter(|r| r.aborted).count()
    }

    pub fn summary(&self, metrics: &EngineMetrics) -> String {
        let rejected = self.rejected_count();
        let aborted = self.aborted_count();
        let mut out = format!(
            "served {} requests in {} steps — {} tokens, sim_end {:.4}s, {}",
            self.records.len() - rejected - aborted,
            self.steps,
            self.total_generated(),
            self.sim_end,
            metrics.churn_report(),
        );
        if rejected > 0 {
            out.push_str(&format!("\nrejected {rejected} invalid requests at admission"));
        }
        if aborted > 0 {
            out.push_str(&format!("\naborted {aborted} in-flight requests at device loss"));
        }
        if let Some([p50, p95, p99]) = self.latency_percentiles() {
            out.push_str(&format!(
                "\nlatency  (sim) p50 {p50:.4}s  p95 {p95:.4}s  p99 {p99:.4}s"
            ));
        }
        if let Some([p50, p95, p99]) = self.ttft_percentiles() {
            out.push_str(&format!(
                "\nTTFT     (sim) p50 {p50:.4}s  p95 {p95:.4}s  p99 {p99:.4}s"
            ));
        }
        let ov = &self.overlap;
        if ov.cohorts > 0 {
            out.push_str(&format!(
                "\noverlap  prefill stream busy {:.6}s / decode stream busy {:.6}s, \
                 {:.6}s shadowed ({:.1}%), GPU idle during decode {:.6}s, CSD idle \
                 during prefill {:.6}s, {} decode steps with a prefill in flight",
                ov.prefill_busy_s,
                ov.decode_busy_s,
                ov.overlapped_s,
                100.0 * ov.overlap_frac(),
                ov.gpu_idle_during_decode_s,
                ov.csd_idle_during_prefill_s(),
                ov.steps_with_prefill_inflight,
            ));
        }
        out
    }
}

/// Drive the scheduler open-loop until every enqueued arrival retires.
/// Fast-forwards the simulated clock across idle gaps; fully
/// deterministic for a fixed arrival trace.
pub fn run_open_loop(
    engine: &mut InferenceEngine,
    arrivals: Vec<Arrival>,
    cfg: SchedConfig,
) -> Result<ServeReport> {
    let mut sched = Scheduler::new(cfg);
    for a in arrivals {
        sched.enqueue(a)?;
    }
    let mut stalled_steps = 0u64;
    while !sched.is_idle() {
        if sched.running.is_empty()
            && sched.suspended.is_empty()
            && sched.pipeline.pending_cohorts() == 0
        {
            if let Some(t) = sched.earliest_pending() {
                if t > engine.sim_now {
                    engine.sim_now = t;
                }
            }
        }
        let rep = sched.step(engine)?;
        let progressed = rep.occupancy > 0
            || rep.admitted > 0
            || rep.resumed > 0
            || rep.retired > 0
            || rep.rejected > 0
            || rep.joined > 0
            || rep.recovered > 0;
        if !progressed {
            stalled_steps += 1;
            if stalled_steps > 3 {
                bail!(
                    "scheduler stalled: {} queued, {} suspended, {} mid-prefill, {} free slots",
                    sched.queued_count(),
                    sched.suspended_count(),
                    sched.pipeline.pending_seqs(),
                    sched.slots.free_count()
                );
            }
        } else {
            stalled_steps = 0;
        }
    }
    Ok(ServeReport {
        records: std::mem::take(&mut sched.finished),
        steps: sched.steps,
        preemptions: sched.slots.stats.preemptions,
        sim_end: engine.sim_now,
        overlap: sched.pipeline.stats.clone(),
    })
}

/// Closed-loop convenience: every request is present at t=0 (the
/// continuous analogue of the offline drain).
pub fn run_closed_loop(
    engine: &mut InferenceEngine,
    reqs: Vec<Request>,
    cfg: SchedConfig,
) -> Result<ServeReport> {
    let at = engine.sim_now;
    let arrivals = reqs
        .into_iter()
        .map(|req| Arrival { req, at, priority: 0 })
        .collect();
    run_open_loop(engine, arrivals, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_order_is_priority_then_fifo() {
        assert!(beats((1, 5.0, 9), (0, 1.0, 1)), "higher priority wins");
        assert!(beats((0, 1.0, 9), (0, 2.0, 1)), "earlier arrival wins");
        assert!(beats((0, 1.0, 1), (0, 1.0, 2)), "lower id breaks ties");
        assert!(!beats((0, 1.0, 2), (0, 1.0, 1)));
    }

    #[test]
    fn scheduler_starts_idle_and_tracks_queue() {
        let mut s = Scheduler::new(SchedConfig::default());
        assert!(s.is_idle());
        s.enqueue(Arrival {
            req: Request { id: 1, prompt: vec![1, 2], max_new_tokens: 2 },
            at: 0.5,
            priority: 1,
        })
        .unwrap();
        assert!(!s.is_idle());
        assert_eq!(s.queued_count(), 1);
        assert_eq!(s.earliest_pending(), Some(0.5));
        // not yet arrived at t=0, so nothing is eligible
        assert!(s.best_eligible(0.0, true).is_none());
        let got = s.best_eligible(1.0, true);
        assert!(matches!(got, Some((1, Cand::Admit(0)))));
    }

    #[test]
    fn duplicate_ids_rejected() {
        let mut s = Scheduler::new(SchedConfig::default());
        let a = Arrival {
            req: Request { id: 7, prompt: vec![1], max_new_tokens: 1 },
            at: 0.0,
            priority: 0,
        };
        s.enqueue(a.clone()).unwrap();
        let err = s.enqueue(a).unwrap_err().to_string();
        assert!(err.contains("duplicate request id"), "{err}");
    }
}
