//! Sequence-slot management: allocates KV slots (the unit the FTL maps),
//! enforces a capacity bound, and reclaims on completion.
//!
//! The continuous-batching scheduler adds two lifecycle refinements:
//!
//! * **reservation** — a slot can be held (`reserve`) before the owning
//!   request is actually prefilled, then bound (`commit`) or returned
//!   (`cancel`).  Admission control reserves during the planning half of
//!   a step so concurrent decisions never hand one slot to two requests.
//! * **suspension** — a preempted sequence keeps its slot (its KV pages
//!   stay resident on flash) but leaves the live set; `resume` brings it
//!   back without re-prefilling.  `release` works from either state.
//!
//! Accounting (`SlotStats`) feeds the serve-loop occupancy report.

use anyhow::{bail, Result};
use std::collections::{BTreeMap, BTreeSet};

/// Monotone lifecycle counters.
#[derive(Debug, Default, Clone)]
pub struct SlotStats {
    pub allocs: u64,
    pub releases: u64,
    pub preemptions: u64,
    pub resumes: u64,
    /// max simultaneously held (live + suspended + reserved) slots
    pub peak_held: usize,
    /// max flash-resident KV bytes observed on any single shard (the
    /// capacity-planning signal for a striped array: the aggregate can
    /// look fine while one device overflows)
    pub peak_shard_kv_bytes: u64,
}

#[derive(Debug)]
pub struct SlotManager {
    capacity: usize,
    free: BTreeSet<u32>,
    reserved: BTreeSet<u32>,
    live: BTreeSet<u32>,
    suspended: BTreeSet<u32>,
    /// flash-resident KV bytes per held slot (scheduler-refreshed)
    kv_bytes: BTreeMap<u32, u64>,
    /// flash-resident KV bytes per shard (scheduler-refreshed from the
    /// shard coordinator's per-device FTL maps)
    shard_kv_bytes: Vec<u64>,
    pub stats: SlotStats,
}

impl SlotManager {
    pub fn new(capacity: usize) -> Self {
        SlotManager {
            capacity,
            free: (0..capacity as u32).collect(),
            reserved: BTreeSet::new(),
            live: BTreeSet::new(),
            suspended: BTreeSet::new(),
            kv_bytes: BTreeMap::new(),
            shard_kv_bytes: Vec::new(),
            stats: SlotStats::default(),
        }
    }

    fn note_held(&mut self) {
        let held = self.capacity - self.free.len();
        self.stats.peak_held = self.stats.peak_held.max(held);
    }

    /// Take a free slot straight to the live set.
    pub fn alloc(&mut self) -> Result<u32> {
        match self.free.pop_first() {
            Some(s) => {
                self.live.insert(s);
                self.stats.allocs += 1;
                self.note_held();
                Ok(s)
            }
            None => bail!("no free KV slots (capacity {})", self.capacity),
        }
    }

    /// Hold a free slot for a request that has not been prefilled yet.
    pub fn reserve(&mut self) -> Result<u32> {
        match self.free.pop_first() {
            Some(s) => {
                self.reserved.insert(s);
                self.note_held();
                Ok(s)
            }
            None => bail!("no free KV slots (capacity {})", self.capacity),
        }
    }

    /// Bind a reserved slot to an admitted (prefilling) sequence.
    pub fn commit(&mut self, slot: u32) -> Result<()> {
        if !self.reserved.remove(&slot) {
            bail!("commit of non-reserved slot {slot}");
        }
        self.live.insert(slot);
        self.stats.allocs += 1;
        Ok(())
    }

    /// Return a reserved slot that was never bound.
    pub fn cancel(&mut self, slot: u32) -> Result<()> {
        if !self.reserved.remove(&slot) {
            bail!("cancel of non-reserved slot {slot}");
        }
        self.free.insert(slot);
        Ok(())
    }

    /// Preempt: the sequence leaves the live set but keeps its slot (KV
    /// pages stay on flash for a later `resume`).
    pub fn suspend(&mut self, slot: u32) -> Result<()> {
        if !self.live.remove(&slot) {
            bail!("suspend of non-live slot {slot}");
        }
        self.suspended.insert(slot);
        self.stats.preemptions += 1;
        Ok(())
    }

    /// Bring a preempted sequence's slot back to the live set.
    pub fn resume(&mut self, slot: u32) -> Result<()> {
        if !self.suspended.remove(&slot) {
            bail!("resume of non-suspended slot {slot}");
        }
        self.live.insert(slot);
        self.stats.resumes += 1;
        Ok(())
    }

    /// Free a slot from the live or suspended set (retirement — the
    /// engine has already issued `FreeSlot` to the CSDs).
    pub fn release(&mut self, slot: u32) -> Result<()> {
        if !self.live.remove(&slot) && !self.suspended.remove(&slot) {
            bail!("release of non-live slot {slot}");
        }
        self.kv_bytes.remove(&slot);
        self.free.insert(slot);
        self.stats.releases += 1;
        Ok(())
    }

    /// Record the flash-resident KV bytes of a held slot.  The scheduler
    /// refreshes this every step (drop-on-resume shrinks it); writes for
    /// slots that are neither live nor suspended are ignored.
    pub fn set_kv_bytes(&mut self, slot: u32, bytes: u64) {
        if self.live.contains(&slot) || self.suspended.contains(&slot) {
            self.kv_bytes.insert(slot, bytes);
        }
    }

    /// Flash-resident KV bytes across held slots.  A preempted
    /// sequence's pages stay resident but its slot moves from `live` to
    /// `suspended` — each held slot is counted exactly once here, and
    /// the DRAM hot tier accounts its (cache-copy) bytes separately, so
    /// the capacity invariant is `resident_kv_bytes() + tier bytes <=
    /// flash + hot-tier capacity` with no double counting.
    pub fn resident_kv_bytes(&self) -> u64 {
        // every kv_bytes key is a held slot: set_kv_bytes only accepts
        // live/suspended slots and release() removes the entry
        debug_assert!(self
            .kv_bytes
            .keys()
            .all(|s| self.live.contains(s) || self.suspended.contains(s)));
        self.kv_bytes.values().sum()
    }

    /// Refresh the per-shard flash-resident footprint (shard-aware
    /// accounting: under head or context striping every *individual*
    /// device must fit its stripe, not just the array in aggregate).
    pub fn set_shard_kv_bytes(&mut self, per_shard: Vec<u64>) {
        if let Some(&m) = per_shard.iter().max() {
            self.stats.peak_shard_kv_bytes = self.stats.peak_shard_kv_bytes.max(m);
        }
        self.shard_kv_bytes = per_shard;
    }

    /// Latest per-shard flash-resident KV bytes.
    pub fn shard_kv_bytes(&self) -> &[u64] {
        &self.shard_kv_bytes
    }

    pub fn live_count(&self) -> usize {
        self.live.len()
    }

    pub fn suspended_count(&self) -> usize {
        self.suspended.len()
    }

    pub fn reserved_count(&self) -> usize {
        self.reserved.len()
    }

    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_cycle() {
        let mut m = SlotManager::new(2);
        let a = m.alloc().unwrap();
        let b = m.alloc().unwrap();
        assert_ne!(a, b);
        assert!(m.alloc().is_err());
        m.release(a).unwrap();
        assert_eq!(m.live_count(), 1);
        let c = m.alloc().unwrap();
        assert_eq!(c, a); // lowest slot reused
        assert!(m.release(99).is_err());
    }

    #[test]
    fn double_release_rejected() {
        let mut m = SlotManager::new(1);
        let a = m.alloc().unwrap();
        m.release(a).unwrap();
        assert!(m.release(a).is_err());
    }

    #[test]
    fn reserve_commit_cancel() {
        let mut m = SlotManager::new(2);
        let r = m.reserve().unwrap();
        assert_eq!(m.reserved_count(), 1);
        assert_eq!(m.live_count(), 0);
        // a reserved slot is not live: release/suspend reject it
        assert!(m.release(r).is_err());
        assert!(m.suspend(r).is_err());
        m.commit(r).unwrap();
        assert_eq!((m.reserved_count(), m.live_count()), (0, 1));
        let r2 = m.reserve().unwrap();
        m.cancel(r2).unwrap();
        assert_eq!(m.free_count(), 1);
        assert!(m.commit(r2).is_err());
    }

    #[test]
    fn kv_byte_accounting_counts_held_slots_once() {
        let mut m = SlotManager::new(2);
        let a = m.alloc().unwrap();
        m.set_kv_bytes(a, 100);
        assert_eq!(m.resident_kv_bytes(), 100);
        // a preempted slot's pages stay resident — counted once, not twice
        m.suspend(a).unwrap();
        assert_eq!(m.resident_kv_bytes(), 100);
        m.resume(a).unwrap();
        m.set_kv_bytes(a, 150);
        assert_eq!(m.resident_kv_bytes(), 150);
        m.release(a).unwrap();
        assert_eq!(m.resident_kv_bytes(), 0);
        // bytes for unheld slots are ignored
        m.set_kv_bytes(7, 999);
        assert_eq!(m.resident_kv_bytes(), 0);
    }

    #[test]
    fn suspend_resume_release_accounting() {
        let mut m = SlotManager::new(2);
        let a = m.alloc().unwrap();
        m.suspend(a).unwrap();
        assert_eq!((m.live_count(), m.suspended_count()), (0, 1));
        // a suspended slot still occupies capacity
        let _b = m.alloc().unwrap();
        assert!(m.alloc().is_err());
        m.resume(a).unwrap();
        assert_eq!(m.live_count(), 2);
        m.suspend(a).unwrap();
        // retirement straight out of suspension is legal
        m.release(a).unwrap();
        assert_eq!(m.free_count(), 1);
        assert_eq!(m.stats.preemptions, 2);
        assert_eq!(m.stats.resumes, 1);
        assert_eq!(m.stats.peak_held, 2);
    }
}
