//! Sequence-slot management: allocates KV slots (the unit the FTL maps),
//! enforces a capacity bound, and reclaims on completion.

use anyhow::{bail, Result};
use std::collections::BTreeSet;

#[derive(Debug)]
pub struct SlotManager {
    capacity: usize,
    free: BTreeSet<u32>,
    live: BTreeSet<u32>,
}

impl SlotManager {
    pub fn new(capacity: usize) -> Self {
        SlotManager {
            capacity,
            free: (0..capacity as u32).collect(),
            live: BTreeSet::new(),
        }
    }

    pub fn alloc(&mut self) -> Result<u32> {
        match self.free.pop_first() {
            Some(s) => {
                self.live.insert(s);
                Ok(s)
            }
            None => bail!("no free KV slots (capacity {})", self.capacity),
        }
    }

    pub fn release(&mut self, slot: u32) -> Result<()> {
        if !self.live.remove(&slot) {
            bail!("release of non-live slot {slot}");
        }
        self.free.insert(slot);
        Ok(())
    }

    pub fn live_count(&self) -> usize {
        self.live.len()
    }

    pub fn free_count(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_cycle() {
        let mut m = SlotManager::new(2);
        let a = m.alloc().unwrap();
        let b = m.alloc().unwrap();
        assert_ne!(a, b);
        assert!(m.alloc().is_err());
        m.release(a).unwrap();
        assert_eq!(m.live_count(), 1);
        let c = m.alloc().unwrap();
        assert_eq!(c, a); // lowest slot reused
        assert!(m.release(99).is_err());
    }

    #[test]
    fn double_release_rejected() {
        let mut m = SlotManager::new(1);
        let a = m.alloc().unwrap();
        m.release(a).unwrap();
        assert!(m.release(a).is_err());
    }
}
