//! Attention-head -> CSD routing (paper §IV-D "Scale To CSD Array").
//!
//! Heads are independent, so the router stripes them round-robin across
//! devices; for n_head >> n_csd every device gets an equal share and the
//! attention outputs concatenate back on the GPU.

#[derive(Debug, Clone)]
pub struct HeadRouter {
    n_heads: usize,
    n_csds: usize,
    /// heads assigned to each CSD (round-robin stripe)
    assignment: Vec<Vec<u16>>,
}

impl HeadRouter {
    pub fn new(n_heads: usize, n_csds: usize) -> Self {
        assert!(n_csds > 0 && n_heads > 0);
        let mut assignment = vec![Vec::new(); n_csds];
        for h in 0..n_heads {
            assignment[h % n_csds].push(h as u16);
        }
        HeadRouter { n_heads, n_csds, assignment }
    }

    pub fn n_csds(&self) -> usize {
        self.n_csds
    }

    pub fn heads_of(&self, csd: usize) -> &[u16] {
        &self.assignment[csd]
    }

    pub fn csd_of(&self, head: u16) -> usize {
        head as usize % self.n_csds
    }

    /// Split a (H, d) row-major tensor into per-CSD packed sub-tensors
    /// (rows in each CSD's head order).
    pub fn scatter(&self, rows: &[f32], d: usize) -> Vec<Vec<f32>> {
        debug_assert_eq!(rows.len(), self.n_heads * d);
        self.assignment
            .iter()
            .map(|heads| {
                let mut out = Vec::with_capacity(heads.len() * d);
                for &h in heads {
                    out.extend_from_slice(&rows[h as usize * d..(h as usize + 1) * d]);
                }
                out
            })
            .collect()
    }

    /// Inverse of `scatter`: reassemble per-CSD outputs into (H, d).
    pub fn gather(&self, parts: &[Vec<f32>], d: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n_heads * d];
        for (c, heads) in self.assignment.iter().enumerate() {
            for (i, &h) in heads.iter().enumerate() {
                out[h as usize * d..(h as usize + 1) * d]
                    .copy_from_slice(&parts[c][i * d..(i + 1) * d]);
            }
        }
        out
    }

    /// Max heads on any device (the load-balance bound of Fig. 17a).
    pub fn max_share(&self) -> usize {
        self.assignment.iter().map(|a| a.len()).max().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn balanced_assignment() {
        let r = HeadRouter::new(40, 3);
        let sizes: Vec<usize> = (0..3).map(|c| r.heads_of(c).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 40);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        assert_eq!(r.max_share(), 14);
        for c in 0..3 {
            for &h in r.heads_of(c) {
                assert_eq!(r.csd_of(h), c);
            }
        }
    }

    #[test]
    fn scatter_gather_roundtrip_property() {
        check(
            "router_scatter_gather_id",
            50,
            |rng| {
                let h = rng.range(1, 16);
                let n = rng.range(1, h.min(5));
                let d = rng.range(1, 8);
                let rows: Vec<f32> = (0..h * d).map(|_| rng.normal_f32()).collect();
                (h, n, d, rows)
            },
            |(h, n, d, rows)| {
                let r = HeadRouter::new(*h, *n);
                let parts = r.scatter(rows, *d);
                let back = r.gather(&parts, *d);
                if &back == rows {
                    Ok(())
                } else {
                    Err("roundtrip mismatch".into())
                }
            },
        );
    }
}
