//! L3 host control plane (paper Fig. 7, "InstHost"): the rust coordinator
//! that owns the request lifecycle, schedules the prefill/decode phases,
//! routes attention heads across CSDs, and manages KV slots — while the
//! GPU (PJRT/native artifacts) and the CSDs (in-storage engines) do all
//! the heavy lifting.  Python never runs here.
//!
//! * [`request`]   — request/sequence state machine
//! * [`batcher`]   — offline batch former (bucketed to the AOT batch
//!   sizes; the paper's drain-the-queue throughput policy)
//! * [`scheduler`] — continuous-batching scheduler: per-step admission,
//!   chunked prefill, mid-flight retirement, priority preemption to
//!   flash; with `overlap` the two-stream pipelined executor
//!   ([`crate::pipeline`]) disaggregates prefill from decode
//! * [`kvmgr`]     — sequence-slot allocation, reservation, suspension,
//!   per-shard KV-footprint accounting
//! * [`engine`]    — the inference engine gluing PJRT + the sharded CSD
//!   array ([`crate::shard::ShardCoordinator`]) per §IV-D
//! * [`metrics`]   — throughput/latency/occupancy/churn accounting
//! * [`serveopts`] — parse-once serve configuration shared by the CLI,
//!   the examples and the engine-backed benches

pub mod batcher;
pub mod engine;
pub mod kvmgr;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod serveopts;

pub use batcher::OfflineBatcher;
pub use engine::{EngineConfig, InferenceEngine};
pub use kvmgr::SlotManager;
pub use metrics::EngineMetrics;
pub use request::{Request, RequestPhase, Sequence};
pub use scheduler::{
    run_closed_loop, run_open_loop, RequestRecord, SchedConfig, Scheduler, ServeReport,
    StepReport,
};
pub use serveopts::ServeOpts;
