//! The inference engine: glues the PJRT artifacts ("GPU") to the CSD
//! array per the paper's §IV-D dataflow.
//!
//! Decode step (per layer): GPU `qkv_proj` -> ship k,v to the CSDs
//! (token write) -> in-storage attention (dense or SparF) -> GPU
//! `post_attn`; after the last layer GPU `logits` picks the next token.
//! Prefill: GPU `prefill_block` per layer, KV shipped to the CSDs
//! layer-wise (overlapped in sim time with the next layer's compute).
//!
//! Two attention backends:
//! * `Csd(mode)` — the paper's system: rust-native engine over simulated
//!   flash (FP16 pages through the FTL), timed by the DES;
//! * `GpuArtifact` — ablation/baseline: the `attn_dense`/`attn_sparf`
//!   PJRT artifacts over host-resident padded caches (what a
//!   FlexGen-style system computes), used for cross-validation.

use crate::config::hw::{CsdSpec, FlashSpec, GpuSpec, PcieSpec};
use crate::coordinator::metrics::EngineMetrics;
use crate::coordinator::request::{RequestPhase, Sequence};
use crate::csd::{AttnMode, NvmeQueue};
use crate::ftl::FtlConfig;
use crate::kvtier::{TierConfig, TierStats};
use crate::runtime::manifest::ModelMeta;
use crate::runtime::{HostTensor, Runtime};
use crate::shard::{ShardCoordinator, ShardPolicy, ShardTopology};
use crate::sim::Time;
use anyhow::{bail, Result};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AttnBackend {
    /// in-storage attention on the CSD array (the paper's system)
    Csd(AttnMode),
    /// PJRT artifact attention over host-padded caches (ablation)
    GpuArtifact { sparse: bool },
}

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub n_csds: usize,
    pub backend: AttnBackend,
    /// peer-to-peer command path to the CSDs (vs host-FS)
    pub p2p: bool,
    pub csd_spec: CsdSpec,
    /// per-CSD hot-tier shape (capacity + eviction policy)
    pub tier: TierConfig,
    /// how a sequence's KV is partitioned across the CSD array
    pub shard_policy: ShardPolicy,
    /// cross-request prefix caching: look admitted prompts up in the
    /// FTL's content-addressed index, attach the shared sealed token
    /// groups (refcounted, copy-on-write), and ship only the unique
    /// suffix.  Off keeps the engine bit-identical — outputs AND
    /// timestamps — to the pre-prefix-cache code path.
    pub prefix_cache: bool,
    /// scoped worker threads for the per-shard dispatch fan-out
    /// (`sim::par`); 1 = serial.  Any value produces bit-identical
    /// outputs, metrics and trace exports.
    pub threads: usize,
}

impl EngineConfig {
    /// Functional-plane default: micro flash geometry sized for the
    /// opt-micro model, in-storage dense attention, P2P on.
    pub fn micro(n_csds: usize) -> Self {
        let csd_spec = CsdSpec::micro();
        EngineConfig {
            n_csds,
            backend: AttnBackend::Csd(AttnMode::Dense),
            p2p: true,
            tier: TierConfig::for_spec(&csd_spec),
            csd_spec,
            shard_policy: ShardPolicy::HeadStripe,
            prefix_cache: false,
            threads: 1,
        }
    }

    /// The one shared functional-plane constructor for the CLI, the
    /// examples and the integration tests: micro CSD spec, `n_csds`
    /// devices, and the model's default SparF parameters when `sparse`.
    /// (Call sites used to hand-roll this; one helper keeps tier and
    /// sparsity defaults from drifting between tests and examples.)
    pub fn micro_for(meta: &ModelMeta, n_csds: usize, sparse: bool) -> Self {
        let cfg = EngineConfig::micro(n_csds);
        if sparse {
            cfg.sparse(meta.sparsity())
        } else {
            cfg
        }
    }

    pub fn sparse(mut self, sp: crate::config::model::SparsityParams) -> Self {
        self.backend = AttnBackend::Csd(AttnMode::SparF(sp));
        self
    }

    /// Enable the CSD-DRAM hot tier with an explicit capacity/policy.
    pub fn tiered(mut self, tier: TierConfig) -> Self {
        self.tier = tier;
        self
    }

    /// Pick the shard partition policy (head stripe by default).
    pub fn sharded(mut self, policy: ShardPolicy) -> Self {
        self.shard_policy = policy;
        self
    }

    /// Enable cross-request prefix caching (content-addressed,
    /// refcounted sealed KV token groups in the flash tier).
    pub fn prefix_cached(mut self, on: bool) -> Self {
        self.prefix_cache = on;
        self
    }

    /// Pick the flash KV data path (placement x read sched x pipelining)
    /// for every CSD in the array.  The micro default is the legacy
    /// channel/fifo/barrier path; `FlashPathConfig::tuned()` is the
    /// die-interleaved pipelined engine.
    pub fn flash_path(mut self, path: crate::config::hw::FlashPathConfig) -> Self {
        self.csd_spec.flash.path = path;
        self
    }

    /// Worker threads for the per-shard dispatch fan-out (0 resolves to
    /// the host's available parallelism).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = if n == 0 { crate::sim::par::available_threads() } else { n };
        self
    }

    /// Arm the deterministic fault plane for every CSD in the array
    /// (`FaultConfig::none()` keeps the engine bit-identical to the
    /// fault-free build).
    pub fn faults(mut self, f: crate::fault::FaultConfig) -> Self {
        self.csd_spec.fault = f;
        self
    }
}

pub struct InferenceEngine {
    pub rt: Runtime,
    pub cfg: EngineConfig,
    /// the CSD array behind its shard coordinator: per-device engine
    /// instances, local clocks, fair-share PCIe all-reduce
    pub shards: ShardCoordinator,
    pub metrics: EngineMetrics,
    /// simulated device clock
    pub sim_now: Time,
    /// host-side padded KV caches per layer (GpuArtifact backend only)
    host_kv: Vec<(HostTensor, HostTensor)>,
    host_kv_bucket: usize,
}

impl InferenceEngine {
    pub fn new(rt: Runtime, cfg: EngineConfig) -> Result<Self> {
        let m = &rt.manifest.model;
        if cfg.csd_spec.fault.kv_replicas > 0 {
            anyhow::ensure!(
                !cfg.prefix_cache,
                "--kv-replicas is incompatible with --prefix-cache \
                 (refcount-shared sealed groups are not mirrored)"
            );
        }
        let ftl_cfg = FtlConfig { d_head: m.d_head, m: m.m, n: m.n };
        let topology = ShardTopology::new(cfg.n_csds, cfg.shard_policy, m.n_heads, m.n);
        let mut shards = ShardCoordinator::new(
            topology,
            cfg.csd_spec,
            ftl_cfg,
            cfg.tier,
            PcieSpec::paper(),
            cfg.p2p,
            GpuSpec::a6000(),
        )?;
        shards.threads = cfg.threads.max(1);
        Ok(InferenceEngine {
            rt,
            cfg,
            shards,
            metrics: EngineMetrics::default(),
            sim_now: 0.0,
            host_kv: Vec::new(),
            host_kv_bucket: 0,
        })
    }

    /// The per-device NVMe queues behind the shard coordinator (flash
    /// counters, FTL statistics, tier state).
    pub fn csds(&self) -> &[NvmeQueue] {
        &self.shards.queues
    }

    fn model(&self) -> crate::runtime::manifest::ModelMeta {
        self.rt.manifest.model.clone()
    }

    /// Run prefill for a batch of sequences (prompts <= prefill_seq),
    /// advancing the engine clock past the KV shipping — the serialized
    /// executor's phase coupling.
    pub fn prefill(&mut self, seqs: &mut [Sequence], bucket: usize) -> Result<()> {
        let done = self.prefill_stage(seqs, bucket, self.sim_now)?;
        self.sim_now = self.sim_now.max(done);
        Ok(())
    }

    /// Stream-resumable prefill stage: GPU prefill blocks + layer-wise
    /// KV shipping, with all simulated time anchored at `start` and the
    /// engine clock left untouched — the caller owns the stream
    /// frontier (the pipelined executor runs this on the GPU prefill
    /// stream while decode ticks advance `sim_now` independently).
    /// Returns the ship-completion time.
    pub fn prefill_stage(
        &mut self,
        seqs: &mut [Sequence],
        bucket: usize,
        start: Time,
    ) -> Result<Time> {
        let m = self.model();
        let sp = m.prefill_seq;
        let b = seqs.len();
        if b > bucket {
            bail!("batch {b} exceeds bucket {bucket}");
        }
        for s in seqs.iter() {
            if s.req.prompt.is_empty() || s.req.prompt.len() > sp {
                bail!("prompt length {} outside 1..={sp}", s.req.prompt.len());
            }
        }
        let t0 = Instant::now();
        // causal edge: each request's track to the prefill stream that
        // serves its cohort
        for s in seqs.iter() {
            crate::obs::flow(
                "prefill_launch",
                crate::obs::TraceLevel::Device,
                (crate::obs::PID_REQUESTS, s.req.id, start),
                (crate::obs::PID_STREAMS, 0, start),
            );
        }

        // ids (bucket, sp) padded with 0
        let mut ids = vec![0i32; bucket * sp];
        for (i, s) in seqs.iter().enumerate() {
            ids[i * sp..i * sp + s.req.prompt.len()].copy_from_slice(&s.req.prompt);
        }
        let ids_t = HostTensor::i32(vec![bucket, sp], ids);
        let x = self.rt.call("embed_prefill", bucket, 0, &[ids_t])?.remove(0);

        // per-layer blocks; ship KV layer-wise (overlapped in sim time)
        let mut x = x;
        if matches!(self.cfg.backend, AttnBackend::GpuArtifact { .. }) {
            self.alloc_host_kv(bucket)?;
        }
        let mut ship_done = start;
        // attach cached prefixes before any suffix KV ships: the FIFO
        // submission queues serialize the metadata command ahead of the
        // layer-0 writes, aliasing the sealed shared groups into each
        // hit slot's stream mappings (refcounted, no page copies)
        if matches!(self.cfg.backend, AttnBackend::Csd(_)) {
            for s in seqs.iter() {
                if s.prefix_hit > 0 {
                    let _req = crate::obs::ReqScope::enter(s.req.id);
                    let t =
                        self.shards.attach_prefix(s.slot, &s.req.prompt, s.prefix_hit, start)?;
                    crate::obs::req_span(s.req.id, "prefix_attach", start, t);
                    ship_done = ship_done.max(t);
                }
            }
        }
        for layer in 0..m.n_layers {
            let mut outs = self.rt.call("prefill_block", bucket, layer, &[x])?;
            let v = outs.pop().unwrap();
            let k = outs.pop().unwrap();
            x = outs.pop().unwrap();
            // layer-wise pipeline: ship layer `layer` while the GPU computes
            // layer+1 — in sim time the ship for this layer starts now
            ship_done = ship_done.max(self.ship_prefill_kv(seqs, layer as u16, &k, &v, sp, start)?);
        }
        // seal + register every just-prefilled prompt in the
        // content-addressed index (metadata-only; the first registration
        // per boundary hash wins, so a donor that itself attached only
        // extends the index past its shared prefix).  Off the request's
        // critical path: the donor's TTFT does not wait on it.
        if self.cfg.prefix_cache && matches!(self.cfg.backend, AttnBackend::Csd(_)) {
            for s in seqs.iter() {
                self.shards.register_prefix(s.slot, &s.req.prompt, ship_done)?;
            }
        }

        // next-token logits from each sequence's last valid row
        let d = m.d_model;
        let xs = x.as_f32()?;
        let mut last = vec![0.0f32; bucket * d];
        for (i, s) in seqs.iter().enumerate() {
            let row = s.req.prompt.len() - 1;
            let base = (i * sp + row) * d;
            last[i * d..(i + 1) * d].copy_from_slice(&xs[base..base + d]);
        }
        let lg = self
            .rt
            .call("logits", bucket, 0, &[HostTensor::f32(vec![bucket, d], last)])?;
        let next = lg[1].as_i32()?;
        for (i, s) in seqs.iter_mut().enumerate() {
            s.generated.push(next[i]);
            s.kv_len = s.req.prompt.len();
            s.phase = RequestPhase::Decoding;
            self.metrics.prefill_tokens += (s.req.prompt.len() - s.prefix_hit) as u64;
            self.metrics.prefix_hit_tokens += s.prefix_hit as u64;
            self.metrics.tokens_generated += 1;
        }
        self.metrics.gpu_wall_s += t0.elapsed().as_secs_f64();
        Ok(ship_done)
    }

    fn alloc_host_kv(&mut self, bucket: usize) -> Result<()> {
        let m = self.model();
        self.host_kv = (0..m.n_layers)
            .map(|_| {
                (
                    HostTensor::zeros_f32(vec![bucket, m.n_heads, m.max_seq, m.d_head]),
                    HostTensor::zeros_f32(vec![bucket, m.n_heads, m.max_seq, m.d_head]),
                )
            })
            .collect();
        self.host_kv_bucket = bucket;
        Ok(())
    }

    /// Ship one prefill layer's KV to the CSD array (or host caches).
    /// `start` anchors the ship in simulated time (the owning stream's
    /// frontier; equals `sim_now` on the serialized path).
    #[allow(clippy::too_many_arguments)]
    fn ship_prefill_kv(
        &mut self,
        seqs: &[Sequence],
        layer: u16,
        k: &HostTensor,
        v: &HostTensor,
        sp: usize,
        start: Time,
    ) -> Result<Time> {
        let m = self.model();
        let (h, dh) = (m.n_heads, m.d_head);
        let kd = k.as_f32()?;
        let vd = v.as_f32()?;
        match self.cfg.backend {
            AttnBackend::GpuArtifact { .. } => {
                let (kc, vc) = &mut self.host_kv[layer as usize];
                let kcd = kc.as_f32_mut()?;
                let smax = m.max_seq;
                for (i, s) in seqs.iter().enumerate() {
                    for hh in 0..h {
                        for t in 0..s.req.prompt.len() {
                            let src = ((i * h + hh) * sp + t) * dh;
                            let dst = ((i * h + hh) * smax + t) * dh;
                            kcd[dst..dst + dh].copy_from_slice(&kd[src..src + dh]);
                        }
                    }
                }
                let vcd = vc.as_f32_mut()?;
                for (i, s) in seqs.iter().enumerate() {
                    for hh in 0..h {
                        for t in 0..s.req.prompt.len() {
                            let src = ((i * h + hh) * sp + t) * dh;
                            let dst = ((i * h + hh) * smax + t) * dh;
                            vcd[dst..dst + dh].copy_from_slice(&vd[src..src + dh]);
                        }
                    }
                }
                Ok(start)
            }
            AttnBackend::Csd(_) => {
                let t0 = Instant::now();
                let mut done = start;
                for (i, s) in seqs.iter().enumerate() {
                    let len = s.req.prompt.len();
                    let base = i * h * sp * dh;
                    let _req = crate::obs::ReqScope::enter(s.req.id);
                    let t = self.shards.prefill_layer(
                        s.slot,
                        layer,
                        sp,
                        len,
                        s.prefix_hit,
                        &kd[base..base + h * sp * dh],
                        &vd[base..base + h * sp * dh],
                        start,
                    )?;
                    crate::obs::req_span(s.req.id, "kv_ship", start, t);
                    done = done.max(t);
                }
                self.metrics.csd_wall_s += t0.elapsed().as_secs_f64();
                Ok(done)
            }
        }
    }

    /// One decode step over the batch; appends one token to every live
    /// sequence and advances the engine clock past the step's CSD work.
    /// `bucket` is the padded PJRT batch.
    pub fn decode_step(&mut self, seqs: &mut [Sequence], bucket: usize) -> Result<()> {
        let start = self.sim_now;
        let done = self.decode_stage(seqs, bucket, start)?;
        // advance the device clock past this step's CSD work
        self.sim_now = self.sim_now.max(done);
        self.metrics.decode_sim_s += self.sim_now - start;
        Ok(())
    }

    /// Stream-resumable decode stage: one decode tick anchored at
    /// `start`, engine clock untouched — the caller owns the decode
    /// stream's frontier.  Returns the step-completion time.
    pub fn decode_stage(
        &mut self,
        seqs: &mut [Sequence],
        bucket: usize,
        start: Time,
    ) -> Result<Time> {
        let m = self.model();
        let b = seqs.len();
        let t0 = Instant::now();

        let mut ids = vec![0i32; bucket];
        let mut pos = vec![0i32; bucket];
        for (i, s) in seqs.iter().enumerate() {
            ids[i] = s.current_token();
            pos[i] = (s.next_pos() as i32).min(m.max_seq as i32 - 1);
        }
        let x = self
            .rt
            .call(
                "embed_decode",
                bucket,
                0,
                &[HostTensor::i32(vec![bucket], ids), HostTensor::i32(vec![bucket], pos)],
            )?
            .remove(0);

        let mut x = x;
        let mut step_done = start;
        for layer in 0..m.n_layers {
            let mut qkv = self.rt.call("qkv_proj", bucket, layer, &[x.clone()])?;
            let v = qkv.pop().unwrap();
            let k = qkv.pop().unwrap();
            let q = qkv.pop().unwrap();

            let attn = match self.cfg.backend {
                AttnBackend::Csd(mode) => {
                    let t1 = Instant::now();
                    let lw = layer as u16;
                    let a = self
                        .csd_attention(seqs, lw, &q, &k, &v, mode, bucket, start, &mut step_done)?;
                    self.metrics.csd_wall_s += t1.elapsed().as_secs_f64();
                    a
                }
                AttnBackend::GpuArtifact { sparse } => {
                    self.gpu_attention(seqs, layer, &q, &k, &v, sparse, bucket)?
                }
            };
            let outs = self.rt.call("post_attn", bucket, layer, &[x, attn])?;
            x = outs.into_iter().next().unwrap();
        }

        let lg = self.rt.call("logits", bucket, 0, &[x])?;
        let next = lg[1].as_i32()?;
        for (i, s) in seqs.iter_mut().enumerate().take(b) {
            s.generated.push(next[i]);
            s.kv_len += 1;
            self.metrics.tokens_generated += 1;
        }
        self.metrics.decode_steps += 1;
        self.metrics.step_occupancy.push(b as f64);
        self.metrics.gpu_wall_s += t0.elapsed().as_secs_f64();
        Ok(step_done)
    }

    /// Smallest AOT batch bucket that fits `n` live sequences.
    pub fn bucket_for(&self, n: usize) -> usize {
        self.rt.manifest.bucket_for(n)
    }

    /// Largest AOT batch bucket — the hard cap on per-step batch size.
    pub fn max_bucket(&self) -> usize {
        self.rt.manifest.batch_buckets.last().copied().unwrap_or(1)
    }

    /// In-storage attention: write this token's k/v, then attend (the new
    /// token attends to itself, so length = kv_len + 1).  `start` is the
    /// decode stream's frontier for this step.
    #[allow(clippy::too_many_arguments)]
    fn csd_attention(
        &mut self,
        seqs: &[Sequence],
        layer: u16,
        q: &HostTensor,
        k: &HostTensor,
        v: &HostTensor,
        mode: AttnMode,
        bucket: usize,
        start: Time,
        step_done: &mut Time,
    ) -> Result<HostTensor> {
        let m = self.model();
        let (h, dh) = (m.n_heads, m.d_head);
        let qd = q.as_f32()?;
        let kd = k.as_f32()?;
        let vd = v.as_f32()?;
        let mut out = vec![0.0f32; bucket * h * dh];
        for (i, s) in seqs.iter().enumerate() {
            let _req = crate::obs::ReqScope::enter(s.req.id);
            let (gathered, done, bd) = self.shards.decode_token(
                s.slot,
                layer,
                &qd[i * h * dh..(i + 1) * h * dh],
                &kd[i * h * dh..(i + 1) * h * dh],
                &vd[i * h * dh..(i + 1) * h * dh],
                s.kv_len + 1,
                mode,
                start,
            )?;
            *step_done = step_done.max(done);
            self.metrics.units.merge(&bd);
            self.metrics.csd_sim_s += bd.total();
            out[i * h * dh..(i + 1) * h * dh].copy_from_slice(&gathered);
        }
        Ok(HostTensor::f32(vec![bucket, h, dh], out))
    }

    /// Ablation backend: attention via the PJRT artifacts over host caches.
    #[allow(clippy::too_many_arguments)]
    fn gpu_attention(
        &mut self,
        seqs: &[Sequence],
        layer: usize,
        q: &HostTensor,
        k: &HostTensor,
        v: &HostTensor,
        sparse: bool,
        bucket: usize,
    ) -> Result<HostTensor> {
        let m = self.model();
        let (h, dh, smax) = (m.n_heads, m.d_head, m.max_seq);
        if self.host_kv_bucket != bucket {
            self.alloc_host_kv(bucket)?;
        }
        let kd = k.as_f32()?.to_vec();
        let vd = v.as_f32()?.to_vec();
        {
            let (kc, vc) = &mut self.host_kv[layer];
            let kcd = kc.as_f32_mut()?;
            let vcd = vc.as_f32_mut()?;
            for (i, s) in seqs.iter().enumerate() {
                let t = s.kv_len.min(smax - 1);
                for hh in 0..h {
                    let src = (i * h + hh) * dh;
                    let dst = ((i * h + hh) * smax + t) * dh;
                    kcd[dst..dst + dh].copy_from_slice(&kd[src..src + dh]);
                    vcd[dst..dst + dh].copy_from_slice(&vd[src..src + dh]);
                }
            }
        }
        let mut lens = vec![1.0f32; bucket];
        for (i, s) in seqs.iter().enumerate() {
            lens[i] = (s.kv_len + 1) as f32;
        }
        let (kc, vc) = &self.host_kv[layer];
        let exe = if sparse { "attn_sparf" } else { "attn_dense" };
        let out = self.rt.call(
            exe,
            bucket,
            0,
            &[q.clone(), kc.clone(), vc.clone(), HostTensor::f32(vec![bucket], lens)],
        )?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Release a finished sequence's KV on every CSD.
    pub fn free_sequence(&mut self, seq: &Sequence) -> Result<()> {
        if matches!(self.cfg.backend, AttnBackend::Csd(_)) {
            self.sim_now = self.shards.free_slot(seq.slot, self.sim_now)?;
        }
        Ok(())
    }

    /// Longest indexed prefix (whole token groups) of `prompt` in the
    /// FTL's content-addressed index, in tokens; 0 when prefix caching
    /// is off or the backend is not the CSD array.  Pure lookup — with
    /// the feature off it performs no work at all, keeping prefix-off
    /// runs bit-identical to the pre-prefix-cache engine.
    pub fn prefix_match(&self, prompt: &[i32]) -> usize {
        if !self.cfg.prefix_cache || !matches!(self.cfg.backend, AttnBackend::Csd(_)) {
            return 0;
        }
        self.shards.prefix_match(prompt).min(prompt.len())
    }

    /// Cumulative per-token attention mass for `slot` in global token
    /// positions, summed across the CSD array (context shards report
    /// local indices, which the coordinator maps back).
    pub fn token_importance(&self, slot: u32) -> Vec<f32> {
        self.shards.token_importance(slot)
    }

    /// Drop token positions of `slot` on the owning CSDs: future
    /// attention masks them out, and fully-dropped token groups free
    /// their flash pages (the scheduler's H2O-style drop-on-resume).
    pub fn drop_tokens(&mut self, slot: u32, tokens: &[u32]) -> Result<()> {
        if tokens.is_empty() || !matches!(self.cfg.backend, AttnBackend::Csd(_)) {
            return Ok(());
        }
        self.sim_now = self.shards.drop_tokens(slot, tokens, self.sim_now)?;
        self.metrics.dropped_tokens += tokens.len() as u64;
        Ok(())
    }

    /// Whether any part of the fault plane is armed on this engine.
    pub fn fault_active(&self) -> bool {
        self.cfg.csd_spec.fault.any_active()
    }

    /// Device already dead at the engine clock, if any (CSD backend
    /// only — the ablation backend has no CSD array to lose).
    pub fn dead_device(&self) -> Option<usize> {
        if !matches!(self.cfg.backend, AttnBackend::Csd(_)) {
            return None;
        }
        self.shards.dead_device(self.sim_now)
    }

    /// Replace lost device `dev` and — under the replicated policy —
    /// restore its streams from the peer mirrors.  Advances the engine
    /// clock past the restore and returns the recovery wall window
    /// `(t0, t1)` for attribution.  Sequence-level consequences
    /// (aborts/restarts) are the scheduler's job.
    pub fn recover_lost_device(&mut self, dev: usize) -> Result<(Time, Time)> {
        let t0 = self.sim_now;
        crate::obs::device_instant(dev, "csd_loss", t0);
        self.shards.replace_device(dev)?;
        if self.shards.recovery_policy() == crate::fault::RecoveryPolicy::Replicated {
            let t = self.shards.restore_from_replica(dev, t0)?;
            self.sim_now = self.sim_now.max(t);
        }
        crate::obs::device_instant(dev, "recovery_done", self.sim_now);
        self.metrics.recovery_s += self.sim_now - t0;
        Ok((t0, self.sim_now))
    }

    /// Aggregate hot-tier statistics across the CSD array.
    pub fn tier_stats(&self) -> TierStats {
        self.shards.tier_stats()
    }

    /// Aggregate flash-array utilisation (die/channel busy, peak die
    /// queue depth) across the CSD array.
    pub fn flash_util(&self) -> crate::csd::FlashUtil {
        self.shards.flash_util()
    }

    /// Bytes currently resident in the hot tiers of all CSDs.
    pub fn tier_hot_bytes(&self) -> usize {
        self.shards.tier_hot_bytes()
    }

    /// Configured hot-tier capacity across all CSDs.
    pub fn tier_capacity_bytes(&self) -> usize {
        self.shards.tier_capacity_bytes()
    }

    /// Flash KV capacity across all CSDs (the cold tier's bound).
    pub fn kv_capacity_bytes(&self) -> u64 {
        self.shards.n_csds() as u64 * self.cfg.csd_spec.kv_capacity_bytes
    }

    /// Flash KV capacity of ONE CSD (each shard must individually fit
    /// its stripe — the aggregate bound alone can hide an overflowing
    /// device).
    pub fn kv_capacity_bytes_per_csd(&self) -> u64 {
        self.cfg.csd_spec.kv_capacity_bytes
    }

    /// Unified metric snapshot: folds the five historical accounting
    /// structs — `EngineMetrics` (`engine.*` / `units.*`), the merged
    /// per-CSD `BusyLedger` (`ledger.*`), `ShardStats` (`shard.*`),
    /// `OverlapStats` (`overlap.*`) and `FlashUtil` (`flash.*`) — into
    /// one deterministically-ordered [`crate::obs::MetricsRegistry`].
    /// This is what `--metrics-json` dumps and what the engine-backed
    /// bench rows read, so every surface reports the same numbers.
    pub fn metrics_registry(
        &self,
        overlap: &crate::pipeline::OverlapStats,
    ) -> crate::obs::MetricsRegistry {
        let mut r = crate::obs::MetricsRegistry::new();
        let m = &self.metrics;
        r.counter("engine.requests_done", m.requests_done);
        r.counter("engine.tokens_generated", m.tokens_generated);
        r.counter("engine.prefill_tokens", m.prefill_tokens);
        r.counter("engine.prefix_hit_tokens", m.prefix_hit_tokens);
        r.counter("engine.dropped_tokens", m.dropped_tokens);
        r.counter("engine.decode_steps", m.decode_steps);
        r.counter("engine.admissions", m.admissions);
        r.counter("engine.retirements", m.retirements);
        r.counter("engine.preemptions", m.preemptions);
        r.counter("engine.resumes", m.resumes);
        r.counter("engine.busy_steps", m.busy_steps);
        r.gauge("engine.gpu_wall_s", m.gpu_wall_s);
        r.gauge("engine.csd_wall_s", m.csd_wall_s);
        r.gauge("engine.csd_sim_s", m.csd_sim_s);
        r.gauge("engine.decode_sim_s", m.decode_sim_s);
        r.gauge("engine.busy_step_sim_s", m.busy_step_sim_s);
        r.gauge("engine.decode_step_time_s", m.decode_step_time_s());
        r.histogram("engine.step_occupancy", &m.step_occupancy);
        r.histogram("engine.batch_latency_s", &m.batch_latencies);
        let u = &m.units;
        r.gauge("units.argtopk_s", u.argtopk);
        r.gauge("units.flash_read_s", u.flash_read);
        r.gauge("units.dram_hit_s", u.dram_hit);
        r.gauge("units.nfc_filter_s", u.nfc_filter);
        r.gauge("units.logit0_s", u.logit0);
        r.gauge("units.logit_s", u.logit);
        r.gauge("units.attend_s", u.attend);
        r.gauge("units.writeback_s", u.writeback);
        r.gauge("units.pcie_xfer_s", u.pcie_xfer);
        r.gauge("units.gpu_merge_s", u.gpu_merge);
        let mut ledger = crate::sim::BusyLedger::default();
        for q in &self.shards.queues {
            ledger.merge(&q.csd.ledger);
        }
        // pre-seed every ledger component name at zero: `rows()` only
        // reports components that accrued time, which would make the
        // snapshot's name set config-dependent and break downstream
        // diffing/gating
        for name in [
            "argtopk",
            "dram_hit",
            "flash_chan_busy",
            "flash_die_busy",
            "flash_read",
            "kernel",
            "nfc_filter",
        ] {
            r.gauge(&format!("ledger.{name}_s"), 0.0);
        }
        for (name, secs, _frac) in ledger.rows() {
            r.gauge(&format!("ledger.{name}_s"), secs);
        }
        let st = &self.shards.stats;
        r.gauge("shard.attn_span_s", st.attn_span_s);
        r.gauge("shard.merge_span_s", st.merge_span_s);
        r.gauge("shard.xfer_bytes", st.xfer_bytes);
        r.counter("shard.merges", st.merges);
        r.gauge("shard.prefill_ship_bytes", st.prefill_ship_bytes);
        r.counter("shard.contended_merges", st.contended_merges);
        r.gauge("shard.contention_delay_s", st.contention_delay_s);
        // fault plane: pre-seeded (all zeros with faults off) so the
        // snapshot's name set stays config-independent
        let ft = self.shards.fault_totals();
        r.counter("fault.nvme_timeouts", ft.nvme_timeouts);
        r.gauge("fault.nvme_retry_s", ft.nvme_retry_s);
        r.counter("fault.flash_ecc_corrected", ft.flash_ecc_corrected);
        r.counter("fault.flash_read_retries", ft.flash_read_retries);
        r.counter("fault.flash_bad_blocks", ft.flash_bad_blocks);
        r.counter("fault.csd_losses", st.csd_losses);
        r.counter("fault.recoveries", st.recoveries);
        r.gauge("fault.replica_bytes", st.replica_bytes);
        r.gauge("fault.restore_bytes", st.restore_bytes);
        r.counter("fault.restarts", m.restarts);
        r.counter("fault.aborted_requests", m.aborted_requests);
        r.gauge("fault.recovery_s", m.recovery_s);
        r.gauge("overlap.prefill_busy_s", overlap.prefill_busy_s);
        r.gauge("overlap.decode_busy_s", overlap.decode_busy_s);
        r.gauge("overlap.overlapped_s", overlap.overlapped_s);
        r.gauge("overlap.gpu_idle_during_decode_s", overlap.gpu_idle_during_decode_s);
        r.counter("overlap.cohorts", overlap.cohorts);
        r.counter("overlap.steps_with_prefill_inflight", overlap.steps_with_prefill_inflight);
        let f = self.flash_util();
        r.gauge("flash.die_busy_s", f.die_busy_s);
        r.gauge("flash.channel_busy_s", f.channel_busy_s);
        r.gauge("flash.die_peak_depth", f.die_peak_depth as f64);
        r
    }

    /// Run a whole batch to completion: prefill, then decode until every
    /// sequence hits its token budget.  Returns the finished sequences.
    pub fn generate(&mut self, mut seqs: Vec<Sequence>, bucket: usize) -> Result<Vec<Sequence>> {
        let t0 = Instant::now();
        self.prefill(&mut seqs, bucket)?;
        let max_steps = seqs.iter().map(|s| s.req.max_new_tokens).max().unwrap_or(0);
        let m = self.model();
        for _ in 1..max_steps {
            // stop early if everyone is done or context exhausted
            if seqs.iter().all(|s| s.is_done()) {
                break;
            }
            if seqs.iter().any(|s| s.next_pos() >= m.max_seq) {
                break;
            }
            self.decode_step(&mut seqs, bucket)?;
        }
        for s in seqs.iter_mut() {
            s.finish();
            self.metrics.requests_done += 1;
        }
        for s in &seqs {
            self.free_sequence(s)?;
        }
        self.metrics.batch_latencies.push(t0.elapsed().as_secs_f64());
        Ok(seqs)
    }
}

// Micro CSD spec lives here to keep hw.rs paper-focused.
impl CsdSpec {
    /// Functional-plane CSD: geometry sized for the opt-micro model
    /// (512 B pages so n=8 token groups fill a page exactly; ~16 MB).
    /// The flash path defaults to legacy so the pinned functional-plane
    /// timing is unchanged; `EngineConfig::flash_path` / the CLI's
    /// `--flash-*` flags opt into the tuned die-interleaved path.
    pub fn micro() -> Self {
        let flash = FlashSpec {
            channels: 4,
            dies_per_channel: 2,
            planes_per_die: 1,
            blocks_per_plane: 64,
            pages_per_block: 64,
            page_bytes: 512,
            channel_bw: 1.4e9,
            read_us: 50.0,
            program_us: 600.0,
            erase_ms: 3.0,
            path: crate::config::hw::FlashPathConfig::legacy(),
        };
        CsdSpec {
            name: "micro-csd",
            flash,
            engine_flops: 768.0 * 285e6 * 2.0,
            clock_hz: 285e6,
            dram_bytes: 64 << 20,
            attn_kernels: 2,
            argtopk_elems_per_s: 285e6,
            filter_bw_per_channel: flash.channel_bw,
            // group buffers are an order of magnitude faster than the
            // aggregate flash channels; tiering is opted in per engine
            // (hot_tier_bytes 0 keeps the paper's flash-only baseline)
            dram_bw: 8e9,
            hot_tier_bytes: 0,
            kv_capacity_bytes: flash.usable_capacity_bytes() as u64,
            fault: crate::fault::FaultConfig::none(),
        }
    }
}
