//! Parse-once, validate-once serving configuration.
//!
//! `instinfer serve`, the `serve_online`/`serve_offline` examples and the
//! engine-backed benches all need the same ~20 knobs turned into five
//! config structs ([`EngineConfig`], [`SchedConfig`], `TierConfig`,
//! `ShardPolicy`, `FlashPathConfig`).  They used to hand-roll the
//! parsing and re-thread the same literals; [`ServeOpts`] is the single
//! surface: one flag-spec table ([`SERVE_FLAGS`]) drives parsing, the
//! generated usage string, and the README's CLI reference — so the
//! three can never drift apart.

use crate::config::hw::{FlashPathConfig, FlashPlacement, FlashReadSched};
use crate::coordinator::engine::EngineConfig;
use crate::coordinator::scheduler::SchedConfig;
use crate::kvtier::{TierConfig, TierPolicy};
use crate::obs::TraceLevel;
use crate::runtime::manifest::ModelMeta;
use crate::shard::ShardPolicy;
use crate::workload::LengthProfile;
use anyhow::{bail, Context, Result};
use std::fmt;

/// One serve flag: the canonical name (with leading `--`), an optional
/// alias, a value placeholder (`None` marks a boolean switch), the
/// default rendered in help text (empty = off/inherit), and a one-line
/// description.
pub struct FlagSpec {
    pub name: &'static str,
    pub alias: Option<&'static str>,
    pub value: Option<&'static str>,
    pub default: &'static str,
    pub help: &'static str,
}

/// The full `serve` flag table — the single source of truth for
/// [`ServeOpts::parse`], [`ServeOpts::usage_block`] and
/// [`ServeOpts::markdown_reference`].
pub const SERVE_FLAGS: &[FlagSpec] = &[
    FlagSpec {
        name: "--requests",
        alias: None,
        value: Some("N"),
        default: "8",
        help: "requests to serve",
    },
    FlagSpec {
        name: "--batch",
        alias: None,
        value: Some("B"),
        default: "4",
        help: "decode seats (max sequences per engine step)",
    },
    FlagSpec {
        name: "--gen",
        alias: Some("--steps"),
        value: Some("T"),
        default: "8",
        help: "new tokens per request",
    },
    FlagSpec {
        name: "--n-csds",
        alias: Some("--csds"),
        value: Some("K"),
        default: "2",
        help: "CSD devices each sequence is sharded across",
    },
    FlagSpec {
        name: "--sparse",
        alias: None,
        value: None,
        default: "",
        help: "SparF sparse in-storage attention (dense by default)",
    },
    FlagSpec {
        name: "--shard-policy",
        alias: None,
        value: Some("P"),
        default: "stripe",
        help: "KV partitioning: stripe|block (heads) or context (token \
               groups, log-sum-exp merge; dense only)",
    },
    FlagSpec {
        name: "--overlap",
        alias: None,
        value: None,
        default: "",
        help: "disaggregate prefill and decode onto two pipelined engine \
               streams (same outputs, decoupled TTFT)",
    },
    FlagSpec {
        name: "--profile",
        alias: None,
        value: Some("P"),
        default: "fixed",
        help: "prompt/output length profile: fixed|chat|qa",
    },
    FlagSpec {
        name: "--artifacts",
        alias: None,
        value: Some("DIR"),
        default: "artifacts",
        help: "AOT artifact directory",
    },
    FlagSpec {
        name: "--arrival-rate",
        alias: Some("--rate"),
        value: Some("R"),
        default: "",
        help: "open-loop Poisson arrivals at R req/s on the simulated \
               clock (absent = closed loop, all requests at t=0)",
    },
    FlagSpec {
        name: "--prefill-chunk",
        alias: None,
        value: Some("C"),
        default: "4",
        help: "max new admissions prefilled per step",
    },
    FlagSpec {
        name: "--slots",
        alias: None,
        value: Some("S"),
        default: "64",
        help: "KV slot capacity",
    },
    FlagSpec {
        name: "--hi-frac",
        alias: None,
        value: Some("F"),
        default: "0",
        help: "fraction of high-priority arrivals (exercises preemption)",
    },
    FlagSpec {
        name: "--hot-kib",
        alias: None,
        value: Some("N"),
        default: "0",
        help: "per-CSD DRAM hot-tier capacity in KiB (0 = flash only)",
    },
    FlagSpec {
        name: "--tier-policy",
        alias: None,
        value: Some("P"),
        default: "lru",
        help: "hot-tier admission/eviction policy: lru|h2o|pin[:W]",
    },
    FlagSpec {
        name: "--drop-on-resume",
        alias: None,
        value: None,
        default: "",
        help: "H2O-style importance drop when a preempted sequence resumes",
    },
    FlagSpec {
        name: "--resume-keep",
        alias: None,
        value: Some("K"),
        default: "0",
        help: "token budget kept per sequence by --drop-on-resume (0 = all)",
    },
    FlagSpec {
        name: "--flash-path",
        alias: None,
        value: Some("P"),
        default: "legacy",
        help: "flash KV data path: legacy (channel placement + fifo reads \
               + read barrier) or tuned (die-interleaved + conflict-aware \
               + pipelined)",
    },
    FlagSpec {
        name: "--flash-placement",
        alias: None,
        value: Some("P"),
        default: "",
        help: "override the page placement component: channel|die",
    },
    FlagSpec {
        name: "--flash-sched",
        alias: None,
        value: Some("P"),
        default: "",
        help: "override the read scheduler component: fifo|interleave",
    },
    FlagSpec {
        name: "--flash-pipeline",
        alias: None,
        value: None,
        default: "",
        help: "force read-compute pipelining on",
    },
    FlagSpec {
        name: "--flash-no-pipeline",
        alias: None,
        value: None,
        default: "",
        help: "force read-compute pipelining off",
    },
    FlagSpec {
        name: "--prefix-cache",
        alias: None,
        value: None,
        default: "",
        help: "cross-request prefix caching: content-addressed, refcounted \
               KV token groups shared in the flash tier; admitted prompts \
               split into cached prefix + unique suffix",
    },
    FlagSpec {
        name: "--share-ratio",
        alias: None,
        value: Some("F"),
        default: "0.5",
        help: "shared-prefix fraction of each prompt in the multi-turn \
               workload (with --prefix-cache)",
    },
    FlagSpec {
        name: "--fault-seed",
        alias: None,
        value: Some("S"),
        default: "0",
        help: "base seed of the deterministic per-device fault streams \
               (same seed + same --threads => same faults, bit-identical)",
    },
    FlagSpec {
        name: "--fault-rate",
        alias: None,
        value: Some("R"),
        default: "0",
        help: "per-operation fault probability for flash page reads and \
               NVMe commands (0 = fault plane off, bit-identical to the \
               fault-free engine)",
    },
    FlagSpec {
        name: "--recovery",
        alias: None,
        value: Some("P"),
        default: "reprefill",
        help: "post-CSD-loss KV recovery policy: retry (abort in-flight) \
               | reprefill (re-run lost prefills) | replicated (restore \
               from the peer mirror; needs --kv-replicas 1)",
    },
    FlagSpec {
        name: "--kv-replicas",
        alias: None,
        value: Some("N"),
        default: "0",
        help: "mirror sealed KV writes to N peer CSDs (0 or 1; needs \
               >= 2 CSDs, head sharding, and no --prefix-cache)",
    },
    FlagSpec {
        name: "--threads",
        alias: None,
        value: Some("N"),
        default: "1",
        help: "worker threads for the deterministic parallel executor: \
               per-CSD shard dispatch fans out on scoped threads between \
               all-reduce barriers (0 = all available cores); outputs, \
               metrics and trace digests are bit-identical for any value",
    },
    FlagSpec {
        name: "--trace",
        alias: None,
        value: Some("FILE"),
        default: "",
        help: "write a Chrome trace-event JSON of the run (load in \
               Perfetto); observational only — outputs and simulated \
               timestamps are bit-identical with tracing off",
    },
    FlagSpec {
        name: "--trace-level",
        alias: None,
        value: Some("L"),
        default: "device",
        help: "trace verbosity: request (lifecycle spans), device (+ \
               streams, NVMe, PCIe, GC), full (+ per-(channel,die) flash \
               FIFOs)",
    },
    FlagSpec {
        name: "--metrics-json",
        alias: None,
        value: Some("FILE"),
        default: "",
        help: "dump the unified metrics registry (engine/ledger/shard/\
               overlap/flash) as one deterministic JSON snapshot",
    },
    FlagSpec {
        name: "--attr-json",
        alias: None,
        value: Some("FILE"),
        default: "",
        help: "dump per-request critical-path latency attribution \
               (instinfer-attr/v1: exclusive buckets summing to wall \
               time, split e2e/TTFT/decode); observational only",
    },
];

fn default_of(name: &str) -> &'static str {
    SERVE_FLAGS
        .iter()
        .find(|f| f.name == name)
        .map(|f| f.default)
        .unwrap_or("")
}

fn parse_profile(s: &str) -> Result<LengthProfile> {
    Ok(match s {
        "fixed" => LengthProfile::Fixed,
        "chat" => LengthProfile::Chat,
        "qa" => LengthProfile::Qa,
        other => bail!("unknown profile {other:?} (fixed|chat|qa)"),
    })
}

fn profile_label(p: LengthProfile) -> &'static str {
    match p {
        LengthProfile::Fixed => "fixed",
        LengthProfile::Chat => "chat",
        LengthProfile::Qa => "qa",
    }
}

/// Everything `serve` needs, parsed and validated exactly once.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    pub requests: usize,
    pub batch: usize,
    pub gen: usize,
    pub n_csds: usize,
    pub sparse: bool,
    pub shard_policy: ShardPolicy,
    pub overlap: bool,
    pub profile: LengthProfile,
    pub artifacts: String,
    pub arrival_rate: Option<f64>,
    pub prefill_chunk: usize,
    pub slots: usize,
    pub hi_frac: f64,
    pub hot_kib: usize,
    pub tier_policy: TierPolicy,
    pub drop_on_resume: bool,
    pub resume_keep: usize,
    pub flash_path: FlashPathConfig,
    pub prefix_cache: bool,
    pub share_ratio: f64,
    /// deterministic fault plane (seed/rate/recovery/replication;
    /// `FaultConfig::none()` when every knob is at its default)
    pub fault: crate::fault::FaultConfig,
    /// worker threads for the parallel deterministic executor (resolved:
    /// `--threads 0` already expanded to the available cores)
    pub threads: usize,
    /// trace output path (None = tracing off)
    pub trace: Option<String>,
    pub trace_level: TraceLevel,
    /// unified metrics snapshot output path (None = no dump)
    pub metrics_json: Option<String>,
    /// latency-attribution report output path (None = attribution off)
    pub attr_json: Option<String>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts::parse(&[]).expect("the flag table's defaults must parse")
    }
}

impl ServeOpts {
    /// Parse a serve argument list against [`SERVE_FLAGS`].  Unknown
    /// flags, missing values and invalid combinations (e.g. `--sparse`
    /// with `--shard-policy context`) are rejected here, once.
    pub fn parse(args: &[String]) -> Result<ServeOpts> {
        let mut seen: Vec<(&'static str, String)> = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = args[i].as_str();
            let Some(spec) =
                SERVE_FLAGS.iter().find(|f| f.name == a || f.alias == Some(a))
            else {
                bail!("unknown serve flag {a:?} (run with no args for usage)");
            };
            match spec.value {
                None => {
                    seen.push((spec.name, String::from("true")));
                    i += 1;
                }
                Some(_) => {
                    let Some(v) = args.get(i + 1) else {
                        bail!("flag {} needs a value", spec.name);
                    };
                    seen.push((spec.name, v.clone()));
                    i += 2;
                }
            }
        }
        let get = |name: &str| -> Option<&str> {
            seen.iter().rev().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
        };
        let has = |name: &str| get(name).is_some();
        let val = |name: &str| -> &str { get(name).unwrap_or_else(|| default_of(name)) };

        let requests: usize = val("--requests").parse().context("--requests")?;
        let batch: usize = val("--batch").parse().context("--batch")?;
        let gen: usize = val("--gen").parse().context("--gen")?;
        let n_csds: usize = val("--n-csds").parse().context("--n-csds")?;
        if n_csds == 0 {
            bail!("--n-csds must be >= 1");
        }
        let sparse = has("--sparse");
        let shard_policy = ShardPolicy::parse(val("--shard-policy"))?;
        if sparse && shard_policy == ShardPolicy::Context {
            bail!("--shard-policy context supports dense attention only (drop --sparse)");
        }
        let overlap = has("--overlap");
        let profile = parse_profile(val("--profile"))?;
        let artifacts = val("--artifacts").to_string();
        let arrival_rate: Option<f64> = match get("--arrival-rate") {
            Some(v) => {
                let r: f64 = v.parse().context("--arrival-rate")?;
                if r <= 0.0 {
                    bail!("--arrival-rate must be > 0");
                }
                Some(r)
            }
            None => None,
        };
        let prefill_chunk: usize = val("--prefill-chunk").parse().context("--prefill-chunk")?;
        let slots: usize = val("--slots").parse().context("--slots")?;
        let hi_frac: f64 = val("--hi-frac").parse().context("--hi-frac")?;
        let hot_kib: usize = val("--hot-kib").parse().context("--hot-kib")?;
        let tier_policy = TierPolicy::parse(val("--tier-policy"))?;
        let drop_on_resume = has("--drop-on-resume");
        let resume_keep: usize = val("--resume-keep").parse().context("--resume-keep")?;
        let mut flash_path = match get("--flash-path") {
            Some(v) => FlashPathConfig::parse(v)?,
            None => FlashPathConfig::legacy(),
        };
        if let Some(v) = get("--flash-placement") {
            flash_path.placement = FlashPlacement::parse(v)?;
        }
        if let Some(v) = get("--flash-sched") {
            flash_path.sched = FlashReadSched::parse(v)?;
        }
        if has("--flash-pipeline") {
            flash_path.pipeline = true;
        }
        if has("--flash-no-pipeline") {
            flash_path.pipeline = false;
        }
        let prefix_cache = has("--prefix-cache");
        let share_ratio: f64 = val("--share-ratio").parse().context("--share-ratio")?;
        if !(0.0..=1.0).contains(&share_ratio) {
            bail!("--share-ratio must be in [0, 1]");
        }
        let fault_seed: u64 = val("--fault-seed").parse().context("--fault-seed")?;
        let fault_rate: f64 = val("--fault-rate").parse().context("--fault-rate")?;
        if !(0.0..=1.0).contains(&fault_rate) {
            bail!("--fault-rate must be in [0, 1]");
        }
        let recovery = crate::fault::RecoveryPolicy::parse(val("--recovery"))?;
        let kv_replicas: u8 = val("--kv-replicas").parse().context("--kv-replicas")?;
        if kv_replicas > 1 {
            bail!("--kv-replicas supports 0 or 1 (one peer mirror per stream)");
        }
        if kv_replicas > 0 {
            if n_csds < 2 {
                bail!("--kv-replicas needs --n-csds >= 2 (the mirror lives on a peer CSD)");
            }
            if shard_policy == ShardPolicy::Context {
                bail!("--kv-replicas supports head sharding only (stripe|block)");
            }
            if prefix_cache {
                bail!(
                    "--kv-replicas is incompatible with --prefix-cache \
                     (refcount-shared sealed groups are not mirrored)"
                );
            }
        }
        if recovery == crate::fault::RecoveryPolicy::Replicated && kv_replicas == 0 {
            bail!("--recovery replicated needs --kv-replicas 1");
        }
        let fault = crate::fault::FaultConfig {
            seed: fault_seed,
            rate: fault_rate,
            csd_loss: None,
            recovery,
            kv_replicas,
        };
        let threads_raw: usize = val("--threads").parse().context("--threads")?;
        let threads = if threads_raw == 0 {
            crate::sim::par::available_threads()
        } else {
            threads_raw
        };
        let trace = get("--trace").filter(|v| !v.is_empty()).map(String::from);
        let trace_level = TraceLevel::parse(val("--trace-level"))?;
        let metrics_json = get("--metrics-json").filter(|v| !v.is_empty()).map(String::from);
        let attr_json = get("--attr-json").filter(|v| !v.is_empty()).map(String::from);

        Ok(ServeOpts {
            requests,
            batch,
            gen,
            n_csds,
            sparse,
            shard_policy,
            overlap,
            profile,
            artifacts,
            arrival_rate,
            prefill_chunk,
            slots,
            hi_frac,
            hot_kib,
            tier_policy,
            drop_on_resume,
            resume_keep,
            flash_path,
            prefix_cache,
            share_ratio,
            fault,
            threads,
            trace,
            trace_level,
            metrics_json,
            attr_json,
        })
    }

    /// The engine-side config: micro functional plane + tier + shard
    /// policy + flash path + prefix caching, exactly as `serve` has
    /// always built it.
    pub fn engine_config(&self, meta: &ModelMeta) -> EngineConfig {
        EngineConfig::micro_for(meta, self.n_csds, self.sparse)
            .tiered(TierConfig { hot_bytes: self.hot_kib * 1024, policy: self.tier_policy })
            .sharded(self.shard_policy)
            .flash_path(self.flash_path)
            .prefix_cached(self.prefix_cache)
            .faults(self.fault)
            .threads(self.threads)
    }

    /// The scheduler-side config (seats, chunked prefill, slots,
    /// drop-on-resume, overlapped executor).
    pub fn sched_config(&self) -> SchedConfig {
        SchedConfig {
            drop_on_resume: self.drop_on_resume,
            resume_keep: self.resume_keep,
            ..SchedConfig::serving(self.batch, self.prefill_chunk, self.slots)
                .overlapped(self.overlap)
        }
    }

    /// The `serve` section of the CLI usage text, generated from
    /// [`SERVE_FLAGS`] so a new flag can never be missing from help.
    pub fn usage_block() -> String {
        let mut out = String::new();
        for f in SERVE_FLAGS {
            let head = match (f.value, f.alias) {
                (Some(v), Some(a)) => format!("{} {v}  ({a})", f.name),
                (Some(v), None) => format!("{} {v}", f.name),
                (None, Some(a)) => format!("{}  ({a})", f.name),
                (None, None) => f.name.to_string(),
            };
            let default = if f.default.is_empty() {
                String::new()
            } else {
                format!(" [default {}]", f.default)
            };
            out.push_str(&format!("    {head:<32} {}{default}\n", f.help));
        }
        out
    }

    /// Markdown table of every serve flag (the README's CLI reference).
    pub fn markdown_reference() -> String {
        let mut out =
            String::from("| flag | default | description |\n| --- | --- | --- |\n");
        for f in SERVE_FLAGS {
            let flag = match f.value {
                Some(v) => format!("`{} {v}`", f.name),
                None => format!("`{}`", f.name),
            };
            let alias = match f.alias {
                Some(a) => format!(" (alias `{a}`)"),
                None => String::new(),
            };
            let default = if f.default.is_empty() {
                "—".to_string()
            } else {
                format!("`{}`", f.default)
            };
            // bare | would split the markdown cell
            let help = f.help.replace('|', "\\|");
            out.push_str(&format!("| {flag}{alias} | {default} | {help} |\n"));
        }
        out
    }
}

impl fmt::Display for ServeOpts {
    /// One summary header line for serve runs.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mode = match self.arrival_rate {
            Some(r) => format!("open-loop {r} req/s, hi-frac {}", self.hi_frac),
            None => "closed-loop".to_string(),
        };
        let tier = if self.hot_kib == 0 {
            "off".to_string()
        } else {
            format!("{} KiB {}", self.hot_kib, self.tier_policy.label())
        };
        write!(
            f,
            "serve: {} requests x {} tokens ({} profile, {mode}), {} seats / \
             chunk {} / {} slots, {} CSD(s) [{}], {} attention, flash {}, tier {}",
            self.requests,
            self.gen,
            profile_label(self.profile),
            self.batch,
            self.prefill_chunk,
            self.slots,
            self.n_csds,
            self.shard_policy.label(),
            if self.sparse { "SparF" } else { "dense" },
            self.flash_path.label(),
            tier,
        )?;
        if self.overlap {
            write!(f, ", overlapped streams")?;
        }
        if self.threads > 1 {
            write!(f, ", {} worker threads", self.threads)?;
        }
        if self.drop_on_resume {
            write!(f, ", drop-on-resume keep {}", self.resume_keep)?;
        }
        if self.prefix_cache {
            write!(f, ", prefix-cache (share ratio {:.2})", self.share_ratio)?;
        }
        if self.fault.any_active() {
            write!(
                f,
                ", faults (seed {} rate {} recovery {} replicas {})",
                self.fault.seed,
                self.fault.rate,
                self.fault.recovery.label(),
                self.fault.kv_replicas,
            )?;
        }
        if let Some(p) = &self.trace {
            write!(f, ", trace {} -> {p}", self.trace_level.label())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_match_flag_table() {
        let o = ServeOpts::default();
        assert_eq!(o.requests, 8);
        assert_eq!(o.batch, 4);
        assert_eq!(o.gen, 8);
        assert_eq!(o.n_csds, 2);
        assert!(!o.sparse && !o.overlap && !o.prefix_cache && !o.drop_on_resume);
        assert_eq!(o.arrival_rate, None);
        assert_eq!(o.slots, 64);
        assert_eq!(o.share_ratio, 0.5);
        assert_eq!(o.artifacts, "artifacts");
        assert_eq!(o.trace, None);
        assert_eq!(o.trace_level, TraceLevel::Device);
        assert_eq!(o.metrics_json, None);
        assert_eq!(o.attr_json, None);
        assert_eq!(o.threads, 1);
    }

    #[test]
    fn threads_flag_parses_and_resolves_zero() {
        let o = ServeOpts::parse(&sv(&["--threads", "4"])).unwrap();
        assert_eq!(o.threads, 4);
        assert!(o.to_string().contains("4 worker threads"));
        let o = ServeOpts::parse(&sv(&["--threads", "0"])).unwrap();
        assert!(o.threads >= 1, "--threads 0 resolves to available cores");
        assert!(ServeOpts::parse(&sv(&["--threads", "-1"])).is_err());
        let meta = crate::runtime::native::micro_meta();
        let ec = ServeOpts::parse(&sv(&["--threads", "8"])).unwrap().engine_config(&meta);
        assert_eq!(ec.threads, 8);
    }

    #[test]
    fn trace_flags_parse_and_validate() {
        let o = ServeOpts::parse(&sv(&[
            "--trace", "out.json", "--trace-level", "full", "--metrics-json", "m.json",
            "--attr-json", "a.json",
        ]))
        .unwrap();
        assert_eq!(o.trace.as_deref(), Some("out.json"));
        assert_eq!(o.trace_level, TraceLevel::Full);
        assert_eq!(o.metrics_json.as_deref(), Some("m.json"));
        assert_eq!(o.attr_json.as_deref(), Some("a.json"));
        assert!(ServeOpts::parse(&sv(&["--trace-level", "verbose"])).is_err());
    }

    #[test]
    fn aliases_and_last_write_wins() {
        let o = ServeOpts::parse(&sv(&[
            "--steps", "12", "--csds", "3", "--rate", "100", "--requests", "4",
            "--requests", "6",
        ]))
        .unwrap();
        assert_eq!(o.gen, 12);
        assert_eq!(o.n_csds, 3);
        assert_eq!(o.arrival_rate, Some(100.0));
        assert_eq!(o.requests, 6, "later occurrence must win");
    }

    #[test]
    fn invalid_combinations_rejected_once() {
        let e = ServeOpts::parse(&sv(&["--sparse", "--shard-policy", "context"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("dense attention only"), "{e}");
        assert!(ServeOpts::parse(&sv(&["--bogus"])).is_err());
        assert!(ServeOpts::parse(&sv(&["--requests"])).is_err(), "missing value");
        assert!(ServeOpts::parse(&sv(&["--share-ratio", "1.5"])).is_err());
        assert!(ServeOpts::parse(&sv(&["--arrival-rate", "0"])).is_err());
        assert!(ServeOpts::parse(&sv(&["--n-csds", "0"])).is_err());
    }

    #[test]
    fn fault_flags_parse_and_validate() {
        use crate::fault::RecoveryPolicy;
        let o = ServeOpts::default();
        assert!(!o.fault.any_active(), "default serve is fault-free");
        let o = ServeOpts::parse(&sv(&[
            "--fault-seed", "9", "--fault-rate", "0.01", "--recovery", "replicated",
            "--kv-replicas", "1",
        ]))
        .unwrap();
        assert_eq!(o.fault.seed, 9);
        assert_eq!(o.fault.rate, 0.01);
        assert_eq!(o.fault.recovery, RecoveryPolicy::Replicated);
        assert_eq!(o.fault.kv_replicas, 1);
        assert!(o.to_string().contains("recovery replicated"));
        let meta = crate::runtime::native::micro_meta();
        assert_eq!(o.engine_config(&meta).csd_spec.fault, o.fault);
        // invalid combinations are caught at parse time, once
        assert!(ServeOpts::parse(&sv(&["--fault-rate", "1.5"])).is_err());
        assert!(ServeOpts::parse(&sv(&["--kv-replicas", "2"])).is_err());
        assert!(ServeOpts::parse(&sv(&["--recovery", "replicated"])).is_err());
        assert!(ServeOpts::parse(&sv(&["--recovery", "bogus"])).is_err());
        assert!(ServeOpts::parse(&sv(&["--kv-replicas", "1", "--n-csds", "1"])).is_err());
        assert!(ServeOpts::parse(&sv(&[
            "--kv-replicas", "1", "--shard-policy", "context"
        ]))
        .is_err());
        assert!(ServeOpts::parse(&sv(&["--kv-replicas", "1", "--prefix-cache"])).is_err());
    }

    #[test]
    fn flash_component_overrides_compose() {
        let o = ServeOpts::parse(&sv(&["--flash-path", "tuned", "--flash-no-pipeline"]))
            .unwrap();
        assert!(!o.flash_path.pipeline);
        let o = ServeOpts::parse(&sv(&["--flash-pipeline"])).unwrap();
        assert!(o.flash_path.pipeline, "component override without --flash-path");
    }

    #[test]
    fn generated_help_covers_every_flag() {
        let usage = ServeOpts::usage_block();
        let md = ServeOpts::markdown_reference();
        for f in SERVE_FLAGS {
            assert!(usage.contains(f.name), "usage missing {}", f.name);
            assert!(md.contains(f.name), "markdown reference missing {}", f.name);
        }
        // the Display header mentions the load mode and backend shape
        let s = ServeOpts::default().to_string();
        assert!(s.contains("closed-loop") && s.contains("2 CSD(s)"), "{s}");
        let o =
            ServeOpts::parse(&sv(&["--prefix-cache", "--share-ratio", "0.75"])).unwrap();
        assert!(o.to_string().contains("share ratio 0.75"));
    }

    #[test]
    fn builds_engine_and_sched_configs() {
        use crate::coordinator::engine::AttnBackend;
        let meta = crate::runtime::native::micro_meta();
        let o = ServeOpts::parse(&sv(&[
            "--prefix-cache", "--overlap", "--batch", "6", "--slots", "16",
            "--drop-on-resume", "--resume-keep", "8",
        ]))
        .unwrap();
        let ec = o.engine_config(&meta);
        assert!(ec.prefix_cache);
        assert!(matches!(ec.backend, AttnBackend::Csd(_)));
        let sc = o.sched_config();
        assert!(sc.overlap && sc.drop_on_resume);
        assert_eq!((sc.max_batch, sc.slots, sc.resume_keep), (6, 16, 8));
    }
}
