//! Request / sequence state machine.

pub use crate::workload::Request;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestPhase {
    Queued,
    Prefilling,
    Decoding,
    /// Preempted out of the running batch; KV stays resident on flash
    /// under the sequence's slot, so resuming needs no re-prefill.
    Preempted,
    Finished,
}

/// A request admitted into the engine, bound to a KV slot.
#[derive(Debug, Clone)]
pub struct Sequence {
    pub req: Request,
    pub slot: u32,
    pub phase: RequestPhase,
    /// tokens currently represented in the KV cache
    pub kv_len: usize,
    /// positions < kv_len masked out by drop-on-resume (their KV pages
    /// are freed group-wise; positions themselves are preserved)
    pub dropped: std::collections::BTreeSet<u32>,
    /// prompt tokens covered by an attached cached prefix (set at
    /// admission from the FTL's content-addressed index; prefill ships
    /// KV only for positions >= prefix_hit)
    pub prefix_hit: usize,
    pub generated: Vec<i32>,
}

impl Sequence {
    pub fn new(req: Request, slot: u32) -> Self {
        Sequence {
            req,
            slot,
            phase: RequestPhase::Queued,
            kv_len: 0,
            dropped: std::collections::BTreeSet::new(),
            prefix_hit: 0,
            generated: Vec::new(),
        }
    }

    /// Absolute position of the next token to be decoded.
    pub fn next_pos(&self) -> usize {
        self.req.prompt.len() + self.generated.len()
    }

    /// The token fed into the next decode step (last prompt token before
    /// any generation, then the most recent generated token).
    pub fn current_token(&self) -> i32 {
        *self.generated.last().unwrap_or_else(|| {
            self.req.prompt.last().expect("prompt must be non-empty")
        })
    }

    pub fn is_done(&self) -> bool {
        self.generated.len() >= self.req.max_new_tokens
    }

    pub fn finish(&mut self) {
        self.phase = RequestPhase::Finished;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(prompt: usize, maxnew: usize) -> Request {
        Request { id: 1, prompt: (0..prompt as i32).collect(), max_new_tokens: maxnew }
    }

    #[test]
    fn sequence_lifecycle() {
        let mut s = Sequence::new(req(4, 2), 7);
        assert_eq!(s.phase, RequestPhase::Queued);
        assert_eq!(s.current_token(), 3);
        assert_eq!(s.next_pos(), 4);
        s.generated.push(42);
        assert_eq!(s.current_token(), 42);
        assert_eq!(s.next_pos(), 5);
        assert!(!s.is_done());
        s.generated.push(43);
        assert!(s.is_done());
    }
}
