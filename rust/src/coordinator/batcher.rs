//! Offline batch former: groups queued requests into decode batches sized
//! to the AOT batch buckets (the throughput-oriented policy of the paper's
//! offline setting — fill the largest bucket that has work).
//!
//! This is the drain-the-queue baseline: a batch, once formed, runs to
//! completion.  Online serving goes through [`super::scheduler`] instead,
//! where batch membership is revisited every engine step.

use crate::workload::Request;
use std::collections::VecDeque;

#[derive(Debug)]
pub struct OfflineBatcher {
    queue: VecDeque<Request>,
    buckets: Vec<usize>,
    max_batch: usize,
}

impl OfflineBatcher {
    /// `buckets` must be ascending (the manifest's batch buckets).
    pub fn new(buckets: Vec<usize>, max_batch: usize) -> Self {
        assert!(!buckets.is_empty());
        assert!(buckets.windows(2).all(|w| w[0] < w[1]));
        OfflineBatcher { queue: VecDeque::new(), buckets, max_batch }
    }

    pub fn push(&mut self, r: Request) {
        self.queue.push_back(r);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Form the next batch: as many requests as fit the largest bucket
    /// <= min(queue length rounded up to a bucket, max_batch).
    /// Returns (requests, bucket) — the batch may be smaller than the
    /// bucket (the engine pads), but never larger.
    pub fn next_batch(&mut self) -> Option<(Vec<Request>, usize)> {
        if self.queue.is_empty() {
            return None;
        }
        let want = self.queue.len().min(self.max_batch);
        // smallest bucket that fits `want`, else the largest bucket
        let bucket = self
            .buckets
            .iter()
            .copied()
            .find(|&b| b >= want)
            .unwrap_or(*self.buckets.last().unwrap());
        let take = want.min(bucket);
        let reqs = self.queue.drain(..take).collect();
        Some((reqs, bucket))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reqs(n: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request { id: i as u64, prompt: vec![1], max_new_tokens: 1 })
            .collect()
    }

    #[test]
    fn batches_fill_buckets() {
        let mut b = OfflineBatcher::new(vec![1, 4, 8], 8);
        for r in reqs(11) {
            b.push(r);
        }
        let (r1, bk1) = b.next_batch().unwrap();
        assert_eq!((r1.len(), bk1), (8, 8));
        let (r2, bk2) = b.next_batch().unwrap();
        assert_eq!((r2.len(), bk2), (3, 4)); // remainder padded into bucket 4
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = OfflineBatcher::new(vec![1, 4, 8], 4);
        for r in reqs(9) {
            b.push(r);
        }
        let (r1, bk1) = b.next_batch().unwrap();
        assert_eq!((r1.len(), bk1), (4, 4));
    }

    #[test]
    fn single_request_uses_smallest_bucket() {
        let mut b = OfflineBatcher::new(vec![1, 4, 8], 8);
        b.push(reqs(1).pop().unwrap());
        let (r, bk) = b.next_batch().unwrap();
        assert_eq!((r.len(), bk), (1, 1));
    }
}
