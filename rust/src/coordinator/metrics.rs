//! Engine accounting: wall-clock (host-measured) + simulated-device time.
//!
//! The functional plane runs the micro model for real, so the interesting
//! numbers are split: PJRT wall time (the "GPU"), simulated CSD time (the
//! DES), and the per-unit breakdown the CSD engines report.

use crate::csd::UnitBreakdown;
use crate::sim::Time;

#[derive(Debug, Default, Clone)]
pub struct EngineMetrics {
    pub requests_done: u64,
    pub tokens_generated: u64,
    pub prefill_tokens: u64,
    pub decode_steps: u64,
    /// host wall time in the PJRT executables
    pub gpu_wall_s: f64,
    /// host wall time in the rust CSD engines (functional compute)
    pub csd_wall_s: f64,
    /// simulated device-time accumulated on the CSDs
    pub csd_sim_s: Time,
    /// per-unit simulated breakdown (Fig. 16 numerator)
    pub units: UnitBreakdown,
    /// per-batch latencies (seconds, wall)
    pub batch_latencies: Vec<f64>,
}

impl EngineMetrics {
    pub fn throughput_tok_per_wall_s(&self) -> f64 {
        let wall = self.gpu_wall_s + self.csd_wall_s;
        if wall == 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / wall
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} tokens={} prefill_toks={} steps={} gpu_wall={:.3}s \
             csd_wall={:.3}s csd_sim={:.6}s tput={:.1} tok/s(wall)",
            self.requests_done,
            self.tokens_generated,
            self.prefill_tokens,
            self.decode_steps,
            self.gpu_wall_s,
            self.csd_wall_s,
            self.csd_sim_s,
            self.throughput_tok_per_wall_s(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_guarded_against_zero() {
        let m = EngineMetrics::default();
        assert_eq!(m.throughput_tok_per_wall_s(), 0.0);
        let m = EngineMetrics { tokens_generated: 10, gpu_wall_s: 2.0, ..Default::default() };
        assert_eq!(m.throughput_tok_per_wall_s(), 5.0);
        assert!(m.report().contains("tokens=10"));
    }
}
