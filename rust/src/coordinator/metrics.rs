//! Engine accounting: wall-clock (host-measured) + simulated-device time.
//!
//! The functional plane runs the micro model for real, so the interesting
//! numbers are split: PJRT wall time (the "GPU"), simulated CSD time (the
//! DES), and the per-unit breakdown the CSD engines report.
//!
//! Continuous batching adds per-step occupancy and request-churn counters
//! (admissions / retirements / preemptions / resumes) — batch membership
//! is a per-step decision, so "how full was each step" becomes a
//! first-class serving metric.
//!
//! The disaggregated executor adds the decode-step-time ledger
//! (`busy_steps`/`busy_step_sim_s`): the simulated span of every
//! scheduler step that decoded, admission stalls included.  On the
//! serialized path that span contains the co-scheduled cohort's prefill
//! + KV shipping; with `--overlap` it is the decode stream alone — the
//! decoupling `bench overlap` measures.  TTFT under overlap is stamped
//! when the prefill STREAM finishes a cohort (`admitted_at` /
//! `first_token_at` in the scheduler), never at the end of the decode
//! step that happens to absorb it.

use crate::csd::UnitBreakdown;
use crate::obs::SampleStats;
use crate::sim::Time;

#[derive(Debug, Default, Clone)]
pub struct EngineMetrics {
    pub requests_done: u64,
    pub tokens_generated: u64,
    pub prefill_tokens: u64,
    /// prompt tokens covered by an attached cached prefix (skipped from
    /// `prefill_tokens` — neither shipped over PCIe nor programmed)
    pub prefix_hit_tokens: u64,
    pub decode_steps: u64,
    /// host wall time in the PJRT executables
    pub gpu_wall_s: f64,
    /// host wall time in the rust CSD engines (functional compute)
    pub csd_wall_s: f64,
    /// simulated device-time accumulated on the CSDs
    pub csd_sim_s: Time,
    /// simulated device-time spent inside decode steps (clock delta per
    /// step; the tier bench's denominator)
    pub decode_sim_s: Time,
    /// token positions dropped by H2O-style drop-on-resume
    pub dropped_tokens: u64,
    /// per-unit simulated breakdown (Fig. 16 numerator)
    pub units: UnitBreakdown,
    /// per-batch latencies (seconds, wall) — capped streaming reservoir
    /// so long open-loop runs don't grow memory with step count
    pub batch_latencies: SampleStats,
    // ---- continuous-batching churn ------------------------------------
    /// sequences admitted into the running batch (chunked prefill done)
    pub admissions: u64,
    /// sequences retired mid-flight (finished or context-exhausted)
    pub retirements: u64,
    /// sequences preempted to flash (slot kept, seat yielded)
    pub preemptions: u64,
    /// preempted sequences brought back into the batch
    pub resumes: u64,
    /// batch occupancy of every decode step — streaming stats (exact
    /// count/sum/min/max; percentiles over a capped first-N reservoir)
    pub step_occupancy: SampleStats,
    // ---- prefill/decode disaggregation --------------------------------
    /// scheduler steps that decoded at least one sequence
    pub busy_steps: u64,
    /// simulated span of those steps (serialized: includes any
    /// co-scheduled admission's prefill + KV ship; overlapped: the
    /// decode stream only)
    pub busy_step_sim_s: Time,
    // ---- fault plane ---------------------------------------------------
    /// sequences reset to re-prefill after a device loss
    pub restarts: u64,
    /// requests aborted by the retry-only recovery policy
    pub aborted_requests: u64,
    /// simulated time spent in post-loss recovery (replacement build,
    /// replica restore, restart bookkeeping)
    pub recovery_s: Time,
}

impl EngineMetrics {
    pub fn throughput_tok_per_wall_s(&self) -> f64 {
        let wall = self.gpu_wall_s + self.csd_wall_s;
        if wall == 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / wall
        }
    }

    /// Mean simulated time per decode-carrying scheduler step — the
    /// serving inter-token latency, admission stalls included.  The
    /// pipelined executor's headline number: with overlap on, this
    /// decouples from concurrent prefills.
    pub fn decode_step_time_s(&self) -> f64 {
        if self.busy_steps == 0 {
            0.0
        } else {
            self.busy_step_sim_s / self.busy_steps as f64
        }
    }

    /// Mean decode-batch occupancy across all steps (0 when no steps ran).
    pub fn mean_occupancy(&self) -> f64 {
        self.step_occupancy.mean()
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} tokens={} prefill_toks={} prefix_hit={} dropped={} steps={} \
             gpu_wall={:.3}s csd_wall={:.3}s csd_sim={:.6}s tput={:.1} tok/s(wall)",
            self.requests_done,
            self.tokens_generated,
            self.prefill_tokens,
            self.prefix_hit_tokens,
            self.dropped_tokens,
            self.decode_steps,
            self.gpu_wall_s,
            self.csd_wall_s,
            self.csd_sim_s,
            self.throughput_tok_per_wall_s(),
        )
    }

    /// One-line serving-churn summary (continuous-batching runs).
    pub fn churn_report(&self) -> String {
        format!(
            "admitted={} retired={} preempted={} resumed={} mean_occupancy={:.2}",
            self.admissions,
            self.retirements,
            self.preemptions,
            self.resumes,
            self.mean_occupancy(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_guarded_against_zero() {
        let m = EngineMetrics::default();
        assert_eq!(m.throughput_tok_per_wall_s(), 0.0);
        let m = EngineMetrics { tokens_generated: 10, gpu_wall_s: 2.0, ..Default::default() };
        assert_eq!(m.throughput_tok_per_wall_s(), 5.0);
        assert!(m.report().contains("tokens=10"));
        assert!(m.report().contains("prefix_hit=0"));
        assert!(m.report().contains("dropped=0"));
    }

    #[test]
    fn occupancy_mean_over_steps() {
        let m = EngineMetrics::default();
        assert_eq!(m.mean_occupancy(), 0.0);
        let mut m = EngineMetrics::default();
        for o in [2.0, 4.0, 6.0] {
            m.step_occupancy.push(o);
        }
        assert!((m.mean_occupancy() - 4.0).abs() < 1e-12);
        assert!(m.churn_report().contains("mean_occupancy"));
    }

    #[test]
    fn decode_step_time_guarded_against_zero() {
        let m = EngineMetrics::default();
        assert_eq!(m.decode_step_time_s(), 0.0);
        let m = EngineMetrics { busy_steps: 4, busy_step_sim_s: 2.0, ..Default::default() };
        assert!((m.decode_step_time_s() - 0.5).abs() < 1e-12);
    }
}
