//! Deterministic trace plane + unified metrics registry.
//!
//! The simulator is single-threaded and every device timestamp is
//! simulated, so tracing can be something real systems can't offer: a
//! **zero-perturbation, byte-reproducible** record of where time goes.
//! Instrumentation sites emit into a thread-local [`TraceSink`] using
//! values the simulation already computed — never scheduling, never
//! reading clocks — so tracing on/off leaves outputs AND simulated
//! timestamps bit-identical (pinned by `tests/obs.rs`).
//!
//! Events live on two kinds of tracks:
//!
//! * **request tracks** (pid 1, tid = request id): arrival → admission →
//!   chunked-prefill spans → KV-ship spans → prefix attach → per-decode-
//!   step spans → preempt/resume → retire;
//! * **device tracks**: the prefill/decode stream frontiers (pid 2),
//!   per-PCIe-link transfers and the contention arbiter (pid 3), and per
//!   CSD `d` (pid 10+d) the NVMe command stream, FTL GC, and — at the
//!   `full` level — every per-(channel, die) flash FIFO.
//!
//! [`TraceSink::export`] renders Chrome trace-event JSON (the
//! `{"traceEvents": [...]}` object form) loadable directly in Perfetto
//! or `chrome://tracing`; [`TraceSink::digest_hex`] hashes the exported
//! bytes (FNV-1a 64) into a stable digest used as a schedule-level
//! regression pin in the bench trajectory document.
//!
//! The module also hosts the [`MetricsRegistry`] — typed counters /
//! gauges / histograms with deterministic (BTreeMap) snapshot order —
//! that unifies the five historical accounting structs (`EngineMetrics`,
//! `BusyLedger`, `ShardStats`, `OverlapStats`, `FlashUtil`) into one
//! `--metrics-json` snapshot, and [`SampleStats`], the capped reservoir
//! that bounds the per-step sample vectors.

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;

use crate::sim::Time;
use crate::util::json::Json;

pub mod attr;

// ---------------------------------------------------------------------------
// Trace levels
// ---------------------------------------------------------------------------

/// Verbosity of the trace plane, ordered: each level includes the ones
/// below it.  `Request` records request-lifecycle tracks only; `Device`
/// adds streams, NVMe commands, PCIe links and FTL GC; `Full` adds every
/// per-(channel, die) flash FIFO span (large files — debugging only).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceLevel {
    Request,
    Device,
    Full,
}

impl TraceLevel {
    pub fn parse(s: &str) -> anyhow::Result<TraceLevel> {
        match s {
            "request" => Ok(TraceLevel::Request),
            "device" => Ok(TraceLevel::Device),
            "full" => Ok(TraceLevel::Full),
            other => anyhow::bail!("unknown --trace-level {other:?} (request|device|full)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            TraceLevel::Request => "request",
            TraceLevel::Device => "device",
            TraceLevel::Full => "full",
        }
    }
}

// ---------------------------------------------------------------------------
// Events and the sink
// ---------------------------------------------------------------------------

/// One structured trace event on simulated time.  `ph` is the chrome
/// trace-event phase: `'X'` for complete spans (with `dur`), `'i'` for
/// instants, `'s'`/`'f'` for flow (dependency) edge endpoints — for the
/// flow phases `arg` carries the flow id, exported top-level as `"id"`.
/// Timestamps are seconds here; export converts to µs.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub pid: u64,
    pub tid: u64,
    pub name: &'static str,
    pub ph: char,
    pub ts: Time,
    pub dur: Time,
    /// optional single argument rendered under `"args"`
    pub arg: Option<(&'static str, f64)>,
}

/// Process ids of the track-naming scheme (see module docs).
pub const PID_REQUESTS: u64 = 1;
pub const PID_STREAMS: u64 = 2;
pub const PID_PCIE: u64 = 3;
pub const PID_CSD_BASE: u64 = 10;

/// Tid offsets inside a CSD process / the PCIe process.
pub const TID_NVME: u64 = 0;
pub const TID_FTL: u64 = 1;
pub const TID_CHANNEL_BASE: u64 = 100;
pub const TID_UNIT_BASE: u64 = 1000;
pub const TID_PCIE_BG_BASE: u64 = 100;
pub const TID_PCIE_ARBITER: u64 = 999;

fn process_label(pid: u64) -> String {
    match pid {
        PID_REQUESTS => "requests".to_string(),
        PID_STREAMS => "streams".to_string(),
        PID_PCIE => "pcie".to_string(),
        d => format!("csd {}", d - PID_CSD_BASE),
    }
}

fn thread_label(pid: u64, tid: u64) -> String {
    match pid {
        PID_REQUESTS => format!("req {tid}"),
        PID_STREAMS => match tid {
            0 => "prefill stream".to_string(),
            _ => "decode stream".to_string(),
        },
        PID_PCIE => {
            if tid == TID_PCIE_ARBITER {
                "arbiter".to_string()
            } else if tid >= TID_PCIE_BG_BASE {
                format!("bg link {}", tid - TID_PCIE_BG_BASE)
            } else {
                format!("link {tid}")
            }
        }
        _ => {
            if tid >= TID_UNIT_BASE {
                format!("unit {}", tid - TID_UNIT_BASE)
            } else if tid >= TID_CHANNEL_BASE {
                format!("channel {}", tid - TID_CHANNEL_BASE)
            } else if tid == TID_FTL {
                "ftl".to_string()
            } else {
                "nvme".to_string()
            }
        }
    }
}

/// Records structured span/instant events on simulated time and exports
/// them as Chrome trace-event JSON.  Event order inside the sink is the
/// (deterministic) emission order; export stable-sorts per track so
/// every track's timestamps are monotone by construction.
#[derive(Debug)]
pub struct TraceSink {
    pub level: TraceLevel,
    events: Vec<TraceEvent>,
}

impl TraceSink {
    pub fn new(level: TraceLevel) -> TraceSink {
        TraceSink { level, events: Vec::new() }
    }

    pub fn record(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Render the chrome trace-event JSON document: `"M"` metadata
    /// events naming every process and thread first, then all span /
    /// instant events stable-sorted by `(pid, tid, ts, emission index)`
    /// — so each track is monotone in `ts` regardless of emission
    /// interleaving.  Timestamps/durations are exported in µs.
    pub fn export(&self) -> String {
        let mut idx: Vec<usize> = (0..self.events.len()).collect();
        idx.sort_by(|&a, &b| {
            let ea = &self.events[a];
            let eb = &self.events[b];
            (ea.pid, ea.tid)
                .cmp(&(eb.pid, eb.tid))
                .then(ea.ts.total_cmp(&eb.ts))
                .then(a.cmp(&b))
        });

        let mut out: Vec<Json> = Vec::new();
        // Metadata: one process_name per pid, one thread_name per
        // (pid, tid), in sorted track order (idx is already sorted).
        let mut last_pid = u64::MAX;
        let mut last_track = (u64::MAX, u64::MAX);
        for &i in &idx {
            let ev = &self.events[i];
            if ev.pid != last_pid {
                last_pid = ev.pid;
                out.push(meta_event("process_name", ev.pid, 0, &process_label(ev.pid)));
            }
            if (ev.pid, ev.tid) != last_track {
                last_track = (ev.pid, ev.tid);
                out.push(meta_event(
                    "thread_name",
                    ev.pid,
                    ev.tid,
                    &thread_label(ev.pid, ev.tid),
                ));
            }
        }
        for &i in &idx {
            out.push(self.events[i].to_json());
        }

        let mut doc = BTreeMap::new();
        doc.insert("traceEvents".to_string(), Json::Arr(out));
        doc.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
        format!("{}\n", Json::Obj(doc))
    }

    /// FNV-1a 64 over the exported JSON bytes, rendered as a 16-hex-char
    /// string (a u64 would lose precision through `Json::Num`).  Equal
    /// digests ⟺ byte-identical trace files.
    pub fn digest_hex(&self) -> String {
        format!("{:016x}", fnv1a64(self.export().as_bytes()))
    }
}

fn meta_event(name: &str, pid: u64, tid: u64, label: &str) -> Json {
    let mut args = BTreeMap::new();
    args.insert("name".to_string(), Json::Str(label.to_string()));
    let mut m = BTreeMap::new();
    m.insert("ph".to_string(), Json::Str("M".to_string()));
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert("pid".to_string(), Json::Num(pid as f64));
    m.insert("tid".to_string(), Json::Num(tid as f64));
    m.insert("args".to_string(), Json::Obj(args));
    Json::Obj(m)
}

impl TraceEvent {
    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.to_string()));
        m.insert("ph".to_string(), Json::Str(self.ph.to_string()));
        m.insert("pid".to_string(), Json::Num(self.pid as f64));
        m.insert("tid".to_string(), Json::Num(self.tid as f64));
        m.insert("ts".to_string(), Json::Num(self.ts * 1e6));
        if self.ph == 'X' {
            m.insert("dur".to_string(), Json::Num(self.dur * 1e6));
        }
        if self.ph == 'i' {
            // instant scope: thread
            m.insert("s".to_string(), Json::Str("t".to_string()));
        }
        if self.ph == 's' || self.ph == 'f' {
            // flow edge endpoint: the arg slot holds the flow id, which
            // chrome/Perfetto expects top-level next to a "flow" category
            m.insert("cat".to_string(), Json::Str("flow".to_string()));
            if let Some((_, id)) = self.arg {
                m.insert("id".to_string(), Json::Num(id));
            }
            if self.ph == 'f' {
                // bind the arrow to the enclosing slice's start
                m.insert("bp".to_string(), Json::Str("e".to_string()));
            }
        } else if let Some((k, v)) = self.arg {
            let mut args = BTreeMap::new();
            args.insert(k.to_string(), Json::Num(v));
            m.insert("args".to_string(), Json::Obj(args));
        }
        Json::Obj(m)
    }
}

pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    bytes
        .iter()
        .fold(OFFSET, |h, &b| (h ^ b as u64).wrapping_mul(PRIME))
}

// ---------------------------------------------------------------------------
// Thread-local installation + ambient device context
// ---------------------------------------------------------------------------

thread_local! {
    static SINK: RefCell<Option<TraceSink>> = const { RefCell::new(None) };
    /// Device index ambient context: set by `NvmeQueue::submit` (via
    /// [`DeviceScope`]) so FTL / flash-array emissions deep in the call
    /// stack tag the CSD that issued them.
    static CUR_DEV: Cell<usize> = const { Cell::new(0) };
    /// Request id ambient context: set by the engine (via [`ReqScope`])
    /// around per-sequence work so device-level emissions deep in the
    /// call stack can draw request → device flow edges and the attr
    /// plane can charge time to the right request.
    static CUR_REQ: Cell<Option<u64>> = const { Cell::new(None) };
    /// Monotone flow-edge id counter; reset on `install` so traces stay
    /// byte-reproducible across runs.
    static FLOW_ID: Cell<u64> = const { Cell::new(0) };
}

/// Install a fresh sink on this thread at the given level.  Replaces any
/// existing sink.
pub fn install(level: TraceLevel) {
    SINK.with(|s| *s.borrow_mut() = Some(TraceSink::new(level)));
    FLOW_ID.with(|c| c.set(0));
}

/// Remove and return the thread's sink (None if tracing was off).
pub fn uninstall() -> Option<TraceSink> {
    SINK.with(|s| s.borrow_mut().take())
}

/// Is a sink installed on this thread?
pub fn enabled() -> bool {
    SINK.with(|s| s.borrow().is_some())
}

// ---------------------------------------------------------------------------
// Capture-and-merge: worker-thread observability for `sim::par`
// ---------------------------------------------------------------------------
//
// The sinks are thread-local, so parallel workers cannot record into the
// spawning thread's sinks directly.  Instead each worker replicates the
// spawning thread's installation (`CaptureSpec`), runs with its own
// sinks, and hands them back (`Captured`); the spawning thread merges
// them in deterministic item order (`merge_captured`).  Merging appends
// trace events in the worker's emission order, so chunked fan-out joined
// in index order reproduces the serial emission sequence exactly — and
// the export's `(pid, tid, ts, emission)` stable sort does the rest.
// The one global emission-order artifact in the exported bytes is the
// flow-edge id: workers allocate dense ids from their own counter, and
// the merge remaps them through the spawning thread's counter in
// first-encounter order, which equals serial allocation order.

/// Snapshot of this thread's observability installation, replicated onto
/// `sim::par` worker threads: trace level, attribution on/off, and the
/// ambient request scope.
#[derive(Clone, Copy)]
pub struct CaptureSpec {
    level: Option<TraceLevel>,
    attr_on: bool,
    req: Option<u64>,
}

/// One worker's drained sinks, merged back on the spawning thread.
pub struct Captured {
    trace: Option<TraceSink>,
    attr: Option<attr::AttrSink>,
}

impl CaptureSpec {
    /// Snapshot the current thread's installation.
    pub fn of_current() -> CaptureSpec {
        CaptureSpec {
            level: SINK.with(|s| s.borrow().as_ref().map(|k| k.level)),
            attr_on: attr::enabled(),
            req: cur_req(),
        }
    }

    /// Install fresh sinks matching the spec on the current (worker)
    /// thread.  Idempotent per work item: any previous item's leftover
    /// state is replaced.
    pub fn install(&self) {
        match self.level {
            Some(level) => install(level),
            None => {
                SINK.with(|s| *s.borrow_mut() = None);
            }
        }
        if self.attr_on {
            attr::install();
        } else {
            let _ = attr::uninstall();
        }
        CUR_REQ.with(|c| c.set(self.req));
    }
}

/// Drain the current (worker) thread's sinks into a `Captured`.
pub fn capture_take() -> Captured {
    Captured { trace: uninstall(), attr: attr::uninstall() }
}

/// Merge one worker's captured sinks into the current thread's sinks.
/// Call in deterministic item order — trace events append in the
/// worker's emission order and flow ids are remapped through this
/// thread's counter, so serial and parallel runs export byte-identical
/// documents.
pub fn merge_captured(cap: Captured) {
    if let Some(worker) = cap.trace {
        SINK.with(|s| {
            if let Some(sink) = s.borrow_mut().as_mut() {
                let mut remap = std::collections::HashMap::<u64, u64>::new();
                for mut ev in worker.events {
                    if ev.ph == 's' || ev.ph == 'f' {
                        if let Some((key, id)) = ev.arg {
                            let new = *remap.entry(id as u64).or_insert_with(|| {
                                FLOW_ID.with(|c| {
                                    let v = c.get();
                                    c.set(v + 1);
                                    v
                                })
                            });
                            ev.arg = Some((key, new as f64));
                        }
                    }
                    sink.record(ev);
                }
            }
        });
    }
    if let Some(worker) = cap.attr {
        attr::merge(worker);
    }
}

/// RAII guard scoping the ambient CSD device index; restores the
/// previous value on drop (NVMe submits never nest across devices, but
/// restoring is cheap and makes the guard composable).
pub struct DeviceScope {
    prev: usize,
}

impl DeviceScope {
    pub fn enter(dev: usize) -> DeviceScope {
        let prev = CUR_DEV.with(|c| c.replace(dev));
        DeviceScope { prev }
    }
}

impl Drop for DeviceScope {
    fn drop(&mut self) {
        CUR_DEV.with(|c| c.set(self.prev));
    }
}

/// RAII guard scoping the ambient request id (see [`ReqScope::enter`]);
/// restores the previous value on drop so nested scopes compose.
pub struct ReqScope {
    prev: Option<u64>,
}

impl ReqScope {
    pub fn enter(req: u64) -> ReqScope {
        let prev = CUR_REQ.with(|c| c.replace(Some(req)));
        ReqScope { prev }
    }
}

impl Drop for ReqScope {
    fn drop(&mut self) {
        CUR_REQ.with(|c| c.set(self.prev));
    }
}

/// The ambient request id, if the call stack is inside a [`ReqScope`].
pub fn cur_req() -> Option<u64> {
    CUR_REQ.with(|c| c.get())
}

fn emit(min: TraceLevel, ev: TraceEvent) {
    SINK.with(|s| {
        if let Some(sink) = s.borrow_mut().as_mut() {
            if sink.level >= min {
                sink.record(ev);
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Emitters — every call site passes values the simulation already
// computed; none of these functions reads or advances any clock.
// ---------------------------------------------------------------------------

/// Request-lifecycle instant (arrive/admit/preempt/resume/retire/...).
pub fn req_instant(id: u64, name: &'static str, ts: Time) {
    emit(
        TraceLevel::Request,
        TraceEvent { pid: PID_REQUESTS, tid: id, name, ph: 'i', ts, dur: 0.0, arg: None },
    );
}

/// Request-lifecycle span (prefill / kv_ship / decode_step).
pub fn req_span(id: u64, name: &'static str, t0: Time, t1: Time) {
    emit(
        TraceLevel::Request,
        TraceEvent { pid: PID_REQUESTS, tid: id, name, ph: 'X', ts: t0, dur: t1 - t0, arg: None },
    );
}

/// Stream-frontier span: stream 0 = prefill, 1 = decode.
pub fn stream_span(stream: u64, name: &'static str, t0: Time, t1: Time) {
    emit(
        TraceLevel::Device,
        TraceEvent { pid: PID_STREAMS, tid: stream, name, ph: 'X', ts: t0, dur: t1 - t0, arg: None },
    );
}

/// NVMe command span on CSD `dev`'s command track.
pub fn device_span(dev: usize, name: &'static str, t0: Time, t1: Time) {
    emit(
        TraceLevel::Device,
        TraceEvent {
            pid: PID_CSD_BASE + dev as u64,
            tid: TID_NVME,
            name,
            ph: 'X',
            ts: t0,
            dur: t1 - t0,
            arg: None,
        },
    );
}

/// Fault-plane instant on the ambient device's command track
/// (nvme_timeout / flash_retry / bad_block).  Faults-off emits nothing,
/// so the trace digest is unchanged.
pub fn dev_instant(name: &'static str, ts: Time) {
    let dev = CUR_DEV.with(|c| c.get());
    emit(
        TraceLevel::Device,
        TraceEvent {
            pid: PID_CSD_BASE + dev as u64,
            tid: TID_NVME,
            name,
            ph: 'i',
            ts,
            dur: 0.0,
            arg: None,
        },
    );
}

/// Fault-plane instant on an explicit device's command track
/// (csd_loss / recovery_done — emitted from the coordinator, outside
/// any DeviceScope).
pub fn device_instant(dev: usize, name: &'static str, ts: Time) {
    emit(
        TraceLevel::Device,
        TraceEvent {
            pid: PID_CSD_BASE + dev as u64,
            tid: TID_NVME,
            name,
            ph: 'i',
            ts,
            dur: 0.0,
            arg: None,
        },
    );
}

/// FTL garbage-collection instant on the ambient device's FTL track.
pub fn ftl_gc(relocations: u64, ts: Time) {
    let dev = CUR_DEV.with(|c| c.get());
    emit(
        TraceLevel::Device,
        TraceEvent {
            pid: PID_CSD_BASE + dev as u64,
            tid: TID_FTL,
            name: "gc",
            ph: 'i',
            ts,
            dur: 0.0,
            arg: Some(("relocations", relocations as f64)),
        },
    );
}

/// Flash unit (die/plane FIFO) span on the ambient device — `full` only.
pub fn flash_unit_span(unit: usize, name: &'static str, t0: Time, t1: Time) {
    let dev = CUR_DEV.with(|c| c.get());
    emit(
        TraceLevel::Full,
        TraceEvent {
            pid: PID_CSD_BASE + dev as u64,
            tid: TID_UNIT_BASE + unit as u64,
            name,
            ph: 'X',
            ts: t0,
            dur: t1 - t0,
            arg: None,
        },
    );
}

/// Flash channel FIFO span on the ambient device — `full` only.
pub fn flash_channel_span(ch: usize, name: &'static str, t0: Time, t1: Time) {
    let dev = CUR_DEV.with(|c| c.get());
    emit(
        TraceLevel::Full,
        TraceEvent {
            pid: PID_CSD_BASE + dev as u64,
            tid: TID_CHANNEL_BASE + ch as u64,
            name,
            ph: 'X',
            ts: t0,
            dur: t1 - t0,
            arg: None,
        },
    );
}

/// Foreground PCIe link transfer (all-reduce shard merge) on link `dev`.
pub fn pcie_span(dev: usize, name: &'static str, t0: Time, t1: Time, bytes: f64) {
    emit(
        TraceLevel::Device,
        TraceEvent {
            pid: PID_PCIE,
            tid: dev as u64,
            name,
            ph: 'X',
            ts: t0,
            dur: t1 - t0,
            arg: Some(("bytes", bytes)),
        },
    );
}

/// Background PCIe transfer (prefill KV shipping) on link `dev`.
pub fn pcie_bg_span(dev: usize, name: &'static str, t0: Time, t1: Time, bytes: f64) {
    emit(
        TraceLevel::Device,
        TraceEvent {
            pid: PID_PCIE,
            tid: TID_PCIE_BG_BASE + dev as u64,
            name,
            ph: 'X',
            ts: t0,
            dur: t1 - t0,
            arg: Some(("bytes", bytes)),
        },
    );
}

/// PCIe ingress-contention arbiter decision instant.
pub fn pcie_arbiter(background: usize, delay: Time, ts: Time) {
    emit(
        TraceLevel::Device,
        TraceEvent {
            pid: PID_PCIE,
            tid: TID_PCIE_ARBITER,
            name: if background > 0 { "contended" } else { "uncontended" },
            ph: 'i',
            ts,
            dur: 0.0,
            arg: Some(("delay_s", delay)),
        },
    );
}

/// Dependency (flow) edge between two tracks: a paired `'s'`/`'f'` event
/// sharing one flow id, rendered as an arrow in Perfetto.  `from` and
/// `to` are `(pid, tid, ts)` triples; the edge is recorded atomically
/// (both endpoints or neither) so exports never hold dangling halves.
pub fn flow(name: &'static str, min: TraceLevel, from: (u64, u64, Time), to: (u64, u64, Time)) {
    SINK.with(|s| {
        let mut b = s.borrow_mut();
        let Some(sink) = b.as_mut() else { return };
        if sink.level < min {
            return;
        }
        let id = FLOW_ID.with(|c| {
            let v = c.get();
            c.set(v + 1);
            v
        }) as f64;
        sink.record(TraceEvent {
            pid: from.0,
            tid: from.1,
            name,
            ph: 's',
            ts: from.2,
            dur: 0.0,
            arg: Some(("id", id)),
        });
        sink.record(TraceEvent {
            pid: to.0,
            tid: to.1,
            name,
            ph: 'f',
            ts: to.2,
            dur: 0.0,
            arg: Some(("id", id)),
        });
    });
}

/// Request → NVMe-command flow edge on the ambient device: the arrow
/// from a request track to the device that serves its command.
pub fn cmd_flow(req: u64, issued: Time, dev: usize, started: Time) {
    flow(
        "issue",
        TraceLevel::Device,
        (PID_REQUESTS, req, issued),
        (PID_CSD_BASE + dev as u64, TID_NVME, started),
    );
}

/// Flash die/plane FIFO → channel FIFO flow edge on the ambient device
/// (`full` only): ties each die read to the channel transfer it feeds.
pub fn flash_read_flow(unit: usize, unit_done: Time, ch: usize, chan_start: Time) {
    let dev = CUR_DEV.with(|c| c.get()) as u64;
    flow(
        "die_to_channel",
        TraceLevel::Full,
        (PID_CSD_BASE + dev, TID_UNIT_BASE + unit as u64, unit_done),
        (PID_CSD_BASE + dev, TID_CHANNEL_BASE + ch as u64, chan_start),
    );
}

// ---------------------------------------------------------------------------
// SampleStats — capped streaming reservoir
// ---------------------------------------------------------------------------

/// Streaming sample statistics with a deterministic index-strided
/// reservoir for percentiles: `count/sum/min/max` are exact over ALL
/// pushed samples; `p50/p95` come from samples taken at indices
/// `0, stride, 2·stride, …`, where the stride doubles (and the reservoir
/// halves) each time the cap fills.  The kept set is always uniformly
/// spread over the whole stream seen so far — no RNG, byte-reproducible
/// — unlike a first-N window, whose percentiles freeze on the earliest
/// samples of a long open-loop serve.  Exact for runs shorter than the
/// cap.  Replaces the unbounded per-step `Vec`s in `EngineMetrics` so
/// open-loop serve memory no longer grows linearly with steps.
#[derive(Debug, Clone)]
pub struct SampleStats {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    reservoir: Vec<f64>,
    cap: usize,
    stride: u64,
}

/// Default reservoir bound (samples, not bytes): 32 KiB of f64 per stat.
pub const SAMPLE_CAP: usize = 4096;

impl Default for SampleStats {
    fn default() -> Self {
        SampleStats::with_cap(SAMPLE_CAP)
    }
}

impl SampleStats {
    pub fn with_cap(cap: usize) -> SampleStats {
        SampleStats {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            reservoir: Vec::new(),
            cap,
            stride: 1,
        }
    }

    pub fn push(&mut self, x: f64) {
        let idx = self.count;
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.cap == 0 || idx % self.stride != 0 {
            return;
        }
        if self.reservoir.len() == self.cap {
            // cap reached: keep every other kept sample (still uniform,
            // twice the spacing) and double the stride going forward
            let mut keep = 0;
            self.reservoir.retain(|_| {
                let k = keep % 2 == 0;
                keep += 1;
                k
            });
            self.stride *= 2;
            if idx % self.stride != 0 {
                return;
            }
        }
        self.reservoir.push(x);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Percentile over the reservoir window; 0.0 when empty.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.reservoir.is_empty() {
            return 0.0;
        }
        let mut xs = self.reservoir.clone();
        crate::util::stats::percentile(&mut xs, q)
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            count: self.count,
            sum: self.sum,
            min: self.min(),
            max: self.max(),
            p50: self.percentile(50.0),
            p95: self.percentile(95.0),
        }
    }
}

// ---------------------------------------------------------------------------
// MetricsRegistry
// ---------------------------------------------------------------------------

/// Point-in-time summary of a [`SampleStats`] histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

/// One typed metric value.
#[derive(Debug, Clone, PartialEq)]
pub enum MetricValue {
    Counter(u64),
    Gauge(f64),
    Histogram(HistogramSnapshot),
}

/// Unified, deterministically-ordered metric snapshot: the five ad-hoc
/// accounting structs (`EngineMetrics`, `BusyLedger`, `ShardStats`,
/// `OverlapStats`, `FlashUtil`) register here under dotted names
/// (`engine.*`, `ledger.*`, `shard.*`, `overlap.*`, `flash.*`,
/// `units.*`), and `--metrics-json` / bench rows read the one snapshot.
/// BTreeMap keys make iteration and JSON output order deterministic.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    map: BTreeMap<String, MetricValue>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    pub fn counter(&mut self, name: &str, v: u64) {
        self.map.insert(name.to_string(), MetricValue::Counter(v));
    }

    pub fn gauge(&mut self, name: &str, v: f64) {
        self.map.insert(name.to_string(), MetricValue::Gauge(v));
    }

    pub fn histogram(&mut self, name: &str, s: &SampleStats) {
        self.map
            .insert(name.to_string(), MetricValue::Histogram(s.snapshot()));
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&MetricValue> {
        self.map.get(name)
    }

    /// Numeric read across types (counter as f64, gauge, histogram
    /// mean) — the bench-table accessor.
    pub fn value(&self, name: &str) -> Option<f64> {
        self.map.get(name).map(|v| match v {
            MetricValue::Counter(c) => *c as f64,
            MetricValue::Gauge(g) => *g,
            MetricValue::Histogram(h) => {
                if h.count == 0 {
                    0.0
                } else {
                    h.sum / h.count as f64
                }
            }
        })
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &MetricValue)> {
        self.map.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Deterministic JSON object: counters/gauges as numbers, histograms
    /// as `{count, sum, min, max, p50, p95}` objects.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        for (k, v) in &self.map {
            let jv = match v {
                MetricValue::Counter(c) => Json::Num(*c as f64),
                MetricValue::Gauge(g) => Json::Num(*g),
                MetricValue::Histogram(h) => {
                    let mut hm = BTreeMap::new();
                    hm.insert("count".to_string(), Json::Num(h.count as f64));
                    hm.insert("sum".to_string(), Json::Num(h.sum));
                    hm.insert("min".to_string(), Json::Num(h.min));
                    hm.insert("max".to_string(), Json::Num(h.max));
                    hm.insert("p50".to_string(), Json::Num(h.p50));
                    hm.insert("p95".to_string(), Json::Num(h.p95));
                    Json::Obj(hm)
                }
            };
            obj.insert(k.clone(), jv);
        }
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_levels_are_ordered() {
        assert!(TraceLevel::Request < TraceLevel::Device);
        assert!(TraceLevel::Device < TraceLevel::Full);
        assert_eq!(TraceLevel::parse("device").unwrap(), TraceLevel::Device);
        assert!(TraceLevel::parse("bogus").is_err());
        assert_eq!(TraceLevel::Full.label(), "full");
    }

    #[test]
    fn sink_filters_below_level() {
        let mut sink = TraceSink::new(TraceLevel::Request);
        // emulate what emit() does for a device-level event
        if sink.level >= TraceLevel::Device {
            sink.record(TraceEvent {
                pid: PID_STREAMS,
                tid: 0,
                name: "x",
                ph: 'X',
                ts: 0.0,
                dur: 1.0,
                arg: None,
            });
        }
        assert!(sink.is_empty());
    }

    #[test]
    fn export_is_sorted_and_parses() {
        let mut sink = TraceSink::new(TraceLevel::Full);
        // emit out of track order and out of ts order across tracks
        sink.record(TraceEvent {
            pid: PID_CSD_BASE,
            tid: TID_NVME,
            name: "attn",
            ph: 'X',
            ts: 2.0,
            dur: 0.5,
            arg: None,
        });
        sink.record(TraceEvent {
            pid: PID_REQUESTS,
            tid: 7,
            name: "arrive",
            ph: 'i',
            ts: 1.0,
            dur: 0.0,
            arg: None,
        });
        sink.record(TraceEvent {
            pid: PID_CSD_BASE,
            tid: TID_NVME,
            name: "write",
            ph: 'X',
            ts: 1.0,
            dur: 0.25,
            arg: Some(("bytes", 64.0)),
        });
        let text = sink.export();
        let doc = Json::parse(text.trim_end()).expect("export parses");
        let evs = doc.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        // 3 events + 2 process_name + 2 thread_name metadata
        assert_eq!(evs.len(), 7);
        // metadata first
        assert_eq!(evs[0].get("ph").and_then(|p| p.as_str()), Some("M"));
        // per-track monotone ts among 'X'/'i' events
        let mut last: Option<(f64, f64, f64)> = None;
        for e in evs {
            if e.get("ph").and_then(|p| p.as_str()) == Some("M") {
                continue;
            }
            let key = (
                e.get("pid").and_then(|v| v.as_f64()).unwrap(),
                e.get("tid").and_then(|v| v.as_f64()).unwrap(),
                e.get("ts").and_then(|v| v.as_f64()).unwrap(),
            );
            if let Some(prev) = last {
                assert!(key >= prev, "events not sorted: {prev:?} then {key:?}");
            }
            last = Some(key);
        }
        // byte-stable: re-export is identical, digest matches
        assert_eq!(text, sink.export());
        assert_eq!(sink.digest_hex(), sink.digest_hex());
        assert_eq!(sink.digest_hex().len(), 16);
    }

    #[test]
    fn install_uninstall_roundtrip() {
        assert!(!enabled());
        install(TraceLevel::Device);
        assert!(enabled());
        req_instant(3, "arrive", 0.5);
        stream_span(1, "decode_step", 1.0, 2.0);
        flash_unit_span(0, "read", 0.0, 1.0); // Full-level: filtered out
        {
            let _scope = DeviceScope::enter(2);
            ftl_gc(5, 3.0);
        }
        let sink = uninstall().expect("sink was installed");
        assert!(!enabled());
        assert_eq!(sink.len(), 3);
        // the gc instant landed on csd 2 (ambient device scope)
        let gc = sink.events().iter().find(|e| e.name == "gc").unwrap();
        assert_eq!(gc.pid, PID_CSD_BASE + 2);
        // emitting with no sink installed is a no-op
        req_instant(4, "arrive", 9.0);
        assert!(!enabled());
    }

    #[test]
    fn sample_stats_caps_reservoir_but_counts_all() {
        let mut s = SampleStats::with_cap(8);
        for i in 0..100 {
            s.push(i as f64);
        }
        assert_eq!(s.count(), 100);
        assert!((s.sum() - 4950.0).abs() < 1e-9);
        assert!((s.mean() - 49.5).abs() < 1e-9);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 99.0);
        // the strided reservoir stays uniform over the whole stream —
        // kept samples are [0, 16, 32, 48, 64, 80, 96], so the median
        // tracks the stream's middle instead of freezing on the first 8
        assert_eq!(s.reservoir, vec![0.0, 16.0, 32.0, 48.0, 64.0, 80.0, 96.0]);
        assert!((s.percentile(50.0) - 48.0).abs() < 1e-9);
        let snap = s.snapshot();
        assert_eq!(snap.count, 100);
        assert_eq!(snap.max, 99.0);
        // empty stats are all-zero, not NaN/inf
        let e = SampleStats::default();
        assert_eq!(e.mean(), 0.0);
        assert_eq!(e.min(), 0.0);
        assert_eq!(e.max(), 0.0);
        assert_eq!(e.percentile(95.0), 0.0);
    }

    #[test]
    fn sample_stats_stride_stays_deterministic_and_uniform() {
        // below the cap the reservoir is exact
        let mut s = SampleStats::with_cap(4);
        for i in 0..3 {
            s.push(i as f64);
        }
        assert_eq!(s.reservoir, vec![0.0, 1.0, 2.0]);
        // beyond the cap: stride doubles, spacing stays uniform
        for i in 3..16 {
            s.push(i as f64);
        }
        assert_eq!(s.reservoir, vec![0.0, 4.0, 8.0, 12.0]);
        // identical streams produce identical reservoirs (no RNG)
        let mut t = SampleStats::with_cap(4);
        for i in 0..16 {
            t.push(i as f64);
        }
        assert_eq!(s.reservoir, t.reservoir);
        // degenerate cap-0 stats keep exact aggregates, empty reservoir
        let mut z = SampleStats::with_cap(0);
        for i in 0..10 {
            z.push(i as f64);
        }
        assert_eq!(z.count(), 10);
        assert!(z.reservoir.is_empty());
        assert_eq!(z.percentile(50.0), 0.0);
    }

    #[test]
    fn registry_snapshot_is_deterministic_json() {
        let mut r = MetricsRegistry::new();
        r.gauge("b.gauge", 2.5);
        r.counter("a.counter", 7);
        let mut s = SampleStats::default();
        s.push(1.0);
        s.push(3.0);
        r.histogram("c.hist", &s);
        assert_eq!(r.len(), 3);
        assert_eq!(r.value("a.counter"), Some(7.0));
        assert_eq!(r.value("b.gauge"), Some(2.5));
        assert_eq!(r.value("c.hist"), Some(2.0)); // histogram mean
        assert_eq!(r.value("missing"), None);
        let keys: Vec<&str> = r.iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["a.counter", "b.gauge", "c.hist"]);
        let j = r.to_json().to_string();
        // BTreeMap order: keys appear sorted in the rendered JSON
        assert!(j.find("a.counter").unwrap() < j.find("b.gauge").unwrap());
        assert!(j.find("b.gauge").unwrap() < j.find("c.hist").unwrap());
        assert!(j.contains("\"p95\""));
        // round-trips through our own parser
        assert!(Json::parse(&j).is_ok());
    }

    #[test]
    fn fnv_digest_matches_reference_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
