//! Critical-path latency attribution over the trace plane.
//!
//! Answers "where did this request's milliseconds go?" by partitioning
//! each request's wall time `[arrive, retire]` into **exclusive** labeled
//! intervals, so per-request attributed fractions sum to measured wall
//! time *by construction* (pinned to 1e-6 relative in `tests/obs.rs`).
//!
//! Like the trace plane, the attr plane is strictly observational: hooks
//! record `(start, end)` values the simulation already computed and
//! never schedule, so enabling attribution leaves outputs AND simulated
//! timestamps bit-identical.  Hooks are cheap no-ops when no [`AttrSink`]
//! is installed.
//!
//! The model: the scheduler marks request lifecycle points
//! ([`MarkKind`]) and brackets every scheduling occupancy into *frames*
//! ([`FrameKind`]: one per prefill launch, one per decode step).  Device
//! hooks deep in the call stack (NVMe, flash array, FTL GC, PCIe, shard
//! merge) record weighted *segments* against the ambient request
//! (`obs::cur_req`).  The extractor then walks each request's timeline:
//!
//! * time between frames is classified by context — [`Bucket::Queue`]
//!   before the first frame, [`Bucket::PreemptWait`] when a preempt mark
//!   falls inside the gap, [`Bucket::Park`] between prefill completion
//!   and the first decode step (pipeline park), [`Bucket::AdmitStall`]
//!   otherwise;
//! * time inside a frame is split across the segment buckets recorded in
//!   it, rescaled so they tile exactly the span the request's own work
//!   covers; the remainder of the frame — time the request sat waiting
//!   on cohort peers — is [`Bucket::BatchWait`].
//!
//! TTFT attribution is the prefix of the partition ending at the first
//! prefill frame's end; decode attribution is the rest.

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;

use crate::sim::Time;
use crate::util::json::Json;

use super::MetricsRegistry;

// ---------------------------------------------------------------------------
// Buckets
// ---------------------------------------------------------------------------

/// Exclusive latency components.  Every second of a request's wall time
/// lands in exactly one bucket.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Bucket {
    /// waiting in the arrival queue before first admission
    Queue,
    /// admitted but stalled between frames (batch formation, seat wait)
    AdmitStall,
    /// evicted by the scheduler, waiting to resume
    PreemptWait,
    /// prefill done, parked in the pipeline awaiting the decode stream
    Park,
    /// GPU prefill compute
    PrefillCompute,
    /// shipping prefill KV to the flash tier (background PCIe)
    KvShip,
    /// NVMe submission-queue wait + command overhead
    NvmeCmd,
    /// flash die tR + channel transfer (the paper's headline bucket)
    FlashRead,
    /// die/channel FIFO conflict wait (queueing behind other reads)
    FlashConflict,
    /// FTL garbage-collection interference
    Gc,
    /// in-storage compute: argtopk, NFC filter, logits, attend, writeback
    CsdCompute,
    /// foreground PCIe all-reduce transfer
    PcieXfer,
    /// PCIe ingress-contention delay (background traffic in the way)
    PcieContend,
    /// GPU-side shard merge
    GpuMerge,
    /// in-frame wait on cohort peers (batch straggler time)
    BatchWait,
    /// NVMe timeout detection + exponential-backoff retry wait
    FaultRetry,
    /// post-fault KV recovery (replica restore / re-prefill cleanup)
    Recovery,
}

/// All buckets, in stable report order.
pub const BUCKETS: [Bucket; 17] = [
    Bucket::Queue,
    Bucket::AdmitStall,
    Bucket::PreemptWait,
    Bucket::Park,
    Bucket::PrefillCompute,
    Bucket::KvShip,
    Bucket::NvmeCmd,
    Bucket::FlashRead,
    Bucket::FlashConflict,
    Bucket::Gc,
    Bucket::CsdCompute,
    Bucket::PcieXfer,
    Bucket::PcieContend,
    Bucket::GpuMerge,
    Bucket::BatchWait,
    Bucket::FaultRetry,
    Bucket::Recovery,
];

pub const NBUCKETS: usize = BUCKETS.len();

impl Bucket {
    pub fn index(self) -> usize {
        BUCKETS.iter().position(|&b| b == self).unwrap()
    }

    pub fn label(self) -> &'static str {
        match self {
            Bucket::Queue => "queue",
            Bucket::AdmitStall => "admit_stall",
            Bucket::PreemptWait => "preempt_wait",
            Bucket::Park => "park",
            Bucket::PrefillCompute => "prefill_compute",
            Bucket::KvShip => "kv_ship",
            Bucket::NvmeCmd => "nvme_cmd",
            Bucket::FlashRead => "flash_read",
            Bucket::FlashConflict => "flash_conflict",
            Bucket::Gc => "gc",
            Bucket::CsdCompute => "csd_compute",
            Bucket::PcieXfer => "pcie_xfer",
            Bucket::PcieContend => "pcie_contend",
            Bucket::GpuMerge => "gpu_merge",
            Bucket::BatchWait => "batch_wait",
            Bucket::FaultRetry => "fault_retry",
            Bucket::Recovery => "recovery",
        }
    }
}

// ---------------------------------------------------------------------------
// Raw recording
// ---------------------------------------------------------------------------

/// Request-lifecycle points the scheduler marks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MarkKind {
    Arrive,
    Admit,
    Preempt,
    Resume,
    Retire,
}

/// Scheduling occupancy kinds the scheduler brackets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    Prefill,
    Decode,
}

/// One weighted component segment: `w` seconds of `bucket` anchored on
/// the wall interval `[t0, t1]` (the weight may differ from `t1 - t0`
/// when components overlap inside a device span — the extractor rescales
/// weights to tile the frame exactly).
#[derive(Debug, Clone, Copy)]
pub struct Seg {
    pub req: u64,
    pub bucket: Bucket,
    pub t0: Time,
    pub t1: Time,
    pub w: f64,
}

/// Raw attribution recording: lifecycle marks, scheduling frames, and
/// weighted component segments, in emission order.
#[derive(Debug, Default)]
pub struct AttrSink {
    pub marks: Vec<(u64, MarkKind, Time)>,
    pub frames: Vec<(u64, FrameKind, Time, Time)>,
    pub segs: Vec<Seg>,
}

thread_local! {
    static ATTR: RefCell<Option<AttrSink>> = const { RefCell::new(None) };
    /// (conflict_wait_s, service_s) accumulated by flash-array reads
    /// since the last NVMe-command drain.
    static PEND_FLASH: Cell<(f64, f64)> = const { Cell::new((0.0, 0.0)) };
    /// GC stall seconds accumulated by the FTL since the last drain.
    static PEND_GC: Cell<f64> = const { Cell::new(0.0) };
}

/// Install a fresh attribution sink on this thread.
pub fn install() {
    ATTR.with(|s| *s.borrow_mut() = Some(AttrSink::default()));
    PEND_FLASH.with(|c| c.set((0.0, 0.0)));
    PEND_GC.with(|c| c.set(0.0));
}

/// Remove and return the thread's attribution sink.
pub fn uninstall() -> Option<AttrSink> {
    ATTR.with(|s| s.borrow_mut().take())
}

/// Is an attribution sink installed on this thread?
pub fn enabled() -> bool {
    ATTR.with(|s| s.borrow().is_some())
}

/// Append a `sim::par` worker's drained sink into the current thread's
/// sink, preserving the worker's recording order.  Called in
/// deterministic item order by `obs::merge_captured`; `extract()` groups
/// by request id, so the merged report is identical to a serial run's.
pub fn merge(worker: AttrSink) {
    ATTR.with(|s| {
        if let Some(sink) = s.borrow_mut().as_mut() {
            sink.marks.extend(worker.marks);
            sink.frames.extend(worker.frames);
            sink.segs.extend(worker.segs);
        }
    });
}

/// Record a lifecycle mark for `req`.
pub fn mark(req: u64, kind: MarkKind, ts: Time) {
    ATTR.with(|s| {
        if let Some(sink) = s.borrow_mut().as_mut() {
            sink.marks.push((req, kind, ts));
        }
    });
}

/// Record a scheduling frame `[t0, t1]` for `req`.
pub fn frame(req: u64, kind: FrameKind, t0: Time, t1: Time) {
    ATTR.with(|s| {
        if let Some(sink) = s.borrow_mut().as_mut() {
            sink.frames.push((req, kind, t0, t1));
        }
    });
}

/// Record `w` seconds of `bucket` anchored on `[t0, t1]` against the
/// ambient request (no-op outside a `ReqScope` or for w ≤ 0).
pub fn seg(bucket: Bucket, t0: Time, t1: Time, w: f64) {
    if w <= 0.0 {
        return;
    }
    let Some(req) = super::cur_req() else { return };
    ATTR.with(|s| {
        if let Some(sink) = s.borrow_mut().as_mut() {
            sink.segs.push(Seg { req, bucket, t0, t1, w });
        }
    });
}

/// Flash-array read hook: accumulate FIFO conflict wait and die/channel
/// service seconds for the NVMe command currently being submitted.
pub fn flash_read_busy(wait: f64, service: f64) {
    if !enabled() {
        return;
    }
    PEND_FLASH.with(|c| {
        let (w, s) = c.get();
        c.set((w + wait.max(0.0), s + service.max(0.0)));
    });
}

/// FTL hook: accumulate GC stall seconds for the current NVMe command.
pub fn gc_busy(d: f64) {
    if !enabled() {
        return;
    }
    PEND_GC.with(|c| c.set(c.get() + d.max(0.0)));
}

/// Take and reset the accumulated (conflict_wait, service) pair.
pub fn drain_flash() -> (f64, f64) {
    PEND_FLASH.with(|c| c.replace((0.0, 0.0)))
}

/// Take and reset the accumulated GC stall.
pub fn drain_gc() -> f64 {
    PEND_GC.with(|c| c.replace(0.0))
}

// ---------------------------------------------------------------------------
// Extraction
// ---------------------------------------------------------------------------

/// One request's attribution: exclusive per-bucket seconds over the whole
/// wall time, plus the TTFT-prefix / decode-suffix split of the same
/// partition.  `buckets[i] == ttft_buckets[i] + decode_buckets[i]`.
#[derive(Debug, Clone)]
pub struct ReqAttr {
    pub req: u64,
    pub wall: f64,
    pub ttft: f64,
    pub buckets: [f64; NBUCKETS],
    pub ttft_buckets: [f64; NBUCKETS],
    pub decode_buckets: [f64; NBUCKETS],
}

/// Aggregated attribution report over all completed requests.
#[derive(Debug, Clone, Default)]
pub struct AttrReport {
    pub requests: Vec<ReqAttr>,
    pub total: [f64; NBUCKETS],
    pub ttft_total: [f64; NBUCKETS],
    pub decode_total: [f64; NBUCKETS],
    pub wall_total: f64,
}

struct ReqRaw {
    marks: Vec<(MarkKind, Time)>,
    frames: Vec<(FrameKind, Time, Time)>,
    segs: Vec<Seg>,
}

/// Extract the per-request critical-path attribution from a drained
/// sink.  Requests without both an Arrive and a Retire mark (rejected or
/// still in flight) are skipped.
pub fn extract(sink: &AttrSink) -> AttrReport {
    let mut by_req: BTreeMap<u64, ReqRaw> = BTreeMap::new();
    let raw = |m: &mut BTreeMap<u64, ReqRaw>, req: u64| -> &mut ReqRaw {
        m.entry(req)
            .or_insert_with(|| ReqRaw { marks: Vec::new(), frames: Vec::new(), segs: Vec::new() })
    };
    for &(req, kind, ts) in &sink.marks {
        raw(&mut by_req, req).marks.push((kind, ts));
    }
    for &(req, kind, t0, t1) in &sink.frames {
        raw(&mut by_req, req).frames.push((kind, t0, t1));
    }
    for &s in &sink.segs {
        raw(&mut by_req, s.req).segs.push(s);
    }

    let mut report = AttrReport::default();
    for (req, r) in &by_req {
        let arrive = r.marks.iter().find(|(k, _)| *k == MarkKind::Arrive).map(|&(_, t)| t);
        let retire = r.marks.iter().find(|(k, _)| *k == MarkKind::Retire).map(|&(_, t)| t);
        let (Some(arrive), Some(retire)) = (arrive, retire) else { continue };
        if retire <= arrive {
            continue;
        }
        let ra = attribute_one(*req, arrive, retire, r);
        for i in 0..NBUCKETS {
            report.total[i] += ra.buckets[i];
            report.ttft_total[i] += ra.ttft_buckets[i];
            report.decode_total[i] += ra.decode_buckets[i];
        }
        report.wall_total += ra.wall;
        report.requests.push(ra);
    }
    report
}

/// One contiguous labeled piece of a request's timeline partition.
struct Piece {
    t1: Time,
    buckets: [f64; NBUCKETS],
}

fn attribute_one(req: u64, arrive: Time, retire: Time, r: &ReqRaw) -> ReqAttr {
    let mut frames: Vec<(FrameKind, Time, Time)> = r
        .frames
        .iter()
        .map(|&(k, t0, t1)| (k, t0.max(arrive), t1.min(retire)))
        .filter(|&(_, t0, t1)| t1 > t0)
        .collect();
    frames.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.2.total_cmp(&b.2)));

    let preempts: Vec<Time> = r
        .marks
        .iter()
        .filter(|(k, _)| *k == MarkKind::Preempt)
        .map(|&(_, t)| t)
        .collect();

    let mut pieces: Vec<Piece> = Vec::new();
    let mut cur = arrive;
    let mut prev_kind: Option<FrameKind> = None;
    for &(kind, f0, f1) in &frames {
        let f0 = f0.max(cur);
        let f1 = f1.max(f0);
        if f0 > cur {
            pieces.push(gap_piece(cur, f0, prev_kind, Some(kind), &preempts));
        }
        if f1 > f0 {
            pieces.push(frame_piece(kind, f0, f1, &r.segs));
        }
        cur = cur.max(f1);
        prev_kind = Some(kind);
    }
    if retire > cur {
        pieces.push(gap_piece(cur, retire, prev_kind, None, &preempts));
    }

    // TTFT boundary: the end of the first prefill frame (clamped order
    // preserved above); pieces are never split by it because the frame
    // partition introduced a boundary exactly there.
    let ttft_end = frames
        .iter()
        .find(|(k, _, _)| *k == FrameKind::Prefill)
        .map(|&(_, _, t1)| t1)
        .unwrap_or(arrive);

    let mut buckets = [0.0; NBUCKETS];
    let mut ttft_buckets = [0.0; NBUCKETS];
    let mut decode_buckets = [0.0; NBUCKETS];
    for p in &pieces {
        let ttft_side = p.t1 <= ttft_end + 1e-12;
        for i in 0..NBUCKETS {
            buckets[i] += p.buckets[i];
            if ttft_side {
                ttft_buckets[i] += p.buckets[i];
            } else {
                decode_buckets[i] += p.buckets[i];
            }
        }
    }
    ReqAttr {
        req,
        wall: retire - arrive,
        ttft: ttft_end - arrive,
        buckets,
        ttft_buckets,
        decode_buckets,
    }
}

/// Classify an inter-frame gap `[g0, g1]` into one whole-interval bucket.
fn gap_piece(
    g0: Time,
    g1: Time,
    prev: Option<FrameKind>,
    next: Option<FrameKind>,
    preempts: &[Time],
) -> Piece {
    let bucket = if prev.is_none() {
        Bucket::Queue
    } else if preempts.iter().any(|&t| t > g0 - 1e-12 && t <= g1 + 1e-12) {
        Bucket::PreemptWait
    } else if prev == Some(FrameKind::Prefill) && next == Some(FrameKind::Decode) {
        Bucket::Park
    } else {
        Bucket::AdmitStall
    };
    let mut b = [0.0; NBUCKETS];
    b[bucket.index()] = g1 - g0;
    Piece { t1: g1, buckets: b }
}

/// Split a frame `[f0, f1]` across the component segments anchored in
/// it.  Segment weights are rescaled to tile `[f0, own_done]` exactly
/// (own_done = the latest segment end, i.e. when the request's own work
/// finished); `[own_done, f1]` is batch-straggler wait.  A frame with no
/// segments is all scheduler-side work: prefill compute for prefill
/// frames, in-storage compute for decode frames.
fn frame_piece(kind: FrameKind, f0: Time, f1: Time, segs: &[Seg]) -> Piece {
    let mut b = [0.0; NBUCKETS];
    let mine: Vec<&Seg> = segs.iter().filter(|s| s.t0 >= f0 - 1e-12 && s.t0 < f1).collect();
    if mine.is_empty() {
        let default = match kind {
            FrameKind::Prefill => Bucket::PrefillCompute,
            FrameKind::Decode => Bucket::CsdCompute,
        };
        b[default.index()] = f1 - f0;
        return Piece { t1: f1, buckets: b };
    }
    let own_done = mine
        .iter()
        .map(|s| s.t1)
        .fold(f64::NEG_INFINITY, f64::max)
        .clamp(f0, f1);
    let own = own_done - f0;
    let wsum: f64 = mine.iter().map(|s| s.w).sum();
    if own > 0.0 && wsum > 0.0 {
        let scale = own / wsum;
        for s in &mine {
            b[s.bucket.index()] += s.w * scale;
        }
        // push the float residue into the largest bucket so the piece
        // sums exactly to its span
        let assigned: f64 = b.iter().sum();
        let largest = (0..NBUCKETS)
            .max_by(|&i, &j| b[i].total_cmp(&b[j]))
            .unwrap();
        b[largest] += own - assigned;
    }
    b[Bucket::BatchWait.index()] += f1 - own_done;
    Piece { t1: f1, buckets: b }
}

// ---------------------------------------------------------------------------
// Report rendering
// ---------------------------------------------------------------------------

impl AttrReport {
    /// Buckets of `totals` sorted descending, with labels.
    pub fn ranked(totals: &[f64; NBUCKETS]) -> Vec<(&'static str, f64)> {
        let mut v: Vec<(&'static str, f64)> =
            BUCKETS.iter().map(|b| (b.label(), totals[b.index()])).collect();
        v.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(b.0)));
        v
    }

    /// The `instinfer-attr/v1` document.
    pub fn to_json(&self) -> Json {
        let scope = |t: &[f64; NBUCKETS]| {
            let mut m = BTreeMap::new();
            for b in BUCKETS {
                m.insert(format!("{}_s", b.label()), Json::Num(t[b.index()]));
            }
            Json::Obj(m)
        };
        let mut scopes = BTreeMap::new();
        scopes.insert("e2e".to_string(), scope(&self.total));
        scopes.insert("ttft".to_string(), scope(&self.ttft_total));
        scopes.insert("decode".to_string(), scope(&self.decode_total));

        let per_req: Vec<Json> = self
            .requests
            .iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("req".to_string(), Json::Num(r.req as f64));
                m.insert("wall_s".to_string(), Json::Num(r.wall));
                m.insert("ttft_s".to_string(), Json::Num(r.ttft));
                m.insert("buckets".to_string(), scope(&r.buckets));
                Json::Obj(m)
            })
            .collect();

        let mut doc = BTreeMap::new();
        doc.insert("schema".to_string(), Json::Str("instinfer-attr/v1".to_string()));
        doc.insert("requests".to_string(), Json::Num(self.requests.len() as f64));
        doc.insert("wall_s".to_string(), Json::Num(self.wall_total));
        doc.insert("buckets".to_string(), Json::Obj(scopes));
        doc.insert("per_request".to_string(), Json::Arr(per_req));
        Json::Obj(doc)
    }

    /// Fold the aggregate into a [`MetricsRegistry`] snapshot.  Always
    /// registers every bucket name (zero when unused) so the snapshot
    /// shape is identical across configs.
    pub fn fold_into(&self, reg: &mut MetricsRegistry) {
        reg.counter("attr.requests", self.requests.len() as u64);
        reg.gauge("attr.wall_s", self.wall_total);
        for b in BUCKETS {
            reg.gauge(&format!("attr.e2e.{}_s", b.label()), self.total[b.index()]);
            reg.gauge(&format!("attr.decode.{}_s", b.label()), self.decode_total[b.index()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0)
    }

    /// hand-built timeline: queue 1s, prefill 2s (no segs), park 0.5s,
    /// decode 1.5s with flash/compute segs + batch wait, stall 1s,
    /// decode 1s (segless)
    fn synthetic() -> AttrSink {
        let mut s = AttrSink::default();
        let req = 7;
        s.marks.push((req, MarkKind::Arrive, 0.0));
        s.marks.push((req, MarkKind::Admit, 1.0));
        s.marks.push((req, MarkKind::Retire, 7.0));
        s.frames.push((req, FrameKind::Prefill, 1.0, 3.0));
        s.frames.push((req, FrameKind::Decode, 3.5, 5.0));
        s.frames.push((req, FrameKind::Decode, 6.0, 7.0));
        // decode step 1: own work ends at 4.7 (0.3 batch wait); weights
        // flash 0.8, compute 0.4 → rescaled to tile the 1.2s own span
        s.segs.push(Seg { req, bucket: Bucket::FlashRead, t0: 3.5, t1: 4.5, w: 0.8 });
        s.segs.push(Seg { req, bucket: Bucket::CsdCompute, t0: 3.6, t1: 4.7, w: 0.4 });
        s
    }

    #[test]
    fn synthetic_partition_sums_to_wall() {
        let report = extract(&synthetic());
        assert_eq!(report.requests.len(), 1);
        let r = &report.requests[0];
        assert!(close(r.wall, 7.0));
        let sum: f64 = r.buckets.iter().sum();
        assert!(close(sum, r.wall), "buckets sum {sum} != wall {}", r.wall);
        // each bucket == ttft part + decode part
        for i in 0..NBUCKETS {
            assert!(close(r.buckets[i], r.ttft_buckets[i] + r.decode_buckets[i]));
        }
        // expected pieces
        assert!(close(r.buckets[Bucket::Queue.index()], 1.0));
        assert!(close(r.buckets[Bucket::PrefillCompute.index()], 2.0));
        assert!(close(r.buckets[Bucket::Park.index()], 0.5));
        assert!(close(r.buckets[Bucket::AdmitStall.index()], 1.0));
        // decode 1: 1.2 own split 2:1 flash:compute, 0.3 batch wait;
        // decode 2 is segless → 1.0 csd_compute
        assert!(close(r.buckets[Bucket::FlashRead.index()], 0.8));
        assert!(close(r.buckets[Bucket::CsdCompute.index()], 0.4 + 1.0));
        assert!(close(r.buckets[Bucket::BatchWait.index()], 0.3));
        // ttft prefix = queue + prefill
        assert!(close(r.ttft, 3.0));
        let ttft_sum: f64 = r.ttft_buckets.iter().sum();
        assert!(close(ttft_sum, 3.0));
    }

    #[test]
    fn preempt_gap_and_unfinished_requests() {
        let mut s = synthetic();
        // the 5.0→6.0 gap now contains a preempt → PreemptWait not stall
        s.marks.push((7, MarkKind::Preempt, 5.2));
        s.marks.push((7, MarkKind::Resume, 6.0));
        // a request with no Retire is skipped, not misattributed
        s.marks.push((9, MarkKind::Arrive, 0.0));
        let report = extract(&s);
        assert_eq!(report.requests.len(), 1);
        let r = &report.requests[0];
        assert!(close(r.buckets[Bucket::PreemptWait.index()], 1.0));
        assert!(close(r.buckets[Bucket::AdmitStall.index()], 0.0));
        assert!(close(r.buckets.iter().sum::<f64>(), 7.0));
    }

    #[test]
    fn install_gates_recording_and_drains_reset() {
        assert!(!enabled());
        // hooks are no-ops when not installed
        mark(1, MarkKind::Arrive, 0.0);
        flash_read_busy(1.0, 2.0);
        gc_busy(3.0);
        assert_eq!(drain_flash(), (0.0, 0.0));
        assert_eq!(drain_gc(), 0.0);

        install();
        assert!(enabled());
        flash_read_busy(0.25, 0.5);
        flash_read_busy(0.25, 0.5);
        gc_busy(0.125);
        assert_eq!(drain_flash(), (0.5, 1.0));
        assert_eq!(drain_flash(), (0.0, 0.0), "drain resets");
        assert_eq!(drain_gc(), 0.125);
        mark(1, MarkKind::Arrive, 0.0);
        let sink = uninstall().unwrap();
        assert!(!enabled());
        assert_eq!(sink.marks.len(), 1);
    }

    #[test]
    fn report_json_and_registry_shape_are_fixed() {
        let report = extract(&synthetic());
        let j = report.to_json();
        assert_eq!(j.req("schema").unwrap().as_str(), Some("instinfer-attr/v1"));
        let e2e = j.req("buckets").unwrap().req("e2e").unwrap();
        for b in BUCKETS {
            assert!(e2e.get(&format!("{}_s", b.label())).is_some(), "{:?} missing", b);
        }
        // folding an EMPTY report registers the same names as a full one
        let mut full = MetricsRegistry::new();
        report.fold_into(&mut full);
        let mut empty = MetricsRegistry::new();
        AttrReport::default().fold_into(&mut empty);
        let names = |r: &MetricsRegistry| -> Vec<String> {
            r.iter().map(|(k, _)| k.to_string()).collect()
        };
        assert_eq!(names(&full), names(&empty));
        assert_eq!(full.len(), 2 + 2 * NBUCKETS);
        // ranked puts the biggest bucket first
        let ranked = AttrReport::ranked(&report.total);
        assert_eq!(ranked[0].0, "prefill_compute");
    }
}
