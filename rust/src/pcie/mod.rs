//! PCIe fabric model: link bandwidths, the host-mediated path (bounce
//! buffer + filesystem stack), and peer-to-peer DMA (paper §IV-D).

use crate::config::hw::PcieSpec;
use crate::sim::Time;

/// Which datapath a transfer takes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Path {
    /// GPU <-> host DRAM (Gen4 x16)
    GpuHost,
    /// SSD <-> host through the block/filesystem stack
    SsdHostFs,
    /// SSD <-> GPU bounced through host DRAM (two hops + FS stack)
    SsdGpuViaHost,
    /// SSD/CSD <-> GPU direct P2P DMA (no host copy, no FS)
    P2p,
}

/// Time for `bytes` over `path`, issued as `ios` commands.
/// Returns the transfer latency (bandwidth + per-IO software overhead).
pub fn transfer_time(pcie: &PcieSpec, path: Path, bytes: f64, ios: u64) -> Time {
    let ios = ios.max(1) as f64;
    match path {
        Path::GpuHost => bytes / pcie.gpu_host_bw + ios * 1e-6,
        Path::SsdHostFs => bytes / pcie.ssd_link_bw + ios * pcie.host_fs_io_us * 1e-6,
        Path::SsdGpuViaHost => {
            // serial hops: SSD->host (FS stack) then host->GPU; the bounce
            // buffer copy rides the slower link's shadow, so charge both
            bytes / pcie.ssd_link_bw
                + bytes / pcie.gpu_host_bw
                + ios * pcie.host_fs_io_us * 1e-6
        }
        Path::P2p => bytes / (pcie.ssd_link_bw * pcie.p2p_efficiency) + ios * pcie.p2p_io_us * 1e-6,
    }
}

/// Effective bandwidth of a path for large transfers (bytes/s).
pub fn effective_bw(pcie: &PcieSpec, path: Path) -> f64 {
    let bytes = 1e9;
    bytes / transfer_time(pcie, path, bytes, 1)
}

/// Aggregate bandwidth with `n` devices on independent links; the
/// host-mediated path does NOT scale (the FS/bounce stack serialises —
/// the paper's Fig. 13 observation), while P2P scales per-device.
pub fn multi_device_bw(pcie: &PcieSpec, path: Path, n: usize) -> f64 {
    match path {
        Path::P2p => effective_bw(pcie, path) * n as f64,
        Path::SsdHostFs | Path::SsdGpuViaHost => effective_bw(pcie, path),
        Path::GpuHost => effective_bw(pcie, path),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_ordering_matches_paper() {
        let p = PcieSpec::paper();
        // 1 GB issued as 128 KiB commands (realistic NVMe transfer size)
        let gb = 1e9;
        let ios = (1e9 / (128.0 * 1024.0)) as u64;
        let t_host = transfer_time(&p, Path::GpuHost, gb, ios);
        let t_p2p = transfer_time(&p, Path::P2p, gb, ios);
        let t_via = transfer_time(&p, Path::SsdGpuViaHost, gb, ios);
        // host DRAM path is the fastest pipe; P2P beats the bounced path
        assert!(t_host < t_p2p, "host {t_host} !< p2p {t_p2p}");
        assert!(t_p2p < t_via, "p2p {t_p2p} !< via-host {t_via}");
    }

    #[test]
    fn io_overhead_dominates_small_transfers() {
        let p = PcieSpec::paper();
        // 4 KiB x 1000 IOs through the FS stack: software cost >> wire time
        let t = transfer_time(&p, Path::SsdHostFs, 4096.0 * 1000.0, 1000);
        let wire = 4096.0 * 1000.0 / p.ssd_link_bw;
        assert!(t > 10.0 * wire);
    }

    #[test]
    fn p2p_scales_with_devices_host_path_does_not() {
        let p = PcieSpec::paper();
        let one = multi_device_bw(&p, Path::P2p, 1);
        let four = multi_device_bw(&p, Path::P2p, 4);
        assert!((four / one - 4.0).abs() < 1e-9);
        let h1 = multi_device_bw(&p, Path::SsdGpuViaHost, 1);
        let h4 = multi_device_bw(&p, Path::SsdGpuViaHost, 4);
        assert_eq!(h1, h4);
    }
}
