//! PCIe fabric model: link bandwidths, the host-mediated path (bounce
//! buffer + filesystem stack), and peer-to-peer DMA (paper §IV-D).

use crate::config::hw::PcieSpec;
use crate::sim::Time;

/// Which datapath a transfer takes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Path {
    /// GPU <-> host DRAM (Gen4 x16)
    GpuHost,
    /// SSD <-> host through the block/filesystem stack
    SsdHostFs,
    /// SSD <-> GPU bounced through host DRAM (two hops + FS stack)
    SsdGpuViaHost,
    /// SSD/CSD <-> GPU direct P2P DMA (no host copy, no FS)
    P2p,
}

/// Time for `bytes` over `path`, issued as `ios` commands.
/// Returns the transfer latency (bandwidth + per-IO software overhead).
pub fn transfer_time(pcie: &PcieSpec, path: Path, bytes: f64, ios: u64) -> Time {
    let ios = ios.max(1) as f64;
    match path {
        Path::GpuHost => bytes / pcie.gpu_host_bw + ios * 1e-6,
        Path::SsdHostFs => bytes / pcie.ssd_link_bw + ios * pcie.host_fs_io_us * 1e-6,
        Path::SsdGpuViaHost => {
            // serial hops: SSD->host (FS stack) then host->GPU; the bounce
            // buffer copy rides the slower link's shadow, so charge both
            bytes / pcie.ssd_link_bw
                + bytes / pcie.gpu_host_bw
                + ios * pcie.host_fs_io_us * 1e-6
        }
        Path::P2p => bytes / (pcie.ssd_link_bw * pcie.p2p_efficiency) + ios * pcie.p2p_io_us * 1e-6,
    }
}

/// Effective bandwidth of a path for large transfers (bytes/s).
pub fn effective_bw(pcie: &PcieSpec, path: Path) -> f64 {
    let bytes = 1e9;
    bytes / transfer_time(pcie, path, bytes, 1)
}

/// Aggregate bandwidth with `n` devices on independent links; the
/// host-mediated path does NOT scale (the FS/bounce stack serialises —
/// the paper's Fig. 13 observation), while P2P scales per-device until
/// the concurrent streams saturate the GPU-side ingress link.
pub fn multi_device_bw(pcie: &PcieSpec, path: Path, n: usize) -> f64 {
    match path {
        Path::P2p => (effective_bw(pcie, path) * n as f64).min(pcie.gpu_p2p_ingress_bw),
        Path::SsdHostFs | Path::SsdGpuViaHost => effective_bw(pcie, path),
        Path::GpuHost => effective_bw(pcie, path),
    }
}

/// One P2P transfer contending for a shared ingress link: it may start
/// moving bytes at `start`, is ceilinged by its own device link
/// (`dev_bw`), and shares the ingress with every concurrently-active
/// transfer.
#[derive(Debug, Clone, Copy)]
pub struct XferReq {
    pub start: Time,
    pub bytes: f64,
    /// the transfer's own link ceiling, bytes/s
    pub dev_bw: f64,
}

/// Max-min fair-share rates for `ceilings` streams over a `cap` link:
/// progressive filling — every stream gets an equal share of what is
/// left unless its own ceiling is lower, in which case the slack is
/// redistributed.  Conservation: the rates sum to
/// `min(cap, sum(ceilings))`.
pub fn fair_share_rates(cap: f64, ceilings: &[f64]) -> Vec<f64> {
    let n = ceilings.len();
    let mut rates = vec![0.0f64; n];
    if n == 0 {
        return rates;
    }
    let mut order: Vec<usize> = (0..n).collect();
    // fill the most-constrained streams first so their slack flows to
    // the rest (stable: ties keep index order)
    order.sort_by(|&a, &b| ceilings[a].total_cmp(&ceilings[b]));
    let mut left = cap.max(0.0);
    let mut remaining = n;
    for &i in &order {
        let fair = left / remaining as f64;
        let r = ceilings[i].max(0.0).min(fair);
        rates[i] = r;
        left -= r;
        remaining -= 1;
    }
    rates
}

/// Completion times of concurrent transfers converging on one ingress
/// link of `ingress_bw` bytes/s (the shard all-reduce: every CSD ships
/// its partial attention result to the GPU at once).  Event-driven
/// progressive filling: whenever a transfer starts or finishes, the
/// active set re-shares the link max-min fairly.  Deterministic; a
/// single transfer degenerates to `bytes / min(dev_bw, ingress_bw)`.
/// A transfer that can never complete (zero bandwidth everywhere)
/// reports `f64::INFINITY` so misconfiguration surfaces as an
/// unbounded step instead of a free transfer.
pub fn fair_share_finish(ingress_bw: f64, reqs: &[XferReq]) -> Vec<Time> {
    let n = reqs.len();
    let mut done = vec![f64::INFINITY; n];
    if n == 0 {
        return done;
    }
    let mut rem: Vec<f64> = reqs.iter().map(|r| r.bytes.max(0.0)).collect();
    let mut finished = vec![false; n];
    let mut now = reqs.iter().map(|r| r.start).fold(f64::INFINITY, f64::min);
    // each iteration finishes or activates at least one transfer
    for _guard in 0..(2 * n + 2) * (n + 1) {
        // retire transfers that have no bytes left the moment they start
        for i in 0..n {
            if !finished[i] && reqs[i].start <= now && rem[i] <= 0.0 {
                finished[i] = true;
                done[i] = now.max(reqs[i].start);
            }
        }
        let active: Vec<usize> = (0..n).filter(|&i| !finished[i] && reqs[i].start <= now).collect();
        let next_start = (0..n)
            .filter(|&i| !finished[i] && reqs[i].start > now)
            .map(|i| reqs[i].start)
            .fold(f64::INFINITY, f64::min);
        if active.is_empty() {
            if next_start.is_finite() {
                now = next_start;
                continue;
            }
            break;
        }
        let ceilings: Vec<f64> = active.iter().map(|&i| reqs[i].dev_bw).collect();
        let rates = fair_share_rates(ingress_bw, &ceilings);
        let mut dt = f64::INFINITY;
        for (k, &i) in active.iter().enumerate() {
            if rates[k] > 0.0 {
                dt = dt.min(rem[i] / rates[k]);
            }
        }
        if next_start.is_finite() {
            dt = dt.min(next_start - now);
        }
        if !dt.is_finite() {
            // zero-bandwidth stall with nothing else arriving: give up
            break;
        }
        now += dt;
        for (k, &i) in active.iter().enumerate() {
            rem[i] -= rates[k] * dt;
            if rem[i] <= 1e-6 {
                rem[i] = 0.0;
                finished[i] = true;
                done[i] = now;
            }
        }
    }
    done
}

/// Completion times for `reqs` when `background` transfers — the
/// overlapped prefill stream's KV shipping — contend for the same
/// ingress fabric (the disaggregated executor runs prefill KV shipping
/// and decode partial returns on the same links).  Returns the
/// per-request finish times and the contention delay of the slowest
/// request relative to an uncontended link.  With no background load
/// this is exactly [`fair_share_finish`] — the serialized path's
/// timing is untouched.
pub fn fair_share_contended(
    ingress_bw: f64,
    reqs: &[XferReq],
    background: &[XferReq],
) -> (Vec<Time>, Time) {
    if background.is_empty() {
        if let Some(t0) = reqs.iter().map(|r| r.start).reduce(Time::min) {
            crate::obs::pcie_arbiter(0, 0.0, t0);
        }
        return (fair_share_finish(ingress_bw, reqs), 0.0);
    }
    let free = fair_share_finish(ingress_bw, reqs);
    let mut all: Vec<XferReq> = Vec::with_capacity(reqs.len() + background.len());
    all.extend_from_slice(reqs);
    all.extend_from_slice(background);
    let contended = fair_share_finish(ingress_bw, &all);
    let fin: Vec<Time> = contended[..reqs.len()].to_vec();
    let t_free = free.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let t_cont = fin.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let delay = if t_free.is_finite() && t_cont.is_finite() {
        (t_cont - t_free).max(0.0)
    } else {
        0.0
    };
    if let Some(t0) = reqs.iter().map(|r| r.start).reduce(Time::min) {
        crate::obs::pcie_arbiter(background.len(), delay, t0);
        if delay > 0.0 {
            // dependency arrow: the background stream that induced the
            // delay feeds the arbiter's contended decision
            let bg0 = background.iter().map(|r| r.start).fold(f64::INFINITY, f64::min);
            if bg0.is_finite() {
                crate::obs::flow(
                    "contention",
                    crate::obs::TraceLevel::Device,
                    (crate::obs::PID_PCIE, crate::obs::TID_PCIE_BG_BASE, bg0),
                    (crate::obs::PID_PCIE, crate::obs::TID_PCIE_ARBITER, t0.max(bg0)),
                );
            }
        }
    }
    (fin, delay)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_ordering_matches_paper() {
        let p = PcieSpec::paper();
        // 1 GB issued as 128 KiB commands (realistic NVMe transfer size)
        let gb = 1e9;
        let ios = (1e9 / (128.0 * 1024.0)) as u64;
        let t_host = transfer_time(&p, Path::GpuHost, gb, ios);
        let t_p2p = transfer_time(&p, Path::P2p, gb, ios);
        let t_via = transfer_time(&p, Path::SsdGpuViaHost, gb, ios);
        // host DRAM path is the fastest pipe; P2P beats the bounced path
        assert!(t_host < t_p2p, "host {t_host} !< p2p {t_p2p}");
        assert!(t_p2p < t_via, "p2p {t_p2p} !< via-host {t_via}");
    }

    #[test]
    fn io_overhead_dominates_small_transfers() {
        let p = PcieSpec::paper();
        // 4 KiB x 1000 IOs through the FS stack: software cost >> wire time
        let t = transfer_time(&p, Path::SsdHostFs, 4096.0 * 1000.0, 1000);
        let wire = 4096.0 * 1000.0 / p.ssd_link_bw;
        assert!(t > 10.0 * wire);
    }

    #[test]
    fn p2p_scales_with_devices_host_path_does_not() {
        let p = PcieSpec::paper();
        let one = multi_device_bw(&p, Path::P2p, 1);
        let four = multi_device_bw(&p, Path::P2p, 4);
        assert!((four / one - 4.0).abs() < 1e-9);
        let h1 = multi_device_bw(&p, Path::SsdGpuViaHost, 1);
        let h4 = multi_device_bw(&p, Path::SsdGpuViaHost, 4);
        assert_eq!(h1, h4);
    }

    #[test]
    fn multi_device_bw_monotone_and_ingress_capped() {
        let p = PcieSpec::paper();
        let mut prev = 0.0;
        for n in 1..=32 {
            let bw = multi_device_bw(&p, Path::P2p, n);
            assert!(bw >= prev, "aggregate P2P bw must be monotone in n");
            assert!(bw <= p.gpu_p2p_ingress_bw + 1e-6, "n={n} exceeds ingress");
            prev = bw;
        }
        // enough devices saturate the GPU-side link exactly
        assert_eq!(multi_device_bw(&p, Path::P2p, 32), p.gpu_p2p_ingress_bw);
    }

    #[test]
    fn fair_share_degenerate_single_transfer_matches_effective_bw() {
        let p = PcieSpec::paper();
        let dev = p.ssd_link_bw * p.p2p_efficiency;
        let done = fair_share_finish(
            p.gpu_p2p_ingress_bw,
            &[XferReq { start: 1.0, bytes: 1e9, dev_bw: dev }],
        );
        // a lone transfer runs at its device-link ceiling: exactly the
        // wire component of `effective_bw(P2p)` (per-IO cost excluded —
        // the arbiter's callers add it before `start`)
        let want = 1.0 + 1e9 / dev;
        assert!((done[0] - want).abs() < 1e-9, "{} vs {want}", done[0]);
    }

    #[test]
    fn fair_share_conserves_aggregate_bandwidth() {
        // 4 equal transfers from t=0 whose device links together exceed
        // the ingress: the link is shared exactly, so the makespan is
        // total bytes / ingress
        let reqs: Vec<XferReq> = (0..4)
            .map(|_| XferReq { start: 0.0, bytes: 1e9, dev_bw: 2e9 })
            .collect();
        let done = fair_share_finish(4e9, &reqs);
        for &d in &done {
            assert!((d - 1.0).abs() < 1e-6, "equal sharers finish together: {d}");
        }
        // below saturation each transfer runs at its own ceiling instead
        let done = fair_share_finish(100e9, &reqs);
        for &d in &done {
            assert!((d - 0.5).abs() < 1e-6, "unsaturated: {d}");
        }
    }

    #[test]
    fn fair_share_monotone_in_contention() {
        // the same transfer finishes no earlier as more peers join
        let mk = |n: usize| -> f64 {
            let reqs: Vec<XferReq> = (0..n)
                .map(|_| XferReq { start: 0.0, bytes: 1e8, dev_bw: 3e9 })
                .collect();
            fair_share_finish(6e9, &reqs)[0]
        };
        let mut prev = 0.0;
        for n in 1..=8 {
            let d = mk(n);
            assert!(d >= prev - 1e-12, "n={n}: {d} < {prev}");
            prev = d;
        }
        // and the aggregate never exceeds the ingress
        let n = 8;
        let total = n as f64 * 1e8;
        assert!(total / mk(n) <= 6e9 + 1e-6);
    }

    #[test]
    fn fair_share_redistributes_slack_max_min() {
        // one slow device (1 GB/s) and one fast (8 GB/s) over a 6 GB/s
        // ingress: max-min gives the slow stream its full 1, the fast
        // one the remaining 5
        let reqs = [
            XferReq { start: 0.0, bytes: 1e9, dev_bw: 1e9 },
            XferReq { start: 0.0, bytes: 5e9, dev_bw: 8e9 },
        ];
        let done = fair_share_finish(6e9, &reqs);
        assert!((done[0] - 1.0).abs() < 1e-6, "slow: {}", done[0]);
        assert!((done[1] - 1.0).abs() < 1e-6, "fast: {}", done[1]);
    }

    #[test]
    fn fair_share_zero_bandwidth_reports_infinite_finish() {
        // a dead link must surface as an unbounded transfer, not a free one
        let done = fair_share_finish(0.0, &[XferReq { start: 1.0, bytes: 64.0, dev_bw: 1e9 }]);
        assert!(done[0].is_infinite());
        let done =
            fair_share_finish(1e9, &[XferReq { start: 0.0, bytes: 64.0, dev_bw: 0.0 }]);
        assert!(done[0].is_infinite());
    }

    #[test]
    fn contended_no_background_is_plain_fair_share() {
        let reqs = [
            XferReq { start: 0.0, bytes: 1e9, dev_bw: 2e9 },
            XferReq { start: 0.5, bytes: 1e9, dev_bw: 2e9 },
        ];
        let (fin, delay) = fair_share_contended(4e9, &reqs, &[]);
        assert_eq!(fin, fair_share_finish(4e9, &reqs));
        assert_eq!(delay, 0.0);
    }

    #[test]
    fn contended_background_slows_and_reports_delay() {
        // one decode return on a 2 GB/s ingress, with a concurrent
        // prefill ship over the same fabric: the return takes twice as
        // long as alone (equal fair shares), and the delay says so
        let ret = [XferReq { start: 0.0, bytes: 1e9, dev_bw: 2e9 }];
        let bg = [XferReq { start: 0.0, bytes: 4e9, dev_bw: 2e9 }];
        let (free, d0) = fair_share_contended(2e9, &ret, &[]);
        assert!((free[0] - 0.5).abs() < 1e-6);
        assert_eq!(d0, 0.0);
        let (fin, delay) = fair_share_contended(2e9, &ret, &bg);
        assert!((fin[0] - 1.0).abs() < 1e-6, "{}", fin[0]);
        assert!((delay - 0.5).abs() < 1e-6, "{delay}");
        // a background ship that starts after the return finishes must
        // not slow it at all
        let late = [XferReq { start: 5.0, bytes: 4e9, dev_bw: 2e9 }];
        let (fin, delay) = fair_share_contended(2e9, &ret, &late);
        assert!((fin[0] - 0.5).abs() < 1e-6);
        assert_eq!(delay, 0.0);
    }

    #[test]
    fn fair_share_staggered_starts_and_empty_transfers() {
        let reqs = [
            XferReq { start: 0.0, bytes: 2e9, dev_bw: 2e9 },
            XferReq { start: 1.0, bytes: 0.0, dev_bw: 2e9 },
            XferReq { start: 0.5, bytes: 1e9, dev_bw: 2e9 },
        ];
        let done = fair_share_finish(2e9, &reqs);
        // transfer 0 runs alone at 2 GB/s for 0.5 s (1 GB left), then
        // shares with transfer 2 at 1 GB/s each
        assert!((done[0] - 1.5).abs() < 1e-6, "{}", done[0]);
        assert!((done[1] - 1.0).abs() < 1e-9, "zero-byte finishes at start");
        assert!((done[2] - 1.5).abs() < 1e-6, "{}", done[2]);
    }
}
