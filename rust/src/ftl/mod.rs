//! KV-cache-oriented FTL (paper §IV-C): dual address mappings
//! (token-indexed and hidden-embedding-indexed), page-aligned group
//! packing, a DRAM group buffer for incremental decode writes, striped
//! block allocation, and GC with write-amplification accounting.
//!
//! Layouts (all FP16 on flash):
//! * token-indexed page: one group of `n` consecutive tokens for one
//!   (slot, layer, head, K|V) stream, token-major `n x d_head`;
//! * embedding-indexed page: `m` channels x `T` tokens of the K cache,
//!   channel-major, where `T = page_bytes / (m * 2)` (paper: 256-1K
//!   tokens per page for 4 KiB pages) — K is stored twice, trading cheap
//!   flash capacity for random access in both orientations;
//! * decode-generated tokens buffer in CSD DRAM until a full group seals,
//!   then program at page granularity into striped open blocks (writes
//!   therefore always fill blocks sequentially — the batch-writing rule).

pub mod layout;

use crate::config::hw::{FlashPlacement, FlashSpec};
use crate::flash::{BlockAddr, FlashArray, Ppa};
use crate::sim::Time;
use anyhow::{anyhow, bail, Result};
use layout::{decode_rows, encode_rows};
use std::collections::{HashMap, VecDeque};

/// One KV stream = one attention head of one layer of one sequence slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StreamKey {
    pub slot: u32,
    pub layer: u16,
    pub head: u16,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum KvKind {
    K,
    V,
}

/// What a physical page currently holds (reverse map for GC).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PageTag {
    Token { key: StreamKey, kind: KvKind, group: u32 },
    Emb { key: StreamKey, eg: u16, tpage: u32 },
}

#[derive(Debug, Clone, Copy)]
pub struct FtlConfig {
    /// head dimension (channels per token row)
    pub d_head: usize,
    /// embedding-group size: channels per embedding-indexed page
    pub m: usize,
    /// token-group size: tokens per token-indexed page
    pub n: usize,
}

impl FtlConfig {
    /// The opt-micro head shape every unit test and bench uses (d_head
    /// 32, m=4 embedding channels per page, n=8 tokens per group) — the
    /// one shared constructor call sites used to copy-paste as a
    /// literal.
    pub fn micro_head() -> Self {
        FtlConfig { d_head: 32, m: 4, n: 8 }
    }

    pub fn tokens_per_emb_page(&self, spec: &FlashSpec) -> usize {
        spec.page_bytes / (self.m * 2)
    }

    pub fn validate(&self, spec: &FlashSpec) -> Result<()> {
        if self.n * self.d_head * 2 > spec.page_bytes {
            bail!(
                "token group {}x{} (FP16) exceeds page size {}",
                self.n, self.d_head, spec.page_bytes
            );
        }
        if self.d_head % self.m != 0 {
            bail!("d_head {} not a multiple of embedding group {}", self.d_head, self.m);
        }
        if self.tokens_per_emb_page(spec) == 0 {
            bail!("embedding group {} too large for page", self.m);
        }
        Ok(())
    }
}

/// DRAM-resident state per stream: the unsealed tail + running v̄.
#[derive(Debug, Clone, Default)]
struct StreamBuf {
    /// total tokens appended so far
    count: usize,
    /// K/V rows since the last sealed token group (each d_head floats)
    k_tail: Vec<f32>,
    v_tail: Vec<f32>,
    /// K rows since the last sealed embedding page row-block
    emb_tail: Vec<f32>,
    /// running sum of (f16-quantised) V rows for v̄
    vbar_sum: Vec<f32>,
}

/// Per-step flash I/O statistics (what the bandwidth model charges).
#[derive(Debug, Clone, Default)]
pub struct FtlCounters {
    pub gc_relocations: u64,
    pub host_bytes: u64,
    pub tail_hits: u64,
    pub page_fetches: u64,
    /// token-group pages copied up into a DRAM tier (reads stay timed)
    pub promotions: u64,
    /// DRAM-tier copies dropped again (flash remains the home copy)
    pub demotions: u64,
    /// sealed token groups whose flash pages were freed outright
    /// (drop-on-resume reclaim)
    pub dropped_groups: u64,
    /// drops/frees that merely released one reference to a page other
    /// streams (or the prefix index) still own — no flash reclaimed
    pub shared_releases: u64,
    /// prefixes registered in the content-addressed index
    pub prefix_registrations: u64,
    /// cached prefixes attached to a new stream's mapping
    pub prefix_attaches: u64,
    /// local tokens served by attachment instead of host writes
    pub prefix_tokens_attached: u64,
    /// blocks retired after a permanent read failure (never reused)
    pub bad_blocks: u64,
}

/// One sealed token group fetched back from the data path: its first
/// token index, decoded rows, and the completion time of *this group's*
/// page read (tail groups complete at issue time).  The per-group times
/// feed the engine's read-compute pipelining; `base`-sorted.
#[derive(Debug, Clone)]
pub struct GroupFetch {
    pub base: usize,
    pub rows: Vec<f32>,
    pub done: Time,
}

/// Raw image of one KV stream — sealed page payloads plus the DRAM
/// stream state — produced by [`KvFtl::export_stream`] and consumed by
/// [`KvFtl::import_stream`] for bit-exact replica restore.
#[derive(Debug, Clone)]
pub struct StreamExport {
    buf: StreamBuf,
    token_pages: Vec<(KvKind, u32, Vec<u8>)>,
    emb_pages: Vec<(u16, u32, Vec<u8>)>,
}

impl StreamExport {
    /// Payload bytes carried by this export (the peer-to-peer restore
    /// traffic it represents on the wire).
    pub fn bytes(&self) -> usize {
        self.token_pages.iter().map(|(_, _, d)| d.len()).sum::<usize>()
            + self.emb_pages.iter().map(|(_, _, d)| d.len()).sum::<usize>()
    }
}

/// Pseudo-slot ids for the content-addressed prefix index live far above
/// any scheduler slot, so a registration's stream keys can never collide
/// with a live sequence.
pub const PREFIX_SLOT_BASE: u32 = u32::MAX / 2;

/// Registered prefixes kept per device (LRU beyond this).
const PREFIX_INDEX_CAP: usize = 32;

/// Chain hashes over `n`-token chunks of a prompt: hash `i` is FNV-1a
/// over the little-endian bytes of the first `(i + 1) * n` token ids,
/// so a longest-prefix lookup is one probe per complete group and two
/// prompts share a boundary hash iff they share the tokens before it.
pub fn prefix_hashes(prompt: &[i32], n: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(prompt.len() / n.max(1));
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for (i, &t) in prompt.iter().enumerate() {
        for b in (t as u32).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        if (i + 1) % n == 0 {
            out.push(h);
        }
    }
    out
}

/// One registered prefix: which (layer, head) streams it covers, how
/// many local tokens its pseudo-slot aliases, and the boundary hashes
/// it owns in the index (removed when the registration is evicted).
#[derive(Debug, Clone)]
struct PrefixReg {
    streams: Vec<(u16, u16)>,
    tokens: usize,
    hashes: Vec<u64>,
    last_use: u64,
}

pub struct KvFtl {
    pub cfg: FtlConfig,
    pub array: FlashArray,
    tokens_per_emb_page: usize,
    /// free blocks per channel (striping pool)
    free: Vec<VecDeque<BlockAddr>>,
    /// open (partially programmed) block per allocation unit: one per
    /// channel under the legacy channel placement, one per (channel,
    /// die) — indexed `ch * dies_per_channel + die` — under the
    /// die-interleaved placement
    open: Vec<Option<BlockAddr>>,
    /// per-channel die round-robin cursor (die placement only):
    /// successive pages staged on a channel rotate across its dies
    next_die: Vec<usize>,
    token_map: HashMap<(StreamKey, KvKind, u32), Ppa>,
    emb_map: HashMap<(StreamKey, u16, u32), Ppa>,
    rev: HashMap<Ppa, PageTag>,
    /// co-owner tags of physically shared pages (cross-request prefix
    /// caching).  Absent => `rev` is the page's sole owner; present =>
    /// every tag in the vector maps to the page and `rev` holds the
    /// canonical tag (`refs[0]`) GC uses for bookkeeping.
    shared: HashMap<Ppa, Vec<PageTag>>,
    /// content-addressed prefix index: boundary hash -> (pseudo-slot,
    /// local tokens at that boundary)
    prefix_index: HashMap<u64, (u32, usize)>,
    prefix_regs: HashMap<u32, PrefixReg>,
    next_pslot: u32,
    prefix_clock: u64,
    /// valid-page count per block
    block_valid: Vec<u32>,
    streams: HashMap<StreamKey, StreamBuf>,
    pub counters: FtlCounters,
    /// guards against GC re-entrancy (relocation needs target blocks; if
    /// none exist the device is genuinely full and we must error, not
    /// recurse)
    gc_active: bool,
    /// retired bad blocks — out of the free pool for good
    bad: Vec<BlockAddr>,
}

impl KvFtl {
    pub fn new(spec: FlashSpec, cfg: FtlConfig) -> Result<Self> {
        cfg.validate(&spec)?;
        let array = FlashArray::new(spec);
        let geo = array.geo;
        let mut free: Vec<VecDeque<BlockAddr>> =
            (0..spec.channels).map(|_| VecDeque::new()).collect();
        for b in 0..geo.total_blocks() {
            let ba = BlockAddr(b);
            free[geo.block_channel(ba)].push_back(ba);
        }
        let units = match spec.path.placement {
            FlashPlacement::Channel => spec.channels,
            FlashPlacement::Die => spec.channels * spec.dies_per_channel,
        };
        Ok(KvFtl {
            tokens_per_emb_page: cfg.tokens_per_emb_page(&spec),
            cfg,
            array,
            free,
            open: vec![None; units],
            next_die: vec![0; spec.channels],
            token_map: HashMap::new(),
            emb_map: HashMap::new(),
            rev: HashMap::new(),
            shared: HashMap::new(),
            prefix_index: HashMap::new(),
            prefix_regs: HashMap::new(),
            next_pslot: PREFIX_SLOT_BASE,
            prefix_clock: 0,
            block_valid: vec![0; geo.total_blocks()],
            streams: HashMap::new(),
            counters: FtlCounters::default(),
            gc_active: false,
            bad: Vec::new(),
        })
    }

    pub fn tokens_per_emb_page(&self) -> usize {
        self.tokens_per_emb_page
    }

    // ---- block allocation / GC -------------------------------------------

    /// Pull a free block on `ch`; `die` steers the allocation to one
    /// die of the channel (die placement), `None` takes the channel
    /// pool's head (the legacy channel placement).  The die is a
    /// preference, not a capacity constraint: when the preferred die is
    /// out of blocks the allocation falls back to any die on the
    /// channel.
    ///
    /// One free block per channel is held back as the GC relocation
    /// reserve.  GC fires exactly when an open block has just filled,
    /// so without the reserve a victim's valid pages would have nowhere
    /// to land (the pre-refactor allocator dead-ended here).  Normal
    /// allocation therefore garbage-collects early and keeps collecting
    /// until the pool is back above the reserve — a relocation may
    /// consume it, and each round returns its erased victim — before
    /// handing out the caller's block.
    fn alloc_block(
        &mut self,
        ch: usize,
        die: Option<usize>,
        at: Time,
    ) -> Result<(BlockAddr, Time)> {
        if self.gc_active {
            // relocation allocation: may take the reserve
            return self.pop_free(ch, die).map(|b| (b, at)).ok_or_else(|| {
                anyhow!("channel {ch}: out of blocks during GC relocation (device full)")
            });
        }
        let geo = self.array.geo;
        let mut t = at;
        loop {
            if self.free[ch].len() > 1 {
                if let Some(b) = self.pop_free(ch, die) {
                    return Ok((b, t));
                }
            }
            // GC: reclaim the most-invalid full block on this channel.
            // Fully valid blocks are not candidates — relocating them
            // frees nothing.  A FULL block lingering in an open slot is
            // fair game (the slot is cleared when the victim is
            // erased); only the programmed==pages_per_block filter
            // keeps actively-written blocks off-limits.
            let candidate = (0..geo.total_blocks())
                .map(BlockAddr)
                .filter(|&b| geo.block_channel(b) == ch)
                .filter(|&b| self.array.programmed_pages(b) == geo.pages_per_block)
                .filter(|&b| (self.block_valid[b.0] as usize) < geo.pages_per_block)
                .min_by_key(|&b| self.block_valid[b.0]);
            let victim = candidate
                .ok_or_else(|| anyhow!("channel {ch}: no reclaimable block (device full)"))?;
            self.gc_active = true;
            let res = self.gc_block(victim, at);
            self.gc_active = false;
            t = t.max(res?);
        }
    }

    /// Take the first free block of `ch`, preferring the given die (pool
    /// order, so the legacy `None` path pops exactly the pre-refactor
    /// block sequence).
    fn pop_free(&mut self, ch: usize, die: Option<usize>) -> Option<BlockAddr> {
        if let Some(d) = die {
            let geo = self.array.geo;
            if let Some(pos) = self.free[ch].iter().position(|&b| geo.block_die(b) == d) {
                return self.free[ch].remove(pos);
            }
        }
        self.free[ch].pop_front()
    }

    /// Relocate valid pages out of `victim`, erase it, return completion.
    ///
    /// The relocation reads are all issued at `at` — the victim's die
    /// pipeline serializes them at tR cadence — and each page
    /// re-programs through the normal placement path as soon as its
    /// read lands, so moves targeting different dies overlap.  Only the
    /// per-block program order (the NAND sequential-program rule)
    /// serializes, on the destination open block's pipeline.  The erase
    /// waits for every move.
    fn gc_block(&mut self, victim: BlockAddr, at: Time) -> Result<Time> {
        let valid = self.array.valid_pages(victim);
        let mut moves: Vec<(Ppa, Vec<PageTag>, Vec<u8>, Time)> = Vec::with_capacity(valid.len());
        for pi in valid {
            let ppa = self.array.geo.page_of(victim, pi);
            // a shared page moves ONCE; every co-owner's mapping follows
            let tags: Vec<PageTag> = match self.shared.get(&ppa) {
                Some(refs) => refs.clone(),
                None => match self.rev.get(&ppa) {
                    Some(t) => vec![*t],
                    None => continue, // untagged (shouldn't happen) — drop it
                },
            };
            let (data, rt) = {
                let (d, rt) = self.array.read(ppa, at)?;
                (d.to_vec(), rt)
            };
            moves.push((ppa, tags, data, rt));
        }
        let mut t = at;
        for (ppa, tags, data, rt) in moves {
            // re-program on the same channel (keeps striping invariant;
            // die placement re-rotates via the cursor, preserving the
            // round-robin spread)
            let ch = self.array.geo.page_channel(ppa);
            let (new_ppa, wt) = self.program_page(ch, &data, rt)?;
            self.shared.remove(&ppa);
            self.rev.remove(&ppa);
            self.retag_all(&tags, new_ppa);
            self.array.invalidate(ppa);
            self.block_valid[victim.0] = self.block_valid[victim.0].saturating_sub(1);
            self.counters.gc_relocations += 1;
            t = t.max(wt);
        }
        let te = self.array.erase(victim, t)?;
        crate::obs::ftl_gc(self.counters.gc_relocations, te);
        crate::obs::attr::gc_busy(te - at);
        self.block_valid[victim.0] = 0;
        // the victim may still sit in an open slot (a full block lingers
        // there until the unit's next program) — clear it so the erased
        // block is never written through two handles at once
        for o in self.open.iter_mut() {
            if *o == Some(victim) {
                *o = None;
            }
        }
        let ch = self.array.geo.block_channel(victim);
        self.free[ch].push_back(victim);
        Ok(te)
    }

    /// Retire a block flagged bad by a permanent read failure: relocate
    /// its valid pages with full GC discipline (refcounts, prefix
    /// sharing, co-owner retagging), erase it, then pull it out of the
    /// free pool for good.  Idempotent per block.
    pub fn retire_block(&mut self, victim: BlockAddr, at: Time) -> Result<Time> {
        if self.bad.contains(&victim) {
            return Ok(at);
        }
        self.gc_active = true;
        let res = self.gc_block(victim, at);
        self.gc_active = false;
        let te = res?;
        // gc_block returned the erased victim to the free pool — a bad
        // block must never be handed out again
        let ch = self.array.geo.block_channel(victim);
        if let Some(pos) = self.free[ch].iter().position(|&b| b == victim) {
            self.free[ch].remove(pos);
        }
        self.bad.push(victim);
        self.counters.bad_blocks += 1;
        Ok(te)
    }

    /// Drain the array's pending bad-block flags (raised by permanent
    /// read failures) and retire each — called at command boundaries so
    /// retirement never interleaves with an in-flight batch read.
    fn drain_retirements(&mut self, at: Time) -> Result<Time> {
        let mut t = at;
        let pending = self.array.take_pending_retire();
        for b in pending {
            t = t.max(self.retire_block(b, at)?);
        }
        Ok(t)
    }

    /// Point every owner tag at a page's new location.  The physical
    /// page is counted once (`block_valid`, `rev`); co-owner tags beyond
    /// the first live in `shared`.
    fn retag_all(&mut self, tags: &[PageTag], new_ppa: Ppa) {
        for tag in tags {
            match *tag {
                PageTag::Token { key, kind, group } => {
                    self.token_map.insert((key, kind, group), new_ppa);
                }
                PageTag::Emb { key, eg, tpage } => {
                    self.emb_map.insert((key, eg, tpage), new_ppa);
                }
            }
        }
        self.rev.insert(new_ppa, tags[0]);
        if tags.len() > 1 {
            self.shared.insert(new_ppa, tags.to_vec());
        }
        self.block_valid[self.array.geo.block_of(new_ppa).0] += 1;
    }

    /// Add a co-owner tag to a mapped page (prefix sharing).  The page's
    /// existing `rev` tag seeds the owner list on first sharing.
    fn add_ref(&mut self, ppa: Ppa, tag: PageTag) {
        let canon = self.rev.get(&ppa).copied();
        let refs = self.shared.entry(ppa).or_insert_with(|| canon.into_iter().collect());
        if !refs.contains(&tag) {
            refs.push(tag);
        }
    }

    /// Drop one owner tag from a page.  Returns true when the page has
    /// no owners left — only then may the caller invalidate it and
    /// reclaim the flash space (copy-on-write discipline: sharers never
    /// free each other's data).
    fn release_ref(&mut self, ppa: Ppa, tag: PageTag) -> bool {
        if let Some(refs) = self.shared.get_mut(&ppa) {
            refs.retain(|t| *t != tag);
            match refs.len() {
                0 => {
                    self.shared.remove(&ppa);
                    self.rev.remove(&ppa);
                    true
                }
                n => {
                    let first = refs[0];
                    if n == 1 {
                        // back to an exclusive owner
                        self.shared.remove(&ppa);
                    }
                    self.rev.insert(ppa, first);
                    self.counters.shared_releases += 1;
                    false
                }
            }
        } else {
            self.rev.remove(&ppa);
            true
        }
    }

    /// Program one page on `ch`, picking the open block per the
    /// configured placement: the channel's single open block (legacy),
    /// or the next die in the channel's round-robin rotation so a
    /// stream's consecutive pages stripe across the channel's dies.
    fn program_page(&mut self, ch: usize, data: &[u8], at: Time) -> Result<(Ppa, Time)> {
        let (unit, die) = match self.array.spec.path.placement {
            FlashPlacement::Channel => (ch, None),
            FlashPlacement::Die => {
                let dpc = self.array.spec.dies_per_channel;
                let ppb = self.array.geo.pages_per_block;
                let mut d = self.next_die[ch];
                if self.gc_active {
                    // steer relocations to a die whose open block still
                    // has room, so one reserve block covers a whole GC
                    // round (blind rotation could demand a fresh block
                    // on every die of the channel mid-GC)
                    for off in 0..dpc {
                        let cand = (d + off) % dpc;
                        if let Some(b) = self.open[ch * dpc + cand] {
                            if self.array.programmed_pages(b) < ppb {
                                d = cand;
                                break;
                            }
                        }
                    }
                }
                self.next_die[ch] = (d + 1) % dpc;
                (ch * dpc + d, Some(d))
            }
        };
        let geo = self.array.geo;
        let mut t = at;
        let block = match self.open[unit] {
            Some(b) if self.array.programmed_pages(b) < geo.pages_per_block => b,
            _ => {
                let (b, ta) = self.alloc_block(ch, die, at)?;
                t = ta;
                match self.open[unit] {
                    // the alloc may have run GC whose relocations
                    // re-opened this very unit — write into that block
                    // instead of evicting it (which would leak its
                    // remaining pages) and return the fresh block to
                    // the head of the pool
                    Some(ob) if self.array.programmed_pages(ob) < geo.pages_per_block => {
                        self.free[ch].push_front(b);
                        ob
                    }
                    _ => {
                        self.open[unit] = Some(b);
                        b
                    }
                }
            }
        };
        let (ppa, done) = self.array.program_next(block, data, t)?;
        Ok((ppa, done))
    }

    fn stage_page(&mut self, tag: PageTag, ch: usize, data: &[u8], at: Time) -> Result<Time> {
        // drop any prior mapping (re-seal after GC-free never happens for
        // KV streams, but keep the FTL self-consistent)
        let prior = match tag {
            PageTag::Token { key, kind, group } => self.token_map.get(&(key, kind, group)).copied(),
            PageTag::Emb { key, eg, tpage } => self.emb_map.get(&(key, eg, tpage)).copied(),
        };
        if let Some(old) = prior {
            if self.release_ref(old, tag) {
                self.array.invalidate(old);
                self.block_valid[self.array.geo.block_of(old).0] =
                    self.block_valid[self.array.geo.block_of(old).0].saturating_sub(1);
            }
        }
        let (ppa, t) = self.program_page(ch, data, at)?;
        self.retag_all(&[tag], ppa);
        Ok(t)
    }

    // ---- write path --------------------------------------------------------

    /// Append one token's K and V rows for a stream.  Rows are quantised to
    /// FP16 at the DRAM buffer boundary (that is what will live on flash).
    /// Seals and programs any group that fills.  Returns completion time of
    /// flash activity (or `at` if everything stayed in DRAM).
    pub fn append_token(
        &mut self,
        key: StreamKey,
        k_row: &[f32],
        v_row: &[f32],
        at: Time,
    ) -> Result<Time> {
        let d = self.cfg.d_head;
        if k_row.len() != d || v_row.len() != d {
            bail!("append_token: row length {} != d_head {}", k_row.len(), d);
        }
        let n = self.cfg.n;
        let t_emb = self.tokens_per_emb_page;
        self.counters.host_bytes += (2 * d * 2) as u64;

        // quantise at the buffer boundary
        let kq: Vec<f32> = k_row.iter().map(|&x| layout::q16(x)).collect();
        let vq: Vec<f32> = v_row.iter().map(|&x| layout::q16(x)).collect();

        let buf = self.streams.entry(key).or_insert_with(|| StreamBuf {
            vbar_sum: vec![0.0; d],
            ..Default::default()
        });
        for c in 0..d {
            buf.vbar_sum[c] += vq[c];
        }
        buf.k_tail.extend_from_slice(&kq);
        buf.v_tail.extend_from_slice(&vq);
        buf.emb_tail.extend_from_slice(&kq);
        buf.count += 1;
        let count = buf.count;

        let mut done = at;
        // seal a token group?
        if buf.k_tail.len() == n * d {
            let group = (count / n - 1) as u32;
            let kpage = encode_rows(&self.streams[&key].k_tail);
            let vpage = encode_rows(&self.streams[&key].v_tail);
            let chans = self.array.spec.channels;
            // stripe this head's groups across channels; K and V of the same
            // group land on different channels so they can stream in parallel
            let ch_k = (key.head as usize + group as usize) % chans;
            let ch_v = (key.head as usize + group as usize + 1) % chans;
            let tag_k = PageTag::Token { key, kind: KvKind::K, group };
            let t1 = self.stage_page(tag_k, ch_k, &kpage, at)?;
            let tag_v = PageTag::Token { key, kind: KvKind::V, group };
            let t2 = self.stage_page(tag_v, ch_v, &vpage, at)?;
            done = done.max(t1).max(t2);
            let buf = self.streams.get_mut(&key).unwrap();
            buf.k_tail.clear();
            buf.v_tail.clear();
        }
        // seal an embedding-page row block?
        if self.streams[&key].emb_tail.len() == t_emb * d {
            let tpage = (count / t_emb - 1) as u32;
            let rows = std::mem::take(&mut self.streams.get_mut(&key).unwrap().emb_tail);
            let chans = self.array.spec.channels;
            for eg in 0..(d / self.cfg.m) {
                let page = layout::encode_emb_page(&rows, d, eg, self.cfg.m, t_emb);
                let ch = (key.head as usize + eg + tpage as usize) % chans;
                let t = self.stage_page(PageTag::Emb { key, eg: eg as u16, tpage }, ch, &page, at)?;
                done = done.max(t);
            }
        }
        Ok(done)
    }

    /// Bulk-append a whole prefill layer for one stream (s tokens).
    pub fn append_prefill(
        &mut self,
        key: StreamKey,
        k_rows: &[f32],
        v_rows: &[f32],
        at: Time,
    ) -> Result<Time> {
        let d = self.cfg.d_head;
        let s = k_rows.len() / d;
        let mut t = at;
        for i in 0..s {
            let kr = &k_rows[i * d..(i + 1) * d];
            let vr = &v_rows[i * d..(i + 1) * d];
            t = t.max(self.append_token(key, kr, vr, at)?);
        }
        Ok(t)
    }

    pub fn tokens_appended(&self, key: StreamKey) -> usize {
        self.streams.get(&key).map_or(0, |b| b.count)
    }

    /// Running compensation vector v̄ = mean of all appended (quantised) V
    /// rows — maintained incrementally, as the engine does on writes.
    pub fn vbar(&self, key: StreamKey) -> Option<Vec<f32>> {
        self.streams.get(&key).map(|b| {
            let inv = 1.0 / b.count.max(1) as f32;
            b.vbar_sum.iter().map(|&s| s * inv).collect()
        })
    }

    // ---- read path ---------------------------------------------------------

    /// Fetch token groups (dual-step loading, step 8): whole pages stream
    /// from flash through the configured issue scheduler; groups still in
    /// the DRAM tail cost no flash I/O.  The single read entry point:
    /// each [`GroupFetch`] reports its first token index, decoded rows,
    /// and when *its* page landed (so the engine can pipeline kernel work
    /// behind the remaining reads — callers that don't care drop `done`),
    /// plus the batch completion time.
    pub fn fetch_token_groups(
        &mut self,
        key: StreamKey,
        kind: KvKind,
        groups: &[usize],
        at: Time,
    ) -> Result<(Vec<GroupFetch>, Time)> {
        let d = self.cfg.d_head;
        let n = self.cfg.n;
        let count = self.tokens_appended(key);
        let sealed_groups = count / n;
        let mut ppas = Vec::new();
        let mut out = Vec::with_capacity(groups.len());
        for &g in groups {
            if g < sealed_groups {
                let ppa = *self
                    .token_map
                    .get(&(key, kind, g as u32))
                    .ok_or_else(|| anyhow!("missing token map entry g={g}"))?;
                ppas.push((g, ppa));
            } else {
                // tail group: serve from DRAM
                let buf = self.streams.get(&key).ok_or_else(|| anyhow!("unknown stream"))?;
                let tail = match kind {
                    KvKind::K => &buf.k_tail,
                    KvKind::V => &buf.v_tail,
                };
                let base_tok = sealed_groups * n;
                if g != sealed_groups {
                    bail!("requested group {g} beyond appended tokens {count}");
                }
                let mut rows = tail.clone();
                rows.resize(n * d, 0.0);
                out.push(GroupFetch { base: base_tok, rows, done: at });
                self.counters.tail_hits += 1;
            }
        }
        let batch: Vec<Ppa> = ppas.iter().map(|&(_, p)| p).collect();
        let times = self.array.read_batch_times(&batch, at)?;
        let done = times.iter().fold(at, |a, &t| a.max(t));
        self.counters.page_fetches += batch.len() as u64;
        for (i, (g, ppa)) in ppas.into_iter().enumerate() {
            let rows = decode_rows(self.array.page_data(ppa)?, n * d);
            out.push(GroupFetch { base: g * n, rows, done: times[i] });
        }
        out.sort_by_key(|g| g.base);
        self.drain_retirements(done)?;
        Ok((out, done))
    }

    /// Fetch selected K channels for tokens [0, len) (dual-step loading,
    /// step 2): reads the embedding-indexed pages covering the requested
    /// channels (one page serves all m channels of its group — requests in
    /// the same group share the fetch), serves the tail from DRAM.
    /// Returns per-requested-channel vectors of `len` values.
    pub fn fetch_emb_channels(
        &mut self,
        key: StreamKey,
        channels: &[usize],
        len: usize,
        at: Time,
    ) -> Result<(Vec<Vec<f32>>, Time)> {
        let d = self.cfg.d_head;
        let m = self.cfg.m;
        let t_emb = self.tokens_per_emb_page;
        let count = self.tokens_appended(key);
        if len > count {
            bail!("fetch_emb_channels: len {len} > appended {count}");
        }
        let sealed_tpages = count / t_emb;
        let need_tpages = len.div_ceil(t_emb).min(sealed_tpages);

        // unique pages to fetch (shared across channels in the same group)
        let mut wanted: Vec<(u16, u32)> = Vec::new();
        for &c in channels {
            if c >= d {
                bail!("channel {c} out of range");
            }
            let eg = (c / m) as u16;
            for tp in 0..need_tpages {
                if !wanted.contains(&(eg, tp as u32)) {
                    wanted.push((eg, tp as u32));
                }
            }
        }
        let mut ppas = Vec::with_capacity(wanted.len());
        for &(eg, tp) in &wanted {
            let ppa = *self
                .emb_map
                .get(&(key, eg, tp))
                .ok_or_else(|| anyhow!("missing emb map entry eg={eg} tp={tp}"))?;
            ppas.push(ppa);
        }
        let done = self.array.read_batch(&ppas, at)?;
        self.counters.page_fetches += ppas.len() as u64;
        self.drain_retirements(done)?;

        let buf = self.streams.get(&key).ok_or_else(|| anyhow!("unknown stream"))?;
        let emb_tail = buf.emb_tail.clone();
        let tail_base = sealed_tpages * t_emb;

        let mut out = Vec::with_capacity(channels.len());
        for &c in channels {
            let eg = (c / m) as u16;
            let off = c % m;
            let mut vals = Vec::with_capacity(len);
            for tp in 0..need_tpages {
                let idx = wanted.iter().position(|&w| w == (eg, tp as u32)).unwrap();
                let page = self.array.page_data(ppas[idx])?;
                let lane = layout::decode_emb_lane(page, off, t_emb);
                let take = (len - vals.len()).min(t_emb);
                vals.extend_from_slice(&lane[..take]);
                if vals.len() == len {
                    break;
                }
            }
            // tail from DRAM
            while vals.len() < len {
                let t = tail_base + (vals.len() - tail_base);
                let row_in_tail = t - tail_base;
                vals.push(emb_tail[row_in_tail * d + c]);
            }
            out.push(vals);
        }
        Ok((out, done))
    }

    // ---- tier interface (page-granularity promote/demote) ------------------
    //
    // The kvtier hot tier fronts this FTL: `promote_group` is the timed
    // page read that fills a DRAM-tier copy, `demote_group` logs the
    // copy's drop (flash stays the home — eviction is metadata-only),
    // and `free_token_group` reclaims a sealed group outright when the
    // scheduler's drop-on-resume path decides its tokens are dead.

    /// Sealed token groups currently appended for a stream (the tail
    /// group beyond this is served from the DRAM stream buffer).
    pub fn sealed_groups(&self, key: StreamKey) -> usize {
        self.tokens_appended(key) / self.cfg.n
    }

    /// Every stream of a sequence slot, in deterministic order.
    pub fn stream_keys(&self, slot: u32) -> Vec<StreamKey> {
        let mut keys: Vec<StreamKey> =
            self.streams.keys().filter(|k| k.slot == slot).copied().collect();
        keys.sort();
        keys
    }

    /// Token-indexed pages currently mapped for a slot (tests use this
    /// to check that promote/demote churn conserves page counts).
    pub fn mapped_token_pages(&self, slot: u32) -> usize {
        self.token_map.keys().filter(|(k, _, _)| k.slot == slot).count()
    }

    /// Total *physical* flash pages currently mapped — token (K/V)
    /// pages AND the dual-K embedding pages, which are ~half again on
    /// top of K/V.  This is the per-shard cold-tier footprint the
    /// scheduler's capacity invariants check under striping; counting
    /// map entries instead would bill a prefix-shared page once per
    /// sharer and starve admission of exactly the capacity that sharing
    /// recovered.  (With no sharing this equals the map entry count.)
    pub fn mapped_pages_total(&self) -> usize {
        self.rev.len()
    }

    /// Promote one sealed token group into a DRAM tier: a timed page
    /// read returning the decoded rows.  The mapping is untouched —
    /// flash remains the home copy.
    pub fn promote_group(
        &mut self,
        key: StreamKey,
        kind: KvKind,
        group: usize,
        at: Time,
    ) -> Result<(Vec<f32>, Time)> {
        let ppa = *self
            .token_map
            .get(&(key, kind, group as u32))
            .ok_or_else(|| anyhow!("promote of unmapped group {group} for {key:?}"))?;
        let want = self.cfg.n * self.cfg.d_head;
        let (rows, t) = {
            let (data, t) = self.array.read(ppa, at)?;
            (decode_rows(data, want), t)
        };
        self.counters.page_fetches += 1;
        self.counters.promotions += 1;
        self.drain_retirements(t)?;
        Ok((rows, t))
    }

    /// Record that a DRAM-tier copy of this group was dropped.  No flash
    /// activity: the home copy stays mapped.
    pub fn demote_group(&mut self, key: StreamKey, kind: KvKind, group: usize) {
        if self.token_map.contains_key(&(key, kind, group as u32)) {
            self.counters.demotions += 1;
        }
    }

    /// Free both K and V pages of one sealed token group (the sequence
    /// dropped these tokens for good — H2O-style drop-on-resume).  The
    /// embedding-indexed K copy stays mapped: it packs many tokens per
    /// page and is reclaimed wholesale at `free_slot`.  Idempotent.
    ///
    /// A group whose pages are prefix-shared only releases this stream's
    /// reference — the flash pages stay for the other owners, and the
    /// call returns false (`dropped_groups` counts real reclaims only).
    pub fn free_token_group(&mut self, key: StreamKey, group: usize) -> bool {
        let mut freed = false;
        for kind in [KvKind::K, KvKind::V] {
            if let Some(ppa) = self.token_map.remove(&(key, kind, group as u32)) {
                let tag = PageTag::Token { key, kind, group: group as u32 };
                if self.release_ref(ppa, tag) {
                    self.array.invalidate(ppa);
                    let b = self.array.geo.block_of(ppa).0;
                    self.block_valid[b] = self.block_valid[b].saturating_sub(1);
                    freed = true;
                }
            }
        }
        if freed {
            self.counters.dropped_groups += 1;
        }
        freed
    }

    // ---- replica export/import (fault recovery) ----------------------------
    //
    // A CSD that dies takes its FTL with it; the replicated recovery
    // policy restores the lost streams from a peer's mirror.  Export is
    // raw page surgery — sealed page images plus the DRAM stream state —
    // so the import reconstructs the stream bit-exactly (same quantised
    // rows, same tail, same v̄), not a lossy re-append.

    /// Read every sealed page of one stream off flash (timed, on this
    /// device's die/channel FIFOs) and snapshot its DRAM state.
    pub fn export_stream(&mut self, key: StreamKey, at: Time) -> Result<(StreamExport, Time)> {
        let buf = self
            .streams
            .get(&key)
            .ok_or_else(|| anyhow!("export of unknown stream {key:?}"))?
            .clone();
        let mut tkeys: Vec<(KvKind, u32)> = self
            .token_map
            .keys()
            .filter(|(k, _, _)| *k == key)
            .map(|&(_, kind, g)| (kind, g))
            .collect();
        tkeys.sort();
        let mut ekeys: Vec<(u16, u32)> = self
            .emb_map
            .keys()
            .filter(|(k, _, _)| *k == key)
            .map(|&(_, eg, tp)| (eg, tp))
            .collect();
        ekeys.sort();
        let ppas: Vec<Ppa> = tkeys
            .iter()
            .map(|&(kind, g)| self.token_map[&(key, kind, g)])
            .chain(ekeys.iter().map(|&(eg, tp)| self.emb_map[&(key, eg, tp)]))
            .collect();
        let done = self.array.read_batch(&ppas, at)?;
        self.counters.page_fetches += ppas.len() as u64;
        let mut token_pages = Vec::with_capacity(tkeys.len());
        for (i, &(kind, g)) in tkeys.iter().enumerate() {
            token_pages.push((kind, g, self.array.page_data(ppas[i])?.to_vec()));
        }
        let mut emb_pages = Vec::with_capacity(ekeys.len());
        for (i, &(eg, tp)) in ekeys.iter().enumerate() {
            emb_pages.push((eg, tp, self.array.page_data(ppas[tkeys.len() + i])?.to_vec()));
        }
        self.drain_retirements(done)?;
        Ok((StreamExport { buf, token_pages, emb_pages }, done))
    }

    /// Program an exported stream into this FTL under `key`: pages land
    /// through the normal placement path (same channel formula as the
    /// append path, so the striping invariant holds) and the DRAM stream
    /// state is installed verbatim.
    pub fn import_stream(&mut self, key: StreamKey, exp: &StreamExport, at: Time) -> Result<Time> {
        let chans = self.array.spec.channels;
        let mut done = at;
        for (kind, g, data) in &exp.token_pages {
            let ch = match kind {
                KvKind::K => (key.head as usize + *g as usize) % chans,
                KvKind::V => (key.head as usize + *g as usize + 1) % chans,
            };
            let tag = PageTag::Token { key, kind: *kind, group: *g };
            done = done.max(self.stage_page(tag, ch, data, at)?);
        }
        for (eg, tp, data) in &exp.emb_pages {
            let ch = (key.head as usize + *eg as usize + *tp as usize) % chans;
            let tag = PageTag::Emb { key, eg: *eg, tpage: *tp };
            done = done.max(self.stage_page(tag, ch, data, at)?);
        }
        self.counters.host_bytes += exp
            .token_pages
            .iter()
            .map(|(_, _, d)| d.len() as u64)
            .chain(exp.emb_pages.iter().map(|(_, _, d)| d.len() as u64))
            .sum::<u64>();
        self.streams.insert(key, exp.buf.clone());
        Ok(done)
    }

    /// Retired bad blocks so far.
    pub fn bad_blocks(&self) -> usize {
        self.bad.len()
    }

    /// Keys of every live stream on this device, sorted (deterministic
    /// enumeration order for replica restore).
    pub fn stream_keys(&self) -> Vec<StreamKey> {
        let mut keys: Vec<StreamKey> = self.streams.keys().copied().collect();
        keys.sort();
        keys
    }

    /// Internal-consistency audit for the property tests: every page
    /// accounting identity the promote/demote/GC/free/share machinery
    /// must conserve.  Cheap enough to run after every op on the tiny
    /// geometry.
    pub fn audit(&self) -> Result<()> {
        let geo = self.array.geo;
        // physical valid pages == reverse-map population, per block and total
        let mut sum_valid = 0usize;
        for b in 0..geo.total_blocks() {
            let ba = BlockAddr(b);
            let phys = self.array.valid_pages(ba).len();
            let acct = self.block_valid[b] as usize;
            if phys != acct {
                bail!("block {b}: {phys} valid pages on flash but block_valid={acct}");
            }
            sum_valid += acct;
        }
        if sum_valid != self.rev.len() {
            bail!("sum(block_valid)={} != rev.len()={}", sum_valid, self.rev.len());
        }
        // shared lists are real shares and rev holds the canonical owner
        for (ppa, refs) in &self.shared {
            if refs.len() < 2 {
                bail!("shared list of page {} has {} owners", ppa.0, refs.len());
            }
            if self.rev.get(ppa) != Some(&refs[0]) {
                bail!("page {}: rev tag is not the canonical shared owner", ppa.0);
            }
        }
        // every forward mapping is owned by its page, and maps back
        let owners = |ppa: Ppa| -> Vec<PageTag> {
            match self.shared.get(&ppa) {
                Some(refs) => refs.clone(),
                None => self.rev.get(&ppa).map(|&t| vec![t]).unwrap_or_default(),
            }
        };
        for (&(key, kind, g), &ppa) in &self.token_map {
            let tag = PageTag::Token { key, kind, group: g };
            if !owners(ppa).contains(&tag) {
                bail!("token map entry {key:?}/{kind:?}/{g} not among page {}'s owners", ppa.0);
            }
        }
        for (&(key, eg, tp), &ppa) in &self.emb_map {
            let tag = PageTag::Emb { key, eg, tpage: tp };
            if !owners(ppa).contains(&tag) {
                bail!("emb map entry {key:?}/{eg}/{tp} not among page {}'s owners", ppa.0);
            }
        }
        // every owner tag resolves back to its page
        for (&ppa, _) in &self.rev {
            for tag in owners(ppa) {
                let mapped = match tag {
                    PageTag::Token { key, kind, group } => {
                        self.token_map.get(&(key, kind, group)).copied()
                    }
                    PageTag::Emb { key, eg, tpage } => self.emb_map.get(&(key, eg, tpage)).copied(),
                };
                if mapped != Some(ppa) {
                    bail!("owner tag {tag:?} of page {} maps to {mapped:?}", ppa.0);
                }
            }
        }
        // pool accounting: free, bad, and open sets are disjoint, and
        // free/bad blocks hold no valid pages
        for (ch, pool) in self.free.iter().enumerate() {
            for &b in pool {
                if geo.block_channel(b) != ch {
                    bail!("block {} pooled on wrong channel {ch}", b.0);
                }
                if self.block_valid[b.0] != 0 {
                    bail!("free block {} still has valid pages", b.0);
                }
                if self.bad.contains(&b) {
                    bail!("bad block {} is in the free pool", b.0);
                }
            }
        }
        for &b in &self.bad {
            if self.block_valid[b.0] != 0 {
                bail!("bad block {} still has valid pages", b.0);
            }
            if self.open.iter().any(|&o| o == Some(b)) {
                bail!("bad block {} is still an open block", b.0);
            }
        }
        Ok(())
    }

    // ---- cross-request prefix caching --------------------------------------
    //
    // The content-addressed index maps boundary hashes of token-id
    // chunks to pseudo-slots whose stream mappings alias a donor's
    // sealed pages (refcounted — zero flash I/O).  Registration pins the
    // pages past the donor's `free_slot`; attachment aliases them again
    // under a new sequence's own stream keys and rebuilds the DRAM
    // stream state, so the fetch path needs no sharing awareness at all.

    /// Register a donor slot's sealed prefix under its content hashes.
    /// `bounds[i] = (boundary hash, local tokens at that boundary)`,
    /// ascending; local tokens is how many of the boundary's tokens this
    /// device's FTL holds (== global tokens under head sharding, the
    /// round-robin group share under context striping — always a
    /// multiple of `n`).  Boundaries already in the index are kept (first
    /// registration wins; the donor's pages are content-identical by
    /// construction).  Returns the pseudo-slots evicted to stay under
    /// the index capacity, so the caller can purge any DRAM-tier copies.
    pub fn register_prefix(&mut self, donor: u32, bounds: &[(u64, usize)]) -> Vec<u32> {
        let fresh: Vec<(u64, usize)> =
            bounds.iter().copied().filter(|(h, _)| !self.prefix_index.contains_key(h)).collect();
        if fresh.is_empty() {
            return Vec::new();
        }
        let tokens = fresh.iter().map(|&(_, t)| t).max().unwrap();
        let n = self.cfg.n;
        let t_emb = self.tokens_per_emb_page;
        let egs = (self.cfg.d_head / self.cfg.m) as u16;
        let pslot = self.next_pslot;
        self.next_pslot += 1;
        let keys = self.stream_keys(donor);
        let mut streams = Vec::with_capacity(keys.len());
        for key in &keys {
            let pkey = StreamKey { slot: pslot, layer: key.layer, head: key.head };
            for g in 0..(tokens / n) as u32 {
                for kind in [KvKind::K, KvKind::V] {
                    if let Some(&ppa) = self.token_map.get(&(*key, kind, g)) {
                        self.token_map.insert((pkey, kind, g), ppa);
                        self.add_ref(ppa, PageTag::Token { key: pkey, kind, group: g });
                    }
                }
            }
            for tp in 0..(tokens / t_emb) as u32 {
                for eg in 0..egs {
                    if let Some(&ppa) = self.emb_map.get(&(*key, eg, tp)) {
                        self.emb_map.insert((pkey, eg, tp), ppa);
                        self.add_ref(ppa, PageTag::Emb { key: pkey, eg, tpage: tp });
                    }
                }
            }
            streams.push((key.layer, key.head));
        }
        let hashes: Vec<u64> = fresh.iter().map(|&(h, _)| h).collect();
        for &(h, t) in &fresh {
            self.prefix_index.insert(h, (pslot, t));
        }
        let tick = self.prefix_clock;
        self.prefix_clock += 1;
        self.prefix_regs.insert(pslot, PrefixReg { streams, tokens, hashes, last_use: tick });
        self.counters.prefix_registrations += 1;

        let mut evicted = Vec::new();
        while self.prefix_regs.len() > PREFIX_INDEX_CAP {
            let victim = self
                .prefix_regs
                .iter()
                .min_by_key(|(&p, r)| (r.last_use, p))
                .map(|(&p, _)| p)
                .unwrap();
            self.release_prefix(victim);
            evicted.push(victim);
        }
        evicted
    }

    /// Longest registered boundary among `hashes` (one hash per complete
    /// group, ascending — [`prefix_hashes`]).  Returns the boundary
    /// index; the caller derives the hit length as `(i + 1) * n` global
    /// tokens.  Read-only: LRU state moves at attach time, never here.
    pub fn lookup_prefix(&self, hashes: &[u64]) -> Option<usize> {
        hashes.iter().rposition(|h| self.prefix_index.contains_key(h))
    }

    /// Attach a cached prefix to `slot`: alias the registered
    /// pseudo-slot's pages into the slot's own mappings (refcounted,
    /// zero flash I/O) and rebuild the DRAM stream state — token count,
    /// embedding tail, running v̄ — exactly as if the rows had been
    /// appended, from the sealed pages (which hold the quantised prefix
    /// rows, so the reconstruction is bit-exact).  Returns the
    /// pseudo-slot and the local tokens attached.
    pub fn attach_prefix(&mut self, hash: u64, slot: u32) -> Result<(u32, usize)> {
        let &(pslot, tokens) = self
            .prefix_index
            .get(&hash)
            .ok_or_else(|| anyhow!("attach of unregistered prefix hash {hash:#x}"))?;
        let tick = self.prefix_clock;
        self.prefix_clock += 1;
        let reg = self
            .prefix_regs
            .get_mut(&pslot)
            .ok_or_else(|| anyhow!("prefix index points at dead pseudo-slot {pslot}"))?;
        reg.last_use = tick;
        let stream_lh = reg.streams.clone();
        let n = self.cfg.n;
        let d = self.cfg.d_head;
        let t_emb = self.tokens_per_emb_page;
        let egs = (d / self.cfg.m) as u16;
        for (layer, head) in stream_lh {
            let pkey = StreamKey { slot: pslot, layer, head };
            let skey = StreamKey { slot, layer, head };
            for g in 0..(tokens / n) as u32 {
                for kind in [KvKind::K, KvKind::V] {
                    let ppa = *self
                        .token_map
                        .get(&(pkey, kind, g))
                        .ok_or_else(|| anyhow!("registered prefix lost group {g}"))?;
                    self.token_map.insert((skey, kind, g), ppa);
                    self.add_ref(ppa, PageTag::Token { key: skey, kind, group: g });
                }
            }
            for tp in 0..(tokens / t_emb) as u32 {
                for eg in 0..egs {
                    let ppa = *self
                        .emb_map
                        .get(&(pkey, eg, tp))
                        .ok_or_else(|| anyhow!("registered prefix lost emb page {tp}"))?;
                    self.emb_map.insert((skey, eg, tp), ppa);
                    self.add_ref(ppa, PageTag::Emb { key: skey, eg, tpage: tp });
                }
            }
            // rebuild the DRAM stream state functionally (no timed I/O)
            let mut vbar_sum = vec![0.0f32; d];
            for g in 0..tokens / n {
                let ppa = self.token_map[&(skey, KvKind::V, g as u32)];
                let rows = decode_rows(self.array.page_data(ppa)?, n * d);
                for r in rows.chunks_exact(d) {
                    for (s, &x) in vbar_sum.iter_mut().zip(r) {
                        *s += x;
                    }
                }
            }
            let emb_base = (tokens / t_emb) * t_emb;
            let mut emb_tail = Vec::with_capacity((tokens - emb_base) * d);
            for t in emb_base..tokens {
                let ppa = self.token_map[&(skey, KvKind::K, (t / n) as u32)];
                let rows = decode_rows(self.array.page_data(ppa)?, n * d);
                emb_tail.extend_from_slice(&rows[(t % n) * d..(t % n + 1) * d]);
            }
            self.streams.insert(
                skey,
                StreamBuf {
                    count: tokens,
                    k_tail: Vec::new(),
                    v_tail: Vec::new(),
                    emb_tail,
                    vbar_sum,
                },
            );
        }
        self.counters.prefix_attaches += 1;
        self.counters.prefix_tokens_attached += tokens as u64;
        Ok((pslot, tokens))
    }

    /// Drop one registration: its index entries and the pseudo-slot's
    /// page references.  Pages shared with live sequences survive; pages
    /// nobody else owns are invalidated for GC.
    fn release_prefix(&mut self, pslot: u32) {
        let Some(reg) = self.prefix_regs.remove(&pslot) else { return };
        for h in &reg.hashes {
            self.prefix_index.remove(h);
        }
        let n = self.cfg.n;
        let t_emb = self.tokens_per_emb_page;
        let egs = (self.cfg.d_head / self.cfg.m) as u16;
        for &(layer, head) in &reg.streams {
            let pkey = StreamKey { slot: pslot, layer, head };
            for g in 0..(reg.tokens / n) as u32 {
                for kind in [KvKind::K, KvKind::V] {
                    if let Some(ppa) = self.token_map.remove(&(pkey, kind, g)) {
                        if self.release_ref(ppa, PageTag::Token { key: pkey, kind, group: g }) {
                            self.array.invalidate(ppa);
                            self.block_valid[self.array.geo.block_of(ppa).0] =
                                self.block_valid[self.array.geo.block_of(ppa).0].saturating_sub(1);
                        }
                    }
                }
            }
            for tp in 0..(reg.tokens / t_emb) as u32 {
                for eg in 0..egs {
                    if let Some(ppa) = self.emb_map.remove(&(pkey, eg, tp)) {
                        if self.release_ref(ppa, PageTag::Emb { key: pkey, eg, tpage: tp }) {
                            self.array.invalidate(ppa);
                            self.block_valid[self.array.geo.block_of(ppa).0] =
                                self.block_valid[self.array.geo.block_of(ppa).0].saturating_sub(1);
                        }
                    }
                }
            }
        }
    }

    /// Registered prefixes currently held (index size in pseudo-slots).
    pub fn prefix_registrations(&self) -> usize {
        self.prefix_regs.len()
    }

    // ---- lifecycle ---------------------------------------------------------

    /// Drop every mapping of sequence `slot` and erase fully-dead blocks.
    pub fn free_slot(&mut self, slot: u32, at: Time) -> Result<Time> {
        let tkeys: Vec<_> = self
            .token_map
            .keys()
            .filter(|(k, _, _)| k.slot == slot)
            .cloned()
            .collect();
        for k in tkeys {
            let ppa = self.token_map.remove(&k).unwrap();
            let tag = PageTag::Token { key: k.0, kind: k.1, group: k.2 };
            if self.release_ref(ppa, tag) {
                self.array.invalidate(ppa);
                self.block_valid[self.array.geo.block_of(ppa).0] =
                    self.block_valid[self.array.geo.block_of(ppa).0].saturating_sub(1);
            }
        }
        let ekeys: Vec<_> = self
            .emb_map
            .keys()
            .filter(|(k, _, _)| k.slot == slot)
            .cloned()
            .collect();
        for k in ekeys {
            let ppa = self.emb_map.remove(&k).unwrap();
            let tag = PageTag::Emb { key: k.0, eg: k.1, tpage: k.2 };
            if self.release_ref(ppa, tag) {
                self.array.invalidate(ppa);
                self.block_valid[self.array.geo.block_of(ppa).0] =
                    self.block_valid[self.array.geo.block_of(ppa).0].saturating_sub(1);
            }
        }
        self.streams.retain(|k, _| k.slot != slot);

        // erase fully-dead full blocks eagerly (cheap: sequential lifetimes)
        let geo = self.array.geo;
        let mut t = at;
        for b in 0..geo.total_blocks() {
            let ba = BlockAddr(b);
            if self.block_valid[b] == 0
                && self.array.programmed_pages(ba) == geo.pages_per_block
                && self.open.iter().all(|&o| o != Some(ba))
            {
                t = t.max(self.array.erase(ba, at)?);
                let ch = geo.block_channel(ba);
                self.free[ch].push_back(ba);
            }
        }
        Ok(t)
    }

    /// Flash bytes programmed / host bytes written (>= 1.0; the group
    /// buffer + block batching keep it near 1 for streaming KV).
    pub fn write_amplification(&self) -> f64 {
        if self.counters.host_bytes == 0 {
            return 1.0;
        }
        self.array.counters.bytes_programmed as f64 / self.counters.host_bytes as f64
    }

    pub fn free_blocks(&self) -> usize {
        self.free.iter().map(|f| f.len()).sum()
    }

    /// Flash channel a sealed token group's page lives on (None if still
    /// in the DRAM tail) — used by the placement ablation and tests to
    /// verify the striping invariant.
    pub fn token_group_channel(&self, key: StreamKey, kind: KvKind, group: usize) -> Option<usize> {
        self.token_map
            .get(&(key, kind, group as u32))
            .map(|&ppa| self.array.geo.page_channel(ppa))
    }

    /// Die (within its channel) a sealed token group's page lives on —
    /// the placement tests check the round-robin spread, including
    /// after GC relocation.
    pub fn token_group_die(&self, key: StreamKey, kind: KvKind, group: usize) -> Option<usize> {
        self.token_map
            .get(&(key, kind, group as u32))
            .map(|&ppa| self.array.geo.block_die(self.array.geo.block_of(ppa)))
    }
}

#[cfg(test)]
mod tests;
