//! FTL unit + property tests: mapping correctness, tail semantics, group
//! sharing, striping, GC, write amplification.

use super::*;
use crate::config::hw::FlashSpec;
use crate::util::prop::check;
use crate::util::rng::Rng;

fn mk() -> KvFtl {
    // tiny flash: 512 B pages; d_head=32, n=8 (8*32*2=512 exact fit), m=4
    KvFtl::new(FlashSpec::tiny(), FtlConfig::micro_head()).unwrap()
}

fn key(slot: u32, layer: u16, head: u16) -> StreamKey {
    StreamKey { slot, layer, head }
}

fn row(rng: &mut Rng, d: usize) -> Vec<f32> {
    (0..d).map(|_| rng.normal_f32()).collect()
}

#[test]
fn config_validation() {
    let spec = FlashSpec::tiny();
    assert!(KvFtl::new(spec, FtlConfig { d_head: 32, m: 4, n: 9 }).is_err()); // >page
    assert!(KvFtl::new(spec, FtlConfig { d_head: 32, m: 5, n: 8 }).is_err()); // d%m
    assert_eq!(mk().tokens_per_emb_page(), 512 / (4 * 2));
}

#[test]
fn append_then_fetch_token_groups_exact() {
    let mut ftl = mk();
    let mut rng = Rng::new(1);
    let k = key(0, 0, 0);
    let mut all_k: Vec<Vec<f32>> = Vec::new();
    let mut all_v: Vec<Vec<f32>> = Vec::new();
    for _ in 0..24 {
        let kr = row(&mut rng, 32);
        let vr = row(&mut rng, 32);
        ftl.append_token(k, &kr, &vr, 0.0).unwrap();
        all_k.push(kr.iter().map(|&x| layout::q16(x)).collect());
        all_v.push(vr.iter().map(|&x| layout::q16(x)).collect());
    }
    // 24 tokens = 3 sealed groups (n=8); fetch groups 0 and 2
    let (rows, t) = ftl.fetch_token_groups(k, KvKind::K, &[0, 2], 0.0).unwrap();
    assert!(t > 0.0);
    assert_eq!(rows.len(), 2);
    for g in rows {
        for i in 0..8 {
            assert_eq!(
                &g.rows[i * 32..(i + 1) * 32],
                &all_k[g.base + i][..],
                "token {}",
                g.base + i
            );
        }
    }
    let (vrows, _) = ftl.fetch_token_groups(k, KvKind::V, &[1], 0.0).unwrap();
    assert_eq!(&vrows[0].rows[..32], &all_v[8][..]);
}

#[test]
fn tail_group_served_from_dram() {
    let mut ftl = mk();
    let mut rng = Rng::new(2);
    let k = key(0, 1, 3);
    for _ in 0..11 {
        // 1 sealed group + 3 tail tokens
        let kr = row(&mut rng, 32);
        let vr = row(&mut rng, 32);
        ftl.append_token(k, &kr, &vr, 0.0).unwrap();
    }
    let reads_before = ftl.array.counters.page_reads;
    let (rows, _) = ftl.fetch_token_groups(k, KvKind::K, &[1], 0.0).unwrap();
    assert_eq!(ftl.array.counters.page_reads, reads_before, "tail must not hit flash");
    assert_eq!(rows[0].base, 8);
    assert_eq!(ftl.counters.tail_hits, 1);
    // tail rows beyond appended tokens are zero-padded
    assert!(rows[0].rows[3 * 32..].iter().all(|&x| x == 0.0));
}

#[test]
fn emb_channels_match_token_rows() {
    let mut ftl = mk();
    let mut rng = Rng::new(3);
    let k = key(2, 0, 1);
    let mut truth: Vec<Vec<f32>> = Vec::new();
    for _ in 0..100 {
        let kr = row(&mut rng, 32);
        ftl.append_token(k, &kr, &row(&mut rng, 32), 0.0).unwrap();
        truth.push(kr.iter().map(|&x| layout::q16(x)).collect());
    }
    // channels spanning sealed pages (64 tokens/emb-page) and the tail
    let chans = [0usize, 5, 17, 31];
    let (lanes, _) = ftl.fetch_emb_channels(k, &chans, 100, 0.0).unwrap();
    for (ci, &c) in chans.iter().enumerate() {
        for t in 0..100 {
            assert_eq!(lanes[ci][t], truth[t][c], "chan {c} tok {t}");
        }
    }
}

#[test]
fn emb_page_fetch_shared_within_group() {
    let mut ftl = mk();
    let mut rng = Rng::new(4);
    let k = key(0, 0, 0);
    for _ in 0..64 {
        ftl.append_token(k, &row(&mut rng, 32), &row(&mut rng, 32), 0.0).unwrap();
    }
    let before = ftl.array.counters.page_reads;
    // channels 0..3 live in the same embedding group (m=4): ONE page read
    ftl.fetch_emb_channels(k, &[0, 1, 2, 3], 64, 0.0).unwrap();
    assert_eq!(ftl.array.counters.page_reads - before, 1);
    let before = ftl.array.counters.page_reads;
    // channels 0 and 4 live in different groups: two page reads
    ftl.fetch_emb_channels(k, &[0, 4], 64, 0.0).unwrap();
    assert_eq!(ftl.array.counters.page_reads - before, 2);
}

#[test]
fn vbar_tracks_running_mean() {
    let mut ftl = mk();
    let k = key(0, 0, 0);
    let mut expect = vec![0.0f32; 32];
    for i in 0..10 {
        let kr = vec![0.0; 32];
        let vr: Vec<f32> = (0..32).map(|c| (i * 32 + c) as f32 * 0.125).collect();
        for c in 0..32 {
            expect[c] += layout::q16(vr[c]);
        }
        ftl.append_token(k, &kr, &vr, 0.0).unwrap();
    }
    let vbar = ftl.vbar(k).unwrap();
    for c in 0..32 {
        assert!((vbar[c] - expect[c] / 10.0).abs() < 1e-4);
    }
}

#[test]
fn head_groups_stripe_across_channels() {
    let mut ftl = mk();
    let mut rng = Rng::new(5);
    let k = key(0, 0, 0);
    for _ in 0..32 {
        // 4 sealed K groups
        ftl.append_token(k, &row(&mut rng, 32), &row(&mut rng, 32), 0.0).unwrap();
    }
    let geo = ftl.array.geo;
    let mut channels_used = std::collections::HashSet::new();
    for g in 0..4u32 {
        let ppa = ftl.token_map[&(k, KvKind::K, g)];
        channels_used.insert(geo.page_channel(ppa));
    }
    // tiny spec has 2 channels; 4 groups must use both
    assert_eq!(channels_used.len(), 2);
}

#[test]
fn free_slot_releases_capacity_and_gc_reclaims() {
    let mut ftl = mk();
    let mut rng = Rng::new(6);
    // fill a significant fraction of the 128-page tiny device, then free
    // and refill several times: GC + erase must keep it running
    for round in 0..6u32 {
        for slot in 0..2u32 {
            let k = key(round * 2 + slot, 0, slot as u16);
            for _ in 0..64 {
                ftl.append_token(k, &row(&mut rng, 32), &row(&mut rng, 32), 0.0)
                    .expect("device should never fill with frees");
            }
        }
        for slot in 0..2u32 {
            ftl.free_slot(round * 2 + slot, 0.0).unwrap();
        }
    }
    assert!(ftl.array.counters.block_erases > 0, "frees must trigger erases");
}

#[test]
fn write_amplification_near_one_for_streaming() {
    let mut ftl = mk();
    let mut rng = Rng::new(7);
    let k = key(0, 0, 0);
    for _ in 0..64 {
        ftl.append_token(k, &row(&mut rng, 32), &row(&mut rng, 32), 0.0).unwrap();
    }
    let wa = ftl.write_amplification();
    // K written twice (token- + emb-indexed) => host sees 2B/elem for K+V,
    // flash programs K twice: WA ~ 1.5 plus padding slack
    assert!((1.2..2.0).contains(&wa), "wa={wa}");
}

#[test]
fn fetch_beyond_appended_errors() {
    let mut ftl = mk();
    let mut rng = Rng::new(8);
    let k = key(0, 0, 0);
    for _ in 0..8 {
        ftl.append_token(k, &row(&mut rng, 32), &row(&mut rng, 32), 0.0).unwrap();
    }
    assert!(ftl.fetch_token_groups(k, KvKind::K, &[5], 0.0).is_err());
    assert!(ftl.fetch_emb_channels(k, &[0], 9, 0.0).is_err());
    assert!(ftl.fetch_emb_channels(k, &[99], 4, 0.0).is_err());
}

/// Append `n_tok` tokens to every (layer 0, head 0|1) stream of `slot`,
/// returning the quantised K truth rows (same for both heads).
fn fill_slot(ftl: &mut KvFtl, slot: u32, n_tok: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    let mut truth = Vec::new();
    for _ in 0..n_tok {
        let kr = row(&mut rng, 32);
        let vr = row(&mut rng, 32);
        for head in 0..2u16 {
            ftl.append_token(key(slot, 0, head), &kr, &vr, 0.0).unwrap();
        }
        truth.push(kr.iter().map(|&x| layout::q16(x)).collect());
    }
    truth
}

/// Register slot 0's 24-token prefix under the hashes of `prompt` and
/// return the boundary hash list.
fn register(ftl: &mut KvFtl, prompt: &[i32]) -> Vec<u64> {
    let hashes = prefix_hashes(prompt, 8);
    let bounds: Vec<(u64, usize)> =
        hashes.iter().enumerate().map(|(i, &h)| (h, (i + 1) * 8)).collect();
    assert!(ftl.register_prefix(0, &bounds).is_empty());
    hashes
}

#[test]
fn prefix_attach_aliases_pages_and_reconstructs_stream() {
    let mut ftl = mk();
    let truth = fill_slot(&mut ftl, 0, 24, 11);
    let prompt: Vec<i32> = (0..24).collect();
    let hashes = register(&mut ftl, &prompt);
    assert_eq!(hashes.len(), 3);
    // longest-boundary lookup, including past the registered range
    let longer = prefix_hashes(&[&prompt[..], &[99, 98][..]].concat(), 8);
    assert_eq!(ftl.lookup_prefix(&longer), Some(2));

    let physical = ftl.mapped_pages_total();
    let programmed = ftl.array.counters.bytes_programmed;
    let (_pslot, toks) = ftl.attach_prefix(hashes[2], 5).unwrap();
    assert_eq!(toks, 24);
    // sharing is metadata-only: no flash programs, no new physical pages
    assert_eq!(ftl.array.counters.bytes_programmed, programmed);
    assert_eq!(ftl.mapped_pages_total(), physical);
    assert_eq!(ftl.counters.prefix_attaches, 1);
    assert_eq!(ftl.counters.prefix_tokens_attached, 24);

    let k5 = key(5, 0, 1);
    assert_eq!(ftl.tokens_appended(k5), 24);
    // reconstructed v̄ matches the donor's bit-exactly
    assert_eq!(ftl.vbar(k5).unwrap(), ftl.vbar(key(0, 0, 1)).unwrap());
    let (rows, _) = ftl.fetch_token_groups(k5, KvKind::K, &[0, 1, 2], 0.0).unwrap();
    for g in rows {
        for i in 0..8 {
            assert_eq!(&g.rows[i * 32..(i + 1) * 32], &truth[g.base + i][..]);
        }
    }
    // the attached stream keeps appending seamlessly past the prefix
    let mut rng = Rng::new(12);
    let (kr, vr) = (row(&mut rng, 32), row(&mut rng, 32));
    ftl.append_token(k5, &kr, &vr, 0.0).unwrap();
    let (tail, _) = ftl.fetch_token_groups(k5, KvKind::K, &[3], 0.0).unwrap();
    assert_eq!(tail[0].base, 24);
    let kq: Vec<f32> = kr.iter().map(|&x| layout::q16(x)).collect();
    assert_eq!(&tail[0].rows[..32], &kq[..]);
    // and the emb view of the attached stream agrees token-for-token
    let (lanes, _) = ftl.fetch_emb_channels(k5, &[7], 25, 0.0).unwrap();
    for t in 0..24 {
        assert_eq!(lanes[0][t], truth[t][7], "emb chan 7 tok {t}");
    }
    assert_eq!(lanes[0][24], kq[7]);
}

#[test]
fn shared_group_gc_relocation_updates_every_owner() {
    let mut ftl = mk();
    let truth = fill_slot(&mut ftl, 0, 24, 21);
    let prompt: Vec<i32> = (100..124).collect();
    let hashes = register(&mut ftl, &prompt);
    ftl.attach_prefix(hashes[2], 5).unwrap();
    // churn other slots until GC relocates pages on the tiny device
    let mut rng = Rng::new(22);
    for round in 0..6u32 {
        let k = key(10 + round, 0, 0);
        for _ in 0..64 {
            ftl.append_token(k, &row(&mut rng, 32), &row(&mut rng, 32), 0.0).unwrap();
        }
        ftl.free_slot(10 + round, 0.0).unwrap();
    }
    assert!(ftl.counters.gc_relocations > 0, "churn must trigger GC");
    // every owner's mapping moved together: donor and sharer still alias
    // the same physical page, and the data survived relocation
    for head in 0..2u16 {
        for g in 0..3u32 {
            for kind in [KvKind::K, KvKind::V] {
                assert_eq!(
                    ftl.token_map[&(key(0, 0, head), kind, g)],
                    ftl.token_map[&(key(5, 0, head), kind, g)],
                    "head {head} group {g} diverged"
                );
            }
        }
        let (rows, _) =
            ftl.fetch_token_groups(key(5, 0, head), KvKind::K, &[0, 1, 2], 0.0).unwrap();
        for g in rows {
            for i in 0..8 {
                assert_eq!(&g.rows[i * 32..(i + 1) * 32], &truth[g.base + i][..]);
            }
        }
    }
}

#[test]
fn drop_on_shared_group_detaches_without_freeing() {
    let mut ftl = mk();
    let truth = fill_slot(&mut ftl, 0, 24, 31);
    let prompt: Vec<i32> = (200..224).collect();
    let hashes = register(&mut ftl, &prompt);
    let (pslot, _) = ftl.attach_prefix(hashes[2], 5).unwrap();
    let physical = ftl.mapped_pages_total();

    // drop-on-resume on the sharer: detach, don't free
    assert!(!ftl.free_token_group(key(5, 0, 0), 0));
    assert_eq!(ftl.counters.dropped_groups, 0);
    assert!(ftl.counters.shared_releases >= 2, "K and V must both detach");
    assert_eq!(ftl.mapped_pages_total(), physical);
    // the donor still reads its group back intact
    let (rows, _) = ftl.fetch_token_groups(key(0, 0, 0), KvKind::K, &[0], 0.0).unwrap();
    assert_eq!(&rows[0].rows[..32], &truth[0][..]);

    // donor drops too: the registration still pins the pages
    assert!(!ftl.free_token_group(key(0, 0, 0), 0));
    assert_eq!(ftl.counters.dropped_groups, 0);
    assert_eq!(ftl.mapped_pages_total(), physical);

    // last owner out reclaims the flash
    ftl.release_prefix(pslot);
    assert!(ftl.mapped_pages_total() < physical);
    assert_eq!(ftl.prefix_registrations(), 0);
}

#[test]
fn donor_free_slot_keeps_registered_prefix_alive() {
    let mut ftl = mk();
    let truth = fill_slot(&mut ftl, 0, 24, 41);
    let prompt: Vec<i32> = (300..324).collect();
    let hashes = register(&mut ftl, &prompt);
    ftl.free_slot(0, 0.0).unwrap();
    // the index still serves the prefix after the donor retired
    assert_eq!(ftl.lookup_prefix(&hashes), Some(2));
    let (_, toks) = ftl.attach_prefix(hashes[2], 7).unwrap();
    assert_eq!(toks, 24);
    let (rows, _) = ftl.fetch_token_groups(key(7, 0, 0), KvKind::K, &[0, 1, 2], 0.0).unwrap();
    for g in rows {
        for i in 0..8 {
            assert_eq!(&g.rows[i * 32..(i + 1) * 32], &truth[g.base + i][..]);
        }
    }
}

#[test]
fn prop_random_append_fetch_consistency() {
    check(
        "ftl_fetch_matches_appends",
        25,
        |r| (r.range(1, 90), r.range(0, 3) as u16, r.next_u64()),
        |&(n_tok, head, seed)| {
            let mut ftl = mk();
            let mut rng = Rng::new(seed);
            let k = key(0, 0, head);
            let mut truth: Vec<Vec<f32>> = Vec::new();
            for _ in 0..n_tok {
                let kr = row(&mut rng, 32);
                ftl.append_token(k, &kr, &row(&mut rng, 32), 0.0).map_err(|e| e.to_string())?;
                truth.push(kr.iter().map(|&x| layout::q16(x)).collect());
            }
            // every complete-or-tail group fetches back exactly
            let n_groups = n_tok.div_ceil(8);
            let groups: Vec<usize> = (0..n_groups).collect();
            let (rows, _) =
                ftl.fetch_token_groups(k, KvKind::K, &groups, 0.0).map_err(|e| e.to_string())?;
            for g in rows {
                for i in 0..8 {
                    let t = g.base + i;
                    if t >= n_tok {
                        continue;
                    }
                    if g.rows[i * 32..(i + 1) * 32] != truth[t][..] {
                        return Err(format!("mismatch at token {t}"));
                    }
                }
            }
            // and the emb view agrees on a random channel
            let c = (seed % 32) as usize;
            let (lanes, _) =
                ftl.fetch_emb_channels(k, &[c], n_tok, 0.0).map_err(|e| e.to_string())?;
            for t in 0..n_tok {
                if lanes[0][t] != truth[t][c] {
                    return Err(format!("emb mismatch chan {c} tok {t}"));
                }
            }
            Ok(())
        },
    );
}

/// Page accounting is conserved under randomized interleavings of
/// append / promote / demote / group-drop / slot-free / prefix-attach
/// over a shared prefix group: `audit()` holds after every single op
/// (forward/reverse maps stay a bijection, shared owner lists stay
/// canonical, block valid counts match physical state), and once every
/// slot is freed and every registration released the reverse map drains
/// to exactly zero mapped pages — nothing leaks, nothing double-frees.
#[test]
fn prop_page_accounting_conserved_under_shared_churn() {
    check(
        "ftl_page_accounting_conserved",
        15,
        |r| r.next_u64(),
        |&seed| {
            let mut ftl = mk();
            let mut rng = Rng::new(seed);
            fill_slot(&mut ftl, 0, 24, seed ^ 1);
            let prompt: Vec<i32> = (0..24).collect();
            let hashes = register(&mut ftl, &prompt);
            let mut pslots: Vec<u32> = Vec::new();
            let mut used: Vec<u32> = vec![0];
            for (i, &h) in hashes.iter().enumerate() {
                let slot = 5 + i as u32;
                let (p, _) = ftl.attach_prefix(h, slot).map_err(|e| format!("attach: {e:#}"))?;
                if !pslots.contains(&p) {
                    pslots.push(p);
                }
                used.push(slot);
            }
            let mut next_attach = 40u32;
            for step in 0..80 {
                match rng.below(6) {
                    // churn: one full 8-token group onto a scratch slot
                    // (skipped near capacity — the tiny device holds 256
                    // pages and GC needs its relocation reserve)
                    0 if ftl.mapped_pages_total() < 160 => {
                        let slot = 10 + rng.below(4) as u32;
                        if !used.contains(&slot) {
                            used.push(slot);
                        }
                        let k = key(slot, 0, 0);
                        for _ in 0..8 {
                            ftl.append_token(k, &row(&mut rng, 32), &row(&mut rng, 32), 0.0)
                                .map_err(|e| format!("step {step}: append: {e:#}"))?;
                        }
                    }
                    0 => {}
                    1 => {
                        // promote a donor group (Err when already dropped)
                        let head = rng.below(2) as u16;
                        let g = rng.below(3);
                        let _ = ftl.promote_group(key(0, 0, head), KvKind::K, g, 0.0);
                    }
                    2 => {
                        let head = rng.below(2) as u16;
                        ftl.demote_group(key(0, 0, head), KvKind::V, rng.below(3));
                    }
                    3 => {
                        // drop-on-resume on any in-use slot: shared groups
                        // must detach, exclusive ones reclaim
                        let slot = used[rng.below(used.len())];
                        let head = rng.below(2) as u16;
                        ftl.free_token_group(key(slot, 0, head), rng.below(3));
                    }
                    4 => {
                        let slot = 10 + rng.below(4) as u32;
                        ftl.free_slot(slot, 0.0)
                            .map_err(|e| format!("step {step}: free_slot: {e:#}"))?;
                    }
                    _ => {
                        // late attach of a random boundary onto a fresh slot
                        let h = hashes[rng.below(hashes.len())];
                        if let Ok((p, _)) = ftl.attach_prefix(h, next_attach) {
                            if !pslots.contains(&p) {
                                pslots.push(p);
                            }
                            used.push(next_attach);
                            next_attach += 1;
                        }
                    }
                }
                ftl.audit().map_err(|e| format!("step {step}: audit: {e:#}"))?;
            }
            // teardown: every slot freed, every registration released —
            // the mapping must drain completely
            for &slot in &used {
                ftl.free_slot(slot, 0.0).map_err(|e| format!("teardown free: {e:#}"))?;
            }
            for &p in &pslots {
                ftl.release_prefix(p);
            }
            ftl.audit().map_err(|e| format!("final audit: {e:#}"))?;
            if ftl.prefix_registrations() != 0 {
                return Err(format!("{} registrations leaked", ftl.prefix_registrations()));
            }
            if ftl.mapped_pages_total() != 0 {
                return Err(format!("{} mapped pages leaked", ftl.mapped_pages_total()));
            }
            Ok(())
        },
    );
}
